"""Measured-recipe benchmark: autotuner vs Table-4 heuristic.

For each suite matrix pair, plan the product twice -- once through the
heuristic recipe, once through ``plan_spgemm(autotune=True)`` against a
fresh DB -- and time both frozen plans' numeric phases.  Rows carry the
work model (``flops`` / ``bytes_moved``), so the JSON trajectory gains
roofline columns for them, matching what the autotune DB itself records
with each winner.

``--smoke`` is the CI gate for the measured-mode contract:

  * the measured choice never loses to the heuristic choice by more
    than 5% on any suite matrix (best-of-5 on both, so one scheduler
    hiccup cannot fail the job);
  * a repeat recommend on the same structure is a DB hit with **zero**
    microbenchmarks, proven by the ``candidates_timed`` counter;
  * both plans agree bitwise-on-dense with the numpy oracle.

    PYTHONPATH=src python benchmarks/bench_autotune.py [--smoke]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, ".")

from repro.autotune import (PerfDB, measure_call_counts, measured_recommend,
                            reset_measure_calls)
from repro.core import plan_spgemm
from repro.core.spgemm import symbolic_flops
from repro.data.rmat import rmat_csr

from benchmarks.common import bench, emit, flops_rate


def suite(quick: bool = True):
    """(tag, a, b) pairs: skewed G500 (the mispriced regime), uniform ER,
    and a squaring -- the shapes Table 4 routes differently."""
    pairs = [
        ("g500_s7_axb", rmat_csr(7, 8, "G500", seed=0),
         rmat_csr(7, 8, "G500", seed=1)),
        ("er_s7_axa", rmat_csr(7, 4, "ER", seed=2), None),
    ]
    if not quick:
        pairs += [
            ("g500_s8_axa", rmat_csr(8, 8, "G500", seed=3), None),
            ("er_s8_axb", rmat_csr(8, 4, "ER", seed=4),
             rmat_csr(8, 4, "ER", seed=5)),
        ]
    return [(tag, a, (a if b is None else b)) for tag, a, b in pairs]


def _work_model(a, b, plan):
    """(flops, bytes) for the roofline columns: multiply-adds count 2."""
    from repro.analysis.roofline import spgemm_traffic_bytes
    flop = float(np.asarray(symbolic_flops(a, b)).sum())
    return 2.0 * flop, spgemm_traffic_bytes(
        n_rows=a.n_rows, nnz_a=float(a.nnz), flop=flop,
        nnz_c=float(plan.nnz_c))


def _pair(tag, a, b, db, iters):
    """Plan heuristic + measured, time both, emit rows; returns plans +
    timings."""
    heur = plan_spgemm(a, b, cache=False)
    meas = plan_spgemm(a, b, autotune=True, autotune_db=db, cache=False)
    assert heur.provenance == "heuristic" and meas.provenance == "measured"
    flops, nbytes = _work_model(a, b, heur)

    t_h = bench(lambda: heur.execute(a, b), iters=iters)
    emit(f"autotune,{tag},heuristic", t_h,
         f"algo={heur.algorithm};{flops_rate(flops / 2, t_h)}",
         flops=flops, bytes_moved=nbytes)
    t_m = bench(lambda: meas.execute(a, b), iters=iters)
    emit(f"autotune,{tag},measured", t_m,
         f"algo={meas.algorithm};t{meas.table_size};"
         f"speedup={t_h / t_m:.2f}x",
         flops=flops, bytes_moved=nbytes)
    return heur, meas, t_h, t_m


def run(quick: bool = True):
    """benchmarks.run suite entry (fresh DB per run: the rows compare the
    recipes, not a previous run's persisted winners)."""
    with tempfile.TemporaryDirectory() as d:
        db = PerfDB(os.path.join(d, "autotune.json"))
        for tag, a, b in suite(quick):
            _pair(tag, a, b, db, iters=2 if quick else 3)


def smoke():
    """CI gate for the measured-mode acceptance contract."""
    with tempfile.TemporaryDirectory() as d:
        db = PerfDB(os.path.join(d, "autotune.json"))
        for tag, a, b in suite(quick=True):
            heur, meas, t_h, t_m = _pair(tag, a, b, db, iters=5)

            # (1) measured never loses to the heuristic by > 5%
            assert t_m <= t_h * 1.05, \
                f"{tag}: measured {meas.algorithm} ({t_m*1e6:.0f}us) lost " \
                f"to heuristic {heur.algorithm} ({t_h*1e6:.0f}us) by " \
                f"{t_m / t_h:.3f}x"

            # (2) repeat recommend = DB hit, zero microbenchmarks
            reset_measure_calls()
            choice = measured_recommend(a, b, db=db)
            calls = measure_call_counts()
            assert choice is not None and choice.source == "db", choice
            assert choice.algorithm == meas.algorithm
            assert calls["candidates_timed"] == 0, \
                f"{tag}: repeat recommend measured: {calls}"
            assert calls["db_hits"] == 1, calls

            # (3) both recipes compute the same (correct) product
            cd = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
            assert np.allclose(np.asarray(meas.execute(a, b).to_dense()),
                               cd, atol=1e-3)
            assert np.allclose(np.asarray(heur.execute(a, b).to_dense()),
                               cd, atol=1e-3)
            print(f"autotune smoke {tag}: measured={meas.algorithm} "
                  f"heuristic={heur.algorithm} ratio={t_m / t_h:.3f}",
                  flush=True)
    print("bench_autotune smoke: OK", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="measured-mode acceptance assertions (CI gate)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    run(quick=not args.full)


if __name__ == "__main__":
    main()
