"""Batched-fleet benchmark (DESIGN.md section 13).

Two questions, on fleets of small R-MAT products:

  1. **Batched execute vs loop-of-planned**: a fleet of N products run as
     a handful of vmapped capacity-class programs
     (:func:`repro.core.batch.plan_batch`) vs N per-product
     ``SpGEMMPlan.execute`` dispatches -- the dispatch/fusion win that
     exists even after all inspection is amortized on both sides.
  2. **Capacity-class count vs fleet size**: how many programs a
     heterogeneous fleet actually compiles (p2 bucketing) against the
     one-program-per-member baseline, and the padding waste it buys them.

A third question rides along since the trace-context layer landed:
**Pallas vs the retired twin dispatch** -- the same fleet planned with
``algorithm="hash"`` (the batched-grid Pallas kernel under ``vmap``)
against ``algorithm="hash_jnp"`` (the jnp twin that used to be the only
batchable body), the measured cost of the gap that layer closed.

``--smoke`` runs a downscaled version with hard assertions -- batched ==
loop-of-planned bitwise per element, class-program count within the
``ceil(log2 spread) + 1`` p2 bound, zero re-inspection and zero program
builds on repeat execute, **batched beating loop-of-planned**, the
Pallas kernel (call counters, twin spy) being what the class programs
dispatch, and Pallas-vs-twin wall-clock within the backend's bound
(parity on compiled backends; a non-regression bound under interpret
mode, whose serial grid scan the twin never pays) -- used as the CI
smoke step.

    PYTHONPATH=src python benchmarks/bench_batch.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys

sys.path.insert(0, ".")

from repro.core import clear_plan_cache, plan_batch
from repro.kernels.spgemm_hash import ops as hash_ops

from benchmarks.common import (assert_bitwise_prefix,
                               batch_class_bound, batch_inspection_counters,
                               bench, counted, emit,
                               planned_loop as _planned_loop,
                               rmat_fleet as _fleet)


def batched_vs_loop(n_products: int, scale: int, tag: str, iters: int):
    pairs = _fleet(n_products, scale)
    clear_plan_cache()
    plan = plan_batch(pairs)
    loop = _planned_loop(plan, pairs)

    # warmup=2: the batched side compiles one program per capacity class
    # on its first call, the loop side one per product -- both must be
    # fully warm before the medians mean anything
    t_loop = bench(lambda: loop(), warmup=2, iters=iters)
    emit(f"batch,{tag},loop_of_planned", t_loop,
         f"products={n_products};programs={n_products}")
    t_bat = bench(lambda: plan.execute(pairs), warmup=2, iters=iters)
    emit(f"batch,{tag},batched_execute", t_bat,
         f"products={n_products};classes={plan.n_classes};"
         f"speedup_vs_loop={t_loop / t_bat:.2f}x")
    return plan, t_loop, t_bat


def pallas_vs_twin(n_products: int, scale: int, tag: str, iters: int):
    """Same fleet, ``hash`` (batched-grid Pallas under vmap) vs the
    retired ``hash_jnp`` twin dispatch.  Both sides fully planned and
    warm; the Pallas side additionally runs numeric-only (its plan froze
    ``indptr_c``), which is the structural half of the win."""
    pairs = _fleet(n_products, scale)
    clear_plan_cache()
    plan_pal = plan_batch(pairs, algorithm="hash")
    plan_twin = plan_batch(pairs, algorithm="hash_jnp")
    t_twin = bench(lambda: plan_twin.execute(pairs), warmup=2, iters=iters)
    emit(f"batch,{tag},hash_jnp_twin", t_twin, f"products={n_products}")
    t_pal = bench(lambda: plan_pal.execute(pairs), warmup=2, iters=iters)
    emit(f"batch,{tag},hash_pallas", t_pal,
         f"products={n_products};speedup_vs_twin={t_twin / t_pal:.2f}x")
    return t_twin, t_pal


def class_economy(n_products: int, scale: int, tag: str):
    """Programs compiled + padding waste of the p2 capacity classes."""
    pairs = _fleet(n_products, scale, seed0=7)
    clear_plan_cache()
    plan = plan_batch(pairs)
    exact = sum(plan.nnz_cs)
    padded = sum(plan.classes[c].cap_c for c in plan.class_of)
    emit(f"batch,{tag},capacity_classes", 0.0,
         f"products={n_products};classes={plan.n_classes};"
         f"pad_waste={padded / max(exact, 1):.2f}x")


def smoke():
    """Downscaled run with hard assertions (the CI smoke step).

    Fleet size matters for the margin assert: the batched win is dispatch
    economy (n_classes programs vs n_products), so it grows with fleet
    size and shrinks with product size -- 64 tiny products is the serving
    regime the subsystem targets (~1.7x here; 16 larger products break
    even, see the suite rows)."""
    n_products, scale = 64, 3
    pairs = _fleet(n_products, scale)
    clear_plan_cache()
    plan = plan_batch(pairs)

    # class count within the p2 bound
    bound = batch_class_bound(pairs)
    assert plan.n_classes <= bound, (plan.n_classes, bound)

    # the auto recipe re-admits the hash family for fleets, and the class
    # programs must stage the batched-grid Pallas kernel -- never the
    # retired jnp twin dispatch (call-counter + spy proof, on the fresh
    # plan's first, program-building execute)
    assert set(plan.algorithms) == {"hash"}, plan.algorithms
    twin_calls: dict = {}
    restore = counted("repro.core.batch", "spgemm_hash_jnp", twin_calls)
    hash_ops.reset_kernel_calls()
    try:
        # batched == loop-of-planned, bitwise per element
        outs = plan.execute(pairs)
    finally:
        restore()
    assert hash_ops.kernel_call_counts()["batched_numeric"] > 0, \
        "Pallas batched-grid kernel never staged"
    assert not twin_calls, f"jnp twin dispatched: {twin_calls}"
    refs = _planned_loop(plan, pairs)()
    for c, ref in zip(outs, refs):
        assert_bitwise_prefix(c, ref)

    # repeat execute: zero re-inspection, zero program builds
    counter, restore = batch_inspection_counters()
    try:
        plan.execute(pairs)
    finally:
        restore()
    assert not counter, f"batched execute re-inspected: {counter}"

    # the margin: a fleet's worth of vmapped dispatches must beat a loop
    # of per-product dispatches (both fully planned and warm).  Timing on
    # a shared CI runner is noisy -- the ~1.4-2x idle-container gap can
    # compress under contention -- so the comparison retries before it
    # fails rather than gating the job on one contended sample.
    for attempt in range(3):
        _, t_loop, t_bat = batched_vs_loop(n_products, scale,
                                           f"smoke{attempt}", iters=5)
        if t_bat < t_loop:
            break
    else:
        raise AssertionError(
            f"batched execute ({t_bat * 1e6:.0f}us) did not beat "
            f"loop-of-planned ({t_loop * 1e6:.0f}us) in 3 attempts")

    # wall-clock vs the twin the class programs retired.  On a compiled
    # backend the batched Pallas grid must at least match it (the
    # paper's headline ordering).  Interpret mode -- every CPU host,
    # including CI -- lowers the grid to a serial scan with per-step
    # block plumbing the twin's fused XLA body never pays, so parity is
    # not achievable there; the gate degrades to a non-regression bound
    # and the emitted rows record the measured ratio either way.
    import jax
    slack = 1.0 if jax.default_backend() == "tpu" else 2.5
    for attempt in range(3):
        t_twin, t_pal = pallas_vs_twin(n_products, scale,
                                       f"smoke{attempt}", iters=5)
        if t_pal <= slack * t_twin:
            break
    else:
        raise AssertionError(
            f"Pallas hash ({t_pal * 1e6:.0f}us) vs jnp twin "
            f"({t_twin * 1e6:.0f}us) exceeded the {slack:.1f}x bound "
            f"in 3 attempts")
    print("bench_batch smoke: OK", flush=True)


def run(quick: bool = True):
    """benchmarks.run suite entry.

    Both regimes on purpose: the small-product fleets where batching wins
    (dispatch economy) and a larger-product fleet where the loop breaks
    even -- the crossover is the recipe-relevant fact.
    """
    configs = ((32, 3), (16, 4)) if quick else ((32, 3), (64, 3), (16, 4))
    for n_products, scale in configs:
        tag = f"fleet{n_products}_s{scale}"
        batched_vs_loop(n_products, scale, tag, iters=2 if quick else 3)
        pallas_vs_twin(n_products, scale, tag, iters=2 if quick else 3)
        class_economy(n_products, scale, tag)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="downscaled run with correctness assertions")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    run(quick=not args.full)


if __name__ == "__main__":
    main()
