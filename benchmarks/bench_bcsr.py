"""Planned BCSR block-SpGEMM benchmark: register-tiled path vs CSR hash.

For each block-clustered suite matrix, freeze both planned paths once --
the block-granularity :func:`repro.core.plan_bcsr` plan and the CSR hash
plan -- and time their numeric phases.  The interesting regime is high
tile occupancy: one MXU block MAC replaces ``bm x bn`` scalar hash
probes, so the block path's advantage grows with block density
(DESIGN.md section 17).

``--smoke`` is the CI gate for the block-path contract:

  * the planned BCSR product agrees **bitwise** with the CSR planned
    hash path on dyadic values (flattened through ``bcsr_to_csr``);
  * repeat executes of a frozen ``BCSRPlan`` re-inspect nothing, proven
    by the block kernel's ``symbolic`` call counter;
  * on a decisively block-dense input (dense 8x8 block diagonal) the
    block plan's numeric phase beats the CSR hash plan's.

    PYTHONPATH=src python benchmarks/bench_bcsr.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

sys.path.insert(0, ".")

from repro.core import CSR, plan_bcsr, plan_spgemm
from repro.core.formats import bcsr_to_csr, csr_to_bcsr
from repro.core.spgemm import symbolic_flops
from repro.kernels.spgemm_bcsr import ops as bcsr_ops

from benchmarks.common import bench, emit, flops_rate


def block_clustered(gm: int, gn: int, bm: int, bn: int, density: float,
                    seed: int) -> np.ndarray:
    """Block-clustered dyadic dense matrix: a ``gm x gn`` occupancy grid
    of fully dense ``bm x bn`` tiles, values in {0.5, 1, 1.5, 2} so every
    kernel-vs-oracle comparison is bitwise."""
    rng = np.random.default_rng(seed)
    occ = (rng.random((gm, gn)) < density).astype(np.float32)
    if not occ.any():
        occ[0, 0] = 1.0
    vals = rng.choice(np.array([0.5, 1.0, 1.5, 2.0], np.float32),
                      size=(gm * bm, gn * bn))
    return np.kron(occ, np.ones((bm, bn), np.float32)) * vals


def block_diag(gm: int, bm: int, seed: int) -> np.ndarray:
    """Dense ``bm x bm`` block diagonal: tile occupancy 1.0 along the
    diagonal, the regime where the block path wins most decisively."""
    rng = np.random.default_rng(seed)
    occ = np.eye(gm, dtype=np.float32)
    vals = rng.choice(np.array([0.5, 1.0, 1.5, 2.0], np.float32),
                      size=(gm * bm, gm * bm))
    return np.kron(occ, np.ones((bm, bm), np.float32)) * vals


def _csr_of(d: np.ndarray) -> CSR:
    r, c = np.nonzero(d)
    return CSR.from_numpy_coo(r, c, d[r, c], d.shape)


def suite(quick: bool = True):
    """(tag, dense_a, dense_b, block) cases across tile occupancy."""
    cases = [
        ("diag16x8", block_diag(16, 8, 0), block_diag(16, 8, 1), (8, 8)),
        ("clust_d50", block_clustered(12, 12, 8, 8, 0.5, 2),
         block_clustered(12, 12, 8, 8, 0.5, 3), (8, 8)),
    ]
    if not quick:
        cases += [
            ("diag32x8", block_diag(32, 8, 4), block_diag(32, 8, 5), (8, 8)),
            ("clust_d25", block_clustered(16, 16, 8, 8, 0.25, 6),
             block_clustered(16, 16, 8, 8, 0.25, 7), (8, 8)),
        ]
    return cases


def _pair(tag, ad, bd, block, iters):
    """Freeze both planned paths, time their numeric phases, emit rows;
    returns (block plan, hash plan, operands, timings)."""
    a, b = _csr_of(ad), _csr_of(bd)
    ab = csr_to_bcsr(a, block)
    bb = csr_to_bcsr(b, (block[1], block[1]))
    bplan = plan_bcsr(ab, bb, cache=False)
    hplan = plan_spgemm(a, b, algorithm="hash", cache=False)
    flop = float(np.asarray(symbolic_flops(a, b)).sum())

    t_b = bench(lambda: bplan.execute(ab, bb).blocks, iters=iters)
    emit(f"bcsr,{tag},block", t_b,
         f"nnzb={bplan.nnzb_c};{flops_rate(flop, t_b)}")
    t_h = bench(lambda: hplan.execute(a, b).data, iters=iters)
    emit(f"bcsr,{tag},hash", t_h,
         f"nnz={hplan.nnz_c};speedup={t_h / t_b:.2f}x")
    return bplan, hplan, (a, b, ab, bb), t_b, t_h


def run(quick: bool = True):
    """benchmarks.run suite entry."""
    for tag, ad, bd, block in suite(quick):
        _pair(tag, ad, bd, block, iters=2 if quick else 3)


def smoke():
    """CI gate for the planned-BCSR contract (see module docstring)."""
    for tag, ad, bd, block in suite(quick=True):
        bplan, hplan, (a, b, ab, bb), t_b, t_h = _pair(
            tag, ad, bd, block, iters=5)

        # (1) bitwise agreement with the CSR planned hash path
        cb = bcsr_to_csr(bplan.execute(ab, bb))
        ch = hplan.execute(a, b)
        assert np.array_equal(np.asarray(cb.to_dense()),
                              np.asarray(ch.to_dense())), \
            f"{tag}: block path disagrees with the CSR hash path"

        # (2) repeat executes re-inspect nothing
        bcsr_ops.reset_kernel_calls()
        for _ in range(3):
            bplan.execute(ab, bb).blocks.block_until_ready()
        calls = bcsr_ops.kernel_call_counts()
        assert calls["symbolic"] == 0, \
            f"{tag}: repeat execute re-inspected: {calls}"
        assert calls["numeric"] + calls["batched_numeric"] > 0, calls

        # (3) the block path wins where tiles are dense
        if tag.startswith("diag"):
            assert t_b < t_h, \
                f"{tag}: block path ({t_b*1e6:.0f}us) lost to CSR hash " \
                f"({t_h*1e6:.0f}us)"
        print(f"bcsr smoke {tag}: block={t_b*1e6:.0f}us "
              f"hash={t_h*1e6:.0f}us ratio={t_h / t_b:.2f}x", flush=True)
    print("bench_bcsr smoke: OK", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="planned-BCSR acceptance assertions (CI gate)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    run(quick=not args.full)


if __name__ == "__main__":
    main()
