"""Chain-composition benchmark (DESIGN.md section 12).

Three questions, on R-MAT inputs:

  1. **Chained-planned vs per-product-planned vs planless** iteration of a
     Galerkin triple product R.A.P: how much does one frozen
     :class:`repro.core.chain.ChainPlan` save over re-inspecting each
     product per call (``plan_spgemm(cache=False)`` twice) and over the
     planless dispatcher with worst-case expansion buffers?
  2. **Unsorted vs sorted intermediates**: the same chain executed with
     intermediates left in hash select order vs force-sorted between
     stages -- the paper's C8 finding applied at every internal hop.
  3. **Galerkin / Gram workload rows** for EXPERIMENTS.md.

``--smoke`` runs a downscaled version with hard assertions -- chain ==
oracle, zero schedule/symbolic invocations inside ``ChainPlan.execute``
and on re-plan, bitwise match against the composed per-product planned
path, and a real unsorted-intermediate speedup -- used as the CI smoke
step.

    PYTHONPATH=src python benchmarks/bench_chain.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

sys.path.insert(0, ".")

from repro.core import (clear_plan_cache, gram, plan_cache_stats,
                        plan_chain, plan_galerkin, plan_gram, plan_spgemm,
                        spgemm)
from repro.data.rmat import aggregation_csr, rmat_csr

from benchmarks.common import bench, counted, emit


def _inspection_counters():
    counter: dict = {}
    restore = [
        counted("repro.core.schedule", "rows_to_bins", counter),
        counted("repro.core.schedule", "make_schedule_eager", counter),
        counted("repro.kernels.spgemm_hash.kernel", "symbolic_call",
                counter),
    ]
    return counter, lambda: [r() for r in restore]


def _rap_mats(scale: int, ef: int, seed: int = 3):
    a = rmat_csr(scale, ef, "G500", seed=seed)
    r, p = aggregation_csr(a.n_rows, max(a.n_rows // 8, 2), seed=seed)
    return r, a, p


def galerkin_modes(scale: int, ef: int, tag: str, iters: int):
    """R.A.P: chained-planned vs per-product-planned vs planless."""
    r, a, p = _rap_mats(scale, ef)
    clear_plan_cache()
    chain = plan_galerkin(r, a, p, algorithm="hash_jnp", sorted_output=True)
    caps = (chain.stages[0].cap_c, chain.stages[1].cap_c)

    def per_product():
        p1 = plan_spgemm(r, a, algorithm="hash_jnp", cache=False)
        c1 = p1.execute(r, a)
        p2 = plan_spgemm(c1, p, algorithm="hash_jnp", sorted_output=True,
                         cache=False)
        return p2.execute(c1, p)

    def planless():
        c1 = spgemm(r, a, caps[0], algorithm="hash_jnp")
        return spgemm(c1, p, caps[1], algorithm="hash_jnp",
                      sorted_output=True)

    t_pl = bench(planless, iters=iters)
    emit(f"chain,{tag},rap_planless", t_pl, f"nnz_c={chain.nnz_c}")
    t_pp = bench(per_product, iters=iters)
    emit(f"chain,{tag},rap_per_product_planned", t_pp,
         f"speedup_vs_planless={t_pl / t_pp:.2f}x")
    t_ch = bench(lambda: chain.execute(r, a, p), iters=iters)
    emit(f"chain,{tag},rap_chain_planned", t_ch,
         f"speedup_vs_per_product={t_pp / t_ch:.2f}x;"
         f"speedup_vs_planless={t_pl / t_ch:.2f}x")
    return chain


def _best_pair(fn_a, fn_b, iters: int):
    """Interleaved best-of-N seconds per call for two variants.

    The sorted-vs-unsorted comparison is a *strict work superset* (the
    sorted chain runs the same products plus one lexsort per hop), so the
    per-variant minimum -- the least OS-noise-contaminated sample -- is
    the honest comparator; interleaving the samples makes a transient
    noise phase on a shared container hit both variants instead of
    poisoning one whole series.
    """
    import time

    import jax
    jax.block_until_ready(fn_a())
    jax.block_until_ready(fn_b())
    best_a = best_b = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def unsorted_vs_sorted(scale: int, ef: int, k: int, tag: str, iters: int):
    """A^k with intermediates in select order vs force-sorted per hop."""
    a = rmat_csr(scale, ef, "ER", seed=3)
    mats = [a] * k
    p_un = plan_chain(mats, algorithm="hash_jnp", sorted_output=True)
    p_so = plan_chain(mats, algorithm="hash_jnp", sorted_output=True,
                      sort_intermediates=True)
    t_un, t_so = _best_pair(lambda: p_un.execute(*mats),
                            lambda: p_so.execute(*mats), iters)
    emit(f"chain,{tag},power{k}_unsorted_intermediates", t_un,
         f"nnz_c={p_un.nnz_c}")
    emit(f"chain,{tag},power{k}_sorted_intermediates", t_so,
         f"unsorted_speedup={t_so / t_un:.2f}x")
    return t_un, t_so


def gram_row(scale: int, ef: int, tag: str, iters: int):
    a = rmat_csr(scale, ef, "G500", seed=5)
    plan = plan_gram(a)
    t = bench(lambda: plan.execute(a), iters=iters)
    emit(f"chain,{tag},gram_planned", t, f"nnz_c={plan.nnz_c}")


def smoke():
    """Downscaled run with hard assertions (the CI smoke step)."""
    r, a, p = _rap_mats(6, 4)
    rd, ad, pd = (np.asarray(x.to_dense()) for x in (r, a, p))
    oracle = rd @ ad @ pd

    clear_plan_cache()
    chain = plan_galerkin(r, a, p, algorithm="hash_jnp", sorted_output=True)
    c = chain.execute(r, a, p)
    assert np.allclose(np.asarray(c.to_dense()), oracle, atol=1e-3)
    assert c.sorted_cols

    # repeat plan is a cache hit; repeat execute does zero re-inspection
    counter, restore = _inspection_counters()
    try:
        before = plan_cache_stats()
        chain2 = plan_galerkin(r, a, p, algorithm="hash_jnp",
                               sorted_output=True)
        c2 = chain2.execute(r, a, p)
    finally:
        restore()
    after = plan_cache_stats()
    assert chain2 is chain and after["misses"] == before["misses"], \
        "repeat plan_galerkin must hit the chain cache"
    assert not counter, f"ChainPlan.execute re-inspected: {counter}"
    assert np.array_equal(np.asarray(c2.indices), np.asarray(c.indices))

    # sorted final output bit-matches the composed per-product planned path
    p1 = plan_spgemm(r, a, algorithm="hash_jnp", cache=False)
    c1 = p1.execute(r, a)
    p2 = plan_spgemm(c1, p, algorithm="hash_jnp", sorted_output=True,
                     cache=False)
    c_comp = p2.execute(c1, p)
    for field in ("indptr", "indices", "data"):
        assert np.array_equal(np.asarray(getattr(c, field)),
                              np.asarray(getattr(c_comp, field))), field
    assert int(c.nnz) == int(c_comp.nnz)

    # gram: A^T A against the dense oracle, values-only regather on repeat
    g = gram(a, sorted_output=True)
    assert np.allclose(np.asarray(g.to_dense()), ad.T @ ad, atol=1e-3)

    # the unsorted-intermediate chain beats the sorted-intermediate chain:
    # low compression ratio (ER at edge factor 1: flop ~ nnz_c) makes the
    # per-hop sort a large fraction of each stage, the C8 regime
    t_un, t_so = unsorted_vs_sorted(10, 1, 5, "smoke", iters=5)
    assert t_so > t_un, \
        f"unsorted intermediates must win (C8 per hop): " \
        f"unsorted {t_un * 1e3:.1f}ms vs sorted {t_so * 1e3:.1f}ms"
    print("bench_chain smoke: OK", flush=True)


def run(quick: bool = True):
    """benchmarks.run suite entry."""
    configs = ((7, 4),) if quick else ((7, 4), (8, 8))
    iters = 2 if quick else 3
    for scale, ef in configs:
        tag = f"g500_s{scale}_ef{ef}"
        galerkin_modes(scale, ef, tag, iters)
        gram_row(scale, ef, tag, iters)
    unsorted_vs_sorted(9, 2, 4, "er_s9_ef2", iters)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="downscaled run with correctness assertions")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    run(quick=not args.full)


if __name__ == "__main__":
    main()
