"""Distributed plan-aware SpGEMM benchmark (DESIGN.md section 11).

Three questions, on an 8-way host-device mesh (self-provisioned via
``--xla_force_host_platform_device_count`` when run as a script):

  1. **Planned vs unplanned distributed iteration**: how much of a repeated
     1D product's wall-clock does ``DistributedPlan.execute`` amortize away
     (per-shard inspection + shard_map retrace vs the memoized jitted
     executor)?
  2. **Equal-flop vs equal-rows sharding**: the mesh-scale version of the
     paper's Fig. 9 balance argument -- skewed G500 inputs concentrate flop
     in few rows, so equal-rows shards idle most chips.
  3. **SUMMA panel count**: K-panel streaming granularity vs wall-clock.

``--smoke`` runs a downscaled version with hard assertions -- sparse-native
sharding (zero ``to_dense`` calls), distributed == single-node planned
products (bitwise), the planned hash path dispatching the **real Pallas
kernel inside the shard_map body** (call counters, jnp-twin spy) and
bit-matching the mesh-free shard executor, zero re-inspection on repeat
executes, plan-cache hits on re-plans, and an honored ``k_panels`` --
used as the CI multi-device smoke step.

    PYTHONPATH=src python benchmarks/bench_distributed.py [--smoke]
"""
from __future__ import annotations

import argparse
import os
import sys

# must precede the first jax import; harmless no-op when run via
# benchmarks.run (jax already up -- the suite then uses however many
# devices the host exposes)
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

sys.path.insert(0, ".")

from repro.core import (CSR, clear_plan_cache, plan_cache_stats,  # noqa: E402
                        plan_spgemm)
from repro.core.distributed import (plan_spgemm_1d, plan_spgemm_summa,  # noqa: E402
                                    shard_csr_rows, spgemm_1d, spgemm_summa,
                                    unshard_rows)
from repro.core.spgemm import symbolic_flops  # noqa: E402
from repro.data.rmat import rmat_csr  # noqa: E402

from benchmarks.common import bench, counted, emit  # noqa: E402




def _mesh():
    return Mesh(np.array(jax.devices()), ("data",))


def _int_csr(m, n, nnz, seed):
    r = np.random.default_rng(seed)
    return CSR.from_numpy_coo(r.integers(0, m, nnz), r.integers(0, n, nnz),
                              r.integers(1, 5, nnz).astype(np.float32),
                              (m, n))


def planned_vs_unplanned(mesh, a, b, tag: str, iters: int):
    """Repeated distributed A@B: fresh planless call vs plan + executes."""
    S = mesh.shape["data"]
    a_sh = shard_csr_rows(a, S, b=b)
    clear_plan_cache()
    plan = plan_spgemm_1d(a_sh, b, algorithm="esc")
    t_un = bench(lambda: spgemm_1d(mesh, a_sh, b, cap_c=plan.cap_c,
                                   flop_cap=plan.flop_cap,
                                   algorithm="esc").parts.data,
                 iters=iters)
    emit(f"dist,{tag},1d_unplanned", t_un)
    t_pl = bench(lambda: plan.execute(mesh, a_sh, b).parts.data,
                 iters=iters)
    emit(f"dist,{tag},1d_planned", t_pl, f"speedup={t_un / t_pl:.2f}x")
    return plan


def flop_vs_rows_sharding(mesh, a, b, tag: str, iters: int):
    """Equal-flop vs equal-rows shard boundaries (mesh-scale Fig. 9)."""
    S = mesh.shape["data"]
    m = a.n_rows
    flop = np.asarray(symbolic_flops(a, b), np.int64)
    for name, sh in (("equal_flop", shard_csr_rows(a, S, b=b)),
                     ("equal_rows", shard_csr_rows(
                         a, S, weights=np.ones(m, np.int64)))):
        plan = plan_spgemm_1d(sh, b, algorithm="esc")
        t = bench(lambda: plan.execute(mesh, sh, b).parts.data, iters=iters)
        starts = sh.row_starts
        per = [int(flop[starts[s]:starts[s + 1]].sum()) for s in range(S)]
        imb = max(per) / max(sum(per) / S, 1)
        emit(f"dist,{tag},shard_{name}", t, f"flop_imbalance={imb:.2f}")


def summa_panels(mesh, a, b, tag: str, iters: int):
    S = mesh.shape["data"]
    for kp in (S, 2 * S, 4 * S):
        if a.n_cols % kp:
            continue
        plan = plan_spgemm_summa(a, b, S, kp, algorithm="esc")
        t = bench(lambda: plan.execute(mesh, a, b).parts.data, iters=iters)
        emit(f"dist,{tag},summa_k{kp}", t, f"panels={plan.k_panels}")


def smoke():
    """Downscaled run with hard assertions (the CI multi-device step)."""
    mesh = _mesh()
    S = mesh.shape["data"]
    a = rmat_csr(6, 3, "G500", seed=1)
    b = rmat_csr(6, 3, "ER", seed=2)

    # sparse-native sharding: zero to_dense on the shard path
    calls = {"n": 0}
    orig = CSR.to_dense

    def spy(self):
        calls["n"] += 1
        return orig(self)

    CSR.to_dense = spy
    try:
        a_sh = shard_csr_rows(a, S, b=b)
    finally:
        CSR.to_dense = orig
    assert calls["n"] == 0, "shard_csr_rows densified"

    # distributed == single-node planned product, bitwise
    clear_plan_cache()
    plan = plan_spgemm_1d(a_sh, b, algorithm="esc")
    ref = plan_spgemm(a, b, algorithm="esc").execute(a, b)
    c = unshard_rows(plan.execute(mesh, a_sh, b))
    assert np.array_equal(np.asarray(c.to_dense()),
                          np.asarray(ref.to_dense()))

    # planned hash: the real Pallas kernel traces inside the shard_map
    # body (numeric counter fires per local product; the retired jnp twin
    # must stay silent) and bit-matches the mesh-free shard executor --
    # the same program text minus the mesh
    from repro.kernels.spgemm_hash import ops as hash_ops
    plan_h = plan_spgemm_1d(a_sh, b, algorithm="hash")
    twin_calls: dict = {}
    restore_twin = counted("repro.core.spgemm", "spgemm_hash_jnp",
                           twin_calls)
    hash_ops.reset_kernel_calls()
    try:
        c_h = plan_h.execute(mesh, a_sh, b)
    finally:
        restore_twin()
    assert hash_ops.kernel_call_counts()["numeric"] > 0, \
        "Pallas hash kernel never traced inside the shard_map body"
    assert not twin_calls, f"jnp twin dispatched: {twin_calls}"
    c_host = plan_h.execute_shards_host(a_sh, b)
    assert np.array_equal(
        np.asarray(unshard_rows(c_h).to_dense()),
        np.asarray(unshard_rows(c_host).to_dense()))

    # repeat execute: zero re-inspection (no schedule / symbolic work)
    counter: dict = {}
    restore = [
        counted("repro.core.schedule", "make_schedule", counter),
        counted("repro.core.schedule", "make_schedule_eager", counter),
        counted("repro.core.schedule", "rows_to_bins", counter),
        counted("repro.core.schedule", "flops_per_row", counter),
        counted("repro.core.spgemm", "symbolic", counter),
    ]
    try:
        c2 = plan.execute(mesh, a_sh, b)
    finally:
        for r in restore:
            r()
    assert not counter, f"distributed execute re-inspected: {counter}"
    assert np.array_equal(np.asarray(unshard_rows(c2).to_dense()),
                          np.asarray(ref.to_dense()))

    # repeat plan requests hit the shared LRU (zero re-inspections)
    before = plan_cache_stats()
    counter2: dict = {}
    restore = [
        counted("repro.core.schedule", "make_schedule_eager", counter2),
        counted("repro.core.spgemm", "symbolic", counter2),
    ]
    try:
        plan_again = plan_spgemm_1d(a_sh, b, algorithm="esc")
    finally:
        for r in restore:
            r()
    after = plan_cache_stats()
    assert plan_again is plan and not counter2
    assert after["misses"] == before["misses"]
    assert after["hits"] == before["hits"] + 1

    # SUMMA: k_panels honored, merge bit-matches on integer values
    ai = _int_csr(64, 64, 256, 3)
    bi = _int_csr(64, 48, 256, 4)
    refd = np.asarray(plan_spgemm(ai, bi, algorithm="esc").execute(ai, bi)
                      .to_dense())
    for kp in (S, 2 * S):
        cs = unshard_rows(spgemm_summa(mesh, ai, bi, k_panels=kp,
                                       algorithm="esc"))
        assert np.array_equal(np.asarray(cs.to_dense()), refd), kp
    try:
        spgemm_summa(mesh, ai, bi, k_panels=S + 1)
    except ValueError:
        pass
    else:
        raise AssertionError("invalid k_panels must raise")
    print(f"bench_distributed smoke: OK ({S} devices)", flush=True)


def run(quick: bool = True):
    """benchmarks.run suite entry (uses however many devices exist)."""
    mesh = _mesh()
    S = mesh.shape["data"]
    scale = 7 if quick else 8
    a = rmat_csr(scale, 3, "G500", seed=scale)
    b = rmat_csr(scale, 3, "ER", seed=scale + 1)
    tag = f"g500_s{scale}_d{S}"
    iters = 2 if quick else 3
    planned_vs_unplanned(mesh, a, b, tag, iters)
    flop_vs_rows_sharding(mesh, a, b, tag, iters)
    ai = _int_csr(1 << scale, 1 << scale, (1 << scale) * 3, scale)
    bi = _int_csr(1 << scale, 1 << scale, (1 << scale) * 3, scale + 1)
    summa_panels(mesh, ai, bi, tag, iters)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="downscaled run with correctness assertions")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    run(quick=not args.full)


if __name__ == "__main__":
    main()
