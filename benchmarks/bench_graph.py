"""Graph-workload benchmarks for the masked/semiring SpGEMM layer
(paper sections 5.5-5.6; EXPERIMENTS.md section Graph workloads).

Three trend claims made measurable:

  * ``graph,masked_vs_unmasked``: the section 5.6 triangle count as one
    masked product vs the unmasked wedge product + host-side filter.  The
    masked path should win whenever the mask prunes a large share of the
    wedge flop (derived column reports the prune fraction).
  * ``graph,sorted_vs_unsorted``: the C8 sortedness finding under the
    boolean semiring -- the same product emitted in hash (select) order vs
    with the explicit sort epilogue.
  * ``graph,bfs``: masked-frontier boolean SpGEMM hops vs the dense
    tall-skinny SpMM frontier stack of section 5.5.

All rows go through ``benchmarks.common.emit`` (name,us_per_call,derived).
"""
from __future__ import annotations

import numpy as np

from repro.core import (spgemm_esc, spgemm_heap, spgemm_hash_jnp,
                        symbolic)
from repro.core.spgemm import symbolic_flops
from repro.data.rmat import rmat_csr, symmetrize, triangular_split
from .common import bench, emit, flops_rate


def _graph(scale: int, ef: int, preset: str, seed: int):
    a = symmetrize(rmat_csr(scale, ef, preset, seed=seed))
    L, U, adj = triangular_split(a, return_adjacency=True)
    return a, L, U, adj


def _heap_caps(L, U, mask=None, complement=False):
    rn, _, _, _ = symbolic(L, U, mask=mask, complement_mask=complement)
    rc = int(np.asarray(rn).max()) + 1
    kw = int(np.asarray(L.row_nnz()).max()) + 1
    return rc, kw


def masked_vs_unmasked(quick=True):
    """Triangle counting: masked product vs unmasked product + filter."""
    scales = (6,) if quick else (6, 7)
    for preset in ("ER", "G500"):
        for sc in scales:
            a, L, U, adj = _graph(sc, 8, preset, seed=sc)
            flop = int(np.asarray(symbolic_flops(L, U)).sum())
            rn_full, _, _, _ = symbolic(L, U)
            rn_mask, _, _, _ = symbolic(L, U, mask=adj)
            cap_full = int(np.asarray(rn_full).sum()) + 8
            cap_mask = int(np.asarray(rn_mask).sum()) + 8
            prune = 1.0 - cap_mask / max(cap_full, 1)
            tag = f"graph,masked_vs_unmasked,{preset},scale{sc}"
            for algo, run_m, run_u in (
                ("esc",
                 lambda: spgemm_esc(L, U, cap_c=cap_mask, mask=adj),
                 lambda: spgemm_esc(L, U, cap_c=cap_full)),
                ("hash",
                 lambda: spgemm_hash_jnp(L, U, cap_c=cap_mask, mask=adj),
                 lambda: spgemm_hash_jnp(L, U, cap_c=cap_full)),
            ):
                t_m = bench(run_m, iters=2)
                t_u = bench(run_u, iters=2)
                emit(f"{tag},{algo},masked", t_m,
                     f"{flops_rate(flop, t_m)};prune={prune:.2f}")
                emit(f"{tag},{algo},unmasked", t_u, flops_rate(flop, t_u))
            # heap: masked row capacity shrinks with the mask
            rc_m, kw = _heap_caps(L, U, mask=adj)
            rc_u, _ = _heap_caps(L, U)
            t_m = bench(lambda: spgemm_heap(L, U, row_cap=rc_m, k_width=kw,
                                            mask=adj), iters=2)
            t_u = bench(lambda: spgemm_heap(L, U, row_cap=rc_u, k_width=kw),
                        iters=2)
            emit(f"{tag},heap,masked", t_m,
                 f"{flops_rate(flop, t_m)};row_cap={rc_m}")
            emit(f"{tag},heap,unmasked", t_u,
                 f"{flops_rate(flop, t_u)};row_cap={rc_u}")


def sorted_vs_unsorted(quick=True):
    """C8 under the boolean semiring: select-order output vs sort epilogue."""
    scales = (6,) if quick else (6, 7)
    for preset in ("ER", "G500"):
        for sc in scales:
            a = symmetrize(rmat_csr(sc, 8, preset, seed=sc))
            flop = int(np.asarray(symbolic_flops(a, a)).sum())
            rn, _, _, _ = symbolic(a, a)
            cap = int(np.asarray(rn).sum()) + 8
            tag = f"graph,sorted_vs_unsorted,{preset},scale{sc}"
            t_u = bench(lambda: spgemm_hash_jnp(a, a, cap,
                                                semiring="boolean"), iters=2)
            t_s = bench(lambda: spgemm_hash_jnp(
                a, a, cap, semiring="boolean").sort_rows(), iters=2)
            emit(f"{tag},boolean,unsorted", t_u, flops_rate(flop, t_u))
            emit(f"{tag},boolean,sorted", t_s, flops_rate(flop, t_s))


def bfs(quick=True):
    """Masked-frontier boolean SpGEMM vs the dense SpMM frontier stack."""
    from examples.graph_analytics import (multi_source_bfs,
                                          multi_source_bfs_masked)
    sc = 6 if quick else 7
    a = symmetrize(rmat_csr(sc, 8, "G500", seed=3))
    sources = list(range(0, a.n_rows, max(1, a.n_rows // 4)))[:4]
    hops = 4
    t_d = bench(lambda: multi_source_bfs(a, sources, hops), iters=2)
    t_m = bench(lambda: multi_source_bfs_masked(a, sources, hops), iters=2)
    emit(f"graph,bfs,scale{sc},dense_spmm", t_d, f"k={len(sources)}")
    emit(f"graph,bfs,scale{sc},masked_boolean", t_m, f"k={len(sources)}")


def run(quick=True):
    masked_vs_unmasked(quick)
    sorted_vs_unsorted(quick)
    bfs(quick)
