"""Paper section 3 microbenchmarks, re-targeted (Figs. 2, 4, 5).

Fig 2 (OpenMP scheduling cost) -> grid/launch overhead: one static Pallas
grid of N programs vs N separate dispatches (the "dynamic scheduling"
shape).  Fig 4 (alloc/dealloc) -> buffer reuse via jit donation vs fresh
host allocation per call (the XLA arena plays TBB's role; donation is the
"parallel"/thread-private reuse).  Fig 5 (stanza access, DDR vs MCDRAM) ->
gather bandwidth vs stanza length; the HBM-vs-VMEM blocking conclusion is
what sizes the BCSR tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import bench, emit


def fig2_scheduling(quick=True):
    n_iters = 64
    x = jnp.zeros((n_iters, 128), jnp.float32)

    @jax.jit
    def static_grid(x):
        return x + 1.0    # one dispatch covering all "iterations"

    @jax.jit
    def one(chunk):
        return chunk + 1.0

    def dynamic(x):
        return [one(x[i]) for i in range(n_iters)]   # dispatch per iteration

    t_static = bench(static_grid, x)
    emit("fig2,static", t_static, f"iters={n_iters}")
    t_dyn = bench(lambda: dynamic(x), iters=2)
    emit("fig2,dynamic", t_dyn,
         f"overhead={t_dyn / max(t_static, 1e-9):.1f}x")


def fig4_alloc(quick=True):
    n = 1 << 22   # 16 MiB f32

    @jax.jit
    def update(buf):
        return buf * 1.0001

    buf = jnp.zeros((n,), jnp.float32)
    donated = jax.jit(update, donate_argnums=(0,))

    def reuse_path():
        nonlocal buf
        buf = donated(buf)
        return buf

    t_reuse = bench(reuse_path, iters=3)
    emit("fig4,reuse_donated", t_reuse, f"bytes={4 * n}")

    def fresh_path():
        fresh = jnp.asarray(np.zeros((n,), np.float32))  # alloc+copy per call
        return update(fresh)

    t_fresh = bench(fresh_path, iters=3)
    emit("fig4,fresh_alloc", t_fresh,
         f"overhead={t_fresh / max(t_reuse, 1e-9):.1f}x")


def fig5_stanza(quick=True):
    """Gather the same total bytes with varying contiguous stanza length."""
    total = 1 << 22                      # elements
    src = jnp.arange(total, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    for stanza in (1, 8, 64, 512):
        n_st = total // stanza // 4      # read a quarter of the array
        starts = jnp.asarray(
            rng.integers(0, total - stanza, n_st).astype(np.int32))

        @jax.jit
        def gather(src, starts):
            idx = starts[:, None] + jnp.arange(stanza)[None, :]
            return src[idx].sum()

        t = bench(gather, src, starts)
        gbps = n_st * stanza * 4 / t / 1e9
        emit(f"fig5,stanza{stanza}", t, f"{gbps:.2f}GB/s")
