"""MoE dispatch = SpGEMM: the paper's C8 (skip the sort) inside the LM.

Measures stable vs unstable dispatch sort (tokens within an expert need no
order -- exactly the unsorted-CSR argument) and the dispatch/combine
round-trip throughput.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models import moe
from .common import bench, emit


def run(quick=True):
    cfg = reduced(ARCHS["qwen3-moe-30b-a3b"], d_model=256)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=32, top_k=4))
    key = jax.random.PRNGKey(0)
    params = moe.init(key, cfg)
    T = 4096 if quick else 16384
    x = jax.random.normal(jax.random.PRNGKey(1), (1, T, cfg.d_model),
                          jnp.bfloat16)
    for stable in (False, True):
        cfg_s = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         stable_dispatch_sort=stable))
        fn = jax.jit(lambda p, x, c=cfg_s: moe.apply_dense(p, x, c)[0])
        t = bench(fn, params, x)
        tag = "stable_sort" if stable else "unsorted"
        emit(f"moe_dispatch,{tag}", t,
             f"tokens={T};topk={cfg.moe.top_k};"
             f"{T / t / 1e6:.2f}Mtok/s")
