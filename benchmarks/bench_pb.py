"""Propagation-blocking SpGEMM benchmark (DESIGN.md section 18).

Two questions, following Gu et al.'s propagation-blocking argument:

  1. **Single node, low compression factor**: when the expansion barely
     collapses (flop / nnz(C) near 1), how does the planned PB
     scatter/merge pair compare against the planned hash path's table
     probes and the ESC sort?  PB's two streaming passes are the
     bandwidth-optimal shape in exactly this regime.
  2. **On the mesh**: the PB-SUMMA bucket exchange moves O(flop) words
     through one ``all_to_all``; the classic SUMMA merge reduce-scatters
     a dense ``(m, n)`` accumulator regardless of sparsity.  On a low-CF
     ER fixture the exchange should win outright.

``--smoke`` is the CI gate for the PB contract:

  * the PB-SUMMA product agrees **bitwise** with the classic SUMMA
    dense-merge product on integer-valued fixtures (panel-sum
    reassociation is exact there);
  * repeat executes of the frozen plans re-inspect nothing, proven by
    the PB kernel counters and the planner-entry spies;
  * on the low-CF ER fixture the PB mesh merge beats the dense
    ``psum_scatter`` merge.

    PYTHONPATH=src python benchmarks/bench_pb.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

# must precede the first jax import; harmless no-op when run via
# benchmarks.run (jax already up -- the suite then uses however many
# devices the host exposes)
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

sys.path.insert(0, ".")

from repro.core import plan_pb, plan_spgemm  # noqa: E402
from repro.core.distributed import (plan_spgemm_pb_summa,  # noqa: E402
                                    plan_spgemm_summa, unshard_rows)
from repro.core.recipe import PB_MAX_COMPRESSION, measure_stats  # noqa: E402
from repro.data.rmat import rmat_csr  # noqa: E402
from repro.kernels.spgemm_pb import ops as pb_ops  # noqa: E402

from benchmarks.common import bench, counted, emit, flops_rate  # noqa: E402


def _mesh():
    return Mesh(np.array(jax.devices()), ("data",))


def _int_values(a, seed: int):
    """Integer-valued twin of a CSR (padding kept zero): fp32 sums over
    small integers are exact, so merge-order differences cannot show
    through and cross-path comparisons are bitwise."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(1, 5, a.cap).astype(np.float32)
    lane = np.arange(a.cap)
    vals = np.where(lane < int(a.nnz), vals, 0.0).astype(np.float32)
    return dataclasses.replace(a, data=jnp.asarray(vals))


def low_cf_er(scale: int, seed: int = 0):
    """A low-compression ER product: sparse enough that nearly every
    partial product is its own output entry -- PB's home regime."""
    a = _int_values(rmat_csr(scale, 1, "ER", seed=seed), seed + 10)
    b = _int_values(rmat_csr(scale, 1, "ER", seed=seed + 1), seed + 11)
    return a, b


def _single_node(tag, a, b, iters):
    stats = measure_stats(a, b)
    flop = float(stats.flop)
    pbp = plan_pb(a, b, cache=False)
    hp = plan_spgemm(a, b, algorithm="hash", sorted_output=True,
                     cache=False)
    ep = plan_spgemm(a, b, algorithm="esc", sorted_output=True,
                     cache=False)
    t_pb = bench(lambda: pbp.execute(a, b).data, iters=iters)
    emit(f"pb,{tag},pb", t_pb,
         f"cf={stats.compression_ratio:.2f};{flops_rate(flop, t_pb)}")
    t_h = bench(lambda: hp.execute(a, b).data, iters=iters)
    emit(f"pb,{tag},hash", t_h, f"speedup={t_h / t_pb:.2f}x")
    t_e = bench(lambda: ep.execute(a, b).data, iters=iters)
    emit(f"pb,{tag},esc", t_e, f"speedup={t_e / t_pb:.2f}x")
    return pbp, t_pb, t_h, t_e


def _mesh_pair(tag, a, b, iters):
    """Freeze both SUMMA merges, time their numeric phases."""
    mesh = _mesh()
    S = len(jax.devices())
    pplan = plan_spgemm_pb_summa(a, b, S, cache=False)
    splan = plan_spgemm_summa(a, b, S, algorithm="esc", cache=False)
    t_pb = bench(lambda: pplan.execute(mesh, a, b).parts.data, iters=iters)
    emit(f"pb,{tag},pb_summa", t_pb,
         f"nnz_c={pplan.nnz_c};xcap={pplan.xcap}")
    t_rs = bench(lambda: splan.execute(mesh, a, b).parts.data, iters=iters)
    emit(f"pb,{tag},summa_psum", t_rs, f"speedup={t_rs / t_pb:.2f}x")
    return pplan, splan, mesh, t_pb, t_rs


def run(quick: bool = True):
    """benchmarks.run suite entry."""
    scales = (6, 7) if quick else (6, 7, 8, 9)
    for scale in scales:
        a, b = low_cf_er(scale, seed=scale)
        _single_node(f"er{1 << scale}", a, b, iters=2 if quick else 3)
    if len(jax.devices()) > 1:
        a, b = low_cf_er(8, seed=3)
        _mesh_pair("er256_mesh", a, b, iters=2 if quick else 3)


def smoke():
    """CI gate for the propagation-blocking contract (module docstring)."""
    a, b = low_cf_er(8, seed=3)
    stats = measure_stats(a, b)
    assert stats.compression_ratio <= PB_MAX_COMPRESSION, \
        f"fixture drifted out of PB's regime: cf={stats.compression_ratio}"

    # (1) single node: planned PB == planned hash (sorted), bitwise
    pbp, t_pb1, t_h, _ = _single_node("er256", a, b, iters=3)
    hp = plan_spgemm(a, b, algorithm="hash", sorted_output=True,
                     cache=False)
    c_pb, c_h = pbp.execute(a, b), hp.execute(a, b)
    nnz = int(c_h.nnz)
    assert int(c_pb.nnz) == nnz
    assert np.array_equal(np.asarray(c_pb.indptr), np.asarray(c_h.indptr))
    assert np.array_equal(np.asarray(c_pb.indices)[:nnz],
                          np.asarray(c_h.indices)[:nnz])
    assert np.array_equal(np.asarray(c_pb.data)[:nnz],
                          np.asarray(c_h.data)[:nnz])

    # (2) mesh: PB exchange bitwise vs the dense psum_scatter merge
    pplan, splan, mesh, t_pb, t_rs = _mesh_pair("er256_mesh", a, b,
                                                iters=5)
    c_x = unshard_rows(pplan.execute(mesh, a, b))
    c_d = unshard_rows(splan.execute(mesh, a, b))
    assert np.array_equal(np.asarray(c_x.to_dense()),
                          np.asarray(c_d.to_dense())), \
        "PB exchange disagrees with the dense reduce-scatter merge"

    # (3) repeat executes re-inspect nothing (kernel counters + planner
    # entry spies around the executes)
    counter: dict = {}
    restore = [counted("repro.core.pb", "plan_pb", counter),
               counted("repro.core.distributed", "plan_spgemm_pb_summa",
                       counter),
               counted("repro.core.distributed", "_shard_summa", counter)]
    try:
        pb_ops.reset_kernel_calls()
        for _ in range(3):
            pplan.execute(mesh, a, b).parts.data.block_until_ready()
            pbp.execute(a, b).data.block_until_ready()
        calls = pb_ops.kernel_call_counts()
        assert calls["inspect"] == 0, f"repeat execute re-inspected: {calls}"
        assert not counter, f"planner re-entered on execute: {counter}"
    finally:
        for r in restore:
            r()

    # (4) the exchange beats the dense merge in PB's home regime
    assert t_pb < t_rs, \
        f"PB exchange ({t_pb*1e6:.0f}us) lost to the dense psum_scatter " \
        f"merge ({t_rs*1e6:.0f}us) on the low-CF ER fixture"
    print(f"pb smoke: pb_summa={t_pb*1e6:.0f}us "
          f"psum_scatter={t_rs*1e6:.0f}us ratio={t_rs / t_pb:.2f}x",
          flush=True)
    print("bench_pb smoke: OK", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="propagation-blocking acceptance assertions "
                         "(CI gate)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    run(quick=not args.full)


if __name__ == "__main__":
    main()
