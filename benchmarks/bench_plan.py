"""Inspector-executor planner benchmark (DESIGN.md section 10).

Two questions, on skewed (G500) R-MAT inputs:

  1. **Planned vs unplanned iteration**: how much of a repeated product's
     wall-clock is inspection (schedule + symbolic + recipe) that
     ``plan.execute`` amortizes away?  Measured for the hash kernel (the
     symbolic *kernel* is skipped on execute) and for ESC (the exact
     ``flop_cap`` shrinks the expansion buffer from the worst-case bound).
  2. **Per-bin vs global-max table sizing** (Fig. 7 lines 9-12): the same
     numeric kernel run with each bin's power-of-two table size vs every
     bin paying for the single worst row in the matrix.

``--smoke`` runs a downscaled version with hard assertions -- planned ==
unplanned == oracle, per-bin == global-max, zero schedule/symbolic
invocations inside ``plan.execute``, cache hit on re-plan -- used as the CI
smoke step.

    PYTHONPATH=src python benchmarks/bench_plan.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, ".")

from repro.core import (clear_plan_cache, plan_cache_stats, plan_spgemm,
                        spgemm, spgemm_esc)
from repro.core.spgemm import symbolic_flops
from repro.data.rmat import rmat_csr
from repro.kernels.spgemm_hash import ops as hash_ops

from benchmarks.common import bench, counted, emit, flops_rate




def planned_vs_unplanned(a, tag: str, iters: int):
    """Repeated A@A: fresh spgemm each call vs one plan + executes."""
    flop = int(np.asarray(symbolic_flops(a, a)).sum())
    clear_plan_cache()
    plan = plan_spgemm(a, a, algorithm="hash")
    cap = plan.cap_c

    t_un = bench(lambda: spgemm(a, a, cap, algorithm="hash"), iters=iters)
    emit(f"plan,{tag},hash_unplanned", t_un, flops_rate(flop, t_un))
    t_pl = bench(lambda: plan.execute(a, a), iters=iters)
    emit(f"plan,{tag},hash_planned", t_pl,
         f"{flops_rate(flop, t_pl)};speedup={t_un / t_pl:.2f}x")

    # ESC: the planned path passes the exact flop bound instead of the
    # worst-case O(cap_a * min(cap_b, n)) expansion buffer.
    plan_esc = plan_spgemm(a, a, algorithm="esc")
    t_eun = bench(lambda: spgemm_esc(a, a, cap_c=cap), iters=iters)
    emit(f"plan,{tag},esc_default_flopcap", t_eun, flops_rate(flop, t_eun))
    t_epl = bench(lambda: plan_esc.execute(a, a), iters=iters)
    emit(f"plan,{tag},esc_planned", t_epl,
         f"{flops_rate(flop, t_epl)};speedup={t_eun / t_epl:.2f}x")
    return plan


def per_bin_vs_global(a, tag: str, iters: int, n_bins: int = 8):
    """Numeric kernel with per-bin table sizes vs global-max everywhere."""
    flop = int(np.asarray(symbolic_flops(a, a)).sum())
    offsets, bin_tsize, table_size = hash_ops.hash_schedule(a, a, n_bins)
    uniform = jnp.full_like(bin_tsize, jnp.int32(table_size))
    cd_nnz = int(np.asarray((a.to_dense() @ a.to_dense()) != 0).sum())
    cap = cd_nnz + 8

    t_bin = bench(lambda: hash_ops.spgemm_hash(
        a, a, cap, table_size=table_size,
        schedule=(offsets, bin_tsize)), iters=iters)
    sizes = "/".join(str(s) for s in np.asarray(bin_tsize).tolist())
    emit(f"plan,{tag},table_per_bin", t_bin, f"sizes={sizes}")
    t_max = bench(lambda: hash_ops.spgemm_hash(
        a, a, cap, table_size=table_size,
        schedule=(offsets, uniform)), iters=iters)
    emit(f"plan,{tag},table_global_max", t_max,
         f"size={table_size};per_bin_speedup={t_max / t_bin:.2f}x")
    return (offsets, bin_tsize, uniform, table_size, cap)


def smoke():
    """Downscaled run with hard assertions (the CI smoke step)."""
    # skewed and sparse: equal-flop bins then get genuinely different
    # max-row-flop bounds, so per-bin table sizes actually spread
    a = rmat_csr(6, 2, "G500", seed=1)
    cd = np.asarray(a.to_dense()) @ np.asarray(a.to_dense())

    clear_plan_cache()
    plan = plan_spgemm(a, a, algorithm="hash")
    assert plan.nnz_c == int((cd != 0).sum())

    # no schedule / symbolic-kernel work inside execute
    counter: dict = {}
    restore = [
        counted("repro.core.schedule", "make_schedule", counter),
        counted("repro.core.schedule", "rows_to_bins", counter),
        counted("repro.kernels.spgemm_hash.kernel", "symbolic_call",
                 counter),
    ]
    try:
        c_pl = plan.execute(a, a)
    finally:
        for r in restore:
            r()
    assert not counter, f"plan.execute re-inspected: {counter}"
    assert np.allclose(np.asarray(c_pl.to_dense()), cd, atol=1e-3)

    # planned == unplanned == oracle
    c_un = spgemm(a, a, plan.cap_c, algorithm="hash")
    assert np.allclose(np.asarray(c_un.to_dense()), cd, atol=1e-3)

    # re-plan on the same structure is a cache hit
    before = plan_cache_stats()
    plan2 = plan_spgemm(a, a, algorithm="hash")
    after = plan_cache_stats()
    assert plan2 is plan and after["hits"] == before["hits"] + 1

    # per-bin sizing changes cost, not results
    offsets, bin_tsize, uniform, table_size, cap = \
        per_bin_vs_global(a, "smoke", iters=1, n_bins=16)
    assert int(np.asarray(bin_tsize).min()) < table_size, \
        "expected a real per-bin size spread on the skewed smoke input"
    c_bin = hash_ops.spgemm_hash(a, a, cap, table_size=table_size,
                                 schedule=(offsets, bin_tsize))
    c_max = hash_ops.spgemm_hash(a, a, cap, table_size=table_size,
                                 schedule=(offsets, uniform))
    assert np.allclose(np.asarray(c_bin.to_dense()),
                       np.asarray(c_max.to_dense()), atol=1e-3)
    assert np.allclose(np.asarray(c_bin.to_dense()), cd, atol=1e-3)
    assert int(np.asarray(bin_tsize).max()) <= table_size

    planned_vs_unplanned(a, "smoke", iters=1)
    print("bench_plan smoke: OK", flush=True)


def run(quick: bool = True):
    """benchmarks.run suite entry.

    Skewed *sparse* inputs are where per-bin sizing pays: with G500 skew
    at low edge factor, most equal-flop bins hold light rows while the
    global max chases one heavy row (dense-ish inputs saturate every
    bin's bound at n_cols and the sizes collapse to one value).
    """
    configs = ((7, 2, 16),) if quick else ((7, 2, 16), (8, 2, 32))
    for scale, ef, n_bins in configs:
        a = rmat_csr(scale, ef, "G500", seed=scale)
        tag = f"g500_s{scale}_ef{ef}"
        planned_vs_unplanned(a, tag, iters=2 if quick else 3)
        per_bin_vs_global(a, tag, iters=2 if quick else 3, n_bins=n_bins)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="downscaled run with correctness assertions")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    run(quick=not args.full)


if __name__ == "__main__":
    main()
