"""Paper figures 11-17: SpGEMM scaling/benchmark suite (scaled to CPU).

One function per figure; each emits `name,us_per_call,derived` CSV rows via
benchmarks.common.emit.  Sizes are reduced (scale 6-8 vs the paper's 14-17)
to fit the single-core container; trends, not absolutes, are the
reproduction target here (see EXPERIMENTS.md section Validation).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import CSR, spgemm_esc, spgemm_heap, spmm
from repro.core.recipe import measure_stats, choose_algorithm_from_stats
from repro.core.spgemm import symbolic_flops
from repro.data.rmat import rmat_csr, rmat_edges, tall_skinny_from, triangular_split
from repro.data.matrices import suite
from repro.kernels.spgemm_hash.ops import spgemm_hash
from .common import bench, emit, flops_rate


def _caps(a, b):
    cd = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
    nnz = int((cd != 0).sum())
    flop = int(np.asarray(symbolic_flops(a, b)).sum())
    return nnz + 16, flop


def _run_algos(a, b, tag, algos=("esc", "heap", "hash", "hash_vector"),
               hash_sorted_too=False):
    cap, flop = _caps(a, b)
    for algo in algos:
        if algo == "esc":
            fn = lambda: spgemm_esc(a, b, cap_c=cap, flop_cap=max(flop, 1) + 8)
        elif algo == "heap":
            ad = np.asarray(a.to_dense())
            cd = ad @ np.asarray(b.to_dense())
            rc = int(max((cd != 0).sum(axis=1))) + 1
            kw = int(max((ad != 0).sum(axis=1))) + 1
            fn = lambda: spgemm_heap(a, b, row_cap=rc, k_width=kw)
        else:
            fn = lambda algo=algo: spgemm_hash(
                a, b, cap, vector=(algo == "hash_vector"), n_bins=8)
        t = bench(fn, iters=2)
        emit(f"{tag},{algo}", t, flops_rate(flop, t))
    if hash_sorted_too:
        fn = lambda: spgemm_hash(a, b, cap, n_bins=8).sort_rows()
        t = bench(fn, iters=2)
        emit(f"{tag},hash_sorted", t, flops_rate(flop, t))


def fig11_density(quick=True):
    """Scaling with density (edge factor), ER + G500, scale 6."""
    efs = (2, 4, 8) if quick else (2, 4, 8, 16)
    for preset in ("ER", "G500"):
        for ef in efs:
            a = rmat_csr(6, ef, preset, seed=ef)
            _run_algos(a, a, f"fig11,{preset},ef{ef}",
                       hash_sorted_too=(ef == efs[-1]))


def fig12_size(quick=True):
    """Scaling with matrix size, edge factor 8."""
    scales = (5, 6, 7) if quick else (5, 6, 7, 8)
    for preset in ("ER", "G500"):
        for sc in scales:
            a = rmat_csr(sc, 8, preset, seed=sc)
            _run_algos(a, a, f"fig12,{preset},scale{sc}",
                       algos=("esc", "heap", "hash"))


def fig13_scaling(quick=True):
    """Thread-count scaling analogue: Pallas grid bins 1..8 (hash kernel).

    On KNL this was OMP threads; the TPU analogue is the number of grid
    programs, with C1's equal-flop binning keeping them balanced."""
    a = rmat_csr(6, 8, "G500", seed=0)
    cap, flop = _caps(a, a)
    for n_bins in (1, 2, 4, 8):
        t = bench(lambda: spgemm_hash(a, a, cap, n_bins=n_bins), iters=2)
        emit(f"fig13,bins{n_bins}", t, flops_rate(flop, t))


def fig9_balanced_vs_naive():
    """Fig 9 analogue: C1 balanced bins vs naive equal-row bins."""
    import repro.core.schedule as sched
    from repro.kernels.spgemm_hash import kernel as HK
    a = rmat_csr(7, 8, "G500", seed=1)     # skewed -> imbalance visible
    cap, flop = _caps(a, a)
    t_bal = bench(lambda: spgemm_hash(a, a, cap, n_bins=8), iters=2)
    emit("fig9,balanced", t_bal, flops_rate(flop, t_bal))
    # naive: equal rows per bin (what static OMP scheduling would do)
    flops = sched.flops_per_row(a, a)
    m = a.n_rows
    naive = jnp.asarray(np.linspace(0, m, 9).astype(np.int32))
    tsize = sched.lowest_p2(int(jnp.max(flops)) + 1)
    # naive bins get no per-bin sizing either: every bin probes the max
    uniform = jnp.full((8,), tsize, jnp.int32)
    sym = HK.symbolic_call(8, m, a.cap, a.cap, tsize, False, True)
    num = HK.numeric_call(8, m, a.cap, a.cap, cap, tsize, False, True)

    def naive_run():
        rn = sym(naive, uniform, a.indptr, a.indptr, a.indices,
                 a.data.astype(jnp.float32), a.indices,
                 a.data.astype(jnp.float32))
        ip = sched.prefix_sum(rn).astype(jnp.int32)
        return num(naive, uniform, a.indptr, a.indptr, ip, a.indices,
                   a.data.astype(jnp.float32), a.indices,
                   a.data.astype(jnp.float32))
    t_nv = bench(naive_run, iters=2)
    emit("fig9,naive_rows", t_nv, flops_rate(flop, t_nv))


def fig14_compression(quick=True):
    """Real-matrix proxies in ascending compression ratio."""
    n = 6 if quick else 12
    for prof, a in suite(divisor=4096, max_matrices=n):
        stats = measure_stats(a, a)
        _run_algos(a, a, f"fig14,{prof.name},cr{stats.compression_ratio:.1f}",
                   algos=("esc", "heap", "hash"))


def fig15_profiles(quick=True):
    """Relative performance profiles (Dolan-More) over the proxy suite."""
    import collections
    times = collections.defaultdict(dict)
    n = 6 if quick else 12
    for prof, a in suite(divisor=4096, max_matrices=n):
        cap, flop = _caps(a, a)
        for algo in ("esc", "heap", "hash"):
            if algo == "esc":
                fn = lambda: spgemm_esc(a, a, cap_c=cap,
                                        flop_cap=max(flop, 1) + 8)
            elif algo == "heap":
                ad = np.asarray(a.to_dense())
                cd = ad @ ad
                rc = int(max((cd != 0).sum(axis=1))) + 1
                kw = int(max((ad != 0).sum(axis=1))) + 1
                fn = lambda: spgemm_heap(a, a, row_cap=rc, k_width=kw)
            else:
                fn = lambda: spgemm_hash(a, a, cap, n_bins=8)
            times[prof.name][algo] = bench(fn, iters=1)
    for theta in (1.0, 1.5, 2.0, 4.0):
        for algo in ("esc", "heap", "hash"):
            frac = np.mean([
                1.0 if times[m][algo] <= theta * min(times[m].values())
                else 0.0 for m in times])
            emit(f"fig15,theta{theta},{algo}", 0.0, f"profile={frac:.2f}")


def fig16_tall_skinny(quick=True):
    """Square x tall-skinny (multi-source BFS frontier stacks)."""
    sc = 6
    rows, cols = rmat_edges(sc, 8, "G500", seed=2)
    a = rmat_csr(sc, 8, "G500", seed=2)
    for ksc in ((2, 4) if quick else (2, 4, 5)):
        b = tall_skinny_from(rows, cols, 1 << sc, ksc, seed=3)
        _run_algos(a, b, f"fig16,k{1 << ksc}", algos=("esc", "hash"))
        # dense-frontier SpMM comparison point
        x = np.asarray(b.to_dense())
        t = bench(lambda: spmm(a, jnp.asarray(x)), iters=2)
        emit(f"fig16,k{1 << ksc},spmm_dense_frontier", t, "")


def fig17_triangle(quick=True):
    """L x U wedge counting on proxy matrices."""
    n = 4 if quick else 8
    for prof, a in suite(divisor=4096, max_matrices=n):
        ad = np.asarray(a.to_dense())
        ad = ((ad + ad.T) > 0).astype(np.float32)
        np.fill_diagonal(ad, 0.0)
        sym_a = CSR.from_dense(jnp.asarray(ad))
        L, U = triangular_split(sym_a)
        stats = measure_stats(L, U)
        _run_algos(L, U, f"fig17,{prof.name},cr{stats.compression_ratio:.1f}",
                   algos=("esc", "heap", "hash"))


def table4_recipe(quick=True):
    """Recipe evaluation.

    Substrate caveat: on this container the hash kernels execute in Pallas
    *interpret mode* (~10^3x slower than compiled XLA), so wall-clock
    comparisons against ESC/heap would measure the interpreter, not the
    algorithms.  The recipe is therefore checked two ways:
      (a) against the theoretical Eq.1/Eq.2 cost-model ranking (which the
          paper itself says predicts Table 4) over all algorithms;
      (b) against measured wall-clock restricted to the compiled-substrate
          pair {esc, heap}.
    """
    from repro.core.recipe import model_costs
    cases = []
    for preset in ("ER", "G500"):
        for ef in (2, 8) if quick else (2, 4, 8, 16):
            cases.append((f"{preset}-ef{ef}", rmat_csr(6, ef, preset,
                                                       seed=ef), "AxA"))
    model_hits = measured_hits = total = 0
    for name, a, use in cases:
        cap, flop = _caps(a, a)
        times = {}
        for algo in ("esc", "heap"):
            if algo == "esc":
                fn = lambda: spgemm_esc(a, a, cap_c=cap,
                                        flop_cap=max(flop, 1) + 8)
            else:
                ad = np.asarray(a.to_dense())
                cd = ad @ ad
                rc = int(max((cd != 0).sum(axis=1))) + 1
                kw = int(max((ad != 0).sum(axis=1))) + 1
                fn = lambda: spgemm_heap(a, a, row_cap=rc, k_width=kw)
            times[algo] = bench(fn, iters=1)
        stats = measure_stats(a, a)
        pred = choose_algorithm_from_stats(stats, sorted_output=False,
                                           use_case=use)
        costs = model_costs(stats, sorted_output=False)
        model_best = min(costs, key=costs.get)
        pred_cost_rank_ok = costs.get(
            "hash" if pred.startswith("hash") else pred, 1e18) <= \
            1.25 * costs[model_best]
        measured_best = min(times, key=times.get)
        model_sub_best = min(("esc", "heap"), key=lambda k: costs[k])
        total += 1
        model_hits += int(pred_cost_rank_ok)
        measured_hits += int(model_sub_best == measured_best)
        emit(f"table4,{name}", times[measured_best],
             f"pred={pred};model_best={model_best};"
             f"measured_best({'|'.join(times)})={measured_best}")
    emit("table4,accuracy", 0.0,
         f"recipe_vs_model={model_hits}/{total};"
         f"model_vs_measured_esc_heap={measured_hits}/{total}")
