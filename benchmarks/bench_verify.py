"""Perf rows for the static contract checker itself.

The verifier traces every planned executor to a jaxpr and walks it with
the interval engine, so its runtime is a real cost worth tracking: a
regression here means plan verification got slower (more eqns staged,
deeper descents), which usually mirrors a regression in trace time of
the executors themselves.

Quick mode proves the single-product plan kinds only; ``--full`` sweeps
all layer-1 kinds (batch/dist/summa/chain included).  The layer-2 lint
row doubles as a live gate: a nonzero violation count in the derived
column means the tree would fail CI's static-analysis job.
"""
from __future__ import annotations

import pathlib
import time

from . import common


def run(quick: bool) -> None:
    from repro.verify import run_layer1, run_layer2

    kinds = ["spgemm"] if quick else None
    t0 = time.perf_counter()
    cases = run_layer1(kinds)
    dt = time.perf_counter() - t0
    n_ok = sum(1 for c in cases if c.ok)
    proved = sum(c.site_counts.get("proved", 0) for c in cases)
    common.emit("verify_layer1" + ("_quick" if quick else "_full"), dt,
                f"{n_ok}/{len(cases)}ok;{proved}proved")

    root = pathlib.Path(__file__).resolve().parents[1]
    t0 = time.perf_counter()
    violations, waivers, n_files = run_layer2(str(root))
    dt = time.perf_counter() - t0
    common.emit("verify_layer2", dt,
                f"{n_files}files;{len(violations)}viol;{len(waivers)}waived")
