"""Shared benchmark utilities: timing + CSV emission.

CPU-container caveat (documented in EXPERIMENTS.md): the Pallas kernels run
in *interpret mode* here, so their absolute timings are not TPU-predictive;
what these benchmarks preserve from the paper is the **relative algorithm
behaviour** (density/size/CR trends, sorted-vs-unsorted gap, balanced-vs-
naive scheduling) plus exact throughput numbers for the XLA-compiled paths
(ESC, heap, SpMM).  TPU-projected numbers live in the roofline analysis.
"""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS = []


def bench(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median seconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    us = seconds * 1e6
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def flops_rate(flop: float, seconds: float) -> str:
    return f"{2.0 * flop / seconds / 1e6:.1f}MFLOPS"


def counted(module_name: str, attr: str, counter: dict):
    """Swap ``module.attr`` for a call-counting wrapper; return a restorer.

    The zero-re-inspection assertion helper shared by the plan /
    distributed / chain smoke suites: wrap the inspection entry points
    (``rows_to_bins``, ``make_schedule_eager``, the symbolic kernel, ...)
    around an ``execute`` and assert the counter stayed empty.
    """
    import importlib
    mod = importlib.import_module(module_name)
    orig = getattr(mod, attr)

    def wrapper(*a, **kw):
        counter[attr] = counter.get(attr, 0) + 1
        return orig(*a, **kw)

    setattr(mod, attr, wrapper)
    return lambda: setattr(mod, attr, orig)
