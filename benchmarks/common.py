"""Shared benchmark utilities: timing + CSV emission.

CPU-container caveat (documented in EXPERIMENTS.md): the Pallas kernels run
in *interpret mode* here, so their absolute timings are not TPU-predictive;
what these benchmarks preserve from the paper is the **relative algorithm
behaviour** (density/size/CR trends, sorted-vs-unsorted gap, balanced-vs-
naive scheduling) plus exact throughput numbers for the XLA-compiled paths
(ESC, heap, SpMM).  TPU-projected numbers live in the roofline analysis.
"""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS = []


def bench(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median seconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "",
         flops: float | None = None, bytes_moved: float | None = None):
    """Record one benchmark row (and print its CSV line).

    ``flops`` / ``bytes_moved`` optionally attach the operation's work
    model: the JSON trajectory then carries roofline columns for the row
    (bound, achieved rates, roof fraction via
    ``repro.analysis.roofline.spgemm_roofline``), which is what the
    autotune DB records alongside winners and what cross-commit
    perf-trajectory diffs normalize against.
    """
    us = seconds * 1e6
    extras = {}
    if flops is not None and bytes_moved is not None:
        from repro.analysis.roofline import spgemm_roofline
        extras["roofline"] = spgemm_roofline(flops, bytes_moved, seconds)
        extras["flops"] = flops
        extras["bytes_moved"] = bytes_moved
    ROWS.append((name, us, derived, extras))
    print(f"{name},{us:.1f},{derived}", flush=True)


def flops_rate(flop: float, seconds: float) -> str:
    return f"{2.0 * flop / seconds / 1e6:.1f}MFLOPS"


def assert_bitwise_prefix(c, ref) -> None:
    """Live-prefix bitwise equality of two CSRs.

    The batched-subsystem contract (DESIGN.md section 13): padding is
    capacity-only, so ``indptr``, ``nnz``, and the first ``nnz`` entries
    of ``indices``/``data`` must match bit for bit while the padded tails
    may differ in length.  Shared by ``tests/test_batch.py`` and the
    ``bench_batch`` CI smoke so the two enforcement sites cannot drift.
    """
    nnz = int(c.nnz)
    assert nnz == int(ref.nnz)
    assert np.array_equal(np.asarray(c.indptr), np.asarray(ref.indptr))
    assert np.array_equal(np.asarray(c.indices)[:nnz],
                          np.asarray(ref.indices)[:nnz])
    assert np.array_equal(np.asarray(c.data)[:nnz],
                          np.asarray(ref.data)[:nnz])


def batch_inspection_counters():
    """Counters over every inspection entry point of the batched
    subsystem: class-program builds, the symbolic phase, flop counting,
    and the schedule pipeline.  One definition shared by
    ``tests/test_batch.py`` and the ``bench_batch`` smoke so "zero
    re-inspection" means the same thing at both enforcement sites.
    Returns ``(counter, restore)``.
    """
    counter: dict = {}
    restore = [
        counted("repro.core.batch", "_build_class_program", counter),
        counted("repro.core.batch", "symbolic", counter),
        counted("repro.core.schedule", "flops_per_row", counter),
        counted("repro.core.schedule", "make_schedule_eager", counter),
    ]
    return counter, lambda: [r() for r in restore]


def batch_class_bound(pairs) -> int:
    """The p2 capacity-class bound for a same-shape fleet:
    ``ceil(log2 (max flop / min flop)) + 1`` (the +1 is the bucket
    fencepost -- values in [min, max] can straddle that many powers of
    two).  Shared by ``tests/test_batch.py`` and the ``bench_batch``
    smoke."""
    import math
    from repro.core.schedule import flops_per_row
    flops = [max(int(np.asarray(flops_per_row(a, b)).sum()), 1)
             for a, b in pairs]
    return math.ceil(math.log2(max(flops) / min(flops))) + 1


def planned_loop(plan, pairs):
    """The per-product planned reference path for a ``BatchedPlan`` fleet.

    One ``SpGEMMPlan`` per product with the *class's* algorithm and the
    batch plan's sortedness pinned -- identical numeric semantics to the
    batched executor, paid as N dispatches.  Returns a zero-arg runner
    (plans are built here, outside any timed region).  Shared by
    ``tests/test_batch.py`` and the ``bench_batch`` smoke so the two
    reference paths cannot drift.
    """
    from repro.core import plan_spgemm
    plans = [plan_spgemm(a, b, algorithm=plan.algorithms[i],
                         sorted_output=plan.sorted_output)
             for i, (a, b) in enumerate(pairs)]

    def run():
        return [p.execute(a, b) for p, (a, b) in zip(plans, pairs)]

    return run


def rmat_fleet(n_products: int, scale: int, seed0: int = 0):
    """Same-shape fleet with heterogeneous nnz/flop: mixed R-MAT presets
    and edge factors, the per-expert / per-subgraph serving shape.
    Shared by ``tests/test_batch.py`` and ``benchmarks/bench_batch.py``.
    """
    from repro.data.rmat import rmat_csr
    pairs = []
    for i in range(n_products):
        preset = "G500" if i % 2 else "ER"
        a = rmat_csr(scale, 1 + (i % 3), preset, seed=seed0 + i)
        b = rmat_csr(scale, 1 + ((i + 1) % 4), "ER", seed=seed0 + 100 + i)
        pairs.append((a, b))
    return pairs


def counted(module_name: str, attr: str, counter: dict):
    """Swap ``module.attr`` for a call-counting wrapper; return a restorer.

    The zero-re-inspection assertion helper shared by the plan /
    distributed / chain smoke suites: wrap the inspection entry points
    (``rows_to_bins``, ``make_schedule_eager``, the symbolic kernel, ...)
    around an ``execute`` and assert the counter stayed empty.
    """
    import importlib
    mod = importlib.import_module(module_name)
    orig = getattr(mod, attr)

    def wrapper(*a, **kw):
        counter[attr] = counter.get(attr, 0) + 1
        return orig(*a, **kw)

    setattr(mod, attr, wrapper)
    return lambda: setattr(mod, attr, orig)
