"""Shared benchmark utilities: timing + CSV emission.

CPU-container caveat (documented in EXPERIMENTS.md): the Pallas kernels run
in *interpret mode* here, so their absolute timings are not TPU-predictive;
what these benchmarks preserve from the paper is the **relative algorithm
behaviour** (density/size/CR trends, sorted-vs-unsorted gap, balanced-vs-
naive scheduling) plus exact throughput numbers for the XLA-compiled paths
(ESC, heap, SpMM).  TPU-projected numbers live in the roofline analysis.
"""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS = []


def bench(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median seconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    us = seconds * 1e6
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def flops_rate(flop: float, seconds: float) -> str:
    return f"{2.0 * flop / seconds / 1e6:.1f}MFLOPS"
