"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs the larger sizes;
the default quick mode fits the single-core container (see
benchmarks/common.py for the interpret-mode caveat).

``--json PATH`` additionally writes the run as a machine-readable perf
trajectory (``BENCH_spgemm.json`` by convention): every emitted row plus
environment provenance, one file per run -- CI produces and uploads it on
every push so regressions are diffable across commits.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys
import time
import traceback

from . import common
from . import bench_spgemm_figs as figs
from . import bench_graph as graph
from . import bench_micro as micro
from . import bench_moe_dispatch as moe_bench
from . import bench_plan as plan_bench
from . import bench_distributed as dist_bench
from . import bench_chain as chain_bench
from . import bench_batch as batch_bench
from . import bench_verify as verify_bench
from . import bench_autotune as autotune_bench
from . import bench_bcsr as bcsr_bench
from . import bench_pb as pb_bench


SUITES = [
    ("fig2_scheduling", lambda q: micro.fig2_scheduling(q)),
    ("fig4_alloc", lambda q: micro.fig4_alloc(q)),
    ("fig5_stanza", lambda q: micro.fig5_stanza(q)),
    ("fig9_balanced_vs_naive", lambda q: figs.fig9_balanced_vs_naive()),
    ("fig11_density", lambda q: figs.fig11_density(q)),
    ("fig12_size", lambda q: figs.fig12_size(q)),
    ("fig13_scaling", lambda q: figs.fig13_scaling(q)),
    ("fig14_compression", lambda q: figs.fig14_compression(q)),
    ("fig15_profiles", lambda q: figs.fig15_profiles(q)),
    ("fig16_tall_skinny", lambda q: figs.fig16_tall_skinny(q)),
    ("fig17_triangle", lambda q: figs.fig17_triangle(q)),
    ("table4_recipe", lambda q: figs.table4_recipe(q)),
    ("graph", lambda q: graph.run(q)),
    ("moe_dispatch", lambda q: moe_bench.run(q)),
    ("plan", lambda q: plan_bench.run(q)),
    ("distributed", lambda q: dist_bench.run(q)),
    ("chain", lambda q: chain_bench.run(q)),
    ("batch", lambda q: batch_bench.run(q)),
    ("verify", lambda q: verify_bench.run(q)),
    ("autotune", lambda q: autotune_bench.run(q)),
    ("bcsr", lambda q: bcsr_bench.run(q)),
    ("pb", lambda q: pb_bench.run(q)),
]


def _git_sha() -> str:
    """Current commit (best effort; benchmarks also run from tarballs)."""
    import subprocess
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=pathlib.Path(__file__).resolve().parent, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _jaxlib_version() -> str:
    try:
        import jaxlib
        return getattr(jaxlib, "__version__", "unknown")
    except ImportError:
        return "unknown"


def _row_doc(row) -> dict:
    """One trajectory row.  Rows that attached a work model via
    ``common.emit(..., flops=, bytes_moved=)`` carry roofline columns
    (bound / roof_fraction / achieved rates)."""
    name, us, derived, extras = row
    doc = {"name": name, "us_per_call": round(us, 3), "derived": derived}
    roof = extras.get("roofline")
    if roof is not None:
        doc["flops"] = extras["flops"]
        doc["bytes_moved"] = extras["bytes_moved"]
        doc["roofline_bound"] = roof["bound"]
        doc["roof_fraction"] = round(roof["roof_fraction"], 6)
        doc["achieved_gflops"] = round(roof["achieved_gflops"], 4)
        doc["achieved_gbps"] = round(roof["achieved_gbps"], 4)
    return doc


def write_json(path: str, suites_run, failures: int) -> None:
    """Serialize ``common.ROWS`` + provenance as the perf trajectory."""
    import jax
    doc = {
        "schema": 1,
        "unix_time": int(time.time()),
        "git_sha": _git_sha(),
        "jax": jax.__version__,
        "jaxlib": _jaxlib_version(),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "suites": list(suites_run),
        "failures": failures,
        "rows": [_row_doc(row) for row in common.ROWS],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {path}: {len(doc['rows'])} rows", file=sys.stderr)
    _feed_db(doc)


def _feed_db(doc: dict) -> None:
    """Best-effort: mirror the trajectory rows into the autotune PerfDB
    (``bench|`` namespace, aged by this run's git sha) so the perf history
    CI gates on is queryable next to the tuner's winners."""
    try:
        from repro.autotune import feed_bench_rows
        n = feed_bench_rows(doc)
        print(f"fed {n} rows into the autotune DB", file=sys.stderr)
    except Exception as exc:  # noqa: BLE001 - ingestion never fails a run
        print(f"autotune DB feed skipped ({type(exc).__name__}: {exc})",
              file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated suite names")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write results as a machine-readable perf "
                         "trajectory (e.g. BENCH_spgemm.json)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    suites_run = []
    for name, fn in SUITES:
        if only and name not in only:
            continue
        suites_run.append(name)
        try:
            fn(not args.full)
        except Exception:  # noqa: BLE001 - report and continue
            failures += 1
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc()
    if args.json:
        write_json(args.json, suites_run, failures)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
