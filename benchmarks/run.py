"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs the larger sizes;
the default quick mode fits the single-core container (see
benchmarks/common.py for the interpret-mode caveat).
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import common
from . import bench_spgemm_figs as figs
from . import bench_graph as graph
from . import bench_micro as micro
from . import bench_moe_dispatch as moe_bench
from . import bench_plan as plan_bench
from . import bench_distributed as dist_bench
from . import bench_chain as chain_bench


SUITES = [
    ("fig2_scheduling", lambda q: micro.fig2_scheduling(q)),
    ("fig4_alloc", lambda q: micro.fig4_alloc(q)),
    ("fig5_stanza", lambda q: micro.fig5_stanza(q)),
    ("fig9_balanced_vs_naive", lambda q: figs.fig9_balanced_vs_naive()),
    ("fig11_density", lambda q: figs.fig11_density(q)),
    ("fig12_size", lambda q: figs.fig12_size(q)),
    ("fig13_scaling", lambda q: figs.fig13_scaling(q)),
    ("fig14_compression", lambda q: figs.fig14_compression(q)),
    ("fig15_profiles", lambda q: figs.fig15_profiles(q)),
    ("fig16_tall_skinny", lambda q: figs.fig16_tall_skinny(q)),
    ("fig17_triangle", lambda q: figs.fig17_triangle(q)),
    ("table4_recipe", lambda q: figs.table4_recipe(q)),
    ("graph", lambda q: graph.run(q)),
    ("moe_dispatch", lambda q: moe_bench.run(q)),
    ("plan", lambda q: plan_bench.run(q)),
    ("distributed", lambda q: dist_bench.run(q)),
    ("chain", lambda q: chain_bench.run(q)),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated suite names")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in SUITES:
        if only and name not in only:
            continue
        try:
            fn(not args.full)
        except Exception:  # noqa: BLE001 - report and continue
            failures += 1
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
