"""Graph analytics on the SpGEMM engine: the paper's two application
scenarios (sections 5.5-5.6) end-to-end, on the masked/semiring layer
(DESIGN.md section 7) and the inspector-executor planner (section 10).

  * triangle counting: reorder by degree, split A = L + U, then one masked
    product ``spgemm(L, U, mask=A_perm)`` -- the mask prunes non-closing
    wedges *inside* the accumulator, so the wedge matrix is never
    materialized (no dense product, no post-filter);
  * multi-source BFS, two ways: the paper's dense tall-skinny SpMM frontier
    stack, and a masked-frontier variant ``spgemm(A, F, semiring="boolean",
    mask=visited, complement_mask=True)`` where the complemented visited
    mask retires vertices inside the product.

Every sparse product goes through ``plan_spgemm`` + ``plan.execute``: the
schedule + symbolic + recipe inspection runs once per *structure*, so a
repeated query over the same graph (the serving shape: many triangle
counts on reweighted graphs, the same BFS re-issued) skips straight to the
numeric phase via the structure-keyed plan cache.

    PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import CSR, plan_cache_stats, plan_spgemm, spmm
from repro.core.distributed import (plan_spgemm_1d, shard_csr_rows,
                                    unshard_rows)
from repro.data.rmat import rmat_csr, symmetrize, triangular_split


def triangle_count(a: CSR) -> int:
    """Triangles via masked wedges: tri = sum(L@U under mask A_perm) / 2.

    The product path is fully sparse: `plan_spgemm` runs the masked
    symbolic phase once (exact capacity, recorded algorithm) and the
    execute is numeric-only -- a second count on the same structure (e.g.
    a reweighted graph) reuses the cached plan.
    """
    L, U, adj = triangular_split(a, return_adjacency=True)
    plan = plan_spgemm(L, U, mask=adj, semiring="plus_times")
    c = plan.execute(L, U)
    tri = float(jnp.where(c.valid_mask(), c.data, 0).sum()) / 2
    return int(round(tri))


def triangle_count_distributed(a: CSR, mesh=None, axis: str = "data") -> int:
    """Mesh-scale masked triangle count: the L@U product row-sharded.

    Same algorithm as :func:`triangle_count`, lifted onto a device mesh
    (DESIGN.md section 11): L is sharded by the planner's per-row flop
    counts (equal-flop shard boundaries -- the paper's Fig. 6 partition at
    chip granularity), the mask is co-sharded with the output rows, and
    every chip runs the planned masked local product.  A repeat count on
    the same structure hits the distributed plan cache and runs
    numeric-only, exactly like the single-node serving loop.
    """
    if mesh is None:
        mesh = jax.sharding.Mesh(np.array(jax.devices()), (axis,))
    L, U, adj = triangular_split(a, return_adjacency=True)
    L_sh = shard_csr_rows(L, mesh.shape[axis], b=U)
    plan = plan_spgemm_1d(L_sh, U, mask=adj, semiring="plus_times")
    c = unshard_rows(plan.execute(mesh, L_sh, U, axis=axis))
    tri = float(jnp.where(c.valid_mask(), c.data, 0).sum()) / 2
    return int(round(tri))


def multi_source_bfs(a: CSR, sources, n_hops: int):
    """Hop distances from each source -- dense frontier stack (SpMM)."""
    n = a.n_rows
    k = len(sources)
    frontier = jnp.zeros((n, k), jnp.float32).at[
        jnp.asarray(sources), jnp.arange(k)].set(1.0)
    dist = jnp.where(frontier > 0, 0, -1).astype(jnp.int32)
    for hop in range(1, n_hops + 1):
        frontier = (spmm(a, frontier) > 0).astype(jnp.float32)
        newly = (frontier > 0) & (dist < 0)
        dist = jnp.where(newly, hop, dist)
    return dist


def _frontier_csr(rows, cols, shape, cap):
    vals = np.ones(len(rows), np.float32)
    return CSR.from_numpy_coo(np.asarray(rows), np.asarray(cols), vals,
                              shape, cap=cap)


def _coo_of(c: CSR):
    v = np.asarray(c.valid_mask())
    return np.asarray(c.row_ids())[v], np.asarray(c.indices)[v]


def multi_source_bfs_masked(a: CSR, sources, n_hops: int):
    """Masked-frontier BFS: sparse frontiers, visited retired by the mask.

    Each hop is one boolean-semiring SpGEMM with the *complemented* visited
    mask: candidates landing on visited vertices are pruned inside the
    product, so the frontier CSR only ever holds newly discovered vertices
    -- the direction-agnostic analogue of the paper's section 5.5 workload
    with the frontier kept sparse end to end.

    Each hop's product is planned: the plan's symbolic phase *is* the
    frontier-size oracle (``plan.nnz_c``), and its exact capacities feed
    the numeric execute.  Hop structures depend only on (graph, sources),
    so re-issuing the same BFS -- the serving pattern -- hits the plan
    cache on every hop and runs numeric-only end to end.
    """
    n, k = a.n_rows, len(sources)
    cap = n * k
    rows, cols = np.asarray(sources), np.arange(k)
    frontier = _frontier_csr(rows, cols, (n, k), cap)
    visited = frontier
    dist = np.full((n, k), -1, np.int32)
    dist[rows, cols] = 0
    for hop in range(1, n_hops + 1):
        # bucket_caps: hop structures drift, so power-of-two capacities let
        # hops with similar frontier sizes share compiled programs on the
        # first run (repeat runs hit the plan cache regardless)
        plan = plan_spgemm(a, frontier, algorithm="hash",
                           semiring="boolean", mask=visited,
                           complement_mask=True, bucket_caps=True)
        if plan.nnz_c == 0:
            break
        nxt = plan.execute(a, frontier)
        nr, nc = _coo_of(nxt)
        dist[nr, nc] = hop
        vr, vc = _coo_of(visited)
        visited = _frontier_csr(np.concatenate([vr, nr]),
                                np.concatenate([vc, nc]), (n, k), cap)
        frontier = _frontier_csr(nr, nc, (n, k), cap)
    return jnp.asarray(dist)


def main():
    import time

    # undirected graph from an R-MAT pattern
    a = symmetrize(rmat_csr(8, 8, "G500", seed=1))
    ad = np.asarray(a.to_dense())
    print(f"graph: {a.n_rows} vertices, {int(a.nnz)} edges (directed nnz)")

    tri = triangle_count(a)
    brute = int(np.trace(np.linalg.matrix_power(ad.astype(np.int64), 3)) // 6)
    print(f"triangles: masked L@U -> {tri}, brute force -> {brute}")
    assert tri == brute

    sources = [0, 17, 42, 100]
    dist = multi_source_bfs(a, sources, n_hops=6)

    t0 = time.perf_counter()
    dist_m = multi_source_bfs_masked(a, sources, n_hops=6)
    t_first = time.perf_counter() - t0
    assert np.array_equal(np.asarray(dist), np.asarray(dist_m)), \
        "masked-frontier BFS must agree with the dense frontier stack"
    reached = np.asarray((dist >= 0).sum(axis=0))
    print(f"multi-source BFS from {sources}: reached per source {reached} "
          f"(dense SpMM == masked boolean SpGEMM)")

    # serving shape: the same query again -- every hop hits the plan cache
    before = plan_cache_stats()
    t0 = time.perf_counter()
    dist_r = multi_source_bfs_masked(a, sources, n_hops=6)
    t_repeat = time.perf_counter() - t0
    after = plan_cache_stats()
    assert np.array_equal(np.asarray(dist_m), np.asarray(dist_r))
    hops_hit = after["hits"] - before["hits"]
    assert after["misses"] == before["misses"], \
        "repeat BFS must not plan anything new"
    print(f"repeat BFS: {hops_hit} cached plans (no schedule/symbolic/"
          f"recipe recomputation), {t_first:.3f}s -> {t_repeat:.3f}s")
    # repeat triangle count hits the cache too (reweighted-graph pattern)
    assert triangle_count(a) == brute

    # mesh scale-out: the same masked count, row-sharded over every device
    # this process sees (a real mesh on TPU; host devices under XLA_FLAGS)
    tri_d = triangle_count_distributed(a)
    assert tri_d == brute, (tri_d, brute)
    before = plan_cache_stats()
    assert triangle_count_distributed(a) == brute
    after = plan_cache_stats()
    assert after["misses"] == before["misses"], \
        "repeat distributed count must replan nothing"
    print(f"distributed triangle count over {len(jax.devices())} device(s): "
          f"{tri_d} (plan cache hit on repeat)")
    print(f"plan cache: {plan_cache_stats()}")


if __name__ == "__main__":
    main()
