"""Graph analytics on the SpGEMM engine: the paper's two application
scenarios (sections 5.5-5.6) end-to-end, on the masked/semiring layer
(DESIGN.md section 7).

  * triangle counting: reorder by degree, split A = L + U, then one masked
    product ``spgemm(L, U, mask=A_perm)`` -- the mask prunes non-closing
    wedges *inside* the accumulator, so the wedge matrix is never
    materialized (no dense product, no post-filter);
  * multi-source BFS, two ways: the paper's dense tall-skinny SpMM frontier
    stack, and a masked-frontier variant ``spgemm(A, F, semiring="boolean",
    mask=visited, complement_mask=True)`` where the complemented visited
    mask retires vertices inside the product.

    PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import CSR, lowest_p2, spgemm, spmm, symbolic
from repro.data.rmat import rmat_csr, symmetrize, triangular_split


def triangle_count(a: CSR) -> int:
    """Triangles via masked wedges: tri = sum(L@U under mask A_perm) / 2.

    The product path is fully sparse: capacity comes from the masked
    symbolic phase and the count is read off the CSR values directly.
    """
    L, U, adj = triangular_split(a, return_adjacency=True)
    row_nnz, _, _, _ = symbolic(L, U, mask=adj)
    cap = int(np.asarray(row_nnz).sum()) + 8
    c = spgemm(L, U, cap, algorithm="auto", mask=adj, semiring="plus_times")
    tri = float(jnp.where(c.valid_mask(), c.data, 0).sum()) / 2
    return int(round(tri))


def multi_source_bfs(a: CSR, sources, n_hops: int):
    """Hop distances from each source -- dense frontier stack (SpMM)."""
    n = a.n_rows
    k = len(sources)
    frontier = jnp.zeros((n, k), jnp.float32).at[
        jnp.asarray(sources), jnp.arange(k)].set(1.0)
    dist = jnp.where(frontier > 0, 0, -1).astype(jnp.int32)
    for hop in range(1, n_hops + 1):
        frontier = (spmm(a, frontier) > 0).astype(jnp.float32)
        newly = (frontier > 0) & (dist < 0)
        dist = jnp.where(newly, hop, dist)
    return dist


def _frontier_csr(rows, cols, shape, cap):
    vals = np.ones(len(rows), np.float32)
    return CSR.from_numpy_coo(np.asarray(rows), np.asarray(cols), vals,
                              shape, cap=cap)


def _coo_of(c: CSR):
    v = np.asarray(c.valid_mask())
    return np.asarray(c.row_ids())[v], np.asarray(c.indices)[v]


def multi_source_bfs_masked(a: CSR, sources, n_hops: int):
    """Masked-frontier BFS: sparse frontiers, visited retired by the mask.

    Each hop is one boolean-semiring SpGEMM with the *complemented* visited
    mask: candidates landing on visited vertices are pruned inside the
    product, so the frontier CSR only ever holds newly discovered vertices
    -- the direction-agnostic analogue of the paper's section 5.5 workload
    with the frontier kept sparse end to end.
    """
    n, k = a.n_rows, len(sources)
    cap = n * k
    rows, cols = np.asarray(sources), np.arange(k)
    frontier = _frontier_csr(rows, cols, (n, k), cap)
    visited = frontier
    dist = np.full((n, k), -1, np.int32)
    dist[rows, cols] = 0
    for hop in range(1, n_hops + 1):
        row_nnz, _, _, _ = symbolic(a, frontier, mask=visited,
                                    complement_mask=True)
        nnz_next = int(np.asarray(row_nnz).sum())
        if nnz_next == 0:
            break
        # power-of-two capacity buckets: cap_c is a static jit argument, so
        # an exact per-hop cap would recompile the product every hop.
        nxt = spgemm(a, frontier, lowest_p2(nnz_next + 8), algorithm="hash",
                     semiring="boolean", mask=visited, complement_mask=True)
        nr, nc = _coo_of(nxt)
        dist[nr, nc] = hop
        vr, vc = _coo_of(visited)
        visited = _frontier_csr(np.concatenate([vr, nr]),
                                np.concatenate([vc, nc]), (n, k), cap)
        frontier = _frontier_csr(nr, nc, (n, k), cap)
    return jnp.asarray(dist)


def main():
    # undirected graph from an R-MAT pattern
    a = symmetrize(rmat_csr(8, 8, "G500", seed=1))
    ad = np.asarray(a.to_dense())
    print(f"graph: {a.n_rows} vertices, {int(a.nnz)} edges (directed nnz)")

    tri = triangle_count(a)
    brute = int(np.trace(np.linalg.matrix_power(ad.astype(np.int64), 3)) // 6)
    print(f"triangles: masked L@U -> {tri}, brute force -> {brute}")
    assert tri == brute

    sources = [0, 17, 42, 100]
    dist = multi_source_bfs(a, sources, n_hops=6)
    dist_m = multi_source_bfs_masked(a, sources, n_hops=6)
    assert np.array_equal(np.asarray(dist), np.asarray(dist_m)), \
        "masked-frontier BFS must agree with the dense frontier stack"
    reached = np.asarray((dist >= 0).sum(axis=0))
    print(f"multi-source BFS from {sources}: reached per source {reached} "
          f"(dense SpMM == masked boolean SpGEMM)")


if __name__ == "__main__":
    main()
