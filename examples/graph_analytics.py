"""Graph analytics on the SpGEMM engine: the paper's two application
scenarios (sections 5.5-5.6) end-to-end.

  * triangle counting: reorder by degree, split A = L + U, count via L @ U
  * multi-source BFS: square x tall-skinny SpMM over frontier stacks

    PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import CSR, spgemm_esc, spmm
from repro.data.rmat import rmat_csr, triangular_split


def triangle_count(a: CSR) -> int:
    """Triangles via wedges: tri = sum(L@U .* A_perm) / 2 (section 5.6)."""
    L, U = triangular_split(a)
    wedges_cap = 1 << 18
    c = spgemm_esc(L, U, cap_c=wedges_cap)
    perm_adj = (L.to_dense() + U.to_dense()) > 0
    tri = float(jnp.sum(c.to_dense() * perm_adj) / 2)
    return int(round(tri))


def multi_source_bfs(a: CSR, sources, n_hops: int):
    """Hop distances from each source (betweenness-style frontier stack)."""
    n = a.n_rows
    k = len(sources)
    frontier = jnp.zeros((n, k), jnp.float32).at[
        jnp.asarray(sources), jnp.arange(k)].set(1.0)
    dist = jnp.where(frontier > 0, 0, -1).astype(jnp.int32)
    for hop in range(1, n_hops + 1):
        frontier = (spmm(a, frontier) > 0).astype(jnp.float32)
        newly = (frontier > 0) & (dist < 0)
        dist = jnp.where(newly, hop, dist)
    return dist


def main():
    # undirected graph from an R-MAT pattern
    g = rmat_csr(8, 8, "G500", seed=1)
    ad = np.asarray(g.to_dense())
    ad = ((ad + ad.T) > 0).astype(np.float32)
    np.fill_diagonal(ad, 0)
    a = CSR.from_dense(jnp.asarray(ad))
    print(f"graph: {a.n_rows} vertices, {int(a.nnz)} edges (directed nnz)")

    tri = triangle_count(a)
    brute = int(np.trace(np.linalg.matrix_power(ad.astype(np.int64), 3)) // 6)
    print(f"triangles: L@U -> {tri}, brute force -> {brute}")
    assert tri == brute

    sources = [0, 17, 42, 100]
    dist = multi_source_bfs(a, sources, n_hops=6)
    reached = np.asarray((dist >= 0).sum(axis=0))
    print(f"multi-source BFS from {sources}: reached per source {reached}")


if __name__ == "__main__":
    main()
