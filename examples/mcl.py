"""Markov clustering (MCL, van Dongen 2000) on the planned SpGEMM engine.

MCL finds graph clusters by iterating a row-stochastic flow matrix M:

  * **expand**  -- M <- M @ M: a planned SpGEMM (``plan_spgemm``; the A^2
    shape of ``core.chain.plan_power``).  Flow spreads along paths;
  * **inflate** -- M <- row_normalize(M ** r): a jitted elementwise kernel
    that sharpens strong flows and starves weak ones;
  * **prune**   -- drop entries below a threshold and renormalize: a
    jitted compaction, keeping the matrix sparse as it converges.

The loop is the *structure-drift* serving shape (DESIGN.md sections 10 &
12): every iteration's M has a different sparsity pattern, so exact-
capacity plans would compile a fresh numeric program per iteration.
``plan_spgemm(..., bucket_caps=True)`` p2-rounds the static capacities
(``cap_c``/``flop_cap``) instead, so successive iterations whose bucketed
sizes coincide share compiled programs -- the example prints the jit
program count next to the iteration count to show the sharing.  Expansion
products run the hash family unsorted (nothing downstream needs sorted
rows -- the C8 finding applied to an iterative workload).

    PYTHONPATH=src python examples/mcl.py
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSR, plan_cache_stats, plan_spgemm, spgemm_hash_jnp
from repro.core.schedule import prefix_sum


def clustered_graph(n_clusters: int = 3, size: int = 12, p_in: float = 0.6,
                    p_out: float = 0.02, seed: int = 0) -> CSR:
    """Planted-partition graph: dense blocks, sparse inter-block noise.

    The clustered analogue of the R-MAT inputs used elsewhere: each block
    is an Erdos-Renyi community at ``p_in``, cross edges appear at
    ``p_out``; symmetric, no self loops (MCL adds its own).
    """
    n = n_clusters * size
    rng = np.random.default_rng(seed)
    dense = rng.random((n, n))
    labels = np.repeat(np.arange(n_clusters), size)
    same = labels[:, None] == labels[None, :]
    adj = np.where(same, dense < p_in, dense < p_out)
    adj = np.triu(adj, k=1)
    adj = (adj | adj.T).astype(np.float32)
    return CSR.from_dense(jnp.asarray(adj))


@jax.jit
def row_normalize(c: CSR) -> CSR:
    """Make each row of ``c`` sum to 1 (rows with no mass stay zero)."""
    v = jnp.where(c.valid_mask(), c.data, 0)
    s = jax.ops.segment_sum(v, c.row_ids(), num_segments=c.n_rows)
    s = jnp.where(s == 0, 1.0, s)
    return dataclasses.replace(c, data=v / s[c.row_ids()])


@jax.jit
def inflate(c: CSR, power) -> CSR:
    """MCL inflation: elementwise power then row renormalization."""
    v = jnp.where(c.valid_mask(), c.data, 0) ** power
    return row_normalize(dataclasses.replace(c, data=v))


@partial(jax.jit, static_argnames=("cap_out",))
def prune(c: CSR, threshold, cap_out: int) -> CSR:
    """Drop entries below ``threshold``, compact to ``cap_out`` slots,
    renormalize rows.

    Stable compaction (argsort of the drop mask) preserves within-row
    entry order, so an unsorted hash-family expansion stays a valid
    unsorted CSR.  ``cap_out`` is static; pruning only removes entries, so
    the input's capacity is always a safe choice.
    """
    keep = c.valid_mask() & (c.data >= threshold)
    order = jnp.argsort(~keep, stable=True)
    lane = jnp.arange(cap_out, dtype=jnp.int32)
    src = order[jnp.minimum(lane, c.cap - 1)]       # pad or truncate
    nnz = jnp.minimum(keep.sum(), cap_out).astype(jnp.int32)
    valid = lane < nnz
    indices = jnp.where(valid, c.indices[src], 0)
    data = jnp.where(valid, c.data[src], 0)
    row_nnz = jax.ops.segment_sum(keep.astype(jnp.int32), c.row_ids(),
                                  num_segments=c.n_rows)
    indptr = prefix_sum(row_nnz).astype(jnp.int32)
    out = CSR(indptr, indices, data, nnz, c.shape,
              sorted_cols=c.sorted_cols)
    return row_normalize(out)


def _with_self_loops(a: CSR) -> CSR:
    d = np.array(a.to_dense())
    np.fill_diagonal(d, 1.0)
    return CSR.from_dense(jnp.asarray(d))


def mcl(a: CSR, inflation: float = 1.5, threshold: float = 1e-3,
        max_iters: int = 40, tol: float = 1e-5):
    """Run MCL to convergence; returns ``(labels, n_iters)``.

    ``labels[i]`` is the cluster id of vertex ``i``: in the converged
    row-stochastic limit, row i's mass sits on i's attractor set, so the
    argmax column identifies the cluster (canonicalized to 0..k-1).
    """
    from repro.core import lowest_p2

    m = row_normalize(_with_self_loops(a))
    n_iters = 0
    buf_cap = None
    for n_iters in range(1, max_iters + 1):
        # expand: planned A^2 with bucketed (p2) capacities -- iterations
        # with the same bucketed sizes share one compiled numeric program
        plan = plan_spgemm(m, m, algorithm="hash_jnp", bucket_caps=True)
        nxt = plan.execute(m, m)
        nxt = inflate(nxt, jnp.float32(inflation))
        # the flow matrix lives in a fixed-cap buffer: static input shapes
        # are half of program sharing (the other half is the plan's p2
        # capacities); grow only if pruning would drop live entries
        kept = int(jnp.sum(nxt.valid_mask() & (nxt.data >= threshold)))
        if buf_cap is None or kept > buf_cap:
            buf_cap = lowest_p2(max(kept, 1))
        nxt = prune(nxt, jnp.float32(threshold), buf_cap)
        delta = float(jnp.abs(nxt.to_dense() - m.to_dense()).max())
        m = nxt
        if delta < tol:
            break
    md = np.asarray(m.to_dense())
    attractor = md.argmax(axis=1)
    _, labels = np.unique(attractor, return_inverse=True)
    return labels, n_iters


def main():
    n_clusters, size = 3, 12
    a = clustered_graph(n_clusters, size, seed=0)
    print(f"graph: {a.n_rows} vertices, {int(a.nnz)} edges, "
          f"{n_clusters} planted clusters")

    labels, n_iters = mcl(a)
    truth = np.repeat(np.arange(n_clusters), size)
    # same partition iff labels are constant within each planted block and
    # distinct across blocks
    blocks = [set(labels[truth == k]) for k in range(n_clusters)]
    assert all(len(s) == 1 for s in blocks), blocks
    assert len({next(iter(s)) for s in blocks}) == n_clusters, blocks
    print(f"MCL converged in {n_iters} iterations; "
          f"recovered all {n_clusters} planted clusters")

    stats = plan_cache_stats()
    programs = spgemm_hash_jnp._cache_size()
    print(f"plan cache: {stats['misses']} inspections for {n_iters} "
          f"drifting structures; {programs} compiled expansion program(s) "
          f"(bucket_caps p2 sharing)")
    assert programs < n_iters or n_iters <= 2, \
        "bucketed capacities should let drifting iterations share programs"


if __name__ == "__main__":
    main()
