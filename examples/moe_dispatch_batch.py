"""Batched MoE dispatch + block-diagonal fleets (DESIGN.md section 13).

Two fleet-of-small-products workloads on the batched subsystem
(``repro.core.batch``), both shapes the repo already serves elsewhere:

  1. **Per-expert MoE dispatch as SpGEMM.**  The MoE benchmark
     (``benchmarks/bench_moe_dispatch.py``) runs dispatch dense, as one
     gather inside the LM; here the same routing (32 experts, top-4, the
     qwen3-moe reduced shapes) is expressed sparsely: expert ``e``'s
     dispatch is the product ``G_e @ F`` of its one-hot token-gather
     matrix with a shared sparse feature matrix -- a fleet of 32 products
     sharing one B.  ``plan_batch`` inspects the fleet once, buckets it
     into a handful of p2 capacity classes, and every serving step
     executes a few vmapped programs instead of 32 dispatches.
  2. **Block-diagonal squaring.**  The DBCSR shape (quantum-chemistry
     batches of small block products): per-subgraph adjacency blocks
     squared with ``plan_batch_power`` -- drifting block structures share
     compiled programs through the p2 classes.

Run:  PYTHONPATH=src python examples/moe_dispatch_batch.py
"""
from __future__ import annotations

import sys
import time

import numpy as np
import jax

sys.path.insert(0, "src")

from repro.core import (CSR, clear_plan_cache, plan_batch,  # noqa: E402
                        plan_batch_power, plan_cache_stats, plan_spgemm,
                        shard_batch)
from repro.data.rmat import rmat_csr  # noqa: E402

# the bench_moe_dispatch routing shapes (qwen3-moe-30b-a3b, reduced)
N_EXPERTS = 32
TOP_K = 4
T = 1024              # tokens (bench runs 4096; reduced for the demo)
D_MODEL = 256
FEATURE_DENSITY = 0.05


def build_dispatch_fleet(seed: int = 0):
    """Per-expert gather matrices G_e (cap_e x T) + shared sparse F (T x d).

    The router draws top-4 experts per token (uniform, like the synthetic
    router of the MoE bench); G_e has one unit entry per slot (slot ->
    token), so ``G_e @ F`` is exactly expert e's dispatched feature rows.
    """
    rng = np.random.default_rng(seed)
    assign = np.stack([rng.choice(N_EXPERTS, size=TOP_K, replace=False)
                       for _ in range(T)])                # (T, top_k)
    fd = rng.uniform(0.5, 1.5, size=(T, D_MODEL)).astype(np.float32)
    fd = np.where(rng.random((T, D_MODEL)) < FEATURE_DENSITY, fd, 0.0)
    rows, cols = np.nonzero(fd)
    f = CSR.from_numpy_coo(rows, cols, fd[rows, cols], (T, D_MODEL))

    pairs = []
    for e in range(N_EXPERTS):
        tokens = np.nonzero((assign == e).any(axis=1))[0]
        cap_e = max(len(tokens), 1)
        g = CSR.from_numpy_coo(np.arange(len(tokens)), tokens,
                               np.ones(len(tokens), np.float32),
                               (cap_e, T))
        pairs.append((g, f))
    return pairs, fd, assign


def moe_dispatch_demo():
    print(f"== batched MoE dispatch: {N_EXPERTS} experts, top-{TOP_K}, "
          f"{T} tokens, d={D_MODEL} ==")
    pairs, fd, assign = build_dispatch_fleet()
    clear_plan_cache()
    plan = plan_batch(pairs)
    print(f"fleet of {plan.n_products} products -> {plan.n_classes} "
          f"capacity classes, algorithms {sorted(set(plan.algorithms))}")
    assert plan.n_classes <= 6, "expert loads should bucket tightly"

    outs = plan.execute(pairs)
    for e, ((g, _), c) in enumerate(zip(pairs, outs)):
        tokens = np.nonzero((assign == e).any(axis=1))[0]
        assert np.allclose(np.asarray(c.to_dense()), fd[tokens], atol=1e-5)
    print("dispatched features == gathered oracle rows: OK")

    # serving-step comparison: the same numeric work as one plan per
    # expert, minus the per-expert dispatch overhead
    per_expert = [plan_spgemm(g, f, algorithm=plan.algorithms[i])
                  for i, (g, f) in enumerate(pairs)]

    def loop():
        return [p.execute(g, f) for p, (g, f) in zip(per_expert, pairs)]

    jax.block_until_ready(loop())
    jax.block_until_ready(plan.execute(pairs))
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(loop())
    t_loop = (time.perf_counter() - t0) / 3
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(plan.execute(pairs))
    t_bat = (time.perf_counter() - t0) / 3
    # regime note: dispatch-size products are compute-bound on CPU, so
    # the batched win here is program economy (2 programs vs 32) and
    # serving simplicity; the raw-speed crossover lives at fleets of
    # *small* products -- bench_batch.py --smoke asserts it at 64 tiny
    # products, and its suite rows show the break-even
    print(f"loop-of-planned {t_loop * 1e6:.0f}us vs batched "
          f"{t_bat * 1e6:.0f}us per serving step "
          f"({plan.n_products} dispatches vs {plan.n_classes} programs)")

    # the fleet distributes by whole products: round-robin across chips,
    # heaviest experts spread first under exact per-product flop weights
    # (class-level caps would tie within a class and degenerate to index
    # order)
    from repro.core.schedule import flops_per_row
    flops = [int(np.asarray(flops_per_row(g, f)).sum()) for g, f in pairs]
    assignment = shard_batch(pairs, 4, weights=flops)
    sizes = [len(s) for s in assignment]
    print(f"shard_batch over 4 chips: {sizes} products per chip")
    assert sorted(i for s in assignment for i in s) == \
        list(range(N_EXPERTS))


def block_diagonal_demo():
    print("== block-diagonal squaring (DBCSR-style fleet) ==")
    blocks = [rmat_csr(4, 1 + (i % 3), "G500" if i % 2 else "ER",
                       seed=40 + i) for i in range(12)]
    clear_plan_cache()
    plan = plan_batch_power(blocks, 2)
    outs = plan.execute(blocks)
    for a, c in zip(blocks, outs):
        d = np.asarray(a.to_dense(), np.float64)
        assert np.allclose(np.asarray(c.to_dense()), d @ d, atol=1e-3)
    print(f"{plan.n_products} blocks squared with {plan.n_classes} "
          f"compiled programs "
          f"(vs {plan.n_products * plan.n_stages} per-product)")
    assert plan.n_classes < plan.n_products * plan.n_stages
    kinds = plan_cache_stats()["kinds"]
    print(f"plan cache kinds: batch={kinds['batch']}, "
          f"batch_power={kinds['batch_power']}")


if __name__ == "__main__":
    moe_dispatch_demo()
    block_diagonal_demo()
    print("moe_dispatch_batch: OK")
