"""Quickstart: SpGEMM with the hash kernel + the recipe (paper sections 4-5).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (spgemm, spgemm_esc, measure_stats, model_costs,
                        choose_algorithm, symbolic)
from repro.data.rmat import rmat_csr


def main():
    # A Graph500-style power-law matrix (scale 8 = 256 vertices, ef 8)
    a = rmat_csr(8, 8, "G500", seed=0)
    print(f"A: {a.shape}, nnz={int(a.nnz)}")

    # Two-phase: symbolic gives exact output size (Fig. 7 phase 1)
    row_nnz, indptr_c, flop, total_flop = symbolic(a, a)
    nnz_c = int(row_nnz.sum())
    print(f"symbolic: flop={int(total_flop)}, nnz(A^2)={nnz_c}, "
          f"compression ratio={int(total_flop) / nnz_c:.2f}")

    # The recipe picks an algorithm from the stats (Table 4)
    stats = measure_stats(a, a)
    print("cost model:", {k: f"{v:.2e}" for k, v in
                          model_costs(stats, sorted_output=False).items()})
    algo = choose_algorithm(a, a, sorted_output=False)
    print(f"recipe picks: {algo}")

    # Run it (hash kernels run in interpret mode on CPU)
    c = spgemm(a, a, cap_c=nnz_c + 16, algorithm=algo, n_bins=8)
    print(f"C = A@A: nnz={int(c.nnz)}, sorted={c.sorted_cols}")

    # C8: ask for sorted output only when you need it -- it costs a pass
    c_sorted = spgemm(a, a, cap_c=nnz_c + 16, algorithm=algo,
                      sorted_output=True, n_bins=8)
    ref = spgemm_esc(a, a, cap_c=nnz_c + 16)
    err = float(jnp.abs(c_sorted.to_dense() - ref.to_dense()).max())
    print(f"hash vs ESC max err: {err:.2e}")
    assert err < 1e-3


if __name__ == "__main__":
    main()
