"""Serve a small model with batched requests through the continuous-
batching engine (iteration-level scheduling, per-slot positions).

    PYTHONPATH=src python examples/serve_lm.py --requests 12
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import transformer as T
from repro.parallel.sharding import single_device_ctx
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list(ARCHS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    pctx = single_device_ctx(remat=False, attn_impl="full")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, pctx, max_batch=args.max_batch, max_len=128)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for r in range(args.requests):
        plen = int(rng.integers(4, 32))
        shape = (plen, cfg.n_codebooks) if cfg.n_codebooks else (plen,)
        eng.add_request(Request(
            rid=r,
            prompt=rng.integers(0, cfg.vocab_size, size=shape)
            .astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=0.8 if r % 2 else 0.0))
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(d.out_tokens) for d in done)
    print(f"{args.arch}: {len(done)} requests, {toks} tokens, "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s, batch={args.max_batch})")
    for d in done[:3]:
        print(f"  req {d.rid}: {[int(t) for t in d.out_tokens[:8]]}...")


if __name__ == "__main__":
    main()
