"""End-to-end training driver: train a qwen3-family LM on the synthetic
Markov stream for a few hundred steps, with checkpointing.

Default is a ~10M-param reduced config sized for this CPU container; pass
``--params 100m`` for the ~100M-class run (same code path, longer wall
time), or use ``python -m repro.launch.train`` directly for full configs.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

from repro.configs import ARCHS, reduced
from repro.parallel.sharding import single_device_ctx
from repro.train import loop as loop_lib
from repro.train import optimizer as opt


def build_cfg(size: str):
    base = ARCHS["qwen3-0.6b"]
    if size == "10m":
        cfg = reduced(base, d_model=128, vocab=512)
        cfg = dataclasses.replace(cfg, n_layers=4, d_ff=512, name="qwen3-10m")
    elif size == "100m":
        cfg = reduced(base, d_model=512, vocab=8192)
        cfg = dataclasses.replace(cfg, n_layers=12, d_ff=2048, n_heads=8,
                                  n_kv_heads=4, head_dim=64,
                                  name="qwen3-100m")
    else:
        raise SystemExit(f"unknown --params {size}")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--params", choices=["10m", "100m"], default="10m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = build_cfg(args.params)
    n = cfg.param_count()
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")
    pctx = single_device_ctx(remat=False, attn_impl="chunked")
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=args.steps // 10,
                           total_steps=args.steps)
    lcfg = loop_lib.LoopConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        log_every=max(args.steps // 20, 1), ckpt_dir=args.ckpt_dir,
        global_batch=args.batch, seq_len=args.seq)

    def log(m):
        print(f"  step {m['step']:5d} loss {m['loss']:.4f} "
              f"({m['sec_per_step']:.2f}s/step)", flush=True)

    _, hist = loop_lib.run(cfg, pctx, ocfg, lcfg, on_metrics=log)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'OK: learning' if last < first else 'NOT LEARNING?'})")


if __name__ == "__main__":
    main()
