"""repro: JAX/Pallas reproduction of Nagasaka et al. 2018 SpGEMM +
a multi-pod LM training/serving framework built around it.

Public API surface:
    repro.core      -- sparse formats + SpGEMM engine (the paper's contribution)
    repro.kernels   -- Pallas TPU kernels (hash SpGEMM, BCSR SpGEMM, SpMM, flash attn)
    repro.models    -- LM model zoo (dense / MoE / SSM / hybrid / VLM / audio)
    repro.configs   -- assigned architecture configs + input shapes
    repro.parallel  -- sharding rules, collectives, pipeline
    repro.train     -- optimizer, train step, loop
    repro.serve     -- prefill/decode engine
    repro.launch    -- mesh, dry-run, drivers
"""

__version__ = "1.0.0"
