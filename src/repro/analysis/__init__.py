"""Dry-run artifact analysis: HLO collective audit + roofline model."""
