"""Collective-bytes audit from optimized HLO text.

``cost_analysis`` has no collective term, so the roofline's third term is
derived here: every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` op's result shape bytes are summed,
grouped by kind.

Loop caveat: ops inside ``while`` bodies (lax.scan over layer periods)
appear ONCE in the module text but execute once per trip.  The same is true
of ``cost_analysis`` flops.  The dry-run therefore runs a 1-period and a
2-period *calibration compile* per cell and linearly extrapolates:
``total = full_reported + (n_periods - 1) * (c2 - c1)`` -- see
``launch/dryrun.py::run_cell(calibrate=True)``.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([\w\[\],{}]+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum collective result bytes by kind over the optimized module text.

    Counts each op once (see module docstring for the loop-trip handling).
    """
    per_kind: dict[str, int] = defaultdict(int)
    count: dict[str, int] = defaultdict(int)
    for cm in _COLL_RE.finditer(hlo_text):
        shape_str = cm.group(1) or cm.group(2)
        kind = cm.group(3)
        per_kind[kind] += _shape_bytes(shape_str)
        count[kind] += 1
    return {"bytes_by_kind": dict(per_kind),
            "count_by_kind": dict(count),
            "total_bytes": int(sum(per_kind.values()))}
