"""EXPERIMENTS.md generator: composes the §Dry-run/§Roofline/§Perf tables
from the results/*.jsonl artifacts so the report is reproducible.

    PYTHONPATH=src python -m repro.analysis.report > EXPERIMENTS.md
"""
from __future__ import annotations

import json
import os
import sys

from . import roofline as R

RESULTS = "results"


def load(path):
    out = {}
    p = os.path.join(RESULTS, path)
    if not os.path.exists(p):
        return out
    for line in open(p):
        line = line.strip()
        if not line:
            continue
        r = json.loads(line)
        out[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return out


def fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def dryrun_table(recs, title):
    rows = [f"### {title}", "",
            "| arch | shape | compile s | HBM args GB/chip | temp GB/chip | "
            "collective GB/chip/step |", "|---|---|---|---|---|---|"]
    for k in sorted(recs):
        r = recs[k]
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR {r['error'][:40]} | | | |")
            continue
        tot = R.corrected_totals(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r.get('compile_s', '?')} "
            f"| {fmt_bytes(r.get('argument_size_in_bytes', 0))} "
            f"| {fmt_bytes(r.get('temp_size_in_bytes', 0))} "
            f"| {fmt_bytes(tot['coll_bytes'])} |")
    return "\n".join(rows)


def roofline_table(recs, title):
    rows = [f"### {title}", "",
            "| arch | shape | compute s | memory s | collective s | "
            "bottleneck | roofline frac | useful ratio |",
            "|---|---|---|---|---|---|---|---|"]
    for k in sorted(recs):
        r = recs[k]
        if "error" in r:
            continue
        a = R.analyze(r)
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']:.3e} "
            f"| {a['memory_s']:.3e} | {a['collective_s']:.3e} "
            f"| {a['bottleneck']} | {a['roofline_fraction']:.3f} "
            f"| {a['useful_ratio']:.2f} |")
    return "\n".join(rows)


def perf_compare(base, opt, cells):
    rows = ["| cell | metric | baseline (paper-faithful) | optimized | gain |",
            "|---|---|---|---|---|"]
    for (arch, shape) in cells:
        kb = (arch, shape, "16x16")
        if kb not in base or kb not in opt:
            continue
        b, n = R.analyze(base[kb]), R.analyze(opt[kb])
        for t, nice in (("roofline_fraction", "roofline fraction"),
                        ("compute_s", "compute term (s)"),
                        ("memory_s", "memory term (s)"),
                        ("collective_s", "collective term (s)")):
            gain = (n[t] / max(b[t], 1e-12)) if t == "roofline_fraction" \
                else (b[t] / max(n[t], 1e-12))
            rows.append(f"| {arch} {shape} | {nice} | {b[t]:.3e} "
                        f"| {n[t]:.3e} | {gain:.2f}x |")
    return "\n".join(rows)


def main():
    base1 = load("dryrun_1pod.jsonl")
    base2 = load("dryrun_2pod.jsonl")
    opt1 = load("dryrun_1pod_opt.jsonl")
    opt2 = load("dryrun_2pod_opt.jsonl")
    print(HEADER)
    print("## Dry-run (deliverable e)\n")
    print(DRYRUN_INTRO)
    print(dryrun_table(opt1, "Single pod 16x16 = 256 chips (optimized code)"))
    print()
    print(dryrun_table(opt2 or base2,
                       "Two pods 2x16x16 = 512 chips"
                       + ("" if opt2 else " (baseline sweep)")))
    print()
    print("## Roofline (deliverable g)\n")
    print(ROOFLINE_INTRO)
    print(roofline_table(base1, "Baseline (paper-faithful first "
                                "implementation), single pod"))
    print()
    print(roofline_table(opt1, "Optimized (after Perf iterations 1-8), "
                               "single pod"))
    print()
    print("## Perf: hypothesis -> change -> measure log (section Perf)\n")
    print(PERF_LOG)
    cells = [("qwen3-0.6b", "train_4k"), ("chameleon-34b", "train_4k"),
             ("qwen3-moe-235b-a22b", "train_4k")]
    print(perf_compare(base1, opt1, cells))
    print()
    print(FOOTER)


HEADER = """# EXPERIMENTS

Reproduction + scale-out study for *High-performance sparse matrix-matrix
products on Intel KNL and multicore architectures* (Nagasaka, Azad,
Matsuoka, Buluc 2018).  All artifacts regenerable:

```
PYTHONPATH=src python -m repro.launch.dryrun --all --calibrate --out results/dryrun_1pod_opt.jsonl
PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out results/dryrun_2pod_opt.jsonl
PYTHONPATH=src python -m repro.analysis.roofline results/dryrun_1pod_opt.jsonl
PYTHONPATH=src python -m benchmarks.run
PYTHONPATH=src python -m repro.analysis.report > EXPERIMENTS.md
```

## Validation against the paper's own claims

The container is CPU-only, so KNL wall-clock numbers are re-targeted:
algorithmic *trends* are validated on CPU (XLA-compiled paths), hardware
*performance* is projected via the TPU-v5e roofline of compiled artifacts.
From `bench_output.txt` (benchmarks/run.py):

* **C8 unsorted-vs-sorted** (paper: 1.58-1.68x harmonic-mean speedup):
  measured here `fig11,G500,ef8`: hash 3.81 ms vs hash_sorted 5.53 ms =
  **1.45x** from skipping the sort epilogue -- the paper's headline
  finding reproduced in direction and magnitude.  In the LM integration
  the same idea is the *unstable* MoE dispatch sort (`moe_dispatch`).
* **C1 balanced scheduling** (paper Fig. 9): `fig9,balanced` 9.2 ms vs
  `fig9,naive_rows` 10.6 ms on a skewed G500 input.  The margin is
  compressed on this container because interpret mode executes grid
  programs *sequentially* on one core -- balancing then only reduces
  tail-bin work, not wall-clock parallel imbalance; on real hardware the
  gap is the paper's Fig. 9.  Same caveat flattens `fig13` (grid-count
  scaling needs parallel cores/SparseCores to show).
* **C6 static-vs-dynamic scheduling** (paper Fig. 2): `fig2,static` vs
  `fig2,dynamic` -- one fused dispatch vs per-iteration dispatch overhead
  (the KNL result reproduced in XLA-dispatch form).
* **C5 allocation reuse** (paper Fig. 4): `fig4,reuse_donated` vs
  `fig4,fresh_alloc`.
* **C7 stanza access** (paper Fig. 5): `fig5,stanza{1,8,64,512}` shows
  bandwidth rising with contiguous stanza length -- the effect that sizes
  the BCSR tiles (DESIGN.md section 2).
* **Recipe** (paper Table 4): `table4,accuracy` reports recipe-vs-cost-
  model and model-vs-measured agreement on the compiled substrate; the
  full decision table is unit-tested in tests/test_recipe.py against the
  paper's Table 4 entries.
* **Eq. 1 / Eq. 2 crossovers** are property-tested (tests/test_recipe.py):
  hash wins at high compression ratio, heap at low CR for LxU -- the
  paper's section 5.6/5.7 conclusions.

Correctness of every algorithm against the dense oracle (and of the
hash/BCSR Pallas kernels against pure-jnp references in interpret mode) is
covered by the test suite (`test_output.txt`).
"""

DRYRUN_INTRO = """Every (architecture x shape) cell lowers AND compiles
at both meshes with zero errors (80 cells total; `results/*.jsonl`).
`memory_analysis()` bytes are per chip.  Temp highlights: the paper-
faithful baseline held multi-GB attention/CE intermediates; after the
perf iterations the small/dense cells fit v5e HBM (16 GB) with margin --
remaining pressure sits in the two largest train cells (chameleon-34b,
qwen3-moe-235b), where microbatching (supported in train/step.py) is the
production answer.

Notes: long_500k cells for pure full-attention archs are `extra` (decode
is O(S); the assignment only requires them for sub-quadratic archs --
DESIGN.md section 5).  The MoE dispatch all_to_alls appear in the
collective column; the 2-pod mesh adds the cross-pod FSDP axis for
>30B-param models (`make_pctx`).
"""

ROOFLINE_INTRO = """Terms are seconds per step **per chip** (the SPMD
program is per-device): compute = FLOPs/197e12, memory = bytes/819e9,
collective = bytes/50e9.  Scan-loop costs are reconstructed exactly from
unrolled 1-period/2-period calibration compiles (`--calibrate`;
`analysis/roofline.py`).  `roofline frac` = compute / max(term) --
the fraction of step time the MXUs are busy under perfect overlap;
`useful ratio` = 6*N_active*D / HLO FLOPs (remat recompute and attention
push it below 1; decode cells are tiny-compute by nature and read the
whole parameter set per token, so they are memory-bound by physics --
their metric of interest is the memory term itself).
"""

PERF_LOG = """Methodology: per iteration -- hypothesis with napkin math ->
change -> re-lower + re-analyze -> confirmed/refuted.  Three hillclimb
cells per the assignment: worst fraction + most collective-bound
(qwen3-0.6b train_4k), most collective-bound large-dense
(chameleon-34b train_4k), most paper-representative (qwen3-moe-235b
train_4k, SpGEMM dispatch).  Full per-iteration JSON in
results/perf_iter*.jsonl.

| # | hypothesis (napkin math) | change | result | verdict |
|---|---|---|---|---|
| 1 | (B,Hkv,G,S,D) GQA fold splits one mesh axis over two dims -> SPMD replicates scan carries ("involuntary full remat" warnings; ~0.6 GB/layer copies) | repeat KV to H heads, keep (B,H,S,D) + explicit constraints on carries | collective 1.32x better, memory 1.08x; warnings gone; temp unchanged | partially confirmed -- the big buffer was elsewhere |
| 2 | differentiating through the attention scan stores every chunk's P panel (~67 MB x 8 chunks x heads/chip) | custom VJP: store (q,k,v,out,lse), recompute P per chunk in bwd (flash backward) | memory 1.27x, collective 1.32x vs baseline; temp still 8.3 GB | partially confirmed -- exactness verified to 3e-6 |
| 3 | temp exactly 8.30 GB = (16,4096,151936) f32 logits+CE bwd (~8 GB/chip napkin) | fused chunked softmax-CE head w/ custom VJP (recompute logits per chunk) | temp 8.30 -> 2.29 GB; compute 1.22x (head flop shed) | **confirmed** (memory-fit goal achieved) |
| 4 | SP activation gathers dominate; disabling seq-sharding should cut collectives at small memory cost | `--opt sp=False` | memory 2.8x WORSE, collective worse, temp 11.6 GB | **refuted** -- SP pulls its weight; gathers were KV-specific |
| 5 | 268 MB f32 all-gathers = pre-repeat KV constrained on unshardable 8-of-16 kv heads | repeat-then-constrain (head-sharded gather) + bf16 through the scan xs | all-gather/layer 2.27 -> 1.73 GB, all-reduce up | partially confirmed -- fused (K,V) tuple gathers remained |
| 6 | head-sharded q forces full-seq q/out gathers; seq-parallel-q needs only the (un-repeated, bf16) KV gather = S*Hkv*hd*2*2B = 134 MB/layer | seq-parallel-q layout + bf16 embedding gather + un-repeated KV gather (6b) | collective 2.99x vs baseline, memory 1.72x, fraction 0.039 -> 0.078, bottleneck flips to memory | **confirmed** |
| 7 | P-panel f32 PV/dV contractions dominate remaining attention bytes | input-dtype (bf16) P contractions, f32 softmax stats | chameleon fraction 0.197 -> 0.411; memory 1.84x | **confirmed** |
| 8 | remaining collective = f32 *param* gathers (FSDP) + f32 expert gathers; f32 master belongs in optimizer state only | bf16 working params + f32 master in OptState; bf16 expert-weight gathers in MoE shard_map; f32-accum fused CE | chameleon 0.197 -> 0.511 overall; collective 2.72x; MoE-235B collective 2.56x | **confirmed** |
| 9 | saving MoE outputs via remat policy avoids replaying dispatch all_to_alls in bwd | `checkpoint_name("moe_out")` + save_only_these_names | terms identical (bwd replays fwd for its own grads regardless); saving dispatch internals would cost ~336 MB/chip/layer | **refuted** -- documented in code |
| 10 | MoE capacity padding (cf=1.25) sends ~20% zero-padding through the all_to_alls and expert GEMMs; terms should scale ~linearly with cf | ablation cf 1.25 -> 1.0 on the 235B cell (unrolled per-layer compiles) | per-layer flops 1.17x, bytes 1.12x, collective 1.14x lower | **confirmed** -- exposed as a quality/perf knob (`MoEConfig.capacity_factor`), default kept at 1.25 (dropping tokens is a modelling decision, not a free win) |
| 11 | mamba2's residual traffic is the XLA-materialized (nc,nh,Q,Q) decay tensor | `kernels/ssd_chunk`: SSD chunk scan as a Pallas kernel, decay/CB panels VMEM-resident, state grid-carried | validated vs oracle (1e-7); TPU-side traffic analysis in kernel docstring (wall-clock needs real hardware) | kernel delivered; roofline impact is a TPU measurement |
| 12 | remat recompute is ~15-20% of dense-cell flops; saving weight-stationary dot outputs should shed it at bounded memory | `remat_policy="dots"` (dots_with_no_batch_dims_saveable) | compute 1.14-1.20x lower as predicted, BUT temp 3.6->8.8 GB (qwen3) / 24.6->73.8 GB (chameleon); dominant terms unmoved -> fraction *drops* | **refuted as default** -- memory buys only recompute flops that overlap anyway; kept as a `ParallelCtx.remat_policy` knob for memory-rich parts |

Stopping: iterations 7-9 produced <5% change on the qwen3-0.6b dominant
term twice and one refuted MoE structural attempt; remaining headroom on
the MoE cell is the expert-FFN recompute (microbatching or activation
offload, noted as future work).

Reading notes for the tables:
* **mamba2 train fraction 0.080 -> 0.055 is not a regression**: the fused
  CE + bf16 params cut the *compute* term 1.65x (useful_ratio 0.49 ->
  0.81, temp 29 -> 4.5 GB) while the SSD memory term barely moved, so the
  (compute / dominant-term) ratio fell even though every absolute term
  improved.  The SSD block itself is the next kernel target (its decay
  tensor is the remaining traffic).
* **2-pod fractions are lower than 1-pod by design**: doubling chips at
  fixed global batch halves per-chip work while the cross-pod reduction
  rides a 50 GB/s link -- the sub-1B models at 512 chips (qwen3-0.6b:
  0.011) are the roofline table telling you not to overscale them.  The
  large cells hold up (chameleon 0.146, qwen1.5 0.133, MoE-235B 0.080 at
  512 chips).

### Baseline (paper-faithful) vs optimized -- hillclimb cells
"""

FOOTER = """
## Perf: kernel-level notes (TPU target)

* `kernels/spgemm_hash`: grid = equal-flop bins (C1), VMEM hash table
  sized by the per-bin bound (C5), vectorized probing option (C3), two
  phases (C2), unsorted emission (C8).  Validated in interpret mode
  against the jnp oracle across shapes/presets/table sizes
  (tests/test_kernels.py); TPU wall-clock is out of scope for this
  container, so its perf story is carried by the structural mapping
  (DESIGN.md section 2) and the roofline of the consuming system.
* `kernels/spgemm_bcsr`: the MXU adaptation -- per-block-row hash of
  block-column keys, (bm,bk)@(bk,bn) tile FMAs with f32 accumulation.
  Block shapes swept in tests; (8,128)x(128,128) recommended on v5e
  (lane-aligned, fits VMEM with 2x double-buffering).
* `kernels/flash_attention`: causal-block skip + GQA-aware index maps.
* `kernels/ssd_chunk`: Mamba-2 SSD chunk scan -- the inter-chunk state
  rides VMEM scratch across the innermost grid dim (the lax.scan becomes
  grid-carried state), the (Q,Q) decay/CB panels never leave VMEM, three
  MXU matmuls per chunk.  Added after the roofline flagged the XLA path's
  materialized decay tensor as mamba2's residual traffic; validated
  against the model-stack oracle incl. multi-chunk state carry and
  strong-decay edge cases.
* serving decode cells: KV caches shard (batch->data, heads->model) with
  automatic seq-sharding fallback (long_500k batch=1 shards the cache
  over all 256/512 chips; the per-shard LSE combine is the distributed
  flash-decoding pattern).

## Multi-pod / 1000+-node readiness (section Dry-run is the proof at 512)

FSDP over ("pod","data") for >30B models, hierarchical grad reductions
emitted by SPMD from the parameter shardings, bf16 gradient reduce-scatter
(structural after iteration 8), optional int8+error-feedback compression
(tested for convergence), ZeRO optimizer sharding with bf16/int8 moment
options (the 235B cell's fit), deterministic data -> bitwise
checkpoint/restart (tested), atomic async checkpoints, elastic reshard on
restore (tested 4->2 devices), static equal-work partitions everywhere
(the paper's C1 at fleet scale).  Scaling past 2 pods adds pod-axis data
parallelism with the same rules; the collective term of the roofline grows
only with the cross-pod reduction (bf16, 2 bytes/param/step) which
overlaps with the backward under XLA's async collectives.
"""


if __name__ == "__main__":
    sys.exit(main())
