"""Roofline analysis from dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh) cell, all **per chip** (the dry-run's
``cost_analysis``/HLO describe the per-device SPMD program, so the
assignment's ``/ chips`` division is already applied):

    compute_s    = HLO_FLOPs      / PEAK_FLOPS        (197 TF/s bf16)
    memory_s     = HLO_bytes      / HBM_BW            (819 GB/s)
    collective_s = collective_B   / LINK_BW           (50 GB/s/link ICI)

Loop correction: scan bodies are counted once by XLA; totals are
reconstructed from the dry-run's unrolled 1p/2p calibration compiles:
``total = c1 + (n_full-1 + n_tail/period) * (c2 - c1)``.

Also reported: MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D
(prefill/decode) per chip, and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs -- remat recompute, attention, and any redundant
compute push it below 1.

Usage:  python -m repro.analysis.roofline results/dryrun_1pod.jsonl [...]
"""
from __future__ import annotations

import json
import sys

PEAK_FLOPS = 197e12     # bf16 FLOP/s per v5e-class chip
HBM_BW = 819e9          # B/s per chip
LINK_BW = 50e9          # B/s per ICI link


# ---------------------------------------------------------------------------
# SpGEMM kernel roofline (autotune DB context + benchmark trajectory rows)
# ---------------------------------------------------------------------------

def spgemm_traffic_bytes(*, n_rows: float, nnz_a: float, flop: float,
                         nnz_c: float, itemsize: int = 4) -> float:
    """Model HBM traffic of one C = A*B numeric phase, in bytes.

    Per the paper's Sec. 2 access pattern: A is streamed once (indices +
    values), every multiply streams one B entry (index + value; the
    paper's ``flop`` counts multiply-adds so ``flop`` B-entry touches),
    and C is written once (indices + values) with one indptr stream over
    the rows.  Accumulator traffic is assumed to stay in cache/scratch
    -- that is the entire point of the hash/heap accumulators -- so this
    is a *lower* bound and the roofline fraction an upper bound.
    """
    index_size = 4   # int32 indices regardless of x64 values
    a_bytes = nnz_a * (index_size + itemsize)
    b_bytes = flop * (index_size + itemsize)
    c_bytes = nnz_c * (index_size + itemsize) + (n_rows + 1) * index_size
    return a_bytes + b_bytes + c_bytes


def spgemm_roofline(flops: float, bytes_moved: float, seconds: float,
                    peak_flops: float = PEAK_FLOPS,
                    hbm_bw: float = HBM_BW) -> dict:
    """Place one measured SpGEMM run on the roofline.

    Returns the two ideal-time terms, which one binds (``bound``), the
    achieved fraction of that roof (``roof_fraction``), and the achieved
    absolute rates -- the context the autotune DB persists with every
    winner so a recorded timing can be sanity-checked against the
    machine it claims to describe.
    """
    compute_s = flops / peak_flops
    memory_s = bytes_moved / hbm_bw
    bound = "memory" if memory_s >= compute_s else "compute"
    ideal_s = max(compute_s, memory_s)
    seconds = max(seconds, 1e-12)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "bound": bound,
        "roof_fraction": ideal_s / seconds,
        "achieved_gflops": flops / seconds / 1e9,
        "achieved_gbps": bytes_moved / seconds / 1e9,
        "intensity_flop_per_byte": flops / max(bytes_moved, 1.0),
    }

_SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}
_KIND = {"train_4k": "train", "prefill_32k": "prefill",
         "decode_32k": "decode", "long_500k": "decode"}


def corrected_totals(rec: dict) -> dict:
    """Apply the calibration extrapolation; falls back to reported."""
    flops = rec.get("hlo_flops", 0.0)
    mbytes = rec.get("hlo_bytes", 0.0)
    coll = float(rec.get("collectives", {}).get("total_bytes", 0))
    calib = rec.get("calib")
    if calib and "c1" in calib and "c2" in calib:
        c1, c2 = calib["c1"], calib["c2"]
        mult = (calib["n_full_periods"] - 1) + \
            calib["n_tail"] / max(calib["period"], 1)
        d_fl = max(0.0, c2["hlo_flops"] - c1["hlo_flops"])
        d_by = max(0.0, c2["hlo_bytes"] - c1["hlo_bytes"])
        d_co = max(0.0, c2["collectives"]["total_bytes"] -
                   c1["collectives"]["total_bytes"])
        flops = c1["hlo_flops"] + mult * d_fl
        mbytes = c1["hlo_bytes"] + mult * d_by
        coll = c1["collectives"]["total_bytes"] + mult * d_co
    return {"flops": flops, "bytes": mbytes, "coll_bytes": coll}


def model_flops_per_chip(rec: dict) -> float:
    n = rec.get("active_params", rec.get("params", 0))
    d = _SHAPE_TOKENS.get(rec["shape"], 1)
    mult = 6 if _KIND.get(rec["shape"]) == "train" else 2
    return mult * n * d / max(rec.get("chips", 1), 1)


def analyze(rec: dict) -> dict:
    tot = corrected_totals(rec)
    compute_s = tot["flops"] / PEAK_FLOPS
    memory_s = tot["bytes"] / HBM_BW
    coll_s = tot["coll_bytes"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values()) if terms else 0.0
    mf = model_flops_per_chip(rec)
    out = dict(rec)
    out.update(
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck,
        roofline_fraction=(compute_s / step_s) if step_s else 0.0,
        model_flops_per_chip=mf,
        useful_ratio=(mf / tot["flops"]) if tot["flops"] else 0.0,
        corrected=tot)
    return out


_ADVICE = {
    "compute": "reduce recompute (remat policy) / shed non-model FLOPs; "
               "compute term is the roofline -- this cell is healthy if "
               "useful_ratio is near 1",
    "memory": "increase arithmetic intensity: larger per-chip batch, fuse "
              "elementwise chains, bf16 activations, avoid resharding "
              "copies",
    "collective": "re-balance sharding: move collectives off the critical "
                  "path (overlap), shrink FSDP gather volume (bigger TP "
                  "share), or compress cross-pod traffic",
}


def render_markdown(records: list[dict]) -> str:
    rows = ["| arch | shape | mesh | compute s | memory s | collective s | "
            "bottleneck | roofline frac | useful ratio |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR: {r['error'][:60]} | | | | | |")
            continue
        a = analyze(r)
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['compute_s']:.3e} | {a['memory_s']:.3e} "
            f"| {a['collective_s']:.3e} | {a['bottleneck']} "
            f"| {a['roofline_fraction']:.2f} | {a['useful_ratio']:.2f} |")
    return "\n".join(rows)


def load_jsonl(path: str) -> list[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def main(argv=None):
    argv = argv if argv is not None else sys.argv[1:]
    records = []
    for p in argv:
        records.extend(load_jsonl(p))
    print(render_markdown(records))
    # bottleneck advice summary
    seen = {}
    for r in records:
        if "error" not in r:
            seen.setdefault(analyze(r)["bottleneck"], 0)
            seen[analyze(r)["bottleneck"]] += 1
    print()
    for k, n in sorted(seen.items(), key=lambda kv: -kv[1]):
        print(f"* {n} cells {k}-bound -- {_ADVICE[k]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
