"""Measured SpGEMM recipe backed by a persistent performance database.

Public surface (DESIGN.md section 16):

  * :func:`measured_recommend` -- DB-first measured algorithm choice;
    what ``recipe.recommend(mode="measured")`` and
    ``plan_spgemm(autotune=True)`` delegate to.
  * :class:`PerfDB` / :func:`default_db_path` -- the JSON results DB.
  * :class:`TunedChoice` -- a resolved choice (algorithm, table scale,
    timing, db-vs-measured source).
  * :class:`AutotuneDBWarning` -- every degraded path warns with this.
  * :func:`reset_measure_calls` / :func:`measure_call_counts` -- the
    effort counters tests use to prove a DB hit measures nothing.
  * :func:`feed_bench_rows` / :func:`bench_row_key` -- bench-trajectory
    ingestion: ``benchmarks/run.py --json`` rows land in the same DB
    under the ``bench|`` namespace, aged by recorded git sha.

This package intentionally lives *outside* ``repro.core``: it times
wall-clock, which the core planner's determinism lint bans, and core
only imports it lazily when a caller asks for measured mode.
"""
from .db import DRIFT_TOLERANCE, SCHEMA_VERSION, AutotuneDBWarning, \
    PerfDB, default_db_path, resolve_db
from .feed import BENCH_KEY_PREFIX, bench_row_key, feed_bench_rows
from .measure import MEASURE_CALLS, TABLE_SCALES, TunedChoice, db_key, \
    measure_call_counts, measured_recommend, reset_measure_calls

__all__ = [
    "AutotuneDBWarning", "BENCH_KEY_PREFIX", "DRIFT_TOLERANCE",
    "MEASURE_CALLS", "PerfDB", "SCHEMA_VERSION", "TABLE_SCALES",
    "TunedChoice", "bench_row_key", "db_key", "default_db_path",
    "feed_bench_rows", "measure_call_counts", "measured_recommend",
    "reset_measure_calls", "resolve_db",
]
