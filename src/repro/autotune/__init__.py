"""Measured SpGEMM recipe backed by a persistent performance database.

Public surface (DESIGN.md section 16):

  * :func:`measured_recommend` -- DB-first measured algorithm choice;
    what ``recipe.recommend(mode="measured")`` and
    ``plan_spgemm(autotune=True)`` delegate to.
  * :class:`PerfDB` / :func:`default_db_path` -- the JSON results DB.
  * :class:`TunedChoice` -- a resolved choice (algorithm, table scale,
    timing, db-vs-measured source).
  * :class:`AutotuneDBWarning` -- every degraded path warns with this.
  * :func:`reset_measure_calls` / :func:`measure_call_counts` -- the
    effort counters tests use to prove a DB hit measures nothing.

This package intentionally lives *outside* ``repro.core``: it times
wall-clock, which the core planner's determinism lint bans, and core
only imports it lazily when a caller asks for measured mode.
"""
from .db import DRIFT_TOLERANCE, SCHEMA_VERSION, AutotuneDBWarning, \
    PerfDB, default_db_path, resolve_db
from .measure import MEASURE_CALLS, TABLE_SCALES, TunedChoice, db_key, \
    measure_call_counts, measured_recommend, reset_measure_calls

__all__ = [
    "AutotuneDBWarning", "DRIFT_TOLERANCE", "MEASURE_CALLS", "PerfDB",
    "SCHEMA_VERSION", "TABLE_SCALES", "TunedChoice", "db_key",
    "default_db_path", "measure_call_counts", "measured_recommend",
    "reset_measure_calls", "resolve_db",
]
