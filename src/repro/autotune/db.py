"""Persistent SpGEMM performance database (DESIGN.md section 16).

A single JSON document on disk mapping autotune keys -- ``(structure
digest A, structure digest B, mask digest, semiring, sortedness,
backend, x64)`` rendered as a string, i.e. keyed exactly like the plan
cache plus the execution context -- to measured winner entries::

    {
      "schema": 1,
      "entries": {
        "<key>": {
          "algorithm": "hash", "table_scale": 1,
          "us": 812.4,                       # winner median
          "candidates": {"esc": 1201.0, "hash": 812.4, ...},
          "stats": {"flop": 51200.0, "nnz_c": 9100.0, "nnz_a": 2048.0},
          "roofline": {"bound": "memory", ...},  # see analysis.roofline
          "backend": "cpu", "x64": false, "schema": 1
        }, ...
      }
    }

Robustness contract (pinned by ``tests/test_autotune.py``): a missing,
truncated, corrupt, or unknown-schema file **never crashes and never
mis-keys** -- it reads as empty with an :class:`AutotuneDBWarning`, and
the next :meth:`PerfDB.put` rewrites a clean schema-1 document.  Writes
are read-merge-replace under an atomic ``os.replace`` of a same-
directory temp file, so two processes measuring the same digest race
benignly: last writer wins for the shared key and the file is always a
complete, parseable document (the determinism test pins this).

Trust contract: an entry is only served while its recorded stats match
the request's freshly measured stats within :data:`DRIFT_TOLERANCE` --
a drifted entry (stale digest reuse, schema evolution of the stats
block) is dropped with a warning and re-measured, not trusted.
"""
from __future__ import annotations

import json
import os
import pathlib
import warnings
from typing import Optional

#: current on-disk schema; files with any other version read as empty
SCHEMA_VERSION = 1

#: relative deviation between an entry's recorded stats and the
#: request's measured stats above which the entry is re-measured
DRIFT_TOLERANCE = 0.05

#: the stats fields the drift check compares.  Only fields that are
#: *exact* on every call path belong here: ``nnz_c`` is recorded too but
#: not compared, because callers without the symbolic phase's counts
#: hold an upper-bound estimate and would spuriously "drift" against an
#: entry recorded with the exact value.
_STAT_FIELDS = ("flop", "nnz_a")

#: algorithms an entry may legally name (anything else is schema drift)
KNOWN_ALGORITHMS = ("esc", "heap", "hash", "hash_vector", "hash_jnp",
                    "bcsr")


class AutotuneDBWarning(UserWarning):
    """A perf-DB file or entry could not be trusted; degraded safely."""


def default_db_path() -> str:
    """``$REPRO_AUTOTUNE_DB`` or ``~/.cache/repro-spgemm/autotune.json``."""
    env = os.environ.get("REPRO_AUTOTUNE_DB")
    if env:
        return env
    return str(pathlib.Path.home() / ".cache" / "repro-spgemm"
               / "autotune.json")


def _warn(msg: str) -> None:
    warnings.warn(msg, AutotuneDBWarning, stacklevel=3)


class PerfDB:
    """One JSON results database (lazy-loading, atomically rewritten)."""

    def __init__(self, path: Optional[str] = None):
        self.path = str(path) if path is not None else default_db_path()

    # -- reading --------------------------------------------------------
    def load(self) -> dict:
        """Entries dict; empty (with a warning) on any untrusted file."""
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except FileNotFoundError:
            return {}
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as exc:
            _warn(f"autotune DB {self.path} unreadable "
                  f"({type(exc).__name__}: {exc}); treating as empty")
            return {}
        if not isinstance(doc, dict) or doc.get("schema") != SCHEMA_VERSION:
            got = doc.get("schema") if isinstance(doc, dict) else type(doc)
            _warn(f"autotune DB {self.path} has schema {got!r}, expected "
                  f"{SCHEMA_VERSION}; treating as empty")
            return {}
        entries = doc.get("entries")
        if not isinstance(entries, dict):
            _warn(f"autotune DB {self.path} entries block malformed; "
                  "treating as empty")
            return {}
        return entries

    def get(self, key: str, stats: Optional[dict] = None,
            tolerance: float = DRIFT_TOLERANCE) -> Optional[dict]:
        """Trusted entry for ``key`` or ``None``.

        ``stats`` (``{"flop", "nnz_c", "nnz_a"}`` of the *current*
        request) arms the drift check: a recorded entry whose stats
        deviate by more than ``tolerance`` relative is stale -- dropped
        with a warning so the caller re-measures instead of trusting it.
        Entries naming an unknown algorithm or missing their stats block
        are equally untrusted.
        """
        entry = self.load().get(key)
        if entry is None:
            return None
        if not isinstance(entry, dict) or \
                entry.get("algorithm") not in KNOWN_ALGORITHMS:
            _warn(f"autotune DB entry for {key!r} names unknown algorithm "
                  f"{entry.get('algorithm') if isinstance(entry, dict) else entry!r}; ignoring")
            return None
        recorded = entry.get("stats")
        if not isinstance(recorded, dict):
            _warn(f"autotune DB entry for {key!r} lacks its stats block; "
                  "re-measuring")
            return None
        if stats is not None:
            for field in _STAT_FIELDS:
                have, want = recorded.get(field), stats.get(field)
                if have is None or want is None:
                    _warn(f"autotune DB entry for {key!r} missing stat "
                          f"{field!r}; re-measuring")
                    return None
                denom = max(abs(float(want)), 1.0)
                if abs(float(have) - float(want)) / denom > tolerance:
                    _warn(f"autotune DB entry for {key!r} drifted: "
                          f"{field}={have} vs measured {want} "
                          f"(tolerance {tolerance}); re-measuring")
                    return None
        return entry

    # -- writing --------------------------------------------------------
    def _write(self, entries: dict) -> None:
        """Atomically replace the document with ``entries`` (same-directory
        temp file + ``os.replace``; failures warn and leave the DB as it
        was)."""
        doc = {"schema": SCHEMA_VERSION, "entries": entries}
        path = pathlib.Path(self.path)
        tmp = path.with_name(f"{path.name}.tmp.{os.getpid()}")
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            with open(tmp, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except OSError as exc:
            _warn(f"autotune DB {self.path} not writable "
                  f"({type(exc).__name__}: {exc}); result not persisted")
            try:
                tmp.unlink(missing_ok=True)
            except OSError:
                pass

    def put(self, key: str, entry: dict) -> None:
        """Read-merge-replace: persist ``entry`` under ``key`` atomically.

        The current file is re-read first so concurrent writers merge
        rather than clobber each other's keys; the temp file lives in
        the same directory so ``os.replace`` is atomic on POSIX.  Write
        failures warn and leave the DB unchanged -- measurement results
        still flow back to the caller.
        """
        entries = self.load()
        entries[key] = entry
        self._write(entries)

    def update(self, mapping: dict) -> None:
        """:meth:`put` for many keys with a single read-merge-replace."""
        if not mapping:
            return
        entries = self.load()
        entries.update(mapping)
        self._write(entries)

    def age(self, current_sha: str, prefix: str = "bench|") -> int:
        """Drop ``prefix``-namespaced entries recorded at a different
        ``git_sha`` (the bench-trajectory aging contract: a row timed on
        old code says nothing about the current tree).  Returns the number
        of entries removed.  Winner entries (``spgemm|...``) carry no sha
        semantics and are never touched.
        """
        entries = self.load()
        stale = [k for k, e in entries.items()
                 if k.startswith(prefix) and isinstance(e, dict)
                 and e.get("git_sha") not in (None, current_sha)]
        if stale:
            for k in stale:
                del entries[k]
            self._write(entries)
        return len(stale)

    def __len__(self) -> int:
        return len(self.load())


def resolve_db(db) -> PerfDB:
    """Coerce ``None`` / path string / :class:`PerfDB` into a PerfDB."""
    if isinstance(db, PerfDB):
        return db
    return PerfDB(db)
