"""Bench-trajectory ingestion: feed ``benchmarks/run.py --json`` rows into
the :class:`~repro.autotune.db.PerfDB` and age them by recorded git sha
(the ROADMAP follow-on to the PR-8 autotuner).

Every suite row of a trajectory document becomes a ``bench``-kind entry
under the ``bench|<row name>|<backend>`` key namespace -- disjoint from
the ``spgemm|...`` winner keys, so :func:`measured_recommend` never reads
them; they exist so the perf history that CI gates on is also queryable
next to the tuner's winners (one DB, one dashboard).

Aging contract: a bench row timed at one ``git_sha`` says nothing about a
tree at another, so feeding a document recorded at sha *S* first drops
every bench entry recorded at a sha other than *S* (:meth:`PerfDB.age`),
then ingests the new rows.  Winner entries carry no sha semantics and are
never aged.  Like everything in :mod:`repro.autotune.db`, ingestion
degrades with a warning instead of crashing -- ``benchmarks/run.py`` calls
this on a best-effort basis after writing the JSON.
"""
from __future__ import annotations

from .db import SCHEMA_VERSION, PerfDB, resolve_db

#: key namespace for ingested bench rows (kept out of the winner keys)
BENCH_KEY_PREFIX = "bench|"


def bench_row_key(name: str, backend: str) -> str:
    """DB key of one ingested bench row."""
    return f"{BENCH_KEY_PREFIX}{name}|{backend}"


def feed_bench_rows(doc: dict, db: PerfDB | str | None = None,
                    prune_stale: bool = True) -> int:
    """Ingest a ``benchmarks.run`` JSON trajectory document.

    ``doc`` is the parsed document (``{"git_sha", "backend", "rows":
    [{"name", "us_per_call", ...}, ...]}``).  Rows without a name or a
    numeric timing are skipped.  With ``prune_stale`` (default) bench
    entries recorded at a different git sha are aged out first.  Returns
    the number of rows ingested.
    """
    pdb = resolve_db(db)
    sha = str(doc.get("git_sha", "unknown"))
    backend = str(doc.get("backend", "unknown"))
    entries = {}
    for row in doc.get("rows", []):
        if not isinstance(row, dict):
            continue
        name, us = row.get("name"), row.get("us_per_call")
        if not isinstance(name, str) or \
                not isinstance(us, (int, float)) or isinstance(us, bool):
            continue
        entries[bench_row_key(name, backend)] = {
            "schema": SCHEMA_VERSION,
            "kind": "bench",
            "us": float(us),
            "derived": row.get("derived", ""),
            "git_sha": sha,
            "backend": backend,
        }
    if prune_stale:
        pdb.age(current_sha=sha, prefix=BENCH_KEY_PREFIX)
    pdb.update(entries)
    return len(entries)
