"""Measured recipe: microbenchmark candidates, persist winners.

The heuristic Table-4 recipe guesses from structure statistics; this
module *measures*.  :func:`measured_recommend` keys the request by the
operands' structure digests plus the execution context (backend, x64
flag) -- the same blake2b digests the plan cache uses, so a DB entry can
never be served to a different structure -- and either

  * **hits** the persistent :class:`repro.autotune.db.PerfDB` and
    returns the recorded winner with zero microbenchmarks (the
    ``candidates_timed`` counter pins this in tests), or
  * **misses**, builds a throwaway (uncached) plan per candidate
    algorithm -- esc / heap (sorted inputs only) / hash / hash_vector /
    hash_jnp, plus x2 hash-table-size variants of the Pallas hash paths
    -- times each as a median of ``REPS`` runs after a compile warmup,
    persists the winner with its timing and roofline context, and
    returns it.

Candidate timing runs the *numeric* phase only (``SpGEMMPlan.execute``):
inspection is shared by every candidate and by the caller, so including
it would just add identical noise to every lane.  Any failure -- a DB
that cannot be trusted degrades per :mod:`repro.autotune.db`; a
candidate that refuses to build or run is skipped; no candidate
surviving -- returns ``None`` and the caller falls back to the
heuristic.  Nothing in here raises at the caller.

Wall-clock timing lives here, outside ``core/``, deliberately: the
``plan-key-determinism`` lint rule bans ``time.*`` in the core planner,
and ``core.recipe`` / ``core.plan`` only import this module lazily when
the caller asks for measured mode.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.analysis.roofline import spgemm_roofline, spgemm_traffic_bytes
from .db import DRIFT_TOLERANCE, SCHEMA_VERSION, AutotuneDBWarning, \
    resolve_db

#: timed repetitions per candidate (median taken; one warmup before)
REPS = 3

#: hash-table-size multipliers tried for the Pallas hash paths.  Scales
#: stay powers of two so the scaled schedule keeps every p2 VC.
TABLE_SCALES = (1, 2)

#: measurement-effort counters, cumulative per process.  Tests reset
#: them around a recommend and assert ``candidates_timed == 0`` on a DB
#: hit -- the "repeat plans measure nothing" contract.
MEASURE_CALLS = {"recommends": 0, "db_hits": 0, "db_misses": 0,
                 "candidates_timed": 0}


def reset_measure_calls() -> None:
    for k in MEASURE_CALLS:
        MEASURE_CALLS[k] = 0


def measure_call_counts() -> dict:
    return dict(MEASURE_CALLS)


@dataclasses.dataclass(frozen=True)
class TunedChoice:
    """What the measured recipe resolved to.

    ``source`` says how: ``"db"`` (persisted winner, zero measurement
    this call) or ``"measured"`` (fresh microbenchmark, now persisted).
    ``us`` is the winner's recorded median execute time.
    """
    algorithm: str
    table_scale: int
    us: float
    source: str


def db_key(a, b, mask=None, *, semiring: str = "plus_times",
           sorted_output: bool = False,
           complement_mask: bool = False) -> str:
    """Autotune DB key: plan-cache structure digests + execution context.

    Two requests share an entry iff their operand (and mask) structures
    are digest-identical AND they run on the same backend with the same
    x64 setting -- a winner measured on one backend says nothing about
    another, and x64 doubles the value traffic.
    """
    from repro.core.plan import structure_key
    parts = [
        "spgemm",
        structure_key(a).hex(),
        structure_key(b).hex(),
        structure_key(mask).hex() if mask is not None else "nomask",
        "cmpl" if complement_mask else "mask",
        semiring,
        "sorted" if sorted_output else "unsorted",
        jax.default_backend(),
        "x64" if jax.config.jax_enable_x64 else "x32",
    ]
    return "|".join(parts)


def _stat_fingerprint(stats) -> dict:
    """The drift-check fields recorded with (and compared against) an
    entry: structure-level totals that move whenever the digest's
    meaning would."""
    return {"flop": float(stats.flop), "nnz_c": float(stats.nnz_c_est),
            "nnz_a": float(stats.nnz_a)}


def _scaled_plan(plan, scale: int, n_cols: int):
    """x``scale`` hash-table variant of a frozen plan (same contract as
    the planner's own table_scale application: p2 in [CHUNK, p2(n+1)],
    per-bin sizes clipped to the scratch, so the schedule VCs of
    ``repro.verify.bounds`` keep holding)."""
    from repro.core import schedule as sched
    from repro.kernels.spgemm_hash import kernel as HK
    table_size = max(min(plan.table_size * scale,
                         sched.lowest_p2(n_cols + 1)), HK.CHUNK)
    bin_tsize = jnp.clip(plan.bin_tsize.astype(jnp.int32) * scale,
                         jnp.int32(HK.CHUNK), jnp.int32(table_size))
    return dataclasses.replace(plan, table_size=table_size,
                               bin_tsize=bin_tsize)


def _time_plan(plan, a, b) -> float:
    """Median execute wall-clock over :data:`REPS` runs, microseconds.

    One untimed run first eats compilation; every run blocks on the
    output buffers so device-async dispatch cannot leak out of the
    timed window."""
    def run():
        out = plan.execute(a, b)
        jax.block_until_ready((out.indptr, out.indices, out.data))

    run()
    samples = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        run()
        samples.append(time.perf_counter() - t0)
    samples.sort()
    MEASURE_CALLS["candidates_timed"] += 1
    return samples[len(samples) // 2] * 1e6


def _candidates(a, b, semiring: str, mask) -> list:
    """(label, algorithm, table_scale) lanes worth timing here.

    Under a general semiring or a mask, ``plan.execute`` routes every
    hash flavor to the jnp fallback, so only esc / heap / hash_jnp are
    distinct programs; the Pallas hash paths (and their table-size
    variants) only race on the plus_times unmasked fast path.
    """
    general = semiring != "plus_times" or mask is not None
    lanes = [("esc", "esc", 1)]
    if a.sorted_cols and b.sorted_cols:
        lanes.append(("heap", "heap", 1))
    if general:
        lanes.append(("hash_jnp", "hash_jnp", 1))
        return lanes
    # Propagation-blocking lane (DESIGN.md section 18): only raced where
    # the recipe's compression gate says the expansion barely collapses
    # (low flop / nnz(C)) -- the regime PB's two streaming passes can
    # beat the hash table's probes; elsewhere the lane obviously loses
    # and would just burn microbenchmark time.
    try:
        from repro.core.recipe import PB_MAX_COMPRESSION, measure_stats
        if measure_stats(a, b).compression_ratio <= PB_MAX_COMPRESSION:
            lanes.append(("pb", "pb", 1))
    except Exception:
        pass
    for algo in ("hash", "hash_vector"):
        for scale in TABLE_SCALES:
            label = algo if scale == 1 else f"{algo}@t{scale}"
            lanes.append((label, algo, scale))
    lanes.append(("hash_jnp", "hash_jnp", 1))
    # MXU block lane (DESIGN.md section 17): only raced where the recipe's
    # eligibility gate says tiles are dense enough to possibly win, and
    # where the host occupancy probe is affordable -- a lane that obviously
    # loses just wastes microbenchmark time on every miss.
    try:
        from repro.core.recipe import (AUTO_PROBE_CELLS,
                                       MXU_MIN_TILE_DENSITY,
                                       block_density_of)
        if a.n_rows * a.n_cols <= AUTO_PROBE_CELLS and \
                block_density_of(a) >= MXU_MIN_TILE_DENSITY:
            lanes.append(("bcsr", "bcsr", 1))
    except Exception:
        pass
    return lanes


def measured_recommend(a, b, *, sorted_output: bool = False,
                       semiring: str = "plus_times", mask=None,
                       complement_mask: bool = False, stats=None,
                       row_nnz_c=None, db=None, measure: bool = True,
                       tolerance: float = DRIFT_TOLERANCE
                       ) -> Optional[TunedChoice]:
    """DB-first measured algorithm choice; ``None`` means "use the
    heuristic".

    ``stats`` (a ``SpGEMMStats``) arms the drift check against the
    recorded entry and is computed here if absent; ``row_nnz_c`` passes
    the symbolic phase's exact counts through to that computation.
    ``measure=False`` restricts to DB lookups -- a miss then returns
    ``None`` instead of spending microbenchmark time, which is what
    latency-sensitive callers probe with.  ``db`` is a path string, a
    :class:`repro.autotune.PerfDB`, or ``None`` for the default path.
    """
    MEASURE_CALLS["recommends"] += 1
    pdb = resolve_db(db)
    if stats is None:
        from repro.core.recipe import measure_stats
        stats = measure_stats(a, b, row_nnz_c=row_nnz_c, mask=mask,
                              complement_mask=complement_mask)
    key = db_key(a, b, mask, semiring=semiring, sorted_output=sorted_output,
                 complement_mask=complement_mask)
    fingerprint = _stat_fingerprint(stats)

    entry = pdb.get(key, stats=fingerprint, tolerance=tolerance)
    if entry is not None:
        MEASURE_CALLS["db_hits"] += 1
        return TunedChoice(algorithm=entry["algorithm"],
                           table_scale=int(entry.get("table_scale", 1)),
                           us=float(entry.get("us", 0.0)), source="db")
    MEASURE_CALLS["db_misses"] += 1
    if not measure:
        return None

    from repro.core.plan import plan_spgemm
    timings: dict[str, float] = {}
    best = None   # (us, label, algorithm, scale)
    for label, algo, scale in _candidates(a, b, semiring, mask):
        try:
            plan = plan_spgemm(a, b, algorithm=algo, semiring=semiring,
                               mask=mask, complement_mask=complement_mask,
                               sorted_output=sorted_output, cache=False)
            if scale != 1:
                plan = _scaled_plan(plan, scale, b.n_cols)
            us = _time_plan(plan, a, b)
        except Exception as exc:   # a lane that cannot run just drops out
            warnings.warn(f"autotune candidate {label} failed "
                          f"({type(exc).__name__}: {exc}); skipping",
                          AutotuneDBWarning, stacklevel=2)
            continue
        timings[label] = us
        if best is None or us < best[0]:
            best = (us, label, algo, scale)
    if best is None:
        warnings.warn("autotune: every candidate failed; falling back to "
                      "the heuristic recipe", AutotuneDBWarning,
                      stacklevel=2)
        return None

    us, label, algo, scale = best
    flops = 2.0 * float(stats.flop)
    bytes_moved = spgemm_traffic_bytes(
        n_rows=stats.n_rows, nnz_a=float(stats.nnz_a),
        flop=float(stats.flop), nnz_c=float(stats.nnz_c_est),
        itemsize=8 if jax.config.jax_enable_x64 else 4)
    roof = spgemm_roofline(flops, bytes_moved, us * 1e-6)
    pdb.put(key, {
        "schema": SCHEMA_VERSION,
        "algorithm": algo,
        "table_scale": scale,
        "label": label,
        "us": us,
        "candidates": timings,
        "stats": fingerprint,
        "roofline": roof,
        "backend": jax.default_backend(),
        "x64": bool(jax.config.jax_enable_x64),
    })
    return TunedChoice(algorithm=algo, table_scale=scale, us=us,
                       source="measured")
