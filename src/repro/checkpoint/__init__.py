"""Sharded, async, elastically-reshardable checkpointing.

Layout (one directory per step):
    <dir>/step_000042/
        meta.json        -- step, tree structure (path list), shapes/dtypes
        <leaf-path>.npy  -- one file per pytree leaf (full logical array)

Design choices for the 1000+-node story (DESIGN.md section 6):
  * leaves are saved as *full logical arrays*: restoring onto a different
    mesh (elastic rescale 512 -> 256, or 8 -> 4 in tests) is just a
    device_put with the new sharding -- no reshard tool needed.  On a real
    multi-host fleet each host writes only the shards it owns and the
    manifest records the index map (the single-process container exercises
    the same API surface).
  * async: save() snapshots to host RAM (device_get) synchronously -- the
    step barrier -- then a worker thread does the file I/O, so training
    resumes while bytes hit disk.  ``wait()`` joins before the next save.
  * atomicity: writes go to ``<dir>.tmp`` then ``os.rename`` -- a crash
    mid-save never corrupts the latest complete checkpoint (restart-safe).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

#: dtypes numpy cannot serialize natively -> (view dtype, restore dtype)
_VIEW_CODECS = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _encode(x: np.ndarray):
    name = str(x.dtype)
    if name in _VIEW_CODECS:
        return x.view(_VIEW_CODECS[name][0]), name
    return x, name


def _decode(x: np.ndarray, dtype_name: str):
    if dtype_name in _VIEW_CODECS:
        return x.view(_VIEW_CODECS[dtype_name][1])
    return x


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return ".".join(parts)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [ _path_str(p) for p, _ in
              jax.tree_util.tree_flatten_with_path(tree)[0] ]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, *, blocking: bool = False):
        self.wait()
        paths, leaves, _ = _flatten(state)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        meta = {
            "step": int(step),
            "paths": paths,
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
        }
        task = self._pool.submit(self._write, step, paths, host_leaves, meta)
        self._pending = task
        if blocking:
            self.wait()

    def _write(self, step, paths, host_leaves, meta):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for p, x in zip(paths, host_leaves):
            enc, _ = _encode(x)
            np.save(os.path.join(tmp, p + ".npy"), enc)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- restore --------------------------------------------------------------
    def list_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any, shardings: Any = None):
        """Restore into the structure of `template` (a state pytree or
        eval_shape thereof).  `shardings`: optional matching pytree of
        NamedSharding for elastic placement on the current mesh."""
        self.wait()
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        paths, leaves, treedef = _flatten(template)
        assert paths == meta["paths"], "checkpoint/template tree mismatch"
        arrays = [_decode(np.load(os.path.join(d, p + ".npy")), dt)
                  for p, dt in zip(paths, meta["dtypes"])]
        if shardings is not None:
            sh_leaves = treedef.flatten_up_to(shardings)
            arrays = [jax.device_put(a, s) if s is not None
                      else jax.device_put(a)
                      for a, s in zip(arrays, sh_leaves)]
        else:
            arrays = [jax.device_put(a) for a in arrays]
        return treedef.unflatten(arrays)
