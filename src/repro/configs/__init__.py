"""Architecture registry: the 10 assigned archs + reduced smoke variants."""
from __future__ import annotations

import dataclasses

from .base import (ModelConfig, MoEConfig, SSMConfig, InputShape, SHAPES,
                   shape_applicable, Plan)

from . import (musicgen_medium, qwen3_0_6b, granite_8b, qwen15_32b,
               phi4_mini_3_8b, qwen3_moe_235b_a22b, qwen3_moe_30b_a3b,
               mamba2_780m, recurrentgemma_9b, chameleon_34b)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (musicgen_medium, qwen3_0_6b, granite_8b, qwen15_32b,
              phi4_mini_3_8b, qwen3_moe_235b_a22b, qwen3_moe_30b_a3b,
              mamba2_780m, recurrentgemma_9b, chameleon_34b)
}

#: aliases used by --arch
ALIASES = {
    "musicgen-medium": "musicgen-medium",
    "qwen3-0.6b": "qwen3-0.6b",
    "granite-8b": "granite-8b",
    "qwen1.5-32b": "qwen1.5-32b",
    "phi4-mini-3.8b": "phi4-mini-3.8b",
    "qwen3-moe-235b-a22b": "qwen3-moe-235b-a22b",
    "qwen3-moe-30b-a3b": "qwen3-moe-30b-a3b",
    "mamba2-780m": "mamba2-780m",
    "recurrentgemma-9b": "recurrentgemma-9b",
    "chameleon-34b": "chameleon-34b",
}


def get(name: str) -> ModelConfig:
    return ARCHS[ALIASES.get(name, name)]


def reduced(cfg: ModelConfig, *, n_layers: int | None = None,
            d_model: int = 64, vocab: int = 128) -> ModelConfig:
    """Smoke-test shrink of an arch: same family/plan/options, tiny dims.

    Keeps every structural feature (GQA ratio, qk_norm, bias, MoE top-k,
    SSD state, plan period) so the smoke test exercises the same code paths
    as the full config.
    """
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_heads = max(2 * ratio, 2)
    n_kv = max(n_heads // ratio, 1)
    hd = max(16, d_model // n_heads)
    if n_layers is None:
        n_layers = cfg.period + min(2, cfg.n_layers % cfg.period or 0) \
            + cfg.period  # two periods + same-shape tail if any
        if cfg.n_layers % cfg.period:
            n_layers = 2 * cfg.period + (cfg.n_layers % cfg.period)
    moe = None
    if cfg.moe is not None:
        # capacity_factor=4 so smoke tests drop no tokens (capacity MoE is
        # only prefill/decode-consistent when nothing is dropped).
        moe = dataclasses.replace(cfg.moe, n_experts=8,
                                  top_k=min(cfg.moe.top_k, 2),
                                  d_expert=max(32, d_model // 2),
                                  capacity_factor=4.0)
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke",
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=hd, d_ff=(0 if cfg.d_ff == 0 else max(64, 2 * d_model)),
        vocab_size=vocab, moe=moe, ssm=ssm,
        attn_window=(64 if cfg.attn_window else None),
        rnn_width=(d_model if cfg.rnn_width else None),
        dtype="float32")


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "InputShape", "SHAPES",
           "shape_applicable", "Plan", "ARCHS", "ALIASES", "get", "reduced"]
