"""Model/shape configuration system.

Every assigned architecture is a :class:`ModelConfig`; every assigned input
shape is an :class:`InputShape`.  The dry-run grid is the cross product
(`launch/dryrun.py`).

Layer plans: a model is a cycled ``plan`` of (mixer, mlp) sub-layer pairs,
e.g. dense transformer = ``(("attn", "swiglu"),)``; recurrentgemma =
``(("rglru", "gated_mlp"), ("rglru", "gated_mlp"), ("attn_local",
"gated_mlp"))``; mamba2 = ``(("ssd", "none"),)``.  The layer stack is
``lax.scan``-ed over full plan periods (compile time stays O(period), not
O(n_layers)), with any remainder layers unrolled.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

Plan = Tuple[Tuple[str, str], ...]

MIXERS = ("attn", "attn_local", "ssd", "rglru")
MLPS = ("swiglu", "gated_mlp", "moe", "none")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001   # load-balance loss (Switch-style)
    # C8 analogue: tokens within an expert need no stable order; an unstable
    # (faster) sort is used when False.
    stable_dispatch_sort: bool = False


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    plan: Plan = (("attn", "swiglu"),)
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_window: Optional[int] = None    # for attn_local mixers
    rnn_width: Optional[int] = None      # for rglru mixers
    n_codebooks: int = 0                 # musicgen-style codebook stack
    logit_softcap: Optional[float] = None
    dtype: str = "bfloat16"
    source: str = ""                     # provenance note [citation; tier]

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def period(self) -> int:
        return len(self.plan)

    @property
    def n_full_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def tail_layers(self) -> Tuple[Tuple[str, str], ...]:
        r = self.n_layers % self.period
        return self.plan[:r]

    @property
    def sub_quadratic(self) -> bool:
        """True if no mixer needs O(S^2) prefill attention over full context."""
        return all(m in ("ssd", "rglru", "attn_local") for m, _ in self.plan)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for MODEL_FLOPS."""
        d, v = self.d_model, self.vocab_size
        n_embed = v * d * (self.n_codebooks or 1)
        if not self.tie_embeddings:
            n_embed += v * d * max(self.n_codebooks, 1)
        total = n_embed
        for li in range(self.n_layers):
            mixer, mlp = self.plan[li % self.period]
            total += d  # norm1
            if mixer in ("attn", "attn_local"):
                qkv = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd
                total += qkv + self.n_heads * self.hd * d
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * self.hd
                if self.qk_norm:
                    total += 2 * self.hd
            elif mixer == "ssd":
                s = self.ssm
                d_in = s.expand * d
                nh = d_in // s.head_dim
                total += d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
                total += s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
                total += 2 * nh + d_in  # A, D, norm
                total += d_in * d
            elif mixer == "rglru":
                w = self.rnn_width or d
                total += 2 * d * w + w * d          # in x2 (gate+rnn), out
                total += 4 * w + 2 * w * (w // 8)   # conv4 + lru gates (block-diag/8)
            if mlp != "none":
                total += d  # norm2
            if mlp in ("swiglu", "gated_mlp"):
                total += 3 * d * self.d_ff
            elif mlp == "moe":
                m = self.moe
                total += d * m.n_experts            # router
                total += m.n_experts * 3 * d * m.d_expert
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        expert_all = 0
        expert_active = 0
        for li in range(self.n_layers):
            _, mlp = self.plan[li % self.period]
            if mlp == "moe":
                expert_all += m.n_experts * 3 * self.d_model * m.d_expert
                expert_active += m.top_k * 3 * self.d_model * m.d_expert
        return full - expert_all + expert_active


@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str                    # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k":    InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k":   InputShape("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Assignment rules: long_500k is required only for sub-quadratic archs
    (decode against a cache is O(S) even for full attention, so those cells
    still lower -- they are reported as `extra`); all other cells apply."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return True, "extra: full-attention arch; decode is O(S) so it " \
                     "lowers, but the cell is not required (see DESIGN.md)"
    return True, "required"
