"""chameleon-34b [vlm]: early-fusion, VQ image tokens share the 65536 vocab
(frontend is a stub: input_specs provides token ids).  48L d_model=8192 64H
(GQA kv=8) d_ff=22016. [arXiv:2405.09818; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22_016, vocab_size=65_536,
    plan=(("attn", "swiglu"),),
    qk_norm=True,   # chameleon uses qk-norm for stability
    source="[arXiv:2405.09818; unverified]",
)
