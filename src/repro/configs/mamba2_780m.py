"""mamba2-780m [ssm]: SSD (state-space duality), attention-free.
48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128.
[arXiv:2405.21060; unverified]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=48, n_kv_heads=48,  # SSD heads (d_inner/head_dim)
    d_ff=0, vocab_size=50_280,
    plan=(("ssd", "none"),),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    tie_embeddings=True,
    source="[arXiv:2405.21060; unverified]",
)
