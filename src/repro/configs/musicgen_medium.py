"""musicgen-medium [audio]: decoder-only over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24 -> MHA) d_ff=6144 vocab=2048, 4 codebooks
with summed codebook embeddings + 4 output heads (delay-pattern frontend is
a stub per the assignment). [arXiv:2306.05284; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    plan=(("attn", "swiglu"),),
    n_codebooks=4,
    source="[arXiv:2306.05284; hf]",
)
