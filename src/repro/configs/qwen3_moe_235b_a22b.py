"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) expert
d_ff=1536 vocab=151936, MoE 128 experts top-8, qk_norm, head_dim=128.
The paper-representative arch: token dispatch = SpGEMM (DESIGN.md section 5).
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab_size=151_936, head_dim=128,
    plan=(("attn", "moe"),),
    qk_norm=True, rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
)
