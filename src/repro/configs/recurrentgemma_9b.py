"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1:2 pattern.
38L d_model=4096 16H (GQA kv=1 -> MQA) d_ff=12288 vocab=256000, head_dim=256,
window=2048, rnn_width=4096.  38 = 12 full (rec, rec, attn) periods + 2
tail rec layers.  [arXiv:2402.19427; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12_288, vocab_size=256_000, head_dim=256,
    plan=(("rglru", "gated_mlp"), ("rglru", "gated_mlp"),
          ("attn_local", "gated_mlp")),
    attn_window=2048, rnn_width=4096, tie_embeddings=True,
    source="[arXiv:2402.19427; unverified]",
)
