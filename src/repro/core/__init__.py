"""Core sparse engine: the paper's contribution as composable JAX modules."""
from .formats import CSR, BCSR, ELL, csr_to_bcsr, bcsr_to_csr, csr_transpose
from .semiring import (Semiring, SEMIRINGS, resolve_semiring, PLUS_TIMES,
                       BOOLEAN, MIN_PLUS, PLUS_FIRST)
from .spgemm import (spgemm, spgemm_dense, spgemm_esc, spgemm_heap,
                     spgemm_hash_jnp, spmm, symbolic, symbolic_flops,
                     finalize)
from .schedule import (flops_per_row, rows_to_bins, bin_flop, make_schedule,
                       lowbnd, lowest_p2, lowest_p2_arr, bin_table_sizes,
                       max_flop_per_bin_row, masked_row_bound, guard_i32_flop,
                       chained_flop_bound)
from .recipe import (SpGEMMStats, measure_stats, model_costs, recommend,
                     choose_algorithm, choose_algorithm_from_stats,
                     aggregate_stats)
from .plan import (SpGEMMPlan, plan_spgemm, structure_key, plan_cache_stats,
                   clear_plan_cache, PLAN_KINDS)
from .bcsr import BCSRPlan, plan_bcsr, bcsr_structure_key
from .pb import PBPlan, plan_pb
from .distributed import (ShardedCSR, shard_csr_rows, reshard_rows,
                          unshard_rows, DistributedPlan, plan_spgemm_1d,
                          spgemm_1d, spmm_1d, SummaPlan, plan_spgemm_summa,
                          spgemm_summa, summa_panel_bounds, shard_batch,
                          PBSummaPlan, plan_spgemm_pb_summa, spgemm_pb_summa,
                          multi_source_bfs as multi_source_bfs_1d)
from .chain import (ChainPlan, plan_chain, plan_galerkin, galerkin,
                    plan_power, GramPlan, plan_gram, gram,
                    DistributedChainPlan, plan_chain_1d,
                    BatchedPowerPlan, plan_batch_power)
from .batch import BatchClass, BatchedPlan, plan_batch, spgemm_batch

__all__ = [
    "CSR", "BCSR", "ELL", "csr_to_bcsr", "bcsr_to_csr", "csr_transpose",
    "Semiring", "SEMIRINGS", "resolve_semiring", "PLUS_TIMES", "BOOLEAN",
    "MIN_PLUS", "PLUS_FIRST",
    "spgemm", "spgemm_dense", "spgemm_esc", "spgemm_heap", "spgemm_hash_jnp",
    "spmm", "symbolic", "symbolic_flops", "finalize",
    "flops_per_row", "rows_to_bins", "bin_flop", "make_schedule", "lowbnd",
    "lowest_p2", "lowest_p2_arr", "bin_table_sizes", "max_flop_per_bin_row",
    "masked_row_bound", "guard_i32_flop", "chained_flop_bound",
    "SpGEMMStats", "measure_stats", "model_costs", "recommend",
    "choose_algorithm", "choose_algorithm_from_stats", "aggregate_stats",
    "SpGEMMPlan", "plan_spgemm", "structure_key", "plan_cache_stats",
    "clear_plan_cache", "PLAN_KINDS",
    "BCSRPlan", "plan_bcsr", "bcsr_structure_key",
    "PBPlan", "plan_pb",
    "ShardedCSR", "shard_csr_rows", "reshard_rows", "unshard_rows",
    "DistributedPlan", "plan_spgemm_1d", "spgemm_1d", "spmm_1d",
    "SummaPlan", "plan_spgemm_summa", "spgemm_summa", "summa_panel_bounds",
    "PBSummaPlan", "plan_spgemm_pb_summa", "spgemm_pb_summa",
    "shard_batch", "multi_source_bfs_1d",
    "ChainPlan", "plan_chain", "plan_galerkin", "galerkin", "plan_power",
    "GramPlan", "plan_gram", "gram", "DistributedChainPlan", "plan_chain_1d",
    "BatchedPowerPlan", "plan_batch_power",
    "BatchClass", "BatchedPlan", "plan_batch", "spgemm_batch",
]
