"""Core sparse engine: the paper's contribution as composable JAX modules."""
from .formats import CSR, BCSR, ELL, csr_to_bcsr, bcsr_to_csr
from .spgemm import (spgemm, spgemm_dense, spgemm_esc, spgemm_heap, spmm,
                     symbolic, symbolic_flops)
from .schedule import (flops_per_row, rows_to_bins, bin_flop, make_schedule,
                       lowbnd, lowest_p2, max_flop_per_bin_row)
from .recipe import (SpGEMMStats, measure_stats, model_costs,
                     choose_algorithm, choose_algorithm_from_stats)

__all__ = [
    "CSR", "BCSR", "ELL", "csr_to_bcsr", "bcsr_to_csr",
    "spgemm", "spgemm_dense", "spgemm_esc", "spgemm_heap", "spmm",
    "symbolic", "symbolic_flops",
    "flops_per_row", "rows_to_bins", "bin_flop", "make_schedule", "lowbnd",
    "lowest_p2", "max_flop_per_bin_row",
    "SpGEMMStats", "measure_stats", "model_costs", "choose_algorithm",
    "choose_algorithm_from_stats",
]
