"""Batched SpGEMM: plan and execute *fleets* of small products (DESIGN.md
section 13).

The paper's recipe assumes one large product per call, but serving-shaped
traffic is fleets of small independent products: DBCSR-style batches of
block multiplications in quantum chemistry (Bethune et al., the DBCSR
Xeon-Phi port), per-expert MoE dispatch products, per-query masked
products in graph serving.  Calling :func:`repro.core.plan.plan_spgemm`
per product pays one inspection *and one compiled program* per member --
a fleet of 64 slightly-different structures compiles 64 numeric programs
and dispatches 64 times per step.

:func:`plan_batch` inspects the whole fleet in one pass and groups the
members into **p2-bucketed capacity classes** -- the same
``bucket_caps=True`` power-of-two rounding :func:`plan_spgemm` uses for
structure-drifting loops, applied across fleet members instead of across
iterations.  A class is keyed by the p2-rounded shapes, mask presence,
and the p2 bucket of the member's total flop (the dominant capacity;
every other static cap correlates with it): within each same-shape,
uniformly-masked subfleet whose flop spans a factor of ``R``, at most
``ceil(log2 R) + 1`` numeric programs compile, not one per member
(heterogeneous shapes add their own classes on top -- shapes cannot
share a ``vmap``).
Each class pads its members to the common static shape, stacks them, and
executes one ``vmap``-ed numeric-only program with intermediates kept
**unsorted** (the C8 finding, per batch element); the hash family runs
the real Pallas kernel here -- the plan freezes each member's schedule
(bin offsets, per-bin table sizes, ``indptr_c``) as stacked batched
operands, and a ``custom_vmap`` rule swaps in the natively batched grid
(``kernels/spgemm_hash``), so every dynamic value traces while the
scratch table stays static per capacity class.  The jnp twin remains
only as the reference oracle and as the body for general semirings /
masked members (mirroring ``SpGEMMPlan.execute``).

Padding is *capacity-only*: the padded tail of a CSR is structurally
empty (``nnz`` marks the live prefix), so the live prefix of every class
member's output is bitwise-identical to what the exact-capacity
per-product planned path produces -- asserted by ``tests/test_batch.py``
and ``benchmarks/bench_batch.py --smoke``.

Algorithm choice is per *class*, from the class's aggregate statistics
(:func:`repro.core.recipe.aggregate_stats` + ``use_case="batch"``): one
program per class means one algorithm per class, the batched analogue of
``plan_spgemm_1d`` resolving ``auto`` once for the whole SPMD mesh.

Plans are cached under a ``("batch", ...)`` kind in the shared plan LRU
(per-kind occupancy in ``plan_cache_stats()["kinds"]``); a structure-
identical fleet replans nothing, and repeat executes re-dispatch the
already-compiled class programs with zero re-inspection.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .formats import CSR
from .plan import cache_lookup, cache_store, structure_key
from .recipe import aggregate_stats, choose_algorithm_from_stats, \
    measure_stats
from .semiring import Semiring, resolve_semiring
from . import schedule as sched
from .spgemm import (_canon_mask, _check_mask, finalize, spgemm_esc,
                     spgemm_hash_jnp, spgemm_heap, symbolic)

# The hash family runs the real Pallas kernel under the vmapped executor
# (plan-frozen schedules ride in as batched operands; there is no twin
# substitution table anymore).  ``dense`` and ``bcsr`` are still rejected
# outright (explicitly, below) -- the dense oracle's explicit-zero
# semantics and the bcsr tile path both have no vmapped twin, and a
# silent substitution would change output structure without warning.

#: Fig. 6 bin count used for the per-member frozen hash schedules -- the
#: same default ``plan_spgemm`` uses, so a class member's numeric result
#: is bitwise the per-product planned result.
_HASH_BINS = 8


def _pad_csr(a: CSR, n_rows: int, n_cols: int, cap: int) -> CSR:
    """Pad a CSR to a class's static shape/capacity (structure-preserving).

    Extra rows are empty (``indptr`` extends flat at its last value), the
    extra entry capacity is zeros past the live prefix, and extra columns
    cost nothing at all -- so the padded product's live output prefix is
    bitwise what the unpadded product computes.  jnp ops throughout: this
    runs on device at execute time, per member, per call.
    """
    assert n_rows >= a.n_rows and n_cols >= a.n_cols and cap >= a.cap, \
        f"class shape ({n_rows}, {n_cols})/cap {cap} cannot hold " \
        f"{a.shape}/cap {a.cap}"
    ip = a.indptr
    if n_rows > a.n_rows:
        ip = jnp.concatenate(
            [ip, jnp.broadcast_to(ip[-1], (n_rows - a.n_rows,))])
    ind = jnp.pad(a.indices, (0, cap - a.cap))
    dat = jnp.pad(a.data, (0, cap - a.cap))
    return CSR(ip, ind, dat, a.nnz, (n_rows, n_cols),
               sorted_cols=a.sorted_cols)


def _stack_csr(mats: Sequence[CSR], sorted_cols: bool) -> CSR:
    """Stack equal-shape CSRs leaf-wise (leading batch dim on every array).

    ``sorted_cols`` is static metadata and must be uniform across the
    stack; the class flag is the AND over members (downgrading a sorted
    member costs nothing -- only the heap path *requires* the flag, and a
    class only records heap when every member is sorted).
    """
    mats = [dataclasses.replace(m, sorted_cols=sorted_cols) for m in mats]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *mats)


def _build_class_program(cls: "BatchClass",
                         shapes_a: Tuple[Tuple[int, int], ...],
                         shapes_b: Tuple[Tuple[int, int], ...],
                         semiring: str, complement_mask: bool,
                         sorted_output: bool, a_shared: bool = False,
                         b_shared: bool = False):
    """One jitted program for one capacity class: pad every member to the
    class's static shape, stack, run the ``vmap``-ed numeric body, unpack
    back to per-member CSRs -- all inside a single dispatch (padding and
    slicing as eager per-member ops would cost more than the fleet math).

    ``shapes_a``/``shapes_b`` are the members' *original* shapes (class
    order), so the unpacked outputs carry exact row counts again.  With
    ``a_shared``/``b_shared`` the corresponding operand arrives *once*
    and broadcasts through ``vmap(in_axes=None)`` instead of being
    stacked -- a fleet of N products against one shared feature matrix
    reads that matrix once, not N copies (the per-expert MoE dispatch
    shape).  This builder is the unit the "compiled programs per fleet"
    accounting counts: the plan memoizes the result per (class,
    sortedness, sharing), so a fleet compiles exactly ``n_classes``
    programs and repeat executes build nothing
    (``benchmarks/bench_batch.py --smoke`` wraps it in a call counter to
    assert both).
    """
    from repro.kernels.spgemm_hash import ops as hash_ops
    sr = resolve_semiring(semiring)
    algo = cls.algorithm
    (M, K), (_, N) = cls.shape_a, cls.shape_b
    # hash classes carry plan-frozen stacked schedules unless the request
    # is general (non-plus_times semiring or masked members), where the
    # jnp twin keeps the contract -- the same split SpGEMMPlan.execute
    # makes for a single product.
    pallas_hash = algo in ("hash", "hash_vector") and \
        cls.hash_sched is not None

    def one(a: CSR, b: CSR, mask: Optional[CSR], hs=None) -> CSR:
        if algo == "esc":
            out = spgemm_esc(a, b, cls.cap_c, flop_cap=cls.flop_cap,
                             semiring=sr, mask=mask,
                             complement_mask=complement_mask)
        elif algo in ("hash", "hash_vector", "hash_jnp"):
            if hs is None:      # explicit hash_jnp pin, or general request
                out = spgemm_hash_jnp(a, b, cls.cap_c,
                                      flop_cap=cls.flop_cap,
                                      semiring=sr, mask=mask,
                                      complement_mask=complement_mask)
            else:
                out = hash_ops.spgemm_hash(
                    a, b, cls.cap_c, vector=(algo == "hash_vector"),
                    table_size=cls.table_size, schedule=(hs[0], hs[1]),
                    indptr_c=hs[2])
        elif algo == "heap":
            out = spgemm_heap(a, b, row_cap=cls.row_cap,
                              k_width=cls.k_width, cap_c=cls.cap_c,
                              semiring=sr, mask=mask,
                              complement_mask=complement_mask)
        else:
            raise ValueError(f"class holds unknown algorithm {algo!r}")
        return finalize(out, sorted_output)

    masked = cls.mask_parts is not None

    def prep(ops, shared, rows, cols, cap, flag):
        if shared:
            return dataclasses.replace(
                _pad_csr(ops, rows, cols, cap), sorted_cols=flag)
        return _stack_csr([_pad_csr(x, rows, cols, cap) for x in ops],
                          flag)

    def fleet(a_in, b_in, *rest) -> Tuple[CSR, ...]:
        # rest: (mask_parts,) for masked classes, or the three stacked
        # hash-schedule operands (offsets, bin_tsize, indptr_c) for
        # Pallas hash classes (mutually exclusive by construction).
        a_proc = prep(a_in, a_shared, M, K, cls.cap_a, cls.a_sorted)
        b_proc = prep(b_in, b_shared, K, N, cls.cap_b, cls.b_sorted)
        axes = (None if a_shared else 0, None if b_shared else 0)
        if masked:
            c_stack = jax.vmap(lambda a, b, m: one(a, b, m),
                               in_axes=axes + (0,))(
                a_proc, b_proc, rest[0])
        elif pallas_hash:
            c_stack = jax.vmap(
                lambda a, b, o, t, ic: one(a, b, None, (o, t, ic)),
                in_axes=axes + (0, 0, 0))(a_proc, b_proc, *rest)
        else:
            c_stack = jax.vmap(lambda a, b: one(a, b, None),
                               in_axes=axes)(a_proc, b_proc)
        outs = []
        for j in range(len(shapes_a)):
            m_j, n_j = shapes_a[j][0], shapes_b[j][1]
            outs.append(CSR(c_stack.indptr[j, :m_j + 1],
                            c_stack.indices[j], c_stack.data[j],
                            c_stack.nnz[j], (m_j, n_j),
                            sorted_cols=c_stack.sorted_cols))
        return tuple(outs)

    return jax.jit(fleet)


@dataclass(frozen=True)
class BatchClass:
    """One capacity class: members that share a compiled numeric program.

    All static shapes/capacities are the p2-rounded class maxima; the
    per-member exact numbers live on the owning :class:`BatchedPlan`.
    ``mask_parts`` holds the members' canonicalized masks, padded to the
    class shape and stacked (structure frozen with the plan, like the
    mask on a ``SpGEMMPlan``).
    """
    members: Tuple[int, ...]
    algorithm: str
    shape_a: Tuple[int, int]      # padded (M, K)
    shape_b: Tuple[int, int]      # padded (K, N)
    cap_a: int
    cap_b: int
    cap_c: int
    flop_cap: int
    row_cap: int
    k_width: int
    a_sorted: bool
    b_sorted: bool
    mask_parts: Optional[CSR] = dataclasses.field(repr=False)
    total_flop: int = 0
    #: all members held the *same object* for this operand at plan time:
    #: the executor may broadcast it (vmap in_axes=None) instead of
    #: stacking N copies -- re-verified by identity at execute time, so a
    #: caller legally substituting per-member values falls back to the
    #: stacked program.
    a_shared: bool = False
    b_shared: bool = False
    #: static Pallas scratch allocation for hash classes: the max over
    #: the members' own natural table sizes (each member's per-bin sizes
    #: are clamped against its *own* table at plan time, so the larger
    #: shared allocation never changes a member's probes or flush order).
    table_size: int = 0
    #: plan-frozen stacked hash schedules for the batched-grid kernel:
    #: ``(offsets (n, n_bins+1), bin_tsize (n, n_bins), indptr_c (n, M+1))``
    #: in class-member order; ``None`` for non-hash / general classes.
    hash_sched: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = \
        dataclasses.field(default=None, repr=False)

    @property
    def n_members(self) -> int:
        return len(self.members)


@dataclass(frozen=True)
class BatchedPlan:
    """Frozen inspection of a fleet of products ``[(A_i, B_i), ...]``.

    ``classes[class_of[i]]`` is product ``i``'s capacity class;
    :meth:`execute` pads/stacks each class's operands, runs the class's
    single vmapped numeric program, and returns per-product CSRs in input
    order (original shapes, class capacity, exact ``nnz``).
    """
    key: tuple = dataclasses.field(repr=False)
    classes: Tuple[BatchClass, ...] = dataclasses.field(repr=False)
    class_of: Tuple[int, ...]
    semiring: str
    complement_mask: bool
    sorted_output: bool
    shapes_a: Tuple[Tuple[int, int], ...]
    shapes_b: Tuple[Tuple[int, int], ...]
    caps_a: Tuple[int, ...]
    caps_b: Tuple[int, ...]
    nnzs_a: Tuple[int, ...]
    nnzs_b: Tuple[int, ...]
    nnz_cs: Tuple[int, ...]       # exact per-product nnz(C_i)
    total_flop: int

    @property
    def n_products(self) -> int:
        return len(self.class_of)

    @property
    def n_classes(self) -> int:
        return len(self.classes)

    @property
    def algorithms(self) -> Tuple[str, ...]:
        """Per-product resolved algorithm (its class's choice)."""
        return tuple(self.classes[c].algorithm for c in self.class_of)

    @property
    def nnz_c(self) -> int:
        return sum(self.nnz_cs)

    def check_structure(self, pairs: Sequence[Tuple[CSR, CSR]]) -> None:
        """Cheap shapes/caps/nnz check of every member against the plan.

        Shapes/caps are static Python and cost nothing.  The per-member
        ``int(op.nnz)`` looks like O(2N) device round-trips on the hot
        dispatch path, but jax memoizes the host value on the array
        itself, so a serving loop re-executing the same fleet objects
        pays each transfer once per operand lifetime, not per call
        (stacking the scalars into one transfer was measured *slower* --
        the eager concatenate dispatch costs more than the amortized
        reads).
        """
        assert len(pairs) == self.n_products, \
            f"plan is for {self.n_products} products, got {len(pairs)}"
        for i, (a, b) in enumerate(pairs):
            assert a.shape == self.shapes_a[i] and \
                b.shape == self.shapes_b[i], \
                f"product {i}: planned {self.shapes_a[i]}x" \
                f"{self.shapes_b[i]}, got {a.shape}x{b.shape}"
            assert a.cap == self.caps_a[i] and b.cap == self.caps_b[i], \
                f"product {i}: operand capacities differ from the " \
                f"planned structure"
            for op, planned in ((a, self.nnzs_a[i]), (b, self.nnzs_b[i])):
                if not isinstance(op.nnz, jax.core.Tracer):
                    assert int(op.nnz) == planned, \
                        f"product {i} nnz differs from the planned " \
                        f"structure (replan or clear_plan_cache)"

    def _class_executor(self, ci: int, sorted_output: bool,
                        a_shared: bool, b_shared: bool):
        cache = self.__dict__.get("_executors")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_executors", cache)
        key = (ci, sorted_output, a_shared, b_shared)
        fn = cache.get(key)
        if fn is None:
            cls = self.classes[ci]
            fn = _build_class_program(
                cls, tuple(self.shapes_a[i] for i in cls.members),
                tuple(self.shapes_b[i] for i in cls.members),
                self.semiring, self.complement_mask, sorted_output,
                a_shared=a_shared, b_shared=b_shared)
            cache[key] = fn
        return fn

    def execute(self, pairs: Sequence[Tuple[CSR, CSR]],
                sorted_output: Optional[bool] = None) -> List[CSR]:
        """Numeric phase only, whole fleet: zero re-inspection.

        One jitted dispatch per capacity class (pad + stack + vmapped
        numeric body + unpack all live inside the class program); results
        come back in input order with each product's original shape
        (capacity is the class's static ``cap_c``; ``nnz`` is exact).
        ``sorted_output`` overrides the plan's recorded sortedness for
        this call -- a pure epilogue, exactly like ``SpGEMMPlan.execute``.
        """
        pairs = [tuple(p) for p in pairs]
        self.check_structure(pairs)
        so = self.sorted_output if sorted_output is None else sorted_output
        outs: List[Optional[CSR]] = [None] * len(pairs)
        for ci, cls in enumerate(self.classes):
            a_ops = tuple(pairs[i][0] for i in cls.members)
            b_ops = tuple(pairs[i][1] for i in cls.members)
            if cls.algorithm == "heap":
                # the class program force-stamps the plan-time sorted
                # flags before vmapping, so an operand downgraded to
                # unsorted since plan time would silently feed the heap
                # merge out of order -- fail loudly instead (static
                # metadata, costs nothing)
                assert all(a.sorted_cols for a in a_ops) and \
                    all(b.sorted_cols for b in b_ops), \
                    "heap class executed with an unsorted operand " \
                    "(structure drifted since plan time; replan)"
            # broadcast an operand only when the caller actually passed
            # one object for the whole class this call (values included);
            # a vmap needs at least one mapped axis, so when everything
            # is shared and unmasked the A side stays stacked
            b_shared = cls.b_shared and len(b_ops) > 1 and \
                all(b is b_ops[0] for b in b_ops)
            a_shared = cls.a_shared and len(a_ops) > 1 and \
                all(a is a_ops[0] for a in a_ops) and \
                (b_shared is False or cls.mask_parts is not None)
            args = ((a_ops[0] if a_shared else a_ops),
                    (b_ops[0] if b_shared else b_ops))
            if cls.mask_parts is not None:
                args = args + (cls.mask_parts,)
            elif cls.hash_sched is not None and \
                    cls.algorithm in ("hash", "hash_vector"):
                args = args + cls.hash_sched
            c_list = self._class_executor(ci, so, a_shared, b_shared)(*args)
            for j, i in enumerate(cls.members):
                outs[i] = c_list[j]
        return outs

    __call__ = execute


def plan_batch(pairs: Sequence[Tuple[CSR, CSR]], *,
               algorithm: str = "auto",
               semiring: str | Semiring = "plus_times",
               masks: Optional[Sequence[Optional[CSR]]] = None,
               complement_mask: bool = False, sorted_output: bool = False,
               cache: bool = True) -> BatchedPlan:
    """Inspect a fleet of products once; freeze a :class:`BatchedPlan`.

    ``pairs`` is a sequence of ``(A_i, B_i)`` CSRs -- repeat the same
    object to share one A or one B across the fleet (per-expert dispatch
    against one feature matrix, one graph against per-query frontiers);
    structure digests are memoized on the instance, so sharing also makes
    the cache key cheap.  ``masks`` optionally gives one structural mask
    per product (``None`` entries allowed); masked and unmasked members
    never share a class.

    Inspection is one pass: per-member flop profile + exact symbolic
    counts, then p2 capacity-class grouping, then one recipe choice per
    class from the class's aggregate statistics
    (``use_case="batch"``).  ``algorithm`` other than ``"auto"`` pins
    every class; the hash family dispatches the real Pallas kernel with
    plan-frozen stacked schedules (``hash_jnp`` stays available as an
    explicit reference-oracle pin).  Cached under a ``("batch", ...)``
    key in the shared plan LRU.
    """
    pairs = [tuple(p) for p in pairs]
    assert pairs, "a batch needs at least one product"
    n = len(pairs)
    for i, (a, b) in enumerate(pairs):
        # fail loudly like _check_chain_shapes: a silent mismatch would
        # gather B row lengths at clamped out-of-range indices and
        # produce plausible wrong numerics
        assert a.n_cols == b.n_rows, \
            f"batch member {i}: {a.shape} @ {b.shape} shapes do not compose"
    masks = list(masks) if masks is not None else [None] * n
    assert len(masks) == n, \
        f"masks must align with pairs: {len(masks)} != {n}"
    sr = resolve_semiring(semiring)
    if algorithm == "heap":
        for i, (a, b) in enumerate(pairs):
            if not (a.sorted_cols and b.sorted_cols):
                raise AssertionError("heap path requires sorted inputs")
    if algorithm in ("bcsr", "dense"):
        raise NotImplementedError(
            f"the {algorithm} path cannot run under the batched (vmapped) "
            f"executor; pick esc/heap/hash")

    key = ("batch",
           tuple((structure_key(a), structure_key(b),
                  None if m is None else structure_key(m))
                 for (a, b), m in zip(pairs, masks)),
           sr.name, complement_mask, sorted_output, algorithm)
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit

    # --- one inspection pass over the fleet ----------------------------
    infos = []
    for (a, b), m in zip(pairs, masks):
        _check_mask(a, b, m)
        m = _canon_mask(m)
        flop = sched.flops_per_row(a, b)
        total_flop = int(jnp.sum(flop)) if flop.size else 0
        # p2-bucketed expansion bound: exact counts either way, but the
        # jitted symbolic phase then compiles one program per flop bucket
        # instead of one per member (inspection cost scales with classes)
        row_nnz_c, indptr_c, _, _ = symbolic(
            a, b, mask=m, complement_mask=complement_mask,
            flop_cap=sched.lowest_p2(max(total_flop, 1)))
        stats = measure_stats(a, b, row_nnz_c=row_nnz_c, mask=m,
                              complement_mask=complement_mask)
        infos.append(dict(
            mask=m, total_flop=total_flop, stats=stats, flop=flop,
            indptr_c=indptr_c.astype(jnp.int32),
            nnz_c=int(jnp.sum(row_nnz_c)),
            row_cap=max(int(jnp.max(row_nnz_c)) if row_nnz_c.size else 0,
                        1),
            k_width=max(int(jnp.max(a.row_nnz())) if a.n_rows else 0, 1)))

    # --- p2 capacity-class grouping ------------------------------------
    # The class key buckets shapes and the member's total flop (the
    # dominant capacity -- cap_c/row_cap/k_width correlate with it), so a
    # fleet with flop spread R lands in <= ceil(log2 R) + 1 classes; all
    # other class capacities are the p2-rounded class maxima.
    p2 = sched.lowest_p2
    groups: dict = {}
    for i, ((a, b), info) in enumerate(zip(pairs, infos)):
        gk = (p2(max(a.n_rows, 1)), p2(max(a.n_cols, 1)),
              p2(max(b.n_cols, 1)), info["mask"] is not None,
              p2(max(info["total_flop"], 1)))
        groups.setdefault(gk, []).append(i)

    classes: List[BatchClass] = []
    class_of = [0] * n
    for gk in sorted(groups):
        idxs = groups[gk]
        M, K, N = gk[0], gk[1], gk[2]
        masked = gk[3]
        a_sorted = all(pairs[i][0].sorted_cols for i in idxs)
        b_sorted = all(pairs[i][1].sorted_cols for i in idxs)
        algo = algorithm
        if algo == "auto":
            agg = aggregate_stats([infos[i]["stats"] for i in idxs])
            algo = choose_algorithm_from_stats(
                agg, sorted_output, use_case="batch", semiring=sr.name)
        if algo == "heap" and not (a_sorted and b_sorted):
            # recipe picked heap on its merits, but a member cannot feed
            # it; hash keeps the unsorted contract (same fallback as
            # plan_spgemm)
            algo = "hash"
        mask_parts = None
        if masked:
            mcap = p2(max(max(infos[i]["mask"].cap for i in idxs), 1))
            mask_parts = _stack_csr(
                [_pad_csr(infos[i]["mask"], M, N, mcap) for i in idxs],
                True)
        # Plan-frozen hash schedules (Fig. 6 + Fig. 7 lines 9-12), one per
        # member over the member's *unpadded* structure, stacked along the
        # class axis: this is what lets the class program dispatch the
        # real Pallas kernel under vmap.  Each member's bin sizes clamp
        # against its own natural table, so the class-max static scratch
        # is inert and the live output prefix stays bitwise the
        # per-product planned result.  General requests (non-plus_times
        # semiring, masks) keep the jnp-twin body instead.
        table_size = 0
        hash_sched = None
        if algo in ("hash", "hash_vector") and not masked and \
                sr.name == "plus_times":
            from repro.kernels.spgemm_hash import kernel as HK
            per_off, per_bts, per_ic = [], [], []
            tables = []
            for i in idxs:
                a_i, b_i = pairs[i]
                flop_i = infos[i]["flop"]
                off_i = sched.rows_to_bins(flop_i, _HASH_BINS)
                tsz_i = jnp.minimum(
                    sched.max_flop_per_bin_row(flop_i, off_i),
                    jnp.int32(b_i.n_cols))
                max_flop = int(jnp.max(flop_i)) if flop_i.size else 0
                t_i = max(sched.lowest_p2(min(max_flop, b_i.n_cols) + 1),
                          HK.CHUNK)
                tables.append(t_i)
                per_off.append(off_i)
                per_bts.append(sched.bin_table_sizes(
                    tsz_i, b_i.n_cols, t_i, floor=HK.CHUNK))
                ip = infos[i]["indptr_c"]
                if M + 1 > ip.shape[0]:      # flat-pad to the class rows
                    ip = jnp.concatenate(
                        [ip, jnp.broadcast_to(ip[-1],
                                              (M + 1 - ip.shape[0],))])
                per_ic.append(ip)
            table_size = max(tables)
            hash_sched = (jnp.stack(per_off), jnp.stack(per_bts),
                          jnp.stack(per_ic))
        cls = BatchClass(
            members=tuple(idxs), algorithm=algo, shape_a=(M, K),
            shape_b=(K, N),
            a_shared=all(pairs[i][0] is pairs[idxs[0]][0] for i in idxs),
            b_shared=all(pairs[i][1] is pairs[idxs[0]][1] for i in idxs),
            cap_a=p2(max(max(pairs[i][0].cap for i in idxs), 1)),
            cap_b=p2(max(max(pairs[i][1].cap for i in idxs), 1)),
            cap_c=p2(max(max(infos[i]["nnz_c"] for i in idxs), 1)),
            flop_cap=p2(max(max(infos[i]["total_flop"] for i in idxs), 1)),
            row_cap=p2(max(infos[i]["row_cap"] for i in idxs)),
            k_width=p2(max(infos[i]["k_width"] for i in idxs)),
            a_sorted=a_sorted, b_sorted=b_sorted, mask_parts=mask_parts,
            total_flop=sum(infos[i]["total_flop"] for i in idxs),
            table_size=table_size, hash_sched=hash_sched)
        for i in idxs:
            class_of[i] = len(classes)
        classes.append(cls)

    plan = BatchedPlan(
        key=key, classes=tuple(classes), class_of=tuple(class_of),
        semiring=sr.name, complement_mask=complement_mask,
        sorted_output=sorted_output,
        shapes_a=tuple(a.shape for a, _ in pairs),
        shapes_b=tuple(b.shape for _, b in pairs),
        caps_a=tuple(a.cap for a, _ in pairs),
        caps_b=tuple(b.cap for _, b in pairs),
        nnzs_a=tuple(int(a.nnz) for a, _ in pairs),
        nnzs_b=tuple(int(b.nnz) for _, b in pairs),
        nnz_cs=tuple(info["nnz_c"] for info in infos),
        total_flop=sum(info["total_flop"] for info in infos))
    if cache:
        cache_store(key, plan)
    return plan


def spgemm_batch(pairs: Sequence[Tuple[CSR, CSR]], *,
                 algorithm: str = "auto",
                 semiring: str | Semiring = "plus_times",
                 masks: Optional[Sequence[Optional[CSR]]] = None,
                 complement_mask: bool = False,
                 sorted_output: bool = False,
                 plan: Optional[BatchedPlan] = None,
                 cache: bool = True) -> List[CSR]:
    """One-shot planned fleet product: ``[A_i @ B_i for i in fleet]``.

    Plans (or pulls from the shared cache -- a repeat fleet on the same
    structures runs numeric-only) and executes.  With ``plan=`` every
    other argument except ``pairs`` is ignored, mirroring
    ``spgemm(plan=)``.
    """
    if plan is None:
        plan = plan_batch(pairs, algorithm=algorithm, semiring=semiring,
                          masks=masks, complement_mask=complement_mask,
                          sorted_output=sorted_output, cache=cache)
    return plan.execute(pairs)
