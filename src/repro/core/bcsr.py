"""Inspector-executor planner for block-sparse (BCSR) SpGEMM
(DESIGN.md section 17).

The scalar planner (:mod:`repro.core.plan`) freezes the paper's Fig. 6/7
inspection at row granularity; this module freezes the *same* inspection
at block granularity for the DBCSR-class workloads (quantum chemistry,
block-MoE) where the matrix is sparse in tiles, not scalars.  One
inspection -- block flop per block row, equal-flop block-row bins, static
and per-bin power-of-two hash-table sizes, the exact symbolic block count
of C -- becomes a frozen :class:`BCSRPlan`; ``plan.execute(a, b)`` then
stages only the register-tiled MXU numeric kernel
(:mod:`repro.kernels.spgemm_bcsr`), with the schedule riding along as
array operands.  Zero re-inspection on repeat executes is counter-verified
(``kernels.spgemm_bcsr.ops.KERNEL_CALLS["symbolic"]`` stays flat).

Plans are cached in the shared LRU of :mod:`repro.core.plan` under the
``"bcsr"`` kind, keyed by the operands' *block structure* (values never
enter the key -- a re-weighted fleet of tiles hits the cached plan).

Planning is host-side eager (capacities must become static shapes);
``execute`` is trace-friendly and runs under ``jit`` and -- through the
kernels' ``custom_vmap`` rule -- under ``vmap`` over block-value fleets.
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import BCSR
from .plan import cache_lookup, cache_store


def bcsr_structure_key(a: BCSR) -> bytes:
    """Digest of a BCSR's *block structure* (pattern + static layout), not
    block values.  The block-granularity twin of
    :func:`repro.core.plan.structure_key`: covers shape, block, capacity,
    block count, and the indptr/indices arrays; memoized on the frozen
    instance so repeat lookups skip the host transfer + hash.
    """
    cached = a.__dict__.get("_structure_digest")
    if cached is not None:
        return cached
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((a.shape, a.block, a.bcap, int(a.nnzb))).encode())
    h.update(np.asarray(a.indptr).tobytes())
    h.update(np.asarray(a.indices).tobytes())
    digest = h.digest()
    object.__setattr__(a, "_structure_digest", digest)
    return digest


@dataclass(frozen=True)
class BCSRPlan:
    """Frozen block-product recipe for one (A, B) block-structure pair.

    Everything the executor needs, nothing recomputed: the block flop
    profile and equal-flop block-row bins (Fig. 6 over the block grid),
    per-bin p2 hash-table sizes and the static scratch allocation (Fig. 7
    lines 9-12, keys = block-column ids), and the exact block row pointer
    and capacity of C from the symbolic phase.  All capacities are Python
    ints, so structure-identical executes hit the jit dispatch cache.
    """
    key: tuple = dataclasses.field(repr=False)
    block_a: Tuple[int, int]
    block_b: Tuple[int, int]
    shape_a: Tuple[int, int]
    shape_b: Tuple[int, int]
    bcap_a: int
    bcap_b: int
    nnzb_a: int
    nnzb_b: int
    n_bins: int
    vector: bool
    # --- inspection products -------------------------------------------
    flop: jax.Array = dataclasses.field(repr=False)   # block flop/block row
    total_flop: int          # total block flop (block-pair MACs)
    offsets: jax.Array = dataclasses.field(repr=False)    # (n_bins + 1,)
    bin_tsize: jax.Array = dataclasses.field(repr=False)  # (n_bins,) p2
    table_size: int          # static scratch allocation (bin max, p2)
    row_nnzb_c: jax.Array = dataclasses.field(repr=False)
    indptr_cb: jax.Array = dataclasses.field(repr=False)
    nnzb_c: int
    bcap_c: int              # exact block-nnz(C) as a static capacity
    provenance: str = "planned"

    @property
    def block_c(self) -> Tuple[int, int]:
        return (self.block_a[0], self.block_b[1])

    # -------------------------------------------------------------------
    def check_structure(self, a: BCSR, b: BCSR) -> None:
        """Cheap block-structure guard (shapes/blocks/caps/nnzb).

        Executing against a different block structure would silently use
        wrong capacities; nnzb is guarded only when concrete so a jit over
        re-valued operands does not trip a concretization error.
        """
        assert a.shape == self.shape_a and b.shape == self.shape_b, \
            f"plan is for {self.shape_a}x{self.shape_b}, " \
            f"got {a.shape}x{b.shape}"
        assert a.block == self.block_a and b.block == self.block_b, \
            f"plan is for blocks {self.block_a}x{self.block_b}, " \
            f"got {a.block}x{b.block}"
        assert a.bcap == self.bcap_a and b.bcap == self.bcap_b, \
            "operand block capacities differ from the planned structure"
        for op, planned in ((a, self.nnzb_a), (b, self.nnzb_b)):
            if not isinstance(op.nnzb, jax.core.Tracer):
                assert int(op.nnzb) == planned, \
                    "operand block nnz differs from the planned structure " \
                    "(replan or clear_plan_cache)"

    def execute(self, a: BCSR, b: BCSR) -> BCSR:
        """Numeric phase only: the register-tiled MXU kernel with this
        plan's frozen schedule -- zero re-inspection (counter-verified by
        ``KERNEL_CALLS["symbolic"]``).  Block rows of C are unsorted (C8).
        """
        self.check_structure(a, b)
        from repro.kernels.spgemm_bcsr import ops as bcsr_ops
        return bcsr_ops.spgemm_bcsr(
            a, b, self.bcap_c, vector=self.vector,
            table_size=self.table_size,
            schedule=(self.offsets, self.bin_tsize),
            indptr_cb=self.indptr_cb)

    __call__ = execute


def plan_bcsr(a: BCSR, b: BCSR, *, n_bins: int = 8, vector: bool = False,
              cache: bool = True) -> BCSRPlan:
    """Run the block-granularity inspection once, freeze a :class:`BCSRPlan`.

    With ``cache=True`` (default) the shared plan LRU is consulted first
    under the ``"bcsr"`` kind: a block-structure-identical repeat request
    returns the existing plan and skips schedule + symbolic entirely.
    """
    bm, bk = a.block
    bk2, bn = b.block
    assert bk == bk2 and a.shape[1] == b.shape[0], \
        f"block-inner mismatch: {a.shape}x{a.block} @ {b.shape}x{b.block}"
    key = ("bcsr", bcsr_structure_key(a), bcsr_structure_key(b), n_bins,
           vector)
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit

    from repro.kernels.spgemm_bcsr import ops as bcsr_ops
    # Fig. 6/7 at block granularity; eager so the int32 flop-overflow
    # guard fires loudly on concrete inputs instead of mis-binning.
    flop, offsets, bin_tsize, table_size, row_nnzb, indptr_cb = \
        bcsr_ops.bcsr_inspect(a, b, n_bins=n_bins, vector=vector,
                              eager=True)
    nnzb_c = int(jnp.sum(row_nnzb))
    plan = BCSRPlan(
        key=key, block_a=a.block, block_b=b.block, shape_a=a.shape,
        shape_b=b.shape, bcap_a=a.bcap, bcap_b=b.bcap, nnzb_a=int(a.nnzb),
        nnzb_b=int(b.nnzb), n_bins=n_bins, vector=vector, flop=flop,
        total_flop=int(jnp.sum(flop)), offsets=offsets, bin_tsize=bin_tsize,
        table_size=table_size, row_nnzb_c=row_nnzb, indptr_cb=indptr_cb,
        nnzb_c=nnzb_c, bcap_c=max(nnzb_c, 1))
    if cache:
        cache_store(key, plan)
    return plan
