"""Plan-composed SpGEMM chains (DESIGN.md section 12).

The paper's real workloads are not single products but *chains*: squaring
A.A for triangle counting, the Gram product A^T.A, and Galerkin-style
triple products R.A.P in multigrid / graph-coarsening pipelines.  DBCSR's
CP2K driver iterates products in sign-matrix chains (arXiv:1708.03604) and
KokkosKernels motivates its symbolic/numeric split precisely so repeated
same-structure multiplies amortize inspection (arXiv:1801.03065) -- which
is what our single-product planner (``core.plan``) does for one product
and this module does for whole chains.

:func:`plan_chain` runs symbolic inspection left-to-right **once**: stage
``k`` is a full :func:`repro.core.plan.plan_spgemm` inspection whose
A-operand is the materialized intermediate of stage ``k-1`` (the
materialization *is* the inspection -- intermediate structure is a
deterministic function of operand structures).  Every stage's frozen
capacities, per-bin table sizes, and recorded algorithm ride in one cached
:class:`ChainPlan` under the same blake2b-keyed LRU as single products.

``chain.execute(...)`` then runs numeric-only end to end and keeps
intermediates **unsorted** between stages (sorting only the final output,
on request): the hash family's select-order output feeds the next stage
directly, so the paper's C8 unsorted-output win applies at every internal
hop, not just the last (``finalize`` is the single sort site).  Mid-chain
algorithm choice is exact: stage ``k``'s recipe receives the previous
stage's recorded ``row_nnz_c`` (``recommend(a_row_nnz=...)``) because an
intermediate's compression factor and skew differ from the user matrices
that produced it.

On top of the chain plan ride the chain-shaped workloads:

  * :func:`galerkin` -- the AMG / graph-coarsening triple product R.A.P;
  * :func:`gram` -- A^T.A via a transpose-aware :class:`GramPlan` that
    freezes the transpose *structure* (gather permutation) so repeat
    executes re-gather values only;
  * :func:`plan_power` -- A^k chains (triangle counting, MCL expansion;
    see ``examples/mcl.py`` for the full Markov-clustering loop);
  * :func:`plan_chain_1d` -- the same composition over row-sharded
    operands on a device mesh (``core.distributed``), where every stage is
    a frozen :class:`repro.core.distributed.DistributedPlan` and the
    intermediate stays sharded (and unsorted) between stages.

Every stage dispatches through ``SpGEMMPlan.execute``, so a stage whose
recipe picked the hash family runs the real Pallas kernel -- including
inside the distributed chain's ``shard_map`` bodies, where the stage's
frozen schedules ride as sharded array operands (DESIGN.md section 14).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from .formats import CSR, csr_transpose
from .plan import (SpGEMMPlan, cache_lookup, cache_store, plan_spgemm,
                   structure_key)
from .semiring import Semiring, resolve_semiring


def _check_chain_shapes(mats: Sequence[CSR], mask: Optional[CSR]) -> None:
    assert len(mats) >= 2, "a chain needs at least two operands"
    for k in range(len(mats) - 1):
        assert mats[k].n_cols == mats[k + 1].n_rows, \
            f"chain stage {k}: {mats[k].shape} @ {mats[k + 1].shape} " \
            f"shapes do not compose"
    if mask is not None:
        out_shape = (mats[0].n_rows, mats[-1].n_cols)
        assert mask.shape == out_shape, \
            f"mask shape {mask.shape} != chain output shape {out_shape}"


def _concrete_nnz(op: CSR) -> Optional[int]:
    return None if isinstance(op.nnz, jax.core.Tracer) else int(op.nnz)


# ----------------------------------------------------------------------------
# ChainPlan: composed single-node plans
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class ChainPlan:
    """Frozen inspection of a whole product chain ``mats[0] @ ... @ mats[-1]``.

    ``stages[k]`` is the :class:`SpGEMMPlan` for product ``k``; its
    A-operand structure for ``k >= 1`` is the intermediate materialized at
    plan time, which :meth:`execute` reproduces exactly (structure is a
    deterministic function of structure).  Intermediates stay unsorted
    unless ``sort_intermediates`` was set; the final output's sortedness
    is the plan's ``sorted_output``, overridable per call.
    """
    key: tuple = dataclasses.field(repr=False)
    stages: Tuple[SpGEMMPlan, ...] = dataclasses.field(repr=False)
    semiring: str
    complement_mask: bool
    sorted_output: bool
    sort_intermediates: bool
    shapes: Tuple[Tuple[int, int], ...]   # operand shapes, left to right
    caps: Tuple[int, ...]
    nnzs: Tuple[int, ...]
    nnz_c: int                            # exact nnz of the final output
    total_flop: int                       # summed over every stage

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def algorithms(self) -> Tuple[str, ...]:
        """Per-stage recorded algorithm choices (recipe-resolved)."""
        return tuple(p.algorithm for p in self.stages)

    def check_structure(self, mats: Sequence[CSR]) -> None:
        """Cheap shapes/caps/nnz check of every operand against the plan."""
        assert len(mats) == len(self.shapes), \
            f"plan composes {len(self.shapes)} operands, got {len(mats)}"
        for k, op in enumerate(mats):
            assert op.shape == self.shapes[k] and op.cap == self.caps[k], \
                f"chain operand {k}: planned {self.shapes[k]}/cap " \
                f"{self.caps[k]}, got {op.shape}/cap {op.cap}"
            nnz = _concrete_nnz(op)
            if nnz is not None:
                assert nnz == self.nnzs[k], \
                    f"chain operand {k} nnz differs from the planned " \
                    f"structure (replan or clear_plan_cache)"

    def execute(self, *mats: CSR,
                sorted_output: Optional[bool] = None) -> CSR:
        """Numeric phase only, end to end: zero re-inspection.

        Accepts the operands positionally or as one sequence.  Each
        internal hop executes with the planned intermediate sortedness
        (unsorted by default -- the C8 win at every hop); only the final
        stage pays the sort epilogue, and only when ``sorted_output``
        (argument, else the plan's recorded flag) asks for it.
        """
        if len(mats) == 1 and not isinstance(mats[0], CSR):
            mats = tuple(mats[0])
        self.check_structure(mats)
        so = self.sorted_output if sorted_output is None else sorted_output
        cur = mats[0]
        for k, stage in enumerate(self.stages):
            last = k == len(self.stages) - 1
            cur = stage.execute(cur, mats[k + 1],
                                sorted_output=so if last
                                else self.sort_intermediates)
        return cur

    __call__ = execute


def plan_chain(mats: Sequence[CSR], *,
               algorithm: Union[str, Sequence[str]] = "auto",
               semiring: str | Semiring = "plus_times",
               mask: Optional[CSR] = None, complement_mask: bool = False,
               sorted_output: bool = False, sort_intermediates: bool = False,
               use_case: Optional[str] = None, n_bins: int = 8,
               cache: bool = True, bucket_caps: bool = False) -> ChainPlan:
    """Inspect a product chain left-to-right once; freeze a :class:`ChainPlan`.

    ``mats`` is the operand sequence (>= 2); the chain computes
    ``mats[0] @ mats[1] @ ... @ mats[-1]`` left to right.  ``algorithm``
    is one name applied to every stage or a per-stage sequence of
    ``len(mats) - 1`` names; ``"auto"`` lets each stage's recipe decide --
    with the previous stage's recorded ``row_nnz_c`` as the A-side
    statistics (``recommend(a_row_nnz=...)``), so mid-chain choices key on
    the real intermediate structure.  The ``mask`` (output coordinates of
    the *final* product) and the requested ``sorted_output`` apply to the
    last stage only; intermediates are planned unsorted unless
    ``sort_intermediates`` (the measured-slower control -- kept for
    ``bench_chain.py``'s sorted-vs-unsorted comparison).

    ``bucket_caps`` p2-rounds every stage's static capacities, so chains
    whose structures drift between calls (MCL iterations) share compiled
    numeric programs.  Cached under a ``("chain", ...)`` key in the shared
    plan LRU; stage plans are independently cached too.  Stage 0's plan is
    the same cache entry a manual ``plan_spgemm(mats[0], mats[1])`` with
    matching flags would hit; stages >= 1 carry the ``a_row_nnz`` recipe
    context in their keys, so a manual per-product composition *matches
    them bitwise on execute* (asserted by ``bench_chain.py --smoke``) but
    does not share their cache entries.
    """
    mats = list(mats)
    _check_chain_shapes(mats, mask)
    sr = resolve_semiring(semiring)
    n_stages = len(mats) - 1
    algos = tuple(algorithm) if not isinstance(algorithm, str) \
        else (algorithm,) * n_stages
    assert len(algos) == n_stages, \
        f"algorithm must be one name or {n_stages} per-stage names"
    key = ("chain", tuple(structure_key(m) for m in mats),
           None if mask is None else structure_key(mask), sr.name,
           complement_mask, sorted_output, sort_intermediates, algos,
           use_case, n_bins, bucket_caps)
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit

    stages = []
    cur = mats[0]
    prev: Optional[SpGEMMPlan] = None
    for k in range(n_stages):
        last = k == n_stages - 1
        stage = plan_spgemm(
            cur, mats[k + 1], algorithm=algos[k], semiring=sr.name,
            mask=mask if last else None,
            complement_mask=complement_mask if last else False,
            sorted_output=sorted_output if last else sort_intermediates,
            use_case=use_case, n_bins=n_bins, cache=cache,
            bucket_caps=bucket_caps,
            a_row_nnz=None if prev is None else prev.row_nnz_c)
        stages.append(stage)
        if not last:
            # materialize the intermediate: this *is* the inspection of
            # stage k+1's A-operand (values ride along but only the
            # structure is consumed; execute reproduces it exactly)
            cur = stage.execute(cur, mats[k + 1])
        prev = stage

    plan = ChainPlan(
        key=key, stages=tuple(stages), semiring=sr.name,
        complement_mask=complement_mask, sorted_output=sorted_output,
        sort_intermediates=sort_intermediates,
        shapes=tuple(m.shape for m in mats),
        caps=tuple(m.cap for m in mats),
        nnzs=tuple(int(m.nnz) for m in mats),
        nnz_c=stages[-1].nnz_c,
        total_flop=sum(p.total_flop for p in stages))
    if cache:
        cache_store(key, plan)
    return plan


# ----------------------------------------------------------------------------
# Chain-shaped workloads: Galerkin triple product, A^k powers
# ----------------------------------------------------------------------------

def plan_galerkin(r: CSR, a: CSR, p: CSR, **kw) -> ChainPlan:
    """Plan the Galerkin triple product ``R @ A @ P`` (AMG / coarsening).

    The multigrid restriction of a fine-grid operator A onto the coarse
    space spanned by P (with R typically P^T, see
    :func:`repro.core.formats.csr_transpose`): the intermediate R.A is
    consumed directly -- unsorted -- by the P product.  Keyword arguments
    are :func:`plan_chain`'s.
    """
    return plan_chain([r, a, p], **kw)


def galerkin(r: CSR, a: CSR, p: CSR, *, sorted_output: bool = False,
             **kw) -> CSR:
    """One-shot planned ``R @ A @ P``.

    Plans (or pulls from the shared cache -- repeat calls on the same
    structures, e.g. re-weighted fine operators under a fixed hierarchy,
    run numeric-only) and executes.  See :func:`plan_galerkin` for the
    planning knobs.
    """
    plan = plan_galerkin(r, a, p, sorted_output=sorted_output, **kw)
    return plan.execute(r, a, p)


def plan_power(a: CSR, k: int, **kw) -> ChainPlan:
    """Plan ``A^k`` (k >= 2) as a left-to-right chain of k-1 products.

    The triangle-counting / MCL-expansion shape: every stage shares A's
    structure key, so the stage-0 plan is one cached inspection and each
    further stage inspects only its (new-structure) intermediate.
    """
    assert k >= 2, "plan_power needs k >= 2 (k == 1 is the identity plan)"
    return plan_chain([a] * k, **kw)


# ----------------------------------------------------------------------------
# Batched powers: A_i^k over a fleet of subgraphs (core.batch x core.chain)
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class BatchedPowerPlan:
    """Frozen ``[A_i^k for i in fleet]``: one :class:`repro.core.batch.
    BatchedPlan` per chain stage, intermediates unsorted between stages.

    The MCL-over-many-subgraphs shape: stage ``j`` multiplies the fleet's
    (not-yet-sorted) intermediates by the original operands in one batched
    program per capacity class, so drifting per-subgraph structures share
    compiled programs along *both* axes -- across the fleet (p2 capacity
    classes) and across stages (the batch planner's built-in p2 rounding,
    the same program-sharing ``bucket_caps=True`` buys single products).
    """
    key: tuple = dataclasses.field(repr=False)
    stages: Tuple = dataclasses.field(repr=False)    # BatchedPlans
    semiring: str
    sorted_output: bool
    n_products: int
    shapes: Tuple[Tuple[int, int], ...]
    nnz_cs: Tuple[int, ...]        # final stage, per product

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def n_classes(self) -> int:
        """Compiled numeric programs across the whole plan."""
        return sum(p.n_classes for p in self.stages)

    def execute(self, mats: Sequence[CSR],
                sorted_output: Optional[bool] = None) -> list:
        """Numeric phase only, fleet x stages; returns per-product CSRs.

        Intermediates stay unsorted between stages (C8 at every hop, per
        batch element); only the final stage pays the sort epilogue, and
        only when asked.
        """
        mats = list(mats)
        assert len(mats) == self.n_products, \
            f"plan is for {self.n_products} products, got {len(mats)}"
        so = self.sorted_output if sorted_output is None else sorted_output
        cur = mats
        for j, stage in enumerate(self.stages):
            last = j == len(self.stages) - 1
            cur = stage.execute(list(zip(cur, mats)),
                                sorted_output=so if last else False)
        return cur

    __call__ = execute


def plan_batch_power(mats: Sequence[CSR], k: int, *,
                     algorithm: str = "auto",
                     semiring: str | Semiring = "plus_times",
                     sorted_output: bool = False,
                     cache: bool = True) -> BatchedPowerPlan:
    """Inspect ``[A_i^k for i in fleet]`` once; freeze the staged batch.

    Stage ``j``'s fleet pairs the stage ``j-1`` intermediates (materialized
    at plan time, exactly like :func:`plan_chain`) with the original
    operands; every stage is a :func:`repro.core.batch.plan_batch` whose
    p2 capacity classes are shared through the plan LRU, so MCL-style
    iterations whose subgraph structures drift re-plan only the members
    whose flop bucket actually moved.  Cached under ``("batch_power",
    ...)`` in the shared LRU.
    """
    from .batch import plan_batch
    mats = list(mats)
    assert mats, "a batched power needs at least one operand"
    assert k >= 2, "plan_batch_power needs k >= 2"
    for m in mats:
        assert m.n_rows == m.n_cols, \
            f"powers need square operands; got {m.shape}"
    sr = resolve_semiring(semiring)
    key = ("batch_power", tuple(structure_key(m) for m in mats), k,
           sr.name, sorted_output, algorithm)
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit

    stages = []
    cur = mats
    for j in range(k - 1):
        last = j == k - 2
        stage = plan_batch(list(zip(cur, mats)), algorithm=algorithm,
                           semiring=sr.name,
                           sorted_output=sorted_output if last else False,
                           cache=cache)
        stages.append(stage)
        if not last:
            cur = stage.execute(list(zip(cur, mats)))

    plan = BatchedPowerPlan(
        key=key, stages=tuple(stages), semiring=sr.name,
        sorted_output=sorted_output, n_products=len(mats),
        shapes=tuple(m.shape for m in mats), nnz_cs=stages[-1].nnz_cs)
    if cache:
        cache_store(key, plan)
    return plan


# ----------------------------------------------------------------------------
# Gram product: A^T A via a transpose-aware plan
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class GramPlan:
    """Frozen ``A^T @ A`` recipe: transpose structure + product plan.

    The transpose's *structure* -- its indptr/indices and the entry-gather
    permutation ``t_perm`` with ``A^T.data == A.data[t_perm]`` -- is
    computed once on the host and frozen with zeroed data (values stay out
    of plans, like every other plan kind), so :meth:`execute` rebuilds
    A^T with one device gather and runs the planned product: a re-weighted
    A reuses everything.
    """
    key: tuple = dataclasses.field(repr=False)
    product: SpGEMMPlan = dataclasses.field(repr=False)
    t_struct: CSR = dataclasses.field(repr=False)     # data zeroed
    t_perm: jax.Array = dataclasses.field(repr=False)
    shape_a: Tuple[int, int]
    cap_a: int
    nnz_a: int

    @property
    def nnz_c(self) -> int:
        return self.product.nnz_c

    @property
    def algorithm(self) -> str:
        return self.product.algorithm

    def check_structure(self, a: CSR) -> None:
        assert a.shape == self.shape_a and a.cap == self.cap_a, \
            f"plan is for {self.shape_a}/cap {self.cap_a}, " \
            f"got {a.shape}/cap {a.cap}"
        nnz = _concrete_nnz(a)
        if nnz is not None:
            assert nnz == self.nnz_a, \
                "operand nnz differs from the planned structure"

    def execute(self, a: CSR, sorted_output: Optional[bool] = None) -> CSR:
        """Numeric phase only: gather A's values through the frozen
        transpose permutation, then run the planned ``A^T @ A``."""
        self.check_structure(a)
        live = jnp.arange(self.t_struct.cap,
                          dtype=jnp.int32) < self.t_struct.nnz
        vals = jnp.where(live, a.data[self.t_perm], 0).astype(a.dtype)
        t = dataclasses.replace(self.t_struct, data=vals)
        return self.product.execute(t, a, sorted_output=sorted_output)

    __call__ = execute


def plan_gram(a: CSR, *, algorithm: str = "auto",
              semiring: str | Semiring = "plus_times",
              sorted_output: bool = False, n_bins: int = 8,
              cache: bool = True, bucket_caps: bool = False) -> GramPlan:
    """Inspect ``A^T @ A`` once -- transpose included -- and freeze it.

    The host-side transpose (:func:`repro.core.formats.csr_transpose`)
    runs at plan time only; its gather permutation is part of the frozen
    structure.  Cached under a ``("gram", ...)`` key in the shared LRU.
    """
    sr = resolve_semiring(semiring)
    key = ("gram", structure_key(a), sr.name, sorted_output, algorithm,
           n_bins, bucket_caps)
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit
    t, perm = csr_transpose(a, return_perm=True)
    product = plan_spgemm(t, a, algorithm=algorithm, semiring=sr.name,
                          sorted_output=sorted_output, n_bins=n_bins,
                          cache=cache, bucket_caps=bucket_caps)
    plan = GramPlan(
        key=key, product=product,
        t_struct=dataclasses.replace(t, data=jnp.zeros_like(t.data)),
        t_perm=perm, shape_a=a.shape, cap_a=a.cap, nnz_a=int(a.nnz))
    if cache:
        cache_store(key, plan)
    return plan


def gram(a: CSR, *, sorted_output: bool = False, **kw) -> CSR:
    """One-shot planned ``A^T @ A`` (cached; repeat calls on the same
    structure -- e.g. re-weighted design matrices -- run numeric-only)."""
    return plan_gram(a, sorted_output=sorted_output, **kw).execute(a)


# ----------------------------------------------------------------------------
# Distributed chains: ChainPlan over spgemm_1d shards
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class DistributedChainPlan:
    """A chain whose every stage is a frozen 1D distributed product.

    Stage ``k`` is a :class:`repro.core.distributed.DistributedPlan`
    multiplying the row-sharded intermediate by the replicated operand
    ``rest[k]``; the row partition is invariant down the chain (a 1D
    product's output inherits its A-operand's partition), so the
    intermediate never crosses chips and stays unsorted between stages,
    exactly like the single-node chain.
    """
    key: tuple = dataclasses.field(repr=False)
    stages: Tuple = dataclasses.field(repr=False)   # DistributedPlans
    semiring: str
    sorted_output: bool
    sort_intermediates: bool
    row_starts: Tuple[int, ...]
    shapes: Tuple[Tuple[int, int], ...]   # a_sh.shape, then rest shapes
    nnz_c: int

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def algorithms(self) -> Tuple[str, ...]:
        return tuple(p.algorithm for p in self.stages)

    def execute(self, mesh, a_sh, *rest, axis: str = "data",
                sorted_output: Optional[bool] = None):
        """Numeric phase only on the mesh; returns the row-sharded result
        (``repro.core.distributed.unshard_rows`` assembles it)."""
        if len(rest) == 1 and not isinstance(rest[0], CSR):
            rest = tuple(rest[0])
        assert len(rest) == len(self.stages), \
            f"plan composes {len(self.stages)} products, got {len(rest)} " \
            f"replicated operands"
        so = self.sorted_output if sorted_output is None else sorted_output
        cur = a_sh
        for k, stage in enumerate(self.stages):
            last = k == len(self.stages) - 1
            cur = stage.execute(mesh, cur, rest[k], axis=axis,
                                sorted_output=so if last
                                else self.sort_intermediates)
        return cur

    __call__ = execute


def plan_chain_1d(a_sh, rest: Sequence[CSR], *, algorithm: str = "auto",
                  semiring: str | Semiring = "plus_times",
                  mask=None, complement_mask: bool = False,
                  sorted_output: bool = False,
                  sort_intermediates: bool = False, n_bins: int = 8,
                  cache: bool = True) -> DistributedChainPlan:
    """Inspect a distributed chain once: ``a_sh @ rest[0] @ ... @ rest[-1]``.

    ``a_sh`` is a row-sharded :class:`repro.core.distributed.ShardedCSR`;
    every ``rest`` operand is replicated (the ``spgemm_1d`` contract).
    Stage ``k+1``'s sharded A-structure is materialized at plan time with
    the mesh-free executor twin
    (:meth:`repro.core.distributed.DistributedPlan.execute_shards_host`),
    so planning needs no mesh -- only :meth:`DistributedChainPlan.execute`
    does.  The ``mask`` (global output coordinates, co-sharded with the
    row partition) applies to the final stage only.  Cached under a
    ``("chain_1d", ...)`` key in the shared LRU.
    """
    from .distributed import plan_spgemm_1d, sharded_structure_key
    rest = list(rest)
    assert rest, "a distributed chain needs at least one replicated operand"
    sr = resolve_semiring(semiring)
    key = ("chain_1d", sharded_structure_key(a_sh),
           tuple(structure_key(b) for b in rest),
           None if mask is None else
           (sharded_structure_key(mask) if hasattr(mask, "row_starts")
            else structure_key(mask)),
           sr.name, complement_mask, sorted_output, sort_intermediates,
           algorithm, n_bins)
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit

    stages = []
    cur = a_sh
    for k, b in enumerate(rest):
        last = k == len(rest) - 1
        stage = plan_spgemm_1d(
            cur, b, algorithm=algorithm, semiring=sr.name,
            mask=mask if last else None,
            complement_mask=complement_mask if last else False,
            sorted_output=sorted_output if last else sort_intermediates,
            n_bins=n_bins, cache=cache)
        stages.append(stage)
        if not last:
            cur = stage.execute_shards_host(cur, b)

    plan = DistributedChainPlan(
        key=key, stages=tuple(stages), semiring=sr.name,
        sorted_output=sorted_output, sort_intermediates=sort_intermediates,
        row_starts=a_sh.row_starts,
        shapes=(a_sh.shape,) + tuple(b.shape for b in rest),
        nnz_c=stages[-1].nnz_c)
    if cache:
        cache_store(key, plan)
    return plan
