"""Distributed SpGEMM/SpMM over a device mesh (beyond-paper scale-out).

The paper is single-node; these routines lift its row-wise formulation onto a
TPU mesh.  The load-balance contribution (C1) is reused at mesh scale: rows
are assigned to chips by the same equal-flop prefix-sum partition, except the
partition must be computed *host-side* (mesh layout is static), so we balance
on nnz(A) rows as the flop proxy and let the per-chip Pallas grid rebalance
exactly (two-level balancing, mirroring the paper's thread/core split).

Algorithms:
  * ``spgemm_1d``: A row-partitioned over the flattened mesh axis, B
    replicated/all-gathered in K panels -> C row-partitioned.  This is the
    communication pattern of distributed Gustavson (A stays put, B streams).
  * ``spmm_1d``: CSR x dense tall-skinny (BFS/betweenness use case) -- B is
    all-gathered once (it is skinny: k << n).
  * ``spgemm_summa``: 2D SUMMA-style over ("data", "model"): A block-rows x
    B block-cols, with B panels broadcast along "data" and partial C
    reduced along "model".  Used by the dry-run to prove the collective
    schedule at 256/512 chips.

Local per-shard products use the ESC engine (static caps per shard); on real
TPUs the Pallas BCSR kernel slots in via the same local_spgemm hook.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .formats import CSR
from .spgemm import spgemm_esc, spmm


def shard_csr_rows(a: CSR, n_shards: int) -> CSR:
    """Re-lay a CSR as n_shards equal-row local CSRs, stacked on axis 0.

    Returns a CSR whose arrays have a leading shard dim:
      indptr (S, m/S + 1), indices (S, cap/S), data (S, cap/S), nnz (S,)
    Capacity is distributed evenly; rows are contiguous blocks (static
    partition -- the dynamic equal-flop split happens *inside* each shard's
    local schedule, see module docstring).
    """
    m = a.n_rows
    assert m % n_shards == 0, (m, n_shards)
    rows_per = m // n_shards
    dense = a.to_dense()             # host/test-scale path
    # Static per-shard capacity must cover the *max* shard (skewed inputs
    # like G500 concentrate nnz in few rows -- the very imbalance C1 exists
    # for); pad to a lane multiple.
    import numpy as _np
    counts = [int((_np.asarray(dense[i * rows_per:(i + 1) * rows_per]) != 0)
                  .sum()) for i in range(n_shards)]
    cap_per = -(-max(max(counts), 1) // 8) * 8
    parts = [CSR.from_dense(dense[i * rows_per:(i + 1) * rows_per, :], cap_per)
             for i in range(n_shards)]
    stack = lambda *xs: jnp.stack(xs)
    return jax.tree.map(stack, *parts)


@partial(jax.jit, static_argnames=("mesh", "axis", "cap_c", "flop_cap"))
def spgemm_1d(mesh: Mesh, a_sharded: CSR, b: CSR, cap_c: int,
              flop_cap: int, axis: str = "data") -> CSR:
    """Row-partitioned SpGEMM: local rows of A x replicated B.

    ``a_sharded`` comes from :func:`shard_csr_rows` (leading shard dim
    sharded over ``axis``); B is replicated (or broadcast by GSPMD).  Output
    is a stacked CSR, row-partitioned the same way.
    """
    def local(a_loc: CSR, b_rep: CSR) -> CSR:
        a_loc = jax.tree.map(lambda x: x[0], a_loc)   # drop unit shard dim
        c = spgemm_esc(a_loc, b_rep, cap_c=cap_c, flop_cap=flop_cap)
        return jax.tree.map(lambda x: x[None], c)

    spec_a = jax.tree.map(lambda _: P(axis), a_sharded,
                          is_leaf=lambda x: isinstance(x, jax.Array))
    spec_b = jax.tree.map(lambda _: P(), b,
                          is_leaf=lambda x: isinstance(x, jax.Array))
    fn = shard_map(local, mesh=mesh, in_specs=(spec_a, spec_b),
                   out_specs=spec_a, check_rep=False)
    return fn(a_sharded, b)


@partial(jax.jit, static_argnames=("mesh", "axis"))
def spmm_1d(mesh: Mesh, a_sharded: CSR, x: jax.Array,
            axis: str = "data") -> jax.Array:
    """Row-partitioned SpMM (square x tall-skinny): y = A @ X.

    X (n, k) is replicated (skinny); output (m, k) row-partitioned.
    """
    def local(a_loc: CSR, x_rep: jax.Array) -> jax.Array:
        a_loc = jax.tree.map(lambda v: v[0], a_loc)
        return spmm(a_loc, x_rep)[None]

    spec_a = jax.tree.map(lambda _: P(axis), a_sharded,
                          is_leaf=lambda v: isinstance(v, jax.Array))
    fn = shard_map(local, mesh=mesh, in_specs=(spec_a, P()),
                   out_specs=P(axis), check_rep=False)
    return fn(a_sharded, x)


def spgemm_summa(mesh: Mesh, a_dense: jax.Array, b_dense: jax.Array,
                 row_axis: str = "data", col_axis: str = "model",
                 k_panels: int | None = None) -> jax.Array:
    """2D SUMMA product with sparse-aware panels, dense I/O (dry-run proof).

    A is (m, n) sharded (row_axis, col_axis); B is (n, k) sharded
    (row_axis=cols of A!, col_axis); C is (m, k) sharded (row_axis,
    col_axis).  Every step broadcasts one K-panel of A along col_axis and
    one of B along row_axis, accumulating local partial products -- the
    classic SUMMA schedule the roofline's collective term measures.

    GSPMD formulation: we express the product as a sharded einsum with
    explicit sharding constraints; XLA emits the all-gather/reduce-scatter
    schedule which `analysis.hlo_collectives` then audits.
    """
    del k_panels
    a_dense = jax.lax.with_sharding_constraint(
        a_dense, jax.sharding.NamedSharding(mesh, P(row_axis, col_axis)))
    b_dense = jax.lax.with_sharding_constraint(
        b_dense, jax.sharding.NamedSharding(mesh, P(col_axis, None)))
    c = a_dense @ b_dense
    return jax.lax.with_sharding_constraint(
        c, jax.sharding.NamedSharding(mesh, P(row_axis, col_axis)))


def multi_source_bfs(mesh: Mesh, a_sharded: CSR, sources: jax.Array,
                     n: int, n_iters: int, axis: str = "data") -> jax.Array:
    """Multi-source BFS via repeated SpMM (paper section 5.5 use case).

    ``sources`` (k,) vertex ids; returns (n, k) hop-distance matrix (-1 =
    unreached).  Frontier is the dense tall-skinny matrix; one SpMM per hop.
    """
    k = sources.shape[0]
    frontier = jnp.zeros((n, k), jnp.float32).at[sources,
                                                 jnp.arange(k)].set(1.0)
    dist = jnp.where(frontier > 0, 0, -1).astype(jnp.int32)

    def body(i, state):
        frontier, dist = state
        nxt = spmm_1d(mesh, a_sharded, frontier, axis=axis)
        nxt = jnp.reshape(nxt, (n, k))
        new = (nxt > 0) & (dist < 0)
        dist = jnp.where(new, i + 1, dist)
        return new.astype(jnp.float32), dist

    _, dist = jax.lax.fori_loop(0, n_iters, body, (frontier, dist))
    return dist
