"""Plan-aware distributed SpGEMM/SpMM over a device mesh (DESIGN.md §11).

The paper's two-level load-balance story (equal-flop partition across
threads, then per-thread hash/heap kernels) is lifted one level further:
rows are assigned to *chips* by the same equal-flop prefix-sum partition
(``schedule.equal_weight_partition``, the int64 host twin of
``rows_to_bins``), and each chip's local product is a planned single-node
SpGEMM -- three-level balancing, mirroring the inspector-executor split
that distributed SpGEMM work (Gu et al., arXiv:2002.11302; the DBCSR port,
arXiv:1708.03604) applies across nodes.

Algorithms:
  * ``spgemm_1d``: A row-partitioned over a mesh axis, B replicated -> C
    row-partitioned (distributed Gustavson: A stays put).  Takes
    ``algorithm=``/``semiring=``/``mask=`` like the single-node dispatcher,
    or a frozen :class:`DistributedPlan` (``plan_spgemm_1d``).
  * ``spmm_1d``: CSR x dense tall-skinny; returns the assembled global
    ``(m, k)`` product (rectangular-safe -- no square assumption).
  * ``spgemm_summa``: outer-product SUMMA over one mesh axis: K is split
    into ``k_panels`` panels; chip ``s`` owns the A column-blocks and B
    row-blocks of its panels, streams them through planned local products,
    and the partial C's are merged with a reduce-scatter
    (``jax.lax.psum_scatter``) that leaves C row-partitioned.

Everything host-side here is **sparse-native**: sharding slices the CSR
arrays directly (never ``to_dense``).  The only dense intermediate in the
whole subsystem is SUMMA's partial-C accumulator, which is what the
reduce-scatter merge sums (its elementwise ``+`` must be the semiring's
``add`` with identity 0 -- hence the ``min_plus`` rejection below).

Local products dispatch through :func:`repro.core.spgemm.spgemm`.  The
*planned* hash family runs the real Pallas kernel inside ``shard_map``:
``plan_spgemm_1d`` / ``plan_spgemm_summa`` freeze each shard's (or
panel's) schedule -- bin offsets, per-bin table sizes, ``indptr_c`` -- as
stacked arrays threaded through the executor with ``P(axis)`` specs, so
every dynamic value arrives as a traced array while the scratch table
stays a static per-plan maximum.  Only the *planless* traced path
(``spgemm_1d`` without a plan, general semirings, masks) still
substitutes ``hash_jnp``, which keeps the identical contract (two-phase
capacity, unsorted select output) and doubles as the reference oracle in
the differential tests.
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .formats import CSR
from .plan import SpGEMMPlan, plan_spgemm, structure_key, cache_lookup, \
    cache_store
from .schedule import equal_weight_partition, flops_per_row
from .semiring import Semiring, resolve_semiring
from .spgemm import spgemm, spmm


def _pad8(x: int) -> int:
    """Static capacities padded to a lane multiple (like shard_csr_rows)."""
    return -(-max(int(x), 1) // 8) * 8


# ----------------------------------------------------------------------------
# Row-sharded CSR (the distributed operand/result currency)
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardedCSR:
    """Row-partitioned CSR: ``parts`` arrays carry a leading shard dim.

    ``parts`` is a CSR whose every array leaf is stacked ``(S, ...)``; its
    static ``shape`` is the *local* ``(rows_cap, n_cols)`` where
    ``rows_cap`` is the max shard height (equal-flop partitions produce
    unequal row counts; short shards are padded with trailing empty rows so
    the one SPMD program covers every shard).  ``row_starts`` records the
    global partition; ``n_rows_global`` the unpadded global row count.
    """
    parts: CSR
    row_starts: Tuple[int, ...] = dataclasses.field(
        metadata=dict(static=True))
    n_rows_global: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_shards(self) -> int:
        return len(self.row_starts) - 1

    @property
    def rows_cap(self) -> int:
        return self.parts.n_rows

    @property
    def cap_per(self) -> int:
        """Per-shard entry capacity (``parts.cap`` would read the shard
        count off the stacked leading dim)."""
        return self.parts.indices.shape[-1]

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_rows_global, self.parts.n_cols)

    def local(self, s: int) -> CSR:
        """Shard ``s`` as a standalone (padded-height) CSR."""
        return jax.tree.map(lambda x: x[s], self.parts)


jax.tree_util.register_dataclass(
    ShardedCSR, data_fields=["parts"],
    meta_fields=["row_starts", "n_rows_global"])


def shard_csr_rows(a: CSR, n_shards: int, b: CSR | None = None,
                   weights=None, row_starts=None) -> ShardedCSR:
    """Sparse-native row sharding with equal-flop boundaries.

    The partition weight is, in order of preference: explicit ``weights``;
    the planner's per-row flop counts ``flops_per_row(a, b)`` when the
    right-hand operand is known; else nnz per row (the flop proxy).  Shard
    boundaries come from :func:`schedule.equal_weight_partition` -- the
    paper's Fig. 6 prefix-sum split, at mesh scale.  ``row_starts``
    overrides the partition outright (used to co-shard masks/outputs with
    an existing operand).

    Never densifies: shards are direct slices of the CSR arrays (a row
    partition of row-major CSR is contiguous), padded to a uniform
    per-shard capacity (lane multiple of 8) and a uniform row count.
    """
    m = a.n_rows
    if row_starts is None:
        if weights is None:
            weights = flops_per_row(a, b) if b is not None else a.row_nnz()
        w = np.asarray(weights, np.int64)
        assert w.shape == (m,), (w.shape, m)
        row_starts = equal_weight_partition(w, n_shards)
    starts = tuple(int(r) for r in np.asarray(row_starts))
    assert len(starts) == n_shards + 1 and starts[0] == 0 \
        and starts[-1] == m, (starts, m)
    ip = np.asarray(a.indptr, np.int64)
    ind = np.asarray(a.indices)
    dat = np.asarray(a.data)
    spans = [(starts[s], starts[s + 1]) for s in range(n_shards)]
    rows_cap = max(max(r1 - r0 for r0, r1 in spans), 1)
    counts = [int(ip[r1] - ip[r0]) for r0, r1 in spans]
    cap_per = _pad8(max(counts))
    indptr_s = np.zeros((n_shards, rows_cap + 1), np.int32)
    indices_s = np.zeros((n_shards, cap_per), np.int32)
    data_s = np.zeros((n_shards, cap_per), dat.dtype)
    for s, (r0, r1) in enumerate(spans):
        loc = (ip[r0:r1 + 1] - ip[r0]).astype(np.int32)
        indptr_s[s, :r1 - r0 + 1] = loc
        indptr_s[s, r1 - r0 + 1:] = loc[-1]        # trailing empty pad rows
        indices_s[s, :counts[s]] = ind[ip[r0]:ip[r1]]
        data_s[s, :counts[s]] = dat[ip[r0]:ip[r1]]
    parts = CSR(jnp.asarray(indptr_s), jnp.asarray(indices_s),
                jnp.asarray(data_s),
                jnp.asarray(np.asarray(counts, np.int32)),
                (rows_cap, a.n_cols), sorted_cols=a.sorted_cols)
    return ShardedCSR(parts, starts, m)


def reshard_rows(a: CSR, like: ShardedCSR) -> ShardedCSR:
    """Shard ``a`` with an existing partition (masks follow their output)."""
    assert a.n_rows == like.n_rows_global, (a.shape, like.shape)
    return shard_csr_rows(a, like.n_shards, row_starts=like.row_starts)


def unshard_rows(c_sh: ShardedCSR, cap: int | None = None) -> CSR:
    """Assemble a row-sharded result back into one global CSR (host-side,
    sparse concatenation -- within-row entry order, hence sortedness, is
    preserved).

    ``cap`` pins the assembled capacity; pass the original operand's
    ``cap`` to make a shard -> unshard round trip bitwise (same structure
    key, so plan reuse matches the single-node path).  The default keeps
    the sharded operand's slack (``n_shards * cap_per``) rather than
    silently shrinking to ``nnz``, which made every round trip a new
    structure."""
    parts, starts = c_sh.parts, c_sh.row_starts
    ip = np.asarray(parts.indptr)
    ind = np.asarray(parts.indices)
    dat = np.asarray(parts.data)
    row_nnz, idx, vals = [], [], []
    for s in range(c_sh.n_shards):
        local_m = starts[s + 1] - starts[s]
        live = int(ip[s, local_m])
        row_nnz.append(np.diff(ip[s, :local_m + 1]))
        idx.append(ind[s, :live])
        vals.append(dat[s, :live])
    row_nnz = np.concatenate(row_nnz) if row_nnz else np.zeros(0, np.int64)
    idx = np.concatenate(idx)
    vals = np.concatenate(vals)
    nnz = int(idx.size)
    if cap is None:
        cap = max(c_sh.n_shards * c_sh.cap_per, 1)
    assert cap >= nnz, (cap, nnz)
    indices = np.zeros(cap, np.int32)
    data = np.zeros(cap, dat.dtype)
    indices[:nnz] = idx
    data[:nnz] = vals
    indptr = np.zeros(c_sh.n_rows_global + 1, np.int32)
    np.cumsum(row_nnz, out=indptr[1:])
    return CSR(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(data),
               jnp.asarray(nnz, jnp.int32), c_sh.shape,
               sorted_cols=parts.sorted_cols)


# ----------------------------------------------------------------------------
# Local product dispatch (shared by the 1D and SUMMA executors)
# ----------------------------------------------------------------------------

#: shard_map-side algorithm substitutions: ``dense`` is the test oracle --
#: run the ESC engine instead of densifying per shard.  The hash family is
#: NOT substituted anymore: planned executors thread frozen schedules
#: through shard_map and run the real Pallas kernel (``_local_spgemm``
#: falls back to ``hash_jnp`` only when no schedule is available -- the
#: planless traced path, where eager inspection cannot run).
_LOCAL_ALGO = {"dense": "esc"}


def _local_spgemm(a_loc: CSR, b_loc: CSR, mask_loc: Optional[CSR], *,
                  algorithm: str, semiring: str, complement_mask: bool,
                  sorted_output: bool, cap_c: int,
                  flop_cap: Optional[int], row_cap: Optional[int],
                  k_width: Optional[int], table_size: int = 0,
                  hash_sched=None, pb_sched=None) -> CSR:
    """One shard's product, dispatched through the single-node front door.

    ``hash_sched=(offsets, bin_tsize, indptr_c)`` is this shard's frozen
    hash schedule (traced arrays are fine -- that is the point); with it
    the hash family runs the numeric-only Pallas kernel.  Without it a
    hash request inside a trace would need eager inspection, so the
    planless path keeps the documented ``hash_jnp`` substitution.

    ``pb_sched=(src_a, src_b, seg, bucket_nnz, indptr_c, cols_c)`` is the
    shard's frozen propagation-blocking geometry (DESIGN.md section 18);
    with it the PB scatter/merge Pallas pair runs numeric-only.  A
    planless ``pb`` request substitutes ``esc`` -- the same sorted-output
    contract without needing eager inspection inside the trace.
    """
    algo = _LOCAL_ALGO.get(algorithm, algorithm)
    if algo in ("hash", "hash_vector") and hash_sched is None:
        algo = "hash_jnp"
    if algo == "pb":
        if pb_sched is None:
            algo = "esc"
        else:
            from repro.kernels.spgemm_pb import ops as pb_ops
            src_a, src_b, seg, bucket_nnz, indptr_c, cols_c = pb_sched
            return pb_ops.spgemm_pb(
                a_loc, b_loc, cap_c, src_a=src_a, src_b=src_b, seg=seg,
                bucket_nnz=bucket_nnz, indptr_c=indptr_c, cols_c=cols_c)
    kw = {}
    if algo in ("esc", "hash_jnp") and flop_cap is not None:
        kw["flop_cap"] = flop_cap
    if algo in ("hash", "hash_vector"):
        kw["schedule"] = (hash_sched[0], hash_sched[1])
        kw["indptr_c"] = hash_sched[2]
        kw["table_size"] = table_size
    if algo == "heap":
        if row_cap is not None:
            kw["row_cap"] = row_cap
        if k_width is not None:
            kw["k_width"] = k_width
    return spgemm(a_loc, b_loc, cap_c, algorithm=algo, semiring=semiring,
                  mask=mask_loc, complement_mask=complement_mask,
                  sorted_output=sorted_output, **kw)


def _build_1d_fn(mesh: Mesh, axis: str, masked: bool, statics: dict,
                 with_sched: bool = False, with_pb: bool = False):
    """shard_map'd SPMD body for the 1D row-partitioned product.

    With ``with_sched`` the last three operands are the plan's stacked
    hash schedules, row-sharded like A (``P(axis)``): each shard slices
    off its own ``(offsets, bin_tsize, indptr_c)`` and the local product
    runs the Pallas hash kernel on them.  With ``with_pb`` (mutually
    exclusive) the last *six* operands are the stacked propagation-
    blocking geometry ``(src_a, src_b, seg, bucket_nnz, indptr_c,
    cols_c)`` and the local product runs the PB scatter/merge pair.
    """
    assert not (with_sched and with_pb)

    def local(a_parts, b_rep, *rest):
        a_loc = jax.tree.map(lambda x: x[0], a_parts)
        m_loc = (jax.tree.map(lambda x: x[0], rest[0])
                 if masked else None)
        hs = tuple(r[0] for r in rest[-3:]) if with_sched else None
        ps = tuple(r[0] for r in rest[-6:]) if with_pb else None
        c = _local_spgemm(a_loc, b_rep, m_loc, hash_sched=hs, pb_sched=ps,
                          **statics)
        return jax.tree.map(lambda x: x[None], c)

    in_specs = (P(axis), P()) + ((P(axis),) if masked else ()) + \
        ((P(axis), P(axis), P(axis)) if with_sched else ()) + \
        ((P(axis),) * 6 if with_pb else ())
    return shard_map(local, mesh=mesh, in_specs=in_specs,
                     out_specs=P(axis), check_rep=False)


# ----------------------------------------------------------------------------
# DistributedPlan: per-shard SpGEMMPlans frozen under one structure key
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class DistributedPlan:
    """Frozen mesh-scale recipe for one (sharded-A, B) structure pair.

    Holds the shard partition and one :class:`SpGEMMPlan` per shard; the
    executor's static capacities are the per-shard maxima (shard_map runs
    one SPMD program, so capacities must be uniform -- each shard's *exact*
    numbers stay available in ``plans`` for audit).  Cached in the same LRU
    as single-node plans under a ``("dist_1d", digest)`` key.
    """
    key: tuple = dataclasses.field(repr=False)
    row_starts: Tuple[int, ...]
    algorithm: str
    semiring: str
    complement_mask: bool
    sorted_output: bool
    mask_sh: Optional[ShardedCSR] = dataclasses.field(repr=False)
    shape_a: Tuple[int, int]
    shape_b: Tuple[int, int]
    cap_a: int
    cap_b: int
    nnz_b: int
    plans: Tuple[SpGEMMPlan, ...] = dataclasses.field(repr=False)
    cap_c: int
    flop_cap: int
    row_cap: int
    k_width: int
    nnz_c: int
    #: static Pallas scratch allocation: max over shards' natural table
    #: sizes (each shard's per-bin sizes clamp against its own table at
    #: plan time, so the uniform allocation never changes shard results).
    table_size: int = 0
    #: stacked per-shard hash schedules ``(offsets (S, n_bins+1),
    #: bin_tsize (S, n_bins), indptr_c (S, rows_cap+1))``, threaded
    #: through shard_map with ``P(axis)`` specs; ``None`` unless the plan
    #: resolved to the hash family on a plain plus_times product.
    hash_sched: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = \
        dataclasses.field(default=None, repr=False)
    #: stacked per-shard propagation-blocking geometry ``(src_a
    #: (S, nb, bcap), src_b (S, nb, bcap), seg (S, nb, bcap), bucket_nnz
    #: (S, nb), indptr_c (S, rows_cap+1), cols_c (S, cap_c))``, threaded
    #: through shard_map with ``P(axis)`` specs; ``None`` unless the plan
    #: resolved to ``"pb"`` on a plus_times product.  Shards are padded
    #: to uniform bucket count / capacities (pad lanes carry
    #: ``bucket_nnz``-masked zeros, so they are never read).
    pb_sched: Optional[Tuple[jax.Array, ...]] = \
        dataclasses.field(default=None, repr=False)

    def check_structure(self, a_sh: ShardedCSR, b: CSR) -> None:
        assert a_sh.row_starts == self.row_starts, \
            "operand partition differs from the planned shard boundaries"
        assert a_sh.shape == self.shape_a and b.shape == self.shape_b, \
            f"plan is for {self.shape_a}x{self.shape_b}, " \
            f"got {a_sh.shape}x{b.shape}"
        assert a_sh.cap_per == self.cap_a and b.cap == self.cap_b, \
            "operand capacities differ from the planned structure"
        if not isinstance(b.nnz, jax.core.Tracer):
            assert int(b.nnz) == self.nnz_b, \
                "B nnz differs from the planned structure (replan)"

    def _statics(self, sorted_output: Optional[bool]) -> dict:
        so = self.sorted_output if sorted_output is None else sorted_output
        return dict(algorithm=self.algorithm, semiring=self.semiring,
                    complement_mask=self.complement_mask,
                    sorted_output=so, cap_c=self.cap_c,
                    flop_cap=self.flop_cap, row_cap=self.row_cap,
                    k_width=self.k_width, table_size=self.table_size)

    def _executor(self, mesh: Mesh, axis: str,
                  sorted_output: Optional[bool] = None):
        statics = self._statics(sorted_output)
        return _memoized_executor(
            self, (mesh, axis, statics["sorted_output"]),
            lambda: _build_1d_fn(mesh, axis, self.mask_sh is not None,
                                 statics,
                                 with_sched=self.hash_sched is not None,
                                 with_pb=self.pb_sched is not None))

    def execute(self, mesh: Mesh, a_sh: ShardedCSR, b: CSR,
                axis: str = "data",
                sorted_output: Optional[bool] = None) -> ShardedCSR:
        """Numeric phase only: zero re-inspection, uniform static caps.

        ``sorted_output`` overrides the plan's recorded sortedness for
        this call (``None`` keeps it) -- a pure per-shard sort epilogue,
        exactly like :meth:`repro.core.plan.SpGEMMPlan.execute`, so one
        cached distributed plan serves sorted and unsorted consumers (the
        distributed chain keeps intermediates unsorted this way)."""
        self.check_structure(a_sh, b)
        args = (a_sh.parts, b)
        if self.mask_sh is not None:
            args = args + (self.mask_sh.parts,)
        if self.hash_sched is not None:
            args = args + self.hash_sched
        if self.pb_sched is not None:
            args = args + self.pb_sched
        out = self._executor(mesh, axis, sorted_output)(*args)
        return ShardedCSR(out, self.row_starts, self.shape_a[0])

    __call__ = execute

    def execute_shards_host(self, a_sh: ShardedCSR, b: CSR,
                            sorted_output: Optional[bool] = None
                            ) -> ShardedCSR:
        """Mesh-free executor twin: every shard's local product, eagerly.

        Runs the exact ``_local_spgemm`` body the shard_map executor runs
        -- same algorithm substitutions, same uniform static capacities --
        shard by shard on the host's default device, and restacks the
        results.  Structure- and value-identical to :meth:`execute` on a
        mesh (the SPMD body is deterministic given structure), which is
        what lets the chain planner (``core.chain.plan_chain_1d``)
        materialize intermediate *sharded* structure at plan time without
        owning a mesh; also a single-process debugging aid.
        """
        self.check_structure(a_sh, b)
        statics = self._statics(sorted_output)
        outs = []
        for s in range(len(self.row_starts) - 1):
            m_loc = self.mask_sh.local(s) if self.mask_sh is not None \
                else None
            hs = None if self.hash_sched is None else \
                tuple(x[s] for x in self.hash_sched)
            ps = None if self.pb_sched is None else \
                tuple(x[s] for x in self.pb_sched)
            outs.append(_local_spgemm(a_sh.local(s), b, m_loc,
                                      hash_sched=hs, pb_sched=ps,
                                      **statics))
        parts = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return ShardedCSR(parts, self.row_starts, self.shape_a[0])


def _memoized_executor(plan, cache_key, build):
    """Jitted executor cache on a frozen plan dataclass, keyed by whatever
    static context the executor was built for -- (mesh, axis) for SUMMA,
    (mesh, axis, sorted_output) for the 1D plan (shared by both)."""
    cache = plan.__dict__.get("_executors")
    if cache is None:
        cache = {}
        object.__setattr__(plan, "_executors", cache)
    fn = cache.get(cache_key)
    if fn is None:
        fn = jax.jit(build())
        cache[cache_key] = fn
    return fn


def sharded_structure_key(sh: ShardedCSR) -> bytes:
    """Digest of a ShardedCSR's structure (partition + stacked pattern).

    The mesh twin of :func:`repro.core.plan.structure_key`: hashes the
    stacked ``indptr``/``indices``/``nnz`` arrays in one pass and memoizes
    on the (long-lived) instance, so repeat plan-cache lookups cost O(1)
    instead of re-slicing and re-hashing every shard.
    """
    cached = sh.__dict__.get("_structure_digest")
    if cached is not None:
        return cached
    p = sh.parts
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((sh.row_starts, sh.n_rows_global, p.shape,
                   p.indices.shape, p.sorted_cols)).encode())
    h.update(np.asarray(p.indptr).tobytes())
    h.update(np.asarray(p.indices).tobytes())
    h.update(np.asarray(p.nnz).tobytes())
    digest = h.digest()
    object.__setattr__(sh, "_structure_digest", digest)
    return digest


def plan_spgemm_1d(a_sh: ShardedCSR, b: CSR, *, algorithm: str = "auto",
                   semiring: str | Semiring = "plus_times",
                   mask: CSR | ShardedCSR | None = None,
                   complement_mask: bool = False,
                   sorted_output: bool = False, n_bins: int = 8,
                   cache: bool = True) -> DistributedPlan:
    """Inspect every shard once and freeze a :class:`DistributedPlan`.

    ``algorithm="auto"`` is resolved by shard 0's recipe choice and then
    forced on every shard (shard_map is SPMD: one program).  The mask (in
    global output coordinates) is co-sharded with A's row partition.  The
    plan is cached in the shared LRU under one blake2b digest of all shard
    structures + B + mask + partition + semantic fields, so a repeat
    product on the same structures replans nothing.
    """
    sr = resolve_semiring(semiring)
    mask_sh = None
    if mask is not None:
        mask_sh = mask if isinstance(mask, ShardedCSR) \
            else reshard_rows(mask, a_sh)
        assert mask_sh.row_starts == a_sh.row_starts, \
            "mask must be sharded with A's row partition"
    S = a_sh.n_shards
    key = ("dist_1d", sharded_structure_key(a_sh), structure_key(b),
           None if mask_sh is None else sharded_structure_key(mask_sh),
           sr.name, complement_mask, sorted_output, algorithm, n_bins)
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit

    a_locals = [a_sh.local(s) for s in range(S)]
    mask_locals = [mask_sh.local(s) for s in range(S)] if mask_sh else None
    algo = algorithm
    plans = []
    for s in range(S):
        p = plan_spgemm(a_locals[s], b, algorithm=algo, semiring=sr.name,
                        mask=mask_locals[s] if mask_locals else None,
                        complement_mask=complement_mask,
                        sorted_output=sorted_output, n_bins=n_bins,
                        use_case="dist", cache=cache)
        if algo == "auto":
            algo = p.algorithm              # shard 0 resolves; rest uniform
        plans.append(p)

    # Freeze the per-shard hash schedules as stacked arrays: shards are
    # padded to a uniform ``rows_cap`` (flat trailing indptr) and share
    # ``n_bins``, so every shard's (offsets, bin_tsize, indptr_c) is
    # shape-uniform and stacks along the shard axis -- exactly what
    # ``shard_map`` needs to hand each chip its own schedule.  Each
    # shard's bin sizes were clamped against its *own* table at plan
    # time, so the uniform static ``table_size`` (the shard max) is inert
    # and per-shard results stay bitwise the per-shard planned results.
    table_size = 0
    hash_sched = None
    if algo in ("hash", "hash_vector") and mask_sh is None and \
            sr.name == "plus_times":
        table_size = max(p.table_size for p in plans)
        hash_sched = (jnp.stack([p.offsets for p in plans]),
                      jnp.stack([p.bin_tsize for p in plans]),
                      jnp.stack([p.indptr_c for p in plans]))

    # Freeze the per-shard propagation-blocking geometry the same way
    # (DESIGN.md section 18).  PB's bucket layout is per-shard (each
    # shard's flop total picks its own bucket width), so the shards are
    # first re-planned with a forced common bucket count -- every shard
    # sees the same ``n_cols``, so a common count yields one common p2
    # width -- then padded to the max bucket capacity / output capacity.
    # Pad lanes sit beyond ``bucket_nnz`` and are never read by either
    # the Pallas pair or the jnp twin.  Mask pruning happened at plan
    # time (structural), so the masked product still runs the mask-free
    # kernels; general semirings keep ``pb_sched=None`` and the SPMD body
    # substitutes esc.
    cap_c_u = _pad8(max(p.cap_c for p in plans))
    pb_sched = None
    if algo == "pb" and sr.name == "plus_times":
        from .pb import plan_pb
        nb = max(p.pb_plan.n_buckets for p in plans)
        pbs = [plan_pb(a_locals[s], b, semiring=sr.name,
                       mask=mask_locals[s] if mask_locals else None,
                       complement_mask=complement_mask, n_buckets=nb,
                       cache=cache) for s in range(S)]
        assert all(q.n_buckets == pbs[0].n_buckets for q in pbs)
        bcap = max(q.bucket_cap for q in pbs)

        def lanes(x, cap):   # pad trailing lane axis to the shard max
            x = np.asarray(x)
            return np.pad(x, [(0, 0)] * (x.ndim - 1) +
                          [(0, cap - x.shape[-1])])

        pb_sched = (
            jnp.stack([jnp.asarray(lanes(q.src_a, bcap)) for q in pbs]),
            jnp.stack([jnp.asarray(lanes(q.src_b, bcap)) for q in pbs]),
            jnp.stack([jnp.asarray(lanes(q.seg, bcap)) for q in pbs]),
            jnp.stack([q.bucket_nnz for q in pbs]),
            jnp.stack([q.indptr_c for q in pbs]),
            jnp.stack([jnp.asarray(lanes(q.cols_c, cap_c_u))
                       for q in pbs]))

    plan = DistributedPlan(
        key=key, row_starts=a_sh.row_starts, algorithm=algo,
        semiring=sr.name, complement_mask=complement_mask,
        sorted_output=sorted_output, mask_sh=mask_sh, shape_a=a_sh.shape,
        shape_b=b.shape, cap_a=a_sh.cap_per, cap_b=b.cap,
        nnz_b=int(b.nnz), plans=tuple(plans),
        cap_c=cap_c_u,
        flop_cap=max(max(p.flop_cap for p in plans), 1),
        row_cap=max(p.row_cap for p in plans),
        k_width=max(p.k_width for p in plans),
        nnz_c=sum(p.nnz_c for p in plans),
        table_size=table_size, hash_sched=hash_sched, pb_sched=pb_sched)
    if cache:
        cache_store(key, plan)
    return plan


def shard_batch(pairs, n_shards: int, weights=None
                ) -> Tuple[Tuple[int, ...], ...]:
    """Round-robin *whole products* of a fleet across mesh chips.

    The batched subsystem's unit of distribution is the product, not the
    row: a fleet of small independent products (``core.batch``) has no
    cross-product reduction, so each chip simply owns a sub-fleet and
    runs its own :func:`repro.core.batch.plan_batch` -- embarrassingly
    parallel, zero collectives (the DBCSR batched-multiply distribution
    shape, vs the row partition ``shard_csr_rows`` uses for one large
    product).

    ``pairs`` is the fleet (only its length is read) or an int count.
    Plain round-robin by default; with ``weights`` (e.g. each product's
    ``total_flop`` from a plan, or ``nnz``) the round-robin visits
    products in descending weight order, so consecutive heavy products
    land on different chips -- the fleet analogue of the equal-flop row
    partition.  Returns ``n_shards`` tuples of product indices; every
    index appears exactly once.
    """
    n = pairs if isinstance(pairs, int) else len(pairs)
    assert n_shards >= 1, n_shards
    if weights is None:
        order = range(n)
    else:
        w = np.asarray(weights)
        assert w.shape == (n,), (w.shape, n)
        order = np.argsort(-w, kind="stable")
    assign: list = [[] for _ in range(n_shards)]
    for pos, i in enumerate(order):
        assign[pos % n_shards].append(int(i))
    return tuple(tuple(s) for s in assign)


# ----------------------------------------------------------------------------
# 1D row-partitioned products
# ----------------------------------------------------------------------------

def spgemm_1d(mesh: Mesh, a_sh: ShardedCSR, b: CSR, cap_c: int | None = None,
              flop_cap: int | None = None, axis: str = "data", *,
              algorithm: str = "esc",
              semiring: str | Semiring = "plus_times",
              mask: CSR | ShardedCSR | None = None,
              complement_mask: bool = False, sorted_output: bool = False,
              plan: DistributedPlan | None = None) -> ShardedCSR:
    """Row-partitioned SpGEMM: local shards of A x replicated B.

    With ``plan=`` (from :func:`plan_spgemm_1d`) every capacity and the
    algorithm/semiring/mask come from the plan and nothing is recomputed.
    Without a plan, ``cap_c`` is the per-shard output capacity and the
    explicit ``algorithm`` dispatches through :func:`spgemm` (``auto``
    needs inspection -- use the planner).
    """
    if plan is not None:
        return plan.execute(mesh, a_sh, b, axis=axis)
    assert cap_c is not None, "spgemm_1d needs cap_c unless plan= is given"
    if algorithm == "auto":
        raise ValueError(
            "algorithm='auto' needs inspection; use plan_spgemm_1d")
    sr = resolve_semiring(semiring)
    mask_sh = None
    if mask is not None:
        mask_sh = mask if isinstance(mask, ShardedCSR) \
            else reshard_rows(mask, a_sh)
        assert mask_sh.row_starts == a_sh.row_starts, \
            "mask must be sharded with A's row partition"
    statics = dict(algorithm=algorithm, semiring=sr.name,
                   complement_mask=complement_mask,
                   sorted_output=sorted_output, cap_c=cap_c,
                   flop_cap=flop_cap, row_cap=None, k_width=None)
    # no frozen schedule on the planless path: a hash request falls back
    # to hash_jnp inside _local_spgemm (use plan_spgemm_1d for Pallas)
    fn = _build_1d_fn(mesh, axis, mask_sh is not None, statics)
    args = (a_sh.parts, b) + ((mask_sh.parts,) if mask_sh else ())
    return ShardedCSR(fn(*args), a_sh.row_starts, a_sh.n_rows_global)


def _gather_rows(y: jax.Array, a_sh: ShardedCSR) -> jax.Array:
    """Drop per-shard pad rows from a stacked (S, rows_cap, k) result and
    reassemble the global (m, k) order (rectangular/unequal-shard safe --
    this replaces the old square-only ``reshape(nxt, (n, k))``)."""
    S, rows_cap = a_sh.n_shards, a_sh.rows_cap
    starts = a_sh.row_starts
    idx = np.concatenate(
        [np.arange(starts[s + 1] - starts[s], dtype=np.int64) + s * rows_cap
         for s in range(S)])
    flat = y.reshape((S * rows_cap,) + y.shape[2:])
    return flat[jnp.asarray(idx, jnp.int32)]


def spmm_1d(mesh: Mesh, a_sh: ShardedCSR, x: jax.Array,
            axis: str = "data") -> jax.Array:
    """Row-partitioned SpMM: y = A @ X with dense X of shape (n_cols, k).

    X is replicated (tall-skinny: k << n); the result is assembled to the
    global ``(n_rows, k)`` layout, which is correct for rectangular A and
    unequal (equal-flop) shard heights alike.
    """
    assert x.shape[0] == a_sh.shape[1], (x.shape, a_sh.shape)

    def local(a_parts, x_rep):
        a_loc = jax.tree.map(lambda v: v[0], a_parts)
        return spmm(a_loc, x_rep)[None]

    fn = shard_map(local, mesh=mesh, in_specs=(P(axis), P()),
                   out_specs=P(axis), check_rep=False)
    return _gather_rows(fn(a_sh.parts, x), a_sh)


def multi_source_bfs(mesh: Mesh, a_sh: ShardedCSR, sources: jax.Array,
                     n: int, n_iters: int, axis: str = "data") -> jax.Array:
    """Multi-source BFS via repeated SpMM (paper section 5.5 use case).

    ``sources`` (k,) vertex ids; returns (n, k) hop-distance matrix (-1 =
    unreached).  Frontier is the dense tall-skinny matrix; one SpMM per hop.
    """
    assert a_sh.shape == (n, n), \
        f"BFS adjacency must be square (n, n); got {a_sh.shape}"
    k = sources.shape[0]
    frontier = jnp.zeros((n, k), jnp.float32).at[sources,
                                                 jnp.arange(k)].set(1.0)
    dist = jnp.where(frontier > 0, 0, -1).astype(jnp.int32)

    def body(i, state):
        frontier, dist = state
        nxt = spmm_1d(mesh, a_sh, frontier, axis=axis)   # (n, k), assembled
        new = (nxt > 0) & (dist < 0)
        dist = jnp.where(new, i + 1, dist)
        return new.astype(jnp.float32), dist

    _, dist = jax.lax.fori_loop(0, n_iters, body, (frontier, dist))
    return dist


# ----------------------------------------------------------------------------
# SUMMA: outer-product K-panel schedule with reduce-scatter merge
# ----------------------------------------------------------------------------

def summa_panel_bounds(k_dim: int, n_shards: int,
                       k_panels: int | None = None) -> Tuple[Tuple[int, int],
                                                             ...]:
    """The K-panel schedule: ``k_panels`` contiguous panels of the
    contraction dimension, ``k_panels / n_shards`` owned per chip.

    ``k_panels`` defaults to one panel per chip and must be a multiple of
    ``n_shards`` no larger than K -- anything else raises (no silently
    ignored arguments).  K need *not* be a multiple of ``k_panels``:
    panels are ``ceil(K / k_panels)`` wide with a ragged (short, possibly
    empty) tail, so prime contraction dims schedule fine.  The first
    panel is always the widest -- executors size buffers off it.
    """
    if k_panels is None:
        k_panels = n_shards
    if k_panels % n_shards != 0:
        raise ValueError(
            f"k_panels={k_panels} must be a multiple of the mesh axis size "
            f"{n_shards} (each chip owns k_panels/n_shards panels)")
    if k_panels > k_dim:
        raise ValueError(
            f"k_panels={k_panels} exceeds the contraction dim {k_dim}")
    step = -(-k_dim // k_panels)
    return tuple((min(i * step, k_dim), min((i + 1) * step, k_dim))
                 for i in range(k_panels))


def _shard_summa(a: CSR, b: CSR, n_shards: int, k_panels: int):
    """Sparse-native operand layout for the outer-product schedule.

    Panel ``p`` (owned by chip ``p // (k_panels/n_shards)``) gets A's
    column block and B's row block for K-range ``bounds[p]``: the column
    block is a host-side entry filter (order-preserving, so sortedness
    survives); the row block is a contiguous CSR slice.  Returns stacked
    CSRs with leading dims ``(S, P)`` plus the per-panel **entry-gather
    indices** ``(a_take, b_take)`` mapping each panel slot back to its
    global entry -- the structural part of the decomposition the plan
    freezes, so repeat executes re-gather only *values* (one device
    gather) instead of re-running this host pass.
    """
    bounds = summa_panel_bounds(a.n_cols, n_shards, k_panels)
    k_panels = len(bounds)
    per = k_panels // n_shards
    m, n = a.n_rows, b.n_cols
    step = bounds[0][1] - bounds[0][0]

    ip_a = np.asarray(a.indptr, np.int64)
    ind_a = np.asarray(a.indices)
    dat_a = np.asarray(a.data)
    live_a = int(ip_a[-1])
    rows_a = np.repeat(np.arange(m), np.diff(ip_a))
    ip_b = np.asarray(b.indptr, np.int64)
    ind_b = np.asarray(b.indices)
    dat_b = np.asarray(b.data)

    a_blocks, b_blocks = [], []
    for lo, hi in bounds:
        sel = (ind_a[:live_a] >= lo) & (ind_a[:live_a] < hi)
        take_a = np.nonzero(sel)[0].astype(np.int32)
        r = rows_a[take_a]
        counts = np.bincount(r, minlength=m)
        indptr = np.zeros(m + 1, np.int32)
        np.cumsum(counts, out=indptr[1:])
        a_blocks.append((indptr, (ind_a[take_a] - lo).astype(np.int32),
                         dat_a[take_a], take_a))
        lo_p, hi_p = int(ip_b[lo]), int(ip_b[hi])
        take_b = np.arange(lo_p, hi_p, dtype=np.int32)
        b_blocks.append(((ip_b[lo:hi + 1] - ip_b[lo]).astype(np.int32),
                         ind_b[take_b].astype(np.int32), dat_b[take_b],
                         take_b))

    cap_a = _pad8(max(blk[1].size for blk in a_blocks))
    cap_b = _pad8(max(blk[1].size for blk in b_blocks))

    def stack(blocks, n_ptr, cap, dtype):
        ptr = np.zeros((n_shards, per, n_ptr), np.int32)
        idx = np.zeros((n_shards, per, cap), np.int32)
        val = np.zeros((n_shards, per, cap), dtype)
        take = np.zeros((n_shards, per, cap), np.int32)
        nnz = np.zeros((n_shards, per), np.int32)
        for pg, (p_ptr, p_idx, p_val, p_take) in enumerate(blocks):
            s, p = pg // per, pg % per
            ptr[s, p, :p_ptr.size] = p_ptr
            ptr[s, p, p_ptr.size:] = p_ptr[-1]   # ragged panel: pad rows
            idx[s, p, :p_idx.size] = p_idx
            val[s, p, :p_idx.size] = p_val
            take[s, p, :p_idx.size] = p_take
            nnz[s, p] = p_idx.size
        return ptr, idx, val, take, nnz

    pa, ia, va, ta, na = stack(a_blocks, m + 1, cap_a, dat_a.dtype)
    pb, ib, vb, tb, nb = stack(b_blocks, step + 1, cap_b, dat_b.dtype)
    a_parts = CSR(jnp.asarray(pa), jnp.asarray(ia), jnp.asarray(va),
                  jnp.asarray(na), (m, step), sorted_cols=a.sorted_cols)
    b_parts = CSR(jnp.asarray(pb), jnp.asarray(ib), jnp.asarray(vb),
                  jnp.asarray(nb), (step, n), sorted_cols=b.sorted_cols)
    return a_parts, b_parts, bounds, jnp.asarray(ta), jnp.asarray(tb)


@dataclass(frozen=True)
class SummaPlan:
    """Frozen outer-product SUMMA schedule: per-(chip, panel) plans, the
    global symbolic result that sizes the row-sharded output, and the
    *panel structure* itself (stacked indptr/indices with zeroed data,
    plus entry-gather indices).  Values deliberately stay out -- like
    ``SpGEMMPlan``, a re-weighted operand pair reuses the plan -- so
    ``execute`` re-gathers only ``data`` with one device gather per
    operand instead of re-running the host decomposition."""
    key: tuple = dataclasses.field(repr=False)
    n_shards: int
    k_panels: int
    bounds: Tuple[Tuple[int, int], ...]
    algorithm: str
    semiring: str
    shape_a: Tuple[int, int]
    shape_b: Tuple[int, int]
    cap_a: int
    cap_b: int
    nnz_a: int
    nnz_b: int
    plans: Tuple[SpGEMMPlan, ...] = dataclasses.field(repr=False)
    a_struct: CSR = dataclasses.field(repr=False)   # stacked, data zeroed
    b_struct: CSR = dataclasses.field(repr=False)
    a_take: jax.Array = dataclasses.field(repr=False)
    b_take: jax.Array = dataclasses.field(repr=False)
    cap_c: int               # uniform per-panel local product capacity
    flop_cap: int
    row_cap: int
    k_width: int
    out_cap: int             # uniform per-row-shard output capacity
    row_starts_out: Tuple[int, ...]
    nnz_c: int
    #: static scratch allocation (max over panel plans) and stacked
    #: per-(chip, panel) hash schedules ``(offsets (S, per, n_bins+1),
    #: bin_tsize (S, per, n_bins), indptr_c (S, per, m+1))`` -- the SUMMA
    #: twin of ``DistributedPlan.hash_sched``.
    table_size: int = 0
    hash_sched: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = \
        dataclasses.field(default=None, repr=False)

    def check_structure(self, a: CSR, b: CSR) -> None:
        assert a.shape == self.shape_a and b.shape == self.shape_b, \
            f"plan is for {self.shape_a}x{self.shape_b}, " \
            f"got {a.shape}x{b.shape}"
        assert a.cap == self.cap_a and b.cap == self.cap_b, \
            "operand capacities differ from the planned structure"
        for op, planned in ((a, self.nnz_a), (b, self.nnz_b)):
            if not isinstance(op.nnz, jax.core.Tracer):
                assert int(op.nnz) == planned, \
                    "operand nnz differs from the planned structure"

    def execute(self, mesh: Mesh, a: CSR, b: CSR,
                axis: str = "data") -> ShardedCSR:
        """Numeric phase only: gather current values into the frozen panel
        structure (device-side), run the panel loop + reduce-scatter."""
        self.check_structure(a, b)
        fn = _memoized_executor(self, (mesh, axis),
                                lambda: _build_summa_fn(self, mesh, axis))
        args = (self.a_struct, self.a_take, a.data,
                self.b_struct, self.b_take, b.data)
        if self.hash_sched is not None:
            args = args + self.hash_sched
        out = fn(*args)
        return ShardedCSR(out, self.row_starts_out, self.shape_a[0])

    __call__ = execute


def _build_summa_fn(plan: SummaPlan, mesh: Mesh, axis: str):
    """SPMD body: gather values into the frozen panel structure, stream
    the chip's K-panels through planned local products, accumulate the
    dense partial C, reduce-scatter over rows."""
    per = plan.k_panels // plan.n_shards
    m, n = plan.shape_a[0], plan.shape_b[1]
    statics = dict(algorithm=plan.algorithm, semiring=plan.semiring,
                   complement_mask=False, sorted_output=False,
                   cap_c=plan.cap_c, flop_cap=plan.flop_cap,
                   row_cap=plan.row_cap, k_width=plan.k_width,
                   table_size=plan.table_size)
    boolean = plan.semiring == "boolean"
    with_sched = plan.hash_sched is not None

    def gather(struct, take, data):
        s_loc = jax.tree.map(lambda x: x[0], struct)     # (per, ...) local
        lane = jnp.arange(take.shape[-1], dtype=jnp.int32)
        live = lane[None, :] < s_loc.nnz[:, None]        # (per, cap)
        vals = jnp.where(live, data[take[0]], 0).astype(data.dtype)
        return dataclasses.replace(s_loc, data=vals)

    def local(a_struct, a_take, a_data, b_struct, b_take, b_data, *sched):
        a_loc = gather(a_struct, a_take, a_data)    # (per, ...) stacked
        b_loc = gather(b_struct, b_take, b_data)
        # this chip's (per, ...) schedule stack, one slice per K-panel
        hs_loc = tuple(r[0] for r in sched) if with_sched else None
        acc = jnp.zeros((m, n), a_data.dtype)
        for p in range(per):
            a_p = jax.tree.map(lambda x: x[p], a_loc)
            b_p = jax.tree.map(lambda x: x[p], b_loc)
            hs = tuple(x[p] for x in hs_loc) if with_sched else None
            c_p = _local_spgemm(a_p, b_p, None, hash_sched=hs, **statics)
            # the reduce-scatter merge is an elementwise +, which is the
            # semiring add for every semiring this path admits (boolean
            # partials are 0/1 counts, thresholded after the scatter)
            # verify: allow(no-densify) -- the reduce-scatter merge is
            # defined on the dense partial; re-sparsified right after
            acc = acc + c_p.to_dense()  # verify: allow(no-densify)
        part = jax.lax.psum_scatter(acc, axis, scatter_dimension=0,
                                    tiled=True)
        if boolean:
            part = (part > 0).astype(acc.dtype)
        c_loc = CSR.from_dense(part, cap=plan.out_cap)
        return jax.tree.map(lambda x: x[None], c_loc)

    in_specs = (P(axis), P(axis), P(), P(axis), P(axis), P()) + \
        ((P(axis), P(axis), P(axis)) if with_sched else ())
    return shard_map(local, mesh=mesh, in_specs=in_specs,
                     out_specs=P(axis), check_rep=False)


def plan_spgemm_summa(a: CSR, b: CSR, n_shards: int,
                      k_panels: int | None = None, *,
                      algorithm: str = "auto",
                      semiring: str | Semiring = "plus_times",
                      n_bins: int = 8, cache: bool = True) -> SummaPlan:
    """Inspect the outer-product SUMMA schedule once and freeze it.

    Runs the *global* plan first (resolving ``auto`` and yielding the exact
    ``row_nnz_c`` that sizes the row-sharded output), then one plan per
    (chip, panel) local product.  Cached under a ``("summa", digest)`` key
    in the shared LRU.

    The merge is a dense-accumulator reduce-scatter, so the semiring's
    ``add`` must be arithmetic ``+`` with identity 0: ``plus_times`` /
    ``plus_first`` directly, ``boolean`` via a post-scatter threshold.
    ``min_plus`` (identity +inf) is rejected.
    """
    sr = resolve_semiring(semiring)
    if sr.name == "min_plus":
        raise NotImplementedError(
            "spgemm_summa's reduce-scatter merge needs an add-identity of "
            "0; min_plus (identity +inf) needs the 1D path (spgemm_1d)")
    m = a.n_rows
    if m % n_shards != 0:
        raise ValueError(
            f"reduce-scatter tiles C rows equally: n_rows={m} must be "
            f"divisible by the mesh axis size {n_shards}")
    bounds = summa_panel_bounds(a.n_cols, n_shards, k_panels)
    k_panels = len(bounds)

    h = hashlib.blake2b(digest_size=16)
    h.update(structure_key(a))
    h.update(structure_key(b))
    h.update(repr((n_shards, k_panels, sr.name, algorithm,
                   n_bins)).encode())
    key = ("summa", h.digest())
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit

    # Global inspection: exact output structure -> per-row-shard capacity,
    # and the recipe's algorithm choice resolved on the whole product.
    gplan = plan_spgemm(a, b, algorithm=algorithm, semiring=sr.name,
                        n_bins=n_bins, use_case="dist", cache=cache)
    algo = gplan.algorithm
    row_nnz = np.asarray(gplan.row_nnz_c, np.int64)
    rows_per = m // n_shards
    out_cap = _pad8(int(row_nnz.reshape(n_shards, rows_per).sum(axis=1)
                        .max()))
    row_starts_out = tuple(range(0, m + 1, rows_per))

    a_parts, b_parts, _, a_take, b_take = _shard_summa(a, b, n_shards,
                                                       k_panels)
    per = k_panels // n_shards
    plans = []
    for s in range(n_shards):
        for p in range(per):
            a_p = jax.tree.map(lambda x: x[s, p], a_parts)
            b_p = jax.tree.map(lambda x: x[s, p], b_parts)
            plans.append(plan_spgemm(a_p, b_p, algorithm=algo,
                                     semiring=sr.name, n_bins=n_bins,
                                     use_case="dist", cache=cache))

    # Per-(chip, panel) frozen hash schedules, stacked (S, per, ...):
    # every panel plan shares n_bins and the global row count m, so the
    # arrays are shape-uniform.  Boolean is general (post-scatter
    # threshold notwithstanding, the *local* product is a boolean-semiring
    # call) and keeps the jnp body, exactly like the 1D path.
    table_size = 0
    hash_sched = None
    if algo in ("hash", "hash_vector") and sr.name == "plus_times":
        table_size = max(p.table_size for p in plans)

        def stack2(field):
            rows = [jnp.stack([field(plans[s * per + p])
                               for p in range(per)])
                    for s in range(n_shards)]
            return jnp.stack(rows)

        hash_sched = (stack2(lambda p: p.offsets),
                      stack2(lambda p: p.bin_tsize),
                      stack2(lambda p: p.indptr_c))

    plan = SummaPlan(
        key=key, n_shards=n_shards, k_panels=k_panels, bounds=bounds,
        algorithm=algo, semiring=sr.name, shape_a=a.shape, shape_b=b.shape,
        cap_a=a.cap, cap_b=b.cap, nnz_a=int(a.nnz), nnz_b=int(b.nnz),
        plans=tuple(plans),
        a_struct=dataclasses.replace(
            a_parts, data=jnp.zeros_like(a_parts.data)),
        b_struct=dataclasses.replace(
            b_parts, data=jnp.zeros_like(b_parts.data)),
        a_take=a_take, b_take=b_take,
        cap_c=_pad8(max(p.cap_c for p in plans)),
        flop_cap=max(max(p.flop_cap for p in plans), 1),
        row_cap=max(p.row_cap for p in plans),
        k_width=max(p.k_width for p in plans),
        out_cap=out_cap, row_starts_out=row_starts_out,
        nnz_c=gplan.nnz_c, table_size=table_size, hash_sched=hash_sched)
    if cache:
        cache_store(key, plan)
    return plan


def spgemm_summa(mesh: Mesh, a: CSR, b: CSR, axis: str = "data",
                 k_panels: int | None = None, *, algorithm: str = "auto",
                 semiring: str | Semiring = "plus_times", n_bins: int = 8,
                 plan: SummaPlan | None = None) -> ShardedCSR:
    """Outer-product SUMMA over one mesh axis; C comes back row-sharded.

    Chip ``s`` owns K-panels ``[s*per, (s+1)*per)`` of A's column blocks
    and B's row blocks, streams them through planned sparse local products,
    and the dense partial C's are merged by a reduce-scatter along
    ``axis``.  ``k_panels`` (default: one per chip) sets the panel count
    of the stream -- invalid values raise, see :func:`summa_panel_bounds`.
    """
    n_shards = mesh.shape[axis]
    if plan is None:
        plan = plan_spgemm_summa(a, b, n_shards, k_panels,
                                 algorithm=algorithm, semiring=semiring,
                                 n_bins=n_bins)
    else:
        if plan.n_shards != n_shards:
            raise ValueError(f"plan is for {plan.n_shards} shards, mesh "
                             f"axis {axis!r} has {n_shards}")
        if k_panels is not None and plan.k_panels != k_panels:
            raise ValueError(f"plan holds k_panels={plan.k_panels}, "
                             f"call requested {k_panels}")
    return plan.execute(mesh, a, b, axis=axis)


# ----------------------------------------------------------------------------
# Propagation-blocking SUMMA: bucket exchange instead of dense reduce-scatter
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class PBSummaPlan:
    """Frozen propagation-blocking merge for the outer-product schedule.

    The classic SUMMA executor (:class:`SummaPlan`) merges K-panel
    partials through a *dense* ``(m, n)`` accumulator and a
    ``psum_scatter`` -- O(m*n) words on the wire regardless of sparsity.
    This plan replaces that merge with the PB exchange (DESIGN.md
    section 18, after Gu et al.'s propagation blocking): the inspector
    expands every panel partial product, assigns it to the chip that
    owns its output *row* (bucket = destination chip), and freezes per
    ``(source, dest)`` gather indices into the chips' panel value
    arrays.  Execute is then numeric-only and three steps per chip:

      1. **scatter** -- multiply local panel values into per-destination
         bucket buffers (the single-node PB scatter kernel, buckets =
         chips),
      2. **exchange** -- one ``all_to_all`` routes each bucket to its
         row owner: O(flop) words total, the communication-avoiding win
         on low-compression products where ``flop ~ nnz(C) << m*n``,
      3. **merge** -- segment-add received products into the frozen
         local output slots (the single-node PB merge kernel; the
         sequential bucket grid makes cross-source accumulation into one
         slot safe).

    Values stay out of the plan: like :class:`SummaPlan`, execute
    re-gathers only ``data`` through the frozen ``a_take``/``b_take``.
    plus_times only (the Pallas pair's contract).
    """
    key: tuple = dataclasses.field(repr=False)
    n_shards: int
    k_panels: int
    bounds: Tuple[Tuple[int, int], ...]
    shape_a: Tuple[int, int]
    shape_b: Tuple[int, int]
    cap_a: int
    cap_b: int
    nnz_a: int
    nnz_b: int
    a_struct: CSR = dataclasses.field(repr=False)   # stacked, data zeroed
    b_struct: CSR = dataclasses.field(repr=False)
    a_take: jax.Array = dataclasses.field(repr=False)
    b_take: jax.Array = dataclasses.field(repr=False)
    #: per-(source, dest) product capacity: the exchange moves
    #: ``n_shards * xcap`` f32 words per chip
    xcap: int
    #: ``[s, d, lane]`` -> flattened ``(per * panel_cap)`` slot in chip
    #: s's gathered panel values (A resp. B)
    src_a: jax.Array = dataclasses.field(repr=False)
    src_b: jax.Array = dataclasses.field(repr=False)
    pair_nnz: jax.Array = dataclasses.field(repr=False)   # (S, S) [src, dst]
    #: ``[d, s, lane]`` -> chip d's local output slot for the lane-th
    #: product received from source s (dest-major: lives on the receiver)
    seg: jax.Array = dataclasses.field(repr=False)
    recv_nnz: jax.Array = dataclasses.field(repr=False)   # (S, S) [dst, src]
    cols_out: jax.Array = dataclasses.field(repr=False)   # (S, out_cap)
    indptr_out: jax.Array = dataclasses.field(repr=False)  # (S, rows_per+1)
    out_nnz: jax.Array = dataclasses.field(repr=False)    # (S,)
    out_cap: int
    row_starts_out: Tuple[int, ...]
    nnz_c: int
    total_flop: int
    semiring: str = "plus_times"
    provenance: str = "planned"

    def check_structure(self, a: CSR, b: CSR) -> None:
        assert a.shape == self.shape_a and b.shape == self.shape_b, \
            f"plan is for {self.shape_a}x{self.shape_b}, " \
            f"got {a.shape}x{b.shape}"
        assert a.cap == self.cap_a and b.cap == self.cap_b, \
            "operand capacities differ from the planned structure"
        for op, planned in ((a, self.nnz_a), (b, self.nnz_b)):
            if not isinstance(op.nnz, jax.core.Tracer):
                assert int(op.nnz) == planned, \
                    "operand nnz differs from the planned structure"

    def execute(self, mesh: Mesh, a: CSR, b: CSR,
                axis: str = "data") -> ShardedCSR:
        """Numeric phase only: gather values, scatter / exchange / merge."""
        self.check_structure(a, b)
        fn = _memoized_executor(self, (mesh, axis),
                                lambda: _build_pb_summa_fn(self, mesh, axis))
        out = fn(self.a_struct, self.a_take, a.data,
                 self.b_struct, self.b_take, b.data,
                 self.src_a, self.src_b, self.pair_nnz, self.seg,
                 self.recv_nnz, self.cols_out, self.indptr_out,
                 self.out_nnz)
        return ShardedCSR(out, self.row_starts_out, self.shape_a[0])

    __call__ = execute


def _build_pb_summa_fn(plan: PBSummaPlan, mesh: Mesh, axis: str):
    """SPMD body: gather panel values, PB-scatter into per-chip buckets,
    all_to_all exchange, PB-merge into the frozen local output."""
    from repro.kernels.spgemm_pb import ops as pb_ops
    n = plan.shape_b[1]
    rows_per = plan.shape_a[0] // plan.n_shards

    def flatvals(struct, take, data):
        s_loc = jax.tree.map(lambda x: x[0], struct)     # (per, ...) local
        lane = jnp.arange(take.shape[-1], dtype=jnp.int32)
        live = lane[None, :] < s_loc.nnz[:, None]        # (per, cap)
        return jnp.where(live, data[take[0]], 0).astype(
            data.dtype).reshape(-1)                      # (per * cap,)

    def local(a_struct, a_take, a_data, b_struct, b_take, b_data,
              src_a, src_b, pair_nnz, seg, recv_nnz, cols_out,
              indptr_out, out_nnz):
        av = flatvals(a_struct, a_take, a_data)
        bv = flatvals(b_struct, b_take, b_data)
        # scatter: bucket g holds this chip's products destined for chip g
        pp = pb_ops.pb_scatter(av, bv, src_a[0], src_b[0], pair_nnz[0])
        # exchange: row d goes to chip d; received row s came from chip s
        pp = jax.lax.all_to_all(pp, axis, split_axis=0, concat_axis=0)
        data = pb_ops.pb_merge(pp, seg[0], recv_nnz[0], plan.out_cap)
        lane = jnp.arange(plan.out_cap, dtype=jnp.int32)
        valid = lane < out_nnz[0]
        c_loc = CSR(indptr_out[0], jnp.where(valid, cols_out[0], 0),
                    jnp.where(valid, data, 0).astype(a_data.dtype),
                    out_nnz[0], (rows_per, n), sorted_cols=True)
        return jax.tree.map(lambda x: x[None], c_loc)

    in_specs = (P(axis), P(axis), P(), P(axis), P(axis), P()) + \
        (P(axis),) * 8
    return shard_map(local, mesh=mesh, in_specs=in_specs,
                     out_specs=P(axis), check_rep=False)


def plan_spgemm_pb_summa(a: CSR, b: CSR, n_shards: int,
                         k_panels: int | None = None, *,
                         cache: bool = True) -> PBSummaPlan:
    """Inspect the PB-merge SUMMA schedule once and freeze it.

    Reuses :func:`_shard_summa`'s panel decomposition (so the frozen
    operand layout is bitwise the classic SUMMA one), then expands every
    panel's partial products on the host, derives the exact global output
    structure, and packs per-``(source, dest)`` bucket gather indices.
    Cached in the shared LRU under the classic plan's ``("summa", ...)``
    kind with a ``pb-merge`` marker in the digest.
    """
    from .schedule import guard_i32_flop
    sr = resolve_semiring("plus_times")
    m = a.n_rows
    if m % n_shards != 0:
        raise ValueError(
            f"the PB exchange tiles C rows equally: n_rows={m} must be "
            f"divisible by the mesh axis size {n_shards}")
    bounds = summa_panel_bounds(a.n_cols, n_shards, k_panels)
    k_panels = len(bounds)

    h = hashlib.blake2b(digest_size=16)
    h.update(structure_key(a))
    h.update(structure_key(b))
    h.update(repr(("pb-merge", n_shards, k_panels, sr.name)).encode())
    key = ("summa", h.digest())
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit

    a_parts, b_parts, _, a_take, b_take = _shard_summa(a, b, n_shards,
                                                       k_panels)
    per = k_panels // n_shards
    cap_pa = a_parts.indices.shape[-1]
    cap_pb = b_parts.indices.shape[-1]
    pa = np.asarray(a_parts.indptr, np.int64)    # (S, per, m+1)
    ia = np.asarray(a_parts.indices)
    na = np.asarray(a_parts.nnz, np.int64)
    pbp = np.asarray(b_parts.indptr, np.int64)   # (S, per, step+1)
    ib = np.asarray(b_parts.indices)

    # Expand every panel's partial products: one (row, col, src-slot-a,
    # src-slot-b, source-chip) record per scalar multiply.  Slots index
    # the *flattened* (per * cap) gathered panel value arrays -- exactly
    # the layout the executor's ``flatvals`` produces.
    R, C, SA, SB, SRC = [], [], [], [], []
    for s in range(n_shards):
        for p in range(per):
            cnt_a = int(na[s, p])
            if cnt_a == 0:
                continue
            rows = np.repeat(np.arange(m), np.diff(pa[s, p]))[:cnt_a]
            kloc = ia[s, p, :cnt_a]                 # panel-local column
            starts = pbp[s, p][kloc]
            counts = pbp[s, p][kloc + 1] - starts
            total = int(counts.sum())
            if total == 0:
                continue
            j = np.repeat(np.arange(cnt_a), counts)
            off = np.arange(total) - np.repeat(
                np.cumsum(counts) - counts, counts)
            t = starts[j] + off
            R.append(rows[j])
            C.append(ib[s, p][t])
            SA.append((p * cap_pa + j).astype(np.int64))
            SB.append((p * cap_pb + t).astype(np.int64))
            SRC.append(np.full(total, s, np.int64))
    if R:
        R = np.concatenate(R); C = np.concatenate(C)
        SA = np.concatenate(SA); SB = np.concatenate(SB)
        SRC = np.concatenate(SRC)
    else:
        R = C = SA = SB = SRC = np.zeros(0, np.int64)
    total_flop = int(R.size)
    guard_i32_flop(total_flop, what="pb-summa expansion")

    # Exact global output structure (sorted rows-major), sliced per dest
    # chip: rows are contiguous per chip, so a chip's slots are the
    # global slots minus its first row's offset.
    rows_per = m // n_shards
    uo = np.lexsort((C, R))
    Rs, Cs = R[uo], C[uo]
    new = np.ones(total_flop, bool)
    if total_flop:
        new[1:] = (Rs[1:] != Rs[:-1]) | (Cs[1:] != Cs[:-1])
    slot_sorted = np.cumsum(new) - 1
    slot = np.empty(total_flop, np.int64)
    slot[uo] = slot_sorted
    nnz_c = int(new.sum()) if total_flop else 0
    ur, uc = Rs[new] if total_flop else Rs, Cs[new] if total_flop else Cs
    row_nnz = np.bincount(ur, minlength=m)
    g_indptr = np.zeros(m + 1, np.int64)
    np.cumsum(row_nnz, out=g_indptr[1:])
    per_dest = (g_indptr[np.arange(1, n_shards + 1) * rows_per]
                - g_indptr[np.arange(n_shards) * rows_per])
    out_cap = _pad8(max(int(per_dest.max(initial=0)), 1))
    out_nnz = per_dest.astype(np.int32)
    cols_out = np.zeros((n_shards, out_cap), np.int32)
    indptr_out = np.zeros((n_shards, rows_per + 1), np.int32)
    for d in range(n_shards):
        lo, hi = int(g_indptr[d * rows_per]), \
            int(g_indptr[(d + 1) * rows_per])
        cols_out[d, :hi - lo] = uc[lo:hi]
        indptr_out[d] = (g_indptr[d * rows_per:(d + 1) * rows_per + 1]
                         - lo)

    # Pack (source, dest) buckets: bucket = destination chip (the row
    # owner).  ``seg`` is dest-major -- it rides on the receiver, mapping
    # each product that arrives from source s into a local output slot.
    dest = R // rows_per if total_flop else R
    pair = SRC * n_shards + dest
    pair_nnz = np.bincount(pair, minlength=n_shards * n_shards) \
        .reshape(n_shards, n_shards).astype(np.int32)
    xcap = _pad8(max(int(pair_nnz.max(initial=0)), 1))
    order = np.lexsort((C, R, pair))
    pr = pair[order]
    starts = np.zeros(n_shards * n_shards, np.int64)
    np.cumsum(pair_nnz.reshape(-1)[:-1], out=starts[1:])
    lane = np.arange(total_flop) - starts[pr]
    src_a = np.zeros((n_shards, n_shards, xcap), np.int32)
    src_b = np.zeros((n_shards, n_shards, xcap), np.int32)
    seg = np.full((n_shards, n_shards, xcap), out_cap - 1, np.int32)
    s_of, d_of = pr // n_shards, pr % n_shards
    src_a[s_of, d_of, lane] = SA[order]
    src_b[s_of, d_of, lane] = SB[order]
    seg[d_of, s_of, lane] = (slot[order]
                             - g_indptr[d_of * rows_per]).astype(np.int32)
    recv_nnz = pair_nnz.T.copy()

    plan = PBSummaPlan(
        key=key, n_shards=n_shards, k_panels=k_panels, bounds=bounds,
        shape_a=a.shape, shape_b=b.shape, cap_a=a.cap, cap_b=b.cap,
        nnz_a=int(a.nnz), nnz_b=int(b.nnz),
        a_struct=dataclasses.replace(
            a_parts, data=jnp.zeros_like(a_parts.data)),
        b_struct=dataclasses.replace(
            b_parts, data=jnp.zeros_like(b_parts.data)),
        a_take=a_take, b_take=b_take, xcap=xcap,
        src_a=jnp.asarray(src_a), src_b=jnp.asarray(src_b),
        pair_nnz=jnp.asarray(pair_nnz), seg=jnp.asarray(seg),
        recv_nnz=jnp.asarray(recv_nnz), cols_out=jnp.asarray(cols_out),
        indptr_out=jnp.asarray(indptr_out), out_nnz=jnp.asarray(out_nnz),
        out_cap=out_cap,
        row_starts_out=tuple(range(0, m + 1, rows_per)),
        nnz_c=nnz_c, total_flop=total_flop)
    if cache:
        cache_store(key, plan)
    return plan


def spgemm_pb_summa(mesh: Mesh, a: CSR, b: CSR, axis: str = "data",
                    k_panels: int | None = None, *,
                    plan: PBSummaPlan | None = None) -> ShardedCSR:
    """Outer-product SUMMA with the propagation-blocking merge.

    Same operand layout and K-panel stream as :func:`spgemm_summa`, but
    the partial-product merge is the PB bucket exchange (one
    ``all_to_all`` of O(flop) words) instead of the dense ``(m, n)``
    reduce-scatter -- the communication-avoiding lane for low-compression
    products.  plus_times only; C comes back row-sharded.
    """
    n_shards = mesh.shape[axis]
    if plan is None:
        plan = plan_spgemm_pb_summa(a, b, n_shards, k_panels)
    else:
        if plan.n_shards != n_shards:
            raise ValueError(f"plan is for {plan.n_shards} shards, mesh "
                             f"axis {axis!r} has {n_shards}")
        if k_panels is not None and plan.k_panels != k_panels:
            raise ValueError(f"plan holds k_panels={plan.k_panels}, "
                             f"call requested {k_panels}")
    return plan.execute(mesh, a, b, axis=axis)
