"""Static-capacity sparse formats for XLA/TPU.

The paper (Nagasaka et al. 2018) stores matrices in CSR with exact-size
allocations obtained from a *symbolic* phase.  Under XLA every shape must be
static, so the symbolic phase here produces a static **capacity** (``cap``)
and the dynamic ``nnz`` is carried as a traced scalar.  All padded tail slots
hold ``indices == 0`` / ``data == 0`` and every consumer masks on
``arange(cap) < nnz``.

Formats:
  * :class:`CSR`  -- scalar compressed sparse rows (paper's native format).
  * :class:`BCSR` -- block compressed sparse rows; the TPU-native currency
    (dense ``(bm, bn)`` tiles feed the MXU).  Scalar CSR rows cannot feed a
    128x128 systolic array; see DESIGN.md section 2.

Both are registered pytrees so they flow through ``jit``/``grad``/``vmap``
and can be sharded with ``NamedSharding`` like any other array bundle.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _register(cls, data_fields, meta_fields):
    jax.tree_util.register_dataclass(cls, data_fields=list(data_fields),
                                     meta_fields=list(meta_fields))
    return cls


@dataclass(frozen=True)
class CSR:
    """Compressed sparse rows with static capacity.

    Attributes:
      indptr:  ``(n_rows + 1,) int32`` row pointer array.
      indices: ``(cap,) int32`` column ids, row-major; padded with 0.
      data:    ``(cap,) dtype`` values; padded with 0.
      nnz:     scalar int32, the live prefix length of indices/data.
      shape:   static ``(n_rows, n_cols)``.
      sorted_cols: static bool -- are column ids sorted within each row?
        The paper's headline C8 finding (unsorted is 1.6x faster) makes this
        flag part of the type, exactly like Table 1's "Sortedness" column.
    """
    indptr: jax.Array
    indices: jax.Array
    data: jax.Array
    nnz: jax.Array
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    sorted_cols: bool = dataclasses.field(default=True, metadata=dict(static=True))

    # ---- static helpers -------------------------------------------------
    @property
    def cap(self) -> int:
        return self.indices.shape[0]

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def dtype(self):
        return self.data.dtype

    # ---- construction ----------------------------------------------------
    @staticmethod
    def from_dense(x: jax.Array, cap: int | None = None) -> "CSR":
        """Build CSR from a dense matrix (jit-compatible given static cap)."""
        m, n = x.shape
        if cap is None:
            cap = m * n
        mask = (x != 0).ravel()
        nnz = mask.sum().astype(jnp.int32)
        # Stable argsort of ~mask puts nonzero slots first, preserving
        # row-major order -> rows ascending, cols ascending within row.
        order = jnp.argsort(~mask, stable=True)[:cap]
        valid = jnp.arange(cap, dtype=jnp.int32) < nnz
        cols = jnp.where(valid, (order % n).astype(jnp.int32), 0)
        vals = jnp.where(valid, x.ravel()[order], 0).astype(x.dtype)
        counts = jnp.sum((x != 0), axis=1, dtype=jnp.int32)
        indptr = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts, dtype=jnp.int32)])
        return CSR(indptr, cols, vals, nnz, (m, n), sorted_cols=True)

    @staticmethod
    def from_numpy_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                       shape: Tuple[int, int], cap: int | None = None,
                       sum_duplicates: bool = True) -> "CSR":
        """Host-side builder (numpy; not jittable). Sorts row-major."""
        m, n = shape
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = np.asarray(vals)
        if sum_duplicates and rows.size:
            key = rows * n + cols
            uniq, inv = np.unique(key, return_inverse=True)
            acc = np.zeros(uniq.shape[0], dtype=np.float64)
            np.add.at(acc, inv, vals.astype(np.float64))
            rows, cols = uniq // n, uniq % n
            vals = acc.astype(vals.dtype)
        else:
            order = np.lexsort((cols, rows))
            rows, cols, vals = rows[order], cols[order], vals[order]
        nnz = int(rows.size)
        if cap is None:
            cap = max(nnz, 1)
        assert nnz <= cap, f"nnz {nnz} exceeds capacity {cap}"
        indices = np.zeros(cap, np.int32)
        data = np.zeros(cap, vals.dtype if vals.size else np.float32)
        indices[:nnz] = cols
        data[:nnz] = vals
        counts = np.bincount(rows, minlength=m)
        indptr = np.zeros(m + 1, np.int32)
        np.cumsum(counts, out=indptr[1:])
        return CSR(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(data),
                   jnp.asarray(nnz, jnp.int32), (m, n), sorted_cols=True)

    # ---- views ------------------------------------------------------------
    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.cap, dtype=jnp.int32) < self.nnz

    def row_ids(self) -> jax.Array:
        """Row id of every slot (cap,), padded slots get n_rows - 1 clamped."""
        e = jnp.arange(self.cap, dtype=jnp.int32)
        r = jnp.searchsorted(self.indptr, e, side="right") - 1
        return jnp.clip(r, 0, self.n_rows - 1).astype(jnp.int32)

    def row_nnz(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def contains(self, rows: jax.Array, cols: jax.Array) -> jax.Array:
        """Structural membership: is ``(rows[i], cols[i])`` a stored entry?

        The mask probe of the masked-SpGEMM layer (DESIGN.md section 7).
        Requires ``sorted_cols``: a row-major CSR has globally sorted
        ``row * n_cols + col`` keys, so membership is one binary search per
        query -- O(log nnz), jit/vmap-friendly, and usable *inside* the
        expand/merge loops (no dense materialization).  Keys use int32; the
        proxy scales here keep ``n_rows * n_cols < 2^31`` (DESIGN.md
        section 9).
        """
        key = rows.astype(jnp.int32) * jnp.int32(self.n_cols) + \
            cols.astype(jnp.int32)
        return sorted_keys_contain(csr_sorted_keys(self), key)

    def to_dense(self) -> jax.Array:
        out = jnp.zeros(self.shape, self.data.dtype)
        v = jnp.where(self.valid_mask(), self.data, 0)
        return out.at[self.row_ids(), self.indices].add(v)

    def sort_rows(self) -> "CSR":
        """Sort column ids within each row (the paper's optional epilogue).

        Cost model: this is exactly the ``sum nnz(c_i*) log nnz(c_i*)`` term
        of Eq. (2); the ``sorted_vs_unsorted`` rows of `bench_graph.py` and
        the per-hop comparison in `bench_chain.py` measure what skipping
        it saves.
        """
        # lexicographic (row, col) sort of the live prefix; padded slots sort
        # to the end via a sentinel row id.
        rows = jnp.where(self.valid_mask(), self.row_ids(),
                         jnp.int32(self.n_rows))
        order = jnp.lexsort((self.indices, rows))
        return CSR(self.indptr, self.indices[order], self.data[order],
                   self.nnz, self.shape, sorted_cols=True)

    def with_unsorted_flag(self) -> "CSR":
        """Same arrays, ``sorted_cols=False``: the static-metadata
        downgrade used to *request* select-order handling (e.g. to route
        a product away from the heap path in tests/benchmarks)."""
        return dataclasses.replace(self, sorted_cols=False)


def csr_transpose(a: CSR, cap: int | None = None,
                  return_perm: bool = False):
    """Host-side CSR transpose (numpy; not jittable): returns ``A^T`` as a
    sorted row-major CSR of shape ``(n_cols, n_rows)``.

    With ``return_perm=True`` also returns the int32 gather ``perm`` of
    shape ``(cap,)`` satisfying ``A^T.data == A.data[perm]`` over the live
    prefix (padded tail gathers slot 0 and must be masked by the caller).
    ``perm`` is the *structural* part of the transpose: it depends only on
    A's pattern, which is what lets transpose-aware plans
    (:func:`repro.core.chain.plan_gram`) freeze it once and re-gather only
    values on repeat executes -- one device gather instead of a host pass.
    """
    m, n = a.shape
    nnz = int(a.nnz)
    ip = np.asarray(a.indptr, np.int64)
    rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(ip))
    cols = np.asarray(a.indices, np.int64)[:nnz]
    vals = np.asarray(a.data)[:nnz]
    # stable (col, row) sort: within each T row the original row ids come
    # out ascending, so the result is sorted_cols by construction
    perm = np.lexsort((rows, cols)).astype(np.int32)
    if cap is None:
        cap = max(a.cap, 1)
    assert nnz <= cap, f"transpose nnz {nnz} exceeds capacity {cap}"
    indices = np.zeros(cap, np.int32)
    data = np.zeros(cap, vals.dtype if vals.size else np.float32)
    indices[:nnz] = rows[perm]
    data[:nnz] = vals[perm]
    counts = np.bincount(cols, minlength=n)
    indptr = np.zeros(n + 1, np.int32)
    np.cumsum(counts, out=indptr[1:])
    t = CSR(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(data),
            jnp.asarray(nnz, jnp.int32), (n, m), sorted_cols=True)
    if not return_perm:
        return t
    perm_full = np.zeros(cap, np.int32)
    perm_full[:nnz] = perm
    return t, jnp.asarray(perm_full)


def csr_sorted_keys(a: CSR) -> jax.Array:
    """Globally sorted ``row * n_cols + col`` int32 keys of a row-major CSR
    (sentinel-padded tail).  The precomputed form of :meth:`CSR.contains`,
    for loops that probe the same mask many times (the heap merge)."""
    assert a.sorted_cols, \
        "sorted keys need sorted_cols (call sort_rows first)"
    sentinel = jnp.int32(2**31 - 1)
    return jnp.where(a.valid_mask(),
                     a.row_ids() * jnp.int32(a.n_cols) + a.indices, sentinel)


def sorted_keys_contain(keys: jax.Array, key: jax.Array) -> jax.Array:
    """Membership of ``key`` (any shape) in sorted sentinel-padded ``keys``."""
    cap = keys.shape[0]
    pos = jnp.searchsorted(keys, key, side="left")
    return (keys[jnp.clip(pos, 0, cap - 1)] == key) & (pos < cap)


_register(CSR, ("indptr", "indices", "data", "nnz"), ("shape", "sorted_cols"))


@dataclass(frozen=True)
class BCSR:
    """Block CSR: dense (bm, bn) tiles in a CSR layout over the block grid.

    This is the TPU adaptation of the paper's CSR: the unit of sparsity is a
    hardware tile, so a "row" of Gustavson's algorithm becomes a *block row*
    and the accumulator hashes block-column ids while the MXU does the
    (bm x bk) @ (bk x bn) tile product.
    """
    indptr: jax.Array          # (n_brows + 1,) int32
    indices: jax.Array         # (bcap,) int32 block-column ids
    blocks: jax.Array          # (bcap, bm, bn)
    nnzb: jax.Array            # scalar int32
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    block: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def bcap(self) -> int:
        return self.indices.shape[0]

    @property
    def grid(self) -> Tuple[int, int]:
        # ceil division: non-tile-multiple logical shapes occupy a partial
        # last block row/column (the tile padding is storage-only; ``shape``
        # stays the logical extent and ``to_dense`` crops back to it)
        bm, bn = self.block
        return (-(-self.shape[0] // bm), -(-self.shape[1] // bn))

    @property
    def dtype(self):
        return self.blocks.dtype

    @staticmethod
    def from_dense(x: jax.Array, block: Tuple[int, int],
                   bcap: int | None = None) -> "BCSR":
        m, n = x.shape
        bm, bn = block
        gm, gn = -(-m // bm), -(-n // bn)
        pm, pn = gm * bm - m, gn * bn - n
        if pm or pn:
            # ragged logical shape: zero-pad into the tile grid; ``shape``
            # below records the *logical* (m, n) and ``to_dense`` crops
            x = jnp.pad(x, ((0, pm), (0, pn)))
        tiles = x.reshape(gm, bm, gn, bn).transpose(0, 2, 1, 3)   # (gm, gn, bm, bn)
        occ = jnp.any(tiles != 0, axis=(2, 3)).ravel()            # (gm*gn,)
        nnzb = occ.sum().astype(jnp.int32)
        if bcap is None:
            # exact capacity when concrete (the planner's eager path);
            # under trace the count is dynamic, so fall back to the grid
            bcap = gm * gn if isinstance(nnzb, jax.core.Tracer) \
                else max(int(nnzb), 1)
        order = jnp.argsort(~occ, stable=True)[:bcap]
        valid = jnp.arange(bcap, dtype=jnp.int32) < nnzb
        bcols = jnp.where(valid, (order % gn).astype(jnp.int32), 0)
        blocks = tiles.reshape(gm * gn, bm, bn)[order]
        blocks = jnp.where(valid[:, None, None], blocks, 0)
        counts = jnp.sum(occ.reshape(gm, gn), axis=1, dtype=jnp.int32)
        indptr = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts, dtype=jnp.int32)])
        return BCSR(indptr, bcols, blocks, nnzb, (m, n), block)

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.bcap, dtype=jnp.int32) < self.nnzb

    def brow_ids(self) -> jax.Array:
        e = jnp.arange(self.bcap, dtype=jnp.int32)
        r = jnp.searchsorted(self.indptr, e, side="right") - 1
        return jnp.clip(r, 0, self.grid[0] - 1).astype(jnp.int32)

    def to_dense(self) -> jax.Array:
        gm, gn = self.grid
        bm, bn = self.block
        dense = jnp.zeros((gm, gn, bm, bn), self.blocks.dtype)
        v = jnp.where(self.valid_mask()[:, None, None], self.blocks, 0)
        dense = dense.at[self.brow_ids(), self.indices].add(v)
        dense = dense.transpose(0, 2, 1, 3).reshape(gm * bm, gn * bn)
        return dense[:self.shape[0], :self.shape[1]]   # crop tile padding


_register(BCSR, ("indptr", "indices", "blocks", "nnzb"), ("shape", "block"))


@dataclass(frozen=True)
class ELL:
    """ELLPACK: fixed nonzeros-per-row padding. Used for regular rows
    (e.g. the tall-skinny BFS frontier stacks) where Gustavson degenerates
    to a uniform gather -- the paper's "uniform" regime."""
    indices: jax.Array   # (n_rows, width) int32, padded with 0
    data: jax.Array      # (n_rows, width)
    row_nnz: jax.Array   # (n_rows,) int32
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def width(self) -> int:
        return self.indices.shape[1]

    @staticmethod
    def from_csr(a: CSR, width: int) -> "ELL":
        m, n = a.shape
        r = jnp.arange(m, dtype=jnp.int32)[:, None]
        k = jnp.arange(width, dtype=jnp.int32)[None, :]
        src = a.indptr[:-1][:, None] + k
        ok = k < (a.indptr[1:] - a.indptr[:-1])[:, None]
        src = jnp.clip(src, 0, a.cap - 1)
        idx = jnp.where(ok, a.indices[src], 0)
        dat = jnp.where(ok, a.data[src], 0)
        del r
        return ELL(idx, dat, (a.indptr[1:] - a.indptr[:-1]).astype(jnp.int32),
                   (m, n))

    def to_dense(self) -> jax.Array:
        m, n = self.shape
        out = jnp.zeros((m, n), self.data.dtype)
        rows = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[:, None],
                                self.indices.shape)
        return out.at[rows, self.indices].add(self.data)


_register(ELL, ("indices", "data", "row_nnz"), ("shape",))


def csr_to_bcsr(a: CSR, block: Tuple[int, int], bcap: int | None = None) -> BCSR:
    """Re-tile a scalar CSR into block CSR.

    Concrete inputs take a host-exact sparse pass: block keys straight from
    (indptr, indices), exact default ``bcap`` (= occupied blocks), no dense
    staging -- so huge-but-sparse matrices convert without materializing
    ``m * n`` cells.  Ragged (non-tile-multiple) logical shapes land in a
    ceil-divided grid with a partial last block row/column.  Under trace
    the structure is dynamic, so conversion falls back to dense staging
    (format conversion is data-pipeline work, not a jit-hot path).
    """
    if isinstance(a.indptr, jax.core.Tracer) or \
            isinstance(a.indices, jax.core.Tracer) or \
            isinstance(a.data, jax.core.Tracer) or \
            isinstance(a.nnz, jax.core.Tracer):
        return BCSR.from_dense(a.to_dense(), block, bcap)  # verify: allow(no-densify)
    bm, bn = block
    m, n = a.shape
    gm, gn = -(-m // bm), -(-n // bn)
    nnz = int(a.nnz)
    ip = np.asarray(a.indptr, np.int64)
    rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(ip))[:nnz]
    cols = np.asarray(a.indices, np.int64)[:nnz]
    vals = np.asarray(a.data)[:nnz]
    key = (rows // bm) * gn + (cols // bn)
    uniq, inv = np.unique(key, return_inverse=True)
    nnzb = int(uniq.size)
    if bcap is None:
        bcap = max(nnzb, 1)
    assert nnzb <= bcap, f"block nnz {nnzb} exceeds capacity {bcap}"
    blocks = np.zeros((bcap, bm, bn),
                      vals.dtype if vals.size else np.float32)
    blocks[inv, rows % bm, cols % bn] = vals
    bcols = np.zeros(bcap, np.int32)
    bcols[:nnzb] = uniq % gn            # sorted within block rows (row-major)
    counts = np.bincount(uniq // gn, minlength=gm)
    indptr = np.zeros(gm + 1, np.int32)
    np.cumsum(counts, out=indptr[1:])
    return BCSR(jnp.asarray(indptr), jnp.asarray(bcols), jnp.asarray(blocks),
                jnp.asarray(nnzb, jnp.int32), (m, n), block)


def bcsr_to_csr(a: BCSR, cap: int | None = None, prune: bool = True) -> CSR:
    """Flatten a block CSR back to scalar CSR (sorted row-major).

    Stored blocks are dense tiles, so flattening emits every in-tile cell --
    including the zeros a sparse scalar pattern was padded with when the
    matrix was re-tiled.  The ``prune`` epilogue (default on) drops those
    explicit zeros so ``bcsr_to_csr(csr_to_bcsr(a, block))`` round-trips
    with ``nnz`` equal to the input's; pass ``prune=False`` to keep the
    dense-tile pattern (every stored cell inside the logical shape becomes
    an explicit entry).  Cells past the logical shape (ragged tile padding)
    are always cropped.  Concrete inputs run a host sparse pass; traced
    inputs fall back to dense staging with ``prune`` semantics matching
    ``CSR.from_dense`` (zeros dropped).
    """
    if isinstance(a.indptr, jax.core.Tracer) or \
            isinstance(a.indices, jax.core.Tracer) or \
            isinstance(a.blocks, jax.core.Tracer) or \
            isinstance(a.nnzb, jax.core.Tracer):
        return CSR.from_dense(a.to_dense(), cap)  # verify: allow(no-densify)
    bm, bn = a.block
    m, n = a.shape
    nnzb = int(a.nnzb)
    ip = np.asarray(a.indptr, np.int64)
    brows = np.repeat(np.arange(a.grid[0], dtype=np.int64),
                      np.diff(ip))[:nnzb]
    bcols = np.asarray(a.indices, np.int64)[:nnzb]
    blocks = np.asarray(a.blocks)[:nnzb]
    ii, jj = np.meshgrid(np.arange(bm, dtype=np.int64),
                         np.arange(bn, dtype=np.int64), indexing="ij")
    rows = (brows[:, None, None] * bm + ii[None]).ravel()
    cols = (bcols[:, None, None] * bn + jj[None]).ravel()
    vals = blocks.reshape(-1)
    keep = (rows < m) & (cols < n)      # crop ragged tile padding
    if prune:
        keep &= vals != 0               # drop block-padding explicit zeros
    return CSR.from_numpy_coo(rows[keep], cols[keep], vals[keep], (m, n),
                              cap=cap)
