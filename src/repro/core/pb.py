"""Inspector-executor planner for propagation-blocking SpGEMM
(DESIGN.md section 18).

The hash planner (:mod:`repro.core.plan`) freezes Gustavson-style row
products; this module freezes the *outer-product* formulation instead,
following Gu/Moreira/Edelsohn/Azad ("Bandwidth-Optimized Parallel
Algorithms for SpGEMM using Propagation Blocking", PAPERS.md).  The
inspection expands every partial product A[r,k]*B[k,c] once, buckets it
by a cache/VMEM-sized *column segment* (``schedule.pb_bucket_layout``),
and resolves its destination slot in the column-sorted CSR of C.  What
freezes into a :class:`PBPlan` is pure gather/scatter geometry:

  src_a[g, i], src_b[g, i]  -- operand value slots of product i of bucket g
  seg[g, i]                 -- its output slot in C (same for duplicates)
  bucket_nnz[g]             -- live lanes per bucket

so repeat executes run two numeric Pallas grids (scatter then merge,
:mod:`repro.kernels.spgemm_pb`) with zero re-inspection
(counter-verified via ``KERNEL_CALLS["inspect"]``).  Because a bucket
owns a contiguous column range, every duplicate of one output coordinate
lands in exactly one bucket -- buckets touch disjoint output slots, which
is the invariant that deletes the global hash table (and, on the mesh,
the dense psum accumulator).

PB pays one partial-product expansion of size flop; it wins when the
*compression factor* flop/nnz(C) is low (little duplicate collapse, so a
hash table mostly misses) -- the routing signal ``recipe.py`` uses.

Masks are pruned *here*, structurally, at plan time: a masked product
simply never enters a bucket, so the executor stays mask-free and repeat
executes inherit the pruning for free.

Plans are cached in the shared LRU of :mod:`repro.core.plan` under the
``"pb"`` kind, keyed by operand structure (never values).  Planning is
host-side eager (numpy); ``execute`` is trace-friendly under ``jit``,
``shard_map`` bodies, and -- via the kernels' ``custom_vmap`` rules --
``vmap`` over value fleets.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import schedule as sched
from .formats import CSR
from .plan import cache_lookup, cache_store, structure_key
from .semiring import resolve_semiring


def _pad8(n: int) -> int:
    """Round a capacity up to a multiple of 8 (sublane-friendly)."""
    return -(-int(n) // 8) * 8


def _expand_products(a: CSR, b: CSR):
    """Enumerate all partial products of A @ B on the host (numpy).

    Returns ``(jj, tt, r, c)``: for product p, ``jj[p]``/``tt[p]`` are
    the value slots in A/B and ``r[p]``/``c[p]`` its output coordinate.
    Same searchsorted expansion as ``spgemm._expand``, but kept in numpy
    because the results freeze into the plan as static geometry.
    """
    m = a.shape[0]
    ip_a = np.asarray(a.indptr, dtype=np.int64)
    ip_b = np.asarray(b.indptr, dtype=np.int64)
    live_a = int(ip_a[-1])
    rows_a = np.repeat(np.arange(m, dtype=np.int64), np.diff(ip_a))
    k_of = np.asarray(a.indices, dtype=np.int64)[:live_a]
    cnt = ip_b[k_of + 1] - ip_b[k_of]
    off = np.concatenate([[0], np.cumsum(cnt)]).astype(np.int64)
    total = int(off[-1])
    sched.guard_i32_flop(total)
    p = np.arange(total, dtype=np.int64)
    jj = np.searchsorted(off, p, side="right") - 1
    tt = ip_b[k_of[jj]] + (p - off[jj])
    return jj, tt, rows_a[jj], np.asarray(b.indices, dtype=np.int64)[tt]


def _mask_keep(mask: CSR, r, c, n: int, complement: bool):
    """Structural membership of (r, c) in the mask pattern (host-side)."""
    mip = np.asarray(mask.indptr, dtype=np.int64)
    mlive = int(mip[-1])
    mrows = np.repeat(np.arange(mask.shape[0], dtype=np.int64),
                      np.diff(mip))
    mkeys = np.sort(mrows * n + np.asarray(mask.indices,
                                           dtype=np.int64)[:mlive])
    keys = r * n + c
    if mkeys.size == 0:
        member = np.zeros(keys.shape[0], dtype=bool)
    else:
        pos = np.minimum(np.searchsorted(mkeys, keys), mkeys.size - 1)
        member = mkeys[pos] == keys
    return ~member if complement else member


@dataclass(frozen=True)
class PBPlan:
    """Frozen propagation-blocking recipe for one (A, B) structure pair.

    Bucket geometry (``bucket_w`` columns per bucket, power of two) plus
    the fully resolved gather/scatter arrays and C's exact column-sorted
    structure.  All capacities are Python ints, so structure-identical
    executes hit the jit dispatch cache.
    """
    key: tuple = dataclasses.field(repr=False)
    shape_a: Tuple[int, int]
    shape_b: Tuple[int, int]
    cap_a: int
    cap_b: int
    nnz_a: int
    nnz_b: int
    semiring: str
    has_mask: bool
    complement_mask: bool
    # --- bucket geometry ------------------------------------------------
    n_buckets: int
    bucket_w: int            # columns per bucket (power of two)
    bucket_cap: int          # padded max products per bucket
    total_flop: int          # products after structural mask pruning
    # --- frozen gather/scatter arrays -----------------------------------
    src_a: jax.Array = dataclasses.field(repr=False)   # (n_buckets, cap)
    src_b: jax.Array = dataclasses.field(repr=False)   # (n_buckets, cap)
    seg: jax.Array = dataclasses.field(repr=False)     # (n_buckets, cap)
    bucket_nnz: jax.Array = dataclasses.field(repr=False)  # (n_buckets,)
    # --- exact output structure (column-sorted) -------------------------
    cols_c: jax.Array = dataclasses.field(repr=False)  # (cap_c,)
    indptr_c: jax.Array = dataclasses.field(repr=False)
    row_nnz_c: jax.Array = dataclasses.field(repr=False)
    nnz_c: int = 0
    cap_c: int = 1
    provenance: str = "planned"

    # -------------------------------------------------------------------
    def check_structure(self, a: CSR, b: CSR) -> None:
        """Cheap structure guard (shapes/caps/nnz).

        Executing a different structure would gather from wrong slots;
        nnz is guarded only when concrete so a jit over re-valued
        operands does not trip a concretization error.
        """
        assert a.shape == self.shape_a and b.shape == self.shape_b, \
            f"plan is for {self.shape_a}x{self.shape_b}, " \
            f"got {a.shape}x{b.shape}"
        assert a.cap == self.cap_a and b.cap == self.cap_b, \
            "operand capacities differ from the planned structure"
        for op, planned in ((a, self.nnz_a), (b, self.nnz_b)):
            if not isinstance(op.nnz, jax.core.Tracer):
                assert int(op.nnz) == planned, \
                    "operand nnz differs from the planned structure " \
                    "(replan or clear_plan_cache)"

    def execute(self, a: CSR, b: CSR) -> CSR:
        """Numeric phases only: bucket scatter + per-bucket merge over
        this plan's frozen geometry -- zero re-inspection (counter-
        verified by ``KERNEL_CALLS["inspect"]``).  C is column-sorted.

        plus_times runs the Pallas pair; general semirings thread the
        identical frozen gathers through the jnp twin (``ref.py``).
        """
        self.check_structure(a, b)
        from repro.kernels.spgemm_pb import ops as pb_ops
        if self.semiring == "plus_times":
            return pb_ops.spgemm_pb(
                a, b, self.cap_c, src_a=self.src_a, src_b=self.src_b,
                seg=self.seg, bucket_nnz=self.bucket_nnz,
                indptr_c=self.indptr_c, cols_c=self.cols_c)
        from repro.kernels.spgemm_pb.ref import pb_numeric_ref
        data = pb_numeric_ref(
            a.data, b.data, self.src_a, self.src_b, self.seg,
            self.bucket_nnz, self.cap_c, self.indptr_c[-1],
            semiring=self.semiring).astype(a.data.dtype)
        m, n = self.shape_a[0], self.shape_b[1]
        return CSR(self.indptr_c, self.cols_c, data, self.indptr_c[-1],
                   (m, n), sorted_cols=True)

    __call__ = execute


def plan_pb(a: CSR, b: CSR, *, semiring: str = "plus_times",
            mask: Optional[CSR] = None, complement_mask: bool = False,
            n_buckets: Optional[int] = None,
            budget: int = sched.PB_BUCKET_BUDGET,
            cache: bool = True) -> PBPlan:
    """Run the propagation-blocking inspection once, freeze a :class:`PBPlan`.

    With ``cache=True`` (default) the shared plan LRU is consulted first
    under the ``"pb"`` kind: a structure-identical repeat request returns
    the existing plan and skips the expansion entirely.
    """
    assert a.shape[1] == b.shape[0], \
        f"inner dim mismatch: {a.shape} @ {b.shape}"
    sr = resolve_semiring(semiring)
    if mask is not None:
        assert mask.shape == (a.shape[0], b.shape[1]), \
            f"mask shape {mask.shape} != output {(a.shape[0], b.shape[1])}"
    key = ("pb", structure_key(a), structure_key(b),
           structure_key(mask) if mask is not None else None,
           sr.name, complement_mask, n_buckets, budget)
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit

    from repro.kernels.spgemm_pb import ops as pb_ops
    pb_ops.KERNEL_CALLS["inspect"] += 1
    m, n = a.shape[0], b.shape[1]

    jj, tt, r, c = _expand_products(a, b)
    if mask is not None:
        keep = _mask_keep(mask, r, c, n, complement_mask)
        jj, tt, r, c = jj[keep], tt[keep], r[keep], c[keep]
    total = int(r.shape[0])

    bucket_w, nb = sched.pb_bucket_layout(n, n_buckets, total_flop=total,
                                          budget=budget)

    # Exact output structure: sort products by (row, col), collapse
    # duplicates; every product learns its output slot in sorted C.
    uo = np.lexsort((c, r))
    rs, cs = r[uo], c[uo]
    new = np.ones(total, dtype=bool)
    if total:
        new[1:] = (rs[1:] != rs[:-1]) | (cs[1:] != cs[:-1])
    slot = np.zeros(total, dtype=np.int64)
    slot[uo] = np.cumsum(new) - 1
    nnz_c = int(new.sum())
    cap_c = max(nnz_c, 1)
    row_nnz_c = np.bincount(rs[new], minlength=m).astype(np.int32)
    indptr_c = np.concatenate([[0], np.cumsum(row_nnz_c)]).astype(np.int32)
    cols_full = np.zeros(cap_c, dtype=np.int32)
    cols_full[:nnz_c] = cs[new]

    # Bucket packing: bucket-major, (row, col) within a bucket -- the
    # accumulation order both the kernel loop and the jnp twin walk.
    bucket = c // bucket_w
    order = np.lexsort((c, r, bucket))  # bucket-major, then (r, c)
    bseq = bucket[order]
    bucket_nnz = np.bincount(bseq, minlength=nb).astype(np.int32)
    bucket_cap = _pad8(max(int(bucket_nnz.max()), 1)) if total else 8
    starts = np.concatenate([[0], np.cumsum(bucket_nnz)]).astype(np.int64)
    lane = np.arange(total, dtype=np.int64) - starts[bseq]
    src_a = np.zeros((nb, bucket_cap), dtype=np.int32)
    src_b = np.zeros((nb, bucket_cap), dtype=np.int32)
    seg = np.full((nb, bucket_cap), cap_c, dtype=np.int32)
    if total:
        src_a[bseq, lane] = jj[order]
        src_b[bseq, lane] = tt[order]
        seg[bseq, lane] = slot[order]

    plan = PBPlan(
        key=key, shape_a=a.shape, shape_b=b.shape, cap_a=a.cap,
        cap_b=b.cap, nnz_a=int(a.nnz), nnz_b=int(b.nnz), semiring=sr.name,
        has_mask=mask is not None, complement_mask=complement_mask,
        n_buckets=nb, bucket_w=bucket_w, bucket_cap=bucket_cap,
        total_flop=total, src_a=jnp.asarray(src_a),
        src_b=jnp.asarray(src_b), seg=jnp.asarray(seg),
        bucket_nnz=jnp.asarray(bucket_nnz), cols_c=jnp.asarray(cols_full),
        indptr_c=jnp.asarray(indptr_c), row_nnz_c=jnp.asarray(row_nnz_c),
        nnz_c=nnz_c, cap_c=cap_c)
    if cache:
        cache_store(key, plan)
    return plan
