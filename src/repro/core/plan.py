"""Inspector-executor SpGEMM planner (DESIGN.md section 10).

The paper's two-phase method (Fig. 7) and ``RowsToThreads`` scheduling
(Fig. 6) are pure *inspection*: for a fixed sparsity structure they can be
computed once and reused across every numeric product.  That is exactly the
repeated-product shape of graph workloads (multi-source BFS iterations,
triangle counting, A.A chains) and of a serving system answering many
products over the same graph -- the symbolic/numeric split-and-reuse that
Deveci et al. (arXiv:1801.03065) make a first-class API in KokkosKernels.

:func:`plan_spgemm` runs the full inspection once -- flop counting, equal-
flop binning, per-bin hash-table sizing, the exact symbolic phase, and the
recipe's algorithm choice -- and freezes the result in a :class:`SpGEMMPlan`.
``plan.execute(a, b)`` (or ``spgemm(a, b, plan=plan)``) then runs only the
numeric work: no schedule, no symbolic kernel, no recipe, and -- because
every capacity in the plan is a deterministic static int -- no retracing
once each (algorithm, capacity) program is compiled.

Plans are cached under a **structure key**: a blake2b digest of each
operand's ``(shape, cap, nnz, indptr, indices)`` plus the request's
semantic fields (semiring, mask structure, complement flag, sortedness,
algorithm, use case, n_bins).  Values deliberately do not enter the key --
a re-weighted graph with the same adjacency hits the cached plan.
Invalidation is by construction: a structural change produces a different
key, and :func:`clear_plan_cache` empties the table wholesale.  Every key
leads with a string **kind** namespace -- ``"spgemm"`` here; the
distributed plans (``"dist_1d"``/``"summa"``) and chain plans
(``"chain"``/``"chain_1d"``/``"gram"``) share the same LRU under their
own kinds (:func:`plan_cache_stats` reports per-kind occupancy).

Planning is a host-side (eager) operation: the exact capacities must be
concrete Python ints to become static shapes.  ``execute`` is
trace-friendly -- it only calls the already-specialized numeric
primitives, and since the plan-frozen hash schedules ride as array
operands (not static arguments), the planned hash path runs unchanged
under ``jit``, ``vmap`` (a batched grid over members via the kernels'
``custom_vmap`` rule), and inside ``shard_map`` bodies (DESIGN.md
section 14).  ``spgemm_hash_jnp`` survives in the dispatch only as the
reference oracle and as the body for general semirings.
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import CSR
from .semiring import Semiring, resolve_semiring
from . import schedule as sched
from .spgemm import (_canon_mask, _check_mask, finalize, spgemm_dense,
                     spgemm_esc, spgemm_hash_jnp, spgemm_heap, symbolic)


def structure_key(a: CSR) -> bytes:
    """Digest of a CSR's *structure* (pattern + static layout), not values.

    Covers shape, capacity, nnz, and the indptr/indices arrays (padded
    tails are zeros by the CSR contract, so whole-array hashing is
    deterministic).  Two CSRs with equal keys run identically through
    schedule + symbolic, which is what makes plan reuse sound.
    """
    cached = a.__dict__.get("_structure_digest")
    if cached is not None:
        return cached
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((a.shape, a.cap, int(a.nnz), a.sorted_cols)).encode())
    h.update(np.asarray(a.indptr).tobytes())
    h.update(np.asarray(a.indices).tobytes())
    digest = h.digest()
    # memoize on the (frozen, immutable-field) instance: jax arrays cannot
    # be mutated in place and dataclasses.replace builds a fresh object, so
    # the digest can never go stale; repeat lookups (the serving loop's
    # per-hop cache hits) skip the O(nnz) host transfer + hash
    object.__setattr__(a, "_structure_digest", digest)
    return digest


#: plan cache: PlanKey tuple -> SpGEMMPlan (insertion-ordered; LRU-bounded
#: so a serving loop over many structures cannot grow host/device memory
#: without bound -- each entry pins O(m) arrays plus the mask CSR)
_CACHE: dict = {}
_STATS = {"hits": 0, "misses": 0}
#: maximum cached plans; oldest-used evicted first.
PLAN_CACHE_CAPACITY = 256

#: every plan-kind namespace that may appear as a cache key's leading
#: string.  ``plan_cache_stats()["kinds"]`` reports a zero entry for each
#: registered kind even on a cold cache, so dashboards can key on a kind
#: unconditionally; new plan families register here when they add a kind.
PLAN_KINDS = ("spgemm", "dist_1d", "summa", "chain", "chain_1d", "gram",
              "batch", "batch_power", "bcsr", "pb")


def plan_cache_stats() -> dict:
    """Copy of the cache counters: ``{'hits', 'misses', 'size', 'kinds'}``.

    ``kinds`` counts live entries per plan *kind* -- the string namespace
    every key leads with: ``"spgemm"`` (single-node), ``"dist_1d"`` /
    ``"summa"`` (``core.distributed``), ``"chain"`` / ``"chain_1d"`` /
    ``"gram"`` (``core.chain``), ``"batch"`` / ``"batch_power"``
    (``core.batch``).  Every kind in :data:`PLAN_KINDS` is present in the
    dict -- zero when it has no live entries -- so a cold cache never
    KeyErrors a dashboard.  All kinds share one LRU, one capacity bound
    (:data:`PLAN_CACHE_CAPACITY`), and one :func:`clear_plan_cache`.
    """
    kinds: dict = {kind: 0 for kind in PLAN_KINDS}
    for key in _CACHE:
        kind = key[0] if isinstance(key[0], str) else "spgemm"
        kinds[kind] = kinds.get(kind, 0) + 1
    return {**_STATS, "size": len(_CACHE), "kinds": kinds}


def clear_plan_cache() -> None:
    """Empty the shared plan LRU (all kinds) and reset the hit/miss
    counters.  Plans already held by callers stay valid -- the cache only
    governs lookup, never plan lifetime."""
    _CACHE.clear()
    _STATS["hits"] = _STATS["misses"] = 0


def cache_lookup(key: tuple):
    """Consult the shared plan LRU (counts a hit or a miss).

    The cache is deliberately kind-agnostic: single-node ``SpGEMMPlan``s and
    the distributed plans of ``core.distributed`` live in one table under
    disjoint key namespaces, so one capacity bound and one ``clear`` govern
    every frozen inspection product.
    """
    hit = _CACHE.get(key)
    if hit is not None:
        _STATS["hits"] += 1
        _CACHE[key] = _CACHE.pop(key)              # LRU: move to newest
        return hit
    _STATS["misses"] += 1
    return None


def cache_store(key: tuple, value) -> None:
    """Insert into the shared plan LRU, evicting least-recent past capacity.

    Pop-before-insert: a re-stored existing key must move to the newest
    position, exactly like a :func:`cache_lookup` hit.  Plain
    ``_CACHE[key] = value`` would overwrite in place and keep the key's
    *old* dict position, so a just-refreshed plan could be evicted as
    "least recent" by the very next store (regression-pinned by
    ``tests/test_plan.py::test_plan_cache_restore_refreshes_recency``).
    """
    _CACHE.pop(key, None)                          # refresh recency on re-store
    _CACHE[key] = value
    while len(_CACHE) > PLAN_CACHE_CAPACITY:
        _CACHE.pop(next(iter(_CACHE)))             # evict least-recent


def _plan_key(a: CSR, b: CSR, mask: Optional[CSR], sr_name: str,
              complement_mask: bool, sorted_output: bool, algorithm: str,
              use_case: Optional[str], n_bins: int) -> tuple:
    # "spgemm" is this key's kind namespace: every plan family in the
    # shared LRU (dist_1d / summa / chain / chain_1d / gram) leads with a
    # distinct string, so keys can never collide across kinds and
    # plan_cache_stats can report per-kind occupancy.
    return ("spgemm", structure_key(a), structure_key(b),
            None if mask is None else structure_key(mask),
            sr_name, complement_mask, sorted_output, algorithm, use_case,
            n_bins)


@dataclass(frozen=True)
class SpGEMMPlan:
    """Frozen product recipe for one (A-structure, B-structure) pair.

    Everything the executor needs, nothing recomputed: the flop profile and
    equal-flop bin offsets (Fig. 6), the per-bin power-of-two hash-table
    sizes and the static scratch allocation (Fig. 7 lines 9-12), the exact
    ``indptr_c``/capacities from the symbolic phase, and the recipe's
    algorithm choice.  All capacities are Python ints -- static shapes --
    so structure-identical executes hit the jit dispatch cache.
    """
    key: tuple = dataclasses.field(repr=False)
    algorithm: str
    semiring: str
    complement_mask: bool
    sorted_output: bool
    mask: Optional[CSR] = dataclasses.field(repr=False)
    shape_a: Tuple[int, int]
    shape_b: Tuple[int, int]
    cap_a: int
    cap_b: int
    nnz_a: int
    nnz_b: int
    n_bins: int
    # --- inspection products -------------------------------------------
    flop: jax.Array = dataclasses.field(repr=False)      # per-row flop
    total_flop: int
    flop_cap: int            # exact expansion bound for esc/jnp-hash paths
    offsets: jax.Array = dataclasses.field(repr=False)   # (n_bins + 1,)
    bin_tsize: jax.Array = dataclasses.field(repr=False)  # (n_bins,) p2
    table_size: int          # static scratch allocation (bin max, p2)
    row_nnz_c: jax.Array = dataclasses.field(repr=False)
    indptr_c: jax.Array = dataclasses.field(repr=False)
    nnz_c: int
    cap_c: int               # exact nnz(C) as a static capacity
    row_cap: int             # heap: max nnz(c_i*)
    k_width: int             # heap: max nnz(a_i*)
    #: where the algorithm choice came from: ``"explicit"`` (caller pinned
    #: it), ``"heuristic"`` (Table-4 recipe), or ``"measured"`` (autotune
    #: DB / microbenchmark, DESIGN.md section 16).
    provenance: str = "explicit"
    #: BCSR routing only (``algorithm == "bcsr"``): the tile shape the CSR
    #: operands are re-blocked into and the frozen block-level plan
    #: (:class:`repro.core.bcsr.BCSRPlan`) the execute runs through.
    block: Optional[Tuple[int, int]] = None
    bcsr_plan: object = dataclasses.field(default=None, repr=False)
    #: PB routing only (``algorithm == "pb"``): the frozen propagation-
    #: blocking plan (:class:`repro.core.pb.PBPlan`) the execute runs
    #: through -- bucket geometry and output structure both frozen, so
    #: repeat executes stay numeric-only (DESIGN.md section 18).
    pb_plan: object = dataclasses.field(default=None, repr=False)

    # -------------------------------------------------------------------
    def check_structure(self, a: CSR, b: CSR, strict: bool = False) -> None:
        """Cheap (shapes/caps/nnz) or strict (re-hash) structure check.

        Executing a plan against a *different* structure silently produces
        wrong capacities, so the cheap check always runs; ``strict=True``
        re-digests both operands (costs a host transfer -- debugging aid).
        """
        assert a.shape == self.shape_a and b.shape == self.shape_b, \
            f"plan is for {self.shape_a}x{self.shape_b}, " \
            f"got {a.shape}x{b.shape}"
        assert a.cap == self.cap_a and b.cap == self.cap_b, \
            "operand capacities differ from the planned structure"
        for op, planned in ((a, self.nnz_a), (b, self.nnz_b)):
            # each operand guarded independently: jit over just one of
            # them (e.g. a re-weighted B in a serving loop) must not trip
            # a concretization error on the other's check
            if not isinstance(op.nnz, jax.core.Tracer):
                assert int(op.nnz) == planned, \
                    "operand nnz differs from the planned structure " \
                    "(replan or clear_plan_cache)"
        if strict:
            assert (structure_key(a), structure_key(b)) == self.key[1:3], \
                "operand structure differs from the planned structure"

    def execute(self, a: CSR, b: CSR,
                sorted_output: Optional[bool] = None) -> CSR:
        """Numeric phase only: same contract as ``spgemm`` with this plan's
        recorded algorithm/semiring/mask, zero re-inspection.

        ``sorted_output`` overrides the plan's recorded sortedness for this
        call (``None`` keeps it).  Sorting is a pure epilogue
        (:func:`repro.core.spgemm.finalize`) -- it changes no capacity and
        no accumulator state -- so one cached plan legally serves both the
        sorted and the unsorted consumer; the chain executor uses this to
        keep intermediates unsorted under a plan whose final output is
        sorted on request (DESIGN.md section 12).
        """
        self.check_structure(a, b)
        sr = resolve_semiring(self.semiring)
        general = sr.name != "plus_times" or self.mask is not None
        algo = self.algorithm
        if algo == "dense":
            out = spgemm_dense(a, b, self.cap_c, semiring=sr,
                                 mask=self.mask,
                                 complement_mask=self.complement_mask)
        elif algo == "esc":
            out = spgemm_esc(a, b, self.cap_c, flop_cap=self.flop_cap,
                               semiring=sr, mask=self.mask,
                               complement_mask=self.complement_mask)
        elif algo == "heap":
            out = spgemm_heap(a, b, row_cap=self.row_cap,
                                k_width=self.k_width, cap_c=self.cap_c,
                                semiring=sr, mask=self.mask,
                                complement_mask=self.complement_mask)
        elif algo == "bcsr":
            # re-block the CSR operands into the planned tile grid (bcap
            # pinned by the plan so the conversion is shape-stable under
            # trace), run the frozen block plan, flatten back to CSR.
            from .bcsr import BCSRPlan
            from .formats import BCSR, bcsr_to_csr
            bp = self.bcsr_plan
            assert isinstance(bp, BCSRPlan) and self.block is not None, \
                "bcsr plan is missing its nested block plan"
            ab = BCSR.from_dense(a.to_dense(), bp.block_a, bcap=bp.bcap_a)  # verify: allow(no-densify)
            bb = BCSR.from_dense(b.to_dense(), bp.block_b, bcap=bp.bcap_b)  # verify: allow(no-densify)
            out = bcsr_to_csr(bp.execute(ab, bb), cap=self.cap_c)
        elif algo == "pb":
            # run the nested propagation-blocking plan (scatter + merge
            # over frozen bucket geometry); pad the exact-capacity output
            # up to this plan's cap_c when bucket_caps rounded it.
            from .pb import PBPlan
            pbp = self.pb_plan
            assert isinstance(pbp, PBPlan), \
                "pb plan is missing its nested bucket plan"
            out = pbp.execute(a, b)
            if out.cap < self.cap_c:
                pad = self.cap_c - out.cap
                out = CSR(out.indptr, jnp.pad(out.indices, (0, pad)),
                          jnp.pad(out.data, (0, pad)), out.nnz, out.shape,
                          out.sorted_cols)
        elif algo in ("hash", "hash_vector", "hash_jnp"):
            if general or algo == "hash_jnp":
                out = spgemm_hash_jnp(a, b, self.cap_c,
                                        flop_cap=self.flop_cap, semiring=sr,
                                        mask=self.mask,
                                        complement_mask=self.complement_mask)
            else:
                from repro.kernels.spgemm_hash import ops as hash_ops
                out = hash_ops.spgemm_hash(
                    a, b, self.cap_c, vector=(algo == "hash_vector"),
                    table_size=self.table_size,
                    schedule=(self.offsets, self.bin_tsize),
                    indptr_c=self.indptr_c)
        else:
            raise ValueError(f"plan holds unknown algorithm {algo!r}")
        so = self.sorted_output if sorted_output is None else sorted_output
        return finalize(out, so)

    __call__ = execute


def plan_spgemm(a: CSR, b: CSR, *, algorithm: str = "auto",
                semiring: str | Semiring = "plus_times",
                mask: Optional[CSR] = None, complement_mask: bool = False,
                sorted_output: bool = False, use_case: Optional[str] = None,
                n_bins: int = 8, cache: bool = True,
                bucket_caps: bool = False, a_row_nnz=None,
                autotune: bool = False, autotune_db=None,
                block: Tuple[int, int] = (8, 8)) -> SpGEMMPlan:
    """Run the full inspection once and freeze it as a :class:`SpGEMMPlan`.

    With ``cache=True`` (default) the structure-keyed cache is consulted
    first: a structure-identical repeat request returns the existing plan
    and skips schedule + symbolic + recipe entirely.

    ``autotune=True`` (with ``algorithm="auto"``) resolves the algorithm
    through the measured recipe instead of the Table-4 heuristics: the
    persistent autotune DB (:mod:`repro.autotune`) is consulted under the
    structure/backend key, a miss microbenchmarks the candidates on the
    actual operands and persists the winner, and any DB trouble degrades
    to the heuristic with a warning.  The plan records where its choice
    came from in :attr:`SpGEMMPlan.provenance` (``"measured"`` vs
    ``"heuristic"`` vs ``"explicit"``), a winning hash-table-size variant
    is applied to the frozen schedule, and ``autotune_db`` overrides the
    default DB path.  Autotuned and heuristic requests are distinct plan
    cache entries.

    ``bucket_caps=True`` rounds the static capacities (``cap_c``,
    ``flop_cap``, heap ``row_cap``) up to powers of two.  Exact capacities
    (the default) allocate nothing beyond nnz(C), but every distinct
    structure then compiles its own numeric program; bucketing trades a
    <2x allocation slack for program sharing across *similar* structures
    -- the right call inside loops whose structure drifts every iteration
    (e.g. BFS frontiers, MCL expansion) where exactness would retrace
    each hop.

    ``a_row_nnz`` marks A as a chain intermediate: pass the previous
    stage's recorded ``plan.row_nnz_c`` and the recipe's A-side statistics
    come from that recorded structure instead of the handed-in buffer
    (``recipe.recommend``'s mid-chain hook; used by ``core.chain``).

    ``block`` is the tile shape the ``"bcsr"`` routing re-blocks the CSR
    operands into (A tiles ``block``, B tiles ``(block[1], block[1])``);
    it only matters when the resolved algorithm is ``"bcsr"`` (explicit,
    recipe block-density routing, or a measured autotune lane) -- the plan
    then nests a frozen :class:`repro.core.bcsr.BCSRPlan` built at
    planning time, so repeat executes stay numeric-only at both
    granularities (DESIGN.md section 17).
    """
    sr = resolve_semiring(semiring)
    arn_digest = None
    if a_row_nnz is not None:
        # a_row_nnz can steer the recipe's auto choice, so it must reach
        # the cache key; digest rather than store the array itself.
        arn_digest = hashlib.blake2b(np.asarray(a_row_nnz).tobytes(),
                                     digest_size=8).digest()
    block = tuple(block)
    key = _plan_key(a, b, mask, sr.name, complement_mask, sorted_output,
                    algorithm, use_case, n_bins) + (bucket_caps, arn_digest,
                                                    autotune, block)
    if cache:
        hit = cache_lookup(key)
        if hit is not None:
            return hit

    from repro.kernels.spgemm_hash import kernel as HK
    _check_mask(a, b, mask)
    mask = _canon_mask(mask)
    n = b.n_cols

    # Fig. 6: flop profile + equal-flop bins.  The eager form is the same
    # code path make_schedule jits, but here the inputs are concrete so
    # the int32 overflow guard raises loudly instead of mis-binning.
    flop, offsets, tsize = sched.make_schedule_eager(a, b, n_bins)
    max_row_flop = int(jnp.max(flop)) if flop.size else 0
    total_flop = int(jnp.sum(flop))

    # Fig. 7 lines 9-12: static scratch allocation = global-max p2 bound;
    # per-bin effective sizes ride in the plan as prefetched scalars.
    table_size = max(sched.lowest_p2(min(max_row_flop, n) + 1), HK.CHUNK)
    bin_tsize = sched.bin_table_sizes(tsize, n, table_size, floor=HK.CHUNK)

    # Symbolic phase with the exact flop bound -- the worst-case
    # O(cap_a * min(cap_b, n)) default buffer is never allocated on replan.
    flop_cap = max(total_flop, 1)
    if bucket_caps:
        flop_cap = sched.lowest_p2(flop_cap)
    row_nnz_c, indptr_c, _, _ = symbolic(
        a, b, mask=mask, complement_mask=complement_mask, flop_cap=flop_cap)
    nnz_c = int(jnp.sum(row_nnz_c))
    cap_c = max(nnz_c, 1)
    row_cap = max(int(jnp.max(row_nnz_c)), 1)
    k_width = max(int(jnp.max(a.row_nnz())), 1)
    if bucket_caps:
        cap_c = sched.lowest_p2(cap_c)
        row_cap = sched.lowest_p2(row_cap)

    if algorithm == "heap" and not (a.sorted_cols and b.sorted_cols):
        # match the direct dispatcher: an explicit heap request on
        # unsorted inputs fails loudly (spgemm_heap's own contract)
        raise AssertionError("heap path requires sorted inputs")
    provenance = "explicit"
    table_scale = 1
    if algorithm == "auto":
        uc = use_case if use_case is not None else \
            ("masked" if mask is not None else "AxA")
        if autotune:
            from repro.autotune import measured_recommend
            choice = measured_recommend(
                a, b, sorted_output=sorted_output, semiring=sr.name,
                mask=mask, complement_mask=complement_mask,
                row_nnz_c=row_nnz_c, db=autotune_db)
            if choice is not None:
                algorithm = choice.algorithm
                table_scale = choice.table_scale
                provenance = "measured"
        if algorithm == "auto":      # no autotune, or DB degraded
            from .recipe import recommend
            algorithm, _ = recommend(a, b, sorted_output=sorted_output,
                                     use_case=uc, semiring=sr.name,
                                     mask=mask,
                                     complement_mask=complement_mask,
                                     row_nnz_c=row_nnz_c,
                                     a_row_nnz=a_row_nnz)
            provenance = "heuristic"
        if algorithm == "heap" and not (a.sorted_cols and b.sorted_cols):
            # recipe picked heap on its merits, but the inputs cannot feed
            # it; hash keeps the unsorted contract
            algorithm = "hash"
    if table_scale != 1 and algorithm in ("hash", "hash_vector"):
        # winning table-size variant: scale the static scratch allocation
        # and per-bin effective sizes together.  Everything stays p2
        # (p2 * p2-scale) and clipped to [CHUNK, table_size] with the
        # scratch capped at p2(n_cols + 1) -- a table wider than every
        # column is pure waste -- so every schedule VC of
        # repro.verify.bounds keeps holding on the scaled plan.
        table_size = max(min(table_size * table_scale,
                             sched.lowest_p2(n + 1)), HK.CHUNK)
        bin_tsize = jnp.clip(bin_tsize.astype(jnp.int32) * table_scale,
                             jnp.int32(HK.CHUNK), jnp.int32(table_size))
    bcsr_plan = None
    if algorithm == "bcsr":
        if sr.name != "plus_times" or mask is not None:
            raise NotImplementedError(
                "the bcsr block path supports plus_times unmasked "
                "products only; plan esc/heap/hash instead")
        # nest the block-granularity inspection now (DESIGN.md section 17):
        # re-block the operand patterns once, plan the block product under
        # the shared LRU's "bcsr" kind, and freeze both levels together.
        from .bcsr import plan_bcsr
        from .formats import csr_to_bcsr
        ab = csr_to_bcsr(a, block)
        bb = csr_to_bcsr(b, (block[1], block[1]))
        bcsr_plan = plan_bcsr(ab, bb, n_bins=n_bins, cache=cache)
    pb_plan = None
    if algorithm == "pb":
        # nest the propagation-blocking inspection now (DESIGN.md
        # section 18): bucket the outer-product expansion once under the
        # shared LRU's "pb" kind and freeze both levels together.  PB
        # handles general semirings (jnp twin) and masks (structural
        # plan-time pruning), so no routing restriction applies here.
        from .pb import plan_pb
        pb_plan = plan_pb(a, b, semiring=sr.name, mask=mask,
                          complement_mask=complement_mask, cache=cache)

    plan = SpGEMMPlan(
        key=key, algorithm=algorithm, semiring=sr.name,
        complement_mask=complement_mask, sorted_output=sorted_output,
        mask=mask, shape_a=a.shape, shape_b=b.shape, cap_a=a.cap,
        cap_b=b.cap, nnz_a=int(a.nnz), nnz_b=int(b.nnz), n_bins=n_bins,
        flop=flop, total_flop=total_flop, flop_cap=flop_cap,
        offsets=offsets, bin_tsize=bin_tsize, table_size=table_size,
        row_nnz_c=row_nnz_c, indptr_c=indptr_c, nnz_c=nnz_c, cap_c=cap_c,
        row_cap=row_cap, k_width=k_width, provenance=provenance,
        block=block if algorithm == "bcsr" else None, bcsr_plan=bcsr_plan,
        pb_plan=pb_plan)
    if cache:
        cache_store(key, plan)
    return plan
