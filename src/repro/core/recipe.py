"""The paper's recipe (sections 4.2.4 + 5.7, Table 4): pick the best SpGEMM
algorithm from matrix statistics + sortedness requirement.

Cost models (paper Eq. 1 / Eq. 2), extended with a block-density term for the
TPU BCSR path (DESIGN.md section 2: a tile product only pays off when blocks
are dense enough to feed the MXU):

  T_heap = sum_i flop(c_i*) * log2 nnz(a_i*)
  T_hash = flop * c + [sorted] sum_i nnz(c_i*) * log2 nnz(c_i*)
  T_esc  = flop * log2(flop)                      (sort-based, always sorted)
  T_bcsr = flop_tile / (tile_density * mxu_eff)   (block path; wins when the
                                                   nonzeros cluster in tiles)

The empirical decision table (Table 4) is reproduced in
:func:`choose_algorithm_from_stats` and validated against the cost-model
and measured rankings by the ``table4_recipe`` rows of
``benchmarks/bench_spgemm_figs.py``; the planner's recorded choices
(``core.plan``) are exercised by ``benchmarks/bench_plan.py``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from .formats import CSR
from . import schedule as sched

#: Average probe count under linear probing at the paper's <=50% load factor
#: (table is the lowest 2^n >= flop(row)); c in Eq. 2.
HASH_COLLISION_FACTOR = 1.5


@dataclass(frozen=True)
class SpGEMMStats:
    """Inputs to the recipe -- everything Table 4 keys on."""
    n_rows: int
    n_cols: int
    nnz_a: float
    flop: float                # 2*flop in FLOPs terms; 'flop' as in the paper
    nnz_c_est: float           # exact from symbolic, or estimate
    max_row_flop: float
    mean_row_nnz_a: float
    row_skew: float            # max_row_flop / mean_row_flop (G500 vs ER)
    compression_ratio: float   # flop / nnz(C)  (paper section 5.4.4)
    density_ef: float          # nnz_a / n_rows == edge factor
    #: TPU extension (DESIGN.md section 2): mean occupancy of occupied
    #: (bm, bn) tiles.  Dense tiles amortize the MXU's 128x128 systolic
    #: pass; >~ MXU_MIN_TILE_DENSITY makes the BCSR kernel the right tool.
    block_density: float = 0.0
    #: Masked-SpGEMM extension (DESIGN.md section 7): nnz(mask) / (m * n)
    #: for a structural mask (complement already applied), 1.0 when
    #: unmasked.  A sparse mask caps nnz(C) directly, which collapses the
    #: accumulator state and shifts the Eq.1/Eq.2 balance toward hash.
    mask_density: float = 1.0
    #: Whether a mask is present at all -- distinct from mask_density
    #: because a fully dense mask legally reaches density 1.0 yet still
    #: routes the product through the generalized (non-bcsr) paths.
    has_mask: bool = False
    #: Exact Eq. 1 log term ``sum_i flop(c_i*) * log2(max(nnz(a_i*), 2))``.
    #: The paper's per-row sum, NOT a mean substitute: log2 is concave, so
    #: on skewed (G500) matrices -- where the heavy rows carry both the
    #: flop and the large nnz(a_i*) -- ``flop * log2(mean nnz_a)`` can
    #: underprice heap by the full skew factor and invert the Eq.1/Eq.2
    #: ranking.  0.0 means "not collected" (hand-built stats); the cost
    #: model then falls back to the mean-based approximation.
    eq1_heap_log: float = 0.0
    #: Exact Eq. 2 sort term ``sum_i nnz(c_i*) * log2(max(nnz(c_i*), 2))``
    #: (same per-row-sum contract as :attr:`eq1_heap_log`).
    eq2_hash_sort: float = 0.0


#: minimum mean tile occupancy for the MXU block path to beat scalar hash
MXU_MIN_TILE_DENSITY = 0.25
#: cell-count ceiling for the *automatic* block-density probe
#: (``probe_blocks="auto"``): the probe densifies A's pattern on the host,
#: so auto mode only pays it where that is clearly cheap; callers with big
#: block-structured matrices opt in with ``probe_blocks=True``.
AUTO_PROBE_CELLS = 1 << 20
#: compression-factor ceiling for the propagation-blocking lane
#: (DESIGN.md section 18): PB expands every partial product once
#: (O(flop) streaming bandwidth, no hash table), so it only wins where
#: the expansion barely compresses -- flop / nnz(C) near 1, the regime
#: the PB paper (PAPERS.md, Gu et al.) calls bandwidth-bound.  At higher
#: compression the hash table's on-chip duplicate collapse amortizes and
#: Eq. 2 wins back.
PB_MAX_COMPRESSION = 1.25
#: mask density below which the hash family wins the masked use case: the
#: mask-pruned accumulator state fits a small probe table and the sort
#: epilogue is skipped (outputs of masked graph products are rarely
#: consumed sorted -- the C8 finding, sharpened by the mask).
MASKED_HASH_DENSITY = 0.25
_PROBE_TILE = (8, 8)


def block_density_of(a: CSR, tile=_PROBE_TILE) -> float:  # verify: allow(no-densify)
    """Mean occupancy of occupied tiles (structure probe, host-side;
    densify waived -- the probe inspects structure, never jit-hot).

    Shapes that are not a tile multiple are zero-padded up to the tile
    grid before probing: the padding dilutes only the boundary tiles'
    occupancy, so a dense-blocked 1000x1000 matrix still reads as
    block-dense instead of silently returning 0.0 (which used to disable
    bcsr routing for every non-multiple shape).
    """
    import numpy as np
    m, n = a.shape
    bm, bn = tile
    dense = np.asarray(a.to_dense()) != 0
    pad_m, pad_n = (-m) % bm, (-n) % bn
    if pad_m or pad_n:
        dense = np.pad(dense, ((0, pad_m), (0, pad_n)))
        m, n = m + pad_m, n + pad_n
    tiles = dense.reshape(m // bm, bm, n // bn, bn).transpose(0, 2, 1, 3)
    occ = tiles.any(axis=(2, 3))
    n_occ = int(occ.sum())
    if not n_occ:
        return 0.0
    return float(tiles.sum()) / (n_occ * bm * bn)


def measure_stats(a: CSR, b: CSR, row_nnz_c=None,
                  probe_blocks: bool = False,
                  mask: CSR | None = None,
                  complement_mask: bool = False,
                  a_row_nnz=None) -> SpGEMMStats:
    """Host-side stat collection (concrete values; jittable pieces inside).

    ``a_row_nnz`` takes the *recorded* per-row counts of the A operand when
    A is a chain intermediate -- the previous stage's ``plan.row_nnz_c``
    (DESIGN.md section 12).  The recorded counts replace the A-side
    *count* statistics (``nnz_a``, ``mean_row_nnz_a``, ``density_ef``):
    unlike ``a.nnz``, they stay exact when the intermediate rides in a
    bucket-capped (p2-padded) buffer or when its ``nnz`` is a tracer
    inside a jitted loop.  The flop-side statistics (``flop``,
    ``max_row_flop``, ``row_skew``) still come from
    :func:`repro.core.schedule.flops_per_row` on the handed-in
    (materialized) structure, which needs A's column indices.
    """
    flop = sched.flops_per_row(a, b)
    total_flop = float(flop.sum())
    if a_row_nnz is not None:
        row_nnz_a = jnp.asarray(a_row_nnz)
        nnz_a = float(row_nnz_a.sum())
    else:
        row_nnz_a = a.row_nnz()
        nnz_a = float(a.nnz)
    if row_nnz_c is None:
        # cheap upper-bound estimate; exact comes from core.spgemm.symbolic
        row_bound = jnp.minimum(flop, b.n_cols)
        if mask is not None:
            row_bound = sched.masked_row_bound(row_bound, mask,
                                               complement_mask)
        row_c = row_bound
        nnz_c = float(row_bound.sum())
    else:
        row_c = jnp.asarray(row_nnz_c)
        nnz_c = float(row_c.sum())
    # The paper's Eq.1/Eq.2 log terms are per-row SUMS -- one reduction
    # each over arrays already in hand.  Substituting a global-mean log
    # (the old shortcut) inverts rankings on skewed inputs because log2
    # is concave (see SpGEMMStats.eq1_heap_log).
    log2_a = jnp.log2(jnp.maximum(row_nnz_a.astype(jnp.float32), 2.0))
    eq1 = float(jnp.sum(flop.astype(jnp.float32) * log2_a))
    rc_f = row_c.astype(jnp.float32)
    eq2 = float(jnp.sum(rc_f * jnp.log2(jnp.maximum(rc_f, 2.0))))
    mean_flop = total_flop / max(a.n_rows, 1)
    cells = max(a.n_rows * b.n_cols, 1)
    if mask is None:
        mask_density = 1.0
    else:
        frac = float(mask.nnz) / cells
        mask_density = (1.0 - frac) if complement_mask else frac
    return SpGEMMStats(
        n_rows=a.n_rows, n_cols=b.n_cols, nnz_a=nnz_a, flop=total_flop,
        nnz_c_est=max(nnz_c, 1.0),
        max_row_flop=float(flop.max()),
        mean_row_nnz_a=nnz_a / max(a.n_rows, 1),
        row_skew=float(flop.max()) / max(mean_flop, 1e-9),
        compression_ratio=total_flop / max(nnz_c, 1.0),
        density_ef=nnz_a / max(a.n_rows, 1),
        block_density=(block_density_of(a) if probe_blocks else 0.0),
        mask_density=mask_density, has_mask=mask is not None,
        eq1_heap_log=eq1, eq2_hash_sort=eq2)


def aggregate_stats(stats_list) -> SpGEMMStats:
    """Fleet-level statistics for a *batch* of products (``core.batch``).

    Count-like fields (``n_rows``, ``nnz_a``, ``flop``, ``nnz_c_est``)
    sum across the fleet -- the batched executor runs the fleet as
    stacked rows of one logical product, which is what the Eq. 1 / Eq. 2
    terms then describe; bound-like fields (``max_row_flop``, ``n_cols``)
    take the max; the derived ratios (``row_skew``,
    ``compression_ratio``, ``density_ef``) are recomputed from the
    aggregates rather than averaged, so one heavy product dominates
    exactly as one heavy row dominates within a product.  ``has_mask`` is true if *any* member is masked
    (a masked member forces the generalized accumulators on its class);
    ``mask_density`` is the member mean.  ``block_density`` stays 0 -- the
    bcsr tile path cannot run under the batched (vmapped) executor.
    """
    stats_list = list(stats_list)
    assert stats_list, "aggregate_stats needs at least one member"
    n_rows = sum(s.n_rows for s in stats_list)
    nnz_a = sum(s.nnz_a for s in stats_list)
    flop = sum(s.flop for s in stats_list)
    nnz_c = sum(s.nnz_c_est for s in stats_list)
    max_row_flop = max(s.max_row_flop for s in stats_list)
    mean_flop = flop / max(n_rows, 1)
    return SpGEMMStats(
        n_rows=n_rows, n_cols=max(s.n_cols for s in stats_list),
        nnz_a=nnz_a, flop=flop, nnz_c_est=max(nnz_c, 1.0),
        max_row_flop=max_row_flop,
        mean_row_nnz_a=nnz_a / max(n_rows, 1),
        row_skew=max_row_flop / max(mean_flop, 1e-9),
        compression_ratio=flop / max(nnz_c, 1.0),
        density_ef=nnz_a / max(n_rows, 1), block_density=0.0,
        mask_density=(sum(s.mask_density for s in stats_list)
                      / len(stats_list)),
        has_mask=any(s.has_mask for s in stats_list),
        # the Eq.1/Eq.2 log terms are sums over rows, and the fleet runs
        # as stacked rows of one logical product: member sums just add
        eq1_heap_log=sum(s.eq1_heap_log for s in stats_list),
        eq2_hash_sort=sum(s.eq2_hash_sort for s in stats_list))


# ---------------------------------------------------------------------------
# Theoretical cost model (Eq. 1 / Eq. 2)
# ---------------------------------------------------------------------------

def cost_heap(stats: SpGEMMStats) -> float:
    """Eq. 1: ``T_heap = sum_i flop(c_i*) * log2 nnz(a_i*)``.

    Uses the exact per-row sum when :func:`measure_stats` collected it;
    hand-constructed stats (``eq1_heap_log == 0``) fall back to the
    mean-based approximation ``flop * log2(mean nnz_a)``, which is a
    strict underestimate on skewed inputs (Jensen: log2 is concave and
    the heavy rows carry the flop) -- the bug this field exists to fix.
    """
    if stats.eq1_heap_log > 0.0:
        return stats.eq1_heap_log
    log_k = max(1.0, float(jnp.log2(jnp.maximum(stats.mean_row_nnz_a, 2.0))))
    return stats.flop * log_k


def cost_hash(stats: SpGEMMStats, sorted_output: bool) -> float:
    """Eq. 2: ``T_hash = flop * c [+ sorted: sum_i nnz(c_i*) * log2
    nnz(c_i*)]`` -- exact per-row sort sum when collected, mean-based
    fallback otherwise (see :func:`cost_heap`)."""
    t = stats.flop * HASH_COLLISION_FACTOR
    if sorted_output:
        if stats.eq2_hash_sort > 0.0:
            t += stats.eq2_hash_sort
        else:
            mean_row_c = stats.nnz_c_est / max(stats.n_rows, 1)
            t += stats.nnz_c_est * max(
                1.0, float(jnp.log2(jnp.maximum(mean_row_c, 2.0))))
    return t


def cost_esc(stats: SpGEMMStats) -> float:
    return stats.flop * max(1.0, float(jnp.log2(jnp.maximum(stats.flop, 2.0))))


def cost_pb(stats: SpGEMMStats) -> float:
    """Propagation-blocking bandwidth model (PB paper section 4).

    Two streaming passes over the expansion -- write each partial product
    into its bucket, read it back in the merge -- plus the output write:
    ``T_pb = 2 * flop + nnz(C)``.  No log term anywhere: the bucket sort
    happened at plan time, and the merge's scatter stays inside one
    cache/VMEM-resident bucket.  Compare against :func:`cost_hash` with
    ``sorted_output=True``: PB's win is exactly the vanished sort term,
    so it prices below hash only when the compression factor is low
    (little duplicate collapse for the hash table to exploit).
    """
    return 2.0 * stats.flop + stats.nnz_c_est


def model_costs(stats: SpGEMMStats, sorted_output: bool) -> dict:
    """Eq. 1/Eq. 2 cost-model scores per algorithm family (lower wins);
    the theoretical ranking `table4_recipe` checks the empirical decision
    table against."""
    return {"heap": cost_heap(stats),
            "hash": cost_hash(stats, sorted_output),
            "esc": cost_esc(stats),
            "pb": cost_pb(stats)}


# ---------------------------------------------------------------------------
# Empirical decision table (Table 4), with the Eq.1/Eq.2 crossovers behind it
# ---------------------------------------------------------------------------

def choose_algorithm_from_stats(stats: SpGEMMStats, sorted_output: bool,
                                use_case: str = "AxA",
                                semiring: str = "plus_times") -> str:
    """Reproduction of Table 4 (+ section 4.2.4 reasoning).

    use_case: "AxA" | "LxU" | "tall_skinny" | "masked" | "batch" |
    "dist" (a distributed planner resolving its SPMD-local algorithm:
    never offered bcsr, whose block inspection cannot run inside the
    traced shard program).

    Extensions beyond Table 4 (DESIGN.md section 7):
      * unsorted boolean/any_pair products route to the hash family: the
        paper's C8 finding (unsorted hash output is ~1.6x faster) is an
        upper bound for boolean semirings, where the accumulator stores no
        values at all and the sort epilogue is the only log factor left;
      * ``use_case="masked"`` keys on mask density -- a sparse mask caps the
        accumulator state to nnz(mask_i*), which favors the probe table,
        while a dense mask degenerates to the LxU column of Table 4.
    """
    high_cr = stats.compression_ratio > 2.0
    dense_ef = stats.density_ef > 8.0
    skewed = stats.row_skew > 8.0

    if use_case == "batch":
        # Fleet of small products fused into one vmapped program
        # (core.batch): the hash kernels run natively under vmap (the
        # batched grid behind the custom_vmap rule in
        # repro.kernels.spgemm_hash), so the full esc / heap / hash
        # families are on offer -- and the stats are the *aggregate* of a
        # capacity class (recipe.aggregate_stats).
        # Unsorted output keeps the C8 default for every semiring: the
        # hash family's select order costs nothing extra and skips every
        # sort (for boolean/any_pair it is also the Table-4 row).  Sorted
        # requests split on compression ratio exactly like L x U: heap's
        # one-phase merge wins while outputs stay sparse (Eq. 1's log
        # factor is per a-row nnz), esc amortizes its single big sort
        # once the fleet's expansion is compressible.
        if sorted_output:
            return "esc" if high_cr else "heap"
        return "hash"

    # TPU extension: clustered nonzeros -> MXU block kernel regardless of
    # the scalar-regime columns (the tile product amortizes everything).
    # Only for plain unmasked (+, x) products: the bcsr path has no
    # semiring/mask support, so recommending it for a generalized request
    # would send the caller straight into a NotImplementedError.
    if (stats.block_density >= MXU_MIN_TILE_DENSITY
            and semiring == "plus_times"
            and not stats.has_mask
            and use_case not in ("masked", "batch", "dist")):
        return "bcsr"

    # Propagation-blocking extension (DESIGN.md section 18): a sorted
    # AxA-regime product whose expansion barely compresses routes to the
    # bucketed outer-product path -- the hash table would mostly miss
    # (every probe an insert), while PB streams the expansion twice and
    # gets sorted output for free from its plan-time bucket sort.  Only
    # for plain unmasked (+, x) AxA products: masked/batch/dist have their
    # own executors and the LxU/tall_skinny columns keep Table 4's rows.
    if (stats.compression_ratio <= PB_MAX_COMPRESSION
            and sorted_output
            and semiring == "plus_times"
            and not stats.has_mask
            and use_case == "AxA"):
        return "pb"

    # Boolean semirings with relaxed sortedness: hash family, per C8.
    if semiring in ("boolean", "any_pair") and not sorted_output:
        return "hash_vector" if dense_ef else "hash"

    if use_case == "masked":
        if stats.mask_density <= MASKED_HASH_DENSITY or high_cr:
            return "hash"
        # dense mask: effectively the LxU regime at low compression ratio
        return "heap"

    if use_case == "LxU":
        # Fig 17: Heap best at low CR (sparser outputs), Hash otherwise.
        return "hash" if high_cr else "heap"
    if use_case == "tall_skinny":
        # Fig 16 / Table 4b: hash family dominates; vectorized probing pays
        # off only in the dense regime where collisions are common.
        return "hash_vector" if (dense_ef and sorted_output) else "hash"
    # AxA, Table 4a/4b.
    if not dense_ef and not skewed:
        # sparse uniform: flop(c_i*) is small -> Eq.1's log factor is tiny
        # and heap's O(nnz(a_i*)) memory wins (latency-bound regime).
        return "heap" if sorted_output else "hash_vector"
    if dense_ef and skewed:
        return "hash"
    if high_cr and not sorted_output:
        # Table 4a unsorted/high-CR row is MKL-inspector; our equivalent
        # single-phase dense-regime code path is the vectorized hash.
        return "hash_vector"
    return "hash"


def _resolve_probe_blocks(probe_blocks, a: CSR, semiring: str, mask,
                          use_case: str, a_row_nnz=None) -> bool:
    """Resolve ``probe_blocks="auto"``: probe tile occupancy only when the
    request is bcsr-eligible (plus_times, unmasked, not a masked / batch /
    distributed use case, not a chain intermediate), the structure is
    concrete, and the host dense probe is affordable
    (:data:`AUTO_PROBE_CELLS`)."""
    if probe_blocks != "auto":
        return bool(probe_blocks)
    import jax
    if semiring != "plus_times" or mask is not None \
            or use_case in ("masked", "batch", "dist") \
            or a_row_nnz is not None:
        return False
    if any(isinstance(x, jax.core.Tracer)
           for x in (a.indptr, a.indices, a.data, a.nnz)):
        return False
    return a.n_rows * a.n_cols <= AUTO_PROBE_CELLS


def recommend(a: CSR, b: CSR, sorted_output: bool = False,
              use_case: str = "AxA",
              probe_blocks: bool | str = "auto",
              semiring: str = "plus_times",
              mask: CSR | None = None,
              complement_mask: bool = False,
              row_nnz_c=None, a_row_nnz=None,
              mode: str = "heuristic",
              db=None) -> tuple[str, SpGEMMStats]:
    """Measure stats and choose -- returns ``(algorithm, stats)``.

    ``probe_blocks`` controls the tile-occupancy probe behind the bcsr
    routing row: ``True``/``False`` force it, the default ``"auto"``
    probes exactly when the request is bcsr-eligible and the probe is
    cheap (:func:`_resolve_probe_blocks`) -- so ``spgemm(algorithm=
    "auto")`` and the planner genuinely reach the MXU block path on
    block-clustered inputs without every scattered product paying for a
    host densify.

    ``mode`` selects the decision procedure:

      * ``"heuristic"`` (default): the fixed Table-4 decision tree over
        the Eq.1/Eq.2 cost models -- zero measurement, deterministic.
      * ``"measured"``: consult the persistent autotune database
        (:mod:`repro.autotune`) under the ``(structure digests, backend,
        x64)`` key.  A DB hit returns the recorded winner with **zero**
        microbenchmarks (counter-verified by ``tests/test_autotune.py``);
        a miss microbenchmarks every candidate algorithm on the actual
        operands, persists the winner with timing + roofline context,
        and returns it.  A DB entry whose recorded stats drift past the
        tolerance is re-measured, not trusted; any DB failure
        (corrupt/truncated file, unknown schema) degrades to the
        heuristic with a warning -- never a crash.  ``db`` overrides the
        default database path (a path string or a
        :class:`repro.autotune.PerfDB`).

    ``row_nnz_c`` takes the symbolic phase's exact per-row counts when the
    caller already has them (the planner does), replacing the cheap
    upper-bound estimate so compression-ratio decisions are exact; the
    chosen algorithm is what the planner records in the plan.

    ``a_row_nnz`` is the mid-chain hook (DESIGN.md section 12): when the A
    operand is a chain *intermediate*, pass the previous stage's recorded
    ``plan.row_nnz_c`` so the A-side statistics come from the real
    intermediate structure instead of whatever buffer padding or traced
    ``nnz`` the handed-in CSR carries.  An intermediate's compression
    factor and skew differ from the user matrices that produced it, so
    without this the stage-k algorithm choice would key on defaults.
    """
    assert mode in ("heuristic", "measured"), mode
    probe_blocks = _resolve_probe_blocks(probe_blocks, a, semiring, mask,
                                         use_case, a_row_nnz)
    stats = measure_stats(a, b, row_nnz_c=row_nnz_c,
                          probe_blocks=probe_blocks, mask=mask,
                          complement_mask=complement_mask,
                          a_row_nnz=a_row_nnz)
    if mode == "measured":
        # imported lazily: the autotuner times things (wall-clock is
        # banned in core/ by the plan-key-determinism rule) and must not
        # load unless asked for
        from repro.autotune import measured_recommend
        choice = measured_recommend(
            a, b, sorted_output=sorted_output, semiring=semiring,
            mask=mask, complement_mask=complement_mask, stats=stats,
            row_nnz_c=row_nnz_c, db=db)
        if choice is not None:
            return choice.algorithm, stats
    return choose_algorithm_from_stats(stats, sorted_output, use_case,
                                       semiring=semiring), stats


def choose_algorithm(a: CSR, b: CSR, sorted_output: bool = False,
                     use_case: str = "AxA",
                     probe_blocks: bool | str = "auto",
                     semiring: str = "plus_times",
                     mask: CSR | None = None,
                     complement_mask: bool = False) -> str:
    """:func:`recommend` without the stats -- what ``spgemm(algorithm=
    "auto")`` calls.  ``use_case`` is one of ``"AxA"`` | ``"LxU"`` |
    ``"tall_skinny"`` | ``"masked"`` (Table 4's columns)."""
    algo, _ = recommend(a, b, sorted_output=sorted_output, use_case=use_case,
                        probe_blocks=probe_blocks, semiring=semiring,
                        mask=mask, complement_mask=complement_mask)
    return algo
