"""Light-weight load-balanced scheduling (paper section 4.1, Fig. 6).

``RowsToThreads``: count flop per output row, prefix-sum, then find each
worker's start row with a binary search (``LOWBND``).  On KNL the workers
were OpenMP threads under *static* scheduling; here the same partition is
used three ways:

  1. Pallas grid programs: bin b processes rows ``offset[b]:offset[b+1]``
     (fed through scalar prefetch);
  2. mesh chips in distributed SpGEMM (equal-flop row partitions per chip);
  3. the serving engine's batch scheduler (equal-token request bins).

The paper's argument -- static scheduling is cheap but needs up-front
balancing -- is *structural* on TPU: a Pallas grid is static by construction,
so this module is what makes static assignment viable, exactly as on KNL.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .formats import CSR

#: largest value an int32 prefix sum may reach without wrapping.
_I32_MAX = 2**31 - 1


def _acc_dtype():
    """Accumulator dtype for flop prefix sums: int64 when x64 is enabled
    (overflow becomes impossible), int32 otherwise (exact at proxy scale,
    DESIGN.md section 9, guarded by :func:`guard_i32_flop`)."""
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def guard_i32_flop(flop, n_bins: int = 1, what: str = "rows_to_bins"):
    """Fail loudly instead of mis-binning on int32 prefix-sum overflow.

    The equal-flop partition multiplies the *total* flop by bin ids up to
    ``n_bins - 1`` before dividing, so the quantity that must fit int32 is
    ``total * (n_bins - 1)``, not just the total.  Three regimes:

      * x64 enabled: accumulation is promoted to int64 -- nothing to guard;
      * concrete ``flop`` (the planner's eager path): check exactly in
        numpy int64 and raise ``OverflowError``;
      * traced without x64 (e.g. inside ``make_schedule``'s jit): the check
        cannot run -- callers that may see >2^31 total flop must plan
        eagerly (``core.plan``) or enable x64.
    """
    if jax.config.jax_enable_x64:
        return
    if isinstance(flop, jax.core.Tracer):
        return
    total = int(np.asarray(flop, dtype=np.int64).sum())
    if total * max(n_bins - 1, 1) > _I32_MAX:
        raise OverflowError(
            f"{what}: total flop {total} (x {max(n_bins - 1, 1)} partition "
            f"targets) overflows the int32 prefix sum; enable "
            f"jax_enable_x64 or shard the product (DESIGN.md section 9)")


def flops_per_row(a: CSR, b: CSR) -> jax.Array:
    """flop[i] = sum_{k in a_i*} nnz(b_k*)  -- Fig. 6 step 1.

    This is both the load-balance weight and the hash-table sizing bound
    (Fig. 7 lines 5-12): row i of C touches at most flop[i] distinct columns.
    """
    rnz = (b.indptr[a.indices + 1] - b.indptr[a.indices]).astype(jnp.int32)
    rnz = jnp.where(a.valid_mask(), rnz, 0)
    return jax.ops.segment_sum(rnz, a.row_ids(), num_segments=a.n_rows)


def masked_row_bound(flop: jax.Array, mask: CSR,
                     complement: bool = False) -> jax.Array:
    """Per-row nnz(C) upper bound under a structural mask (DESIGN.md
    section 7): a non-complemented mask caps row i of C at nnz(mask_i*), a
    complemented mask at ``n_cols - nnz(mask_i*)``.  This is the capacity
    math the symbolic phase and the launcher use when a mask is present --
    the mask shrinks the *static* allocation, not just the dynamic nnz.
    """
    mrow = mask.row_nnz().astype(flop.dtype)
    lim = (jnp.int32(mask.n_cols) - mrow) if complement else mrow
    return jnp.minimum(flop, lim)


def prefix_sum(x: jax.Array) -> jax.Array:
    """Exclusive-then-inclusive prefix sum, (n+1,): ps[0]=0, ps[-1]=total."""
    return jnp.concatenate([jnp.zeros((1,), x.dtype),
                            jnp.cumsum(x, dtype=x.dtype)])


def lowbnd(vec: jax.Array, value: jax.Array) -> jax.Array:
    """Minimum id such that vec[id] >= value (Fig. 6 line 14)."""
    return jnp.searchsorted(vec, value, side="left").astype(jnp.int32)


def rows_to_bins(flop: jax.Array, n_bins: int) -> jax.Array:
    """Fig. 6 steps 2: equal-flop partition; returns offsets (n_bins+1,).

    Invariants (property-tested):
      * offsets[0] == 0, offsets[-1] == n_rows, monotone non-decreasing;
      * every bin's flop <= ceil(total/n_bins) + max_row_flop.
    """
    m = flop.shape[0]
    # Exact arithmetic without float64: int32 accumulation is exact below
    # 2^31 (the proxy-scale regime, DESIGN.md section 9); the guard raises
    # on concrete inputs that would wrap, and x64 promotes to int64.
    guard_i32_flop(flop, n_bins, "rows_to_bins")
    acc = _acc_dtype()
    ps = prefix_sum(flop.astype(acc))
    total = ps[-1]
    targets = (total * jnp.arange(1, n_bins, dtype=acc)) // n_bins
    # ps is over row *boundaries*; bin b starts at the first row whose
    # cumulative flop reaches target b.
    cuts = lowbnd(ps[1:], targets + 1)
    offsets = jnp.concatenate([
        jnp.zeros((1,), jnp.int32), cuts.astype(jnp.int32),
        jnp.full((1,), m, jnp.int32)])
    return jnp.minimum(offsets, m)


def bin_row_assignment(offsets: jax.Array, n_rows: int) -> jax.Array:
    """Inverse view: bin id of every row, (n_rows,)."""
    r = jnp.arange(n_rows, dtype=jnp.int32)
    return (jnp.searchsorted(offsets, r, side="right") - 1).astype(jnp.int32)


def bin_flop(flop: jax.Array, offsets: jax.Array) -> jax.Array:
    """Total flop per bin (n_bins,) -- the balance metric."""
    guard_i32_flop(flop, 1, "bin_flop")
    ps = prefix_sum(flop.astype(_acc_dtype()))
    return ps[offsets[1:]] - ps[offsets[:-1]]


def max_flop_per_bin_row(flop: jax.Array, offsets: jax.Array) -> jax.Array:
    """Per-bin max row flop (n_bins,) -- Fig. 7 lines 5-12: each worker sizes
    its private hash table once, to the max flop of any row in its bin, and
    reuses it for every row (the paper's thread-private allocation, C5)."""
    n_bins = offsets.shape[0] - 1
    bins = bin_row_assignment(offsets, flop.shape[0])
    return jax.ops.segment_max(flop, bins, num_segments=n_bins)


def make_schedule_eager(a: CSR, b: CSR, n_bins: int):
    """Un-jitted Fig. 6 pipeline -- the single source of truth.

    Returns (flop, offsets, bin_table_size); ``bin_table_size`` is the
    per-bin hash-table bound of Fig. 7 line 10: ``min(N_col,
    max-row-flop-in-bin)`` (power-of-two rounding happens where the static
    size is needed: kernel instantiation / :func:`bin_table_sizes`).

    The planner calls this form directly: on concrete inputs the int32
    overflow guard inside :func:`rows_to_bins` actually fires (under
    :func:`make_schedule`'s jit the values are tracers and it cannot).
    """
    flop = flops_per_row(a, b)
    offsets = rows_to_bins(flop, n_bins)
    tsize = jnp.minimum(max_flop_per_bin_row(flop, offsets),
                        jnp.int32(b.n_cols))
    return flop, offsets, tsize


make_schedule = partial(jax.jit, static_argnames=("n_bins",))(
    make_schedule_eager)
make_schedule.__doc__ = "Jitted :func:`make_schedule_eager`."


def equal_weight_partition(weights, n_parts: int) -> np.ndarray:
    """Host-side equal-weight contiguous partition (Fig. 6 at mesh scale).

    The exact int64 twin of :func:`rows_to_bins` for *shard* boundaries:
    mesh layout is static, so the partition is computed eagerly in numpy
    (no overflow guard needed -- int64 accumulation is always exact here).
    Returns ``row_starts`` of shape ``(n_parts + 1,)`` with the same
    invariants as ``rows_to_bins``: starts[0] == 0, starts[-1] == n_rows,
    monotone, and every part's weight <= ceil(total/n_parts) + max weight.

    Degenerate inputs rebalance instead of collapsing: an all-zero weight
    vector has no flop to balance, so rows are split evenly (the old
    zero-total prefix sent every ``searchsorted`` cut to ``n``, handing
    part 0 the whole matrix and every other part zero rows).  With
    ``n_parts > n`` some parts are necessarily empty; the cuts spread
    them across the range rather than piling the empties at the tail.
    """
    w = np.asarray(weights, dtype=np.int64)
    assert w.ndim == 1, w.shape
    assert n_parts >= 1, n_parts
    n = w.shape[0]
    ps = np.concatenate([np.zeros(1, np.int64), np.cumsum(w, dtype=np.int64)])
    total = ps[-1]
    if total == 0:
        return (n * np.arange(n_parts + 1, dtype=np.int64)) // n_parts
    targets = (total * np.arange(1, n_parts, dtype=np.int64)) // n_parts
    cuts = np.searchsorted(ps[1:], targets + 1, side="left")
    starts = np.concatenate([np.zeros(1, np.int64), cuts,
                             np.full(1, n, np.int64)])
    return np.minimum(starts, n)


def chained_flop_bound(row_nnz_prev: jax.Array, b: CSR) -> jax.Array:
    """A-priori per-row flop bound for the *next* product of a chain.

    Stage ``k+1`` of a chain multiplies the (not-yet-materialized)
    intermediate ``C_k`` by the next operand ``B``; before ``C_k``'s column
    structure exists, the only exact inputs are the previous stage's
    symbolic counts ``row_nnz_prev = nnz(c_k,i*)``.  Row ``i`` of stage
    ``k+1`` touches at most one B row per intermediate entry, so

        flop_{k+1}[i] <= nnz(c_k,i*) * max_j nnz(b_j*)

    This is the chained capacity math of DESIGN.md section 12: it bounds
    the next stage's expansion buffer and hash-table sizes from recorded
    plan state alone, and it is what :func:`repro.core.recipe.recommend`'s
    ``a_row_nnz`` hook consumes for mid-chain algorithm choice.  Once the
    chain planner materializes the intermediate, the exact
    :func:`flops_per_row` replaces this bound.
    """
    bmax = jnp.max(b.row_nnz()).astype(jnp.int32) if b.n_rows else \
        jnp.int32(0)
    return row_nnz_prev.astype(jnp.int32) * bmax


def lowest_p2(x: int) -> int:
    """Static helper: minimum 2^n >= x (Fig. 7 line 12)."""
    p = 1
    while p < x:
        p *= 2
    return p


def lowest_p2_arr(x: jax.Array) -> jax.Array:
    """Traceable :func:`lowest_p2` over an int32 array.

    Exponent via float32 log2 with an exactness patch-up (float rounding can
    land one power low); exact for values < 2^24, far above any table size a
    VMEM scratch can hold.
    """
    x = jnp.maximum(x.astype(jnp.int32), 1)
    e = jnp.ceil(jnp.log2(x.astype(jnp.float32))).astype(jnp.int32)
    p = jnp.left_shift(jnp.int32(1), jnp.clip(e, 0, 30))
    return jnp.where(p < x, p * 2, p)


def bin_table_sizes(tsize: jax.Array, n_cols: int, table_size: int,
                    floor: int = 1) -> jax.Array:
    """Per-bin hash-table sizes (Fig. 7 lines 9-12), padded to powers of two.

    ``tsize`` is ``make_schedule``'s per-bin max-row-flop bound; each bin's
    table is the lowest power of two >= ``min(tsize_b, n_cols) + 1`` (the +1
    keeps the load factor < 1 so linear probes terminate), clamped into
    ``[floor, table_size]`` where ``table_size`` is the static scratch
    allocation (the global bin max) and ``floor`` is the vector-probe chunk
    width when chunked probing is on.  Traceable, so plans can be built
    under an outer jit as long as ``table_size`` is pinned.
    """
    t = jnp.minimum(tsize.astype(jnp.int32), jnp.int32(n_cols)) + 1
    return jnp.clip(lowest_p2_arr(t), jnp.int32(max(floor, 1)),
                    jnp.int32(table_size))


#: default propagation-blocking bucket budget: the average number of
#: partial products a column bucket should hold.  Sized so one bucket's
#: gather indices + products fit comfortably in VMEM/cache during the
#: merge (the paper's "bin fits in L2" rule, DESIGN.md section 18).
PB_BUCKET_BUDGET = 2048


def pb_bucket_layout(n_cols: int, n_buckets: int | None = None, *,
                     total_flop: int | None = None,
                     budget: int = PB_BUCKET_BUDGET) -> tuple:
    """Column-bucket layout for propagation-blocking SpGEMM.

    Returns ``(bucket_w, n_buckets)`` with ``bucket_w`` a power of two:
    bucket of column ``c`` is ``c // bucket_w`` (one shift -- the radix
    step), and ``n_buckets = ceil(n_cols / bucket_w)`` buckets cover
    ``[0, n_cols)`` contiguously.

    With ``n_buckets=None`` the count is derived from ``total_flop``:
    enough buckets that the *average* bucket holds <= ``budget`` partial
    products (never more buckets than columns).  An explicit request is
    honored up to p2 rounding -- the returned count can be smaller when
    rounding ``bucket_w`` up swallows trailing buckets.
    """
    assert n_cols >= 1, n_cols
    if n_buckets is None:
        want = max(1, -(-(total_flop or 0) // budget))
        n_buckets = min(want, n_cols)
    n_buckets = max(1, min(int(n_buckets), n_cols))
    bucket_w = lowest_p2(-(-n_cols // n_buckets))
    return bucket_w, -(-n_cols // bucket_w)
