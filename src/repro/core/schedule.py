"""Light-weight load-balanced scheduling (paper section 4.1, Fig. 6).

``RowsToThreads``: count flop per output row, prefix-sum, then find each
worker's start row with a binary search (``LOWBND``).  On KNL the workers
were OpenMP threads under *static* scheduling; here the same partition is
used three ways:

  1. Pallas grid programs: bin b processes rows ``offset[b]:offset[b+1]``
     (fed through scalar prefetch);
  2. mesh chips in distributed SpGEMM (equal-flop row partitions per chip);
  3. the serving engine's batch scheduler (equal-token request bins).

The paper's argument -- static scheduling is cheap but needs up-front
balancing -- is *structural* on TPU: a Pallas grid is static by construction,
so this module is what makes static assignment viable, exactly as on KNL.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .formats import CSR


def flops_per_row(a: CSR, b: CSR) -> jax.Array:
    """flop[i] = sum_{k in a_i*} nnz(b_k*)  -- Fig. 6 step 1.

    This is both the load-balance weight and the hash-table sizing bound
    (Fig. 7 lines 5-12): row i of C touches at most flop[i] distinct columns.
    """
    rnz = (b.indptr[a.indices + 1] - b.indptr[a.indices]).astype(jnp.int32)
    rnz = jnp.where(a.valid_mask(), rnz, 0)
    return jax.ops.segment_sum(rnz, a.row_ids(), num_segments=a.n_rows)


def masked_row_bound(flop: jax.Array, mask: CSR,
                     complement: bool = False) -> jax.Array:
    """Per-row nnz(C) upper bound under a structural mask (DESIGN.md
    section 7): a non-complemented mask caps row i of C at nnz(mask_i*), a
    complemented mask at ``n_cols - nnz(mask_i*)``.  This is the capacity
    math the symbolic phase and the launcher use when a mask is present --
    the mask shrinks the *static* allocation, not just the dynamic nnz.
    """
    mrow = mask.row_nnz().astype(flop.dtype)
    lim = (jnp.int32(mask.n_cols) - mrow) if complement else mrow
    return jnp.minimum(flop, lim)


def prefix_sum(x: jax.Array) -> jax.Array:
    """Exclusive-then-inclusive prefix sum, (n+1,): ps[0]=0, ps[-1]=total."""
    return jnp.concatenate([jnp.zeros((1,), x.dtype),
                            jnp.cumsum(x, dtype=x.dtype)])


def lowbnd(vec: jax.Array, value: jax.Array) -> jax.Array:
    """Minimum id such that vec[id] >= value (Fig. 6 line 14)."""
    return jnp.searchsorted(vec, value, side="left").astype(jnp.int32)


def rows_to_bins(flop: jax.Array, n_bins: int) -> jax.Array:
    """Fig. 6 steps 2: equal-flop partition; returns offsets (n_bins+1,).

    Invariants (property-tested):
      * offsets[0] == 0, offsets[-1] == n_rows, monotone non-decreasing;
      * every bin's flop <= ceil(total/n_bins) + max_row_flop.
    """
    m = flop.shape[0]
    # float64-free exact arithmetic: totals stay < 2^31 for the workloads
    # here (the proxy suite is downscaled); see DESIGN.md section 9.
    ps = prefix_sum(flop.astype(jnp.int32))
    total = ps[-1]
    targets = (total * jnp.arange(1, n_bins, dtype=jnp.int32)) // n_bins
    # ps is over row *boundaries*; bin b starts at the first row whose
    # cumulative flop reaches target b.
    cuts = lowbnd(ps[1:], targets + 1)
    offsets = jnp.concatenate([
        jnp.zeros((1,), jnp.int32), cuts.astype(jnp.int32),
        jnp.full((1,), m, jnp.int32)])
    return jnp.minimum(offsets, m)


def bin_row_assignment(offsets: jax.Array, n_rows: int) -> jax.Array:
    """Inverse view: bin id of every row, (n_rows,)."""
    r = jnp.arange(n_rows, dtype=jnp.int32)
    return (jnp.searchsorted(offsets, r, side="right") - 1).astype(jnp.int32)


def bin_flop(flop: jax.Array, offsets: jax.Array) -> jax.Array:
    """Total flop per bin (n_bins,) -- the balance metric."""
    ps = prefix_sum(flop.astype(jnp.int32))
    return ps[offsets[1:]] - ps[offsets[:-1]]


def max_flop_per_bin_row(flop: jax.Array, offsets: jax.Array) -> jax.Array:
    """Per-bin max row flop (n_bins,) -- Fig. 7 lines 5-12: each worker sizes
    its private hash table once, to the max flop of any row in its bin, and
    reuses it for every row (the paper's thread-private allocation, C5)."""
    n_bins = offsets.shape[0] - 1
    bins = bin_row_assignment(offsets, flop.shape[0])
    return jax.ops.segment_max(flop, bins, num_segments=n_bins)


@partial(jax.jit, static_argnames=("n_bins",))
def make_schedule(a: CSR, b: CSR, n_bins: int):
    """Full Fig. 6 pipeline. Returns (flop, offsets, bin_table_size).

    ``bin_table_size`` is the per-bin hash-table bound of Fig. 7 line 10:
    ``min(N_col, max-row-flop-in-bin)`` (power-of-two rounding happens at
    kernel instantiation where the static size is needed).
    """
    flop = flops_per_row(a, b)
    offsets = rows_to_bins(flop, n_bins)
    tsize = jnp.minimum(max_flop_per_bin_row(flop, offsets),
                        jnp.int32(b.n_cols))
    return flop, offsets, tsize


def lowest_p2(x: int) -> int:
    """Static helper: minimum 2^n >= x (Fig. 7 line 12)."""
    p = 1
    while p < x:
        p *= 2
    return p
