"""Semirings for SpGEMM (DESIGN.md section 7).

The paper's kernels hard-code the arithmetic semiring ``(+, x, 0)``, but its
headline use cases are graph algorithms (sections 5.5-5.6) where the natural
formulation is ``C = A (+.x) B`` over a *semiring*: multi-source BFS is a
boolean ``any_pair`` product, shortest paths are ``min_plus``, and frontier
expansion with parent tracking is ``plus_first``.  GraphBLAS-style engines
(KokkosKernels, CombBLAS) ship this as a first-class knob; here it is a small
frozen dataclass threaded through every accumulator as a *static* argument,
so each (algorithm, semiring) pair jit-compiles to its own specialized
program -- no dynamic dispatch inside kernels.

Semantics follow GraphBLAS: ``mul`` combines *stored* entries only (a
structural zero annihilates), ``add`` reduces the multi-set of products per
output coordinate, and ``zero`` is the additive identity used for padded
lanes.  The output keeps the *structural* union pattern: an entry exists in C
iff at least one (a_ik, b_kj) pair of stored entries exists -- value-level
cancellation does not remove entries (matching the paper's symbolic phase,
which is pattern-only).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Semiring:
    """An SpGEMM semiring ``(add, mul, zero)``.

    Attributes:
      name: canonical registry key.
      add:  elementwise reduction combiner (associative + commutative).
      mul:  elementwise product of a stored A value and a stored B value.
      zero: additive identity (value given to padded / invalid lanes before
        a reduction; ``add(x, zero) == x``).
      segment_reduce: the ``jax.ops.segment_*`` matching ``add`` -- the
        sort-based accumulators (ESC and the hash jnp fallback) reduce
        duplicate coordinates with one segmented reduction instead of a loop.
    """
    name: str
    add: Callable[[jax.Array, jax.Array], jax.Array]
    mul: Callable[[jax.Array, jax.Array], jax.Array]
    zero: float
    segment_reduce: Callable[..., jax.Array]

    def __repr__(self):  # keep jit cache keys readable in logs
        return f"Semiring({self.name})"


def _ones_like_pair(x, y):
    # any_pair: the mere existence of a stored (a, b) pair contributes 1.
    return jnp.ones_like(x * y)


def _first(x, y):
    # plus_first: keep the A-side value (frontier products: B is a pattern).
    return x * jnp.ones_like(y)


PLUS_TIMES = Semiring("plus_times", jnp.add, jnp.multiply, 0.0,
                      jax.ops.segment_sum)
BOOLEAN = Semiring("boolean", jnp.maximum, _ones_like_pair, 0.0,
                   jax.ops.segment_max)
MIN_PLUS = Semiring("min_plus", jnp.minimum, jnp.add, float("inf"),
                    jax.ops.segment_min)
PLUS_FIRST = Semiring("plus_first", jnp.add, _first, 0.0,
                      jax.ops.segment_sum)

SEMIRINGS = {
    "plus_times": PLUS_TIMES,
    "boolean": BOOLEAN,
    "any_pair": BOOLEAN,       # GraphBLAS alias
    "min_plus": MIN_PLUS,
    "plus_first": PLUS_FIRST,
}


def resolve_semiring(s: "str | Semiring") -> Semiring:
    """Accept a registry name or a Semiring instance (custom semirings are
    legal anywhere a name is -- they just need hashable fields so they can be
    a static jit argument)."""
    if isinstance(s, Semiring):
        return s
    try:
        return SEMIRINGS[s]
    except KeyError:
        raise ValueError(
            f"unknown semiring {s!r}; known: {sorted(SEMIRINGS)}") from None
