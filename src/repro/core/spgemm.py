"""SpGEMM: C = A @ B on sparse A, B (paper sections 2 & 4).

Four executable algorithms, mirroring Table 1 of the paper:

  algorithm      phases  accumulator                 sortedness (in/out)
  -----------    ------  --------------------------  -------------------
  ``dense``      1       dense (oracle only)         any / sorted
  ``esc``        2       sort + segmented reduce     any / sorted
  ``heap``       1       k-way tournament merge      sorted / sorted
  ``hash``       2       VMEM hash table (Pallas)    any / select
  ``hash_vector``2       VMEM vectorized probing     any / select

``dense`` is the test oracle.  ``esc`` (expand-sort-compress) is the
XLA-native baseline -- it is the sort-based family the paper cites from the
GPU literature [18, 21] and doubles as the TPU-idiomatic "sorted merge"
equivalent of the heap path.  ``heap`` is the faithful one-phase merge of
section 4.2.3 (an argmin tournament replaces the pointer heap: on a VPU the
k-wide argmin is one vector op, while a binary heap is a latency-bound
pointer chase -- see DESIGN.md section 2).  ``hash``/``hash_vector`` live in
``repro.kernels.spgemm_hash`` (Pallas) with a jnp fallback here
(:func:`spgemm_hash_jnp`) that owns the semiring/masked generalizations.

Graph-workload generalizations (DESIGN.md section 7):

  * every accumulator takes ``semiring=`` (:mod:`repro.core.semiring`):
    ``plus_times`` (default), ``boolean``/``any_pair``, ``min_plus``,
    ``plus_first``;
  * ``mask=`` takes a structural CSR mask (``complement_mask=True`` inverts
    it) and prunes candidates *inside* the expand/merge/probe loops -- never
    by post-filtering a dense product -- with matching capacity math in
    :func:`symbolic` (``schedule.masked_row_bound``).

Shapes are static everywhere: capacities come from the symbolic phase
(:func:`symbolic`), the dynamic ``nnz`` rides along as a scalar -- the
paper's two-phase method is load-bearing under XLA.
"""
from __future__ import annotations

from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from .formats import CSR, csr_sorted_keys, sorted_keys_contain
from .semiring import Semiring, resolve_semiring
from . import schedule as sched

Algorithm = Literal["auto", "dense", "esc", "heap", "hash", "hash_vector",
                    "hash_jnp", "bcsr", "pb"]

#: hash-order scrambling modulus for the jnp hash fallback (Fig. 8's
#: multiply hash over a fixed 2^20 table: output order == table-scan order).
_HASH_CONST = -1640531527
_HASH_P = 1 << 20


# ----------------------------------------------------------------------------
# Mask plumbing (DESIGN.md section 7): structural CSR masks, probed with one
# binary search per candidate inside the accumulator loops.  All membership
# logic lives in formats.csr_sorted_keys / sorted_keys_contain (shared with
# CSR.contains) so the sorted_cols guard exists exactly once.
# ----------------------------------------------------------------------------

def _check_mask(a: CSR, b: CSR, mask: CSR | None):
    """Masks live in output coordinates: shape must be (m, n) of C.

    The membership probe encodes ``row * n_cols + col`` with the *mask's*
    n_cols; a shape-mismatched mask would silently test arbitrary other
    coordinates, so fail loudly instead."""
    if mask is not None:
        assert mask.shape == (a.n_rows, b.n_cols), \
            f"mask shape {mask.shape} != output shape {(a.n_rows, b.n_cols)}"


def _canon_mask(mask: CSR | None) -> CSR | None:
    """Probes binary-search row-major keys; an unsorted mask (e.g. a
    previous hash-family output) is canonicalized first.  ``sorted_cols``
    is static metadata, so this is a trace-time branch."""
    if mask is not None and not mask.sorted_cols:
        return mask.sort_rows()
    return mask


def _mask_prune(rows, cols, valid, mask: CSR | None, complement: bool):
    """valid &= (rows, cols) in mask  (or not-in, when complemented)."""
    if mask is None:
        return valid
    allowed = mask.contains(rows, cols)
    if complement:
        allowed = ~allowed
    return valid & allowed


# ----------------------------------------------------------------------------
# Symbolic phase (paper Fig. 7 "Symbolic"): flop bound + exact nnz(C).
# ----------------------------------------------------------------------------

def symbolic_flops(a: CSR, b: CSR) -> jax.Array:
    """Upper bound per-row nnz(C) = flop per row. O(nnz(A)) like the paper."""
    return sched.flops_per_row(a, b)


@partial(jax.jit, static_argnames=("complement_mask", "flop_cap"))
def symbolic(a: CSR, b: CSR, mask: CSR | None = None,
             complement_mask: bool = False, flop_cap: int | None = None):
    """Exact per-row nnz(C) and total flop, mask-aware.

    Returns (row_nnz_c, indptr_c, flop_per_row, total_flop).  Uses the
    dense-free ESC expansion with a *count-distinct* reduction; this is the
    two-phase method's phase one, giving the numeric phase its exact static
    capacity requirement (the "select cap" the launcher uses).  With a mask,
    pruned candidates are not counted, so the capacity the launcher
    allocates is the *masked* nnz(C) -- additionally bounded a priori by
    ``schedule.masked_row_bound``.

    ``flop_cap`` sizes the expansion buffer.  The default is the worst-case
    ``O(cap_a * min(cap_b, n))`` bound; callers with a tight bound -- the
    planner passes the exact ``flop.sum()`` on structure-identical re-plans
    -- shrink the dominant intermediate by orders of magnitude.  It must be
    >= the true total flop or candidates are silently dropped.
    """
    _check_mask(a, b, mask)
    mask = _canon_mask(mask)
    flop = symbolic_flops(a, b)
    if flop_cap is None:
        flop_cap = _default_flop_cap(a, b)
    rows, cols, _, valid = _expand(a, b, flop_cap=flop_cap)
    valid = _mask_prune(rows, cols, valid, mask, complement_mask)
    order = jnp.lexsort((cols, jnp.where(valid, rows, a.n_rows)))
    rows_s, cols_s, valid_s = rows[order], cols[order], valid[order]
    newseg = _boundary_flags(rows_s, cols_s, valid_s)
    row_nnz = jax.ops.segment_sum(newseg.astype(jnp.int32),
                                  jnp.where(valid_s, rows_s, a.n_rows),
                                  num_segments=a.n_rows + 1)[:-1]
    indptr_c = sched.prefix_sum(row_nnz).astype(jnp.int32)
    return row_nnz, indptr_c, flop, flop.sum()


# ----------------------------------------------------------------------------
# Oracle
# ----------------------------------------------------------------------------

def spgemm_dense(a: CSR, b: CSR, cap_c: int,  # verify: allow(no-densify)
                 semiring: str | Semiring = "plus_times",
                 mask: CSR | None = None,
                 complement_mask: bool = False) -> CSR:
    """Reference oracle via dense product. O(m*n*k) -- tests only.

    The only code path allowed to post-filter a dense product with the mask;
    every real accumulator prunes inside its loops.

    Representation caveat: a dense array cannot carry an *explicit zero*,
    so a structurally-present entry whose semiring value is exactly 0
    (e.g. a zero-sum ``min_plus`` path under mixed-sign weights) is dropped
    by ``CSR.from_dense`` here while the sparse accumulators keep it.
    ``to_dense()`` comparisons are unaffected; nnz comparisons against this
    oracle are only exact when values cannot hit 0 (the R-MAT suite uses
    values in [0.5, 1.5]).
    """
    sr = resolve_semiring(semiring)
    _check_mask(a, b, mask)
    ad, bd = a.to_dense(), b.to_dense()
    ap, bp = ad != 0, bd != 0
    if sr.name == "plus_times":
        c = ad @ bd
    elif sr.name == "boolean":
        c = ((ap.astype(jnp.float32) @ bp.astype(jnp.float32)) > 0) \
            .astype(a.dtype)
    elif sr.name == "plus_first":
        c = ad @ bp.astype(ad.dtype)
    elif sr.name == "min_plus":
        pair = ap[:, :, None] & bp[None, :, :]
        s = jnp.where(pair, ad[:, :, None] + bd[None, :, :], jnp.inf)
        c = jnp.min(s, axis=1)
        c = jnp.where(jnp.isinf(c), 0.0, c).astype(a.dtype)
    else:
        raise ValueError(f"dense oracle lacks semiring {sr.name!r}")
    if mask is not None:
        md = mask.to_dense() != 0
        keep = ~md if complement_mask else md
        c = jnp.where(keep, c, 0)
    return CSR.from_dense(c, cap=cap_c)


# ----------------------------------------------------------------------------
# ESC: expand - sort - compress
# ----------------------------------------------------------------------------

def _default_flop_cap(a: CSR, b: CSR) -> int:
    # static heuristic: every A slot cannot touch more than min(b.cap, n_cols)
    # B entries; callers with tight bounds should pass flop_cap explicitly.
    return a.cap * max(1, min(b.cap, b.n_cols))


def _expand(a: CSR, b: CSR, flop_cap: int, sr: Semiring | None = None):
    """Materialize all intermediate products (paper's `value` in Fig. 1).

    Returns (rows, cols, vals, valid) each of shape (flop_cap,).
    ``vals`` holds ``sr.mul`` products with ``sr.zero`` in invalid lanes.
    """
    if sr is None:
        from .semiring import PLUS_TIMES
        sr = PLUS_TIMES
    pnz = (b.indptr[a.indices + 1] - b.indptr[a.indices]).astype(jnp.int32)
    pnz = jnp.where(a.valid_mask(), pnz, 0)
    off = sched.prefix_sum(pnz)                      # (cap_a + 1,)
    total = off[-1]
    p = jnp.arange(flop_cap, dtype=jnp.int32)
    j = jnp.clip(jnp.searchsorted(off, p, side="right") - 1, 0, a.cap - 1)
    t = p - off[j]
    b_slot = jnp.clip(b.indptr[a.indices[j]] + t, 0, b.cap - 1)
    valid = p < total
    rows = a.row_ids()[j]
    cols = jnp.where(valid, b.indices[b_slot], 0)
    vals = jnp.where(valid, sr.mul(a.data[j], b.data[b_slot]),
                     jnp.asarray(sr.zero, a.dtype))
    return rows, cols, vals, valid


def _boundary_flags(rows_s, cols_s, valid_s):
    prev_r = jnp.concatenate([jnp.full((1,), -1, rows_s.dtype), rows_s[:-1]])
    prev_c = jnp.concatenate([jnp.full((1,), -1, cols_s.dtype), cols_s[:-1]])
    return valid_s & ((rows_s != prev_r) | (cols_s != prev_c))


def _esc_core(a: CSR, b: CSR, cap_c: int, flop_cap: int | None,
              sr: Semiring, mask: CSR | None, complement_mask: bool,
              hash_order: bool) -> CSR:
    """Shared expand/prune/sort/compress pipeline.

    ``hash_order=False``: plain ESC, output sorted by column (Table 1).
    ``hash_order=True``: the hash-family jnp fallback -- within each row the
    output is emitted in multiply-hash *table-scan* order (Fig. 8a over a
    fixed 2^20 table), i.e. deliberately unsorted, preserving the C8
    contract so the sorted-vs-unsorted gap stays measurable on CPU.

    Mask pruning happens right after expand -- the jnp analogue of skipping
    the probe/insert for masked-out candidates -- so pruned candidates never
    enter the sort (the expensive part) nor claim an output slot.
    """
    if flop_cap is None:
        flop_cap = _default_flop_cap(a, b)
    _check_mask(a, b, mask)
    mask = _canon_mask(mask)
    m, n = a.n_rows, b.n_cols
    rows, cols, vals, valid = _expand(a, b, flop_cap, sr)
    valid = _mask_prune(rows, cols, valid, mask, complement_mask)
    vals = jnp.where(valid, vals, jnp.asarray(sr.zero, a.dtype))
    sort_rows = jnp.where(valid, rows, m)  # invalid to the end
    if hash_order:
        h = (cols * _HASH_CONST) & (_HASH_P - 1)
        order = jnp.lexsort((cols, h, sort_rows))
    else:
        order = jnp.lexsort((cols, sort_rows))
    rows_s, cols_s, vals_s, valid_s = (rows[order], cols[order], vals[order],
                                       valid[order])
    flags = _boundary_flags(rows_s, cols_s, valid_s)
    uid = jnp.cumsum(flags.astype(jnp.int32)) - 1          # id of output slot
    nnz_c = flags.sum().astype(jnp.int32)
    seg = jnp.where(valid_s, jnp.minimum(uid, cap_c - 1), cap_c)
    data_c = sr.segment_reduce(vals_s, seg, num_segments=cap_c + 1)[:cap_c]
    put = jnp.where(flags & (uid < cap_c), uid, cap_c)
    cols_c = jnp.zeros((cap_c,), jnp.int32).at[put].set(cols_s, mode="drop")
    row_nnz = jax.ops.segment_sum(flags.astype(jnp.int32),
                                  jnp.where(valid_s, rows_s, m),
                                  num_segments=m + 1)[:-1]
    indptr_c = sched.prefix_sum(row_nnz).astype(jnp.int32)
    nnz_c = jnp.minimum(nnz_c, cap_c)
    valid_c = jnp.arange(cap_c, dtype=jnp.int32) < nnz_c
    data_c = jnp.where(valid_c, data_c, 0).astype(a.dtype)
    return CSR(indptr_c, cols_c, data_c, nnz_c, (m, n),
               sorted_cols=not hash_order)


@partial(jax.jit, static_argnames=("cap_c", "flop_cap", "semiring",
                                   "complement_mask"))
def spgemm_esc(a: CSR, b: CSR, cap_c: int, flop_cap: int | None = None,
               semiring: str | Semiring = "plus_times",
               mask: CSR | None = None,
               complement_mask: bool = False) -> CSR:
    """Expand-sort-compress SpGEMM. Output is sorted (it is a sort)."""
    sr = resolve_semiring(semiring)
    return _esc_core(a, b, cap_c, flop_cap, sr, mask, complement_mask,
                     hash_order=False)


@partial(jax.jit, static_argnames=("cap_c", "flop_cap", "semiring",
                                   "complement_mask"))
def spgemm_hash_jnp(a: CSR, b: CSR, cap_c: int, flop_cap: int | None = None,
                    semiring: str | Semiring = "plus_times",
                    mask: CSR | None = None,
                    complement_mask: bool = False) -> CSR:
    """jnp fallback for the hash family (semiring/mask generality).

    The Pallas kernels in ``repro.kernels.spgemm_hash`` stay specialized to
    the arithmetic semiring; any request with a non-default semiring or a
    mask routes here.  Contract-equivalent to the kernel: two-phase exact
    capacity, mask pruned at probe time (before any accumulation state is
    touched), rows emitted in table-scan order => ``sorted_cols=False`` (C8).
    """
    sr = resolve_semiring(semiring)
    return _esc_core(a, b, cap_c, flop_cap, sr, mask, complement_mask,
                     hash_order=True)


# ----------------------------------------------------------------------------
# Heap SpGEMM (paper section 4.2.3): one-phase k-way merge, sorted in/out.
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("row_cap", "k_width", "cap_c", "semiring",
                                   "complement_mask"))
def spgemm_heap(a: CSR, b: CSR, row_cap: int, k_width: int,
                cap_c: int | None = None,
                semiring: str | Semiring = "plus_times",
                mask: CSR | None = None,
                complement_mask: bool = False) -> CSR:
    """Faithful one-phase merge accumulator.

    Per output row i: ``nnz(a_i*)`` cursors walk the (sorted) rows of B; each
    step extracts the minimum head column (argmin tournament == heap
    extract-min), accumulates into the current output slot, and advances that
    cursor -- exactly Fig. 1 with the section 4.2.3 accumulator.  Memory per
    row is O(nnz(a_i*)) cursors + O(row_cap) output, matching the paper's
    space argument.

    Semiring: ``sr.mul`` at the leaves, ``sr.add`` on same-column repeats.
    Mask: each extracted head is probed against the mask (one binary search
    on precomputed keys) *inside* the merge loop; masked-out candidates
    advance their cursor without claiming an output slot, so ``row_cap`` may
    be sized to the masked bound (``schedule.masked_row_bound``).

    Static bounds: ``k_width`` >= max nnz(a_i*); ``row_cap`` >= max nnz(c_i*);
    ``cap_c`` is the CSR output capacity (default ``m * row_cap``) -- passing
    the same ``cap_c`` every other algorithm uses keeps output shapes equal
    across the dispatcher, which is what makes compiled consumers reusable
    across algorithm choices.  A row that exceeds ``row_cap`` keeps its
    first ``row_cap`` (smallest-column) entries and *drops* the overflow --
    it never overwrites the last emitted entry.
    Requires sorted inputs, emits sorted output (Table 1).
    """
    assert a.sorted_cols and b.sorted_cols, "heap path requires sorted inputs"
    sr = resolve_semiring(semiring)
    _check_mask(a, b, mask)
    mask = _canon_mask(mask)
    m, n = a.n_rows, b.n_cols
    INF = jnp.int32(n + 1)
    mkeys = None if mask is None else csr_sorted_keys(mask)

    k = jnp.arange(k_width, dtype=jnp.int32)[None, :]
    a_start = a.indptr[:-1][:, None] + k                      # (m, k_width)
    a_live = k < (a.indptr[1:] - a.indptr[:-1])[:, None]
    a_slot = jnp.clip(a_start, 0, a.cap - 1)
    a_vals = jnp.where(a_live, a.data[a_slot], 0)             # (m, k_width)
    b_row = jnp.where(a_live, a.indices[a_slot], 0)
    cur = jnp.where(a_live, b.indptr[b_row], 0)               # cursor per lane
    end = jnp.where(a_live, b.indptr[b_row + 1], 0)

    def one_row(row_id, cur, end, avals):
        out_cols = jnp.full((row_cap,), -1, jnp.int32)
        out_vals = jnp.zeros((row_cap,), a.dtype)

        def cond(state):
            cur, _, _, _ = state
            return jnp.any(cur < end)

        def body(state):
            cur, out_cols, out_vals, out_n = state
            heads = jnp.where(cur < end, b.indices[jnp.clip(cur, 0, b.cap - 1)],
                              INF)
            j = jnp.argmin(heads)                              # extract-min
            c = heads[j]
            v = sr.mul(avals[j], b.data[jnp.clip(cur[j], 0, b.cap - 1)])
            if mkeys is None:
                allowed = jnp.bool_(True)
            else:
                allowed = sorted_keys_contain(mkeys,
                                              row_id * jnp.int32(n) + c)
                if complement_mask:
                    allowed = ~allowed
            prev = out_cols[jnp.maximum(out_n - 1, 0)]
            same = (out_n > 0) & (prev == c)
            # Overflow policy: a *new* column on a full row is dropped (the
            # cursor still advances), keeping the first row_cap entries
            # intact; repeats of the last kept column still accumulate.
            allowed = allowed & (same | (out_n < row_cap))
            slot = jnp.where(same, out_n - 1, jnp.minimum(out_n, row_cap - 1))
            out_cols = out_cols.at[slot].set(
                jnp.where(allowed, c, out_cols[slot]))
            out_vals = out_vals.at[slot].set(
                jnp.where(allowed,
                          jnp.where(same, sr.add(out_vals[slot], v), v),
                          out_vals[slot]))
            out_n = jnp.where(allowed & ~same,
                              jnp.minimum(out_n + 1, row_cap), out_n)
            cur = cur.at[j].add(1)
            return cur, out_cols, out_vals, out_n

        _, out_cols, out_vals, out_n = jax.lax.while_loop(
            cond, body, (cur, out_cols, out_vals, jnp.int32(0)))
        return out_cols, out_vals, out_n

    out_cols, out_vals, out_n = jax.vmap(one_row)(
        jnp.arange(m, dtype=jnp.int32), cur, end, a_vals)      # (m, cap)
    # compact (m, row_cap) panels into a cap_c-sized CSR buffer (matching
    # the static output shape of the esc/hash paths; default keeps the old
    # worst-case m * row_cap panel size)
    if cap_c is None:
        cap_c = m * row_cap
    indptr_c = sched.prefix_sum(out_n).astype(jnp.int32)
    nnz_c = jnp.minimum(indptr_c[-1], jnp.int32(cap_c))
    lane = jnp.arange(row_cap, dtype=jnp.int32)[None, :]
    live = lane < out_n[:, None]
    dest = jnp.where(live, indptr_c[:-1][:, None] + lane, cap_c)
    cols_c = jnp.zeros((cap_c,), jnp.int32).at[dest.ravel()].set(
        jnp.maximum(out_cols, 0).ravel(), mode="drop")
    data_c = jnp.zeros((cap_c,), a.dtype).at[dest.ravel()].set(
        out_vals.ravel(), mode="drop")
    return CSR(indptr_c, cols_c, data_c, nnz_c, (m, n), sorted_cols=True)


# ----------------------------------------------------------------------------
# SpMM: CSR x dense (square x tall-skinny use case, section 5.5)
# ----------------------------------------------------------------------------

@jax.jit
def spmm(a: CSR, x: jax.Array) -> jax.Array:
    """C = A @ X with dense X of shape (n, k). Gather + segment-sum."""
    vals = jnp.where(a.valid_mask(), a.data, 0)
    gathered = vals[:, None] * x[a.indices]          # (cap, k)
    return jax.ops.segment_sum(gathered, a.row_ids(), num_segments=a.n_rows)


# ----------------------------------------------------------------------------
# Sort-on-demand epilogue + public dispatcher
# ----------------------------------------------------------------------------

def finalize(c: CSR, sorted_output: bool) -> CSR:
    """Sort-on-demand epilogue: sort ``c``'s rows iff the caller asked for
    sorted output and the accumulator emitted select (unsorted) order.

    This is the single place the dispatcher, ``SpGEMMPlan.execute``, and
    the chain executor (``core.chain``) pay the Eq. 2 sort term
    ``sum_i nnz(c_i*) log nnz(c_i*)`` -- and deliberately *not* paying it
    between chain stages is the paper's C8 finding applied at every
    internal hop (DESIGN.md section 12).  A no-op on already-sorted
    results (``sorted_cols`` is static metadata, so this is a trace-time
    branch).
    """
    if sorted_output and not c.sorted_cols:
        return c.sort_rows()
    return c

def spgemm(a: CSR, b: CSR, cap_c: int | None = None,
           algorithm: Algorithm = "auto",
           sorted_output: bool | None = None,
           semiring: str | Semiring = "plus_times",
           mask: CSR | None = None, complement_mask: bool = False,
           use_case: str | None = None, plan=None, **kw) -> CSR:
    """Front door. ``auto`` consults the recipe (core.recipe).

    ``semiring``/``mask`` flow to every accumulator; the Pallas hash kernels
    keep their (+, x) specialization, so generalized requests on the hash
    family execute :func:`spgemm_hash_jnp` (same contract, unsorted output).

    ``plan=`` takes an :class:`repro.core.plan.SpGEMMPlan` (inspector-
    executor path): schedule, symbolic capacities, and the recipe choice all
    come from the plan and nothing is recomputed -- every other argument
    except ``(a, b)`` is ignored.
    """
    if plan is not None:
        return plan.execute(a, b)
    assert cap_c is not None, "spgemm needs cap_c unless plan= is given"
    sr = resolve_semiring(semiring)
    general = sr.name != "plus_times" or mask is not None
    mask = _canon_mask(mask)
    if algorithm == "auto":
        from .recipe import choose_algorithm
        if use_case is None:
            use_case = "masked" if mask is not None else "AxA"
        algorithm = choose_algorithm(
            a, b, sorted_output=bool(sorted_output), use_case=use_case,
            semiring=sr.name, mask=mask, complement_mask=complement_mask)
    if algorithm == "dense":
        out = spgemm_dense(a, b, cap_c, semiring=sr, mask=mask,
                           complement_mask=complement_mask)
    elif algorithm == "esc":
        out = spgemm_esc(a, b, cap_c, semiring=sr, mask=mask,
                         complement_mask=complement_mask, **kw)
    elif algorithm == "heap":
        row_cap = kw.pop("row_cap", min(cap_c, b.n_cols))
        k_width = kw.pop("k_width", a.cap)
        # cap_c flows through so heap output shapes agree with every other
        # algorithm (static-shape contract; jit reuse across algorithms).
        out = spgemm_heap(a, b, row_cap=row_cap, k_width=k_width,
                          cap_c=cap_c, semiring=sr, mask=mask,
                          complement_mask=complement_mask)
    elif algorithm == "hash_jnp":
        # Explicit jnp-fallback request: same contract as the hash family
        # (unsorted select output) with no Pallas dependency.  Its roles
        # today: the reference oracle in the differential tests, and the
        # body of *planless* traced hash calls (the planned paths thread
        # frozen schedules through vmap/shard_map and run the real Pallas
        # kernel -- core/batch.py, core/distributed.py).
        kw.pop("schedule", None)
        kw.pop("indptr_c", None)
        kw.pop("table_size", None)
        out = spgemm_hash_jnp(a, b, cap_c, semiring=sr, mask=mask,
                              complement_mask=complement_mask, **kw)
    elif algorithm in ("hash", "hash_vector"):
        if general:
            # Pallas kernels are (+, x)-specialized; the jnp fallback owns
            # semirings and masked probing (DESIGN.md section 7).
            kw.pop("n_bins", None)
            kw.pop("table_size", None)
            kw.pop("vector", None)
            kw.pop("interpret", None)
            kw.pop("schedule", None)
            kw.pop("indptr_c", None)
            out = spgemm_hash_jnp(a, b, cap_c, semiring=sr, mask=mask,
                                  complement_mask=complement_mask, **kw)
        else:
            from repro.kernels.spgemm_hash import ops as hash_ops
            out = hash_ops.spgemm_hash(a, b, cap_c,
                                       vector=(algorithm == "hash_vector"),
                                       **kw)
    elif algorithm == "bcsr":
        if general:
            raise NotImplementedError(
                "bcsr path is (+, x)-only and unmasked; pick esc/heap/hash")
        # TPU block path (DESIGN.md section 2): dense (bm, bn) tiles on the
        # MXU with a block-column hash accumulator.  CSR in / CSR out.
        from repro.core.formats import csr_to_bcsr, bcsr_to_csr
        from repro.kernels.spgemm_bcsr import ops as bcsr_ops
        block = kw.pop("block", (8, 8))
        # ragged shapes land in a ceil-divided grid (partial edge tiles are
        # zero-padded storage; formats crop back to the logical shape)
        bcap_c = kw.pop("bcap_c",
                        (-(-a.n_rows // block[0])) *
                        (-(-b.n_cols // block[1])))
        ab = csr_to_bcsr(a, (block[0], block[1]))
        bb = csr_to_bcsr(b, (block[1], block[1]))
        cb = bcsr_ops.spgemm_bcsr(ab, bb, bcap_c=bcap_c, **kw)
        out = bcsr_to_csr(cb, cap=cap_c)
    elif algorithm == "pb":
        # Propagation blocking (DESIGN.md section 18): outer-product
        # expansion bucketed by column segment, merged per bucket.  The
        # direct call plans eagerly (inspection needs concrete structure);
        # repeat products should hold the PBPlan (core.pb.plan_pb) and
        # execute it, exactly like the hash/bcsr planned paths.
        from .pb import plan_pb
        pbp = plan_pb(a, b, semiring=sr.name, mask=mask,
                      complement_mask=complement_mask,
                      n_buckets=kw.pop("n_buckets", None),
                      budget=kw.pop("budget", sched.PB_BUCKET_BUDGET),
                      cache=kw.pop("cache", True))
        assert cap_c >= pbp.nnz_c, \
            f"cap_c={cap_c} < exact nnz(C)={pbp.nnz_c}"
        out = pbp.execute(a, b)
        if out.cap < cap_c:
            pad = cap_c - out.cap
            out = CSR(out.indptr, jnp.pad(out.indices, (0, pad)),
                      jnp.pad(out.data, (0, pad)), out.nnz, out.shape,
                      out.sorted_cols)
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return finalize(out, bool(sorted_output))
