"""Data substrate: graph/matrix generators + the LM token pipeline."""
from . import rmat, matrices
