"""Deterministic synthetic LM data pipeline.

Bitwise-reproducible by (step, shard): the stream is a fixed random Markov
chain over the vocabulary, generated with counter-based PRNG keyed on
``(seed, step)`` -- no filesystem, no state.  Determinism is what makes
checkpoint/restart *exactly* resumable (tests/test_fault_tolerance.py) and
is the data-side half of the straggler story: any host can recompute any
shard of any step.

A Markov stream (order-1, skewed transitions) is learnable, so example
training runs show a real loss curve rather than log(V) noise.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def make_transition_table(vocab: int, seed: int = 7, branch: int = 4):
    """Each token has `branch` likely successors. Host-side, O(V*branch)."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, branch))
    return jnp.asarray(succ, jnp.int32)


@partial(jax.jit, static_argnames=("batch", "seq", "vocab", "n_codebooks"))
def sample_batch(table, step, *, batch: int, seq: int, vocab: int,
                 n_codebooks: int = 0, seed: int = 0):
    """Returns {"tokens", "labels"} for a given step (deterministic)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    n_streams = batch * max(n_codebooks, 1)
    k0, k1 = jax.random.split(key)
    start = jax.random.randint(k0, (n_streams,), 0, vocab)
    picks = jax.random.randint(k1, (n_streams, seq), 0, table.shape[1])

    def walk(tok, pick_t):
        nxt = table[tok, pick_t]
        return nxt, nxt

    _, toks = jax.lax.scan(
        lambda c, p: walk(c, p), start, picks.T)
    toks = toks.T                                     # (n_streams, seq)
    if n_codebooks:
        toks = toks.reshape(batch, n_codebooks, seq).transpose(0, 2, 1)
        labels = jnp.roll(toks, -1, axis=1)
    else:
        toks = toks.reshape(batch, seq)
        labels = jnp.roll(toks, -1, axis=1)
    return {"tokens": toks, "labels": labels}


class DataPipeline:
    """Sharded, prefetching view of the synthetic stream.

    `global_batch` is divided over `n_hosts`; each host materializes only
    its shard (host_id picks the slice deterministically).  `prefetch`
    issues the jitted sample for step+1 while step executes (async dispatch
    does the overlap on real hardware)."""

    def __init__(self, cfg, global_batch: int, seq: int, *, seed: int = 0,
                 n_hosts: int = 1, host_id: int = 0):
        self.vocab = cfg.vocab_size
        self.ncb = cfg.n_codebooks
        self.table = make_transition_table(self.vocab, seed=seed + 7)
        assert global_batch % n_hosts == 0
        self.local_batch = global_batch // n_hosts
        self.seq = seq
        self.seed = seed * 1000 + host_id
        self._next = None
        self._next_step = None

    def batch(self, step: int):
        if self._next_step == step and self._next is not None:
            out = self._next
        else:
            out = sample_batch(self.table, step, batch=self.local_batch,
                               seq=self.seq, vocab=self.vocab,
                               n_codebooks=self.ncb, seed=self.seed)
        # prefetch next (async dispatch)
        self._next = sample_batch(self.table, step + 1,
                                  batch=self.local_batch, seq=self.seq,
                                  vocab=self.vocab, n_codebooks=self.ncb,
                                  seed=self.seed)
        self._next_step = step + 1
        return out
