"""Synthetic proxy suite for the paper's Table 2 (26 SuiteSparse matrices).

SuiteSparse is not available offline, so each matrix is replaced by a
synthetic proxy matched on the statistics the paper's evaluation keys on:
dimension ``n``, ``nnz(A)``, ``flop(A^2)`` and ``nnz(A^2)`` -- hence the same
compression ratio CR = flop/nnz(A^2), which is the x-axis of Figs. 14/17 and
the decision variable of Table 4.  Profiles are scaled down by
``SCALE_DIVISOR`` so the suite runs on one CPU core; CR and edge factor are
scale-free so the recipe evaluation is preserved.

Each proxy mixes three pattern families to hit the target flop/nnz ratios:
  * banded/stencil rows (regular FEM-like: cant, consph, pwtk, ...)
  * power-law rows (graphs: wb-edu, webbase, patents, ...)
  * uniform random rows (ER-like: mc2depi, majorbasis, ...)
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.formats import CSR

#: (name, n, nnz, flop(A^2), nnz(A^2)) in raw counts -- Table 2 (millions).
TABLE2 = [
    ("2cubes_sphere",   101_492,  1_647_264,   27_450_606,   8_974_526),
    ("cage12",          130_228,  2_032_536,   34_610_826,  15_231_874),
    ("cage15",        5_154_859, 99_199_551, 2_078_631_615, 929_023_247),
    ("cant",             62_451,  4_007_383,  269_486_473,  17_440_029),
    ("conf5_4-8x8-05",   49_152,  1_916_928,   74_760_192,  10_911_744),
    ("consph",           83_334,  6_010_480,  463_845_030,  26_539_736),
    ("cop20k_A",        121_192,  2_624_331,   79_883_385,  18_705_069),
    ("delaunay_n24", 16_777_216, 100_663_202,  633_914_372, 347_322_258),
    ("filter3D",        106_437,  2_707_179,   85_957_185,  20_161_619),
    ("hood",            220_542, 10_768_436,  562_028_117,  34_242_181),
    ("m133-b3",         200_200,    800_800,    3_203_200,   3_182_751),
    ("mac_econ_fwd500", 206_500,  1_273_389,    7_556_897,   6_704_899),
    ("majorbasis",      160_000,  1_750_416,   19_178_064,   8_243_392),
    ("mario002",        389_874,  2_097_566,   12_829_364,   6_449_598),
    ("mc2depi",         525_825,  2_100_225,    8_391_680,   5_245_952),
    ("mono_500Hz",      169_410,  5_036_288,  204_030_968,  41_377_964),
    ("offshore",        259_789,  4_242_673,   71_342_515,  23_356_245),
    ("patents_main",    240_547,    560_943,    2_604_790,   2_281_308),
    ("pdb1HYS",          36_417,  4_344_765,  555_322_659,  19_594_581),
    ("poisson3Da",       13_514,    352_762,   11_770_796,   2_957_530),
    ("pwtk",            217_918, 11_634_424,  626_054_402,  32_772_236),
    ("rma10",            46_835,  2_374_001,  156_480_259,   7_900_917),
    ("scircuit",        170_998,    958_936,    8_676_313,   5_222_525),
    ("shipsec1",        140_874,  7_813_404,  450_639_288,  24_086_412),
    ("wb-edu",        9_845_725, 57_156_537, 1_559_579_990, 630_077_764),
    ("webbase-1M",    1_000_005,  3_105_536,   69_524_195,  51_111_996),
]

#: Downscale factor so the proxy suite runs on this container.
SCALE_DIVISOR = 256


@dataclass(frozen=True)
class MatrixProfile:
    name: str
    n: int
    nnz: int
    flop: int          # flop(A^2) of the original
    nnz_c: int         # nnz(A^2) of the original

    @property
    def edge_factor(self) -> float:
        return self.nnz / self.n

    @property
    def compression_ratio(self) -> float:
        return self.flop / self.nnz_c


def profiles() -> list[MatrixProfile]:
    return [MatrixProfile(*row) for row in TABLE2]


def _power_law_degrees(rng, n, mean_deg, skew=2.0):
    raw = rng.pareto(skew, n) + 1.0
    deg = np.maximum(1, (raw / raw.mean() * mean_deg).astype(np.int64))
    return np.minimum(deg, n - 1)


def synth_proxy(profile: MatrixProfile, seed: int = 0,
                divisor: int = SCALE_DIVISOR, cap: int | None = None) -> CSR:
    """Build a proxy with ~n/divisor rows matching edge factor and CR.

    CR = flop/nnz(C) is controlled by the *overlap regularity* of rows:
    banded rows (all neighbors adjacent) maximize index collisions -> high
    CR; scattered power-law rows minimize them -> CR ~ 1.  We interpolate by
    giving each row a band of width w around a center, where w is fit from
    the target CR, plus power-law degree spread for skewed targets.
    """
    rng = np.random.default_rng(seed + hash(profile.name) % (1 << 16))
    n = max(64, profile.n // divisor)
    ef = max(1.0, profile.edge_factor)
    target_cr = profile.compression_ratio
    # banded share: high CR needs clustered columns. Empirical map fit in
    # tests: share = clip((cr - 1) / (ef), 0, 1).
    banded_share = float(np.clip((target_cr - 1.0) / max(ef, 1.0), 0.0, 0.95))
    deg = _power_law_degrees(rng, n, ef) if target_cr < 3.0 else \
        np.maximum(1, rng.poisson(ef, n))
    rows_list, cols_list = [], []
    centers = rng.integers(0, n, n)
    for i in range(n):
        d = int(deg[i])
        nb = int(round(d * banded_share))
        band = (centers[i] + np.arange(nb)) % n
        rest = rng.integers(0, n, d - nb)
        cols_i = np.concatenate([band, rest])
        rows_list.append(np.full(cols_i.shape[0], i, np.int64))
        cols_list.append(cols_i.astype(np.int64))
    rows = np.concatenate(rows_list)
    cols = np.concatenate(cols_list)
    vals = rng.uniform(0.5, 1.5, rows.shape[0]).astype(np.float32)
    return CSR.from_numpy_coo(rows, cols, vals, (n, n), cap=cap)


def suite(divisor: int = SCALE_DIVISOR, seed: int = 0,
          max_matrices: int | None = None):
    """Yield (profile, CSR) for the whole proxy suite."""
    ps = profiles()
    if max_matrices is not None:
        ps = ps[:max_matrices]
    for p in ps:
        yield p, synth_proxy(p, seed=seed, divisor=divisor)
