"""R-MAT recursive matrix generator (Chakrabarti et al. [9]; paper section 5.1).

Two presets, exactly as the paper:
  * ER   -- a=b=c=d=0.25 (Erdos-Renyi uniform)
  * G500 -- a=0.57, b=c=0.19, d=0.05 (Graph500 power-law / skewed)

"A scale n matrix represents 2^n-by-2^n"; ``edge_factor`` = nnz / n.
Host-side numpy implementation (generation is data-pipeline work, not a
jit-hot path), returning a :class:`repro.core.CSR`.
"""
from __future__ import annotations

import numpy as np

from repro.core.formats import CSR

PRESETS = {
    "ER":   (0.25, 0.25, 0.25, 0.25),
    "G500": (0.57, 0.19, 0.19, 0.05),
}


def rmat_edges(scale: int, edge_factor: int, preset: str = "G500",
               seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Generate ~n*edge_factor directed edges over 2^scale vertices."""
    a, b, c, d = PRESETS[preset]
    n = 1 << scale
    n_edges = n * edge_factor
    rng = np.random.default_rng(seed)
    rows = np.zeros(n_edges, np.int64)
    cols = np.zeros(n_edges, np.int64)
    # vectorized bit-by-bit recursive descent
    p_row1 = c + d                      # P(row bit = 1)
    for bit in range(scale):
        r = rng.random(n_edges)
        row_bit = (r >= a + b).astype(np.int64)
        # conditional col-bit probability given row bit
        p_col1 = np.where(row_bit == 0, b / (a + b), d / (c + d))
        col_bit = (rng.random(n_edges) < p_col1).astype(np.int64)
        rows = (rows << 1) | row_bit
        cols = (cols << 1) | col_bit
    del p_row1
    return rows, cols


def rmat_csr(scale: int, edge_factor: int, preset: str = "G500",
             seed: int = 0, cap: int | None = None,
             dtype=np.float32) -> CSR:
    """Paper-style input: R-MAT pattern, unit-ish values, duplicates summed."""
    rows, cols = rmat_edges(scale, edge_factor, preset, seed)
    n = 1 << scale
    rng = np.random.default_rng(seed + 1)
    vals = rng.uniform(0.5, 1.5, size=rows.shape[0]).astype(dtype)
    return CSR.from_numpy_coo(rows, cols, vals, (n, n), cap=cap)


def er_csr(scale: int, edge_factor: int, seed: int = 0,
           cap: int | None = None) -> CSR:
    return rmat_csr(scale, edge_factor, "ER", seed, cap)


def g500_csr(scale: int, edge_factor: int, seed: int = 0,
             cap: int | None = None) -> CSR:
    return rmat_csr(scale, edge_factor, "G500", seed, cap)


def tall_skinny_from(a_rows: np.ndarray, a_cols: np.ndarray, n: int,
                     k_scale: int, seed: int = 0,
                     cap: int | None = None) -> CSR:
    """Paper section 5.5: the tall-skinny B is built by randomly selecting
    2^k_scale columns of the graph itself (multi-source BFS frontiers)."""
    rng = np.random.default_rng(seed)
    k = 1 << k_scale
    chosen = rng.choice(n, size=k, replace=False)
    col_map = np.full(n, -1, np.int64)
    col_map[chosen] = np.arange(k)
    keep = col_map[a_cols] >= 0
    rows, cols = a_rows[keep], col_map[a_cols[keep]]
    vals = np.ones(rows.shape[0], np.float32)
    return CSR.from_numpy_coo(rows, cols, vals, (n, k), cap=cap)


def aggregation_csr(n: int, coarse: int, seed: int = 0):
    """AMG-style aggregation pair for Galerkin triple products R·A·P.

    ``P`` is ``(n, coarse)`` with one unit entry per row (each fine
    vertex assigned to a random aggregate) and ``R = P^T``; returns
    ``(r, p)``.  Shared by ``benchmarks/bench_chain.py`` and
    ``tests/test_chain.py`` so both exercise the same coarsening shape.
    """
    from repro.core.formats import csr_transpose
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, coarse, size=n)
    p = CSR.from_numpy_coo(np.arange(n), cols, np.ones(n, np.float32),
                           (n, coarse))
    return csr_transpose(p), p


def symmetrize(a: CSR, cap: int | None = None) -> CSR:
    """Undirected simple graph from a directed pattern: A|A^T, no diagonal.

    Host-side preprocessing (like generation itself); shared by the graph
    example, the graph benchmarks, and the tests.
    """
    d = np.asarray(a.to_dense())
    d = ((d + d.T) > 0).astype(np.float32)
    np.fill_diagonal(d, 0.0)
    return CSR.from_dense(np.asarray(d), cap=cap)


def triangular_split(a: CSR, return_adjacency: bool = False):
    """Paper section 5.6 preprocessing: reorder rows by increasing degree,
    split A = L + U; returns (L, U) ready for the L @ U wedge count.

    With ``return_adjacency=True`` also returns the degree-permuted
    adjacency as a CSR -- the structural mask of the masked triangle count
    ``spgemm(L, U, mask=adj)`` (only wedges that close into triangles are
    ever accumulated; DESIGN.md section 7).
    """
    dense = np.asarray(a.to_dense())
    deg = (dense != 0).sum(axis=1)
    order = np.argsort(deg, kind="stable")
    p = dense[order][:, order]
    l = np.tril(p, k=-1)
    u = np.triu(p, k=1)
    L = CSR.from_dense(np.asarray(l), cap=a.cap)
    U = CSR.from_dense(np.asarray(u), cap=a.cap)
    if return_adjacency:
        return L, U, CSR.from_dense(np.asarray(p), cap=a.cap)
    return L, U
