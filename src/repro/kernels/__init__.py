"""Pallas TPU kernels (validated in interpret mode on CPU; see DESIGN.md).

  spgemm_hash     -- paper C2/C3: hash + vectorized-probe SpGEMM (CSR)
  spgemm_bcsr     -- TPU adaptation: block-row Gustavson on the MXU
  spgemm_pb       -- propagation-blocking scatter/merge pair (low CF)
  spmm            -- CSR x dense (square x tall-skinny use case)
  flash_attention -- online-softmax attention for the LM prefill path
"""
