"""Version shims for the Pallas TPU API surface.

The kernels are written against the current Pallas names; older jaxlibs
(<= 0.4.x) spell some of them differently.  Everything version-dependent is
funnelled through here so the kernel bodies stay on one spelling.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

#: ``pltpu.CompilerParams`` (new) vs ``pltpu.TPUCompilerParams`` (<= 0.4.x).
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
