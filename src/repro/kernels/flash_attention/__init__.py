from .ops import flash_attention, chunked_attention
