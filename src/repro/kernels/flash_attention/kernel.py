"""Pallas TPU kernel: causal flash attention (online-softmax), GQA-aware.

Used by the LM stack for prefill (the 32k cells would otherwise materialize
S^2 score panels: 32768^2 * 2B = 2 GiB per head).  Standard two-level
structure: grid = (batch, q_head, q_block, kv_block) with the kv dimension
innermost ("arbitrary" semantics) carrying (m, l, acc) scratch across
iterations; output is emitted on the last *needed* kv block.

TPU notes:
  * q/k/v blocks are (bq, d) / (bkv, d) VMEM tiles; d is the lane dim
    (128/256 -> MXU-aligned);
  * fully-causally-masked kv blocks are skipped with ``pl.when`` -- the
    paper's C1 lesson (do no work you can statically avoid) applied to the
    attention grid;
  * GQA: the kv head index is ``q_head // group`` in the BlockSpec index
    map, so KV tiles are fetched once per group on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale, causal, bq, bkv, n_kv_blocks):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal block skip: block is needed iff its first kv index is <= the
    # last q index of this q block.
    if causal:
        needed = ki * bkv <= qi * bq + bq - 1
        last_needed = jnp.minimum(jnp.int32(n_kv_blocks - 1),
                                  (qi * bq + bq - 1) // bkv)
    else:
        needed = None
        last_needed = n_kv_blocks - 1

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            cols = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    if causal:
        pl.when(needed)(_compute)
    else:
        _compute()

    @pl.when(ki == last_needed)
    def _emit():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def fwd_call(batch: int, n_heads: int, n_kv_heads: int, seq_q: int,
             seq_kv: int, d: int, *, scale: float, causal: bool,
             bq: int, bkv: int, dtype, interpret: bool):
    group = n_heads // n_kv_heads
    nq, nkv = seq_q // bq, seq_kv // bkv
    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               bq=bq, bkv=bkv, n_kv_blocks=nkv)
    grid = (batch, n_heads, nq, nkv)
    q_spec = pl.BlockSpec((1, 1, bq, d), lambda b, h, qi, ki: (b, h, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, bkv, d),
                           lambda b, h, qi, ki: (b, h // group, ki, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((batch, n_heads, seq_q, d), dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )
