"""Jit'd wrapper for the flash attention kernel, plus the pure-XLA chunked
fallback the dry-run lowers on non-TPU backends.

``flash_attention``      -- Pallas kernel (TPU target; interpret elsewhere).
``chunked_attention``    -- lax.scan online-softmax with O(S * bkv) memory;
                            identical math, lowers on any backend.  This is
                            what the LM stack uses under the dry-run so
                            compile-time memory stays bounded at 32k/500k.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import kernel as K


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool | None = None):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, H, Sq, D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, bq, skv, bkv)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    call = K.fwd_call(b, h, hkv, sq, skv, d, scale=scale, causal=causal,
                      bq=bq, bkv=bkv, dtype=q.dtype, interpret=interpret)
    return call(q, k, v)


# ---------------------------------------------------------------------------
# Flash-style chunked attention with a custom VJP (the XLA fallback path).
#
# Differentiating *through* the forward scan makes XLA save every chunk's
# probability panel -- O(S^2) residuals, exactly what flash attention
# exists to avoid (measured: +GBs of temp per device in the baseline
# dry-run; EXPERIMENTS.md Perf iteration 2).  The custom VJP stores only
# (q, k, v, out, lse) and the backward rescans kv chunks recomputing p,
# accumulating dq and emitting per-chunk dk/dv -- the standard flash
# backward, in pure XLA.
# ---------------------------------------------------------------------------

def _mask_scores(s, q_pos, k_pos, causal, window):
    if causal:
        m = q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            m &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(m[None, None], s, K.NEG_INF)
    return s


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _chunked_attn_core(q, k, v, causal, window, bkv, shard_q, shard_kv):
    out, _ = _chunked_attn_fwd_impl(q, k, v, causal, window, bkv, shard_q,
                                    shard_kv)
    return out


def _chunked_attn_fwd_impl(q, k, v, causal, window, bkv, shard_q, shard_kv):
    b, h, sq, d = q.shape
    skv = k.shape[2]
    n_chunks = skv // bkv
    scale = 1.0 / (d ** 0.5)
    qf = shard_q(q.astype(jnp.float32))
    # keep kv in model dtype through the scan xs (the SP gather then moves
    # bf16); upcast per-chunk inside the step.
    ks = jnp.moveaxis(k.reshape(b, h, n_chunks, bkv, d), 2, 0)
    vs = jnp.moveaxis(v.reshape(b, h, n_chunks, bkv, d), 2, 0)
    q_pos = (skv - sq) + jnp.arange(sq)

    def step(carry, xs):
        m, l, acc = carry
        ci, kc, vc = xs
        kcr = shard_kv(kc)
        vcr = shard_kv(vc)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                       kcr.astype(jnp.float32)) * scale
        s = _mask_scores(s, q_pos, ci * bkv + jnp.arange(bkv), causal,
                         window)
        m_new = shard_q(jnp.maximum(m, jnp.max(s, axis=-1)))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = shard_q(l * alpha + jnp.sum(p, axis=-1))
        # PV contraction in the *input* dtype: for bf16 models this halves
        # the dominant attention HBM traffic and feeds the MXU its native
        # dtype (Perf iter 7); softmax statistics stay f32; f32 inputs keep
        # full precision.
        acc_new = shard_q(acc * alpha[..., None] +
                          jnp.einsum("bhqk,bhkd->bhqd",
                                     p.astype(vcr.dtype), vcr
                                     ).astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = shard_q(jnp.full((b, h, sq), K.NEG_INF, jnp.float32))
    l0 = shard_q(jnp.zeros((b, h, sq), jnp.float32))
    acc0 = shard_q(jnp.zeros((b, h, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  (jnp.arange(n_chunks), ks, vs))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out, lse


def _chunked_attn_vjp_fwd(q, k, v, causal, window, bkv, shard_q, shard_kv):
    out, lse = _chunked_attn_fwd_impl(q, k, v, causal, window, bkv, shard_q,
                                      shard_kv)
    return out, (q, k, v, out, lse)


def _chunked_attn_vjp_bwd(causal, window, bkv, shard_q, shard_kv, res, dout):
    q, k, v, out, lse = res
    b, h, sq, d = q.shape
    skv = k.shape[2]
    n_chunks = skv // bkv
    scale = 1.0 / (d ** 0.5)
    qf = shard_q(q.astype(jnp.float32))
    do = shard_q(dout.astype(jnp.float32))
    Drow = shard_q(jnp.sum(do * out.astype(jnp.float32), axis=-1))  # (B,H,S)
    ks = jnp.moveaxis(k.reshape(b, h, n_chunks, bkv, d), 2, 0)
    vs = jnp.moveaxis(v.reshape(b, h, n_chunks, bkv, d), 2, 0)
    q_pos = (skv - sq) + jnp.arange(sq)

    def step(dq, xs):
        ci, kc, vc = xs
        kcr = shard_kv(kc)
        vcr = shard_kv(vc)
        lp = kcr.dtype   # low-precision contraction dtype = input dtype
        s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                       kcr.astype(jnp.float32)) * scale
        s = _mask_scores(s, q_pos, ci * bkv + jnp.arange(bkv), causal,
                         window)
        p = jnp.exp(s - lse[..., None])                    # recomputed
        dv_c = jnp.einsum("bhqk,bhqd->bhkd", p.astype(lp),
                          do.astype(lp)).astype(jnp.float32)
        dp = jnp.einsum("bhqd,bhkd->bhqk", do, vcr.astype(jnp.float32))
        ds = p * (dp - Drow[..., None])
        dq = shard_q(dq + jnp.einsum("bhqk,bhkd->bhqd", ds.astype(lp),
                                     kcr).astype(jnp.float32) * scale)
        dk_c = jnp.einsum("bhqk,bhqd->bhkd", ds.astype(lp),
                          qf.astype(lp)).astype(jnp.float32) * scale
        return dq, (dk_c, dv_c)

    dq0 = shard_q(jnp.zeros((b, h, sq, d), jnp.float32))
    dq, (dks, dvs) = jax.lax.scan(step, dq0,
                                  (jnp.arange(n_chunks), ks, vs))
    dk = jnp.moveaxis(dks, 0, 2).reshape(b, h, skv, d)
    dv = jnp.moveaxis(dvs, 0, 2).reshape(b, h, skv, d)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_chunked_attn_core.defvjp(_chunked_attn_vjp_fwd, _chunked_attn_vjp_bwd)


def chunked_attention(q, k, v, shard=None, shard_kv=None, *,
                      causal: bool = True, window: int | None = None,
                      bkv: int = 512):
    # NOTE: deliberately not jit-wrapped -- always called inside the outer
    # jitted step, and `shard` closures would defeat the jit cache.
    """Online-softmax attention as a lax.scan over kv chunks (flash math).

    Peak live intermediate is (B, H, Sq, bkv) instead of (B, H, Sq, Skv).
    GQA KV heads are repeated up-front so every tensor keeps the clean
    (batch->DP, heads->TP) layout -- folding heads into (hkv, group) splits
    one mesh axis across two tensor dims, which SPMD cannot express as a
    sharding and resolves by replicating scan carries (the "involuntary
    full rematerialization" found in the baseline dry-run; EXPERIMENTS.md
    section Perf iteration 1).

    ``shard``: optional callable(array) -> array applying the caller's
    sharding constraint; it is applied to q/k/v and to every scan carry so
    both the forward and the transposed (backward) scan stay head-sharded.

    q positions are assumed to be the *last* Sq positions of the kv stream
    (prefill: Sq == Skv; decode: Sq == 1).  ``window`` adds sliding-window
    masking (recurrentgemma local attention).
    """
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = h // hkv
    bkv = min(bkv, skv)
    assert skv % bkv == 0
    ident = lambda x: x
    shard = shard or ident
    shard_kv = shard_kv or ident
    # constrain (=> gather, under SP) the *un-repeated* GQA heads, then
    # repeat locally: the all-gather moves n_kv_heads, not n_heads.
    kf = jnp.repeat(shard_kv(k), group, axis=1)
    vf = jnp.repeat(shard_kv(v), group, axis=1)
    return _chunked_attn_core(q, kf, vf, causal, window, bkv, shard,
                              shard_kv)
