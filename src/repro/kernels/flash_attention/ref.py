"""Pure-jnp oracle: exact softmax attention with GQA + causal mask."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: float | None = None
                  ) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D). Returns (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    if causal:
        skv = k.shape[2]
        mask = jnp.arange(sq)[:, None] + (skv - sq) >= jnp.arange(skv)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vq.astype(jnp.float32)).astype(q.dtype)
