from .ops import spgemm_bcsr
