"""Pallas TPU kernel: block-row Gustavson SpGEMM over BCSR (TPU adaptation).

This is the paper's hash algorithm lifted to the tile granularity the MXU
needs (DESIGN.md sections 2 + 17): the unit of sparsity is a dense
``(bm, bk)`` tile, the hash keys are **block**-column ids, and the
accumulator is a bank of ``(bm, bn)`` VMEM tiles addressed by the hash
table -- i.e. Fig. 7 where `insert` allocates an MXU accumulator tile
instead of a scalar.

Per grid program (one equal-flop bin of block rows, C1):
  for block-row i in bin:                      # Gustavson outer loop
    reinit table                               # C5: reuse, don't realloc
    for j in A.block_row(i):                   # A tiles
      for t in B.block_row(A.bcol[j]):         # B tiles
        slot = hash_probe(B.bcol[t])           # C2: linear probing
        acc[slot] += A.block[j] @ B.block[t]   # MXU (preferred f32 accum)
    flush occupied slots -> C blocks           # unsorted block order (C8)

Like the scalar hash kernel, every bin probes and flushes only its own
power-of-two effective table prefix (Fig. 7 lines 9-12): ``bin_tsize``
rides in as a prefetched scalar so a bin of light block rows never scans
the single worst row's table -- with ``(bm, bn)`` accumulator tiles the
flush saving is ``bm * bn`` times the scalar kernel's.

The batched-grid variant (``batched_numeric_call``) adds a leading grid
dimension over fleet members -- grid ``(n_members, n_bins)``, member
payloads blocked ``(1, bcap[, bm, bk])`` by BlockSpec, schedules as 2-D
prefetched scalars indexed ``[member, bin]`` -- exactly the shape
``spgemm_hash`` uses so the planned BCSR path traces under ``vmap``
through the ``custom_vmap`` rule in ``ops.py``.  The scratch bank is
shared across the whole grid: the block-row loop reinitializes it per
block row, so member programs cannot observe each other.

The scalar-CSR hash kernel (`spgemm_hash`) handles the sparse regime where
blocks would be mostly empty; `core.recipe` arbitrates (block density term).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

from repro.kernels.spgemm_hash.kernel import (_View, _probe_scalar,
                                              _probe_vector, EMPTY)


def _block_row_loop(i, *, indptr_a_ref, indptr_b_ref, a_bcol_ref, a_blk_ref,
                    b_bcol_ref, b_blk_ref, tkey_ref, tacc_ref, tsz, vector):
    """Fig. 1 inner loops for one output *block* row, hash accumulation.

    ``tsz`` is this bin's effective table size (a power of two <= the
    static scratch allocation); probes never leave the ``[0, tsz)``
    prefix, so accumulator tiles past it stay zero and cost nothing but
    the vectorized whole-bank reinit.
    """
    probe = _probe_vector if vector else _probe_scalar
    # Fig. 7: "reuses that hash table ... by reinitializing for each row".
    tkey_ref[...] = jnp.full_like(tkey_ref, EMPTY)
    tacc_ref[...] = jnp.zeros_like(tacc_ref)

    def do_a(j, _):
        k = a_bcol_ref[j]
        a_blk = a_blk_ref[j]                      # (bm, bk) VMEM tile

        def do_b(t, _):
            c = b_bcol_ref[t]
            slot = probe(tkey_ref, c, tsz)
            tkey_ref[slot] = c
            # MXU tile product with f32 accumulation (the PR-6 rounding
            # contract: the backend may fuse each scalar lane into FMAs,
            # so bitwise claims vs per-product-rounding oracles hold on
            # dyadic values and to 1 ulp per product otherwise).
            tacc_ref[slot] = tacc_ref[slot] + jnp.dot(
                a_blk, b_blk_ref[t], preferred_element_type=jnp.float32)
            return 0

        return jax.lax.fori_loop(indptr_b_ref[k], indptr_b_ref[k + 1],
                                 do_b, 0)

    jax.lax.fori_loop(indptr_a_ref[i], indptr_a_ref[i + 1], do_a, 0)


def _numeric_kernel(offsets_ref, tsize_ref, indptr_a_ref, indptr_b_ref,
                    indptr_c_ref, a_bcol_ref, a_blk_ref, b_bcol_ref,
                    b_blk_ref, out_bcol_ref, out_blk_ref, tkey_ref,
                    tacc_ref, *, table_size, vector):
    bin_id = pl.program_id(0)
    # per-bin effective table size (prefetched; clamped to the allocation)
    tsz = jnp.minimum(tsize_ref[bin_id], jnp.int32(table_size))

    @pl.when(bin_id == 0)
    def _init():
        out_bcol_ref[...] = jnp.zeros_like(out_bcol_ref)
        out_blk_ref[...] = jnp.zeros_like(out_blk_ref)

    def do_block_row(i, _):
        _block_row_loop(
            i, indptr_a_ref=indptr_a_ref, indptr_b_ref=indptr_b_ref,
            a_bcol_ref=a_bcol_ref, a_blk_ref=a_blk_ref,
            b_bcol_ref=b_bcol_ref, b_blk_ref=b_blk_ref,
            tkey_ref=tkey_ref, tacc_ref=tacc_ref, tsz=tsz, vector=vector)
        # Flush occupied slots in table order -> **unsorted** block
        # columns (C8).  Only this bin's [0, tsz) prefix can be occupied.
        base = indptr_c_ref[i]

        def flush(s, cnt):
            key = tkey_ref[s]
            occupied = key != EMPTY
            pos = base + cnt

            @pl.when(occupied)
            def _():
                out_bcol_ref[pos] = key
                out_blk_ref[pos] = tacc_ref[s]

            return cnt + occupied.astype(jnp.int32)

        jax.lax.fori_loop(0, tsz, flush, jnp.int32(0))
        return 0

    jax.lax.fori_loop(offsets_ref[bin_id], offsets_ref[bin_id + 1],
                      do_block_row, 0)


@functools.lru_cache(maxsize=128)
def numeric_call(n_bins: int, gm: int, bcap_a: int, bcap_b: int, bcap_c: int,
                 block_a, block_b, table_size: int, vector: bool,
                 interpret: bool):
    """Cached builder for the plain (1-D grid) numeric phase.

    Call signature of the returned function:
    ``(offsets, bin_tsize, indptr_a, indptr_b, indptr_c,
       a_bcol, a_blk, b_bcol, b_blk)`` -> ``(out_bcol, out_blk)``.
    """
    bm, bk = block_a
    bk2, bn = block_b
    assert bk == bk2, (block_a, block_b)
    kernel = functools.partial(_numeric_kernel, table_size=table_size,
                               vector=vector)
    full1 = lambda n: pl.BlockSpec((n,), lambda b, *p: (0,))
    full3 = lambda n, r, c: pl.BlockSpec((n, r, c), lambda b, *p: (0, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,   # offsets, bin_tsize, indptr_a/b, indptr_c
        grid=(n_bins,),
        in_specs=[full1(bcap_a), full3(bcap_a, bm, bk),
                  full1(bcap_b), full3(bcap_b, bk, bn)],
        out_specs=[full1(bcap_c), full3(bcap_c, bm, bn)],
        scratch_shapes=[pltpu.VMEM((table_size,), jnp.int32),
                        pltpu.VMEM((table_size, bm, bn), jnp.float32)],
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((bcap_c,), jnp.int32),
                   jax.ShapeDtypeStruct((bcap_c, bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
    ))


# ---------------------------------------------------------------------------
# batched grid: one extra grid dimension over fleet members
# ---------------------------------------------------------------------------

def _batched_numeric_kernel(offsets_ref, tsize_ref, indptr_a_ref,
                            indptr_b_ref, indptr_c_ref, a_bcol_ref,
                            a_blk_ref, b_bcol_ref, b_blk_ref, out_bcol_ref,
                            out_blk_ref, tkey_ref, tacc_ref, *,
                            table_size, vector):
    e = pl.program_id(0)                      # fleet member
    b = pl.program_id(1)                      # equal-flop block-row bin
    tsz = jnp.minimum(tsize_ref[e, b], jnp.int32(table_size))
    ic = _View(indptr_c_ref, e)               # prefetched: full 2-D array
    oc, ob = _View(out_bcol_ref, 0), _View(out_blk_ref, 0)

    @pl.when(b == 0)
    def _init():
        out_bcol_ref[...] = jnp.zeros_like(out_bcol_ref)
        out_blk_ref[...] = jnp.zeros_like(out_blk_ref)

    def do_block_row(i, _):
        _block_row_loop(
            i, indptr_a_ref=_View(indptr_a_ref, e),
            indptr_b_ref=_View(indptr_b_ref, e),
            a_bcol_ref=_View(a_bcol_ref, 0), a_blk_ref=_View(a_blk_ref, 0),
            b_bcol_ref=_View(b_bcol_ref, 0), b_blk_ref=_View(b_blk_ref, 0),
            tkey_ref=tkey_ref, tacc_ref=tacc_ref, tsz=tsz, vector=vector)
        base = ic[i]

        def flush(s, cnt):
            key = tkey_ref[s]
            occupied = key != EMPTY
            pos = base + cnt

            @pl.when(occupied)
            def _():
                oc[pos] = key
                ob[pos] = tacc_ref[s]

            return cnt + occupied.astype(jnp.int32)

        jax.lax.fori_loop(0, tsz, flush, jnp.int32(0))
        return 0

    jax.lax.fori_loop(offsets_ref[e, b], offsets_ref[e, b + 1],
                      do_block_row, 0)


@functools.lru_cache(maxsize=128)
def batched_numeric_call(n_members: int, n_bins: int, gm: int, bcap_a: int,
                         bcap_b: int, bcap_c: int, block_a, block_b,
                         table_size: int, vector: bool, interpret: bool):
    """Batched-grid numeric phase: grid ``(n_members, n_bins)``.

    Mirrors :func:`numeric_call` with a leading member axis on every
    operand: schedules ``(n_members, n_bins+1)`` / ``(n_members,
    n_bins)``, block payloads ``(n_members, bcap[, bm, bk])``, outputs
    ``(n_members, bcap_c[, bm, bn])``.  The scratch bank is shared across
    the whole grid -- the block-row loop reinitializes it per block row,
    so member programs cannot observe each other.
    """
    bm, bk = block_a
    bk2, bn = block_b
    assert bk == bk2, (block_a, block_b)
    kernel = functools.partial(_batched_numeric_kernel,
                               table_size=table_size, vector=vector)
    bfull1 = lambda n: pl.BlockSpec((1, n), lambda e, b, *p: (e, 0))
    bfull3 = lambda n, r, c: pl.BlockSpec((1, n, r, c),
                                          lambda e, b, *p: (e, 0, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,   # offsets, bin_tsize, indptr_a/b, indptr_c
        grid=(n_members, n_bins),
        in_specs=[bfull1(bcap_a), bfull3(bcap_a, bm, bk),
                  bfull1(bcap_b), bfull3(bcap_b, bk, bn)],
        out_specs=[bfull1(bcap_c), bfull3(bcap_c, bm, bn)],
        scratch_shapes=[pltpu.VMEM((table_size,), jnp.int32),
                        pltpu.VMEM((table_size, bm, bn), jnp.float32)],
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_members, bcap_c), jnp.int32),
                   jax.ShapeDtypeStruct((n_members, bcap_c, bm, bn),
                                        jnp.float32)],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    ))
