"""Pallas TPU kernel: block-row Gustavson SpGEMM over BCSR (TPU adaptation).

This is the paper's hash algorithm lifted to the tile granularity the MXU
needs (DESIGN.md section 2): the unit of sparsity is a dense ``(bm, bk)``
tile, the hash keys are **block**-column ids, and the accumulator is a bank
of ``(bm, bn)`` VMEM tiles addressed by the hash table -- i.e. Fig. 7 where
`insert` allocates an MXU accumulator tile instead of a scalar.

Per grid program (one equal-flop bin of block rows, C1):
  for block-row i in bin:                      # Gustavson outer loop
    reinit table                               # C5: reuse, don't realloc
    for j in A.block_row(i):                   # A tiles
      for t in B.block_row(A.bcol[j]):         # B tiles
        slot = hash_probe(B.bcol[t])           # C2: linear probing
        acc[slot] += A.block[j] @ B.block[t]   # MXU (preferred f32 accum)
    flush occupied slots -> C blocks           # unsorted block order (C8)

The scalar-CSR hash kernel (`spgemm_hash`) handles the sparse regime where
blocks would be mostly empty; `core.recipe` arbitrates (block density term).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

from repro.kernels.spgemm_hash.kernel import _probe_scalar, _probe_vector, EMPTY


def _numeric_kernel(offsets_ref, indptr_a_ref, indptr_b_ref, indptr_c_ref,
                    a_bcol_ref, a_blk_ref, b_bcol_ref, b_blk_ref,
                    out_bcol_ref, out_blk_ref, tkey_ref, tacc_ref, *,
                    table_size, vector):
    bin_id = pl.program_id(0)
    probe = _probe_vector if vector else _probe_scalar

    @pl.when(bin_id == 0)
    def _init():
        out_bcol_ref[...] = jnp.zeros_like(out_bcol_ref)
        out_blk_ref[...] = jnp.zeros_like(out_blk_ref)

    def do_block_row(i, _):
        tkey_ref[...] = jnp.full_like(tkey_ref, EMPTY)
        tacc_ref[...] = jnp.zeros_like(tacc_ref)

        def do_a(j, _):
            k = a_bcol_ref[j]
            a_blk = a_blk_ref[j]                      # (bm, bk) VMEM tile

            def do_b(t, _):
                c = b_bcol_ref[t]
                slot = probe(tkey_ref, c, table_size)
                tkey_ref[slot] = c
                # MXU tile product with f32 accumulation.
                tacc_ref[slot] = tacc_ref[slot] + jnp.dot(
                    a_blk, b_blk_ref[t], preferred_element_type=jnp.float32)
                return 0

            return jax.lax.fori_loop(indptr_b_ref[k], indptr_b_ref[k + 1],
                                     do_b, 0)

        jax.lax.fori_loop(indptr_a_ref[i], indptr_a_ref[i + 1], do_a, 0)

        base = indptr_c_ref[i]

        def flush(s, cnt):
            key = tkey_ref[s]
            occupied = key != EMPTY
            pos = base + cnt

            @pl.when(occupied)
            def _():
                out_bcol_ref[pos] = key
                out_blk_ref[pos] = tacc_ref[s]

            return cnt + occupied.astype(jnp.int32)

        jax.lax.fori_loop(0, table_size, flush, jnp.int32(0))
        return 0

    jax.lax.fori_loop(offsets_ref[bin_id], offsets_ref[bin_id + 1],
                      do_block_row, 0)


@functools.lru_cache(maxsize=128)
def numeric_call(n_bins: int, gm: int, bcap_a: int, bcap_b: int, bcap_c: int,
                 block_a, block_b, table_size: int, vector: bool,
                 interpret: bool):
    bm, bk = block_a
    bk2, bn = block_b
    assert bk == bk2, (block_a, block_b)
    kernel = functools.partial(_numeric_kernel, table_size=table_size,
                               vector=vector)
    full1 = lambda n: pl.BlockSpec((n,), lambda b, *p: (0,))
    full3 = lambda n, r, c: pl.BlockSpec((n, r, c), lambda b, *p: (0, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,   # offsets, indptr_a(blocks), indptr_b, indptr_c
        grid=(n_bins,),
        in_specs=[full1(bcap_a), full3(bcap_a, bm, bk),
                  full1(bcap_b), full3(bcap_b, bk, bn)],
        out_specs=[full1(bcap_c), full3(bcap_c, bm, bn)],
        scratch_shapes=[pltpu.VMEM((table_size,), jnp.int32),
                        pltpu.VMEM((table_size, bm, bn), jnp.float32)],
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((bcap_c,), jnp.int32),
                   jax.ShapeDtypeStruct((bcap_c, bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
    ))
