"""Jit'd wrapper for BCSR SpGEMM: symbolic at block granularity (reusing the
scalar hash symbolic kernel on the block *pattern*), then the MXU numeric
kernel.  The paper's two-phase structure is unchanged; only the currency is
tiles instead of scalars."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import CSR, BCSR
import repro.core.schedule as sched
from repro.kernels.spgemm_hash import kernel as HK
from . import kernel as K


def _pattern_csr(a: BCSR) -> CSR:
    """Block-occupancy pattern of a BCSR as a scalar CSR over the block grid."""
    gm, gn = a.grid
    ones = jnp.where(a.valid_mask(), 1.0, 0.0).astype(jnp.float32)
    return CSR(a.indptr, a.indices, ones, a.nnzb, (gm, gn), sorted_cols=True)


def spgemm_bcsr(a: BCSR, b: BCSR, bcap_c: int, *, n_bins: int = 8,
                vector: bool = False, table_size: int | None = None,
                interpret: bool | None = None) -> BCSR:
    """C = A @ B on BCSR operands. Block rows of C are unsorted (C8)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm, bk = a.block
    bk2, bn = b.block
    assert bk == bk2 and a.shape[1] == b.shape[0], (a.block, b.block)
    pa, pb = _pattern_csr(a), _pattern_csr(b)
    gm = pa.n_rows

    flop, offsets, tsize = sched.make_schedule(pa, pb, n_bins)
    if table_size is None:
        table_size = sched.lowest_p2(
            int(min(int(jnp.max(flop)), pb.n_cols)) + 1)
    table_size = max(table_size, HK.CHUNK)
    bin_tsize = sched.bin_table_sizes(tsize, pb.n_cols, table_size,
                                      floor=HK.CHUNK)

    # Phase 1 (symbolic): exact block-nnz per block row of C.
    sym = HK.symbolic_call(n_bins, gm, pa.cap, pb.cap, table_size, vector,
                           interpret)
    row_nnzb = sym(offsets, bin_tsize, pa.indptr, pb.indptr,
                   pa.indices, pa.data, pb.indices, pb.data)
    indptr_cb = sched.prefix_sum(row_nnzb).astype(jnp.int32)

    # Phase 2 (numeric): MXU tile products into the hash-addressed VMEM bank.
    num = K.numeric_call(n_bins, gm, a.bcap, b.bcap, bcap_c, a.block, b.block,
                         table_size, vector, interpret)
    bcols_c, blocks_c = num(offsets, a.indptr, b.indptr, indptr_cb,
                            a.indices, a.blocks.astype(jnp.float32),
                            b.indices, b.blocks.astype(jnp.float32))
    nnzb_c = indptr_cb[-1]
    valid = jnp.arange(bcap_c, dtype=jnp.int32) < nnzb_c
    bcols_c = jnp.where(valid, bcols_c, 0)
    blocks_c = jnp.where(valid[:, None, None], blocks_c, 0).astype(a.dtype)
    return BCSR(indptr_cb, bcols_c, blocks_c, nnzb_c,
                (a.shape[0], b.shape[1]), (bm, bn))
