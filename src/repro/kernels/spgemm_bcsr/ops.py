"""Jit'd wrapper for BCSR SpGEMM: symbolic at block granularity (reusing the
scalar hash symbolic kernel on the block *pattern*), then the MXU numeric
kernel.  The paper's two-phase structure is unchanged; only the currency is
tiles instead of scalars.

Inspector-executor path (``core.bcsr``): ``bcsr_inspect`` is the whole
Fig. 6/7 inspection at block granularity -- equal-flop block-row bins,
static + per-bin table sizes, and the exact block-nnz row pointer of C via
the scalar symbolic kernel on the occupancy patterns.  ``plan_bcsr`` runs
it once (eagerly) and freezes the result; ``spgemm_bcsr(...,
schedule=(offsets, bin_tsize), indptr_cb=...)`` then skips it entirely, so
a structure-identical repeat product stages the numeric kernel alone.

Trace contexts: with a plan-frozen schedule every dynamic value is an
ordinary traced array, so the planned path runs under ``jit`` and --
through a ``custom_vmap`` rule dispatching the batched grid of
``kernel.py`` -- under ``vmap`` over fleets of block-value members.

``KERNEL_CALLS`` counts, at trace time, which phase was staged:
``symbolic`` is the block-granularity inspection (schedule + symbolic
kernel), so planned repeat executes are proven to re-inspect zero times;
``numeric``/``batched_numeric`` are the MXU Pallas entries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import custom_batching

from repro.core.formats import CSR, BCSR
import repro.core.schedule as sched
from repro.kernels.spgemm_hash import kernel as HK
from . import kernel as K

#: Trace-time dispatch counters (see module docstring).
KERNEL_CALLS = {"symbolic": 0, "numeric": 0, "batched_numeric": 0}


def reset_kernel_calls() -> None:
    """Zero the trace-time dispatch counters (test/bench helper)."""
    for k in KERNEL_CALLS:
        KERNEL_CALLS[k] = 0


def kernel_call_counts() -> dict:
    """Snapshot of :data:`KERNEL_CALLS`."""
    return dict(KERNEL_CALLS)


def _pattern_csr(a: BCSR) -> CSR:
    """Block-occupancy pattern of a BCSR as a scalar CSR over the block grid."""
    gm, gn = a.grid
    ones = jnp.where(a.valid_mask(), 1.0, 0.0).astype(jnp.float32)
    return CSR(a.indptr, a.indices, ones, a.nnzb, (gm, gn), sorted_cols=True)


def bcsr_inspect(a: BCSR, b: BCSR, *, n_bins: int = 8, vector: bool = False,
                 table_size: int | None = None, interpret: bool | None = None,
                 eager: bool = False):
    """Block-granularity inspection: Fig. 6 schedule + Fig. 7 table sizing +
    symbolic block-nnz, all on the occupancy patterns of A and B.

    Returns ``(flop, offsets, bin_tsize, table_size, row_nnzb, indptr_cb)``
    where ``flop`` is the per-block-row *block* flop profile (the
    load-balance weight and the verifier's probe-termination bound).
    ``eager=True`` uses the un-jitted schedule so the int32 flop-overflow
    guard can fire on concrete inputs (the planner's path).
    """
    KERNEL_CALLS["symbolic"] += 1
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pa, pb = _pattern_csr(a), _pattern_csr(b)
    gm = pa.n_rows

    mk = sched.make_schedule_eager if eager else sched.make_schedule
    flop, offsets, tsize = mk(pa, pb, n_bins)
    if table_size is None:
        table_size = sched.lowest_p2(
            int(min(int(jnp.max(flop)), pb.n_cols)) + 1)
    table_size = max(table_size, HK.CHUNK)
    bin_tsize = sched.bin_table_sizes(tsize, pb.n_cols, table_size,
                                      floor=HK.CHUNK)

    # Phase 1 (symbolic): exact block-nnz per block row of C, via the
    # scalar hash symbolic kernel on the block patterns.
    sym = HK.symbolic_call(n_bins, gm, pa.cap, pb.cap, table_size, vector,
                           interpret)
    row_nnzb = sym(offsets, bin_tsize, pa.indptr, pb.indptr,
                   pa.indices, pa.data, pb.indices, pb.data)
    indptr_cb = sched.prefix_sum(row_nnzb).astype(jnp.int32)
    return flop, offsets, bin_tsize, table_size, row_nnzb, indptr_cb


# ---------------------------------------------------------------------------
# trace-context entry point: the plain numeric kernel, made vmappable
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)
def _numeric_entry(n_bins: int, gm: int, bcap_a: int, bcap_b: int,
                   bcap_c: int, block_a, block_b, table_size: int,
                   vector: bool, interpret: bool):
    plain = K.numeric_call(n_bins, gm, bcap_a, bcap_b, bcap_c, block_a,
                           block_b, table_size, vector, interpret)

    @custom_batching.custom_vmap
    def num(offsets, bin_tsize, indptr_a, indptr_b, indptr_c,
            a_bcol, a_blk, b_bcol, b_blk):
        KERNEL_CALLS["numeric"] += 1
        bcols, blocks = plain(offsets, bin_tsize, indptr_a, indptr_b,
                              indptr_c, a_bcol, a_blk, b_bcol, b_blk)
        return bcols, blocks

    @num.def_vmap
    def _rule(axis_size, in_batched, *args):
        KERNEL_CALLS["batched_numeric"] += 1
        args = [x if bd else jnp.broadcast_to(x, (axis_size,) + x.shape)
                for x, bd in zip(args, in_batched)]
        bcols, blocks = K.batched_numeric_call(
            axis_size, n_bins, gm, bcap_a, bcap_b, bcap_c, block_a, block_b,
            table_size, vector, interpret)(*args)
        return (bcols, blocks), (True, True)

    return num


def spgemm_bcsr(a: BCSR, b: BCSR, bcap_c: int, *, n_bins: int = 8,
                vector: bool = False, table_size: int | None = None,
                interpret: bool | None = None,
                schedule=None, indptr_cb: jax.Array | None = None) -> BCSR:
    """C = A @ B on BCSR operands. Block rows of C are unsorted (C8).

    ``schedule=(offsets, bin_tsize)`` skips the block-level Fig. 6
    inspection (pass a static ``table_size`` alongside); ``indptr_cb=``
    additionally skips the symbolic kernel -- the planned execute path
    stages the MXU numeric kernel alone.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm, bk = a.block
    bk2, bn = b.block
    assert bk == bk2 and a.shape[1] == b.shape[0], (a.block, b.block)
    gm = a.grid[0]

    if schedule is None or indptr_cb is None:
        assert schedule is None and indptr_cb is None, \
            "pass schedule and indptr_cb together (both from bcsr_inspect)"
        _, offsets, bin_tsize, table_size, _, indptr_cb = bcsr_inspect(
            a, b, n_bins=n_bins, vector=vector, table_size=table_size,
            interpret=interpret)
    else:
        offsets, bin_tsize = schedule
        assert table_size is not None, \
            "a precomputed schedule needs its static table_size"
        table_size = max(table_size, HK.CHUNK)
    n_bins = offsets.shape[0] - 1

    # Phase 2 (numeric): MXU tile products into the hash-addressed VMEM bank.
    num = _numeric_entry(n_bins, gm, a.bcap, b.bcap, bcap_c, a.block,
                         b.block, table_size, vector, interpret)
    bcols_c, blocks_c = num(offsets, bin_tsize, a.indptr, b.indptr, indptr_cb,
                            a.indices, a.blocks.astype(jnp.float32),
                            b.indices, b.blocks.astype(jnp.float32))
    nnzb_c = indptr_cb[-1]
    valid = jnp.arange(bcap_c, dtype=jnp.int32) < nnzb_c
    bcols_c = jnp.where(valid, bcols_c, 0)
    blocks_c = jnp.where(valid[:, None, None], blocks_c, 0).astype(a.dtype)
    return BCSR(indptr_cb, bcols_c, blocks_c, nnzb_c,
                (a.shape[0], b.shape[1]), (bm, bn))
