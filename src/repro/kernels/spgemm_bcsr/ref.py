"""Pure-jnp oracle for the BCSR SpGEMM kernel: dense product, re-blocked.

Structural note: C's block pattern from the kernel is the *product pattern*
(a block is present iff some A-block x B-block pair touches it), which can
include numerically-zero blocks under value cancellation; `to_dense`
comparison is therefore the canonical check.

Rounding contract (PR 6): the Pallas kernel accumulates each output lane
with the backend's fused multiply-add inside ``jnp.dot(...,
preferred_element_type=f32)``, while this twin -- like scipy's BSR
matmul -- rounds every product before summing.  Block pattern, block row
pointers, and (set-wise) block columns agree always; values agree bitwise
whenever the arithmetic is exactly representable (the dyadic fuzz values
{0.5, 1.0, 1.5, 2.0}), and to 1 ulp per accumulated product otherwise.
"""
from __future__ import annotations

import jax

from repro.core.formats import BCSR


def numeric_ref(a: BCSR, b: BCSR) -> jax.Array:
    """Dense jnp twin of the planned BCSR numeric phase (see module doc)."""
    return a.to_dense() @ b.to_dense()
