"""Pure-jnp oracle for the BCSR SpGEMM kernel: dense product, re-blocked.

Structural note: C's block pattern from the kernel is the *product pattern*
(a block is present iff some A-block x B-block pair touches it), which can
include numerically-zero blocks under value cancellation; `to_dense`
comparison is therefore the canonical check.
"""
from __future__ import annotations

import jax

from repro.core.formats import BCSR


def numeric_ref(a: BCSR, b: BCSR) -> jax.Array:
    return a.to_dense() @ b.to_dense()
