from .ops import spgemm_hash, spgemm_hash_symbolic
