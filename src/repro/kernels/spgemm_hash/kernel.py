"""Pallas TPU kernel: hash-accumulator SpGEMM (paper Figs. 7 & 8).

Faithful structure, TPU-resident state:

  * grid = equal-flop row bins from ``core.schedule`` (C1; Fig. 6) -- the
    Pallas grid replaces the OpenMP static thread pool;
  * per-program hash table in **VMEM scratch** (C5: thread-private memory,
    sized once per worker to the max per-row flop -- Fig. 7 lines 5-14 --
    and *reinitialized per row*, not reallocated);
  * power-of-two table, multiply hash, linear probing (Fig. 8a);
  * optional **vectorized probing** (C3 / Fig. 8b): the table is scanned in
    ``CHUNK``-wide vector compares -- the VPU analogue of the AVX-512
    chunked probe of Ross [28]; first-hit / first-empty are extracted with
    an iota-masked min instead of x86 ``ctz``;
  * two phases: ``symbolic`` counts nnz per row, ``numeric`` fills values
    (section 2: the two-phase method gives exact output capacity);
  * output rows are emitted **unsorted** (C8) in table-scan order; sorting
    is an explicit epilogue owned by the caller (Table 1 "Any/Select").

Memory plumbing: CSR arrays ride in VMEM whole (test scale); on a real chip
the row bins stream through double-buffered DMA windows, which changes the
BlockSpecs but not the kernel body.  Scalar row pointers (A, B, C) and the
bin offsets ride in SMEM via ``PrefetchScalarGridSpec`` so the control loops
never touch VMEM.

Batched-grid variants (``batched_symbolic_call`` / ``batched_numeric_call``)
add a leading grid dimension over fleet members: grid ``(n_members,
n_bins)``, member operands blocked ``(1, cap)`` by BlockSpec, schedules as
2-D prefetched scalars indexed ``[member, bin]``.  Scratch stays a single
unbatched table (static per capacity class) because ``_row_loop``
reinitializes it per row -- no cross-member state survives.  These are the
kernels ``ops.py`` swaps in through a ``custom_vmap`` rule so the planned
hash path traces under ``vmap`` (batched fleets) and ``shard_map``
(distributed executors) with bitwise-identical per-member results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

#: Knuth multiplicative constant (wraps mod 2^32; int32 two's complement).
HASH_CONST = -1640531527   # == 2654435761 mod 2^32 (Python int -> inlined literal)

#: Vector probe width (lanes compared per step in hash_vector mode).
CHUNK = 8

EMPTY = -1


def _hash(key: jax.Array, mask: jax.Array) -> jax.Array:
    return (key * HASH_CONST) & mask


def _probe_scalar(tkey_ref, key, table_size):
    """Linear probing (Fig. 8a): return slot holding `key` or first empty.

    ``table_size`` may be a static int or a traced per-bin scalar (Fig. 7
    lines 9-12: each bin probes only its own power-of-two-sized prefix of
    the scratch table); either way it must be a power of two.

    The probed key rides in the loop carry so the cond never reads the ref
    (older jax cannot discharge ref reads in a while cond under interpret
    mode; on TPU the two spellings lower identically).
    """
    mask = jnp.int32(table_size) - 1

    def cond(state):
        _, k = state
        return (k != key) & (k != EMPTY)

    def body(state):
        idx, _ = state
        nidx = (idx + 1) & mask
        return nidx, tkey_ref[nidx]

    idx0 = _hash(key, mask)
    idx, _ = jax.lax.while_loop(cond, body, (idx0, tkey_ref[idx0]))
    return idx


def _probe_vector(tkey_ref, key, table_size):
    """Chunked probing (Fig. 8b): compare CHUNK table entries per step.

    The hash addresses a *chunk*; within a chunk, hit/empty lanes are found
    with a masked iota-min (TPU stand-in for ``__builtin_ctz``).  Falls
    through to the next chunk on a full miss (linear probing over chunks).
    ``table_size`` may be static or a traced per-bin scalar (>= CHUNK).
    """
    cmask = jnp.int32(table_size) // CHUNK - 1
    lane = jax.lax.broadcasted_iota(jnp.int32, (CHUNK,), 0)
    BIG = CHUNK + 1

    def load(chunk_id):
        return pl.load(tkey_ref, (pl.ds(chunk_id * CHUNK, CHUNK),))

    # chunk contents ride in the carry: no ref reads in the while cond
    # (same interpret-mode constraint as _probe_scalar).
    def cond(state):
        _, ks = state
        return ~jnp.any((ks == key) | (ks == EMPTY))

    def body(state):
        chunk_id, _ = state
        nid = (chunk_id + 1) & cmask
        return nid, load(nid)

    c0 = _hash(key, cmask)
    chunk_id, ks = jax.lax.while_loop(cond, body, (c0, load(c0)))
    hit_lane = jnp.min(jnp.where(ks == key, lane, BIG))
    empty_lane = jnp.min(jnp.where(ks == EMPTY, lane, BIG))
    lane_id = jnp.where(hit_lane < BIG, hit_lane, empty_lane)
    return chunk_id * CHUNK + lane_id


def _row_loop(i, *, indptr_a_ref, indptr_b_ref, a_idx_ref, a_val_ref,
              b_idx_ref, b_val_ref, tkey_ref, tval_ref, tsize, vector,
              numeric):
    """Fig. 1 inner loops for one output row, hash accumulation.

    ``tsize`` is this bin's effective table size (Fig. 7 lines 9-12: a
    power of two <= the static scratch allocation); probes never leave the
    ``[0, tsize)`` prefix, so slots past it stay EMPTY and cost nothing but
    the vectorized whole-table reinit.
    """
    probe = _probe_vector if vector else _probe_scalar
    # Fig. 7: "reuses that hash table ... by reinitializing for each row".
    tkey_ref[...] = jnp.full_like(tkey_ref, EMPTY)
    if numeric:
        tval_ref[...] = jnp.zeros_like(tval_ref)

    def do_a(j, inserted):
        k = a_idx_ref[j]
        av = a_val_ref[j] if numeric else jnp.float32(0)

        def do_b(t, inserted):
            c = b_idx_ref[t]
            slot = probe(tkey_ref, c, tsize)
            is_new = tkey_ref[slot] == EMPTY
            tkey_ref[slot] = c
            if numeric:
                # NB the backend is free to contract this into an FMA (one
                # rounding per probe -- the host LLVM backend does, matching
                # the paper's AVX-512 FMA kernels).  Cross-oracle bitwise
                # claims therefore hold for exactly-representable arithmetic
                # (the dyadic fuzz values); against per-product-rounding
                # references (jnp twin, scipy) real-valued results may
                # differ by 1 ulp per accumulated product.
                tval_ref[slot] = tval_ref[slot] + av * b_val_ref[t]
            return inserted + is_new.astype(jnp.int32)

        return jax.lax.fori_loop(indptr_b_ref[k], indptr_b_ref[k + 1], do_b,
                                 inserted)

    return jax.lax.fori_loop(indptr_a_ref[i], indptr_a_ref[i + 1], do_a,
                             jnp.int32(0))


def _symbolic_kernel(offsets_ref, tsize_ref, indptr_a_ref, indptr_b_ref,
                     a_idx_ref, a_val_ref, b_idx_ref, b_val_ref,
                     row_nnz_ref, tkey_ref, *, table_size, vector):
    b = pl.program_id(0)
    # per-bin effective table size (prefetched; clamped to the allocation)
    tsz = jnp.minimum(tsize_ref[b], jnp.int32(table_size))

    def do_row(i, _):
        cnt = _row_loop(
            i, indptr_a_ref=indptr_a_ref, indptr_b_ref=indptr_b_ref,
            a_idx_ref=a_idx_ref, a_val_ref=a_val_ref, b_idx_ref=b_idx_ref,
            b_val_ref=b_val_ref, tkey_ref=tkey_ref, tval_ref=None,
            tsize=tsz, vector=vector, numeric=False)
        row_nnz_ref[i] = cnt
        return 0

    jax.lax.fori_loop(offsets_ref[b], offsets_ref[b + 1], do_row, 0)


def _numeric_kernel(offsets_ref, tsize_ref, indptr_a_ref, indptr_b_ref,
                    indptr_c_ref, a_idx_ref, a_val_ref, b_idx_ref, b_val_ref,
                    out_idx_ref, out_val_ref, tkey_ref, tval_ref, *,
                    table_size, vector):
    b = pl.program_id(0)
    tsz = jnp.minimum(tsize_ref[b], jnp.int32(table_size))

    @pl.when(b == 0)
    def _init():
        out_idx_ref[...] = jnp.zeros_like(out_idx_ref)
        out_val_ref[...] = jnp.zeros_like(out_val_ref)

    def do_row(i, _):
        _row_loop(
            i, indptr_a_ref=indptr_a_ref, indptr_b_ref=indptr_b_ref,
            a_idx_ref=a_idx_ref, a_val_ref=a_val_ref, b_idx_ref=b_idx_ref,
            b_val_ref=b_val_ref, tkey_ref=tkey_ref, tval_ref=tval_ref,
            tsize=tsz, vector=vector, numeric=True)
        # Flush occupied slots in table order -> **unsorted** columns (C8).
        # Only this bin's [0, tsz) prefix can be occupied, so the scan stops
        # there -- the per-bin sizing win the paper gets from Fig. 7 line 10.
        base = indptr_c_ref[i]

        def flush(s, cnt):
            key = tkey_ref[s]
            occupied = key != EMPTY
            pos = base + cnt
            # masked single-element store: padded lane writes are dropped by
            # writing to the (guaranteed-live) same slot when unoccupied.
            @pl.when(occupied)
            def _():
                out_idx_ref[pos] = key
                out_val_ref[pos] = tval_ref[s]
            return cnt + occupied.astype(jnp.int32)

        jax.lax.fori_loop(0, tsz, flush, jnp.int32(0))
        return 0

    jax.lax.fori_loop(offsets_ref[b], offsets_ref[b + 1], do_row, 0)


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------

def _full(spec_len):
    # index_map receives (grid idx, *scalar_prefetch_refs) under
    # PrefetchScalarGridSpec; the whole array is one block for all programs.
    return pl.BlockSpec((spec_len,), lambda b, *prefetch: (0,))


@functools.lru_cache(maxsize=256)
def symbolic_call(n_bins: int, m: int, cap_a: int, cap_b: int,
                  table_size: int, vector: bool, interpret: bool):
    """Cached builder: a stable callable per static config, jit-wrapped so
    repeat invocations hit the dispatch cache instead of retracing (the
    paper's C5 allocate-once discipline applied to compilation).

    Call signature of the returned function:
    ``(offsets, bin_tsize, indptr_a, indptr_b, a_idx, a_val, b_idx, b_val)``
    where ``bin_tsize`` holds each bin's power-of-two effective table size
    (Fig. 7 lines 9-12); ``table_size`` stays the static scratch allocation
    (the bin max), so the grid and scratch shapes never depend on the data.
    """
    kernel = functools.partial(_symbolic_kernel, table_size=table_size,
                               vector=vector)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,           # offsets, bin_tsize, indptr_a/b
        grid=(n_bins,),
        in_specs=[_full(cap_a), _full(cap_a), _full(cap_b), _full(cap_b)],
        out_specs=_full(m),
        scratch_shapes=[pltpu.VMEM((table_size,), jnp.int32)],
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
    ))


@functools.lru_cache(maxsize=256)
def numeric_call(n_bins: int, m: int, cap_a: int, cap_b: int, cap_c: int,
                 table_size: int, vector: bool, interpret: bool):
    kernel = functools.partial(_numeric_kernel, table_size=table_size,
                               vector=vector)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,   # offsets, bin_tsize, indptr_a/b, indptr_c
        grid=(n_bins,),
        in_specs=[_full(cap_a), _full(cap_a), _full(cap_b), _full(cap_b)],
        out_specs=[_full(cap_c), _full(cap_c)],
        scratch_shapes=[pltpu.VMEM((table_size,), jnp.int32),
                        pltpu.VMEM((table_size,), jnp.float32)],
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((cap_c,), jnp.int32),
                   jax.ShapeDtypeStruct((cap_c,), jnp.float32)],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
    ))


# ---------------------------------------------------------------------------
# batched grid: one extra grid dimension over fleet members / row shards
# ---------------------------------------------------------------------------

class _View:
    """1-D view of a ref's row ``lead`` so ``_row_loop`` runs unchanged.

    Member operands arrive as ``(1, cap)`` BlockSpec blocks (lead 0) and
    schedules as full 2-D prefetched scalars (lead = member id); either way
    the row/probe loops only ever see ``ref[lead, i]``.
    """

    def __init__(self, ref, lead):
        self._ref, self._lead = ref, lead

    def __getitem__(self, i):
        return self._ref[self._lead, i]

    def __setitem__(self, i, v):
        self._ref[self._lead, i] = v


def _batched_symbolic_kernel(offsets_ref, tsize_ref, indptr_a_ref,
                             indptr_b_ref, a_idx_ref, a_val_ref, b_idx_ref,
                             b_val_ref, row_nnz_ref, tkey_ref, *,
                             table_size, vector):
    e = pl.program_id(0)                      # fleet member / row shard
    b = pl.program_id(1)                      # equal-flop row bin
    tsz = jnp.minimum(tsize_ref[e, b], jnp.int32(table_size))
    out = _View(row_nnz_ref, 0)

    def do_row(i, _):
        cnt = _row_loop(
            i, indptr_a_ref=_View(indptr_a_ref, e),
            indptr_b_ref=_View(indptr_b_ref, e),
            a_idx_ref=_View(a_idx_ref, 0), a_val_ref=_View(a_val_ref, 0),
            b_idx_ref=_View(b_idx_ref, 0), b_val_ref=_View(b_val_ref, 0),
            tkey_ref=tkey_ref, tval_ref=None, tsize=tsz, vector=vector,
            numeric=False)
        out[i] = cnt
        return 0

    jax.lax.fori_loop(offsets_ref[e, b], offsets_ref[e, b + 1], do_row, 0)


def _batched_numeric_kernel(offsets_ref, tsize_ref, indptr_a_ref,
                            indptr_b_ref, indptr_c_ref, a_idx_ref, a_val_ref,
                            b_idx_ref, b_val_ref, out_idx_ref, out_val_ref,
                            tkey_ref, tval_ref, *, table_size, vector):
    e = pl.program_id(0)
    b = pl.program_id(1)
    tsz = jnp.minimum(tsize_ref[e, b], jnp.int32(table_size))
    ic = _View(indptr_c_ref, e)               # prefetched: full 2-D array
    oi, ov = _View(out_idx_ref, 0), _View(out_val_ref, 0)

    @pl.when(b == 0)
    def _init():
        out_idx_ref[...] = jnp.zeros_like(out_idx_ref)
        out_val_ref[...] = jnp.zeros_like(out_val_ref)

    def do_row(i, _):
        _row_loop(
            i, indptr_a_ref=_View(indptr_a_ref, e),
            indptr_b_ref=_View(indptr_b_ref, e),
            a_idx_ref=_View(a_idx_ref, 0), a_val_ref=_View(a_val_ref, 0),
            b_idx_ref=_View(b_idx_ref, 0), b_val_ref=_View(b_val_ref, 0),
            tkey_ref=tkey_ref, tval_ref=tval_ref, tsize=tsz, vector=vector,
            numeric=True)
        base = ic[i]

        def flush(s, cnt):
            key = tkey_ref[s]
            occupied = key != EMPTY
            pos = base + cnt

            @pl.when(occupied)
            def _():
                oi[pos] = key
                ov[pos] = tval_ref[s]
            return cnt + occupied.astype(jnp.int32)

        jax.lax.fori_loop(0, tsz, flush, jnp.int32(0))
        return 0

    jax.lax.fori_loop(offsets_ref[e, b], offsets_ref[e, b + 1], do_row, 0)


def _bfull(cap):
    # one (1, cap) block per member; bins share the member's block.
    return pl.BlockSpec((1, cap), lambda e, b, *prefetch: (e, 0))


@functools.lru_cache(maxsize=256)
def batched_symbolic_call(n_members: int, n_bins: int, m: int, cap_a: int,
                          cap_b: int, table_size: int, vector: bool,
                          interpret: bool):
    """Batched-grid symbolic phase: grid ``(n_members, n_bins)``.

    Signature of the returned callable mirrors :func:`symbolic_call` with a
    leading member axis on every operand: schedules ``(n_members, n_bins+1)``
    / ``(n_members, n_bins)``, CSR payloads ``(n_members, cap)``, output
    row counts ``(n_members, m)``.  The scratch table is shared across the
    whole grid -- ``_row_loop`` reinitializes it per row, so member programs
    cannot observe each other, and the static allocation is the capacity
    class's bin max (per-member effective sizes still ride in as data).
    """
    kernel = functools.partial(_batched_symbolic_kernel,
                               table_size=table_size, vector=vector)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,           # offsets, bin_tsize, indptr_a/b
        grid=(n_members, n_bins),
        in_specs=[_bfull(cap_a), _bfull(cap_a), _bfull(cap_b), _bfull(cap_b)],
        out_specs=_bfull(m),
        scratch_shapes=[pltpu.VMEM((table_size,), jnp.int32)],
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_members, m), jnp.int32),
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    ))


@functools.lru_cache(maxsize=256)
def batched_numeric_call(n_members: int, n_bins: int, m: int, cap_a: int,
                         cap_b: int, cap_c: int, table_size: int,
                         vector: bool, interpret: bool):
    """Batched-grid numeric phase; see :func:`batched_symbolic_call`."""
    kernel = functools.partial(_batched_numeric_kernel,
                               table_size=table_size, vector=vector)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,   # offsets, bin_tsize, indptr_a/b, indptr_c
        grid=(n_members, n_bins),
        in_specs=[_bfull(cap_a), _bfull(cap_a), _bfull(cap_b), _bfull(cap_b)],
        out_specs=[_bfull(cap_c), _bfull(cap_c)],
        scratch_shapes=[pltpu.VMEM((table_size,), jnp.int32),
                        pltpu.VMEM((table_size,), jnp.float32)],
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_members, cap_c), jnp.int32),
                   jax.ShapeDtypeStruct((n_members, cap_c), jnp.float32)],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    ))
