"""Jit'd wrapper around the hash SpGEMM Pallas kernel.

Assembles the full two-phase pipeline of paper Fig. 7:

  1. ``RowsToThreads`` (core.schedule): flop per row -> equal-flop bins;
  2. table sizing (Fig. 7 lines 9-12): the *static* scratch allocation is
     ``lowest_p2(min(N_col, max_row_flop) + 1)`` (the +1 keeps the load
     factor < 1 so probes terminate), and each bin additionally carries its
     own power-of-two effective size ``bin_tsize[b] =
     lowest_p2(min(N_col, max-row-flop-in-bin) + 1)`` threaded into the
     kernels via scalar prefetch -- so a bin of light rows probes and
     flushes a small table instead of paying for the single worst row in
     the whole matrix;
  3. symbolic kernel -> exact row nnz -> indptr_C (prefix sum);
  4. numeric kernel -> (indices, values), unsorted within rows (C8).

Static-shape note: the scratch table size must be a Python int, so when the
inputs are concrete (the normal eager call) it is derived from the measured
max row flop exactly as the paper sizes per-thread tables; under an outer
``jit``/dry-run trace the caller must pin ``table_size``.  The per-bin
sizes are data (prefetched scalars), so they stay exact either way.

Inspector-executor path (``core.plan``): ``schedule=`` takes a precomputed
``(offsets, bin_tsize)`` pair and ``indptr_c=`` the symbolic phase's exact
row pointer, so a structure-identical repeat product runs the numeric
kernel alone.

Trace contexts: with a plan-frozen schedule (and static ``table_size``)
every dynamic value is an ordinary traced array, so the planned path runs
under ``jit``, inside ``shard_map`` bodies, and -- through a ``custom_vmap``
rule that swaps in the batched-grid kernels of ``kernel.py`` -- under
``vmap`` over fleet members.  Only the *inspection* (``hash_schedule`` with
no pinned ``table_size``) needs concrete inputs.  ``spgemm_hash_jnp``
remains solely as a reference oracle for differential tests and as the
documented fallback for general semirings / masks and planless traced
calls.

Rounding contract vs the oracle: the kernel accumulates with the
backend's fused multiply-add (one rounding per probe; the host LLVM
backend contracts, matching the paper's AVX-512 FMA kernels), while the
jnp twin -- like scipy -- rounds every product before summing.  Sparsity
pattern, row pointers, and output ordering agree bitwise always; values
agree bitwise whenever the arithmetic is exactly representable (the
dyadic fuzz values), and to 1 ulp per accumulated product otherwise.

``KERNEL_CALLS`` counts, at trace time, which Pallas entry was
staged -- tests use it to prove the real kernel (not the jnp twin) is in a
compiled program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import custom_batching

from repro.core.formats import CSR
import repro.core.schedule as sched
from . import kernel as K

#: Trace-time dispatch counters: how many times each Pallas entry point was
#: staged into a computation (eager call or jit trace; dispatch-cache hits
#: do not re-count).  Keys: symbolic, numeric, batched_symbolic,
#: batched_numeric -- the ``batched_*`` entries are the vmap-rule kernels.
KERNEL_CALLS = {"symbolic": 0, "numeric": 0,
                "batched_symbolic": 0, "batched_numeric": 0}


def reset_kernel_calls() -> None:
    """Zero the trace-time dispatch counters (test/bench helper)."""
    for k in KERNEL_CALLS:
        KERNEL_CALLS[k] = 0


def kernel_call_counts() -> dict:
    """Snapshot of :data:`KERNEL_CALLS`."""
    return dict(KERNEL_CALLS)


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def _static_table_size(flop, n: int, table_size: int | None) -> int:
    if table_size is None:
        if not _is_concrete(flop):
            raise ValueError("under trace, pass a static table_size")
        table_size = sched.lowest_p2(
            int(min(int(jnp.max(flop)), n)) + 1)
    return max(table_size, K.CHUNK)


def hash_schedule(a: CSR, b: CSR, n_bins: int,
                  table_size: int | None = None):
    """Fig. 6 + Fig. 7 lines 9-12: bins, static scratch size, per-bin sizes.

    Returns ``(offsets, bin_tsize, table_size)`` -- everything the kernels
    need besides the CSR payloads.  This is the inspection the planner
    (``core.plan``) runs once and reuses.
    """
    flop, offsets, tsize = sched.make_schedule(a, b, n_bins)
    table_size = _static_table_size(flop, b.n_cols, table_size)
    bin_tsize = sched.bin_table_sizes(tsize, b.n_cols, table_size,
                                      floor=K.CHUNK)
    return offsets, bin_tsize, table_size


# ---------------------------------------------------------------------------
# trace-context entry points: the plain kernels, made vmappable
# ---------------------------------------------------------------------------
# ``jax.vmap`` has no batching rule for a pallas_call with scalar-prefetch
# operands whose *schedule semantics* differ per member, so each entry wraps
# the plain 1-D-grid kernel in a ``custom_vmap`` whose rule dispatches the
# natively batched grid of ``kernel.py`` (grid (n_members, n_bins)) instead.
# Unbatched operands (e.g. a shared B, or a schedule override closed over by
# a vmapped caller) are broadcast along the member axis; BlockSpec blocking
# keeps the per-program working set at one member regardless.

@functools.lru_cache(maxsize=256)
def _symbolic_entry(n_bins: int, m: int, cap_a: int, cap_b: int,
                    table_size: int, vector: bool, interpret: bool):
    plain = K.symbolic_call(n_bins, m, cap_a, cap_b, table_size, vector,
                            interpret)

    @custom_batching.custom_vmap
    def sym(offsets, bin_tsize, indptr_a, indptr_b, a_idx, a_val,
            b_idx, b_val):
        KERNEL_CALLS["symbolic"] += 1
        return plain(offsets, bin_tsize, indptr_a, indptr_b,
                     a_idx, a_val, b_idx, b_val)

    @sym.def_vmap
    def _rule(axis_size, in_batched, *args):
        KERNEL_CALLS["batched_symbolic"] += 1
        args = [x if bd else jnp.broadcast_to(x, (axis_size,) + x.shape)
                for x, bd in zip(args, in_batched)]
        out = K.batched_symbolic_call(axis_size, n_bins, m, cap_a, cap_b,
                                      table_size, vector, interpret)(*args)
        return out, True

    return sym


@functools.lru_cache(maxsize=256)
def _numeric_entry(n_bins: int, m: int, cap_a: int, cap_b: int, cap_c: int,
                   table_size: int, vector: bool, interpret: bool):
    plain = K.numeric_call(n_bins, m, cap_a, cap_b, cap_c, table_size,
                           vector, interpret)

    @custom_batching.custom_vmap
    def num(offsets, bin_tsize, indptr_a, indptr_b, indptr_c,
            a_idx, a_val, b_idx, b_val):
        KERNEL_CALLS["numeric"] += 1
        cols, vals = plain(offsets, bin_tsize, indptr_a, indptr_b, indptr_c,
                           a_idx, a_val, b_idx, b_val)
        return cols, vals

    @num.def_vmap
    def _rule(axis_size, in_batched, *args):
        KERNEL_CALLS["batched_numeric"] += 1
        args = [x if bd else jnp.broadcast_to(x, (axis_size,) + x.shape)
                for x, bd in zip(args, in_batched)]
        cols, vals = K.batched_numeric_call(
            axis_size, n_bins, m, cap_a, cap_b, cap_c, table_size, vector,
            interpret)(*args)
        return (cols, vals), (True, True)

    return num


def spgemm_hash(a: CSR, b: CSR, cap_c: int, *, n_bins: int = 8,
                vector: bool = False, table_size: int | None = None,
                interpret: bool | None = None,
                semiring="plus_times", mask: CSR | None = None,
                complement_mask: bool = False,
                schedule=None, indptr_c: jax.Array | None = None) -> CSR:
    """C = A @ B via the hash kernel. Returns CSR with sorted_cols=False.

    The Pallas kernel is specialized to the arithmetic semiring; requests
    with a non-default ``semiring`` or a ``mask`` take the jnp fallback
    (``core.spgemm.spgemm_hash_jnp``), which keeps the same contract
    (two-phase capacity, probe-time mask pruning, unsorted select output).

    ``schedule=(offsets, bin_tsize)`` skips the Fig. 6 inspection (pass a
    static ``table_size`` alongside); ``indptr_c=`` additionally skips the
    symbolic kernel -- the planned execute path runs numeric only.
    """
    from repro.core.semiring import resolve_semiring
    if resolve_semiring(semiring).name != "plus_times" or mask is not None:
        from repro.core.spgemm import spgemm_hash_jnp
        return spgemm_hash_jnp(a, b, cap_c, semiring=semiring, mask=mask,
                               complement_mask=complement_mask)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = a.n_rows, b.n_cols
    if schedule is None:
        offsets, bin_tsize, table_size = hash_schedule(a, b, n_bins,
                                                       table_size)
    else:
        offsets, bin_tsize = schedule
        assert table_size is not None, \
            "a precomputed schedule needs its static table_size"
        table_size = max(table_size, K.CHUNK)
    n_bins = offsets.shape[0] - 1

    if indptr_c is None:
        sym = _symbolic_entry(n_bins, m, a.cap, b.cap, table_size, vector,
                              interpret)
        row_nnz = sym(offsets, bin_tsize, a.indptr, b.indptr,
                      a.indices, a.data.astype(jnp.float32),
                      b.indices, b.data.astype(jnp.float32))
        indptr_c = sched.prefix_sum(row_nnz).astype(jnp.int32)

    num = _numeric_entry(n_bins, m, a.cap, b.cap, cap_c, table_size, vector,
                         interpret)
    cols_c, vals_c = num(offsets, bin_tsize, a.indptr, b.indptr, indptr_c,
                         a.indices, a.data.astype(jnp.float32),
                         b.indices, b.data.astype(jnp.float32))
    nnz_c = indptr_c[-1]
    valid = jnp.arange(cap_c, dtype=jnp.int32) < nnz_c
    cols_c = jnp.where(valid, cols_c, 0)
    vals_c = jnp.where(valid, vals_c, 0).astype(a.dtype)
    return CSR(indptr_c, cols_c, vals_c, nnz_c, (m, n), sorted_cols=False)


def spgemm_hash_symbolic(a: CSR, b: CSR, *, n_bins: int = 8,
                         vector: bool = False, table_size: int | None = None,
                         interpret: bool | None = None,
                         schedule=None) -> jax.Array:
    """Symbolic phase only: exact nnz(C) per row."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m = a.n_rows
    if schedule is None:
        offsets, bin_tsize, table_size = hash_schedule(a, b, n_bins,
                                                       table_size)
    else:
        offsets, bin_tsize = schedule
        assert table_size is not None, \
            "a precomputed schedule needs its static table_size"
        table_size = max(table_size, K.CHUNK)
    n_bins = offsets.shape[0] - 1
    sym = _symbolic_entry(n_bins, m, a.cap, b.cap, table_size, vector,
                          interpret)
    return sym(offsets, bin_tsize, a.indptr, b.indptr,
               a.indices, a.data.astype(jnp.float32),
               b.indices, b.data.astype(jnp.float32))
