"""Jit'd wrapper around the hash SpGEMM Pallas kernel.

Assembles the full two-phase pipeline of paper Fig. 7:

  1. ``RowsToThreads`` (core.schedule): flop per row -> equal-flop bins;
  2. table sizing (Fig. 7 lines 9-12): the *static* scratch allocation is
     ``lowest_p2(min(N_col, max_row_flop) + 1)`` (the +1 keeps the load
     factor < 1 so probes terminate), and each bin additionally carries its
     own power-of-two effective size ``bin_tsize[b] =
     lowest_p2(min(N_col, max-row-flop-in-bin) + 1)`` threaded into the
     kernels via scalar prefetch -- so a bin of light rows probes and
     flushes a small table instead of paying for the single worst row in
     the whole matrix;
  3. symbolic kernel -> exact row nnz -> indptr_C (prefix sum);
  4. numeric kernel -> (indices, values), unsorted within rows (C8).

Static-shape note: the scratch table size must be a Python int, so when the
inputs are concrete (the normal eager call) it is derived from the measured
max row flop exactly as the paper sizes per-thread tables; under an outer
``jit``/dry-run trace the caller must pin ``table_size``.  The per-bin
sizes are data (prefetched scalars), so they stay exact either way.

Inspector-executor path (``core.plan``): ``schedule=`` takes a precomputed
``(offsets, bin_tsize)`` pair and ``indptr_c=`` the symbolic phase's exact
row pointer, so a structure-identical repeat product runs the numeric
kernel alone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import CSR
import repro.core.schedule as sched
from . import kernel as K


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def _static_table_size(flop, n: int, table_size: int | None) -> int:
    if table_size is None:
        if not _is_concrete(flop):
            raise ValueError("under trace, pass a static table_size")
        table_size = sched.lowest_p2(
            int(min(int(jnp.max(flop)), n)) + 1)
    return max(table_size, K.CHUNK)


def hash_schedule(a: CSR, b: CSR, n_bins: int,
                  table_size: int | None = None):
    """Fig. 6 + Fig. 7 lines 9-12: bins, static scratch size, per-bin sizes.

    Returns ``(offsets, bin_tsize, table_size)`` -- everything the kernels
    need besides the CSR payloads.  This is the inspection the planner
    (``core.plan``) runs once and reuses.
    """
    flop, offsets, tsize = sched.make_schedule(a, b, n_bins)
    table_size = _static_table_size(flop, b.n_cols, table_size)
    bin_tsize = sched.bin_table_sizes(tsize, b.n_cols, table_size,
                                      floor=K.CHUNK)
    return offsets, bin_tsize, table_size


def spgemm_hash(a: CSR, b: CSR, cap_c: int, *, n_bins: int = 8,
                vector: bool = False, table_size: int | None = None,
                interpret: bool | None = None,
                semiring="plus_times", mask: CSR | None = None,
                complement_mask: bool = False,
                schedule=None, indptr_c: jax.Array | None = None) -> CSR:
    """C = A @ B via the hash kernel. Returns CSR with sorted_cols=False.

    The Pallas kernel is specialized to the arithmetic semiring; requests
    with a non-default ``semiring`` or a ``mask`` take the jnp fallback
    (``core.spgemm.spgemm_hash_jnp``), which keeps the same contract
    (two-phase capacity, probe-time mask pruning, unsorted select output).

    ``schedule=(offsets, bin_tsize)`` skips the Fig. 6 inspection (pass a
    static ``table_size`` alongside); ``indptr_c=`` additionally skips the
    symbolic kernel -- the planned execute path runs numeric only.
    """
    from repro.core.semiring import resolve_semiring
    if resolve_semiring(semiring).name != "plus_times" or mask is not None:
        from repro.core.spgemm import spgemm_hash_jnp
        return spgemm_hash_jnp(a, b, cap_c, semiring=semiring, mask=mask,
                               complement_mask=complement_mask)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = a.n_rows, b.n_cols
    if schedule is None:
        offsets, bin_tsize, table_size = hash_schedule(a, b, n_bins,
                                                       table_size)
    else:
        offsets, bin_tsize = schedule
        assert table_size is not None, \
            "a precomputed schedule needs its static table_size"
        table_size = max(table_size, K.CHUNK)
    n_bins = offsets.shape[0] - 1

    if indptr_c is None:
        sym = K.symbolic_call(n_bins, m, a.cap, b.cap, table_size, vector,
                              interpret)
        row_nnz = sym(offsets, bin_tsize, a.indptr, b.indptr,
                      a.indices, a.data.astype(jnp.float32),
                      b.indices, b.data.astype(jnp.float32))
        indptr_c = sched.prefix_sum(row_nnz).astype(jnp.int32)

    num = K.numeric_call(n_bins, m, a.cap, b.cap, cap_c, table_size, vector,
                         interpret)
    cols_c, vals_c = num(offsets, bin_tsize, a.indptr, b.indptr, indptr_c,
                         a.indices, a.data.astype(jnp.float32),
                         b.indices, b.data.astype(jnp.float32))
    nnz_c = indptr_c[-1]
    valid = jnp.arange(cap_c, dtype=jnp.int32) < nnz_c
    cols_c = jnp.where(valid, cols_c, 0)
    vals_c = jnp.where(valid, vals_c, 0).astype(a.dtype)
    return CSR(indptr_c, cols_c, vals_c, nnz_c, (m, n), sorted_cols=False)


def spgemm_hash_symbolic(a: CSR, b: CSR, *, n_bins: int = 8,
                         vector: bool = False, table_size: int | None = None,
                         interpret: bool | None = None,
                         schedule=None) -> jax.Array:
    """Symbolic phase only: exact nnz(C) per row."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m = a.n_rows
    if schedule is None:
        offsets, bin_tsize, table_size = hash_schedule(a, b, n_bins,
                                                       table_size)
    else:
        offsets, bin_tsize = schedule
        assert table_size is not None, \
            "a precomputed schedule needs its static table_size"
        table_size = max(table_size, K.CHUNK)
    n_bins = offsets.shape[0] - 1
    sym = K.symbolic_call(n_bins, m, a.cap, b.cap, table_size, vector,
                          interpret)
    return sym(offsets, bin_tsize, a.indptr, b.indptr,
               a.indices, a.data.astype(jnp.float32),
               b.indices, b.data.astype(jnp.float32))
