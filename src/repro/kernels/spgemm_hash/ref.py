"""Pure-jnp oracle for the hash SpGEMM kernel.

The semantic contract of the kernel (per phase):
  * symbolic: exact nnz per output row;
  * numeric:  CSR triple (indptr from symbolic, indices, values) where each
    row holds the correct {col: sum of products} set in *some* order
    (unsorted output, C8).

The oracle is the dense product; comparisons therefore canonicalize via
``CSR.to_dense()`` which is order-insensitive, plus an explicit per-row
set/sum check in the tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import CSR


def symbolic_ref(a: CSR, b: CSR) -> jax.Array:
    c = a.to_dense().astype(jnp.float32) @ b.to_dense().astype(jnp.float32)
    # structural nnz: products of the sparsity patterns, not value cancels
    pattern = (a.to_dense() != 0).astype(jnp.float32) @ \
              (b.to_dense() != 0).astype(jnp.float32)
    del c
    return jnp.sum(pattern > 0, axis=1).astype(jnp.int32)


def numeric_ref(a: CSR, b: CSR) -> jax.Array:
    """Dense C = A @ B (the canonical value oracle)."""
    return a.to_dense() @ b.to_dense()
