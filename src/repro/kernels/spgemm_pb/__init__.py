from .ops import pb_merge, pb_scatter, spgemm_pb
