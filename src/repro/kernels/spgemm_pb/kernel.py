"""Pallas TPU kernel pair: propagation-blocking SpGEMM merge.

Gu/Moreira/Edelsohn/Azad ("Bandwidth-Optimized Parallel Algorithms for
SpGEMM using Propagation Blocking", PAPERS.md) split the outer-product
formulation into a *propagate* phase that buckets partial products by
column segment and a *merge* phase that reduces each bucket privately --
no global hash table, no random scatter across the whole output: every
memory stream is a contiguous bucket that fits in cache.  Here the bucket
layout is frozen at plan time (``core.pb``), so both phases are pure
numeric gathers over plan arrays (DESIGN.md section 18):

  scatter (grid over buckets):
    pp[g, i] = a_data[src_a[g, i]] * b_data[src_b[g, i]]   i < bucket_nnz[g]
  merge (grid over buckets):
    out[seg[g, i]] += pp[g, i]                             i < bucket_nnz[g]

``src_a``/``src_b`` gather straight from the operands' value arrays (the
plan resolved every CSR walk already), and ``seg`` maps each partial
product to its output slot in the *column-sorted* CSR of C.  Because a
bucket owns a contiguous column range, all duplicates of one output
coordinate live in exactly one bucket -- bucket programs write disjoint
output slots, which is what makes the merge a private, sequential-grid
scatter-add instead of an atomic or a psum over a dense accumulator.

Keeping scatter and merge as a *pair* (not one fused kernel) is
deliberate: the distributed lift inserts the all-to-all exchange between
them (scatter on the producer chip, merge on the consumer chip), so the
single-node and mesh paths share both kernels.

The batched variants add a leading grid dimension over fleet members --
grid ``(n_members, n_buckets)`` -- exactly the shape the hash/bcsr
kernels use, so the planned PB path traces under ``vmap`` through the
``custom_vmap`` rules in ``ops.py``.

Rounding contract (PR 6): one multiply rounding per partial product and
one add rounding per merge step, same accumulation order as the frozen
plan; the jnp twin (``ref.py``) reduces with ``segment_sum`` in the same
bucket-major order, so values agree bitwise on dyadic values and to 1 ulp
per product otherwise.  All gather/scatter indices are clipped to their
static capacity so the verifier's interval analysis can discharge the
in-bounds obligations (``repro.verify.bounds``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _full(spec_len):
    # whole-array block shared by every grid program (see spgemm_hash)
    return pl.BlockSpec((spec_len,), lambda g, *prefetch: (0,))


def _bucket(cap):
    # one bucket's row of a (n_buckets, cap) operand per grid program
    return pl.BlockSpec((1, cap), lambda g, *prefetch: (g, 0))


# ---------------------------------------------------------------------------
# scatter: expand one bucket's partial products from the operand values
# ---------------------------------------------------------------------------

def _scatter_kernel(bucket_nnz_ref, src_a_ref, src_b_ref, a_val_ref,
                    b_val_ref, pp_ref, *, cap_a, cap_b):
    g = pl.program_id(0)
    pp_ref[...] = jnp.zeros_like(pp_ref)       # pad lanes stay 0

    def body(i, _):
        ja = jnp.clip(src_a_ref[0, i], 0, cap_a - 1)
        jb = jnp.clip(src_b_ref[0, i], 0, cap_b - 1)
        pp_ref[0, i] = a_val_ref[ja] * b_val_ref[jb]
        return 0

    jax.lax.fori_loop(0, bucket_nnz_ref[g], body, 0)


@functools.lru_cache(maxsize=256)
def scatter_call(n_buckets: int, bucket_cap: int, cap_a: int, cap_b: int,
                 interpret: bool):
    """Cached builder for the bucket-scatter grid.

    Call signature: ``(bucket_nnz, src_a, src_b, a_data, b_data)`` ->
    ``pp`` of shape ``(n_buckets, bucket_cap)`` (float32, pad lanes 0).
    """
    kernel = functools.partial(_scatter_kernel, cap_a=cap_a, cap_b=cap_b)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                 # bucket_nnz
        grid=(n_buckets,),
        in_specs=[_bucket(bucket_cap), _bucket(bucket_cap),
                  _full(cap_a), _full(cap_b)],
        out_specs=_bucket(bucket_cap),
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_buckets, bucket_cap), jnp.float32),
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
    ))


# ---------------------------------------------------------------------------
# merge: reduce one bucket's products into its (disjoint) output slots
# ---------------------------------------------------------------------------

def _merge_kernel(bucket_nnz_ref, seg_ref, pp_ref, out_ref, *, cap_c):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def body(i, _):
        s = jnp.clip(seg_ref[g, i], 0, cap_c - 1)
        out_ref[s] = out_ref[s] + pp_ref[0, i]
        return 0

    jax.lax.fori_loop(0, bucket_nnz_ref[g], body, 0)


@functools.lru_cache(maxsize=256)
def merge_call(n_buckets: int, bucket_cap: int, cap_c: int, interpret: bool):
    """Cached builder for the per-bucket merge grid.

    Call signature: ``(bucket_nnz, seg, pp)`` -> ``data_c`` of shape
    ``(cap_c,)`` (float32).  ``seg`` rides in SMEM as a prefetched scalar
    array: the merge's control stream (output slots) never touches VMEM.
    """
    kernel = functools.partial(_merge_kernel, cap_c=cap_c)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # bucket_nnz, seg
        grid=(n_buckets,),
        in_specs=[_bucket(bucket_cap)],
        out_specs=_full(cap_c),
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cap_c,), jnp.float32),
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
    ))


# ---------------------------------------------------------------------------
# batched grid: one extra grid dimension over fleet members
# ---------------------------------------------------------------------------

def _bbucket(cap):
    return pl.BlockSpec((1, 1, cap), lambda e, g, *prefetch: (e, g, 0))


def _bfull(cap):
    return pl.BlockSpec((1, cap), lambda e, g, *prefetch: (e, 0))


def _batched_scatter_kernel(bucket_nnz_ref, src_a_ref, src_b_ref, a_val_ref,
                            b_val_ref, pp_ref, *, cap_a, cap_b):
    e = pl.program_id(0)
    g = pl.program_id(1)
    pp_ref[...] = jnp.zeros_like(pp_ref)

    def body(i, _):
        ja = jnp.clip(src_a_ref[0, 0, i], 0, cap_a - 1)
        jb = jnp.clip(src_b_ref[0, 0, i], 0, cap_b - 1)
        pp_ref[0, 0, i] = a_val_ref[0, ja] * b_val_ref[0, jb]
        return 0

    jax.lax.fori_loop(0, bucket_nnz_ref[e, g], body, 0)


@functools.lru_cache(maxsize=256)
def batched_scatter_call(n_members: int, n_buckets: int, bucket_cap: int,
                         cap_a: int, cap_b: int, interpret: bool):
    """Batched scatter: grid ``(n_members, n_buckets)``, member payloads
    blocked to one member per program."""
    kernel = functools.partial(_batched_scatter_kernel, cap_a=cap_a,
                               cap_b=cap_b)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_members, n_buckets),
        in_specs=[_bbucket(bucket_cap), _bbucket(bucket_cap),
                  _bfull(cap_a), _bfull(cap_b)],
        out_specs=_bbucket(bucket_cap),
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_members, n_buckets, bucket_cap),
                                       jnp.float32),
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    ))


def _batched_merge_kernel(bucket_nnz_ref, seg_ref, pp_ref, out_ref, *,
                          cap_c):
    e = pl.program_id(0)
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def body(i, _):
        s = jnp.clip(seg_ref[e, g, i], 0, cap_c - 1)
        out_ref[0, s] = out_ref[0, s] + pp_ref[0, 0, i]
        return 0

    jax.lax.fori_loop(0, bucket_nnz_ref[e, g], body, 0)


@functools.lru_cache(maxsize=256)
def batched_merge_call(n_members: int, n_buckets: int, bucket_cap: int,
                       cap_c: int, interpret: bool):
    """Batched merge: grid ``(n_members, n_buckets)``, one output row of
    ``(n_members, cap_c)`` per member."""
    kernel = functools.partial(_batched_merge_kernel, cap_c=cap_c)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_members, n_buckets),
        in_specs=[_bbucket(bucket_cap)],
        out_specs=_bfull(cap_c),
    )
    return jax.jit(pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_members, cap_c), jnp.float32),
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    ))
