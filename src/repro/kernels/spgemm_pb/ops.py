"""Public entry points for the propagation-blocking SpGEMM kernels.

The lifecycle mirrors ``spgemm_hash``: all inspection happens host-side
in ``core.pb.plan_pb`` (counted as ``"inspect"`` here), and the two
numeric phases -- bucket scatter and per-bucket merge -- run over frozen
plan arrays only.  ``pb_scatter`` and ``pb_merge`` stay separate public
ops because the distributed layer exchanges the partial-product buffers
between them (scatter on the producer chip, all-to-all, merge on the
consumer chip); ``spgemm_pb`` composes them for the single-device path.

``KERNEL_CALLS`` counts invocations per phase so tests can pin the
zero-re-inspection property: repeat executes must bump only
``scatter``/``merge`` (or their ``batched_`` twins under vmap), never
``inspect``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import custom_batching

from repro.core.formats import CSR

from . import kernel as K

KERNEL_CALLS = {
    "inspect": 0,
    "scatter": 0,
    "merge": 0,
    "batched_scatter": 0,
    "batched_merge": 0,
}


def reset_kernel_calls() -> None:
    for k in KERNEL_CALLS:
        KERNEL_CALLS[k] = 0


def kernel_call_counts() -> dict:
    return dict(KERNEL_CALLS)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# vmap-dispatching entries (same shape as spgemm_hash ops)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _scatter_entry(n_buckets: int, bucket_cap: int, cap_a: int, cap_b: int,
                   interpret: bool):
    plain = K.scatter_call(n_buckets, bucket_cap, cap_a, cap_b, interpret)

    @custom_batching.custom_vmap
    def entry(bucket_nnz, src_a, src_b, a_data, b_data):
        KERNEL_CALLS["scatter"] += 1
        return plain(bucket_nnz, src_a, src_b, a_data, b_data)

    @entry.def_vmap
    def _rule(axis_size, in_batched, *args):
        args = [x if bd else jnp.broadcast_to(x, (axis_size,) + x.shape)
                for x, bd in zip(args, in_batched)]
        KERNEL_CALLS["batched_scatter"] += 1
        batched = K.batched_scatter_call(axis_size, n_buckets, bucket_cap,
                                         cap_a, cap_b, interpret)
        return batched(*args), True

    return entry


@functools.lru_cache(maxsize=128)
def _merge_entry(n_buckets: int, bucket_cap: int, cap_c: int,
                 interpret: bool):
    plain = K.merge_call(n_buckets, bucket_cap, cap_c, interpret)

    @custom_batching.custom_vmap
    def entry(bucket_nnz, seg, pp):
        KERNEL_CALLS["merge"] += 1
        return plain(bucket_nnz, seg, pp)

    @entry.def_vmap
    def _rule(axis_size, in_batched, *args):
        args = [x if bd else jnp.broadcast_to(x, (axis_size,) + x.shape)
                for x, bd in zip(args, in_batched)]
        KERNEL_CALLS["batched_merge"] += 1
        batched = K.batched_merge_call(axis_size, n_buckets, bucket_cap,
                                       cap_c, interpret)
        return batched(*args), True

    return entry


# ---------------------------------------------------------------------------
# public phase ops
# ---------------------------------------------------------------------------

def pb_scatter(a_data, b_data, src_a, src_b, bucket_nnz, *,
               interpret: bool | None = None):
    """Propagate phase: expand partial products into bucket-major order.

    Returns ``pp`` of shape ``(n_buckets, bucket_cap)`` (float32), pad
    lanes zeroed.  All index arrays come frozen out of a ``PBPlan``.
    """
    if interpret is None:
        interpret = _default_interpret()
    n_buckets, bucket_cap = src_a.shape
    entry = _scatter_entry(n_buckets, bucket_cap, a_data.shape[0],
                           b_data.shape[0], interpret)
    return entry(bucket_nnz, src_a, src_b, a_data.astype(jnp.float32),
                 b_data.astype(jnp.float32))


def pb_merge(pp, seg, bucket_nnz, cap_c: int, *,
             interpret: bool | None = None):
    """Merge phase: reduce each bucket into its disjoint output slots.

    Returns ``data_c`` of shape ``(cap_c,)`` (float32).  Safe to run per
    bucket independently: the plan guarantees buckets never share an
    output slot (one column segment per bucket).
    """
    if interpret is None:
        interpret = _default_interpret()
    n_buckets, bucket_cap = pp.shape
    entry = _merge_entry(n_buckets, bucket_cap, cap_c, interpret)
    return entry(bucket_nnz, seg, pp)


def spgemm_pb(a: CSR, b: CSR, cap_c: int, *, src_a, src_b, seg, bucket_nnz,
              indptr_c, cols_c, interpret: bool | None = None) -> CSR:
    """Planned propagation-blocking SpGEMM (plus_times), numeric only.

    Every structural decision -- bucket layout, source gathers, output
    slots, C's sorted column structure -- is frozen in the plan arrays;
    this function is trace-safe and touches no data-dependent shapes.
    """
    pp = pb_scatter(a.data, b.data, src_a, src_b, bucket_nnz,
                    interpret=interpret)
    data = pb_merge(pp, seg, bucket_nnz, cap_c, interpret=interpret)
    nnz_c = indptr_c[-1]
    valid = jnp.arange(cap_c, dtype=jnp.int32) < nnz_c
    data = jnp.where(valid, data, 0).astype(a.data.dtype)
    cols = jnp.where(valid, cols_c, 0)
    m, n = a.shape[0], b.shape[1]
    return CSR(indptr_c, cols, data, nnz_c, (m, n), sorted_cols=True)
