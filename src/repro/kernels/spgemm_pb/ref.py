"""jnp reference twin for the propagation-blocking numeric phases.

Same role as ``spgemm_hash_jnp`` / ``spgemm_bcsr``'s twin under the PR-6
rounding contract: each partial product is rounded once (no FMA), then
reduced with the semiring's ``segment_reduce`` in the same bucket-major
order the Pallas merge walks.  Structure is untouched here -- it comes
frozen from the plan -- so the twin and the kernel agree bitwise on
indptr/indices always, bitwise on dyadic values, and to 1 ulp per
accumulated product otherwise.

The twin is also the *general-semiring* executor: the Pallas pair is
plus_times-only (mul + add), while ``pb_numeric_ref`` threads any
registered :class:`repro.core.semiring.Semiring` through the identical
frozen gathers, so ``PBPlan.execute`` stays one code path per contract.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.semiring import Semiring, resolve_semiring


def pb_numeric_ref(a_data, b_data, src_a, src_b, seg, bucket_nnz,
                   cap_c: int, nnz_c, *, semiring="plus_times"):
    """Reduce frozen PB plan arrays to C's value vector (shape (cap_c,)).

    Pad lanes (beyond each bucket's ``bucket_nnz``) are routed to a dump
    segment ``cap_c`` and their value forced to the semiring zero, so
    empty segments of min_plus-style semirings never leak ``inf`` into
    live output slots; tails beyond ``nnz_c`` are zeroed to keep the
    capacity slack bitwise-stable.
    """
    sr: Semiring = resolve_semiring(semiring)
    n_buckets, bucket_cap = src_a.shape
    cap_a, cap_b = a_data.shape[0], b_data.shape[0]
    lane = jnp.arange(bucket_cap, dtype=jnp.int32)
    live = lane[None, :] < bucket_nnz[:, None]
    av = a_data[jnp.clip(src_a, 0, cap_a - 1)]
    bv = b_data[jnp.clip(src_b, 0, cap_b - 1)]
    vals = jnp.where(live, sr.mul(av, bv), sr.zero)
    s = jnp.where(live, seg, cap_c)
    data = sr.segment_reduce(vals.ravel(), s.ravel(),
                             num_segments=cap_c + 1)[:cap_c]
    valid = jnp.arange(cap_c, dtype=jnp.int32) < nnz_c
    return jnp.where(valid, data, 0)
