from .ops import spmm_pallas
