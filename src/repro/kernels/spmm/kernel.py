"""Pallas TPU kernel: SpMM  y = A @ X  (CSR x dense tall-skinny).

The square x tall-skinny use case of paper section 5.5 (multi-source BFS /
betweenness frontiers).  Grid = equal-flop row bins (C1); each program walks
its rows, gathering rows of X -- the *stanza* access pattern of section 3.3:
each gather reads a contiguous (1, k) panel, which is exactly the access
shape the MCDRAM/HBM study says is bandwidth-friendly once k is lane-wide.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _spmm_kernel(offsets_ref, indptr_a_ref, a_idx_ref, a_val_ref, x_ref,
                 y_ref, acc_ref):
    b = pl.program_id(0)

    def do_row(i, _):
        acc_ref[...] = jnp.zeros_like(acc_ref)

        def do_nz(j, _):
            col = a_idx_ref[j]
            av = a_val_ref[j]
            acc_ref[...] = acc_ref[...] + av * pl.load(
                x_ref, (pl.ds(col, 1), slice(None))).astype(jnp.float32)
            return 0

        jax.lax.fori_loop(indptr_a_ref[i], indptr_a_ref[i + 1], do_nz, 0)
        pl.store(y_ref, (pl.ds(i, 1), slice(None)),
                 acc_ref[...].astype(y_ref.dtype))
        return 0

    jax.lax.fori_loop(offsets_ref[b], offsets_ref[b + 1], do_row, 0)


@functools.lru_cache(maxsize=128)
def spmm_call(n_bins: int, m: int, n: int, k: int, cap_a: int, dtype,
              interpret: bool):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,    # offsets, indptr_a
        grid=(n_bins,),
        in_specs=[pl.BlockSpec((cap_a,), lambda b, *p: (0,)),
                  pl.BlockSpec((cap_a,), lambda b, *p: (0,)),
                  pl.BlockSpec((n, k), lambda b, *p: (0, 0))],
        out_specs=pl.BlockSpec((m, k), lambda b, *p: (0, 0)),
        scratch_shapes=[pltpu.VMEM((1, k), jnp.float32)],
    )
    return jax.jit(pl.pallas_call(
        _spmm_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, k), dtype),
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
    ))
