"""Jit'd wrapper for the SpMM kernel (CSR x dense)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import CSR
import repro.core.schedule as sched
from . import kernel as K


def spmm_pallas(a: CSR, x: jax.Array, *, n_bins: int = 8,
                interpret: bool | None = None) -> jax.Array:
    """y = A @ X; X dense (n, k), returns (m, k)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = a.shape
    k = x.shape[1]
    flop, offsets, _ = sched.make_schedule(a, a, n_bins)  # balance on nnz(A)
    # for SpMM the work per row is nnz(a_i*) * k; nnz-based bins suffice
    row_nnz = a.row_nnz()
    offsets = sched.rows_to_bins(row_nnz, n_bins)
    del flop
    call = K.spmm_call(n_bins, m, n, k, a.cap, x.dtype, interpret)
    return call(offsets, a.indptr, a.indices, a.data.astype(jnp.float32), x)
