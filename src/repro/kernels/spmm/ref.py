"""Pure-jnp oracle for SpMM."""
from repro.core.formats import CSR


def spmm_ref(a: CSR, x):
    return a.to_dense() @ x
