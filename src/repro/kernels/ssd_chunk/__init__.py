from .ops import ssd_pallas
