"""Pallas TPU kernel: Mamba-2 SSD chunk scan [arXiv:2405.21060].

Identified as the next kernel target by the roofline (EXPERIMENTS.md: the
mamba2 cells' remaining traffic is the (nc, nh, Q, Q) decay tensor the XLA
path materializes).  This kernel keeps the whole chunk-local working set --
decay matrix L, C.B^T panel, and the (n, hp) running state -- in VMEM and
feeds the MXU three (Q x Q)/(Q x n)-class matmuls per chunk:

  grid = (batch, heads, chunks); chunks is the innermost "arbitrary" dim
  carrying the inter-chunk state in scratch (the lax.scan of the XLA path
  becomes grid-carried VMEM state -- same trick as flash attention's kv
  loop).

Per (b, h, c):
  cum   = cumsum(log_a_c)                           # (Q,)
  L     = tril(exp(cum_i - cum_j))                  # (Q, Q)   VPU
  y     = ((C_c B_c^T) * L) @ xd_c                  # MXU
        + exp(cum) * (C_c @ state)                  # MXU (inter-chunk)
  state = exp(cum_Q) * state + B_c^T (exp(cum_Q - cum) xd_c)   # MXU
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _ssd_kernel(xd_ref, la_ref, b_ref, c_ref, y_ref, hT_ref, state_scr, *,
                q: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xd = xd_ref[0, :, 0, :].astype(jnp.float32)          # (Q, hp)
    la = la_ref[0, :, 0].astype(jnp.float32)             # (Q,)
    B = b_ref[0, :, 0, :].astype(jnp.float32)            # (Q, n)
    C = c_ref[0, :, 0, :].astype(jnp.float32)            # (Q, n)

    cum = jnp.cumsum(la)                                 # (Q,)
    seg = cum[:, None] - cum[None, :]                    # (Q, Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)

    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y_intra = jax.lax.dot_general(CB * L, xd, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state = state_scr[...]                               # (n, hp)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    dec_end = jnp.exp(cum[-1] - cum)                     # (Q,)
    new_state = jnp.exp(cum[-1]) * state + jax.lax.dot_general(
        B, dec_end[:, None] * xd, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (n, hp)
    state_scr[...] = new_state

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hT_ref[0, 0] = new_state.astype(hT_ref.dtype)


@functools.lru_cache(maxsize=64)
def ssd_call(batch: int, seq: int, nh: int, hp: int, g: int, n: int,
             chunk: int, dtype, interpret: bool):
    assert seq % chunk == 0 and nh % g == 0
    n_chunks = seq // chunk
    rep = nh // g
    kernel = functools.partial(_ssd_kernel, q=chunk, n_chunks=n_chunks)
    grid = (batch, nh, n_chunks)
    xd_spec = pl.BlockSpec((1, chunk, 1, hp),
                           lambda b, h, c: (b, c, h, 0))
    la_spec = pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h))
    bc_spec = pl.BlockSpec((1, chunk, 1, n),
                           lambda b, h, c: (b, c, h // rep, 0))
    return jax.jit(pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[xd_spec, la_spec, bc_spec, bc_spec],
        out_specs=[xd_spec,
                   pl.BlockSpec((1, 1, n, hp), lambda b, h, c: (b, h, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((batch, seq, nh, hp), dtype),
                   jax.ShapeDtypeStruct((batch, nh, n, hp), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n, hp), jnp.float32)],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    ))
