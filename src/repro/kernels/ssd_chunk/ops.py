"""Jit'd wrapper for the SSD chunk kernel."""
from __future__ import annotations

import jax

from . import kernel as K


def ssd_pallas(xd, log_a, Bm, Cm, chunk: int, *,
               interpret: bool | None = None):
    """Same contract as repro.models.ssm.ssd_chunked.

    xd: (b, s, nh, hp) inputs pre-scaled by dt; log_a: (b, s, nh);
    Bm/Cm: (b, s, g, n).  Returns (y (b, s, nh, hp), hT (b, nh, n, hp))
    -- note hT is (n, hp)-ordered; transpose to match SSMCache.h's
    (hp, n) if feeding the decode path.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, nh, hp = xd.shape
    g, n = Bm.shape[2], Bm.shape[3]
    call = K.ssd_call(b, s, nh, hp, g, n, min(chunk, s), xd.dtype,
                      interpret)
    return call(xd, log_a, Bm, Cm)
