"""Oracle: the pure-jnp chunked SSD from the model stack."""
from repro.models.ssm import ssd_chunked


def ssd_ref(xd, log_a, Bm, Cm, chunk):
    return ssd_chunked(xd, log_a, Bm, Cm, chunk)
