"""Launchers: mesh construction, dry-run, train/serve drivers.
NOTE: do not import dryrun here -- it sets XLA device-count flags on import."""
from . import mesh
