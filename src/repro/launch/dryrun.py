import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with zero real allocation (ShapeDtypeStruct
stand-ins):
  * proof that the sharding config is coherent at 256 (single-pod) and 512
    (2-pod) chips -- ``.lower().compile()`` must succeed;
  * ``compiled.memory_analysis()``  -> bytes/device (fits-in-HBM proof);
  * ``compiled.cost_analysis()``    -> HLO FLOPs / bytes for section Roofline;
  * a collective inventory (bytes per all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute) parsed from the
    optimized HLO, for the roofline's collective term.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
      [--multi-pod] [--out results.json] [--opt <name>=<val> ...]
  python -m repro.launch.dryrun --all [--multi-pod] --out dryrun.json
"""

import argparse
import os
import json
import sys
import time
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get as get_arch, shape_applicable
from repro.parallel import sharding
from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.parallel.sharding import (ParallelCtx, named_sharding,
                                     param_shardings)
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib
from repro.analysis.hlo_collectives import collective_bytes


# ---------------------------------------------------------------------------
# ParallelCtx policy per cell
# ---------------------------------------------------------------------------

def make_pctx(cfg: ModelConfig, mesh, *, overrides: dict | None = None
              ) -> ParallelCtx:
    big = cfg.param_count() > 30e9
    fsdp = ("pod", "data") if (big and "pod" in mesh.shape) else ("data",)
    kw = dict(mesh=mesh, fsdp_axes=fsdp, attn_impl="chunked",
              moe_impl="shard_map", remat=True, sp=True)
    if overrides:
        kw.update(overrides)
    return ParallelCtx(**kw)


def opt_config_for(cfg: ModelConfig) -> opt_lib.AdamWConfig:
    # distributed-opt trick: quantized optimizer state for the largest
    # models (the 235B cell does not fit 512 x 16 GiB with f32 m/v).
    state_dtype = "bfloat16" if cfg.param_count() > 30e9 else "float32"
    return opt_lib.AdamWConfig(state_dtype=state_dtype)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape, pctx: ParallelCtx):
    """Shardable, weak-type-correct stand-ins; no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    dp = pctx.batch_axes

    def tok_struct(shp):
        return jax.ShapeDtypeStruct(
            shp, jnp.int32,
            sharding=named_sharding(pctx, shp, (dp,) + (None,) * (len(shp) - 1)))

    if shape.kind == "train":
        return {"tokens": tok_struct(tok_shape),
                "labels": tok_struct(tok_shape)}
    if shape.kind == "prefill":
        return {"tokens": tok_struct(tok_shape)}
    # decode: one new token against a cache of length S
    one = (B, 1, cfg.n_codebooks) if cfg.n_codebooks else (B, 1)
    return {"token": tok_struct(one),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def _struct_with_shardings(tree, shardings):
    def one(x, s):
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s)
    return jax.tree.map(one, tree, shardings)


def state_struct(cfg: ModelConfig, pctx: ParallelCtx,
                 opt_cfg: opt_lib.AdamWConfig):
    key = jax.random.PRNGKey(0)
    st = jax.eval_shape(
        lambda k: step_lib.init_state(k, cfg, opt_cfg, "none"), key)
    sh = step_lib.state_shardings(st, pctx)
    return _struct_with_shardings(st, sh)


def params_struct(cfg: ModelConfig, pctx: ParallelCtx):
    key = jax.random.PRNGKey(0)
    p = jax.eval_shape(lambda k: T.init_params(k, cfg), key)
    return _struct_with_shardings(p, param_shardings(p, pctx))


def caches_struct(cfg: ModelConfig, batch: int, max_len: int,
                  pctx: ParallelCtx):
    from repro.models.attention import cache_spec
    c = jax.eval_shape(
        lambda: T.init_caches(cfg, batch, max_len, jnp.bfloat16))

    def shard_leaf(x):
        # KV caches: (.., B, H, S, hd); states: batch-leading -- use the
        # generic rule: shard the largest dim that matches batch or heads.
        tmpl = [None] * x.ndim
        # find batch dim == `batch` from the left (after optional stack dim)
        for i, d in enumerate(x.shape):
            if d == batch:
                tmpl[i] = pctx.batch_axes
                break
        # kv-head / seq dim for attention caches
        if x.ndim >= 3 and x.shape[-2] == max_len:
            tmpl[-3] = pctx.tp_axis          # kv heads
            tmpl[-2] = pctx.batch_axes + pctx.tp  # seq fallback (batch=1)
            # avoid double-assigning axes: safe_pspec dedups used axes
        return named_sharding(pctx, x.shape, tmpl)

    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(
        x.shape, x.dtype, sharding=shard_leaf(x)), c)


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()``: dict on current jax, list-of-dicts (one
    per computation) on older jax -- normalize to one dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def lower_cell(cfg: ModelConfig, shape: InputShape, mesh, *,
               overrides: dict | None = None, pctx: ParallelCtx | None = None,
               opt_cfg=None):
    """Returns (lowered, pctx)."""
    if pctx is None:
        pctx = make_pctx(cfg, mesh, overrides=overrides)
    specs = input_specs(cfg, shape, pctx)
    if shape.kind == "train":
        opt_cfg = opt_cfg or opt_config_for(cfg)
        train_step = step_lib.make_train_step(cfg, pctx, opt_cfg)
        st = state_struct(cfg, pctx, opt_cfg)
        with sharding.mesh_context(mesh):
            lowered = jax.jit(train_step, donate_argnums=(0,)).lower(st, specs)
        return lowered, pctx
    if shape.kind == "prefill":
        p = params_struct(cfg, pctx)

        def prefill_step(params, tokens):
            logits, caches = T.prefill(params, tokens, cfg, pctx)
            return logits, caches

        with sharding.mesh_context(mesh):
            lowered = jax.jit(prefill_step).lower(p, specs["tokens"])
        return lowered, pctx
    # decode
    p = params_struct(cfg, pctx)
    caches = caches_struct(cfg, shape.global_batch, shape.seq_len, pctx)

    def serve_step(params, token, caches, pos):
        return T.decode_step(params, token, caches, pos, cfg, pctx)

    with sharding.mesh_context(mesh):
        lowered = jax.jit(serve_step, donate_argnums=(2,)).lower(
            p, specs["token"], caches, specs["pos"])
    return lowered, pctx


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             overrides: dict | None = None, compile_: bool = True,
             calibrate: bool = False) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    _, applicability = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "chips": n_chips, "applicability": applicability,
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}
    t0 = time.time()
    lowered, pctx = lower_cell(cfg, shape, mesh, overrides=overrides)
    rec["lower_s"] = round(time.time() - t0, 1)
    if not compile_:
        return rec
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
    cost = _cost_dict(compiled)
    if cost:
        rec["hlo_flops"] = float(cost.get("flops", 0.0))
        rec["hlo_bytes"] = float(cost.get("bytes accessed", 0.0))
        rec["cost_raw"] = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float)) and
                           ("utilization" not in k)}
    coll = collective_bytes(compiled.as_text())
    rec["collectives"] = coll
    if calibrate:
        rec["calib"] = _calibrate(cfg, shape, mesh, overrides)
    print(json.dumps(rec), flush=True)
    return rec


def _calibrate(cfg: ModelConfig, shape: InputShape, mesh, overrides) -> dict:
    """Per-period cost via unrolled 1-period / 2-period compiles.

    XLA's cost_analysis counts a `while` body once regardless of trip
    count, so the full model's reported numbers undercount the layer scan.
    The unrolled small variants give exact per-period costs; section
    Roofline extrapolates ``total = c1 + (n_periods-1)*(c2-c1) +
    (n_tail/period)*(c2-c1)``.
    """
    pctx_full = make_pctx(cfg, mesh, overrides=overrides)
    opt_cfg = opt_config_for(cfg)
    out = {"n_full_periods": cfg.n_full_periods,
           "n_tail": len(cfg.tail_layers), "period": cfg.period}
    for tag, n_layers in (("c1", cfg.period), ("c2", 2 * cfg.period)):
        cfg_v = replace(cfg, n_layers=n_layers)
        pctx_v = replace(pctx_full, scan_unroll=True)
        lowered, _ = lower_cell(cfg_v, shape, mesh, pctx=pctx_v,
                                opt_cfg=opt_cfg)
        compiled = lowered.compile()
        cost = _cost_dict(compiled)
        out[tag] = {
            "hlo_flops": float(cost.get("flops", 0.0)),
            "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": collective_bytes(compiled.as_text()),
        }
    return out


ALL_CELLS = [(a, s) for a in ARCHS for s in SHAPES]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="also run unrolled 1p/2p compiles for exact "
                         "per-period roofline terms")
    ap.add_argument("--opt", action="append", default=[],
                    help="ParallelCtx overrides, e.g. --opt sp=False")
    args = ap.parse_args(argv)

    overrides = {}
    for o in args.opt:
        k, v = o.split("=", 1)
        overrides[k] = {"True": True, "False": False}.get(v, v)
        if isinstance(overrides[k], str) and "," in v:
            overrides[k] = tuple(v.split(","))

    cells = ALL_CELLS if args.all else [(args.arch, args.shape)]
    results = []
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, args.multi_pod,
                           overrides=overrides or None,
                           compile_=not args.no_compile,
                           calibrate=args.calibrate)
        except Exception as e:  # noqa: BLE001 -- a failing cell is a bug report
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "error": f"{type(e).__name__}: {e}"}
            print(json.dumps(rec), flush=True)
        results.append(rec)
    if args.out:
        mode = "a" if os.path.exists(args.out) else "w"
        with open(args.out, mode) as f:
            for r in results:
                f.write(json.dumps(r) + "\n")
    n_err = sum("error" in r for r in results)
    print(f"# dry-run: {len(results)} cells, {n_err} errors", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
