"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state -- smoke tests and benchmarks must
see the real single CPU device, while the dry-run sets
``xla_force_host_platform_device_count=512`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over whatever devices exist (tests)."""
    return jax.make_mesh(shape, axes)
