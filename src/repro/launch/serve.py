"""Serving driver: batched generation with continuous batching.

Example (CPU smoke):
  python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get, reduced
from repro.models import transformer as T
from repro.parallel.sharding import single_device_ctx
from repro.serve import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    pctx = single_device_ctx(remat=False, attn_impl="full")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, pctx, max_batch=args.max_batch,
                 max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for r in range(args.requests):
        plen = int(rng.integers(4, 24))
        shape = (plen, cfg.n_codebooks) if cfg.n_codebooks else (plen,)
        eng.add_request(Request(
            rid=r, prompt=rng.integers(0, cfg.vocab_size,
                                       size=shape).astype(np.int32),
            max_new_tokens=args.max_new, temperature=args.temperature))
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    n_tok = sum(len(d.out_tokens) for d in done)
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
