"""Training driver.

Smoke (CPU, reduced config):
  python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 30

Production shape (the dry-run proves this lowers at 256/512 chips; on a
real fleet each host runs this same entry point under jax.distributed):
  python -m repro.launch.train --arch qwen3-moe-235b-a22b \
      --steps 1000 --global-batch 256 --seq 4096 --ckpt-dir /ckpts/run1
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, get, reduced
from repro.parallel.sharding import ParallelCtx, single_device_ctx
from repro.train import loop as loop_lib
from repro.train import optimizer as opt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--mesh", type=str, default=None,
                    help='e.g. "4x2" to build a data x model mesh over '
                         'local devices')
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh(shape, ("data", "model")[:len(shape)])
        pctx = ParallelCtx(mesh=mesh, batch_axes=("data",),
                           fsdp_axes=("data",), attn_impl="chunked")
    else:
        pctx = single_device_ctx(
            remat=not args.smoke,
            attn_impl="full" if args.smoke else "chunked")

    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps)
    lcfg = loop_lib.LoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        log_every=max(args.steps // 20, 1), ckpt_dir=args.ckpt_dir,
        global_batch=args.global_batch, seq_len=args.seq,
        n_microbatches=args.microbatches,
        grad_compression=args.grad_compression)

    def log(m):
        print(f"step {m['step']:6d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.3f}  lr {m['lr']:.2e}  "
              f"{m['sec_per_step']:.3f}s/step", flush=True)

    _, hist = loop_lib.run(cfg, pctx, ocfg, lcfg, on_metrics=log)
    print(f"final loss {hist[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
