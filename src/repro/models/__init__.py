"""LM model zoo: unified transformer over per-arch layer plans."""
from . import layers, attention, moe, ssm, rglru, transformer
