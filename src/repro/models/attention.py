"""GQA attention: train/prefill (flash-chunked) + decode (cache) paths.

Features per the assigned archs: GQA (any kv ratio incl. MQA), qk-norm
(qwen3/chameleon), QKV bias (qwen1.5), RoPE, sliding-window local attention
(recurrentgemma).  Sharding: heads over TP, batch over DP, sequence over SP
between blocks; decode KV caches shard (batch -> data, kv-heads -> model)
with automatic fallback to sequence sharding for small batches
(`safe_pspec`), giving the distributed flash-decoding LSE combine for the
long_500k cells (the partial max/sum reductions over the sharded kv axis
are inserted by SPMD).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import chunked_attention, flash_attention
from repro.parallel.sharding import ParallelCtx, constrain
from . import layers as L


class KVCache(NamedTuple):
    k: jax.Array           # (B, Hkv, S_max, hd)
    v: jax.Array


def init(key, cfg):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {"wq": L.dense_init(ks[0], d, H * hd),
         "wk": L.dense_init(ks[1], d, KV * hd),
         "wv": L.dense_init(ks[2], d, KV * hd),
         "wo": L.dense_init(ks[3], H * hd, d)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((KV * hd,), jnp.float32)
        p["bv"] = jnp.zeros((KV * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((hd,), jnp.float32)
        p["k_scale"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(params, x, cfg, positions):
    """x: (B, S, d) -> q (B, H, S, hd), k/v (B, KV, S, hd), roped."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = _headnorm(q, params["q_scale"], cfg.norm_eps)
        k = _headnorm(k, params["k_scale"], cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _headnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def apply_full(params, x, cfg, pctx: ParallelCtx, *, local: bool = False):
    """Training/prefill attention over the whole sequence.  Returns
    (out, KVCache) -- the cache is consumed by prefill, ignored by train."""
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = _project_qkv(params, x, cfg, positions)
    # Sequence-parallel-q layout (Perf iteration 6): q, the softmax stats,
    # and the attention output stay sharded over (batch->DP, seq->TP) --
    # the residual stream's own layout, so the block needs NO activation
    # gathers on the q side.  Only K/V are (un-repeated, bf16) gathered to
    # full sequence, which for GQA is the smallest tensor in the block.
    # The head dim stays unsharded here; head-sharding would instead force
    # full-seq q/out gathers (the baseline's 268 MB/layer f32 copies).
    spec_q = (pctx.batch_axes, None, pctx.tp_axis if pctx.sp else None, None)
    spec_kv = (pctx.batch_axes, None, None, None)
    q = constrain(q, pctx, spec_q)

    def shard(t):
        # q-side: rank-4 (B, H, Sq, D) / rank-3 (B, H, Sq)
        return constrain(t, pctx, spec_q[:2] + spec_q[2:2 + t.ndim - 2])

    def shard_kv(t):
        return constrain(t, pctx, spec_kv[:t.ndim])

    window = cfg.attn_window if (local and cfg.attn_window and
                                 cfg.attn_window < S) else None
    if window is None and pctx.attn_impl == "flash" and \
            jax.default_backend() == "tpu":
        o = flash_attention(q, k, v, causal=True)
    elif window is None and pctx.attn_impl == "full":
        from repro.kernels.flash_attention.ref import attention_ref
        o = attention_ref(q, k, v, causal=True)
    else:
        o = chunked_attention(q, k, v, shard, shard_kv, causal=True,
                              window=window, bkv=min(512, S))
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
    out = o @ params["wo"].astype(x.dtype)
    return out, KVCache(k, v)


def init_cache(cfg, batch: int, max_len: int, dtype) -> KVCache:
    shape = (batch, cfg.n_kv_heads, max_len, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def cache_spec(cfg, pctx: ParallelCtx):
    """Sharding template for KV caches: batch->data, kv-heads->model; the
    sequence dim picks up whatever axes remain unused (long-context cells
    with batch < |data| shard the cache over sequence instead -- the
    distributed flash-decoding layout)."""
    return (pctx.batch_axes, pctx.tp_axis, pctx.batch_axes + pctx.tp, None)


def apply_decode(params, x_t, cache: KVCache, pos, cfg, pctx: ParallelCtx,
                 *, local: bool = False):
    """One decode step. x_t: (B, 1, d); pos: scalar or (B,) positions
    (per-slot positions support the continuous-batching engine).

    Returns (out (B, 1, d), updated cache)."""
    B = x_t.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos_b[:, None, None]                 # (B,1,1) for rope bcast
    q, k_new, v_new = _project_qkv(params, x_t, cfg, positions)

    def upd(c, new, p):
        return jax.lax.dynamic_update_slice(c, new.astype(c.dtype),
                                            (0, p, 0))
    k = jax.vmap(upd)(cache.k, k_new, pos_b)
    v = jax.vmap(upd)(cache.v, v_new, pos_b)
    k = constrain(k, pctx, cache_spec(cfg, pctx))
    v = constrain(v, pctx, cache_spec(cfg, pctx))
    S = k.shape[2]
    hkv, hd = cfg.n_kv_heads, cfg.hd
    group = cfg.n_heads // hkv
    scale = 1.0 / (hd ** 0.5)
    qg = (q.astype(jnp.float32) * scale).reshape(B, hkv, group, hd)
    s = jnp.einsum("bngd,bnkd->bngk", qg, k.astype(jnp.float32))
    k_pos = jnp.arange(S)
    valid = k_pos[None, :] <= pos_b[:, None]
    if local and cfg.attn_window:
        valid &= k_pos[None, :] > pos_b[:, None] - cfg.attn_window
    valid = valid[:, None, None, :]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngk,bnkd->bngd", p, v.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * hd).astype(x_t.dtype)
    out = o @ params["wo"].astype(x_t.dtype)
    return out, KVCache(k, v)
