"""Shared model primitives: norms, RoPE, initializers, dense MLPs.

Plain-pytree parameters (no framework dependency): every module is an
``init(key, cfg) -> params`` + ``apply(params, x, ...) -> y`` pair.
Compute dtype is bf16 (cfg.dtype) with f32 params and f32 norm/softmax
accumulation -- the standard mixed-precision recipe.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def cdtype(cfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale)


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float):
    """x: (..., S, hd); positions: (S,) or broadcastable to x[..., :, 0]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLPs (SwiGLU / GeGLU-style)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d, f),
            "w_in": dense_init(k2, d, f),
            "w_out": dense_init(k3, f, d)}


def mlp_apply(params, x, *, act: str = "silu"):
    dt = x.dtype
    gate = x @ params["w_gate"].astype(dt)
    up = x @ params["w_in"].astype(dt)
    actv = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = actv(gate.astype(jnp.float32)).astype(dt) * up
    return h @ params["w_out"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / heads
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, n_codebooks: int = 0):
    if n_codebooks:
        return {"tok": jax.random.normal(key, (n_codebooks, vocab, d),
                                         jnp.float32)}
    return {"tok": jax.random.normal(key, (vocab, d), jnp.float32)}


def embed_apply(params, tokens, cfg):
    dt = cdtype(cfg)
    # cast the table BEFORE the take: with a vocab-sharded table the lookup
    # lowers to masked-take + psum over the vocab axis, and casting first
    # halves that collective (bf16 vs f32) -- Perf iteration 6.
    if cfg.n_codebooks:
        # tokens: (B, S, ncb); sum codebook embeddings (musicgen frontend)
        embs = []
        for c in range(cfg.n_codebooks):
            embs.append(jnp.take(params["tok"][c].astype(dt),
                                 tokens[..., c], axis=0))
        return sum(embs)
    return jnp.take(params["tok"].astype(dt), tokens, axis=0)


def head_init(key, cfg):
    if cfg.tie_embeddings:
        return {}
    d, v = cfg.d_model, cfg.vocab_size
    if cfg.n_codebooks:
        return {"lm_head": jax.random.normal(key, (cfg.n_codebooks, d, v),
                                             jnp.float32) * d ** -0.5}
    return {"lm_head": jax.random.normal(key, (d, v), jnp.float32)
            * d ** -0.5}


def head_apply(head_params, embed_params, x, cfg):
    """x: (B, S, d) -> logits (B, S, V) or (B, S, ncb, V)."""
    dt = x.dtype
    if cfg.n_codebooks:
        if cfg.tie_embeddings:
            w = jnp.swapaxes(embed_params["tok"], 1, 2)   # (ncb, d, V)
        else:
            w = head_params["lm_head"]
        logits = jnp.einsum("bsd,cdv->bscv", x, w.astype(dt))
    else:
        w = (embed_params["tok"].T if cfg.tie_embeddings
             else head_params["lm_head"])
        logits = x @ w.astype(dt)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits.astype(jnp.float32) / c) * c
    return logits


def cross_entropy(logits, labels):
    """Mean CE; logits (..., V) f32-accumulated; labels int (...)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


# ---------------------------------------------------------------------------
# Fused chunked softmax-cross-entropy head (custom VJP).
#
# Materializing (B, S, V) logits + their f32 CE intermediates dominates
# training memory for large vocabularies (the 152k-vocab cells: ~8 GB/chip
# in the baseline dry-run -- EXPERIMENTS.md Perf iteration 3).  This head
# scans sequence chunks, computing loss statistics forward and recomputing
# the chunk's softmax in the backward -- peak live logits are (B, chunk, V)
# and the only stored residuals are (x, w, labels).
# ---------------------------------------------------------------------------

def _ce_chunks(T: int, chunk: int) -> int:
    c = min(chunk, T)
    while T % c:
        c -= 1
    return max(c, 1)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_softmax_xent(x2, w, labels, chunk: int = 512):
    """Mean CE of labels under softmax(x2 @ w).

    x2: (T, d); w: (d, V); labels: (T,) int. Returns scalar mean loss."""
    loss, _ = _fused_xent_fwd_impl(x2, w, labels, chunk)
    return loss


def _fused_xent_fwd_impl(x2, w, labels, chunk):
    T, d = x2.shape
    c = _ce_chunks(T, chunk)
    xs = x2.reshape(T // c, c, d)
    ls = labels.reshape(T // c, c)

    def step(acc, xs_):
        xc, lc = xs_
        # f32 accumulation even for bf16 working params (iteration 8)
        logits = jnp.matmul(xc, w, preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
        return acc + jnp.sum(lse - ll), None

    total, _ = jax.lax.scan(step, jnp.float32(0), (xs, ls))
    return total / T, (x2, w, labels)


def _fused_xent_vjp_fwd(x2, w, labels, chunk):
    loss, res = _fused_xent_fwd_impl(x2, w, labels, chunk)
    return loss, res


def _fused_xent_vjp_bwd(chunk, res, g):
    x2, w, labels = res
    T, d = x2.shape
    c = _ce_chunks(T, chunk)
    xs = x2.reshape(T // c, c, d)
    ls = labels.reshape(T // c, c)
    scale = g / T

    def step(dw, xs_):
        xc, lc = xs_
        logits = jnp.matmul(xc, w, preferred_element_type=jnp.float32)
        p = jax.nn.softmax(logits, axis=-1)          # recomputed
        p = p.at[jnp.arange(c), lc].add(-1.0)
        p = p * scale
        dx_c = (p @ w.astype(jnp.float32).T).astype(x2.dtype)
        dw = dw + xc.astype(jnp.float32).T @ p
        return dw, dx_c

    dw0 = jnp.zeros((d, w.shape[1]), jnp.float32)
    dw, dxs = jax.lax.scan(step, dw0, (xs, ls))
    dx = dxs.reshape(T, d)
    return dx, dw.astype(w.dtype), None


fused_softmax_xent.defvjp(_fused_xent_vjp_fwd, _fused_xent_vjp_bwd)


def fused_head_loss(head_params, embed_params, x, labels, cfg,
                    chunk: int = 512):
    """Chunked CE over the LM head; handles tying and codebook stacks.

    x: (B, S, d); labels: (B, S) or (B, S, ncb)."""
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    if cfg.n_codebooks:
        losses = []
        for cb in range(cfg.n_codebooks):
            w = (jnp.swapaxes(embed_params["tok"], 1, 2)[cb]
                 if cfg.tie_embeddings else head_params["lm_head"][cb])
            losses.append(fused_softmax_xent(
                x2, w.astype(x.dtype), labels[..., cb].reshape(B * S),
                chunk))
        return sum(losses) / cfg.n_codebooks
    w = (embed_params["tok"].T if cfg.tie_embeddings
         else head_params["lm_head"])
    return fused_softmax_xent(x2, w.astype(x.dtype),
                              labels.reshape(B * S), chunk)
