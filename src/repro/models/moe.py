"""Mixture-of-Experts layer: top-k routing + expert-parallel dispatch.

This is where the paper's technique is a first-class LM feature
(DESIGN.md section 5): the token->expert dispatch matrix is a sparse matrix
in CSR-by-expert layout, dispatch is an SpMM, and the paper's C8 finding
(skip the sort when order doesn't matter) maps to the *unstable* dispatch
sort -- tokens within an expert have no required order, so
``stable_dispatch_sort=False`` (default) uses the cheaper unstable sort and
benchmarks the difference (bench `moe_dispatch`).

Two implementations:
  * ``dense``     -- single-device reference (smoke tests, examples);
  * ``shard_map`` -- production expert parallelism: tokens sharded
    (batch->DP, seq->SP), experts sharded E->TP ("model"); the dispatch
    buffer (E, C, d) is exchanged with ``lax.all_to_all`` over "model",
    expert FFN weights are fe-sharded over FSDP axes and all-gathered
    per layer (ZeRO-3), and the combine reverses the all_to_all.

Both share `_route` / `_dispatch` / `_combine`, so the reference IS the
oracle for the distributed path (tested in tests/test_moe.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.parallel.sharding import ParallelCtx, safe_pspec
from . import layers as L


def init(key, cfg):
    m = cfg.moe
    d, E, fe = cfg.d_model, m.n_experts, m.d_expert
    ks = jax.random.split(key, 4)
    return {
        "router": L.dense_init(ks[0], d, E, scale=d ** -0.5),
        "we_gate": jax.random.normal(ks[1], (E, d, fe), jnp.float32) * d ** -0.5,
        "we_in":   jax.random.normal(ks[2], (E, d, fe), jnp.float32) * d ** -0.5,
        "we_out":  jax.random.normal(ks[3], (E, fe, d), jnp.float32) * fe ** -0.5,
    }


# ---------------------------------------------------------------------------
# Routing + sparse dispatch (shared by both impls)
# ---------------------------------------------------------------------------

def _route(params, x2, cfg):
    """x2: (T, d) -> (top_p (T,k), top_i (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = (x2.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_i[:, 0], m.n_experts), axis=0)
    aux = m.n_experts * jnp.sum(me * ce)
    return top_p.astype(x2.dtype), top_i, aux


def _dispatch(x2, top_i, n_experts: int, capacity: int, stable: bool):
    """Build the (E*C, d) expert input buffer -- an SpMM with the
    CSR-by-expert dispatch matrix.

    Returns (buffer, slot_of_assignment (T, k) with -1 for dropped)."""
    T, k = top_i.shape
    d = x2.shape[1]
    flat_e = top_i.reshape(-1)                                    # (T*k,)
    # C8: unstable sort -- order within an expert is irrelevant.
    order = jnp.argsort(flat_e, stable=stable)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(T * k, dtype=jnp.int32) - first              # rank in expert
    keep = pos < capacity
    dest = jnp.where(keep, sorted_e * capacity + pos, n_experts * capacity)
    src_tok = order // k
    buf = jnp.zeros((n_experts * capacity, d), x2.dtype)
    buf = buf.at[dest].set(x2[src_tok], mode="drop")
    slot = jnp.full((T * k,), -1, jnp.int32).at[order].set(
        jnp.where(keep, dest, -1))
    return buf, slot.reshape(T, k)


def _combine(ybuf, slot, top_p):
    """Inverse dispatch: gather expert outputs back and mix by gate probs."""
    T, k = slot.shape
    safe = jnp.maximum(slot, 0)
    y = ybuf[safe.reshape(-1)].reshape(T, k, -1)
    y = jnp.where((slot >= 0)[..., None], y, 0)
    return jnp.einsum("tkd,tk->td", y, top_p.astype(y.dtype))


def _expert_ffn(xb, wg, wi, wo):
    """xb: (E, C, d); weights (E, d, fe)/(E, fe, d). Grouped SwiGLU."""
    dt = xb.dtype
    g = jnp.einsum("ecd,edf->ecf", xb, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xb, wi.astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))


# ---------------------------------------------------------------------------
# Reference (single device / no mesh)
# ---------------------------------------------------------------------------

def apply_dense(params, x, cfg):
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    cap = max(1, int(T * m.top_k / m.n_experts * m.capacity_factor))
    top_p, top_i, aux = _route(params, x2, cfg)
    buf, slot = _dispatch(x2, top_i, m.n_experts, cap,
                          m.stable_dispatch_sort)
    xb = buf.reshape(m.n_experts, cap, d)
    yb = _expert_ffn(xb, params["we_gate"], params["we_in"], params["we_out"])
    y = _combine(yb.reshape(m.n_experts * cap, d), slot, top_p)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map implementation
# ---------------------------------------------------------------------------

def apply_ep(params, x, cfg, pctx: ParallelCtx):
    m = cfg.moe
    mesh = pctx.mesh
    B, S, d = x.shape
    tp = pctx.tp_axis
    ep = mesh.shape[tp]
    assert m.n_experts % ep == 0, (m.n_experts, ep)

    x_spec = safe_pspec(mesh, x.shape, (pctx.batch_axes, pctx.tp_axis, None))
    r_spec = P(None, None)
    fsdp = pctx.fsdp if pctx.fsdp else None
    wg_spec = safe_pspec(mesh, params["we_gate"].shape, (tp, None, fsdp))
    wo_spec = safe_pspec(mesh, params["we_out"].shape, (tp, fsdp, None))
    out_spec = x_spec

    # local token count (static)
    def _shards(spec, shape):
        n = 1
        for dim, s in zip(shape, spec):
            if s is None:
                continue
            for a in ((s,) if isinstance(s, str) else s):
                n *= mesh.shape[a]
        return n

    t_loc = (B * S) // _shards(x_spec, x.shape)
    cap = max(1, int(t_loc * m.top_k / m.n_experts * m.capacity_factor))
    fe_gather_axes = tuple(a for a in (pctx.fsdp or ())
                           if a in mesh.shape and
                           wg_spec[2] is not None and
                           (a == wg_spec[2] or (isinstance(wg_spec[2], tuple)
                                                and a in wg_spec[2])))

    def local(x_l, router, wg_l, wi_l, wo_l):
        bl, sl, _ = x_l.shape
        x2 = x_l.reshape(bl * sl, d)
        top_p, top_i, aux = _route({"router": router}, x2, cfg)
        buf, slot = _dispatch(x2, top_i, m.n_experts, cap,
                              m.stable_dispatch_sort)
        xb = buf.reshape(m.n_experts, cap, d)
        # exchange tokens for experts over the TP/EP axis
        xb = jax.lax.all_to_all(xb, tp, split_axis=0, concat_axis=1,
                                tiled=True)          # (E/ep, ep*cap, d)
        # ZeRO-3: regather fe-sharded expert weights for this layer.
        # Cast to the compute dtype BEFORE the gather so the collective
        # moves bf16, not f32 master bytes (Perf iteration 8).
        cdt = x_l.dtype
        if fe_gather_axes:
            wg = jax.lax.all_gather(wg_l.astype(cdt), fe_gather_axes,
                                    axis=2, tiled=True)
            wi = jax.lax.all_gather(wi_l.astype(cdt), fe_gather_axes,
                                    axis=2, tiled=True)
            wo = jax.lax.all_gather(wo_l.astype(cdt), fe_gather_axes,
                                    axis=1, tiled=True)
        else:
            wg, wi, wo = wg_l.astype(cdt), wi_l.astype(cdt), wo_l.astype(cdt)
        yb = _expert_ffn(xb, wg, wi, wo)             # (E/ep, ep*cap, d)
        yb = jax.lax.all_to_all(yb, tp, split_axis=1, concat_axis=0,
                                tiled=True)          # (E, cap, d)
        y = _combine(yb.reshape(m.n_experts * cap, d), slot, top_p)
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return y.reshape(bl, sl, d), aux

    fn = shard_map(local, mesh=mesh,
                   in_specs=(x_spec, r_spec, wg_spec, wg_spec, wo_spec),
                   out_specs=(out_spec, P()),
                   check_rep=False)
    return fn(x, params["router"], params["we_gate"], params["we_in"],
              params["we_out"])


def apply(params, x, cfg, pctx: ParallelCtx):
    if pctx.mesh is None or pctx.moe_impl == "dense":
        return apply_dense(params, x, cfg)
    return apply_ep(params, x, cfg, pctx)
