"""RG-LRU recurrent block (Griffin / RecurrentGemma [arXiv:2402.19427]).

Block: y = W_out( GeLU(W_gate x) * RG-LRU( conv4( W_rnn x ) ) )

RG-LRU cell (block-diagonal input/recurrence gates, n_blocks=NB):
  r_t = sigmoid(blockdiag(gate_a) . x_t)
  i_t = sigmoid(blockdiag(gate_x) . x_t)
  log a_t = -c * softplus(a_param) * r_t          (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` over time (O(log S) depth);
decode is the single-step recurrence with (conv window, h) cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import layers as L

C_FACTOR = 8.0
NB = 8               # gate block-diagonal blocks
D_CONV = 4


class RGLRUCache(NamedTuple):
    conv: jax.Array    # (B, D_CONV-1, w) trailing conv inputs
    h: jax.Array       # (B, w) recurrent state (f32)


def init(key, cfg):
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_gate_in": L.dense_init(ks[0], d, w),
        "w_rnn_in": L.dense_init(ks[1], d, w),
        "conv_w": jax.random.normal(ks[2], (D_CONV, w), jnp.float32)
                  * D_CONV ** -0.5,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "gate_a": jax.random.normal(ks[3], (NB, w // NB, w // NB),
                                    jnp.float32) * (w // NB) ** -0.5,
        "gate_x": jax.random.normal(ks[4], (NB, w // NB, w // NB),
                                    jnp.float32) * (w // NB) ** -0.5,
        "a_param": jnp.log(jnp.expm1(
            jnp.linspace(0.1, 0.5, w).astype(jnp.float32))),  # softplus^-1
        "w_rnn_out": L.dense_init(ks[5], w, d),
    }


def _block_gate(g, x):
    """x: (..., w) -> sigmoid(blockdiag(g) x); g: (NB, w/NB, w/NB)."""
    shape = x.shape
    xb = x.reshape(shape[:-1] + (NB, shape[-1] // NB))
    y = jnp.einsum("...bi,bij->...bj", xb.astype(jnp.float32), g)
    return jax.nn.sigmoid(y).reshape(shape)


def _gates(params, xr):
    r = _block_gate(params["gate_a"], xr)
    i = _block_gate(params["gate_x"], xr)
    log_a = -C_FACTOR * jax.nn.softplus(params["a_param"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * xr.astype(jnp.float32))
    return a, b


def apply_full(params, x, cfg):
    """x: (B, S, d) -> (y, RGLRUCache)."""
    dt_ = x.dtype
    B, S, _ = x.shape
    gate = jax.nn.gelu((x @ params["w_gate_in"].astype(dt_))
                       .astype(jnp.float32))
    xr = x @ params["w_rnn_in"].astype(dt_)
    # causal depthwise conv4
    pad = jnp.pad(xr, ((0, 0), (D_CONV - 1, 0), (0, 0)))
    xc = sum(pad[:, i:i + S, :] * params["conv_w"][i].astype(dt_)
             for i in range(D_CONV)) + params["conv_b"].astype(dt_)
    a, b = _gates(params, xc)                       # (B, S, w) f32
    # h_t = a_t h_{t-1} + b_t  via associative scan on (a, b)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (gate * h).astype(dt_)
    out = y @ params["w_rnn_out"].astype(dt_)
    conv_tail = xr[:, -(D_CONV - 1):, :]
    if S < D_CONV - 1:
        conv_tail = jnp.pad(xr, ((0, 0), (D_CONV - 1 - S, 0), (0, 0)))
    return out, RGLRUCache(conv_tail, h[:, -1, :])


def init_cache(cfg, batch: int, dtype) -> RGLRUCache:
    w = cfg.rnn_width or cfg.d_model
    return RGLRUCache(conv=jnp.zeros((batch, D_CONV - 1, w), dtype),
                      h=jnp.zeros((batch, w), jnp.float32))


def apply_decode(params, x_t, cache: RGLRUCache, cfg):
    """One step. x_t: (B, 1, d)."""
    dt_ = x_t.dtype
    B = x_t.shape[0]
    gate = jax.nn.gelu((x_t @ params["w_gate_in"].astype(dt_))
                       .astype(jnp.float32))[:, 0]
    xr = (x_t @ params["w_rnn_in"].astype(dt_))[:, 0]        # (B, w)
    win = jnp.concatenate([cache.conv, xr[:, None, :]], axis=1)
    xc = jnp.einsum("bkw,kw->bw", win, params["conv_w"].astype(dt_)) + \
        params["conv_b"].astype(dt_)
    a, b = _gates(params, xc)
    h = a * cache.h + b
    y = (gate * h).astype(dt_)
    out = (y @ params["w_rnn_out"].astype(dt_))[:, None, :]
    return out, RGLRUCache(win[:, 1:, :], h)
