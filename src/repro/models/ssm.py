"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked training path: within-chunk terms are dense (Q x Q) matmuls (MXU
work -- this is the "duality"), across-chunk state is a short scan.  Decode
path is the O(1)-state recurrence.  TPU notes: chunk length is cfg.ssm.chunk
(default 256 = two MXU tiles); with sequence parallelism the per-chip
sequence is a handful of chunks, keeping the (nc, nh, Q, Q) decay tensor in
the tens of MB.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import layers as L


class SSMCache(NamedTuple):
    conv: jax.Array      # (B, d_conv-1, conv_channels) trailing inputs
    h: jax.Array         # (B, nh, head_dim, d_state)


def dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return d_in, nh, conv_ch


def init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_ch = dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * d_in + 2 * s.n_groups * s.d_state + nh),
        "conv_w": jax.random.normal(ks[1], (s.d_conv, conv_ch), jnp.float32)
                  * (s.d_conv ** -0.5),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": L.dense_init(ks[4], d_in, d),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _gated_norm(y, z, scale, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def ssd_chunked(xd, log_a, Bm, Cm, chunk: int):
    """SSD: y_t = C_t^T H_t,  H_t = a_t H_{t-1} + B_t xd_t^T.

    xd: (b, s, nh, hp)  (inputs already scaled by dt)
    log_a: (b, s, nh)   (per-step log decay, <= 0)
    Bm, Cm: (b, s, g, n); heads map to groups by nh//g blocks.
    Returns (b, s, nh, hp) and final state (b, nh, hp, n).
    """
    b, s, nh, hp = xd.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = nh // g
    assert s % chunk == 0, (s, chunk)
    nc, Q = s // chunk, chunk
    f32 = jnp.float32

    xd_ = xd.reshape(b, nc, Q, nh, hp).astype(f32)
    la = log_a.reshape(b, nc, Q, nh).astype(f32)
    B_ = jnp.repeat(Bm.reshape(b, nc, Q, g, n), rep, axis=3).astype(f32)
    C_ = jnp.repeat(Cm.reshape(b, nc, Q, g, n), rep, axis=3).astype(f32)

    cum = jnp.cumsum(la, axis=2)                          # (b, nc, Q, nh)
    # intra-chunk: Y[i] += sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) xd_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,Qi,Qj,nh)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: the upper triangle holds positive exponents whose
    # exp overflows; exp(inf)*0 in the cotangent is NaN (classic where-trap)
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e30)
    Ld = jnp.exp(seg)
    CB = jnp.einsum("bcihn,bcjhn->bcijh", C_, B_)          # (b,nc,Qi,Qj,nh)
    y_intra = jnp.einsum("bcijh,bcijh,bcjhp->bcihp", CB, Ld, xd_)

    # chunk-end states: S_c = sum_j exp(cum_end - cum_j) B_j xd_j^T
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)             # (b, nc, Q, nh)
    S_c = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", dec_end, B_, xd_)

    # cross-chunk recurrence: H_c = exp(sum la_c) H_{c-1} + S_c (scan)
    a_chunk = jnp.exp(cum[:, :, -1, :])                    # (b, nc, nh)

    def step(h, inp):
        a_c, s_c = inp
        h_new = h * a_c[..., None, None] + s_c
        return h_new, h                                    # emit H_{c-1}
    h0 = jnp.zeros((b, nh, hp, n), f32)
    hT, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(S_c, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                    # (b, nc, nh, hp, n)

    # inter-chunk: Y[i] += exp(cum_i) C_i . H_{c-1}
    y_inter = jnp.einsum("bcih,bcihn,bchpn->bcihp",
                         jnp.exp(cum), C_, h_prev)
    y = (y_intra + y_inter).reshape(b, s, nh, hp)
    return y.astype(xd.dtype), hT


def _pick_chunk(s: int, chunk: int) -> int:
    """Largest divisor of s that is <= the configured chunk."""
    c = min(chunk, s)
    while s % c:
        c -= 1
    return max(c, 1)


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_in, nh, _ = dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * gn], axis=-1)
    return z, xbc, dt


def apply_full(params, x, cfg):
    """Training/prefill. x: (B, S, d) -> (y, SSMCache)."""
    s = cfg.ssm
    d_in, nh, conv_ch = dims(cfg)
    gn = s.n_groups * s.d_state
    dt_ = x.dtype
    B_, S_, _ = x.shape
    zxbcdt = x @ params["in_proj"].astype(dt_)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, params["conv_w"].astype(dt_),
                       params["conv_b"].astype(dt_))
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(dt_)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + gn], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                          # (nh,)
    xh = xs.reshape(B_, S_, nh, s.head_dim)
    xd = xh * dt[..., None].astype(dt_)
    log_a = dt * A                                          # (B, S, nh)
    Bm = Bm.reshape(B_, S_, s.n_groups, s.d_state)
    Cm = Cm.reshape(B_, S_, s.n_groups, s.d_state)
    y, hT = ssd_chunked(xd, log_a, Bm, Cm, _pick_chunk(S_, s.chunk))
    y = y + params["D"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(B_, S_, d_in)
    y = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    return out, SSMCache(_tail_conv_inputs(cfg, x, params), hT)


def _tail_conv_inputs(cfg, x, params):
    """Last (d_conv-1) pre-activation conv inputs, for decode continuation."""
    s = cfg.ssm
    dt_ = x.dtype
    zxbcdt = x[:, -(s.d_conv - 1):, :] @ params["in_proj"].astype(dt_)
    _, xbc, _ = _split_proj(cfg, zxbcdt)
    B_ = x.shape[0]
    pad = s.d_conv - 1 - xbc.shape[1]
    if pad > 0:
        xbc = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    return xbc


def init_cache(cfg, batch: int, dtype) -> SSMCache:
    s = cfg.ssm
    d_in, nh, conv_ch = dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        h=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32))


def apply_decode(params, x_t, cache: SSMCache, cfg):
    """One step. x_t: (B, 1, d)."""
    s = cfg.ssm
    d_in, nh, conv_ch = dims(cfg)
    gn = s.n_groups * s.d_state
    dt_ = x_t.dtype
    B_ = x_t.shape[0]
    zxbcdt = x_t @ params["in_proj"].astype(dt_)
    z, xbc_new, dt = _split_proj(cfg, zxbcdt)
    # conv over the window [cache.conv, xbc_new]
    win = jnp.concatenate([cache.conv, xbc_new], axis=1)    # (B, K, C)
    w = params["conv_w"].astype(dt_)
    xbc = jnp.einsum("bkc,kc->bc", win, w)[:, None, :] + \
        params["conv_b"].astype(dt_)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(dt_)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + gn], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,1,nh)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)[:, 0]                               # (B, nh)
    xh = xs.reshape(B_, nh, s.head_dim)
    rep = nh // s.n_groups
    Bv = jnp.repeat(Bm.reshape(B_, s.n_groups, s.d_state), rep, axis=1)
    Cv = jnp.repeat(Cm.reshape(B_, s.n_groups, s.d_state), rep, axis=1)
    xd = (xh * dt[:, 0, :, None].astype(dt_)).astype(jnp.float32)
    h = cache.h * a[..., None, None] + \
        jnp.einsum("bhp,bhn->bhpn", xd, Bv.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", h, Cv.astype(jnp.float32))
    y = y.astype(dt_) + params["D"].astype(dt_)[None, :, None] * xh
    y = y.reshape(B_, 1, d_in)
    y = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    return out, SSMCache(win[:, 1:, :], h)
