"""Unified LM: assembles any assigned architecture from its layer plan.

Layer stack = ``lax.scan`` over full plan *periods* (stacked params), with
remainder layers unrolled -- compile time is O(period), not O(n_layers),
which is what keeps the 94-layer MoE dry-run cells tractable.  Each period
is rematerialized (``jax.checkpoint``) during training.

Entry points (all pure; pctx carries mesh/sharding context):
  init_params(key, cfg)                 -> params pytree
  train_loss(params, batch, cfg, pctx)  -> (loss, metrics)
  prefill(params, tokens, cfg, pctx)    -> (last_logits, caches)
  decode_step(params, token, caches, pos, cfg, pctx) -> (logits, caches)
"""
from __future__ import annotations

from typing import Any

from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParallelCtx, constrain
from . import layers as L
from . import attention, moe, ssm, rglru


# ---------------------------------------------------------------------------
# Sub-layer (mixer + mlp)
# ---------------------------------------------------------------------------

def _init_sublayer(key, cfg, mixer: str, mlp: str):
    kmix, kmlp = jax.random.split(key)
    p: dict[str, Any] = {"norm1": L.rmsnorm_init(cfg.d_model)}
    if mixer in ("attn", "attn_local"):
        p["mixer"] = attention.init(kmix, cfg)
    elif mixer == "ssd":
        p["mixer"] = ssm.init(kmix, cfg)
    elif mixer == "rglru":
        p["mixer"] = rglru.init(kmix, cfg)
    else:
        raise ValueError(mixer)
    if mlp != "none":
        p["norm2"] = L.rmsnorm_init(cfg.d_model)
        if mlp in ("swiglu", "gated_mlp"):
            p["mlp"] = L.mlp_init(kmlp, cfg.d_model, cfg.d_ff)
        elif mlp == "moe":
            p["mlp"] = moe.init(kmlp, cfg)
        else:
            raise ValueError(mlp)
    return p


def _act_spec(pctx):
    return (pctx.batch_axes, pctx.tp_axis if pctx.sp else None, None)


def _apply_sublayer_full(p, x, cfg, pctx, mixer: str, mlp: str):
    """Returns (x, cache, aux)."""
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if mixer in ("attn", "attn_local"):
        y, cache = attention.apply_full(p["mixer"], h, cfg, pctx,
                                        local=(mixer == "attn_local"))
    elif mixer == "ssd":
        y, cache = ssm.apply_full(p["mixer"], h, cfg)
    elif mixer == "rglru":
        y, cache = rglru.apply_full(p["mixer"], h, cfg)
    x = x + y
    x = constrain(x, pctx, _act_spec(pctx))
    aux = jnp.float32(0)
    if mlp != "none":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if mlp == "moe":
            y, aux = moe.apply(p["mlp"], h, cfg, pctx)
            # Named for selective-remat policies.  Measured (Perf iteration
            # 9, REFUTED): saving only the output does NOT reduce the MoE
            # recompute traffic -- the transposed layer still replays the
            # dispatch to produce expert-weight grads; saving the dispatch
            # internals instead costs ~336 MB/chip/layer, which does not
            # fit.  Kept because downstream consumers (logit head) avoid
            # one replay, and it documents the experiment.
            y = _checkpoint_name(y, "moe_out")
        else:
            y = L.mlp_apply(p["mlp"], h,
                            act=("gelu" if mlp == "gated_mlp" else "silu"))
        x = x + y
        x = constrain(x, pctx, _act_spec(pctx))
    return x, cache, aux


def _apply_sublayer_decode(p, x_t, cache, pos, cfg, pctx, mixer: str,
                           mlp: str):
    h = L.rmsnorm(p["norm1"], x_t, cfg.norm_eps)
    if mixer in ("attn", "attn_local"):
        y, cache = attention.apply_decode(p["mixer"], h, cache, pos, cfg,
                                          pctx,
                                          local=(mixer == "attn_local"))
    elif mixer == "ssd":
        y, cache = ssm.apply_decode(p["mixer"], h, cache, cfg)
    elif mixer == "rglru":
        y, cache = rglru.apply_decode(p["mixer"], h, cache, cfg)
    x_t = x_t + y
    if mlp != "none":
        h = L.rmsnorm(p["norm2"], x_t, cfg.norm_eps)
        if mlp == "moe":
            y, _ = moe.apply(p["mlp"], h, cfg, pctx)
        else:
            y = L.mlp_apply(p["mlp"], h,
                            act=("gelu" if mlp == "gated_mlp" else "silu"))
        x_t = x_t + y
    return x_t, cache


def _init_cache_sublayer(cfg, mixer: str, batch: int, max_len: int, dtype):
    if mixer in ("attn", "attn_local"):
        return attention.init_cache(cfg, batch, max_len, dtype)
    if mixer == "ssd":
        return ssm.init_cache(cfg, batch, dtype)
    if mixer == "rglru":
        return rglru.init_cache(cfg, batch, dtype)
    raise ValueError(mixer)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_params(key, cfg):
    ks = jax.random.split(key, 4 + cfg.n_layers)
    params = {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                              cfg.n_codebooks),
        "head": L.head_init(ks[1], cfg),
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }
    n_full = cfg.n_full_periods
    if n_full:
        periods = []
        for i in range(n_full):
            layer_keys = jax.random.split(ks[3 + i], cfg.period)
            periods.append(tuple(
                _init_sublayer(layer_keys[j], cfg, *cfg.plan[j])
                for j in range(cfg.period)))
        params["periods"] = jax.tree.map(lambda *xs: jnp.stack(xs), *periods)
    else:
        params["periods"] = None
    tail = []
    for j, (mixer, mlp) in enumerate(cfg.tail_layers):
        tail.append(_init_sublayer(ks[3 + n_full + j], cfg, mixer, mlp))
    params["tail"] = tuple(tail)
    return params


def init_caches(cfg, batch: int, max_len: int, dtype):
    """Caches mirroring the params layout: stacked periods + tail list."""
    def one_period():
        return tuple(_init_cache_sublayer(cfg, mixer, batch, max_len, dtype)
                     for mixer, _ in cfg.plan)
    n_full = cfg.n_full_periods
    periods = None
    if n_full:
        ps = [one_period() for _ in range(n_full)]
        periods = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    tail = tuple(_init_cache_sublayer(cfg, mixer, batch, max_len, dtype)
                 for mixer, _ in cfg.tail_layers)
    return {"periods": periods, "tail": tail}


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _backbone_full(params, x, cfg, pctx, *, want_caches: bool):
    """Shared by train and prefill.  Returns (x, caches|None, aux_total)."""
    aux_total = jnp.float32(0)
    caches_periods = None

    def period_fn(carry, period_params):
        x, aux = carry
        caches = []
        for j, (mixer, mlp) in enumerate(cfg.plan):
            x, cache, aux_j = _apply_sublayer_full(
                period_params[j], x, cfg, pctx, mixer, mlp)
            caches.append(cache)
            aux = aux + aux_j
        return (x, aux), tuple(caches)

    if params["periods"] is not None:
        body = period_fn
        if pctx.remat:
            policy = None
            if any(mlp == "moe" for _, mlp in cfg.plan):
                policy = jax.checkpoint_policies.save_only_these_names(
                    "moe_out")
            elif pctx.remat_policy == "dots":
                policy = jax.checkpoint_policies.\
                    dots_with_no_batch_dims_saveable
            body = jax.checkpoint(period_fn, prevent_cse=False,
                                  policy=policy)
        if pctx.scan_unroll:
            n_full = jax.tree.leaves(params["periods"])[0].shape[0]
            ys = []
            carry = (x, aux_total)
            for i in range(n_full):
                carry, y = body(carry, jax.tree.map(
                    lambda v: v[i], params["periods"]))
                ys.append(y)
            (x, aux_total) = carry
            caches_periods = jax.tree.map(lambda *vs: jnp.stack(vs), *ys) \
                if want_caches else ys[-1]
        else:
            (x, aux_total), caches_periods = jax.lax.scan(
                body, (x, aux_total), params["periods"])
    caches_tail = []
    for j, (mixer, mlp) in enumerate(cfg.tail_layers):
        x, cache, aux_j = _apply_sublayer_full(params["tail"][j], x, cfg,
                                               pctx, mixer, mlp)
        caches_tail.append(cache)
        aux_total = aux_total + aux_j
    caches = None
    if want_caches:
        caches = {"periods": caches_periods, "tail": tuple(caches_tail)}
    return x, caches, aux_total


def train_loss(params, batch, cfg, pctx: ParallelCtx):
    """batch: {"tokens": (B,S) or (B,S,ncb), "labels": same}."""
    tokens, labels = batch["tokens"], batch["labels"]
    x = L.embed_apply(params["embed"], tokens, cfg)
    x = constrain(x, pctx, _act_spec(pctx))
    x, _, aux = _backbone_full(params, x, cfg, pctx, want_caches=False)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if pctx.fused_ce and cfg.logit_softcap is None:
        # chunked fused softmax-CE: never materializes (B, S, V) logits
        loss = L.fused_head_loss(params["head"], params["embed"], x, labels,
                                 cfg, chunk=pctx.ce_chunk)
    else:
        logits = L.head_apply(params["head"], params["embed"], x, cfg)
        logits = constrain(logits, pctx,
                           (pctx.batch_axes, None, pctx.tp_axis)
                           if not cfg.n_codebooks else
                           (pctx.batch_axes, None, None, pctx.tp_axis))
        loss = L.cross_entropy(logits, labels)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux
    metrics = {"loss": loss, "aux": aux}
    return loss, metrics


def prefill(params, tokens, cfg, pctx: ParallelCtx):
    """Returns (last-position logits, caches at len S)."""
    x = L.embed_apply(params["embed"], tokens, cfg)
    x = constrain(x, pctx, _act_spec(pctx))
    x, caches, _ = _backbone_full(params, x, cfg, pctx, want_caches=True)
    x_last = x[:, -1:, :]
    x_last = L.rmsnorm(params["final_norm"], x_last, cfg.norm_eps)
    logits = L.head_apply(params["head"], params["embed"], x_last, cfg)
    return logits, caches


def decode_step(params, token, caches, pos, cfg, pctx: ParallelCtx):
    """token: (B, 1) or (B, 1, ncb); pos: scalar int (0-based write slot).

    Returns (logits (B, 1, V...), updated caches)."""
    x = L.embed_apply(params["embed"], token, cfg)

    def period_fn(x, xs):
        period_params, period_caches = xs
        new_caches = []
        for j, (mixer, mlp) in enumerate(cfg.plan):
            x, cache = _apply_sublayer_decode(
                period_params[j], x, period_caches[j], pos, cfg, pctx,
                mixer, mlp)
            new_caches.append(cache)
        return x, tuple(new_caches)

    new_period_caches = None
    if params["periods"] is not None:
        if pctx.scan_unroll:
            n_full = jax.tree.leaves(params["periods"])[0].shape[0]
            ys = []
            for i in range(n_full):
                x, y = period_fn(x, jax.tree.map(
                    lambda v: v[i], (params["periods"], caches["periods"])))
                ys.append(y)
            new_period_caches = jax.tree.map(lambda *vs: jnp.stack(vs), *ys)
        else:
            x, new_period_caches = jax.lax.scan(
                period_fn, x, (params["periods"], caches["periods"]))
    new_tail = []
    for j, (mixer, mlp) in enumerate(cfg.tail_layers):
        x, cache = _apply_sublayer_decode(
            params["tail"][j], x, caches["tail"][j], pos, cfg, pctx,
            mixer, mlp)
        new_tail.append(cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_apply(params["head"], params["embed"], x, cfg)
    return logits, {"periods": new_period_caches, "tail": tuple(new_tail)}
