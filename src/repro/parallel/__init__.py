"""Distribution substrate: sharding rules, collectives, pipeline."""
from .sharding import (ParallelCtx, single_device_ctx, safe_pspec, constrain,
                       named_sharding, param_shardings)
