"""Sharding rules: logical-axis mapping for params/activations on the mesh.

Mesh axes (launch/mesh.py):
  single pod: ("data", "model") = (16, 16)
  multi pod:  ("pod", "data", "model") = (2, 16, 16)

Strategy (DESIGN.md section 6):
  * TP  ("model"): attention heads, FFN hidden, vocab, MoE expert dim E.
  * FSDP ("data", + "pod" for large models): the non-TP dim of every weight;
    XLA's SPMD partitioner turns this into per-layer all-gather (ZeRO-3)
    inside the scan + reduce-scatter of grads.
  * DP  ("pod", "data"): activation batch.
  * SP  ("model"): activation sequence dim between attention blocks
    (Megatron-style sequence parallelism) and in MoE dispatch.

Every constraint goes through :func:`safe_pspec`, which drops mesh axes that
do not divide the dimension (e.g. batch=1 long_500k cells fall back to
sequence sharding automatically).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisT = Optional[Any]   # None | str | tuple[str, ...]


@dataclass(frozen=True)
class ParallelCtx:
    """Everything the model/train/serve code needs to know about the mesh."""
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("pod", "data")
    tp_axis: str = "model"
    fsdp_axes: Tuple[str, ...] = ("data",)
    sp: bool = True
    remat: bool = True
    attn_impl: str = "chunked"        # chunked | flash | full
    moe_impl: str = "shard_map"       # shard_map | dense
    # distributed-optimization knobs (DESIGN.md section 6)
    grad_compression: str = "none"    # none | bf16 | int8_ef
    hierarchical_allreduce: bool = True
    zero1_over_pod: bool = True       # shard optimizer state over pod too
    # analysis knob: unroll the layer scan (used by the roofline calibration
    # compiles so cost_analysis sees every period; never used at scale)
    scan_unroll: bool = False
    # fused chunked softmax-CE head (Perf iteration 3); exact, so on by
    # default -- False falls back to materialized (B,S,V) logits + CE
    fused_ce: bool = True
    ce_chunk: int = 512
    # remat policy for the layer scan: "none" recomputes everything;
    # "dots" saves weight-stationary matmul outputs (XLA
    # dots_with_no_batch_dims_saveable) -- Perf iteration 12 knob
    remat_policy: str = "none"

    def present(self, axes) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        if isinstance(axes, str):
            axes = (axes,)
        return tuple(a for a in axes if a in self.mesh.shape)

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return self.present(self.batch_axes)

    @property
    def tp(self) -> Tuple[str, ...]:
        return self.present(self.tp_axis)

    @property
    def fsdp(self) -> Tuple[str, ...]:
        return self.present(self.fsdp_axes)


def single_device_ctx(**kw) -> ParallelCtx:
    return ParallelCtx(mesh=None, **kw)


def mesh_context(mesh: Mesh):
    """``jax.set_mesh(mesh)`` where available; on older jax the Mesh object
    itself is the context manager that installs the global mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def safe_pspec(mesh: Mesh, shape: Tuple[int, ...],
               template: Sequence[AxisT]) -> P:
    """Build a PartitionSpec, dropping axes that don't divide the dim or
    aren't in the mesh.  Template entries may be None, "axis", or a tuple of
    axes (major-to-minor)."""
    out = []
    used: set[str] = set()
    for dim, t in zip(shape, tuple(template) + (None,) * len(shape)):
        if t is None:
            out.append(None)
            continue
        axes = (t,) if isinstance(t, str) else tuple(t)
        axes = [a for a in axes if a in mesh.shape and a not in used]
        # greedily keep the prefix of axes whose product divides dim
        keep = []
        prod = 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    return P(*out)


def constrain(x: jax.Array, pctx: ParallelCtx, template: Sequence[AxisT]
              ) -> jax.Array:
    """with_sharding_constraint through safe_pspec; no-op off-mesh."""
    if pctx.mesh is None or not isinstance(x, jax.Array | jax.core.Tracer):
        return x
    spec = safe_pspec(pctx.mesh, x.shape, template)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pctx.mesh, spec))


def named_sharding(pctx: ParallelCtx, shape: Tuple[int, ...],
                   template: Sequence[AxisT]) -> Optional[NamedSharding]:
    if pctx.mesh is None:
        return None
    return NamedSharding(pctx.mesh, safe_pspec(pctx.mesh, shape, template))


# ---------------------------------------------------------------------------
# Parameter partitioning rules (path-name driven)
# ---------------------------------------------------------------------------

#: map from leaf-name -> sharding template over the *trailing* dims
#: (leading stacked-scan dims get None).  "fsdp"/"tp" are placeholders
#: resolved against the ctx.

def _rules():
    # (name suffixes, template) -- first match wins; templates are for the
    # last len(template) dims of the param.
    return [
        (("tok",),          ("tp", "fsdp")),        # embedding (V, d)
        (("lm_head",),      ("fsdp", "tp")),        # (d, V)
        (("wq", "wk", "wv"), ("fsdp", "tp")),
        (("wo",),           ("tp", "fsdp")),
        (("bq", "bk", "bv"), ("tp",)),
        (("w_gate", "w_in"), ("fsdp", "tp")),       # dense mlp (d, f)
        (("w_out",),        ("tp", "fsdp")),        # dense mlp (f, d)
        (("router",),       ("fsdp", None)),        # (d, E)
        (("we_gate", "we_in"), ("tp", None, "fsdp")),   # moe (E, d, fe)
        (("we_out",),       ("tp", "fsdp", None)),      # moe (E, fe, d)
        (("in_proj", "out_proj"), ("fsdp", "tp")),  # ssd / rglru projections
        (("w_gate_in", "w_rnn_in"), ("fsdp", "tp")),
        (("w_rnn_out",),    ("tp", "fsdp")),
        (("gate_a", "gate_x"), (None, "tp", None)), # rglru block-diag (nb, w/nb, w/nb)
        (("conv_w",),       (None, "tp")),          # (d_conv, channels)
        (("A_log", "D", "a_param", "conv_b"), ("tp",)),
        (("scale", "q_scale", "k_scale"), (None,)), # norms replicated
    ]


def param_template(path: str, ndim: int) -> tuple:
    """Sharding template for a param, from its tree path (joined names)."""
    leaf = path.split("/")[-1]
    for names, tmpl in _rules():
        if leaf in names:
            pad = (None,) * (ndim - len(tmpl))
            return pad + tuple(tmpl)
    return (None,) * ndim


def resolve_template(tmpl: Sequence, pctx: ParallelCtx) -> tuple:
    out = []
    for t in tmpl:
        if t == "tp":
            out.append(pctx.tp if len(pctx.tp) != 1 else pctx.tp[0])
        elif t == "fsdp":
            out.append(pctx.fsdp if len(pctx.fsdp) != 1 else pctx.fsdp[0])
        else:
            out.append(t)
    return tuple(x if x != () else None for x in out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params_tree, pctx: ParallelCtx):
    """NamedSharding pytree for a param (shape) pytree."""
    if pctx.mesh is None:
        return jax.tree.map(lambda _: None, params_tree)

    def one(path, leaf):
        tmpl = resolve_template(param_template(_path_str(path), leaf.ndim),
                                pctx)
        return NamedSharding(pctx.mesh,
                             safe_pspec(pctx.mesh, leaf.shape, tmpl))

    return jax.tree_util.tree_map_with_path(one, params_tree)
