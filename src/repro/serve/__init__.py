"""Serving runtime: batched prefill/decode with continuous batching."""
from .engine import Engine, Request
from .sampling import sample_logits
