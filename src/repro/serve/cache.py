"""Batched-cache surgery for continuous batching.

Caches are pytrees of per-layer state objects (KVCache / SSMCache /
RGLRUCache), possibly with a leading stacked-period dim.  Each state type
declares the batch axis of its leaves *from the right*, which is invariant
under period stacking -- that is what lets one `insert` work for both the
scanned stack and the unrolled tail.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import KVCache
from repro.models.ssm import SSMCache
from repro.models.rglru import RGLRUCache

#: negative batch-axis per (cache type, field index)
_BATCH_AXIS = {
    (KVCache, 0): -4, (KVCache, 1): -4,          # k, v: (B, H, S, hd)
    (SSMCache, 0): -3, (SSMCache, 1): -4,        # conv (B,K-1,C), h (B,nh,hp,n)
    (RGLRUCache, 0): -3, (RGLRUCache, 1): -2,    # conv (B,3,w), h (B,w)
}

_TYPES = (KVCache, SSMCache, RGLRUCache)


def _is_state(x):
    return isinstance(x, _TYPES)


def _map_states(fn, *trees):
    return jax.tree.map(fn, *trees, is_leaf=_is_state)


def insert_slot(batched, single, slot: int):
    """Write a batch-1 cache (from a prefill) into slot `slot` of a batched
    cache; also supports batch-1 caches with shorter sequence (the KV prefix
    is written, the rest left as-is)."""

    def one(big_state, small_state):
        t = type(big_state)
        new_fields = []
        for i, (big, small) in enumerate(zip(big_state, small_state)):
            ax = _BATCH_AXIS[(t, i)] % big.ndim
            src = jnp.squeeze(small, axis=ax % small.ndim) \
                if small.shape[ax % small.ndim] == 1 else small[..., 0, :]
            # build index: batch axis -> slot; for KV, seq may be shorter
            idx = [slice(None)] * big.ndim
            idx[ax] = slot
            if t is KVCache:
                s_small = small.shape[-2]
                idx[-2] = slice(0, s_small)
            new_fields.append(big.at[tuple(idx)].set(src))
        return t(*new_fields)

    return _map_states(one, batched, single)


def init_batched_like(cfg, max_batch: int, max_len: int, dtype):
    from repro.models import transformer as T
    return T.init_caches(cfg, max_batch, max_len, dtype)
