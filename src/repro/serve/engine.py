"""Continuous-batching serving engine.

One decode step serves every active slot; newly-arrived requests are
prefilled (batch-1) and inserted into free slots between decode steps --
the vLLM-style iteration-level schedule, sized by the paper's C1 logic
(admission keeps per-step work balanced; a prefill counts as its token
count, a decode slot as 1).

The engine is deliberately host-driven and jit-light: `prefill_fn` and
`decode_fn` are the two compiled artifacts (the same ones the dry-run
lowers at production scale).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.parallel.sharding import ParallelCtx
from . import cache as cache_lib
from .sampling import sample_logits


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) or (S, ncb)
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, pctx: ParallelCtx, *, max_batch: int = 4,
                 max_len: int = 512, seed: int = 0):
        self.cfg, self.params, self.pctx = cfg, params, pctx
        self.max_batch, self.max_len = max_batch, max_len
        dtype = jnp.dtype(cfg.dtype)
        self.caches = T.init_caches(cfg, max_batch, max_len, dtype)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)      # next write position
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, tok, caches, pos: T.decode_step(p, tok, caches, pos,
                                                      cfg, pctx))
        self._prefill = jax.jit(
            lambda p, tok: T.prefill(p, tok, cfg, pctx))

    # -- public -------------------------------------------------------------
    def add_request(self, req: Request):
        self.queue.append(req)

    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def step(self):
        """Admit (at most one prefill) + one decode for all active slots."""
        self._admit()
        if self.active() == 0:
            return []
        finished = []
        tokens = np.zeros((self.max_batch, 1) +
                          ((self.cfg.n_codebooks,) if self.cfg.n_codebooks
                           else ()), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            last = req.out_tokens[-1] if req.out_tokens else \
                np.asarray(req.prompt[-1])
            tokens[i, 0] = last
        # per-slot positions: attention masks/rope use pos[b] (vector pos).
        logits, self.caches = self._decode(self.params, jnp.asarray(tokens),
                                           self.caches,
                                           jnp.asarray(self.pos))
        self.key, sub = jax.random.split(self.key)
        temps = [r.temperature if r else 0.0 for r in self.slots]
        toks = np.asarray(sample_logits(sub, logits[:, 0],
                                        temperature=max(temps) if any(
                                            t > 0 for t in temps) else 0.0))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = toks[i] if not self.cfg.n_codebooks else toks[i]
            req.out_tokens.append(np.asarray(tok))
            self.pos[i] += 1
            if len(req.out_tokens) >= req.max_new_tokens or \
                    self.pos[i] >= self.max_len - 1:
                req.done = True
                finished.append(req)
                self.slots[i] = None
        return finished

    def run_to_completion(self, max_steps: int = 10_000):
        out = []
        steps = 0
        while (self.queue or self.active()) and steps < max_steps:
            out.extend(self.step())
            steps += 1
        return out

    # -- internals ------------------------------------------------------------
    def _admit(self):
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                prompt = jnp.asarray(req.prompt)[None]      # (1, S, ...)
                logits, caches1 = self._prefill(self.params, prompt)
                self.caches = cache_lib.insert_slot(self.caches, caches1, i)
                self.key, sub = jax.random.split(self.key)
                tok = np.asarray(sample_logits(
                    sub, logits[:, 0], temperature=req.temperature))[0]
                req.out_tokens.append(tok)
                self.slots[i] = req
                self.pos[i] = prompt.shape[1]
