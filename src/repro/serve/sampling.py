"""Token sampling: greedy / temperature / top-k."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_logits(key, logits, *, temperature: float = 1.0,
                  top_k: int = 0) -> jax.Array:
    """logits: (..., V) -> token ids (...,). temperature<=0 means greedy."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        thresh = jax.lax.top_k(lf, top_k)[0][..., -1:]
        lf = jnp.where(lf < thresh, -1e30, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)
