"""Training substrate: optimizer, step, loop."""
from . import optimizer, step, loop
