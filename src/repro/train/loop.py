"""Training loop with fault tolerance: checkpoint/restart, deterministic
data, failure injection (for tests), and straggler notes.

Fault-tolerance contract (DESIGN.md section 6):
  * data is a pure function of step -> restart from checkpoint step k
    replays step k+1 identically (bitwise on CPU; tested);
  * checkpoints are atomic (rename) and async (I/O off the step path);
  * on SPMD TPU fleets a dead host stalls the step; recovery = restart from
    the latest checkpoint on a reconfigured mesh -- restore() reshards
    elastically, so the replacement fleet may be a different size;
  * stragglers: static balanced partitions (paper C1) mean no dynamic
    work-stealing is needed; persistent slow hosts are handled by the
    restart path, and the loop exports step-time telemetry to spot them.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax

from repro.checkpoint import Checkpointer
from repro.data.lm_synthetic import DataPipeline
from repro.parallel.sharding import ParallelCtx
from . import optimizer as opt
from . import step as step_lib


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    fail_at_step: Optional[int] = None    # failure injection (tests)
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    n_microbatches: int = 1
    grad_compression: str = "none"


def run(cfg, pctx: ParallelCtx, opt_cfg: opt.AdamWConfig, loop: LoopConfig,
        on_metrics: Optional[Callable] = None):
    """Train; returns (final_state, history).  Resumes from the latest
    checkpoint in loop.ckpt_dir if one exists."""
    data = DataPipeline(cfg, loop.global_batch, loop.seq_len, seed=loop.seed)
    train_step = step_lib.make_train_step(
        cfg, pctx, opt_cfg, n_microbatches=loop.n_microbatches,
        grad_compression=loop.grad_compression)

    ckpt = Checkpointer(loop.ckpt_dir) if loop.ckpt_dir else None
    start_step = 0
    key = jax.random.PRNGKey(loop.seed)
    state = step_lib.init_state(key, cfg, opt_cfg, loop.grad_compression)
    if ckpt is not None and ckpt.latest_step() is not None:
        start_step = ckpt.latest_step()
        shardings = step_lib.state_shardings(state, pctx) \
            if pctx.mesh is not None else None
        state = ckpt.restore(start_step, state, shardings)

    jitted = jax.jit(train_step, donate_argnums=(0,))
    history = []
    t_last = time.perf_counter()
    try:
        for s in range(start_step, loop.total_steps):
            if loop.fail_at_step is not None and s == loop.fail_at_step:
                raise RuntimeError(f"injected failure at step {s}")
            batch = data.batch(s)
            state, metrics = jitted(state, batch)
            if (s + 1) % loop.log_every == 0 or s == loop.total_steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                now = time.perf_counter()
                m["step"] = s
                m["sec_per_step"] = (now - t_last) / loop.log_every
                t_last = now
                history.append(m)
                if on_metrics:
                    on_metrics(m)
            if ckpt is not None and (s + 1) % loop.ckpt_every == 0:
                ckpt.save(s + 1, state)
    except BaseException:
        # Fault-tolerance contract (DESIGN.md section 6): drain the async
        # writer before the process dies, or a crash between the host
        # snapshot and the atomic rename silently loses the newest complete
        # checkpoint (it would sit in ``.tmp`` forever).  A writer error
        # must not mask the original failure being propagated.
        if ckpt is not None:
            try:
                ckpt.wait()
            except Exception:
                pass
        raise
    if ckpt is not None:
        ckpt.save(loop.total_steps, state, blocking=True)
    return state, history
