"""AdamW with ZeRO-sharded, optionally quantized state.

Distributed-optimization features (DESIGN.md section 6):
  * optimizer state inherits the parameter FSDP sharding (ZeRO); with
    ``zero1_over_pod`` the m/v trees additionally shard over "pod";
  * ``state_dtype``: f32 | bf16 | int8 -- bf16/int8 m+v is what lets the
    235B MoE cell fit 512 x 16 GiB (10 -> 6 bytes/param; see EXPERIMENTS.md
    section Dry-run);  int8 uses per-block (128) absmax scales;
  * master params stay f32; the forward casts to cfg.dtype at use sites.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"       # float32 | bfloat16 | int8
    #: working-parameter dtype.  "bfloat16" = classic mixed precision: the
    #: model holds bf16 params (so every FSDP all-gather and grad
    #: reduce-scatter moves bf16 -- Perf iteration 8) while the optimizer
    #: carries the f32 master copy.
    param_dtype: str = "bfloat16"
    warmup_steps: int = 100
    total_steps: int = 10_000


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any      # f32 master params ({} when param_dtype == float32)


# --- int8 block quantization (per-BLOCK absmax) -----------------------------

class QTensor(NamedTuple):
    q: jax.Array        # int8 payload, flat padded
    scale: jax.Array    # f32 per-block scales
    shape: tuple        # static


def _quantize(x: jax.Array) -> QTensor:
    flat = x.ravel()
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32), x.shape)


def _dequantize(t: QTensor) -> jax.Array:
    flat = (t.q.astype(jnp.float32) * t.scale[:, None]).ravel()
    n = 1
    for s in t.shape:
        n *= s
    return flat[:n].reshape(t.shape)


def _to_state_dtype(x, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(jnp.dtype(dtype))


def _from_state_dtype(x, dtype: str):
    if dtype == "int8":
        return _dequantize(x)
    return x.astype(jnp.float32)


# --- AdamW ------------------------------------------------------------------

def init(params_f32, cfg: AdamWConfig):
    """Returns (working_params, OptState). ``params_f32`` is the f32 init."""
    zeros = jax.tree.map(
        lambda p: _to_state_dtype(jnp.zeros(p.shape, jnp.float32),
                                  cfg.state_dtype), params_f32)
    zeros2 = jax.tree.map(
        lambda p: _to_state_dtype(jnp.zeros(p.shape, jnp.float32),
                                  cfg.state_dtype), params_f32)
    if cfg.param_dtype == "float32":
        master = {}
        working = params_f32
    else:
        master = params_f32
        working = jax.tree.map(
            lambda p: p.astype(jnp.dtype(cfg.param_dtype)), params_f32)
    return working, OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                             v=zeros2, master=master)


def lr_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    prog = jnp.clip((step.astype(jnp.float32) - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(grads, state: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    is_q = lambda x: isinstance(x, QTensor)
    has_master = cfg.param_dtype != "float32"

    def upd(p, g, m, v, mast):
        g = g.astype(jnp.float32) * clip
        mf = _from_state_dtype(m, cfg.state_dtype)
        vf = _from_state_dtype(v, cfg.state_dtype)
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * g * g
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        base = mast if has_master else p.astype(jnp.float32)
        if p.ndim >= 2:            # decoupled wd on matrices only
            delta = delta + cfg.weight_decay * base
        new_master = base - lr * delta
        p_new = new_master.astype(p.dtype)
        return p_new, _to_state_dtype(mf, cfg.state_dtype), \
            _to_state_dtype(vf, cfg.state_dtype), \
            (new_master if has_master else None)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m) if cfg.state_dtype != "int8" else \
        jax.tree.flatten(state.m, is_leaf=is_q)[0]
    flat_v = tdef.flatten_up_to(state.v) if cfg.state_dtype != "int8" else \
        jax.tree.flatten(state.v, is_leaf=is_q)[0]
    flat_mast = tdef.flatten_up_to(state.master) if has_master else \
        [None] * len(flat_p)
    out = [upd(p, g, m, v, mast) for p, g, m, v, mast in
           zip(flat_p, flat_g, flat_m, flat_v, flat_mast)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_mast = tdef.unflatten([o[3] for o in out]) if has_master else {}
    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, OptState(step, new_m, new_v, new_mast), metrics
