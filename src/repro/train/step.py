"""Jitted train step: grad (+accumulation), compression, AdamW update.

Distributed-optimization tricks wired here:
  * microbatch gradient accumulation (lax.scan) with configurable
    accumulator dtype (f32/bf16);
  * gradient compression before the optimizer: "bf16" cast or "int8_ef"
    (block-quantized int8 with a persistent error-feedback buffer carried
    in TrainState -- the EF residual re-enters the next step's gradient, so
    the quantization error is unbiased over time);
  * the cross-shard gradient reductions themselves are emitted by SPMD from
    the parameter shardings (reduce-scatter within FSDP axes); compression
    applies on top of the materialized per-shard gradient.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.parallel.sharding import ParallelCtx
from . import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.OptState
    ef: Any                  # error-feedback tree (or None-like empty dict)


def init_state(key, cfg, opt_cfg: opt.AdamWConfig,
               grad_compression: str = "none") -> TrainState:
    params_f32 = T.init_params(key, cfg)
    working, state = opt.init(params_f32, opt_cfg)
    ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                      working) if grad_compression == "int8_ef" else {}
    return TrainState(working, state, ef)


def _compress(grads, ef, mode: str):
    """Returns (grads_for_update, new_ef)."""
    if mode == "none":
        return grads, ef
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), ef
    if mode == "int8_ef":
        def one(g, e):
            total = g.astype(jnp.float32) + e.astype(jnp.float32)
            q = opt._quantize(total)
            deq = opt._dequantize(q)
            return deq, (total - deq).astype(jnp.bfloat16)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(ef)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in outs]),
                tdef.unflatten([o[1] for o in outs]))
    raise ValueError(mode)


def make_train_step(cfg, pctx: ParallelCtx, opt_cfg: opt.AdamWConfig,
                    *, n_microbatches: int = 1,
                    grad_compression: str = "none",
                    accum_dtype: str = "float32"):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = T.train_loss(params, batch, cfg, pctx)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        if n_microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def micro(carry, mb):
                acc, = carry
                (l, m), g = grad_fn(state.params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(a.dtype), acc, g)
                return (acc,), (l, m)

            mbs = jax.tree.map(
                lambda x: x.reshape((n_microbatches,
                                     x.shape[0] // n_microbatches)
                                    + x.shape[1:]), batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.dtype(accum_dtype)),
                state.params)
            (acc,), (losses, ms) = jax.lax.scan(micro, (acc0,), mbs)
            grads = jax.tree.map(lambda a: a / n_microbatches, acc)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        grads, ef = _compress(grads, state.ef, grad_compression)
        new_params, new_opt, om = opt.update(grads, state.opt, state.params,
                                             opt_cfg)
        metrics = dict(metrics, **om, loss=loss)
        return TrainState(new_params, new_opt, ef), metrics

    return train_step


def state_shardings(state: TrainState, pctx: ParallelCtx):
    """NamedShardings for the whole TrainState (ZeRO: opt state follows the
    param sharding; with zero1_over_pod the m/v additionally shard the
    first shardable dim over 'pod')."""
    from repro.parallel.sharding import param_shardings, named_sharding
    if pctx.mesh is None:
        return jax.tree.map(lambda _: None, state)
    p_sh = param_shardings(state.params, pctx)

    def opt_leaf_sharding(path_sh, leaf):
        return path_sh   # same layout as the param

    m_sh = jax.tree.map(lambda s: s, p_sh)
    v_sh = jax.tree.map(lambda s: s, p_sh)
    mast_sh = jax.tree.map(lambda s: s, p_sh) if state.opt.master else {}
    ef_sh = jax.tree.map(lambda s: s, p_sh) if state.ef else {}
    step_sh = named_sharding(pctx, (), ())
    return TrainState(p_sh,
                      opt.OptState(step=step_sh, m=m_sh, v=v_sh,
                                   master=mast_sh),
                      ef_sh)
