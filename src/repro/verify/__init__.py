"""Static contract checker for the SpGEMM subsystems (two layers).

Layer 1 (:mod:`repro.verify.bounds`) traces every planned executor to a
jaxpr and walks it with an interval/bounds domain
(:mod:`repro.verify.intervals`) seeded from the plan's frozen schedule:
store/slice indices are proved within the planned capacities and p2
table sizes, int32 prefix sums are proved unable to overflow given
``schedule.guard_i32_flop``'s admitted range, and the jaxpr's primitive
census is checked against the algorithm's budget (zero inspection
primitives -- no symbolic Pallas kernel, no unbudgeted ``sort``, no
``dot_general`` densify).

Layer 2 (:mod:`repro.verify.lint` + :mod:`repro.verify.rules`) is an AST
repo-rule linter over ``src/repro/`` enforcing source-level contracts
(no densify in core execute paths, deterministic plan keys, static
Pallas scratch shapes, counter hygiene, frozen-plan immutability, no
Python branches on traced values, no dead imports).

Both layers run as ``python -m repro.verify --all`` (the CI
``static-analysis`` job) and are importable as test helpers -- see
``tests/test_verify.py`` and DESIGN.md section 15.
"""
from .intervals import Ival, JaxprAnalyzer, Site, TOP
from .bounds import (check_plan_vcs, verify_batch, verify_bcsr,
                     verify_chain, verify_dist_1d, verify_pb,
                     verify_spgemm, verify_summa, run_layer1)
from .lint import LintViolation, lint_paths, run_layer2
from .report import Report, layer1_to_dict, layer2_to_dict

__all__ = [
    "Ival", "JaxprAnalyzer", "Site", "TOP",
    "check_plan_vcs", "verify_spgemm", "verify_batch", "verify_bcsr",
    "verify_dist_1d", "verify_pb", "verify_summa", "verify_chain",
    "run_layer1",
    "LintViolation", "lint_paths", "run_layer2",
    "Report", "layer1_to_dict", "layer2_to_dict",
]
