"""CLI: ``python -m repro.verify [--all|--layer1|--layer2] [--json PATH]``.

Exit status is the contract: 0 when every proof obligation holds and the
lint surface is clean, 1 on any violation -- the CI ``static-analysis``
job runs ``--all --json verify_report.json`` and uploads the report.
"""
from __future__ import annotations

import argparse
import sys

from . import lint
from .bounds import run_layer1
from .lint import run_layer2
from .report import Report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.verify",
        description="static contract checker: jaxpr bounds proofs "
                    "(layer 1) + repo-rule linter (layer 2)")
    ap.add_argument("--all", action="store_true",
                    help="run both layers (default if neither is chosen)")
    ap.add_argument("--layer1", action="store_true",
                    help="jaxpr interval/bounds proofs over every plan kind")
    ap.add_argument("--layer2", action="store_true",
                    help="AST repo-rule lint over the repo surface")
    ap.add_argument("--kinds", default=None,
                    help="comma list of layer-1 plan kinds "
                         "(spgemm,batch,dist_1d,summa,chain,bcsr)")
    ap.add_argument("--rules", default=None,
                    help="comma list of layer-2 rules (see --list-rules)")
    ap.add_argument("--root", default=".",
                    help="repo root for layer 2 (default: cwd)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable report here")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the registered layer-2 rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        from . import rules as _rules  # noqa: F401
        for name in lint.rule_names():
            print(f"{name}: {lint.rule_doc(name)}")
        return 0

    do_l1 = args.all or args.layer1 or not (args.layer1 or args.layer2)
    do_l2 = args.all or args.layer2 or not (args.layer1 or args.layer2)
    report = Report()

    if do_l1:
        kinds = args.kinds.split(",") if args.kinds else None
        report.layer1 = run_layer1(kinds)
        for case in report.layer1:
            mark = "ok " if case.ok else "FAIL"
            bad_vcs = [vc.name for vc in case.vcs if not vc.ok]
            extra = f" vcs-failed={bad_vcs}" if bad_vcs else ""
            if not case.budget.get("ok"):
                extra += (f" budget expected={case.budget['expected']} "
                          f"got={case.budget['got']}")
            print(f"[{mark}] layer1 {case.name}: "
                  f"sites={case.site_counts}{extra}")
            for v in case.violations:
                print(f"       violation: {v['kind']} at {v['path']}: "
                      f"{v['detail']}")

    if do_l2:
        rules = args.rules.split(",") if args.rules else None
        violations, waivers, n_files = run_layer2(args.root, rules)
        report.layer2 = violations
        report.layer2_files = n_files
        report.layer2_waivers = waivers
        print(f"[{'ok ' if not violations else 'FAIL'}] layer2: "
              f"{n_files} files, {len(violations)} violations, "
              f"{len(waivers)} waived")
        for v in violations:
            print(f"       {v}")

    if args.json:
        report.to_json(args.json)
        print(f"report written to {args.json}")

    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
