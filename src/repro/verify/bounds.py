"""Layer 1: trace planned executors to jaxprs and prove their contracts.

Three proof families per executor case, mirroring what Nagasaka et
al.'s inspector-executor split actually promises:

**Schedule verification conditions** (:func:`check_plan_vcs`) are exact
checks on the plan's *frozen* arrays -- the hash bins partition the
rows, every per-bin p2 table is large enough for its rows' symbolic
counts (so probes terminate and flushes fit), the output indptr is
monotone and lands exactly on ``nnz_c <= cap_c``, and the
flop-scaled quantities ``schedule.guard_i32_flop`` admits stay under
``2**31 - 1`` recomputed in exact Python integers.

**Interval site proofs** walk the execute jaxpr with
:class:`repro.verify.intervals.JaxprAnalyzer`: every Pallas
``get``/``swap``, ``scatter`` and ``dynamic_slice`` index must come
back ``proved`` / ``guarded`` / ``discharged`` -- the only discharge in
the repo is the hash kernel's flush cursor (``indptr_c[i] + cnt``),
which is relational and covered by the store-capacity + flush-bound
VCs, hence only granted after those VCs pass.

**Primitive budgets** pin the no-reinspection / no-densify story: a
planned execute must stage *exactly* the numeric primitives its
algorithm owns -- one numeric Pallas call per hash product (a second
would be the symbolic kernel re-inspecting), the single numeric
expansion ``sort`` for ESC-family algorithms, zero ``sort`` for heap
and planned hash, and zero ``dot_general`` anywhere (SUMMA's dense
partial accumulator is scatter-based by design and stays
``dot_general``-free).

Fixtures are tiny and deterministic; tracing never executes a kernel,
so everything here runs on any backend in a few seconds.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core import CSR
from repro.core import schedule as sched
from repro.kernels.spgemm_hash import kernel as HK

from .intervals import TOP, Ival, JaxprAnalyzer, VIOLATION, UNPROVED_READ
from .report import VC, CaseReport

_I32_MAX = 2**31 - 1


# ---------------------------------------------------------------------------
# schedule verification conditions (concrete, exact)
# ---------------------------------------------------------------------------

def _vc(name: str, ok, detail: str = "") -> VC:
    return VC(name, bool(ok), detail)


def _check_hash_schedule(offsets, bin_tsize, indptr_c, *, n_rows: int,
                         n_cols: int, cap_c: int, table_size: int,
                         flop=None, exact_cover: bool = True,
                         label: str = "") -> List[VC]:
    """The four hash-executor VCs on one (offsets, bin_tsize, indptr_c)
    schedule.  ``flop`` (the frozen per-row symbolic flop) enables the
    exact probe-termination recompute; without it (stacked batch/dist
    schedules don't carry flop) the structural form is checked.
    ``exact_cover=False`` admits padded schedules (batch classes round
    a member's ``m`` up to the class shape, so ``offsets[-1]`` is the
    member's true row count, <= the padded ``n_rows``)."""
    pre = f"{label}: " if label else ""
    offsets = np.asarray(offsets)
    bin_tsize = np.asarray(bin_tsize)
    indptr_c = np.asarray(indptr_c)
    vcs: List[VC] = []

    # bins partition the rows
    cover_ok = (offsets[-1] == n_rows if exact_cover
                else offsets[-1] <= n_rows)
    part_ok = (offsets.ndim == 1 and offsets[0] == 0
               and cover_ok and np.all(np.diff(offsets) >= 0))
    vcs.append(_vc("offsets-partition", part_ok,
                   f"{pre}bins cover [0, {int(offsets[-1])}] within "
                   f"[0, {n_rows}) contiguously"))

    # p2 tables within [CHUNK, table_size]
    bt = bin_tsize.astype(np.int64)
    p2_ok = np.all((bt & (bt - 1)) == 0) and np.all(bt >= HK.CHUNK) \
        and np.all(bt <= table_size)
    vcs.append(_vc("table-p2-range", p2_ok,
                   f"{pre}per-bin tables p2 in [{HK.CHUNK}, {table_size}]"))

    # probes terminate: each bin's table exceeds its rows' worst row
    if flop is not None and part_ok:
        flop = np.asarray(flop)[:n_rows].astype(np.int64)
        need = np.empty(len(bin_tsize), np.int64)
        for b in range(len(bin_tsize)):
            rows = flop[int(offsets[b]):int(offsets[b + 1])]
            worst = int(rows.max()) if rows.size else 0
            need[b] = sched.lowest_p2(min(worst, n_cols) + 1)
        term_ok = np.all(bt >= np.minimum(need, table_size))
        vcs.append(_vc("probe-termination", term_ok,
                       f"{pre}bin_tsize >= p2(min(max bin flop, n)+1)"))

    # output indptr is monotone and lands exactly on nnz_c <= cap_c
    nnz_c = int(indptr_c[-1])
    cap_ok = (indptr_c[0] == 0 and np.all(np.diff(indptr_c) >= 0)
              and nnz_c <= cap_c)
    vcs.append(_vc("store-capacity", cap_ok,
                   f"{pre}indptr_c monotone, nnz_c={nnz_c} <= cap_c={cap_c}"))

    # flushes fit: each row's exact count leaves a free probe slot
    row_nnz = np.diff(indptr_c.astype(np.int64))
    flush_ok = True
    if part_ok:
        for b in range(len(bin_tsize)):
            rows = row_nnz[int(offsets[b]):int(offsets[b + 1])]
            if rows.size and int(rows.max()) > int(bt[b]) - 1:
                flush_ok = False
    vcs.append(_vc("flush-bound", flush_ok,
                   f"{pre}row_nnz_c[i] <= bin_tsize[bin(i)] - 1"))
    return vcs


def _check_spgemm_vcs(plan) -> List[VC]:
    vcs: List[VC] = []
    m, n = plan.shape_a[0], plan.shape_b[1]
    flop = np.asarray(plan.flop).astype(np.int64)[:m]

    # i32 admissibility, recomputed in exact Python ints the way
    # schedule.guard_i32_flop admits it (bin targets scale by n_bins-1)
    total = int(flop.sum())
    scaled = total * max(plan.n_bins - 1, 1)
    vcs.append(_vc("i32-flop", total == int(plan.total_flop)
                   and scaled <= _I32_MAX,
                   f"total_flop={total}, x(n_bins-1)={scaled} <= 2^31-1"))
    vcs.append(_vc("expansion-capacity", int(plan.flop_cap) >= total,
                   f"flop_cap={plan.flop_cap} >= total_flop={total}"))

    row_nnz = np.asarray(plan.row_nnz_c).astype(np.int64)
    vcs.append(_vc("row-capacity",
                   int(plan.row_cap) >= (int(row_nnz.max()) if m else 0),
                   f"row_cap={plan.row_cap} >= max row_nnz_c"))
    vcs.append(_vc("nnz-consistent",
                   int(np.asarray(plan.indptr_c)[-1]) == int(plan.nnz_c)
                   and int(plan.nnz_c) <= int(plan.cap_c),
                   f"nnz_c={plan.nnz_c} <= cap_c={plan.cap_c}"))

    if plan.offsets is not None and plan.bin_tsize is not None:
        vcs += _check_hash_schedule(
            plan.offsets, plan.bin_tsize, plan.indptr_c, n_rows=m,
            n_cols=n, cap_c=int(plan.cap_c), table_size=int(plan.table_size),
            flop=flop)
    return vcs


def _check_bcsr_vcs(plan) -> List[VC]:
    """Block-granularity VCs for one frozen :class:`BCSRPlan`: the hash
    schedule invariants hold verbatim over the *block* grid (block rows
    are the rows, block columns of B the hash keys), plus the block-shape
    compatibility and i32 admissibility the planner promised."""
    vcs: List[VC] = []
    gm = -(-plan.shape_a[0] // plan.block_a[0])
    gn_b = -(-plan.shape_b[1] // plan.block_b[1])
    flop = np.asarray(plan.flop).astype(np.int64)[:gm]

    vcs.append(_vc("block-compatible",
                   plan.block_a[1] == plan.block_b[0],
                   f"A tile inner {plan.block_a[1]} == B tile outer "
                   f"{plan.block_b[0]}"))

    total = int(flop.sum())
    scaled = total * max(plan.n_bins - 1, 1)
    vcs.append(_vc("i32-flop", total == int(plan.total_flop)
                   and scaled <= _I32_MAX,
                   f"block total_flop={total}, x(n_bins-1)={scaled} "
                   "<= 2^31-1"))
    vcs.append(_vc("nnz-consistent",
                   int(np.asarray(plan.indptr_cb)[-1]) == int(plan.nnzb_c)
                   and int(plan.nnzb_c) <= int(plan.bcap_c),
                   f"nnzb_c={plan.nnzb_c} <= bcap_c={plan.bcap_c}"))

    vcs += _check_hash_schedule(
        plan.offsets, plan.bin_tsize, plan.indptr_cb, n_rows=gm,
        n_cols=gn_b, cap_c=int(plan.bcap_c),
        table_size=int(plan.table_size), flop=flop)
    return vcs


def _check_pb_vcs(plan) -> List[VC]:
    """Propagation-blocking VCs for one frozen :class:`PBPlan`: the
    bucket layout covers the output columns, every bucket's packed
    products fit its static capacity, all frozen gather/segment indices
    are in-bounds, and -- the PB race-freedom invariant -- every live
    product's output column lands inside its own bucket's column range,
    so buckets write disjoint output slots and merge independently."""
    vcs: List[VC] = []
    n = plan.shape_b[1]
    nb, bw = int(plan.n_buckets), int(plan.bucket_w)
    bucket_nnz = np.asarray(plan.bucket_nnz).astype(np.int64)
    src_a = np.asarray(plan.src_a)
    src_b = np.asarray(plan.src_b)
    seg = np.asarray(plan.seg)
    indptr_c = np.asarray(plan.indptr_c).astype(np.int64)
    cols_c = np.asarray(plan.cols_c).astype(np.int64)

    vcs.append(_vc("bucket-cover",
                   bw >= 1 and (bw & (bw - 1)) == 0 and nb * bw >= n,
                   f"{nb} buckets x p2 width {bw} cover {n} columns"))

    total = int(bucket_nnz.sum())
    vcs.append(_vc("i32-flop", total == int(plan.total_flop)
                   and total <= _I32_MAX,
                   f"sum(bucket_nnz)={total} == total_flop, <= 2^31-1"))
    vcs.append(_vc("bucket-capacity",
                   int(bucket_nnz.max(initial=0)) <= int(plan.bucket_cap),
                   f"max bucket_nnz <= bucket_cap={plan.bucket_cap}"))

    lane = np.arange(src_a.shape[-1])
    live = lane[None, :] < bucket_nnz[:, None]
    src_ok = (np.all((src_a >= 0) & (src_a < plan.cap_a) | ~live)
              and np.all((src_b >= 0) & (src_b < plan.cap_b) | ~live))
    vcs.append(_vc("gather-bounds", src_ok,
                   f"live src_a < cap_a={plan.cap_a}, "
                   f"src_b < cap_b={plan.cap_b}"))
    seg_ok = np.all((seg >= 0) & (seg < max(int(plan.cap_c), 1)) | ~live)
    vcs.append(_vc("segment-bounds", seg_ok,
                   f"live seg < cap_c={plan.cap_c}"))

    # race freedom: a live product in bucket g merges into an output slot
    # whose column is in [g*bw, (g+1)*bw)
    g = np.arange(nb)[:, None]
    col_of = cols_c[np.clip(seg, 0, max(int(plan.cap_c) - 1, 0))]
    disjoint = np.all((col_of // bw == g) | ~live)
    vcs.append(_vc("bucket-disjoint", disjoint,
                   "every live product's output column lies in its own "
                   "bucket's range (buckets write disjoint slots)"))

    nnz_c = int(indptr_c[-1])
    vcs.append(_vc("store-capacity",
                   indptr_c[0] == 0 and np.all(np.diff(indptr_c) >= 0)
                   and nnz_c == int(plan.nnz_c)
                   and nnz_c <= int(plan.cap_c),
                   f"indptr_c monotone, nnz_c={nnz_c} <= "
                   f"cap_c={plan.cap_c}"))
    return vcs


def _check_stacked_hash_vcs(hash_sched, *, n_rows: int, n_cols: int,
                            cap_c: int, table_size: int,
                            label: str) -> List[VC]:
    """Structural hash VCs over a stacked ``(..., n_bins+1/n_bins/m+1)``
    schedule (batch classes, distributed shards, SUMMA panels)."""
    offsets, bin_tsize, indptr_c = (np.asarray(x) for x in hash_sched)
    lead = offsets.shape[:-1]
    offsets = offsets.reshape(-1, offsets.shape[-1])
    bin_tsize = bin_tsize.reshape(-1, bin_tsize.shape[-1])
    indptr_c = indptr_c.reshape(-1, indptr_c.shape[-1])
    merged: Dict[str, VC] = {}
    for i in range(offsets.shape[0]):
        for vc in _check_hash_schedule(
                offsets[i], bin_tsize[i], indptr_c[i], n_rows=n_rows,
                n_cols=n_cols, cap_c=cap_c, table_size=table_size,
                exact_cover=False, label=f"{label}[{i}/{lead}]"):
            prev = merged.get(vc.name)
            if prev is None or (prev.ok and not vc.ok):
                merged[vc.name] = vc
    return list(merged.values())


def check_plan_vcs(plan) -> List[VC]:
    """Concrete verification conditions for any plan kind (dispatches on
    the plan's type; container plans recurse into their members)."""
    from repro.core.batch import BatchedPlan
    from repro.core.bcsr import BCSRPlan
    from repro.core.chain import ChainPlan, GramPlan
    from repro.core.distributed import DistributedPlan, SummaPlan
    from repro.core.pb import PBPlan
    from repro.core.plan import SpGEMMPlan

    if isinstance(plan, BCSRPlan):
        return _check_bcsr_vcs(plan)

    if isinstance(plan, PBPlan):
        return _check_pb_vcs(plan)

    if isinstance(plan, SpGEMMPlan):
        vcs = _check_spgemm_vcs(plan)
        if plan.bcsr_plan is not None:
            # bcsr-routed CSR plan: the nested block plan's VCs gate too
            vcs += [VC(f"bcsr.{vc.name}", vc.ok, vc.detail)
                    for vc in _check_bcsr_vcs(plan.bcsr_plan)]
        if plan.pb_plan is not None:
            # pb-routed CSR plan: the nested PB plan's VCs gate too
            vcs += [VC(f"pb.{vc.name}", vc.ok, vc.detail)
                    for vc in _check_pb_vcs(plan.pb_plan)]
        return vcs

    if isinstance(plan, ChainPlan):
        vcs: List[VC] = []
        for k, stage in enumerate(plan.stages):
            for vc in _check_spgemm_vcs(stage):
                vcs.append(VC(f"stage{k}.{vc.name}", vc.ok, vc.detail))
        return vcs

    if isinstance(plan, GramPlan):
        return [VC(f"gram.{vc.name}", vc.ok, vc.detail)
                for vc in _check_spgemm_vcs(plan.product)]

    if isinstance(plan, BatchedPlan):
        vcs = []
        for ci, cls in enumerate(plan.classes):
            members = [i for i in range(plan.n_products)
                       if plan.class_of[i] == ci]
            nnz_ok = all(plan.nnz_cs[i] <= cls.cap_c for i in members)
            vcs.append(_vc(f"class{ci}.member-capacity", nnz_ok,
                           f"every member nnz_c <= class cap_c={cls.cap_c}"))
            if cls.hash_sched is not None:
                for vc in _check_stacked_hash_vcs(
                        cls.hash_sched, n_rows=cls.shape_a[0],
                        n_cols=cls.shape_b[1], cap_c=int(cls.cap_c),
                        table_size=int(cls.table_size),
                        label=f"class{ci}"):
                    vcs.append(VC(f"class{ci}.{vc.name}", vc.ok, vc.detail))
        return vcs

    if isinstance(plan, DistributedPlan):
        vcs = []
        uniform_ok = all(
            int(p.cap_c) <= int(plan.cap_c)
            and int(p.table_size) <= int(plan.table_size)
            for p in plan.plans)
        vcs.append(_vc("uniform-statics", uniform_ok,
                       "per-shard exact capacities fit the uniform "
                       "SPMD allocation"))
        for s, p in enumerate(plan.plans):
            for vc in _check_spgemm_vcs(p):
                vcs.append(VC(f"shard{s}.{vc.name}", vc.ok, vc.detail))
        if plan.hash_sched is not None:
            rows = max(p.shape_a[0] for p in plan.plans)
            vcs += _check_stacked_hash_vcs(
                plan.hash_sched, n_rows=rows,
                n_cols=plan.shape_b[1], cap_c=int(plan.cap_c),
                table_size=int(plan.table_size), label="shard")
        return vcs

    if isinstance(plan, SummaPlan):
        vcs = []
        uniform_ok = all(
            int(p.cap_c) <= int(plan.cap_c)
            and int(p.table_size) <= int(plan.table_size)
            for p in plan.plans)
        vcs.append(_vc("uniform-statics", uniform_ok,
                       "per-panel exact capacities fit the uniform "
                       "SPMD allocation"))
        bounds_ok = all(0 <= lo <= hi <= plan.shape_a[1]
                        for lo, hi in plan.bounds)
        vcs.append(_vc("panel-bounds", bounds_ok,
                       "k-panel boundaries within [0, K]"))
        for s, p in enumerate(plan.plans):
            for vc in _check_spgemm_vcs(p):
                vcs.append(VC(f"panel{s}.{vc.name}", vc.ok, vc.detail))
        if plan.hash_sched is not None:
            vcs += _check_stacked_hash_vcs(
                plan.hash_sched, n_rows=plan.shape_a[0],
                n_cols=plan.shape_b[1], cap_c=int(plan.cap_c),
                table_size=int(plan.table_size), label="panel")
        return vcs

    raise TypeError(f"no verification conditions for {type(plan).__name__}")


# ---------------------------------------------------------------------------
# trace harnesses + seeding
# ---------------------------------------------------------------------------

def _csr_args(c: CSR) -> Tuple[Any, ...]:
    return (c.indptr, c.indices, c.data, c.nnz)


def _csr_seeds(c: CSR) -> List[Ival]:
    """Admitted input intervals for one CSR operand: the structure
    contract every caller promises (indptr/nnz within the static
    capacity, column ids within the operand's width)."""
    n = c.shape[1]
    return [Ival(0, int(c.cap)), Ival(0, max(int(n) - 1, 0)), TOP,
            Ival(0, int(c.cap))]


def _rebuild(c: CSR, parts) -> CSR:
    ip, ix, dat, nnz = parts
    return dataclasses.replace(c, indptr=ip, indices=ix, data=dat, nnz=nnz)


def _bcsr_args(x) -> Tuple[Any, ...]:
    return (x.indptr, x.indices, x.blocks, x.nnzb)


def _bcsr_seeds(x) -> List[Ival]:
    """Admitted input intervals for one BCSR operand: indptr/nnzb within
    the static block capacity, block-column ids within the block grid."""
    gn = -(-x.shape[1] // x.block[1])
    return [Ival(0, int(x.bcap)), Ival(0, max(gn - 1, 0)), TOP,
            Ival(0, int(x.bcap))]


def _rebuild_bcsr(x, parts):
    ip, ix, blk, nnzb = parts
    return dataclasses.replace(x, indptr=ip, indices=ix, blocks=blk,
                               nnzb=nnzb)


def _dyadic_dense(m: int, n: int, density: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    vals = rng.choice(np.array([0.5, 1.0, 1.5, 2.0], np.float32),
                      size=(m, n))
    return np.where(rng.random((m, n)) < density, vals, 0.0
                    ).astype(np.float32)


def _block_dyadic(gm: int, gn: int, bm: int, bn: int, density: float,
                  seed: int) -> np.ndarray:
    """Block-clustered dyadic dense fixture: a ``gm x gn`` occupancy grid
    of fully dense ``bm x bn`` tiles with values from {0.5, 1, 1.5, 2}
    (exactly representable, so kernel-vs-oracle comparisons are bitwise)."""
    rng = np.random.default_rng(seed)
    occ = (rng.random((gm, gn)) < density).astype(np.float32)
    vals = rng.choice(np.array([0.5, 1.0, 1.5, 2.0], np.float32),
                      size=(gm * bm, gn * bn))
    return np.kron(occ, np.ones((bm, bn), np.float32)) * vals


def _csr_of(d: np.ndarray, cap: Optional[int] = None) -> CSR:
    r, c = np.nonzero(d)
    return CSR.from_numpy_coo(r, c, d[r, c], d.shape, cap=cap)


def _analyze_traced(trace_fn: Callable, flat_args: Sequence[Any],
                    seeds: Sequence[Ival],
                    discharges: Dict[str, bool]) -> JaxprAnalyzer:
    closed = jax.make_jaxpr(trace_fn)(*flat_args)
    analyzer = JaxprAnalyzer(discharges=discharges)
    analyzer.analyze(closed, list(seeds))
    return analyzer


def _flush_discharge(vcs: Sequence[VC]) -> Dict[str, bool]:
    """The hash flush cursor's discharge is granted only when the
    concrete store-capacity + flush-bound VCs actually passed."""
    need = {"store-capacity", "flush-bound"}
    got = {vc.name.split(".")[-1] for vc in vcs if vc.ok}
    return {"flush-capacity": need <= got}


# primitive budgets: what a *numeric-only* execute may stage ------------

#: inspection primitives that must never appear in *any* execute jaxpr:
#: planning inspects structure once (host-side sort/unique/nonzero live
#: there); an execute staging one is re-inspection by definition
_FORBIDDEN = {"unique": 0, "nonzero": 0, "argwhere": 0}


def _algo_budget(algorithm: str, general: bool,
                 sorted_output: bool) -> Dict[str, int]:
    if algorithm in ("hash", "hash_vector") and not general:
        return {"pallas_call": 1, "sort": 1 if sorted_output else 0,
                "dot_general": 0, **_FORBIDDEN}
    if algorithm == "heap":
        return {"pallas_call": 0, "sort": 0, "dot_general": 0, **_FORBIDDEN}
    # esc / hash_jnp / any general-semiring or masked fallback: one
    # numeric expansion sort (the output comes out sorted, so the
    # epilogue never adds another)
    return {"pallas_call": 0, "sort": 1, "dot_general": 0, **_FORBIDDEN}


def _budget_check(expected: Dict[str, int],
                  counts: Dict[str, int]) -> Dict[str, Any]:
    got = {k: int(counts.get(k, 0)) for k in expected}
    return {"expected": expected, "got": got, "ok": got == expected}


def _case(kind: str, name: str, algorithm: str, vcs: List[VC],
          analyzer: JaxprAnalyzer,
          expected: Dict[str, int]) -> CaseReport:
    from collections import Counter
    site_counts = dict(Counter(s.status for s in analyzer.sites))
    census = {k: int(v) for k, v in analyzer.counts.items()
              if k in ("pallas_call", "sort", "dot_general", "scatter",
                       "scatter-add", "gather", "dynamic_slice", "while",
                       "scan", "cumsum", "i32-sum-proved",
                       "i32-sum-unbounded", "custom_vmap_call")}
    def site_dict(s):
        return {"kind": s.kind, "path": s.path, "detail": s.detail,
                "status": s.status, "index": s.index, "bound": s.bound}
    return CaseReport(
        kind=kind, name=name, algorithm=algorithm, vcs=vcs,
        site_counts=site_counts, census=census,
        budget=_budget_check(expected, analyzer.counts),
        violations=[site_dict(s) for s in analyzer.sites
                    if s.status == VIOLATION],
        warnings=[site_dict(s) for s in analyzer.sites
                  if s.status == UNPROVED_READ])


# ---------------------------------------------------------------------------
# per-kind verifiers
# ---------------------------------------------------------------------------

def verify_spgemm(plan, a: CSR, b: CSR, name: str = "") -> CaseReport:
    """Prove one frozen :class:`SpGEMMPlan` against its executor jaxpr."""
    vcs = check_plan_vcs(plan)

    def trace(ai, aj, ax, an, bi, bj, bx, bn, _plan=plan):
        return _plan.execute(_rebuild(a, (ai, aj, ax, an)),
                             _rebuild(b, (bi, bj, bx, bn)))

    analyzer = _analyze_traced(trace, _csr_args(a) + _csr_args(b),
                               _csr_seeds(a) + _csr_seeds(b),
                               _flush_discharge(vcs))
    sr_general = plan.semiring != "plus_times" or plan.mask is not None
    expected = _algo_budget(plan.algorithm, sr_general, plan.sorted_output)
    return _case("spgemm", name or f"spgemm/{plan.algorithm}",
                 plan.algorithm, vcs, analyzer, expected)


def verify_bcsr(plan, a, b, name: str = "") -> CaseReport:
    """Prove one frozen :class:`repro.core.bcsr.BCSRPlan` against its
    executor jaxpr.  The budget pins the register-tiled story: exactly
    one numeric Pallas call (a second would be the block symbolic kernel
    re-inspecting), zero ``sort`` (block rows come out hash-ordered by
    contract), and exactly one ``dot_general`` -- the MXU tile MAC inside
    the kernel body, the only dense product a planned block execute may
    stage."""
    vcs = check_plan_vcs(plan)

    def trace(ai, aj, ax, an, bi, bj, bx, bn, _plan=plan):
        return _plan.execute(_rebuild_bcsr(a, (ai, aj, ax, an)),
                             _rebuild_bcsr(b, (bi, bj, bx, bn)))

    analyzer = _analyze_traced(trace, _bcsr_args(a) + _bcsr_args(b),
                               _bcsr_seeds(a) + _bcsr_seeds(b),
                               _flush_discharge(vcs))
    expected = {"pallas_call": 1, "sort": 0, "dot_general": 1, **_FORBIDDEN}
    return _case("bcsr", name or "bcsr/planned", "bcsr", vcs, analyzer,
                 expected)


def verify_pb(plan, a: CSR, b: CSR, name: str = "") -> CaseReport:
    """Prove one frozen :class:`repro.core.pb.PBPlan` against its
    executor jaxpr.  The budget pins the propagation-blocking story:
    exactly two numeric Pallas calls on the plus_times fast path -- the
    column-bucket scatter and the per-bucket merge, kept separate so the
    mesh path can insert an ``all_to_all`` between them -- zero ``sort``
    (the output order was frozen at plan time), and zero ``dot_general``.
    A general-semiring plan runs the jnp twin: zero Pallas calls, still
    sort-free (the segment reduction is scatter-based)."""
    vcs = check_plan_vcs(plan)

    def trace(ai, aj, ax, an, bi, bj, bx, bn, _plan=plan):
        return _plan.execute(_rebuild(a, (ai, aj, ax, an)),
                             _rebuild(b, (bi, bj, bx, bn)))

    analyzer = _analyze_traced(trace, _csr_args(a) + _csr_args(b),
                               _csr_seeds(a) + _csr_seeds(b),
                               _flush_discharge(vcs))
    n_pallas = 2 if plan.semiring == "plus_times" else 0
    expected = {"pallas_call": n_pallas, "sort": 0, "dot_general": 0,
                **_FORBIDDEN}
    return _case("pb", name or "pb/planned", "pb", vcs, analyzer, expected)


def verify_batch(plan, pairs: Sequence[Tuple[CSR, CSR]],
                 name: str = "") -> CaseReport:
    """Prove one :class:`BatchedPlan` against its class programs."""
    vcs = check_plan_vcs(plan)
    flat_args: List[Any] = []
    seeds: List[Ival] = []
    for a, b in pairs:
        flat_args += _csr_args(a) + _csr_args(b)
        seeds += _csr_seeds(a) + _csr_seeds(b)

    def trace(*flat, _plan=plan):
        rebuilt = []
        it = iter(range(0, len(flat), 8))
        for (a, b), off in zip(pairs, it):
            rebuilt.append((_rebuild(a, flat[off:off + 4]),
                            _rebuild(b, flat[off + 4:off + 8])))
        return _plan.execute(rebuilt)

    analyzer = _analyze_traced(trace, flat_args, seeds,
                               _flush_discharge(vcs))
    expected = {"pallas_call": 0, "sort": 0, "dot_general": 0, **_FORBIDDEN}
    general = plan.semiring != "plus_times"
    for cls in plan.classes:
        for k, v in _algo_budget(cls.algorithm,
                                 general or cls.mask_parts is not None,
                                 plan.sorted_output).items():
            expected[k] = expected.get(k, 0) + v
    algos = ",".join(sorted({c.algorithm for c in plan.classes}))
    return _case("batch", name or f"batch/{algos}", algos, vcs,
                 analyzer, expected)


def verify_dist_1d(plan, a_sh, b: CSR, name: str = "") -> CaseReport:
    """Prove one :class:`DistributedPlan` via its mesh-free executor twin
    (``execute_shards_host`` runs the exact shard_map body per shard, so
    the traced jaxpr contains every shard's local product)."""
    vcs = check_plan_vcs(plan)
    n_shards = len(plan.plans)

    def trace(ai, aj, ax, an, bi, bj, bx, bn, _plan=plan):
        parts = _rebuild(a_sh.parts, (ai, aj, ax, an))
        a2 = dataclasses.replace(a_sh, parts=parts)
        return _plan.execute_shards_host(a2, _rebuild(b, (bi, bj, bx, bn)))

    flat_args = _csr_args(a_sh.parts) + _csr_args(b)
    cap_per = int(a_sh.cap_per)
    seeds = [Ival(0, cap_per), Ival(0, max(plan.shape_a[1] - 1, 0)), TOP,
             Ival(0, cap_per)] + _csr_seeds(b)
    analyzer = _analyze_traced(trace, flat_args, seeds,
                               _flush_discharge(vcs))
    sr_general = plan.semiring != "plus_times" or plan.mask_sh is not None
    per_shard = _algo_budget(plan.algorithm, sr_general, plan.sorted_output)
    expected = {k: v * n_shards for k, v in per_shard.items()}
    return _case("dist_1d", name or f"dist_1d/{plan.algorithm}",
                 plan.algorithm, vcs, analyzer, expected)


def verify_summa(plan, mesh, a: CSR, b: CSR, name: str = "") -> CaseReport:
    """Prove one :class:`SummaPlan` through its shard_map executor."""
    vcs = check_plan_vcs(plan)

    def trace(ax, bx, _plan=plan):
        a2 = dataclasses.replace(a, data=ax)
        b2 = dataclasses.replace(b, data=bx)
        return _plan.execute(mesh, a2, b2)

    analyzer = _analyze_traced(trace, (a.data, b.data), [TOP, TOP],
                               _flush_discharge(vcs))
    n_local = len(plan.plans)        # n_shards x panels-per-shard
    per = _algo_budget(plan.algorithm, plan.semiring != "plus_times",
                       False)
    # the panel loop runs per-shard inside one SPMD program: the jaxpr
    # stages panels-per-shard bodies, each shard executing them in SPMD
    per_shard_panels = n_local // plan.n_shards
    expected = {k: v * per_shard_panels for k, v in per.items()}
    # plus exactly one sort: the CSR.from_dense compaction epilogue that
    # re-sparsifies the reduce-scattered dense partial per shard program
    expected["sort"] = expected.get("sort", 0) + 1
    return _case("summa", name or f"summa/{plan.algorithm}",
                 plan.algorithm, vcs, analyzer, expected)


def verify_chain(plan, mats: Sequence[CSR], name: str = "") -> CaseReport:
    """Prove one :class:`ChainPlan` end to end across its stages."""
    vcs = check_plan_vcs(plan)
    flat_args: List[Any] = []
    seeds: List[Ival] = []
    for m in mats:
        flat_args += _csr_args(m)
        seeds += _csr_seeds(m)

    def trace(*flat, _plan=plan):
        rebuilt = [_rebuild(m, flat[off:off + 4])
                   for m, off in zip(mats, range(0, len(flat), 4))]
        return _plan.execute(*rebuilt)

    analyzer = _analyze_traced(trace, flat_args, seeds,
                               _flush_discharge(vcs))
    expected = {"pallas_call": 0, "sort": 0, "dot_general": 0, **_FORBIDDEN}
    last = len(plan.stages) - 1
    for k, stage in enumerate(plan.stages):
        so = plan.sorted_output if k == last else plan.sort_intermediates
        general = stage.semiring != "plus_times" or stage.mask is not None
        for key, v in _algo_budget(stage.algorithm, general, so).items():
            expected[key] = expected.get(key, 0) + v
    algos = ",".join(s.algorithm for s in plan.stages)
    return _case("chain", name or f"chain/{algos}", algos, vcs,
                 analyzer, expected)


# ---------------------------------------------------------------------------
# the --all fixture sweep
# ---------------------------------------------------------------------------

def run_layer1(kinds: Optional[Sequence[str]] = None) -> List[CaseReport]:
    """Trace-and-prove the standard fixture sweep over all plan kinds.

    Fixtures are small, dyadic and seed-pinned; tracing stages but never
    runs kernels, so the sweep is backend-independent and fast.  Returns
    one :class:`CaseReport` per case; the CLI turns them into the gating
    JSON document.
    """
    from repro.core import (plan_batch, plan_bcsr, plan_chain, plan_pb,
                            plan_spgemm, plan_spgemm_1d, plan_spgemm_summa)
    from repro.core.distributed import shard_csr_rows
    from repro.core.formats import BCSR

    kinds = set(kinds or ("spgemm", "batch", "dist_1d", "summa", "chain",
                          "bcsr", "pb"))
    cases: List[CaseReport] = []

    ad = _dyadic_dense(16, 12, 0.3, 0)
    bd = _dyadic_dense(12, 10, 0.35, 1)
    a, b = _csr_of(ad), _csr_of(bd)

    if "spgemm" in kinds:
        for algo in ("hash", "hash_vector", "esc", "heap", "hash_jnp"):
            plan = plan_spgemm(a, b, algorithm=algo)
            cases.append(verify_spgemm(plan, a, b))
        plan = plan_spgemm(a, b, algorithm="hash", sorted_output=True)
        cases.append(verify_spgemm(plan, a, b,
                                   name="spgemm/hash sorted"))

    if "batch" in kinds:
        pairs = [(a, b),
                 (_csr_of(_dyadic_dense(8, 12, 0.4, 2)), b),
                 (_csr_of(_dyadic_dense(5, 6, 0.5, 3)),
                  _csr_of(_dyadic_dense(6, 7, 0.5, 4)))]
        plan = plan_batch(pairs)
        cases.append(verify_batch(plan, pairs))

    if "dist_1d" in kinds:
        a_sh = shard_csr_rows(a, 2)
        plan = plan_spgemm_1d(a_sh, b, algorithm="hash")
        cases.append(verify_dist_1d(plan, a_sh, b))

    if "summa" in kinds:
        sad = _dyadic_dense(8, 8, 0.4, 5)
        sbd = _dyadic_dense(8, 6, 0.4, 6)
        sa, sb = _csr_of(sad), _csr_of(sbd)
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
        plan = plan_spgemm_summa(sa, sb, n_shards=1, k_panels=2)
        cases.append(verify_summa(plan, mesh, sa, sb))

    if "bcsr" in kinds:
        ba = BCSR.from_dense(_block_dyadic(4, 3, 4, 4, 0.6, 8), (4, 4))
        bb2 = BCSR.from_dense(_block_dyadic(3, 4, 4, 8, 0.6, 9), (4, 8))
        plan = plan_bcsr(ba, bb2)
        cases.append(verify_bcsr(plan, ba, bb2))
        # rectangular-tile variant at a different bin count
        ba2 = BCSR.from_dense(_block_dyadic(5, 4, 2, 4, 0.5, 10), (2, 4))
        bb3 = BCSR.from_dense(_block_dyadic(4, 5, 4, 2, 0.5, 11), (4, 2))
        plan = plan_bcsr(ba2, bb3, n_bins=3)
        cases.append(verify_bcsr(plan, ba2, bb3, name="bcsr/rect-tiles"))

    if "pb" in kinds:
        plan = plan_pb(a, b)
        cases.append(verify_pb(plan, a, b))
        # multi-bucket + masked variant: structural pruning at plan time,
        # so the masked product still stages the mask-free Pallas pair
        md = (_dyadic_dense(16, 10, 0.5, 12) > 0).astype(np.float32)
        plan = plan_pb(a, b, mask=_csr_of(md), n_buckets=4)
        cases.append(verify_pb(plan, a, b, name="pb/masked-4buckets"))

    if "chain" in kinds:
        cd = _dyadic_dense(10, 7, 0.4, 7)
        c = _csr_of(cd)
        plan = plan_chain([a, b, c], algorithm="hash")
        cases.append(verify_chain(plan, [a, b, c]))
        plan = plan_chain([a, b, c], algorithm="esc")
        cases.append(verify_chain(plan, [a, b, c], name="chain/esc-all"))

    return cases
