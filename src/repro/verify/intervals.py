"""Interval abstract interpretation over jaxprs, for bounds proofs.

The domain is deliberately coarse -- one ``[lo, hi]`` pair summarizing a
whole array, with an optional exact concrete payload for plan-frozen
constants -- because the properties being proved are coarse: *every*
index an executor can feed a store/slice/probe stays inside the
planned capacity or p2 table size, and *every* bounded int32 sum stays
under ``2**31 - 1``.  Arithmetic on plan constants (offsets, bin table
sizes, output indptr) folds exactly through a small numpy whitelist, so
schedule-derived indices keep tight bounds instead of widening.

The walker descends through nested jaxprs (``pjit``,
``custom_vmap_call``, ``while``/``cond``/``scan``, ``pallas_call``),
models Pallas refs as monotone stores (reads of an input/prefetch ref
return the backing operand's interval; writes to output/scratch refs
join), runs while-loops to a widened fixpoint with condition-based
narrowing (the ``fori_loop`` pattern ``i < hi`` tightens the index
carry), and records a :class:`Site` verdict for every indexed memory
access it meets:

``proved``
    the index interval is inside ``[0, dim)`` (or the static slice is).
``guarded``
    out-of-range lanes are dropped/clamped by construction
    (``FILL_OR_DROP`` scatters, clamped ``dynamic_slice`` starts).
``discharged:<vc>``
    the interval alone is not relational enough (the hash kernel's
    flush cursor ``indptr_c[i] + cnt``), but a named verification
    condition checked concretely against the plan's frozen schedule
    covers it -- see :func:`repro.verify.bounds.check_plan_vcs`.
``unproved-read``
    a ``PROMISE_IN_BOUNDS`` gather whose index interval could not be
    bounded.  Reads cannot corrupt state (XLA clamps them), so this is
    reported as a warning, not a violation.
``violation``
    an unproved, unguarded, undischarged *write* index.
"""
from __future__ import annotations

import dataclasses
import math
from collections import Counter
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

_INF = math.inf
_I32_MAX = 2**31 - 1

# verdict strings, ordered from best to worst
PROVED = "proved"
GUARDED = "guarded"
DISCHARGED = "discharged"       # reported as "discharged:<vc-name>"
UNPROVED_READ = "unproved-read"
VIOLATION = "violation"


class Ival:
    """``[lo, hi]`` over every element of an array (Python numbers, so
    int arithmetic is exact and never wraps), plus an optional exact
    concrete payload for plan-frozen constants."""

    __slots__ = ("lo", "hi", "concrete")

    def __init__(self, lo, hi, concrete: Optional[np.ndarray] = None):
        self.lo, self.hi, self.concrete = lo, hi, concrete

    # -- constructors ---------------------------------------------------
    @staticmethod
    def of_concrete(x) -> "Ival":
        arr = np.asarray(x)
        if arr.size == 0:
            return Ival(0, 0, arr)
        if arr.dtype == bool:
            return Ival(0, 1, arr)
        if not np.issubdtype(arr.dtype, np.number):
            return TOP
        lo, hi = arr.min(), arr.max()
        if np.issubdtype(arr.dtype, np.integer):
            return Ival(int(lo), int(hi), arr)
        if np.isnan(lo) or np.isnan(hi):
            return TOP
        return Ival(float(lo), float(hi), arr)

    # -- lattice --------------------------------------------------------
    @property
    def is_top(self) -> bool:
        return self.lo == -_INF and self.hi == _INF

    def join(self, other: "Ival") -> "Ival":
        return Ival(min(self.lo, other.lo), max(self.hi, other.hi))

    def same_bounds(self, other: "Ival") -> bool:
        return self.lo == other.lo and self.hi == other.hi

    def widen(self, other: "Ival") -> "Ival":
        """Classic interval widening: any bound that moved jumps to inf."""
        lo = self.lo if other.lo >= self.lo else -_INF
        hi = self.hi if other.hi <= self.hi else _INF
        return Ival(lo, hi)

    def within(self, lo, hi) -> bool:
        return self.lo >= lo and self.hi <= hi

    def __repr__(self):
        return f"[{self.lo}, {self.hi}]"


TOP = Ival(-_INF, _INF)
BOOL = Ival(0, 1)


def _is_ival(x) -> bool:
    return isinstance(x, Ival)


class RefState:
    """Abstract state of one Pallas ref: shape, role, stored interval.

    ``role`` is ``prefetch`` / ``in`` / ``out`` / ``scratch``.  Reads
    return ``val``; writes join into it (monotone, so the while-loop
    fixpoint converges).  Input and prefetch refs start at the backing
    operand's interval; output and scratch refs start at TOP (their
    initial contents are unspecified) -- kernels never use those reads
    as indices, only as accumulator values.
    """

    __slots__ = ("shape", "role", "val", "label")

    def __init__(self, shape: Tuple[int, ...], role: str, val: Ival,
                 label: str = ""):
        self.shape, self.role, self.val, self.label = shape, role, val, label

    def __repr__(self):
        return f"Ref<{self.role}{list(self.shape)}>{self.val}"


@dataclasses.dataclass
class Site:
    """One checked memory-access (or overflow-candidate) site."""
    kind: str                 # get / swap / scatter / gather / dynamic_slice / i32-sum
    path: str                 # nesting path, e.g. "pjit/custom_vmap_call/pallas_call/while"
    detail: str
    status: str               # PROVED / GUARDED / "discharged:<vc>" / ...
    index: Optional[Tuple[float, float]] = None
    bound: Optional[int] = None

    @property
    def ok(self) -> bool:
        return self.status != VIOLATION


def _aval_shape(var) -> Tuple[int, ...]:
    aval = var.aval
    inner = getattr(aval, "inner_aval", None)
    if inner is not None:
        aval = inner
    return tuple(getattr(aval, "shape", ()))


def _aval_dtype(var):
    aval = var.aval
    inner = getattr(aval, "inner_aval", None)
    if inner is not None:
        aval = inner
    return getattr(aval, "dtype", None)


def _size(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


# numpy folds for exact propagation of plan-frozen constants; anything
# not listed (or that raises) falls back to interval arithmetic.
_FOLDS = {
    "add": np.add, "sub": np.subtract, "mul": np.multiply,
    "max": np.maximum, "min": np.minimum,
    "neg": np.negative, "abs": np.abs,
    "cumsum": lambda x, **kw: np.cumsum(x, axis=kw.get("axis", 0)),
    "reduce_sum": lambda x, **kw: np.sum(x, axis=tuple(kw["axes"]) or None),
    "reduce_max": lambda x, **kw: np.max(x, axis=tuple(kw["axes"]) or None),
    "reduce_min": lambda x, **kw: np.min(x, axis=tuple(kw["axes"]) or None),
    "squeeze": lambda x, **kw: np.squeeze(x, axis=tuple(kw["dimensions"])),
    "reshape": lambda x, **kw: np.reshape(x, kw["new_sizes"]),
    "convert_element_type": lambda x, **kw: np.asarray(
        x, dtype=kw["new_dtype"]),
    "and": np.bitwise_and, "or": np.bitwise_or, "xor": np.bitwise_xor,
    "rem": np.remainder, "clamp": lambda lo, x, hi: np.clip(x, lo, hi),
}
_FOLD_SIZE_LIMIT = 1 << 20


class JaxprAnalyzer:
    """Walks a (closed) jaxpr with the interval domain, recording
    :class:`Site` verdicts and a primitive census.

    ``discharges`` maps verification-condition names that the caller
    has *already proved concretely* on the plan's frozen schedule (see
    ``bounds.check_plan_vcs``) to True; the only site class that leans
    on one is the hash kernel's output flush (``flush-capacity``).
    """

    def __init__(self, discharges: Optional[Dict[str, bool]] = None):
        self.sites: List[Site] = []
        self.counts: Counter = Counter()
        self.discharges = dict(discharges or {})
        self._grid: List[Tuple[int, ...]] = []   # pallas grid stack
        self._path: List[str] = []
        self._record = True

    # ------------------------------------------------------------------
    def analyze(self, closed_jaxpr, in_ivals: Sequence[Ival]) -> List[Ival]:
        jaxpr = closed_jaxpr.jaxpr
        env: Dict[Any, Any] = {}
        for var, const in zip(jaxpr.constvars, closed_jaxpr.consts):
            env[var] = Ival.of_concrete(np.asarray(const))
        assert len(jaxpr.invars) == len(in_ivals), \
            f"seeded {len(in_ivals)} inputs, jaxpr takes {len(jaxpr.invars)}"
        for var, ival in zip(jaxpr.invars, in_ivals):
            env[var] = ival
        return self._eval_jaxpr(jaxpr, env)

    # ------------------------------------------------------------------
    def _read(self, env, atom) -> Any:
        if hasattr(atom, "val"):              # Literal
            return Ival.of_concrete(np.asarray(atom.val))
        return env.get(atom, TOP)

    def _eval_jaxpr(self, jaxpr, env) -> List[Ival]:
        for eqn in jaxpr.eqns:
            self._eval_eqn(eqn, env)
        return [self._read(env, v) for v in jaxpr.outvars]

    def _path_str(self) -> str:
        return "/".join(self._path) or "<top>"

    def _site(self, kind, detail, status, index=None, bound=None):
        if self._record:
            idx = None if index is None else (index.lo, index.hi)
            self.sites.append(Site(kind, self._path_str(), detail, status,
                                   idx, bound))

    # ------------------------------------------------------------------
    def _eval_eqn(self, eqn, env) -> None:
        prim = eqn.primitive.name
        if self._record:
            self.counts[prim] += 1
        invals = [self._read(env, v) for v in eqn.invars]

        if prim in ("pjit", "closed_call", "core_call", "custom_vmap_call",
                    "custom_jvp_call", "custom_vjp_call",
                    "custom_vjp_call_jaxpr", "remat", "checkpoint",
                    "shard_map"):
            # shard_map descent with full-array operand intervals is
            # sound: every shard's slice interval is contained in them.
            outs = self._descend_call(eqn, invals)
        elif prim == "while":
            outs = self._while(eqn, invals)
        elif prim == "cond":
            outs = self._cond(eqn, invals)
        elif prim == "scan":
            outs = self._scan(eqn, invals)
        elif prim == "pallas_call":
            outs = self._pallas(eqn, invals)
        elif prim in ("get", "masked_load"):
            outs = [self._ref_get(eqn, invals)]
        elif prim in ("swap", "masked_swap"):
            outs = [self._ref_swap(eqn, invals)]
        elif prim == "addupdate":
            self._ref_swap(eqn, invals)
            outs = []
        else:
            outs = [self._transfer(prim, eqn, invals)]

        for var, out in zip(eqn.outvars, list(outs) + [TOP] * 8):
            env[var] = out

        # int32 overflow candidates: any bounded integer sum whose
        # interval escapes i32 is a violation; unbounded ones are censused
        # (the concrete flop-scaling VC covers the schedule quantities).
        # Products are excluded -- the hash kernel's Knuth multiply wraps
        # int32 by design before masking the result into the table.
        if prim in ("add", "cumsum", "reduce_sum"):
            dt = _aval_dtype(eqn.outvars[0]) if eqn.outvars else None
            if dt is not None and np.issubdtype(dt, np.integer) \
                    and np.dtype(dt).itemsize <= 4:
                out = outs[0] if outs else TOP
                if out.hi == _INF or out.lo == -_INF:
                    if self._record:
                        self.counts["i32-sum-unbounded"] += 1
                elif out.hi > _I32_MAX or out.lo < -_I32_MAX - 1:
                    self._site("i32-sum", f"{prim} interval {out} escapes "
                               "int32", VIOLATION, out, _I32_MAX)
                elif self._record:
                    self.counts["i32-sum-proved"] += 1

    # -- generic transfer functions ------------------------------------
    def _transfer(self, prim, eqn, invals) -> Ival:
        # exact fold when every operand is a small concrete constant
        fold = _FOLDS.get(prim)
        if fold is not None and invals and \
                all(_is_ival(v) and v.concrete is not None for v in invals) \
                and all(v.concrete.size <= _FOLD_SIZE_LIMIT for v in invals):
            try:
                return Ival.of_concrete(fold(*[v.concrete for v in invals],
                                             **eqn.params))
            except Exception:
                pass
        a = invals[0] if invals else TOP
        b = invals[1] if len(invals) > 1 else TOP
        if not _is_ival(a):
            a = TOP
        if not _is_ival(b):
            b = TOP

        if prim == "add":
            return Ival(a.lo + b.lo, a.hi + b.hi)
        if prim == "sub":
            return Ival(a.lo - b.hi, a.hi - b.lo)
        if prim == "mul":
            cands = [x * y for x in (a.lo, a.hi) for y in (b.lo, b.hi)
                     if not (math.isinf(x) and y == 0)
                     and not (math.isinf(y) and x == 0)]
            cands = cands or [0]
            return Ival(min(cands), max(cands))
        if prim == "neg":
            return Ival(-a.hi, -a.lo)
        if prim == "max":
            return Ival(max(a.lo, b.lo), max(a.hi, b.hi))
        if prim == "min":
            return Ival(min(a.lo, b.lo), min(a.hi, b.hi))
        if prim == "clamp":      # clamp(lo, x, hi)
            lo, x, hi = invals[0], invals[1], invals[2]
            return Ival(max(x.lo, lo.lo) if lo.lo != -_INF else x.lo,
                        min(x.hi, hi.hi) if hi.hi != _INF else x.hi)
        if prim == "and":
            # x & m  with m >= 0  is in [0, m.hi]; symmetric in operands
            bounds = [v.hi for v in (a, b) if v.lo >= 0]
            if bounds:
                return Ival(0, min(bounds))
            return TOP
        if prim in ("or", "xor"):
            if a.lo >= 0 and b.lo >= 0 and a.hi != _INF and b.hi != _INF:
                m = max(int(a.hi), int(b.hi))
                return Ival(0, (1 << m.bit_length()) - 1)
            return TOP
        if prim == "rem":
            if b.lo > 0 and a.lo >= 0:
                return Ival(0, b.hi - 1)
            return TOP
        if prim == "div":
            if b.lo > 0 and a.lo >= 0 and a.hi != _INF:
                return Ival(a.lo // b.hi if b.hi != _INF else 0,
                            a.hi // b.lo)
            return TOP
        if prim == "iota":
            n = eqn.params["shape"][eqn.params["dimension"]]
            return Ival(0, max(int(n) - 1, 0))
        if prim in ("eq", "ne", "lt", "le", "gt", "ge", "is_finite",
                    "reduce_and", "reduce_or", "not"):
            return BOOL
        if prim == "select_n":
            out = invals[1]
            for v in invals[2:]:
                out = out.join(v)
            return out
        if prim in ("broadcast_in_dim", "reshape", "squeeze", "transpose",
                    "rev", "slice", "copy", "stop_gradient", "sort",
                    "expand_dims", "real", "imag", "reduce_max",
                    "reduce_min", "dynamic_slice", "optimization_barrier",
                    "reduce_precision"):
            # shape/order-preserving on values (dynamic_slice start clamp
            # is checked separately in _eval_eqn's caller via _dyn_slice)
            if prim == "dynamic_slice":
                self._dyn_slice(eqn, invals)
            if prim == "sort":
                # multi-operand sort returns every operand permuted
                return invals[0]
            return a
        if prim == "convert_element_type":
            return a
        if prim == "concatenate":
            out = invals[0]
            for v in invals[1:]:
                out = out.join(v)
            return out
        if prim == "pad":
            return a.join(invals[1])           # payload ∪ padding value
        if prim in ("argmax", "argmin"):
            axes = eqn.params.get("axes", ())
            shape = _aval_shape(eqn.invars[0])
            n = max((int(shape[ax]) for ax in axes), default=_size(shape))
            return Ival(0, max(n - 1, 0))
        if prim == "reduce_sum":
            axes = eqn.params.get("axes", ())
            shape = _aval_shape(eqn.invars[0])
            n = _size([shape[ax] for ax in axes]) if axes else _size(shape)
            return Ival(min(a.lo * n, a.lo), max(a.hi * n, a.hi))
        if prim in ("cumsum", "cumlogsumexp", "cummax", "cummin", "cumprod"):
            shape = _aval_shape(eqn.invars[0])
            ax = eqn.params.get("axis", 0)
            n = int(shape[ax]) if shape else 1
            if prim == "cumsum":
                return Ival(min(a.lo * n, a.lo), max(a.hi * n, a.hi))
            return a
        if prim == "gather":
            self._gather(eqn, invals)
            mode = str(eqn.params.get("mode", ""))
            if "FILL" in mode:
                return a.join(Ival(0, 0))      # OOB lanes read the fill
            return a
        if prim in ("scatter", "scatter-add", "scatter-max", "scatter-min",
                    "scatter_add", "scatter-mul"):
            return self._scatter(prim, eqn, invals)
        if prim == "dynamic_update_slice":
            self._dus(eqn, invals)
            return a.join(invals[1])
        if prim == "program_id":
            axis = eqn.params.get("axis", 0)
            grid = self._grid[-1] if self._grid else ()
            n = int(grid[axis]) if axis < len(grid) else 0
            return Ival(0, max(n - 1, 0))
        if prim == "num_programs":
            return Ival(1, _INF)
        if prim in ("sign",):
            return Ival(-1, 1)
        if prim == "square" or (prim == "integer_pow"
                                and eqn.params.get("y") == 2):
            cands = [a.lo * a.lo, a.hi * a.hi]
            lo = 0 if a.lo <= 0 <= a.hi else min(cands)
            return Ival(lo, max(cands))
        # unknown primitive: descend into any nested jaxpr conservatively,
        # return TOP
        self._descend_unknown(eqn)
        return TOP

    # -- indexed-access checks -----------------------------------------
    def _check_index(self, kind, ival: Ival, dim: int, what: str,
                     write: bool, mode: str = "") -> None:
        if _is_ival(ival) and ival.within(0, dim - 1):
            self._site(kind, what, PROVED, ival, dim)
            return
        if "FILL" in mode or "DROP" in mode or "CLIP" in mode:
            self._site(kind, what, GUARDED, ival, dim)
            return
        if not write:
            self._site(kind, f"{what} (clamped read)", UNPROVED_READ,
                       ival, dim)
            return
        # unproved write: a named VC can discharge the hash flush cursor
        vc = "flush-capacity"
        if self.discharges.get(vc):
            self._site(kind, what, f"{DISCHARGED}:{vc}", ival, dim)
            return
        self._site(kind, what, VIOLATION, ival, dim)

    def _gather(self, eqn, invals) -> None:
        mode = str(eqn.params.get("mode", ""))
        dnums = eqn.params["dimension_numbers"]
        src_shape = _aval_shape(eqn.invars[0])
        idx = invals[1]
        dims = [int(src_shape[d]) for d in dnums.start_index_map] or [1]
        self._check_index("gather", idx, min(dims),
                          f"gather into shape {list(src_shape)}",
                          write=False, mode=mode)

    def _scatter(self, prim, eqn, invals) -> Ival:
        mode = str(eqn.params.get("mode", ""))
        dnums = eqn.params["dimension_numbers"]
        dst_shape = _aval_shape(eqn.invars[0])
        idx = invals[1]
        dims = [int(dst_shape[d])
                for d in dnums.scatter_dims_to_operand_dims] or [1]
        self._check_index("scatter", idx, min(dims),
                          f"{prim} into shape {list(dst_shape)}",
                          write=True, mode=mode)
        return invals[0].join(invals[2]) if prim != "scatter-add" else \
            Ival(invals[0].lo + min(invals[2].lo, 0) * 4,
                 invals[0].hi + max(invals[2].hi, 0) *
                 max(_size(_aval_shape(eqn.invars[1])), 1)) \
            if invals[0].hi != _INF and invals[2].hi != _INF else TOP

    def _dyn_slice(self, eqn, invals) -> None:
        shape = _aval_shape(eqn.invars[0])
        sizes = eqn.params["slice_sizes"]
        for ax, start in enumerate(invals[1:1 + len(shape)]):
            dim, sz = int(shape[ax]), int(sizes[ax])
            limit = dim - sz
            if _is_ival(start) and start.within(0, limit):
                self._site("dynamic_slice", f"axis {ax} of {list(shape)}",
                           PROVED, start, dim)
            else:
                # XLA clamps dynamic_slice starts into range by definition
                self._site("dynamic_slice", f"axis {ax} of {list(shape)}",
                           GUARDED, start, dim)

    def _dus(self, eqn, invals) -> None:
        shape = _aval_shape(eqn.invars[0])
        upd = _aval_shape(eqn.invars[1])
        for ax, start in enumerate(invals[2:2 + len(shape)]):
            dim, sz = int(shape[ax]), int(upd[ax])
            if _is_ival(start) and start.within(0, dim - sz):
                self._site("dynamic_update_slice",
                           f"axis {ax} of {list(shape)}", PROVED, start, dim)
            else:        # clamped like dynamic_slice
                self._site("dynamic_update_slice",
                           f"axis {ax} of {list(shape)}", GUARDED, start, dim)

    # -- Pallas refs ----------------------------------------------------
    def _indexer_dims(self, eqn, invals) -> Optional[List[Tuple[Any, int]]]:
        """Pairs of (index abstract value | static Slice, dim size) per
        indexed axis, from the state primitive's NDIndexer tree."""
        ref_shape = _aval_shape(eqn.invars[0])
        tree = eqn.params.get("tree")
        if tree is None:
            return None
        n_idx = tree.num_leaves
        # swap carries the stored value after the ref; indices follow.
        idx_vals = invals[len(invals) - n_idx:] if n_idx else []
        try:
            obj = jax.tree_util.tree_unflatten(tree, idx_vals)
        except Exception:
            return None
        indexers = obj if isinstance(obj, (tuple, list)) else (obj,)
        out: List[Tuple[Any, int]] = []
        dims = list(ref_shape)
        for indexer in indexers:
            idx = getattr(indexer, "indices", None)
            if idx is None:
                return None
            for ax, elem in enumerate(idx):
                if ax >= len(dims):
                    return None
                out.append((elem, int(dims[ax])))
        return out

    def _check_ref_access(self, eqn, invals, write: bool) -> None:
        ref = invals[0]
        role = ref.role if isinstance(ref, RefState) else "?"
        pairs = self._indexer_dims(eqn, invals)
        kind = "swap" if write else "get"
        what = f"{role} ref {getattr(ref, 'label', '')}".strip()
        if pairs is None:
            self._site(kind, f"{what}: unrecognized indexer",
                       UNPROVED_READ if not write else VIOLATION)
            return
        for elem, dim in pairs:
            if _is_ival(elem):
                self._check_index(kind, elem, dim, f"{what} dim {dim}",
                                  write=write)
                continue
            # static or dynamic-start Slice
            start = getattr(elem, "start", None)
            size = getattr(elem, "size", None)
            stride = getattr(elem, "stride", 1) or 1
            if start is None:
                continue          # e.g. full-slice sentinel: whole axis
            if _is_ival(start):
                limit = dim - (int(size) - 1) * int(stride) - 1 \
                    if size is not None else dim - 1
                self._check_index(kind, start, max(limit + 1, 0),
                                  f"{what} slice start (dim {dim})",
                                  write=write)
            elif isinstance(start, int):
                last = start + ((int(size) - 1) * int(stride)
                                if size is not None else 0)
                ok = 0 <= start and last < dim
                self._site(kind, f"{what} static slice [{start}:+{size}] "
                           f"of dim {dim}", PROVED if ok else VIOLATION,
                           Ival(start, last), dim)

    def _ref_get(self, eqn, invals) -> Ival:
        self._check_ref_access(eqn, invals, write=False)
        ref = invals[0]
        return ref.val if isinstance(ref, RefState) else TOP

    def _ref_swap(self, eqn, invals) -> Ival:
        self._check_ref_access(eqn, invals, write=True)
        ref = invals[0]
        stored = invals[1] if len(invals) > 1 and _is_ival(invals[1]) else TOP
        if isinstance(ref, RefState):
            old = ref.val
            ref.val = ref.val.join(stored)
            return old
        return TOP

    # -- nested structures ----------------------------------------------
    def _find_callee(self, eqn):
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr", "call"):
            cj = eqn.params.get(key)
            if cj is not None and hasattr(cj, "jaxpr"):
                return cj
            # shard_map stores an *open* Jaxpr (no consts); close it
            if cj is not None and hasattr(cj, "eqns") \
                    and hasattr(cj, "invars") and not getattr(
                        cj, "constvars", True):
                return jax.core.ClosedJaxpr(cj, [])
        for v in eqn.params.values():
            if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                return v
        return None

    def _descend_call(self, eqn, invals) -> List[Ival]:
        cj = self._find_callee(eqn)
        if cj is None or len(cj.jaxpr.invars) != len(invals):
            self._descend_unknown(eqn)
            return [TOP] * len(eqn.outvars)
        self._path.append(eqn.primitive.name)
        try:
            return self.analyze(cj, invals)
        finally:
            self._path.pop()

    def _descend_unknown(self, eqn) -> None:
        """Sound fallback: walk nested jaxprs with TOP inputs."""
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for x in vs:
                cj = x if hasattr(x, "jaxpr") and hasattr(
                    getattr(x, "jaxpr"), "eqns") else None
                if cj is not None:
                    self._path.append(eqn.primitive.name + "?")
                    try:
                        self.analyze(cj, [TOP] * len(cj.jaxpr.invars))
                    finally:
                        self._path.pop()

    # -- control flow ---------------------------------------------------
    def _narrow_by_cond(self, cond_cj, cond_consts: List[Ival],
                        carries: List[Ival]) -> List[Ival]:
        """Tighten carries using the loop condition, for the fori pattern
        ``lt i hi`` (and friends) where ``i`` is a carry."""
        jaxpr = cond_cj.jaxpr
        env: Dict[Any, Any] = {}
        allv = list(cond_consts) + list(carries)
        for var, ival in zip(jaxpr.invars, allv):
            env[var] = ival
        for var, const in zip(jaxpr.constvars, cond_cj.consts):
            env[var] = Ival.of_concrete(np.asarray(const))
        narrowed = list(carries)
        pos = {v: i for i, v in enumerate(jaxpr.invars)}
        n_consts = len(cond_consts)
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in ("lt", "le", "gt", "ge") and len(eqn.invars) == 2:
                a, b = eqn.invars
                av, bv = self._read(env, a), self._read(env, b)
                if name in ("gt", "ge"):       # a > b  ==  b < a
                    a, b, av, bv = b, a, bv, av
                    name = "lt" if name == "gt" else "le"
                ub = bv.hi - (1 if name == "lt" else 0)
                i = pos.get(a, -1) - n_consts
                if 0 <= i < len(narrowed) and ub != _INF:
                    c = narrowed[i]
                    narrowed[i] = Ival(c.lo, min(c.hi, ub))
                lb = av.lo + (1 if name == "lt" else 0)
                j = pos.get(b, -1) - n_consts
                if 0 <= j < len(narrowed) and lb != -_INF:
                    c = narrowed[j]
                    narrowed[j] = Ival(max(c.lo, lb), c.hi)
        return narrowed

    def _while(self, eqn, invals) -> List[Ival]:
        p = eqn.params
        cond_cj, body_cj = p["cond_jaxpr"], p["body_jaxpr"]
        nc, nb = p["cond_nconsts"], p["body_nconsts"]
        cond_consts = invals[:nc]
        body_consts = invals[nc:nc + nb]
        init = [v if _is_ival(v) or isinstance(v, RefState) else TOP
                for v in invals[nc + nb:]]
        carries = list(init)

        def ivals_only(xs):
            return [x if _is_ival(x) else TOP for x in xs]

        record, self._record = self._record, False
        try:
            for it in range(6):
                narrowed = self._narrow_by_cond(
                    cond_cj, ivals_only(cond_consts), ivals_only(carries))
                body_in = [c if isinstance(c, RefState) else n
                           for c, n in zip(carries, narrowed)]
                outs = self.analyze(body_cj, list(body_consts) + body_in)
                new = []
                stable = True
                for c, o in zip(carries, outs):
                    if isinstance(c, RefState):
                        new.append(c)          # refs join in place
                        continue
                    o = o if _is_ival(o) else TOP
                    j = c.join(o)
                    if not j.same_bounds(c):
                        stable = False
                        j = c.widen(j) if it >= 2 else j
                    new.append(j)
                carries = new
                if stable:
                    break
        finally:
            self._record = record

        # final, recorded pass over the body at the stable invariant
        narrowed = self._narrow_by_cond(
            cond_cj, ivals_only(cond_consts), ivals_only(carries))
        body_in = [c if isinstance(c, RefState) else n
                   for c, n in zip(carries, narrowed)]
        self._path.append("while")
        try:
            self.analyze(body_cj, list(body_consts) + body_in)
        finally:
            self._path.pop()
        return carries

    def _cond(self, eqn, invals) -> List[Ival]:
        branches = eqn.params["branches"]
        ops = invals[1:]
        outs: Optional[List[Ival]] = None
        self._path.append("cond")
        try:
            for br in branches:
                res = self.analyze(br, ops)
                res = [r if _is_ival(r) else TOP for r in res]
                outs = res if outs is None else \
                    [a.join(b) for a, b in zip(outs, res)]
        finally:
            self._path.pop()
        return outs or []

    def _scan(self, eqn, invals) -> List[Ival]:
        p = eqn.params
        body = p["jaxpr"]
        n_consts, n_carry = p["num_consts"], p["num_carry"]
        consts = invals[:n_consts]
        carries = [v if _is_ival(v) else TOP
                   for v in invals[n_consts:n_consts + n_carry]]
        xs = [v if _is_ival(v) else TOP for v in invals[n_consts + n_carry:]]
        record, self._record = self._record, False
        try:
            for it in range(6):
                outs = self.analyze(body, list(consts) + carries + xs)
                new_c = []
                stable = True
                for c, o in zip(carries, outs[:n_carry]):
                    o = o if _is_ival(o) else TOP
                    j = c.join(o)
                    if not j.same_bounds(c):
                        stable = False
                        j = c.widen(j) if it >= 2 else j
                    new_c.append(j)
                carries = new_c
                if stable:
                    break
        finally:
            self._record = record
        self._path.append("scan")
        try:
            outs = self.analyze(body, list(consts) + carries + xs)
        finally:
            self._path.pop()
        ys = [o if _is_ival(o) else TOP for o in outs[n_carry:]]
        return carries + ys

    # -- pallas ----------------------------------------------------------
    def _pallas(self, eqn, invals) -> List[Ival]:
        jaxpr = eqn.params["jaxpr"]
        gm = eqn.params.get("grid_mapping")
        grid = tuple(int(g) for g in getattr(gm, "grid", ()) or ())
        n_prefetch = int(getattr(gm, "num_index_operands", 0) or 0)
        n_scratch = int(getattr(gm, "num_scratch_operands", 0) or 0)
        n_out = len(eqn.outvars)
        kern_invars = jaxpr.invars
        n_in = len(kern_invars) - n_prefetch - n_out - n_scratch

        refs: List[RefState] = []
        for i, var in enumerate(kern_invars):
            if i < n_prefetch:
                role, backing = "prefetch", invals[i]
            elif i < n_prefetch + n_in:
                role, backing = "in", invals[i]
            elif i < n_prefetch + n_in + n_out:
                role, backing = "out", TOP
            else:
                role, backing = "scratch", TOP
            backing = backing if _is_ival(backing) else TOP
            refs.append(RefState(_aval_shape(var), role, backing,
                                 label=f"{role}{i}"))

        env: Dict[Any, Any] = {}
        for var, ref in zip(kern_invars, refs):
            env[var] = ref
        for var in jaxpr.constvars:
            env[var] = TOP

        self._grid.append(grid)
        self._path.append("pallas_call")
        try:
            self._eval_jaxpr(jaxpr, env)
        finally:
            self._path.pop()
            self._grid.pop()
        return [TOP] * n_out
