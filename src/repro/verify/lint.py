"""Layer 2: the AST repo-rule linter framework.

Rules (:mod:`repro.verify.rules`) are small ``ast`` visitors over the
repo's own sources, each enforcing one codebase contract that runtime
tests can't see (a densify call that *would* be reachable, a
nondeterministic plan key, a Pallas call with dynamic scratch).  A rule
is a callable ``rule(tree, src, path) -> list[(lineno, message)]``
registered with :func:`rule`; the runner handles file discovery, waiver
comments, and report assembly.

Waivers are per-line source comments::

    acc = acc + c_p.to_dense()   # verify: allow(no-densify) -- dense
                                 # partial accumulator is the SUMMA merge

A waiver on the flagged line (or on the ``def``/``class`` line of the
enclosing scope) suppresses the violation and is listed in the report,
so every exception stays visible and justified at the site.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

RuleFn = Callable[[ast.AST, str, str], List[Tuple[int, str]]]

_RULES: Dict[str, Tuple[str, RuleFn]] = {}

_WAIVER_RE = re.compile(r"#\s*verify:\s*allow\(([a-z0-9_,\- ]+)\)")


def rule(name: str, doc: str) -> Callable[[RuleFn], RuleFn]:
    """Register a named lint rule."""
    def deco(fn: RuleFn) -> RuleFn:
        _RULES[name] = (doc, fn)
        return fn
    return deco


def rule_names() -> List[str]:
    return sorted(_RULES)


def rule_doc(name: str) -> str:
    return _RULES[name][0]


@dataclasses.dataclass
class LintViolation:
    rule: str
    path: str
    line: int
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class Waiver:
    rule: str
    path: str
    line: int
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _waived_lines(src: str) -> Dict[int, set]:
    """Line number -> set of rule names waived on that line."""
    out: Dict[int, set] = {}
    for i, text in enumerate(src.splitlines(), start=1):
        m = _WAIVER_RE.search(text)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",")}
    return out


def _scope_lines(tree: ast.AST) -> List[Tuple[int, int, int]]:
    """(def-line, body-start, body-end) per function/class scope, so a
    waiver on the ``def`` line covers the whole body."""
    spans = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            end = max((getattr(n, "end_lineno", node.lineno)
                       for n in ast.walk(node)), default=node.lineno)
            spans.append((node.lineno, node.lineno, end))
    return spans


def lint_source(src: str, path: str,
                rules: Optional[Sequence[str]] = None
                ) -> Tuple[List[LintViolation], List[Waiver]]:
    """Run the selected rules over one source string."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as exc:
        return [LintViolation("parse", path, exc.lineno or 0,
                              f"syntax error: {exc.msg}")], []
    waived = _waived_lines(src)
    scopes = _scope_lines(tree)
    violations: List[LintViolation] = []
    waivers: List[Waiver] = []
    for name in (rules or rule_names()):
        _, fn = _RULES[name]
        for lineno, message in fn(tree, src, path):
            rule_waived = name in waived.get(lineno, ())
            if not rule_waived:
                for def_line, lo, hi in scopes:
                    if lo <= lineno <= hi and name in waived.get(
                            def_line, ()):
                        rule_waived = True
                        break
            if rule_waived:
                waivers.append(Waiver(name, path, lineno, message))
            else:
                violations.append(LintViolation(name, path, lineno, message))
    return violations, waivers


def lint_paths(paths: Iterable[str],
               rules: Optional[Sequence[str]] = None
               ) -> Tuple[List[LintViolation], List[Waiver], int]:
    """Run rules over files; returns (violations, waivers, n_files)."""
    violations: List[LintViolation] = []
    waivers: List[Waiver] = []
    n = 0
    for p in paths:
        src = Path(p).read_text()
        n += 1
        v, w = lint_source(src, str(p), rules)
        violations += v
        waivers += w
    return violations, waivers, n


def default_paths(root: str = ".") -> List[str]:
    """The repo surfaces each rule owns by default.

    ``src/repro`` is linted in full except ``serve/`` (reserved by the
    ROADMAP serving item -- its contracts land with that subsystem);
    ``benchmarks``/``tests``/``tools`` join for the counter-hygiene
    rule's scan surface.  Seeded-violation fixtures (``_bad_*.py``) are
    excluded everywhere: they exist to be linted *explicitly* by
    ``tests/test_verify.py``.
    """
    rootp = Path(root)
    out: List[str] = []
    for sub in ("src/repro", "benchmarks", "tools", "tests"):
        base = rootp / sub
        if not base.exists():
            continue
        for p in sorted(base.rglob("*.py")):
            rel = p.relative_to(rootp).as_posix()
            if rel.startswith("src/repro/serve/"):
                continue
            if p.name.startswith("_bad_"):
                continue
            out.append(str(p))
    return out


def run_layer2(root: str = ".",
               rules: Optional[Sequence[str]] = None
               ) -> Tuple[List[LintViolation], List[Waiver], int]:
    """Lint the default repo surface; importing rules registers them."""
    from . import rules as _rules  # noqa: F401  (registration side effect)
    return lint_paths(default_paths(root), rules)
