"""Machine-readable report types for both analyzer layers.

The JSON document written by ``python -m repro.verify --json PATH`` (and
uploaded by the CI ``static-analysis`` job) has one top-level dict per
layer; ``ok`` is the gate CI fails on.  Warnings (clamped reads the
interval domain could not bound) are informational and never gate.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

SCHEMA = 1
_MAX_WARNINGS = 25


@dataclasses.dataclass
class VC:
    """One concrete verification condition on a plan's frozen schedule."""
    name: str
    ok: bool
    detail: str = ""


@dataclasses.dataclass
class CaseReport:
    """Layer-1 verdict for one traced executor case."""
    kind: str                    # spgemm / batch / dist_1d / summa / chain
    name: str                    # e.g. "spgemm/hash sorted=False"
    algorithm: str
    vcs: List[VC]
    site_counts: Dict[str, int]
    census: Dict[str, int]
    budget: Dict[str, Any]       # {"expected": {...}, "got": {...}, "ok": bool}
    violations: List[Dict[str, Any]]
    warnings: List[Dict[str, Any]]

    @property
    def ok(self) -> bool:
        return (not self.violations and self.budget.get("ok", False)
                and all(vc.ok for vc in self.vcs))

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind, "name": self.name,
            "algorithm": self.algorithm, "ok": self.ok,
            "vcs": [dataclasses.asdict(vc) for vc in self.vcs],
            "sites": self.site_counts, "census": self.census,
            "budget": self.budget, "violations": self.violations,
            "warnings": self.warnings[:_MAX_WARNINGS],
        }


@dataclasses.dataclass
class Report:
    """Whole-run container: either layer may be absent (``None``)."""
    layer1: Optional[List[CaseReport]] = None
    layer2: Optional[list] = None        # List[LintViolation]
    layer2_files: int = 0
    layer2_waivers: Optional[list] = None

    @property
    def ok(self) -> bool:
        l1 = self.layer1 is None or all(c.ok for c in self.layer1)
        l2 = not self.layer2
        return l1 and l2

    def to_dict(self) -> Dict[str, Any]:
        doc: Dict[str, Any] = {"schema": SCHEMA, "ok": self.ok}
        if self.layer1 is not None:
            doc["layer1"] = layer1_to_dict(self.layer1)
        if self.layer2 is not None:
            doc["layer2"] = layer2_to_dict(
                self.layer2, self.layer2_files, self.layer2_waivers or [])
        return doc

    def to_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, indent=2, sort_keys=True)
            fh.write("\n")


def layer1_to_dict(cases: List[CaseReport]) -> Dict[str, Any]:
    by_kind: Dict[str, List[Dict[str, Any]]] = {}
    for c in cases:
        by_kind.setdefault(c.kind, []).append(c.to_dict())
    return {
        "ok": all(c.ok for c in cases),
        "n_cases": len(cases),
        "kinds": by_kind,
    }


def layer2_to_dict(violations: list, n_files: int,
                   waivers: list) -> Dict[str, Any]:
    return {
        "ok": not violations,
        "n_files": n_files,
        "violations": [v.to_dict() for v in violations],
        "waivers": [w.to_dict() for w in waivers],
    }
