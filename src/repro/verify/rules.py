"""The repo-rule set: one AST visitor per codebase contract.

Every rule here is demonstrated by a seeded violation in
``tests/_bad_kernels.py`` (pinned by ``tests/test_verify.py``), and the
clean run over the live tree gates CI.  Scoping lives *in* the rule --
each knows which part of the repo owns its contract -- so the runner
can hand every rule every file.
"""
from __future__ import annotations

import ast
from typing import List, Tuple

from .lint import rule

Findings = List[Tuple[int, str]]


def _func_root(node: ast.AST):
    """Leftmost name of a (possibly dotted) call target, plus leaf attr."""
    leaf = None
    while isinstance(node, ast.Attribute):
        leaf = leaf or node.attr
        node = node.value
    if isinstance(node, ast.Name):
        return node.id, leaf or node.id
    return None, leaf


def _in_core(path: str) -> bool:
    return "/core/" in path.replace("\\", "/")


def _in_kernels(path: str) -> bool:
    return "/kernels/" in path.replace("\\", "/")


# ---------------------------------------------------------------------------
@rule("no-densify",
      "core/ execute paths must stay sparse: no to_dense()/todense() "
      "calls outside explicitly waived sites (the dense oracle, the "
      "SUMMA partial accumulator)")
def no_densify(tree: ast.AST, src: str, path: str) -> Findings:
    if not _in_core(path):
        return []
    out: Findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("to_dense", "todense"):
            out.append((node.lineno,
                        f"densify call .{node.func.attr}() in core/"))
    return out


# ---------------------------------------------------------------------------
_NONDET_ROOTS = {"time", "random", "uuid", "datetime", "secrets"}
_NONDET_BUILTINS = {"hash", "id"}


@rule("plan-key-determinism",
      "plan keys and cache lookups must be deterministic functions of "
      "structure: no wall-clock, RNG, uuid, or PYTHONHASHSEED-dependent "
      "builtins anywhere in core/")
def plan_key_determinism(tree: ast.AST, src: str, path: str) -> Findings:
    if not _in_core(path):
        return []
    out: Findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        root, leaf = _func_root(node.func)
        if root in _NONDET_ROOTS:
            out.append((node.lineno,
                        f"nondeterministic source {root}.{leaf}() in core/"))
        elif isinstance(node.func, ast.Name) and \
                node.func.id in _NONDET_BUILTINS:
            out.append((node.lineno,
                        f"builtin {node.func.id}() is run-dependent "
                        "(PYTHONHASHSEED / address); use a content digest"))
        elif root in ("np", "numpy") and leaf is not None and \
                isinstance(node.func, ast.Attribute) and \
                isinstance(node.func.value, ast.Attribute) and \
                node.func.value.attr == "random":
            out.append((node.lineno, "np.random.* in core/"))
    return out


# ---------------------------------------------------------------------------
_SCRATCH_TYPES = {"VMEM", "SMEM", "ANY", "SemaphoreType", "MemorySpace"}


@rule("pallas-static-shapes",
      "every pallas_call declares out_shape, a grid (grid= or "
      "grid_spec=), and inline scratch allocations with explicit "
      "pltpu memory spaces and static shapes")
def pallas_static_shapes(tree: ast.AST, src: str, path: str) -> Findings:
    out: Findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        _, leaf = _func_root(node.func)
        if leaf != "pallas_call":
            continue
        kw = {k.arg for k in node.keywords if k.arg}
        if "out_shape" not in kw:
            out.append((node.lineno, "pallas_call without out_shape"))
        if not ({"grid", "grid_spec"} & kw):
            out.append((node.lineno,
                        "pallas_call without grid= or grid_spec="))
        # scratch_shapes may ride on the call or inside its grid spec
        for inner in ast.walk(node):
            if not isinstance(inner, ast.Call):
                continue
            for k in inner.keywords:
                if k.arg != "scratch_shapes":
                    continue
                if not isinstance(k.value, (ast.List, ast.Tuple)):
                    out.append((k.value.lineno,
                                "scratch_shapes must be an inline "
                                "list/tuple of static allocations"))
                    continue
                for elt in k.value.elts:
                    _, sleaf = _func_root(
                        elt.func) if isinstance(elt, ast.Call) else (None,
                                                                     None)
                    if sleaf not in _SCRATCH_TYPES:
                        out.append((elt.lineno,
                                    "scratch allocation without an "
                                    "explicit pltpu memory space"))
    return out


# ---------------------------------------------------------------------------
@rule("counter-reset",
      "KERNEL_CALLS assertions must observe a well-defined window: any "
      "function reading kernel_call_counts() calls reset_kernel_calls() "
      "first (or snapshots a before-value ahead of the dispatch)")
def counter_reset(tree: ast.AST, src: str, path: str) -> Findings:
    out: Findings = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        reads: List[int] = []
        resets: List[int] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                _, leaf = _func_root(node.func)
                if leaf == "kernel_call_counts":
                    reads.append(node.lineno)
                elif leaf == "reset_kernel_calls":
                    resets.append(node.lineno)
        if reads and not resets:
            out.append((min(reads),
                        f"{fn.name}() reads kernel_call_counts() without "
                        "reset_kernel_calls(): the counter window is "
                        "whatever ran before"))
        elif reads and resets and min(resets) > min(reads):
            # a pre-reset read is fine only as a before-snapshot that is
            # actually assigned; a bare expression read is a lost window
            first = min(reads)
            assigned = any(isinstance(node, ast.Assign)
                           and node.lineno == first
                           for node in ast.walk(fn))
            if not assigned:
                out.append((first,
                            f"{fn.name}() reads kernel_call_counts() "
                            "before reset_kernel_calls() without "
                            "snapshotting it"))
    return out


# ---------------------------------------------------------------------------
@rule("frozen-plan-immutability",
      "frozen plan dataclasses are never mutated after construction: "
      "object.__setattr__/setattr escape hatches may only touch "
      "underscore-prefixed memoization slots")
def frozen_plan_immutability(tree: ast.AST, src: str, path: str) -> Findings:
    if "src/repro" not in path.replace("\\", "/"):
        return []
    out: Findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        is_obj_setattr = (isinstance(node.func, ast.Attribute)
                          and node.func.attr == "__setattr__")
        is_setattr = (isinstance(node.func, ast.Name)
                      and node.func.id == "setattr")
        if not (is_obj_setattr or is_setattr):
            continue
        attr_arg = node.args[1] if len(node.args) > 1 else None
        if isinstance(attr_arg, ast.Constant) and \
                isinstance(attr_arg.value, str):
            if not attr_arg.value.startswith("_"):
                out.append((node.lineno,
                            f"setattr of public field "
                            f"{attr_arg.value!r} on a (frozen) object"))
        else:
            out.append((node.lineno,
                        "setattr with a computed attribute name defeats "
                        "the frozen-plan contract"))
    return out


# ---------------------------------------------------------------------------
@rule("no-traced-branch",
      "kernel bodies must not branch Python control flow on values "
      "read from refs (trace-time if/while on traced data); use "
      "lax.cond / pl.when")
def no_traced_branch(tree: ast.AST, src: str, path: str) -> Findings:
    if not _in_kernels(path):
        return []
    out: Findings = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        args = [a.arg for a in (fn.args.posonlyargs + fn.args.args
                                + fn.args.kwonlyargs)]
        if not any(a.endswith("_ref") for a in args):
            continue
        tainted = set()

        def expr_tainted(node: ast.AST) -> bool:
            for sub in ast.walk(node):
                if isinstance(sub, ast.Name) and sub.id in tainted:
                    return True
                if isinstance(sub, ast.Subscript):
                    root, _ = _func_root(sub.value)
                    if root is not None and root.endswith("_ref"):
                        return True
                if isinstance(sub, ast.Call):
                    _, leaf = _func_root(sub.func)
                    if leaf == "load":
                        return True
            return False

        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and expr_tainted(node.value):
                for tgt in node.targets:
                    for sub in ast.walk(tgt):
                        if isinstance(sub, ast.Name):
                            tainted.add(sub.id)
            elif isinstance(node, ast.AugAssign) and \
                    expr_tainted(node.value) and \
                    isinstance(node.target, ast.Name):
                tainted.add(node.target.id)
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)) and \
                    expr_tainted(node.test):
                kind = "if" if isinstance(node, ast.If) else "while"
                out.append((node.lineno,
                            f"Python `{kind}` on a ref-read value in "
                            f"kernel body {fn.name}()"))
    return out


# ---------------------------------------------------------------------------
@rule("dead-import",
      "module-level imports must be used (or re-exported); stale seed "
      "imports hide dead entry points")
def dead_import(tree: ast.AST, src: str, path: str) -> Findings:
    posix = path.replace("\\", "/")
    if posix.endswith("__init__.py"):
        return []          # re-export surface: unused-at-module is the point
    imported: List[Tuple[int, str]] = []
    for node in tree.body if isinstance(tree, ast.Module) else []:
        stmts = [node]
        if isinstance(node, ast.Try):
            stmts = node.body + [s for h in node.handlers for s in h.body]
        if isinstance(node, ast.If):    # TYPE_CHECKING / platform guards
            stmts = node.body + node.orelse
        for stmt in stmts:
            if isinstance(stmt, ast.Import):
                for alias in stmt.names:
                    name = alias.asname or alias.name.split(".")[0]
                    imported.append((stmt.lineno, name))
            elif isinstance(stmt, ast.ImportFrom):
                if stmt.module == "__future__":
                    continue
                for alias in stmt.names:
                    if alias.name == "*":
                        continue
                    name = alias.asname or alias.name
                    imported.append((stmt.lineno, name))
    if not imported:
        return []
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            root, _ = _func_root(node)
            if root:
                used.add(root)
    # names re-exported via __all__ strings count as used
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "__all__" and \
                        isinstance(node.value, (ast.List, ast.Tuple)):
                    for elt in node.value.elts:
                        if isinstance(elt, ast.Constant):
                            used.add(str(elt.value))
    return [(lineno, f"unused module-level import {name!r}")
            for lineno, name in imported if name not in used]
