"""Deliberately-broken code: one seeded violation per layer-2 lint rule.

``tests/test_verify.py`` lints this source under a *pretend* in-tree
path (``src/repro/core/kernels/_bad.py``) so every path-scoped rule is
in scope, and asserts each rule fires exactly on its ``# BAD:`` line.
The file itself is excluded from the CI lint surface
(:func:`repro.verify.lint.default_paths` skips ``_bad_*.py``) and is
never imported -- it only needs to parse.
"""
import os                                          # BAD: dead-import

import jax.numpy as jnp
from jax.experimental import pallas as pl


def densify_in_core(c):
    return c.to_dense() @ c.to_dense().T           # BAD: no-densify


def nondeterministic_plan_key(a):
    import time
    return (hash(a.indices.tobytes()),             # BAD: plan-key-determinism
            time.time())                           # BAD: plan-key-determinism


def undeclared_pallas_call(kernel, m):
    # no out_shape, no grid, anonymous scratch allocation
    return pl.pallas_call(                         # BAD: pallas-static-shapes
        kernel,
        scratch_shapes=[jnp.zeros((m,))],          # BAD: pallas-static-shapes
    )


def unreset_counter_assert(run, kernel_call_counts):
    run()
    counts = kernel_call_counts()                  # BAD: counter-reset
    assert counts["hash"] == 1


def mutate_frozen_plan(plan, cap):
    object.__setattr__(plan, "cap_c", cap)         # BAD: frozen-plan-immutability
    field = "nnz" + "_c"
    object.__setattr__(plan, field, cap)           # BAD: frozen-plan-immutability
    return plan


def traced_branch_kernel(a_ref, o_ref):
    cnt = a_ref[0]
    if cnt > 0:                                    # BAD: no-traced-branch
        o_ref[0] = cnt
    steps = pl.load(a_ref, (pl.dslice(0, 1),))
    while steps[0] > 0:                            # BAD: no-traced-branch
        steps = steps - 1
