"""Reusable structure generators + property-based strategies for the
differential SpGEMM suites.

One home for the CSR/product generators that used to live inline in
``test_differential.py``, so every fuzz layer (single products, batched
fleets, future suites) draws from the same structure space: rectangular
shapes, empty rows/columns, empty matrices, duplicate-free sorted and
*unsorted* CSRs, dyadic values.

The pure-numpy helpers in the first half (``VALS``, :func:`rand_dense`,
:func:`csr_of`, :func:`scramble_rows`) import unconditionally -- the
deterministic grids of ``test_differential.py`` / ``test_batch.py`` /
``test_hash_saturation.py`` share them with no optional dependency.  The
hypothesis *strategies* in the second half exist only when the optional
``hypothesis`` extra is installed; consumers guard exactly like the old
inline layers did::

    try:
        from _fuzz import product_case      # ImportError without the extra
        HAVE_HYPOTHESIS = True
    except ImportError:
        HAVE_HYPOTHESIS = False

Values are drawn from dyadic rationals ({0.5, 1.0, 1.5, 2.0}) so fp32
products and sums are exact and every comparison can be bitwise; they are
also strictly positive, which sidesteps the dense-oracle explicit-zero
caveat documented on ``repro.core.spgemm.spgemm_dense``.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import CSR

#: dyadic, strictly positive: exact fp32 arithmetic, no explicit zeros.
VALS = np.array([0.5, 1.0, 1.5, 2.0], np.float32)

SEMIRINGS = ("plus_times", "boolean", "min_plus", "plus_first")
ALGOS = ("esc", "heap", "hash", "hash_jnp")


def rand_dense(m: int, n: int, density: float, seed: int) -> np.ndarray:
    """Dense dyadic-valued matrix with the given fill fraction."""
    rng = np.random.default_rng(seed)
    d = rng.choice(VALS, size=(m, n))
    return np.where(rng.random((m, n)) < density, d, 0.0).astype(np.float32)


def csr_of(d: np.ndarray, cap: int | None = None) -> CSR:
    """Sorted, duplicate-free CSR of a dense matrix."""
    r, c = np.nonzero(d)
    return CSR.from_numpy_coo(r, c, d[r, c], d.shape, cap=cap)


def scramble_rows(a: CSR) -> CSR:
    """Unsorted twin: reverse each row's entries, flag ``sorted_cols=False``.

    Deterministic (no RNG), duplicate-free by construction, and the dense
    view is unchanged -- the canonical way every suite builds the
    "Table 1 unsorted input" case.
    """
    ip = np.asarray(a.indptr)
    ind = np.asarray(a.indices).copy()
    dat = np.asarray(a.data).copy()
    for i in range(a.n_rows):
        ind[ip[i]:ip[i + 1]] = ind[ip[i]:ip[i + 1]][::-1]
        dat[ip[i]:ip[i + 1]] = dat[ip[i]:ip[i + 1]][::-1]
    return CSR(jnp.asarray(ip), jnp.asarray(ind), jnp.asarray(dat),
               a.nnz, a.shape, sorted_cols=False)


# ---------------------------------------------------------------------------
# Hypothesis strategies (optional extra; absent => the names don't exist
# and `from _fuzz import product_case` raises ImportError, which is the
# guard every consumer already uses)
# ---------------------------------------------------------------------------

try:
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    #: dims drawn from a tiny fixed set so examples share compiled programs.
    DIMS = st.sampled_from((3, 5, 8))
    DENSITIES = st.sampled_from((0.0, 0.2, 0.5, 0.9))

    @st.composite
    def dense_with_structure(draw, m: int, n: int, seed: int) -> np.ndarray:
        """Dense matrix with optionally-forced empty rows/columns."""
        d = rand_dense(m, n, draw(DENSITIES), seed)
        if draw(st.booleans()) and m > 1:      # force some empty rows
            kill = draw(st.sets(st.integers(0, m - 1), max_size=m // 2))
            for i in kill:
                d[i, :] = 0.0
        if draw(st.booleans()) and n > 1:      # force some empty columns
            kill = draw(st.sets(st.integers(0, n - 1), max_size=n // 2))
            for j in kill:
                d[:, j] = 0.0
        return d

    @st.composite
    def csr_case(draw, m: int | None = None, n: int | None = None,
                 allow_unsorted: bool = True):
        """One CSR plus its dense view: ``(a, ad)``.

        Rectangular by default (independent row/col dims), possibly with
        empty rows/cols or fully empty, possibly row-scrambled unsorted.
        """
        m = draw(DIMS) if m is None else m
        n = draw(DIMS) if n is None else n
        seed = draw(st.integers(0, 2**16))
        ad = draw(dense_with_structure(m, n, seed))
        a = csr_of(ad)
        if allow_unsorted and draw(st.booleans()):
            a = scramble_rows(a)
        return a, ad

    @st.composite
    def product_case(draw):
        """One product request: ``(ad, bd, md, complement, semiring, algo)``.

        The single-product differential layer's case shape (dense operands
        + optional dense mask + semantic fields); the consumer builds CSRs
        and compares against its oracle.
        """
        m, k, n = draw(DIMS), draw(DIMS), draw(DIMS)
        seed = draw(st.integers(0, 2**16))
        density = draw(DENSITIES)
        ad = rand_dense(m, k, density, seed)
        bd = rand_dense(k, n, density, seed + 1)
        masked = draw(st.booleans())
        md = rand_dense(m, n, 0.5, seed + 2) if masked else None
        complement = draw(st.booleans()) if masked else False
        semiring = draw(st.sampled_from(SEMIRINGS))
        algo = draw(st.sampled_from(ALGOS))
        return ad, bd, md, complement, semiring, algo

    @st.composite
    def batch_case(draw, min_products: int = 2, max_products: int = 6):
        """A fleet of CSR products for ``spgemm_batch`` fuzzing.

        Returns ``(pairs, semiring)`` where ``pairs`` is a list of
        ``(A_i, B_i)`` CSRs: heterogeneous rectangular shapes and
        densities, empty rows/cols, sorted/unsorted members -- optionally
        all sharing one B (the shared-operand fleet shape, e.g.
        per-expert dispatch against one feature matrix).
        """
        n_products = draw(st.integers(min_products, max_products))
        semiring = draw(st.sampled_from(SEMIRINGS))
        share_b = draw(st.booleans())
        pairs = []
        if share_b:
            k, n = draw(DIMS), draw(DIMS)
            b, _ = draw(csr_case(m=k, n=n))
            for _ in range(n_products):
                a, _ = draw(csr_case(n=k))
                pairs.append((a, b))
        else:
            for _ in range(n_products):
                k = draw(DIMS)
                a, _ = draw(csr_case(n=k))
                b, _ = draw(csr_case(m=k))
                pairs.append((a, b))
        return pairs, semiring
