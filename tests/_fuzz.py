"""Reusable structure generators + property-based strategies for the
differential SpGEMM suites.

One home for the CSR/product generators that used to live inline in
``test_differential.py``, so every fuzz layer (single products, batched
fleets, future suites) draws from the same structure space: rectangular
shapes, empty rows/columns, empty matrices, duplicate-free sorted and
*unsorted* CSRs, dyadic values.

The pure-numpy helpers in the first half (``VALS``, :func:`rand_dense`,
:func:`csr_of`, :func:`scramble_rows`, :func:`member_value_fleet`, the
trace-context runner :func:`run_planned_hash_in_context`) import
unconditionally -- the deterministic grids of ``test_differential.py`` /
``test_batch.py`` / ``test_hash_saturation.py`` /
``test_trace_contexts.py`` share them with no optional dependency.  The
hypothesis *strategies* in the second half exist only when the optional
``hypothesis`` extra is installed; consumers guard exactly like the old
inline layers did::

    try:
        from _fuzz import product_case      # ImportError without the extra
        HAVE_HYPOTHESIS = True
    except ImportError:
        HAVE_HYPOTHESIS = False

Values are drawn from dyadic rationals ({0.5, 1.0, 1.5, 2.0}) so fp32
products and sums are exact and every comparison can be bitwise; they are
also strictly positive, which sidesteps the dense-oracle explicit-zero
caveat documented on ``repro.core.spgemm.spgemm_dense``.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import CSR

#: dyadic, strictly positive: exact fp32 arithmetic, no explicit zeros.
VALS = np.array([0.5, 1.0, 1.5, 2.0], np.float32)

SEMIRINGS = ("plus_times", "boolean", "min_plus", "plus_first")
ALGOS = ("esc", "heap", "hash", "hash_jnp")


def rand_dense(m: int, n: int, density: float, seed: int) -> np.ndarray:
    """Dense dyadic-valued matrix with the given fill fraction."""
    rng = np.random.default_rng(seed)
    d = rng.choice(VALS, size=(m, n))
    return np.where(rng.random((m, n)) < density, d, 0.0).astype(np.float32)


def csr_of(d: np.ndarray, cap: int | None = None) -> CSR:
    """Sorted, duplicate-free CSR of a dense matrix."""
    r, c = np.nonzero(d)
    return CSR.from_numpy_coo(r, c, d[r, c], d.shape, cap=cap)


def block_clustered_dense(gm: int, gn: int, bm: int, bn: int,
                          density: float, seed: int) -> np.ndarray:
    """Block-clustered dyadic dense matrix: a ``gm x gn`` occupancy grid
    of fully dense ``bm x bn`` tiles -- the structure the BCSR recipe
    routing keys on.  Dyadic values keep every comparison bitwise."""
    rng = np.random.default_rng(seed)
    occ = (rng.random((gm, gn)) < density).astype(np.float32)
    vals = rng.choice(VALS, size=(gm * bm, gn * bn)).astype(np.float32)
    return np.kron(occ, np.ones((bm, bn), np.float32)) * vals


def member_value_fleet(ad: np.ndarray, n_members: int, seed: int) -> np.ndarray:
    """``(n_members, nnz)`` dyadic value stacks on ``ad``'s fixed pattern.

    The traced-context suites vmap one structure-frozen plan over these
    per-member values; row 0 is ``ad``'s own values so member 0 doubles
    as the eager-path case.
    """
    rng = np.random.default_rng(seed)
    nnz = int(np.count_nonzero(ad))
    vals = rng.choice(VALS, size=(n_members, nnz)).astype(np.float32)
    if nnz:
        r, c = np.nonzero(ad)
        vals[0] = ad[r, c]
    return vals


def run_planned_hash_in_context(a: CSR, b: CSR, member_vals: np.ndarray,
                                context: str, vector: bool = False):
    """Execute one structure-frozen hash plan inside a trace context.

    Plans ``a @ b`` once with the real Pallas hash kernel, then executes
    it over ``member_vals`` -- a ``(E, nnz_a)`` stack of value fleets on
    A's fixed sparsity pattern -- inside the requested context:

      * ``"vmap"``: ``jax.vmap`` of the plan's execute over member values
        (dispatches the batched-grid kernel via its ``custom_vmap`` rule);
      * ``"shard_map"``: a one-device in-process ``shard_map`` whose body
        runs the plan's execute per member (the plain kernel traces
        inside the SPMD body);
      * ``"both"``: the ``shard_map`` body vmaps over the member axis.

    Returns ``(dense, counts)``: the ``(E, m, n)`` dense results and the
    kernel-call counter delta, so callers can assert the Pallas kernel
    (not the jnp twin) was staged.  Dyadic values make every comparison
    against a per-product-rounding oracle bitwise despite the kernel's
    FMA accumulation (see ``repro.kernels.spgemm_hash.ops``).
    """
    import dataclasses
    import jax
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core import plan_spgemm
    from repro.kernels.spgemm_hash import ops as hash_ops

    algorithm = "hash_vector" if vector else "hash"
    plan = plan_spgemm(a, b, algorithm=algorithm)
    e = member_vals.shape[0]
    pad = a.cap - member_vals.shape[1]
    vals = np.concatenate(
        [member_vals, np.zeros((e, pad), np.float32)], axis=1) \
        if pad else member_vals
    vals = jnp.asarray(vals)

    def one(v):
        return plan.execute(dataclasses.replace(a, data=v), b).to_dense()

    hash_ops.reset_kernel_calls()
    before = hash_ops.kernel_call_counts()
    if context == "vmap":
        dense = jax.vmap(one)(vals)
    else:
        mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
        if context == "shard_map":
            body = lambda v: jnp.stack([one(v[i]) for i in range(e)])
        elif context == "both":
            body = lambda v: jax.vmap(one)(v)
        else:
            raise ValueError(f"unknown trace context {context!r}")
        # check_rep=False matches the production executors in
        # core.distributed: custom_vmap_call has no replication rule
        dense = shard_map(body, mesh=mesh, in_specs=(P(),),
                          out_specs=P(), check_rep=False)(vals)
    counts = {k: v - before[k]
              for k, v in hash_ops.kernel_call_counts().items()}
    return np.asarray(dense), counts


def scramble_rows(a: CSR) -> CSR:
    """Unsorted twin: reverse each row's entries, flag ``sorted_cols=False``.

    Deterministic (no RNG), duplicate-free by construction, and the dense
    view is unchanged -- the canonical way every suite builds the
    "Table 1 unsorted input" case.
    """
    ip = np.asarray(a.indptr)
    ind = np.asarray(a.indices).copy()
    dat = np.asarray(a.data).copy()
    for i in range(a.n_rows):
        ind[ip[i]:ip[i + 1]] = ind[ip[i]:ip[i + 1]][::-1]
        dat[ip[i]:ip[i + 1]] = dat[ip[i]:ip[i + 1]][::-1]
    return CSR(jnp.asarray(ip), jnp.asarray(ind), jnp.asarray(dat),
               a.nnz, a.shape, sorted_cols=False)


PLAN_PERTURBATIONS = ("cap_c", "bin_tsize")


def perturb_plan(plan, which: str):
    """A structurally-broken twin of a frozen hash :class:`SpGEMMPlan`.

    The layer-1 verifier (:func:`repro.verify.check_plan_vcs`) must
    *reject* every twin this produces and keep passing the untouched
    plan -- the differential contract of ``tests/test_verify.py``:

      * ``"cap_c"``: output capacity dropped below the planned exact
        ``nnz_c`` (breaks ``store-capacity`` / ``nnz-consistent``);
      * ``"bin_tsize"``: every per-bin hash table halved -- now either
        under the kernel's CHUNK floor (``table-p2-range``) or too small
        for its bin's worst row (``probe-termination`` /
        ``flush-bound``).

    Returns a new frozen plan; the input is never mutated.
    """
    import dataclasses
    if which == "cap_c":
        bad = max(int(plan.nnz_c) - 1, 0)
        return dataclasses.replace(plan, cap_c=bad)
    if which == "bin_tsize":
        assert plan.bin_tsize is not None, "perturbation needs a hash plan"
        halved = jnp.maximum(jnp.asarray(plan.bin_tsize) // 2, 1)
        return dataclasses.replace(plan, bin_tsize=halved)
    raise ValueError(f"unknown plan perturbation {which!r}")


# ---------------------------------------------------------------------------
# Hypothesis strategies (optional extra; absent => the names don't exist
# and `from _fuzz import product_case` raises ImportError, which is the
# guard every consumer already uses)
# ---------------------------------------------------------------------------

try:
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    #: dims drawn from a tiny fixed set so examples share compiled programs.
    DIMS = st.sampled_from((3, 5, 8))
    DENSITIES = st.sampled_from((0.0, 0.2, 0.5, 0.9))

    @st.composite
    def dense_with_structure(draw, m: int, n: int, seed: int) -> np.ndarray:
        """Dense matrix with optionally-forced empty rows/columns."""
        d = rand_dense(m, n, draw(DENSITIES), seed)
        if draw(st.booleans()) and m > 1:      # force some empty rows
            kill = draw(st.sets(st.integers(0, m - 1), max_size=m // 2))
            for i in kill:
                d[i, :] = 0.0
        if draw(st.booleans()) and n > 1:      # force some empty columns
            kill = draw(st.sets(st.integers(0, n - 1), max_size=n // 2))
            for j in kill:
                d[:, j] = 0.0
        return d

    @st.composite
    def csr_case(draw, m: int | None = None, n: int | None = None,
                 allow_unsorted: bool = True):
        """One CSR plus its dense view: ``(a, ad)``.

        Rectangular by default (independent row/col dims), possibly with
        empty rows/cols or fully empty, possibly row-scrambled unsorted.
        """
        m = draw(DIMS) if m is None else m
        n = draw(DIMS) if n is None else n
        seed = draw(st.integers(0, 2**16))
        ad = draw(dense_with_structure(m, n, seed))
        a = csr_of(ad)
        if allow_unsorted and draw(st.booleans()):
            a = scramble_rows(a)
        return a, ad

    @st.composite
    def product_case(draw):
        """One product request: ``(ad, bd, md, complement, semiring, algo)``.

        The single-product differential layer's case shape (dense operands
        + optional dense mask + semantic fields); the consumer builds CSRs
        and compares against its oracle.
        """
        m, k, n = draw(DIMS), draw(DIMS), draw(DIMS)
        seed = draw(st.integers(0, 2**16))
        density = draw(DENSITIES)
        ad = rand_dense(m, k, density, seed)
        bd = rand_dense(k, n, density, seed + 1)
        masked = draw(st.booleans())
        md = rand_dense(m, n, 0.5, seed + 2) if masked else None
        complement = draw(st.booleans()) if masked else False
        semiring = draw(st.sampled_from(SEMIRINGS))
        algo = draw(st.sampled_from(ALGOS))
        return ad, bd, md, complement, semiring, algo

    @st.composite
    def traced_context_case(draw, max_members: int = 3):
        """A planned-product-under-trace-context case:
        ``(ad, bd, member_vals, context)``.

        ``ad``/``bd`` fix one product structure; ``member_vals`` is an
        ``(E, nnz_a)`` dyadic value stack on A's pattern (row 0 = ``ad``'s
        own values); ``context`` picks where the structure-frozen plan
        executes: under ``vmap``, inside a ``shard_map`` body, or both
        nested.  Consumed by :func:`run_planned_hash_in_context`.
        """
        m, k, n = draw(DIMS), draw(DIMS), draw(DIMS)
        seed = draw(st.integers(0, 2**16))
        ad = draw(dense_with_structure(m, k, seed))
        bd = rand_dense(k, n, draw(DENSITIES), seed + 1)
        context = draw(st.sampled_from(("vmap", "shard_map", "both")))
        e = draw(st.integers(2, max_members))
        member_vals = member_value_fleet(ad, e, draw(st.integers(0, 2**16)))
        vector = draw(st.booleans())
        return ad, bd, member_vals, context, vector

    #: tile dims for the BCSR strategy (tiny, so examples share programs)
    BLOCK_DIMS = st.sampled_from((1, 2, 4))

    @st.composite
    def bcsr_case(draw):
        """One block product: ``(ad, bd, (bm, bk, bn))``.

        A tiles ``(bm, bk)``, B tiles ``(bk, bn)`` on independent
        occupancy grids; tiles are optionally thinned below full density
        (partially-filled blocks), and either operand may be all-zero.
        The consumer re-blocks with ``csr_to_bcsr`` / ``BCSR.from_dense``
        and compares the planned block product against the scipy BSR
        oracle.
        """
        bm, bk, bn = draw(BLOCK_DIMS), draw(BLOCK_DIMS), draw(BLOCK_DIMS)
        gm, gk, gn = (draw(st.integers(1, 4)) for _ in range(3))
        seed = draw(st.integers(0, 2**16))
        ad = block_clustered_dense(gm, gk, bm, bk, draw(DENSITIES), seed)
        bd = block_clustered_dense(gk, gn, bk, bn, draw(DENSITIES),
                                   seed + 1)
        if draw(st.booleans()):     # partially-filled A tiles
            rng = np.random.default_rng(seed + 2)
            ad = ad * (rng.random(ad.shape) < 0.7)
        if draw(st.booleans()):     # partially-filled B tiles
            rng = np.random.default_rng(seed + 3)
            bd = bd * (rng.random(bd.shape) < 0.7)
        return ad.astype(np.float32), bd.astype(np.float32), (bm, bk, bn)

    @st.composite
    def perturbed_plan_case(draw):
        """A hash-plannable product plus a schedule perturbation kind:
        ``(ad, bd, which)``.  The consumer plans ``hash``, applies
        :func:`perturb_plan`, and asserts the layer-1 VCs reject the
        twin while the untouched plan keeps passing."""
        m, k, n = draw(DIMS), draw(DIMS), draw(DIMS)
        seed = draw(st.integers(0, 2**16))
        # nonzero density: a perturbable plan needs at least one product
        ad = rand_dense(m, k, draw(st.sampled_from((0.2, 0.5, 0.9))), seed)
        bd = rand_dense(k, n, draw(st.sampled_from((0.5, 0.9))), seed + 1)
        which = draw(st.sampled_from(PLAN_PERTURBATIONS))
        return ad, bd, which

    @st.composite
    def batch_case(draw, min_products: int = 2, max_products: int = 6):
        """A fleet of CSR products for ``spgemm_batch`` fuzzing.

        Returns ``(pairs, semiring)`` where ``pairs`` is a list of
        ``(A_i, B_i)`` CSRs: heterogeneous rectangular shapes and
        densities, empty rows/cols, sorted/unsorted members -- optionally
        all sharing one B (the shared-operand fleet shape, e.g.
        per-expert dispatch against one feature matrix).
        """
        n_products = draw(st.integers(min_products, max_products))
        semiring = draw(st.sampled_from(SEMIRINGS))
        share_b = draw(st.booleans())
        pairs = []
        if share_b:
            k, n = draw(DIMS), draw(DIMS)
            b, _ = draw(csr_case(m=k, n=n))
            for _ in range(n_products):
                a, _ = draw(csr_case(n=k))
                pairs.append((a, b))
        else:
            for _ in range(n_products):
                k = draw(DIMS)
                a, _ = draw(csr_case(n=k))
                b, _ = draw(csr_case(m=k))
                pairs.append((a, b))
        return pairs, semiring

    @st.composite
    def degenerate_partition_case(draw):
        """A ``(weights, n_parts)`` pair biased toward the partition
        degeneracies: all-zero weights, zero-weight spans, single rows,
        and ``n_parts > n_rows``.  The consumer checks the
        ``equal_weight_partition`` invariants (cover, monotone, balance,
        and no all-rows-in-part-0 collapse on zero totals)."""
        shape = draw(st.sampled_from(("zeros", "spans", "random", "tiny")))
        if shape == "zeros":
            n = draw(st.integers(1, 16))
            w = np.zeros(n, np.int64)
        elif shape == "tiny":
            n = draw(st.integers(1, 3))
            w = np.asarray(draw(st.lists(st.integers(0, 4),
                                         min_size=n, max_size=n)), np.int64)
        else:
            n = draw(st.integers(4, 16))
            rng = np.random.default_rng(draw(st.integers(0, 2**16)))
            w = rng.integers(0, 9, n).astype(np.int64)
            if shape == "spans":       # zero out a contiguous span
                i = draw(st.integers(0, n - 1))
                j = draw(st.integers(i, n))
                w[i:j] = 0
        n_parts = draw(st.sampled_from((1, 2, 3, 8, 32)))
        return w, n_parts

    @st.composite
    def pb_case(draw):
        """A low-compression-factor product for the propagation-blocking
        differential layer: ``(ad, bd, n_buckets)``.

        Wide-ish B with thin rows keeps flop / nnz(C) near 1 (few
        collisions to merge -- PB's home regime); the strategy still mixes
        in denser draws so the bucket merge sees real duplicate columns.
        """
        m, k = draw(DIMS), draw(DIMS)
        n = draw(st.sampled_from((8, 16, 32)))
        seed = draw(st.integers(0, 2**16))
        ad = draw(dense_with_structure(m, k, seed))
        bd = rand_dense(k, n, draw(st.sampled_from((0.05, 0.1, 0.3))),
                        seed + 1)
        n_buckets = draw(st.sampled_from((1, 2, 4)))
        return ad, bd, n_buckets
