"""Shared independent oracles for the differential suites.

One implementation of the per-semiring reference product, used by both
``test_differential.py`` (single products) and ``test_chain.py``
(chains), so a semiring added or a tolerance fixed in the oracle reaches
every differential suite at once.  ``plus_times``/``boolean`` go through
scipy.sparse (a genuinely independent sparse engine); callers are
responsible for skipping when scipy is absent (both suites
``importorskip`` it at module level).
"""
import numpy as np


def semiring_oracle(ad: np.ndarray, bd: np.ndarray,
                    sr_name: str) -> np.ndarray:
    import scipy.sparse as sp
    ap, bp = ad != 0, bd != 0
    if sr_name == "plus_times":
        return np.asarray((sp.csr_matrix(ad) @ sp.csr_matrix(bd)).todense(),
                          np.float32)
    if sr_name == "boolean":
        return ((sp.csr_matrix(ap) @ sp.csr_matrix(bp)).todense() > 0) \
            .astype(np.float32)
    if sr_name == "plus_first":
        return (ad @ bp.astype(np.float32)).astype(np.float32)
    if sr_name == "min_plus":
        s = np.where(ap[:, :, None] & bp[None, :, :],
                     ad[:, :, None] + bd[None, :, :], np.inf)
        out = s.min(axis=1)
        return np.where(np.isinf(out), 0.0, out).astype(np.float32)
    raise AssertionError(sr_name)
