import os
import sys

# src-layout import without installation (PYTHONPATH=src also works).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _bounded_jit_accumulation():
    """Free compiled executables after every test module.

    A single-process run of the whole suite compiles hundreds of one-off
    XLA/Pallas executables; past ~300 tests the accumulated native JIT
    state deterministically segfaults a later large compile (observed at
    test_ssd_kernel's chunk==seq sweep, inside backend_compile).
    Clearing per module bounds live JIT state by the heaviest single
    module; cross-module compilation reuse is negligible.
    """
    yield
    jax.clear_caches()
