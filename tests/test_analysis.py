"""HLO collective parser + roofline math unit tests."""
import pytest

from repro.analysis.hlo_collectives import collective_bytes, _shape_bytes
from repro.analysis.roofline import (analyze, corrected_totals,
                                     model_flops_per_chip, PEAK_FLOPS,
                                     HBM_BW, LINK_BW)

HLO = """
HloModule jit_step
%fused (x: f32[128,256]) -> f32[128,256] {
  ...
}
ENTRY %main {
  %ag = f32[1024,128]{1,0} all-gather(%p0), replica_groups={}
  %ar = bf16[512]{0} all-reduce(%p1), to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%p2), dimensions={0}
  %a2a = bf16[16,32,8]{2,1,0} all-to-all(%p3), dimensions={0}
  %cp = f32[256]{0} collective-permute(%p4), source_target_pairs={{0,1}}
  %tup = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-gather-start(%p5)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[512]") == 1024
    assert _shape_bytes("(f32[8,8], f32[8,8])") == 2 * 256


def test_collective_parse():
    out = collective_bytes(HLO)
    bk = out["bytes_by_kind"]
    assert bk["all-gather"] == 1024 * 128 * 4 + 2 * 8 * 8 * 4
    assert bk["all-reduce"] == 512 * 2
    assert bk["reduce-scatter"] == 64 * 64 * 4
    assert bk["all-to-all"] == 16 * 32 * 8 * 2
    assert bk["collective-permute"] == 256 * 4
    assert out["count_by_kind"]["all-gather"] == 2


def _rec(**kw):
    base = dict(arch="x", shape="train_4k", mesh="16x16", chips=256,
                params=1e9, active_params=1e9,
                hlo_flops=1e12, hlo_bytes=1e11,
                collectives={"total_bytes": int(1e10)})
    base.update(kw)
    return base


def test_roofline_terms_and_bottleneck():
    a = analyze(_rec())
    assert a["compute_s"] == pytest.approx(1e12 / PEAK_FLOPS)
    assert a["memory_s"] == pytest.approx(1e11 / HBM_BW)
    assert a["collective_s"] == pytest.approx(1e10 / LINK_BW)
    assert a["bottleneck"] == "collective"
    assert 0 < a["roofline_fraction"] <= 1


def test_calibration_extrapolation():
    calib = {"n_full_periods": 10, "n_tail": 0, "period": 1,
             "c1": {"hlo_flops": 100.0, "hlo_bytes": 10.0,
                    "collectives": {"total_bytes": 5}},
             "c2": {"hlo_flops": 130.0, "hlo_bytes": 13.0,
                    "collectives": {"total_bytes": 6}}}
    tot = corrected_totals(_rec(calib=calib))
    assert tot["flops"] == pytest.approx(100 + 9 * 30)
    assert tot["bytes"] == pytest.approx(10 + 9 * 3)
    assert tot["coll_bytes"] == pytest.approx(5 + 9 * 1)


def test_model_flops():
    r = _rec()
    assert model_flops_per_chip(r) == pytest.approx(
        6 * 1e9 * 4096 * 256 / 256)
    r2 = _rec(shape="decode_32k")
    assert model_flops_per_chip(r2) == pytest.approx(2 * 1e9 * 128 / 256)
