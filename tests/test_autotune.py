"""Measured recipe + persistent perf DB (DESIGN.md section 16).

Robustness contract: a missing / truncated / corrupt / unknown-schema DB
file and a stale (drifted) entry must all degrade to the heuristic
recipe with an :class:`AutotuneDBWarning` -- never a crash, never an
entry served for the wrong structure.  Effort contract: a DB hit does
**zero** microbenchmarks, pinned by the ``candidates_timed`` counter.
"""
import json
import os
import sys
import warnings

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.autotune import (AutotuneDBWarning, PerfDB, SCHEMA_VERSION,
                            TunedChoice, db_key, measure_call_counts,
                            measured_recommend, reset_measure_calls)
from repro.autotune.measure import _scaled_plan
from repro.core import clear_plan_cache, plan_spgemm
from repro.core.recipe import recommend
from repro.data.rmat import rmat_csr
from repro.verify.bounds import check_plan_vcs

ALGOS = ("esc", "heap", "hash", "hash_vector", "hash_jnp")


def _pair(seed=0, scale=5, ef=3):
    return (rmat_csr(scale, ef, "G500", seed=seed),
            rmat_csr(scale, ef, "ER", seed=seed + 50))


def _seed_entry(db: PerfDB, a, b, **overrides):
    """Plant a plausible winner entry for (a, b) directly."""
    key = db_key(a, b)
    from repro.core.recipe import measure_stats
    s = measure_stats(a, b)
    entry = {"schema": SCHEMA_VERSION, "algorithm": "esc", "table_scale": 1,
             "us": 100.0, "candidates": {"esc": 100.0},
             "stats": {"flop": float(s.flop), "nnz_a": float(s.nnz_a),
                       "nnz_c": float(s.nnz_c_est)},
             "backend": "cpu", "x64": False}
    entry.update(overrides)
    db.put(key, entry)
    return key


# ---------------------------------------------------------------------------
# DB file robustness: degrade, warn, never crash, never mis-key
# ---------------------------------------------------------------------------

def test_db_missing_file_is_empty_without_warning(tmp_path):
    db = PerfDB(str(tmp_path / "nope.json"))
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # a missing DB is normal
        assert db.load() == {}
        assert db.get("anything") is None


def test_db_truncated_json_degrades_with_warning(tmp_path):
    path = tmp_path / "db.json"
    db = PerfDB(str(path))
    a, b = _pair(seed=1)
    _seed_entry(db, a, b)
    full = path.read_text()
    path.write_text(full[: len(full) // 2])     # torn write / truncation
    with pytest.warns(AutotuneDBWarning, match="unreadable"):
        assert db.load() == {}
    with pytest.warns(AutotuneDBWarning):
        assert measured_recommend(a, b, db=db, measure=False) is None


def test_db_corrupt_json_degrades_and_heals_on_next_put(tmp_path):
    path = tmp_path / "db.json"
    path.write_text("{not json at all")
    db = PerfDB(str(path))
    a, b = _pair(seed=2)
    with pytest.warns(AutotuneDBWarning):
        assert db.get(db_key(a, b)) is None
    # the next put rewrites a clean schema-1 document
    with pytest.warns(AutotuneDBWarning):       # put re-loads the bad file
        key = _seed_entry(db, a, b)
    doc = json.loads(path.read_text())
    assert doc["schema"] == SCHEMA_VERSION and key in doc["entries"]
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert db.get(key) is not None


def test_db_unknown_schema_version_degrades(tmp_path):
    path = tmp_path / "db.json"
    path.write_text(json.dumps({"schema": 99, "entries": {"k": {}}}))
    db = PerfDB(str(path))
    with pytest.warns(AutotuneDBWarning, match="schema"):
        assert db.load() == {}


def test_db_non_dict_document_degrades(tmp_path):
    path = tmp_path / "db.json"
    path.write_text(json.dumps([1, 2, 3]))
    db = PerfDB(str(path))
    with pytest.warns(AutotuneDBWarning):
        assert db.load() == {}


def test_db_stale_entry_drift_is_remeasured_not_trusted(tmp_path):
    """An entry whose recorded stats disagree with the request's measured
    stats past the tolerance is dropped (the stale-digest guard)."""
    db = PerfDB(str(tmp_path / "db.json"))
    a, b = _pair(seed=3)
    _seed_entry(db, a, b,
                stats={"flop": 1e9, "nnz_a": 1e9, "nnz_c": 1e9})
    with pytest.warns(AutotuneDBWarning, match="drifted"):
        assert measured_recommend(a, b, db=db, measure=False) is None


def test_db_entry_with_unknown_algorithm_is_ignored(tmp_path):
    db = PerfDB(str(tmp_path / "db.json"))
    a, b = _pair(seed=4)
    _seed_entry(db, a, b, algorithm="quantum_annealer")
    with pytest.warns(AutotuneDBWarning, match="unknown algorithm"):
        assert measured_recommend(a, b, db=db, measure=False) is None


def test_db_never_mis_keys_across_structures(tmp_path):
    """A winner recorded for one structure is invisible to a different
    structure of the same shape -- the digest key, not the shape, is the
    identity."""
    db = PerfDB(str(tmp_path / "db.json"))
    a, b = _pair(seed=5)
    _seed_entry(db, a, b)
    a2, b2 = _pair(seed=6)                      # same shapes, new structure
    assert db_key(a2, b2) != db_key(a, b)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # a clean miss, no warning
        assert measured_recommend(a2, b2, db=db, measure=False) is None


def test_recommend_measured_mode_survives_corrupt_db(tmp_path):
    """End-to-end: mode="measured" against garbage on disk still returns
    a valid algorithm (and heals the DB), with warnings, not a crash."""
    path = tmp_path / "db.json"
    path.write_text('{"schema": 1, "entries": "oops"}')
    a, b = _pair(seed=7, scale=4)               # tiny: it will measure
    with pytest.warns(AutotuneDBWarning):
        algo, stats = recommend(a, b, mode="measured", db=str(path))
    assert algo in ALGOS
    assert json.loads(path.read_text())["schema"] == SCHEMA_VERSION


# ---------------------------------------------------------------------------
# Determinism / convergence: two measuring processes, one entry
# ---------------------------------------------------------------------------

def test_two_writers_converge_on_one_entry(tmp_path):
    """Two PerfDB handles on one path (two processes in miniature): both
    measure the same digest; the file ends with exactly one entry for it
    and the second handle's read agrees with what it wrote."""
    path = str(tmp_path / "db.json")
    a, b = _pair(seed=8, scale=4)
    c1 = measured_recommend(a, b, db=PerfDB(path))
    c2 = measured_recommend(a, b, db=PerfDB(path))
    assert c1 is not None and c1.source == "measured"
    # the second handle reads the first's persisted winner -- a hit, so
    # it reports source="db" and the identical algorithm
    assert c2 is not None and c2.source == "db"
    assert c2.algorithm == c1.algorithm
    entries = PerfDB(path).load()
    assert len(entries) == 1
    (key,) = entries
    assert key == db_key(a, b)


def test_concurrent_puts_merge_not_clobber(tmp_path):
    """Interleaved writers with distinct keys both land: put re-reads the
    file before writing, so the last writer merges rather than erases."""
    path = str(tmp_path / "db.json")
    db1, db2 = PerfDB(path), PerfDB(path)
    a, b = _pair(seed=9)
    a2, b2 = _pair(seed=10)
    k1 = _seed_entry(db1, a, b)
    k2 = _seed_entry(db2, a2, b2)               # db2 never saw k1 in memory
    entries = PerfDB(path).load()
    assert set(entries) == {k1, k2}


# ---------------------------------------------------------------------------
# Effort counters: a DB hit measures nothing
# ---------------------------------------------------------------------------

def test_db_hit_does_zero_microbenchmarks(tmp_path):
    db = PerfDB(str(tmp_path / "db.json"))
    a, b = _pair(seed=11, scale=4)
    reset_measure_calls()
    first = measured_recommend(a, b, db=db)
    calls = measure_call_counts()
    assert first.source == "measured" and calls["candidates_timed"] > 0
    reset_measure_calls()
    again = measured_recommend(a, b, db=db)
    calls = measure_call_counts()
    assert again.source == "db"
    assert calls["candidates_timed"] == 0, calls
    assert calls["db_hits"] == 1 and calls["db_misses"] == 0


def test_plan_autotune_repeat_hits_db_and_records_provenance(tmp_path):
    db = PerfDB(str(tmp_path / "db.json"))
    a, b = _pair(seed=12, scale=4)
    clear_plan_cache()
    p_meas = plan_spgemm(a, b, autotune=True, autotune_db=db, cache=False)
    assert p_meas.provenance == "measured"
    reset_measure_calls()
    p2 = plan_spgemm(a, b, autotune=True, autotune_db=db, cache=False)
    assert p2.provenance == "measured"
    assert p2.algorithm == p_meas.algorithm
    assert measure_call_counts()["candidates_timed"] == 0
    # provenance of the other two resolution paths
    assert plan_spgemm(a, b, cache=False).provenance == "heuristic"
    assert plan_spgemm(a, b, algorithm="esc",
                       cache=False).provenance == "explicit"
    # autotuned vs heuristic requests are distinct plan-cache entries
    clear_plan_cache()
    p_h = plan_spgemm(a, b)
    p_m = plan_spgemm(a, b, autotune=True, autotune_db=db)
    assert p_h is not p_m and p_h.key != p_m.key


def test_measured_plan_output_matches_oracle(tmp_path):
    db = PerfDB(str(tmp_path / "db.json"))
    a, b = _pair(seed=13, scale=4)
    plan = plan_spgemm(a, b, autotune=True, autotune_db=db, cache=False)
    cd = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
    assert np.allclose(np.asarray(plan.execute(a, b).to_dense()), cd,
                       atol=1e-3)


# ---------------------------------------------------------------------------
# Scaled-table variants keep the schedule VCs
# ---------------------------------------------------------------------------

def test_scaled_table_variant_passes_plan_vcs():
    a, b = _pair(seed=14)
    base = plan_spgemm(a, b, algorithm="hash", cache=False)
    for scale in (2, 4):
        variant = _scaled_plan(base, scale, b.n_cols)
        failures = [vc for vc in check_plan_vcs(variant) if not vc.ok]
        assert not failures, failures
        assert variant.table_size >= base.table_size
        # and it still computes the same product
        cd = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
        assert np.allclose(np.asarray(variant.execute(a, b).to_dense()),
                           cd, atol=1e-3)


def test_tuned_choice_threads_table_scale_into_plan(tmp_path):
    """A DB entry naming a table-scale variant actually scales the frozen
    schedule (and the plan still verifies + computes correctly)."""
    db = PerfDB(str(tmp_path / "db.json"))
    a, b = _pair(seed=15)
    base = plan_spgemm(a, b, algorithm="hash", cache=False)
    _seed_entry(db, a, b, algorithm="hash", table_scale=2)
    plan = plan_spgemm(a, b, autotune=True, autotune_db=db, cache=False)
    assert plan.provenance == "measured" and plan.algorithm == "hash"
    assert plan.table_size >= base.table_size
    failures = [vc for vc in check_plan_vcs(plan) if not vc.ok]
    assert not failures, failures
    cd = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
    assert np.allclose(np.asarray(plan.execute(a, b).to_dense()), cd,
                       atol=1e-3)


# ---------------------------------------------------------------------------
# Roofline context helpers
# ---------------------------------------------------------------------------

def test_spgemm_roofline_classifies_bounds():
    from repro.analysis.roofline import spgemm_roofline, \
        spgemm_traffic_bytes
    # sparse products are memory-bound: ~1 flop/byte << machine balance
    nbytes = spgemm_traffic_bytes(n_rows=1000, nnz_a=8000, flop=64000,
                                  nnz_c=32000)
    roof = spgemm_roofline(2.0 * 64000, nbytes, seconds=1e-3)
    assert roof["bound"] == "memory"
    assert 0.0 < roof["roof_fraction"]
    # a hypothetical compute-heavy op flips the bound
    roof2 = spgemm_roofline(1e15, 1e6, seconds=1.0)
    assert roof2["bound"] == "compute"


def test_measured_entry_records_roofline_and_candidates(tmp_path):
    db = PerfDB(str(tmp_path / "db.json"))
    a, b = _pair(seed=16, scale=4)
    choice = measured_recommend(a, b, db=db)
    assert isinstance(choice, TunedChoice)
    (entry,) = db.load().values()
    assert entry["roofline"]["bound"] in ("memory", "compute")
    assert entry["candidates"] and \
        min(entry["candidates"].values()) == entry["us"]
    assert entry["algorithm"] in ALGOS


# ---------------------------------------------------------------------------
# Bench-trajectory ingestion: feed + sha aging (repro.autotune.feed)
# ---------------------------------------------------------------------------

def _traj_doc(sha, rows, backend="cpu"):
    """A minimal benchmarks.run --json trajectory document."""
    return {"schema": 1, "git_sha": sha, "backend": backend,
            "rows": rows}


def test_feed_bench_rows_ingests_under_bench_namespace(tmp_path):
    from repro.autotune import bench_row_key, feed_bench_rows
    db = PerfDB(str(tmp_path / "db.json"))
    doc = _traj_doc("aaa111", [
        {"name": "bcsr,diag16x8,block", "us_per_call": 12.5,
         "derived": "nnzb=16"},
        {"name": "plan,s5", "us_per_call": 3.0},
        {"name": "broken-no-timing"},               # skipped: no us
        {"name": 42, "us_per_call": 1.0},           # skipped: bad name
        {"name": "bool-timing", "us_per_call": True},  # skipped: bool
    ])
    assert feed_bench_rows(doc, db=db) == 2
    entry = db.load()[bench_row_key("bcsr,diag16x8,block", "cpu")]
    assert entry["kind"] == "bench" and entry["us"] == 12.5
    assert entry["git_sha"] == "aaa111" and entry["schema"] == SCHEMA_VERSION


def test_feed_ages_stale_shas_but_never_winners(tmp_path):
    """Re-feeding at a new sha drops the old sha's bench rows (a timing
    on old code says nothing about the current tree) while winner
    entries -- which carry no sha semantics -- survive untouched."""
    from repro.autotune import BENCH_KEY_PREFIX, bench_row_key, \
        feed_bench_rows
    db = PerfDB(str(tmp_path / "db.json"))
    a, b = _pair(seed=21, scale=4)
    winner_key = _seed_entry(db, a, b)

    feed_bench_rows(_traj_doc("sha_A", [
        {"name": "bcsr,diag16x8,block", "us_per_call": 10.0},
        {"name": "plan,s5", "us_per_call": 5.0}]), db=db)
    feed_bench_rows(_traj_doc("sha_B", [
        {"name": "bcsr,diag16x8,block", "us_per_call": 11.0}]), db=db)

    entries = db.load()
    bench_keys = [k for k in entries if k.startswith(BENCH_KEY_PREFIX)]
    assert bench_keys == [bench_row_key("bcsr,diag16x8,block", "cpu")]
    assert entries[bench_keys[0]]["git_sha"] == "sha_B"
    assert winner_key in entries          # winners never aged


def test_age_is_prefix_scoped_and_counts(tmp_path):
    from repro.autotune import bench_row_key
    db = PerfDB(str(tmp_path / "db.json"))
    db.update({
        bench_row_key("r1", "cpu"): {"kind": "bench", "git_sha": "old"},
        bench_row_key("r2", "cpu"): {"kind": "bench", "git_sha": "new"},
        bench_row_key("r3", "cpu"): {"kind": "bench"},  # sha-less: kept
    })
    assert db.age(current_sha="new") == 1
    kept = sorted(db.load())
    assert kept == sorted([bench_row_key("r2", "cpu"),
                           bench_row_key("r3", "cpu")])
    assert db.age(current_sha="new") == 0   # idempotent
