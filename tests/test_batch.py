"""Batched SpGEMM subsystem (DESIGN.md section 13).

Contracts:
  * ``spgemm_batch`` over a heterogeneous fleet is **bitwise-equal**, per
    element, to a loop of single planned products running the same
    algorithm with exact capacities (padding is capacity-only);
  * a fleet whose total-flop spread is R compiles at most
    ``ceil(log2 R) + 1`` capacity-class programs (p2 bucketing), counted
    via the class-program builder;
  * repeat execution does zero re-inspection (flop counting / symbolic /
    program builds all stay at zero);
  * plans are cached under the ``("batch", ...)`` kind with per-kind
    stats, and ``plan_cache_stats()["kinds"]`` reports zero entries for
    registered-but-empty kinds on a cold cache;
  * ``shard_batch`` round-robins whole products, covering every index
    exactly once, with weighted balance when weights are given;
  * ``plan_batch_power`` composes batched stages with unsorted
    intermediates and matches the per-product chain path.

The deterministic grid runs everywhere; the property layer at the bottom
fuzzes fleet structures via ``tests/_fuzz.py`` when the optional
``hypothesis`` extra is installed (absence skips only that layer).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import (CSR, BatchedPlan, clear_plan_cache,  # noqa: E402
                        plan_batch, plan_batch_power, plan_cache_stats,
                        plan_power, plan_spgemm, shard_batch, spgemm,
                        spgemm_batch)
from repro.data.rmat import rmat_csr  # noqa: E402
from benchmarks.common import (assert_bitwise_prefix as _assert_bitwise,
                               batch_class_bound, batch_inspection_counters,
                               counted, planned_loop,
                               rmat_fleet as _fleet)  # noqa: E402
from _fuzz import csr_of as _csr, rand_dense as _rand_dense  # noqa: E402

try:
    from hypothesis import given, settings
    from _fuzz import batch_case
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _planned_loop(plan: BatchedPlan, pairs):
    """The per-product planned path (shared benchmarks.common helper)."""
    return planned_loop(plan, pairs)()


# ---------------------------------------------------------------------------
# Acceptance: 32 heterogeneous products, bitwise, bounded program count,
# zero re-inspection on repeat execution
# ---------------------------------------------------------------------------

def test_batch_32_products_bitwise_and_program_bound():
    clear_plan_cache()
    pairs = _fleet(32, scale=4)
    plan = plan_batch(pairs)
    assert plan.n_products == 32

    # p2 bucketing: same-shape fleet with flop spread R compiles at most
    # ceil(log2 R) + 1 class programs (shared bound helper; +1 is the
    # bucket fencepost)
    assert plan.n_classes <= batch_class_bound(pairs), plan.n_classes

    # first execute compiles exactly n_classes programs
    built: dict = {}
    restore = counted("repro.core.batch", "_build_class_program", built)
    try:
        outs = plan.execute(pairs)
    finally:
        restore()
    assert built.get("_build_class_program", 0) == plan.n_classes

    # bitwise equality vs the per-product planned loop, per element
    refs = _planned_loop(plan, pairs)
    for c, ref in zip(outs, refs):
        _assert_bitwise(c, ref)

    # repeat execution: zero re-inspection, zero program builds
    counter, restore = batch_inspection_counters()
    try:
        outs2 = plan.execute(pairs)
    finally:
        restore()
    assert not counter, f"repeat execute re-inspected: {counter}"
    for c, c2 in zip(outs, outs2):
        _assert_bitwise(c, c2)


def test_batch_heterogeneous_shapes():
    """Different (m, k, n) members land in different classes and still
    match the per-product planned path bitwise."""
    cases = [(5, 7, 9), (8, 3, 4), (16, 16, 16), (5, 7, 9), (2, 11, 6)]
    pairs = []
    for i, (m, k, n) in enumerate(cases):
        pairs.append((_csr(_rand_dense(m, k, 0.4, seed=2 * i)),
                      _csr(_rand_dense(k, n, 0.4, seed=2 * i + 1))))
    plan = plan_batch(pairs)
    outs = plan.execute(pairs)
    for c, ref in zip(outs, _planned_loop(plan, pairs)):
        _assert_bitwise(c, ref)
    for (a, b), c in zip(pairs, outs):
        assert c.shape == (a.n_rows, b.n_cols)
        cd = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
        assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-4)


@pytest.mark.parametrize("algorithm", ("esc", "heap", "hash_jnp"))
def test_batch_pinned_algorithm_bitwise(algorithm):
    pairs = _fleet(6, scale=3, seed0=40)
    plan = plan_batch(pairs, algorithm=algorithm)
    assert set(plan.algorithms) == {algorithm}
    for c, ref in zip(plan.execute(pairs), _planned_loop(plan, pairs)):
        _assert_bitwise(c, ref)


@pytest.mark.parametrize("semiring", ("boolean", "min_plus", "plus_first"))
def test_batch_semirings_match_single_dispatch(semiring):
    pairs = _fleet(4, scale=3, seed0=60)
    outs = spgemm_batch(pairs, semiring=semiring)
    for (a, b), c in zip(pairs, outs):
        ref = spgemm(a, b, max(int(c.nnz), 1) + 4, algorithm="esc",
                     semiring=semiring)
        assert np.array_equal(np.asarray(c.to_dense()),
                              np.asarray(ref.to_dense()))


def test_batch_masked_members():
    """Masked and unmasked members split classes; masked results prune."""
    pairs = _fleet(4, scale=3, seed0=80)
    masks = [None, None,
             _csr(_rand_dense(8, 8, 0.5, seed=7)),
             _csr(_rand_dense(8, 8, 0.5, seed=8))]
    plan = plan_batch(pairs, masks=masks)
    outs = plan.execute(pairs)
    for i, ((a, b), m) in enumerate(zip(pairs, masks)):
        c = outs[i]
        # bitwise vs a single dispatch of the member's planned algorithm
        ref = spgemm(a, b, 64, algorithm=plan.algorithms[i], mask=m)
        assert np.array_equal(np.asarray(c.to_dense()),
                              np.asarray(ref.to_dense()))
        # esc pins the mask-pruning semantics; it rounds every product
        # while the Pallas hash accumulates with FMA, so allclose here
        esc = spgemm(a, b, 64, algorithm="esc", mask=m)
        assert np.allclose(np.asarray(c.to_dense()),
                           np.asarray(esc.to_dense()), rtol=1e-6)
    masked_cls = {plan.class_of[2], plan.class_of[3]}
    unmasked_cls = {plan.class_of[0], plan.class_of[1]}
    assert not (masked_cls & unmasked_cls)


def test_batch_shared_b_and_sorted_output():
    """Fleet sharing one B; sorted_output as plan flag and per-call
    override both yield sorted rows."""
    b = _csr(_rand_dense(8, 8, 0.5, seed=90))
    pairs = [(_csr(_rand_dense(8, 8, 0.2 + 0.2 * (i % 3), seed=91 + i)), b)
             for i in range(5)]
    plan = plan_batch(pairs, sorted_output=True)
    for c in plan.execute(pairs):
        assert c.sorted_cols
        cols, ip = np.asarray(c.indices), np.asarray(c.indptr)
        for i in range(c.n_rows):
            assert np.all(np.diff(cols[ip[i]:ip[i + 1]]) > 0)
    plan_u = plan_batch(pairs)          # unsorted plan, sorted override
    for c in plan_u.execute(pairs, sorted_output=True):
        assert c.sorted_cols


def test_batch_empty_and_mixed_sortedness_members():
    """Fully empty members (zero flop buckets) and unsorted members mixed
    with sorted ones ride the same fleet without special-casing."""
    empty_a = CSR.from_numpy_coo(np.zeros(0, np.int64),
                                 np.zeros(0, np.int64),
                                 np.zeros(0, np.float32), (5, 4), cap=2)
    empty_b = CSR.from_numpy_coo(np.zeros(0, np.int64),
                                 np.zeros(0, np.int64),
                                 np.zeros(0, np.float32), (4, 6), cap=1)
    b = _csr(_rand_dense(4, 6, 0.5, seed=101))
    a = _csr(_rand_dense(5, 4, 0.5, seed=102))
    pairs = [(empty_a, b), (a, b), (empty_a, empty_b),
             (a.with_unsorted_flag(), b)]
    plan = plan_batch(pairs)
    outs = plan.execute(pairs)
    for i, ((ai, bi), c) in enumerate(zip(pairs, outs)):
        ref = np.asarray(ai.to_dense()) @ np.asarray(bi.to_dense())
        assert np.array_equal(np.asarray(c.to_dense()), ref), i
    assert int(outs[0].nnz) == 0 and int(outs[2].nnz) == 0


def test_batch_rejects_heap_on_unsorted_and_bcsr():
    a = _csr(_rand_dense(6, 6, 0.5, seed=5))
    au = a.with_unsorted_flag()
    with pytest.raises(AssertionError, match="sorted inputs"):
        plan_batch([(au, a)], algorithm="heap", cache=False)
    with pytest.raises(NotImplementedError):
        plan_batch([(a, a)], algorithm="bcsr", cache=False)
    with pytest.raises(NotImplementedError):
        # dense is the test oracle (explicit-zero semantics); a silent
        # esc substitution would change output structure
        plan_batch([(a, a)], algorithm="dense", cache=False)
    # inner dims must compose, like _check_chain_shapes (a silent
    # mismatch would clamp gathers and produce plausible wrong numerics)
    bad = _csr(_rand_dense(5, 6, 0.5, seed=6))
    with pytest.raises(AssertionError, match="do not compose"):
        plan_batch([(a, a), (a, bad)], cache=False)
    # a heap class refuses operands downgraded to unsorted since plan
    # time (the class program would re-stamp the sorted flag silently)
    plan_h = plan_batch([(a, a)], algorithm="heap", cache=False)
    with pytest.raises(AssertionError, match="unsorted operand"):
        plan_h.execute([(a.with_unsorted_flag(), a)])


def test_batch_cache_kind_and_cold_zero_entries():
    clear_plan_cache()
    stats = plan_cache_stats()
    # satellite fix: registered-but-empty kinds report zero, no KeyError
    for kind in ("spgemm", "dist_1d", "summa", "chain", "chain_1d",
                 "gram", "batch", "batch_power"):
        assert stats["kinds"][kind] == 0
    pairs = _fleet(3, scale=3, seed0=11)
    plan = plan_batch(pairs)
    before = plan_cache_stats()
    assert before["kinds"]["batch"] == 1
    plan2 = plan_batch(pairs)
    after = plan_cache_stats()
    assert plan2 is plan and after["hits"] == before["hits"] + 1


def test_batch_structure_check_rejects_drift():
    pairs = _fleet(2, scale=3, seed0=21)
    plan = plan_batch(pairs)
    other = rmat_csr(3, 3, "ER", seed=999)
    with pytest.raises(AssertionError, match="nnz differs|capacities"):
        plan.execute([(other, pairs[0][1]), pairs[1]])


# ---------------------------------------------------------------------------
# shard_batch: whole-product round-robin
# ---------------------------------------------------------------------------

def test_shard_batch_covers_and_round_robins():
    assign = shard_batch(10, 3)
    flat = sorted(i for s in assign for i in s)
    assert flat == list(range(10))
    assert assign[0] == (0, 3, 6, 9)            # plain round-robin
    # weighted: heaviest products spread across chips first
    w = [1, 100, 1, 90, 1, 80]
    assign_w = shard_batch(6, 3, weights=w)
    flat = sorted(i for s in assign_w for i in s)
    assert flat == list(range(6))
    per_shard = [sum(w[i] for i in s) for s in assign_w]
    assert max(per_shard) <= 100 + 2            # no chip hoards the heavies
    pairs = _fleet(4, scale=3, seed0=31)
    assert sorted(i for s in shard_batch(pairs, 2) for i in s) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# plan_batch_power: batched A_i^k chains
# ---------------------------------------------------------------------------

def test_plan_batch_power_matches_per_product_chain():
    mats = [rmat_csr(3, 2, "G500", seed=50 + i) for i in range(4)]
    # same algorithm on both sides: the comparison is then bitwise (auto
    # may legally pick different per-stage algorithms for the fleet's
    # aggregate than for one product, changing fp accumulation order)
    plan = plan_batch_power(mats, 3, algorithm="hash_jnp")
    outs = plan.execute(mats)
    for m, c in zip(mats, outs):
        d = np.asarray(m.to_dense(), np.float64)
        assert np.allclose(np.asarray(c.to_dense()), d @ d @ d, atol=1e-3)
        ref = plan_power(m, 3, algorithm="hash_jnp").execute([m, m, m])
        assert np.array_equal(np.asarray(c.to_dense()),
                              np.asarray(ref.to_dense()))
    # program sharing: fleet x stages compiles far fewer programs than
    # products x stages
    assert plan.n_classes < plan.n_products * plan.n_stages


def test_plan_batch_power_cache_hit():
    clear_plan_cache()
    mats = [rmat_csr(3, 2, "ER", seed=70 + i) for i in range(3)]
    p1 = plan_batch_power(mats, 2)
    before = plan_cache_stats()
    p2 = plan_batch_power(mats, 2)
    after = plan_cache_stats()
    assert p2 is p1 and after["hits"] == before["hits"] + 1
    assert plan_cache_stats()["kinds"]["batch_power"] == 1


# ---------------------------------------------------------------------------
# Property-based layer (optional hypothesis extra; strategies in _fuzz.py)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(batch_case())
    @settings(max_examples=15, deadline=None)
    def test_property_batch_bitwise_equals_planned_loop(case):
        pairs, semiring = case
        plan = plan_batch(pairs, semiring=semiring)
        outs = plan.execute(pairs)
        for i, ((a, b), c) in enumerate(zip(pairs, outs)):
            ref = plan_spgemm(a, b, algorithm=plan.algorithms[i],
                              semiring=semiring).execute(a, b)
            _assert_bitwise(c, ref)
