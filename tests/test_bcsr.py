"""Differential suite for the planned BCSR subsystem (DESIGN.md sec. 17).

The oracle is ``scipy.sparse.bsr_matrix``: re-blocking a CSR must
reproduce scipy's BSR structure bit for bit (indptr + sorted block
columns), and the planned block product must reproduce the scipy BSR
product's structure exactly -- indptr bitwise, per-row block-column
*sets* (the kernel emits hash order; sortedness is not part of the
contract, per the paper's C8 finding) -- and its values bitwise on
dyadic inputs.  Both sides keep structurally-present but numerically
zero blocks (the structural-product contract), so the comparisons are
exact even for partially-filled tiles.

Also pinned here: the ragged-edge round-trip (``bcsr_to_csr(csr_to_bcsr
(a))`` preserves nnz exactly -- the prune epilogue regression), empty
rows / empty operands, sorted and unsorted inputs, semiring routing
(boolean never reaches the (+, x)-only block path), zero re-inspection
on repeat executes (counter-verified), and the ``"bcsr"`` plan-cache
kind.  The trace-context (jit/vmap) counter proofs live in
``tests/test_trace_contexts.py``; the hypothesis property layer at the
bottom consumes ``_fuzz.bcsr_case``.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp  # noqa: E402

from repro.core import (BCSRPlan, clear_plan_cache, plan_bcsr,  # noqa: E402
                        plan_cache_stats, plan_spgemm, spgemm)
from repro.core.formats import BCSR, bcsr_to_csr, csr_to_bcsr  # noqa: E402
from repro.core.recipe import choose_algorithm  # noqa: E402
from repro.kernels.spgemm_bcsr import ops as bcsr_ops  # noqa: E402
from repro.kernels.spgemm_bcsr import ref as bcsr_ref  # noqa: E402
from _fuzz import (block_clustered_dense, csr_of,  # noqa: E402
                   rand_dense, scramble_rows)

sp = pytest.importorskip("scipy.sparse")


def _bsr(d: np.ndarray, block):
    return sp.bsr_matrix(np.asarray(d, np.float32), blocksize=block)


def _assert_bcsr_matches_scipy(ours: BCSR, oracle) -> None:
    """Bitwise structure equality of a conversion against scipy BSR
    (both sides emit sorted block columns)."""
    nnzb = int(ours.nnzb)
    assert nnzb == oracle.indices.shape[0]
    assert np.array_equal(np.asarray(ours.indptr), oracle.indptr)
    assert np.array_equal(np.asarray(ours.indices)[:nnzb], oracle.indices)
    assert np.array_equal(np.asarray(ours.blocks)[:nnzb],
                          oracle.data.astype(np.float32))


def _assert_product_matches_scipy(c: BCSR, oracle) -> None:
    """Planned-product structure vs the scipy BSR product: indptr
    bitwise, block columns per row as sets (kernel order is hash order),
    dense values bitwise."""
    nnzb = int(c.nnzb)
    assert nnzb == oracle.indices.shape[0]
    ip = np.asarray(c.indptr)
    assert np.array_equal(ip, oracle.indptr)
    bcols = np.asarray(c.indices)[:nnzb]
    for i in range(len(ip) - 1):
        assert (set(bcols[ip[i]:ip[i + 1]].tolist())
                == set(oracle.indices[ip[i]:ip[i + 1]].tolist())), i
    assert np.array_equal(np.asarray(c.to_dense()),
                          np.asarray(oracle.todense(), np.float32))


# ---------------------------------------------------------------------------
# scipy BSR differential: conversion + planned product
# ---------------------------------------------------------------------------

BLOCK_GRID = [
    # (bm, bk, bn, gm, gk, gn): square and rectangular tiles, incl. 1x1
    (1, 1, 1, 5, 4, 6),
    (2, 2, 2, 4, 3, 5),
    (4, 4, 4, 3, 4, 2),
    (8, 8, 8, 2, 2, 2),
    (2, 4, 8, 3, 2, 2),
    (4, 2, 1, 2, 3, 4),
]


@pytest.mark.parametrize("bm,bk,bn,gm,gk,gn", BLOCK_GRID)
@pytest.mark.parametrize("density", (0.3, 0.7))
def test_csr_to_bcsr_matches_scipy_bsr(bm, bk, bn, gm, gk, gn, density):
    """Re-blocking a CSR reproduces scipy's BSR structure bitwise."""
    ad = block_clustered_dense(gm, gk, bm, bk, density, seed=bm * 100 + gk)
    ab = csr_to_bcsr(csr_of(ad), (bm, bk))
    _assert_bcsr_matches_scipy(ab, _bsr(ad, (bm, bk)))


@pytest.mark.parametrize("bm,bk,bn,gm,gk,gn", BLOCK_GRID)
def test_planned_product_matches_scipy_bsr(bm, bk, bn, gm, gk, gn):
    """The frozen block plan's product == scipy's BSR product: structure
    exactly (set order within rows), dense values bitwise."""
    ad = block_clustered_dense(gm, gk, bm, bk, 0.5, seed=7 * bm + bk)
    bd = block_clustered_dense(gk, gn, bk, bn, 0.5, seed=7 * bn + gk + 1)
    ab = csr_to_bcsr(csr_of(ad), (bm, bk))
    bb = csr_to_bcsr(csr_of(bd), (bk, bn))
    plan = plan_bcsr(ab, bb, cache=False)
    assert isinstance(plan, BCSRPlan) and plan.block_c == (bm, bn)
    c = plan.execute(ab, bb)
    _assert_product_matches_scipy(
        c, (_bsr(ad, (bm, bk)) @ _bsr(bd, (bk, bn))).astype(np.float32))


def test_partially_filled_tiles_keep_structural_zero_blocks():
    """A structurally-present product block whose values are all zero
    (tile misalignment, no cancellation) stays in the pattern on both
    sides -- the structural-product contract."""
    ad = np.zeros((4, 4), np.float32)
    ad[0, 0], ad[1, 0] = 1.0, 2.0       # A tile: nonzeros in tile col 0
    bd = np.zeros((4, 4), np.float32)
    bd[1, 0] = 1.0                      # B tile: nonzeros in tile row 1
    ab = csr_to_bcsr(csr_of(ad), (2, 2))
    bb = csr_to_bcsr(csr_of(bd), (2, 2))
    c = plan_bcsr(ab, bb, cache=False).execute(ab, bb)
    oracle = _bsr(ad, (2, 2)) @ _bsr(bd, (2, 2))
    assert int(c.nnzb) == 1 == oracle.indices.shape[0]
    _assert_product_matches_scipy(c, oracle)


def test_unsorted_input_rows():
    """Row-scrambled (unsorted) CSR input re-blocks to the same BCSR as
    its sorted twin -- the Table-1 unsorted-input case at block
    granularity."""
    ad = block_clustered_dense(4, 4, 4, 4, 0.5, seed=13)
    srt = csr_to_bcsr(csr_of(ad), (4, 4))
    uns = csr_to_bcsr(scramble_rows(csr_of(ad)), (4, 4))
    assert int(srt.nnzb) == int(uns.nnzb)
    assert np.array_equal(np.asarray(srt.indptr), np.asarray(uns.indptr))
    assert np.array_equal(np.asarray(srt.to_dense()),
                          np.asarray(uns.to_dense()))


def test_empty_rows_and_empty_operands():
    """Empty block rows, an all-zero A, and an all-zero product are all
    legal plans that execute to the correct (empty) result."""
    ad = block_clustered_dense(4, 3, 2, 2, 0.6, seed=17)
    ad[2:4, :] = 0.0                    # empty block row
    bd = block_clustered_dense(3, 4, 2, 2, 0.6, seed=18)
    ab, bb = csr_to_bcsr(csr_of(ad), (2, 2)), csr_to_bcsr(csr_of(bd), (2, 2))
    c = plan_bcsr(ab, bb, cache=False).execute(ab, bb)
    _assert_product_matches_scipy(c, (_bsr(ad, (2, 2)) @ _bsr(bd, (2, 2))))

    z = BCSR.from_dense(jnp.zeros((8, 6), jnp.float32), (2, 2))
    plan = plan_bcsr(z, bb, cache=False)
    assert int(plan.nnzb_c) == 0
    out = np.asarray(plan.execute(z, bb).to_dense())
    assert out.shape == (8, 8) and not out.any()


# ---------------------------------------------------------------------------
# ragged edges: non-tile-multiple shapes + the prune-epilogue regression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,block", [
    ((19, 23), (4, 4)), ((19, 23), (8, 8)), ((7, 5), (2, 4)),
    ((9, 16), (4, 4)), ((16, 9), (8, 2)),
])
def test_ragged_roundtrip_preserves_nnz(shape, block):
    """``bcsr_to_csr(csr_to_bcsr(a))`` on non-tile-multiple shapes is the
    identity: same nnz as the input (the prune epilogue drops the zero
    padding the partial edge tiles store), same dense view."""
    ad = rand_dense(shape[0], shape[1], 0.35, seed=shape[0] + block[0])
    a = csr_of(ad)
    rt = bcsr_to_csr(csr_to_bcsr(a, block))
    assert int(rt.nnz) == int(a.nnz) == int(np.count_nonzero(ad))
    assert np.array_equal(np.asarray(rt.to_dense()), ad)


def test_ragged_planned_product_matches_dense():
    """Planned block product on ragged shapes with rectangular tiles is
    bitwise the dense oracle (partial edge tiles are zero-padded storage;
    the logical shape crops back)."""
    ad = rand_dense(19, 23, 0.4, seed=23)
    bd = rand_dense(23, 17, 0.4, seed=24)
    ab = csr_to_bcsr(csr_of(ad), (4, 4))
    bb = csr_to_bcsr(csr_of(bd), (4, 8))
    plan = plan_bcsr(ab, bb, cache=False)
    got = np.asarray(plan.execute(ab, bb).to_dense())
    assert got.shape == (19, 17)
    assert np.array_equal(got, ad @ bd)
    assert np.array_equal(got, np.asarray(bcsr_ref.numeric_ref(ab, bb)))


# ---------------------------------------------------------------------------
# inspector-executor contract: zero re-inspection, cache kind, dispatcher
# ---------------------------------------------------------------------------

def test_repeat_execute_zero_reinspection():
    """A frozen ``BCSRPlan`` re-inspects nothing: repeat executes run the
    numeric kernel only, proven by the block kernel's call counters."""
    ad = block_clustered_dense(4, 3, 4, 4, 0.6, seed=29)
    bd = block_clustered_dense(3, 4, 4, 4, 0.6, seed=30)
    ab, bb = csr_to_bcsr(csr_of(ad), (4, 4)), csr_to_bcsr(csr_of(bd), (4, 4))
    plan = plan_bcsr(ab, bb, cache=False)
    bcsr_ops.reset_kernel_calls()
    for _ in range(3):
        plan.execute(ab, bb).blocks.block_until_ready()
    calls = bcsr_ops.kernel_call_counts()
    assert calls["symbolic"] == 0, calls
    assert calls["numeric"] == 3, calls


def test_plan_cache_bcsr_kind():
    """``plan_bcsr`` lands in the shared LRU under the ``"bcsr"`` kind;
    a repeat plan on the same structures is a hit that re-inspects
    nothing."""
    clear_plan_cache()
    ad = block_clustered_dense(3, 3, 4, 4, 0.7, seed=31)
    bd = block_clustered_dense(3, 3, 4, 4, 0.7, seed=32)
    ab, bb = csr_to_bcsr(csr_of(ad), (4, 4)), csr_to_bcsr(csr_of(bd), (4, 4))
    p1 = plan_bcsr(ab, bb)
    stats = plan_cache_stats()
    assert stats["kinds"]["bcsr"] >= 1, stats
    bcsr_ops.reset_kernel_calls()
    p2 = plan_bcsr(ab, bb)
    assert p2 is p1
    assert bcsr_ops.kernel_call_counts()["symbolic"] == 0
    assert plan_cache_stats()["hits"] > stats["hits"]


def test_plan_spgemm_bcsr_routing_end_to_end():
    """``plan_spgemm(algorithm="bcsr")`` nests a frozen block plan and
    its CSR-in/CSR-out execute matches the hash planned path bitwise."""
    ad = block_clustered_dense(3, 3, 8, 8, 0.8, seed=33)
    bd = block_clustered_dense(3, 3, 8, 8, 0.8, seed=34)
    a, b = csr_of(ad), csr_of(bd)
    plan = plan_spgemm(a, b, algorithm="bcsr", cache=False)
    assert plan.algorithm == "bcsr"
    assert isinstance(plan.bcsr_plan, BCSRPlan)
    got = plan.execute(a, b)
    ref = plan_spgemm(a, b, algorithm="hash", cache=False).execute(a, b)
    assert int(got.nnz) == int(ref.nnz)
    assert np.array_equal(np.asarray(got.to_dense()),
                          np.asarray(ref.to_dense()))


# ---------------------------------------------------------------------------
# semiring coverage: boolean never reaches the (+, x)-only block path
# ---------------------------------------------------------------------------

def test_boolean_routing_and_explicit_rejection():
    """The recipe never routes boolean products to bcsr; pinning bcsr
    with a general semiring raises; the boolean product on block-dense
    input still computes correctly through the hash family."""
    ad = block_clustered_dense(3, 3, 8, 8, 0.9, seed=35)
    a = csr_of(ad)
    assert choose_algorithm(a, a, probe_blocks=True) == "bcsr"
    assert choose_algorithm(a, a, probe_blocks=True,
                            semiring="boolean") != "bcsr"
    with pytest.raises(NotImplementedError):
        plan_spgemm(a, a, algorithm="bcsr", semiring="boolean", cache=False)
    with pytest.raises(NotImplementedError):
        spgemm(a, a, cap_c=a.n_rows * a.n_rows, algorithm="bcsr",
               semiring="boolean")
    out = spgemm(a, a, cap_c=int((np.count_nonzero(ad @ ad))),
                 semiring="boolean")
    got = np.asarray(out.to_dense())
    assert np.array_equal(got != 0, (ad @ ad) != 0)
    assert set(np.unique(got)) <= {0.0, 1.0}


# ---------------------------------------------------------------------------
# vector-probe variant parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("vector", (False, True))
def test_vector_probe_variant_bitwise(vector):
    """Scalar and vectorized block probes agree bitwise with scipy."""
    ad = block_clustered_dense(3, 4, 4, 4, 0.6, seed=37)
    bd = block_clustered_dense(4, 3, 4, 4, 0.6, seed=38)
    ab, bb = csr_to_bcsr(csr_of(ad), (4, 4)), csr_to_bcsr(csr_of(bd), (4, 4))
    plan = plan_bcsr(ab, bb, vector=vector, cache=False)
    c = plan.execute(ab, bb)
    _assert_product_matches_scipy(
        c, (_bsr(ad, (4, 4)) @ _bsr(bd, (4, 4))).astype(np.float32))


# ---------------------------------------------------------------------------
# hypothesis property layer (optional extra)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from _fuzz import bcsr_case
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(bcsr_case())
    def test_fuzz_planned_bcsr_vs_scipy(case):
        """Property layer: any block-clustered product (rectangular
        tiles, thinned tiles, empty operands) planned and executed
        through the block path matches the scipy BSR oracle exactly."""
        ad, bd, (bm, bk, bn) = case
        ab = csr_to_bcsr(csr_of(ad), (bm, bk))
        bb = csr_to_bcsr(csr_of(bd), (bk, bn))
        _assert_bcsr_matches_scipy(ab, _bsr(ad, (bm, bk)))
        plan = plan_bcsr(ab, bb, cache=False)
        c = plan.execute(ab, bb)
        _assert_product_matches_scipy(
            c, (_bsr(ad, (bm, bk)) @ _bsr(bd, (bk, bn))).astype(np.float32))
        rt = bcsr_to_csr(c)
        assert np.array_equal(np.asarray(rt.to_dense()),
                              np.asarray(c.to_dense()))
