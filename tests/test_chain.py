"""Chain-composition subsystem (DESIGN.md section 12).

Contracts:
  * ``ChainPlan.execute`` for R.A.P and A^3 matches an independent
    scipy/numpy oracle across semirings x masks x sorted/unsorted final
    output, with intermediates kept unsorted;
  * a sorted-final chain bit-matches the composed per-product planned
    path (stage plans are the same frozen inspections);
  * repeated ``galerkin`` calls hit the chain cache (zero new
    inspections), including on re-weighted operands;
  * ``gram`` is a transpose-aware A^T A (values-only regather on repeat);
  * the distributed chain equals the single-node chain after reassembly;
  * ``recommend(a_row_nnz=...)`` keys the A-side stats on recorded
    intermediate structure (the mid-chain recipe hook);
  * MCL on a planted-partition graph converges and recovers the planted
    clusters (the structure-drift workload pin).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

sp = pytest.importorskip("scipy.sparse")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import (CSR, chained_flop_bound, clear_plan_cache,  # noqa: E402
                        csr_transpose, finalize, flops_per_row, galerkin,
                        gram, plan_cache_stats, plan_chain,
                        plan_chain_1d, plan_galerkin, plan_gram, plan_power,
                        plan_spgemm, recommend, shard_csr_rows, spgemm,
                        unshard_rows)
from repro.data.rmat import aggregation_csr, rmat_csr

SEMIRINGS = ("plus_times", "boolean", "min_plus", "plus_first")


# ---------------------------------------------------------------------------
# Oracles and builders
# ---------------------------------------------------------------------------

from _oracles import semiring_oracle as _oracle_product  # noqa: E402


def _oracle_chain(mats, sr_name: str, mask=None, complement=False):
    cur = np.asarray(mats[0].to_dense())
    for b in mats[1:]:
        cur = _oracle_product(cur, np.asarray(b.to_dense()), sr_name)
    if mask is not None:
        md = np.asarray(mask.to_dense()) != 0
        keep = ~md if complement else md
        cur = np.where(keep, cur, 0)
    return cur


def _rap(seed=3, scale=5, ef=3):
    a = rmat_csr(scale, ef, "G500", seed=seed)
    r, p = aggregation_csr(a.n_rows, a.n_rows // 4, seed=seed)
    return r, a, p


def _rand_mask(shape, density=0.4, seed=11):
    rng = np.random.default_rng(seed)
    dense = (rng.random(shape) < density).astype(np.float32)
    return CSR.from_dense(jnp.asarray(dense))


# ---------------------------------------------------------------------------
# Differential grid: R.A.P and A^3
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("masked", ("none", "mask", "complement"))
@pytest.mark.parametrize("sorted_output", (False, True))
def test_rap_differential(semiring, masked, sorted_output):
    r, a, p = _rap()
    mask = None if masked == "none" else \
        _rand_mask((r.n_rows, p.n_cols))
    complement = masked == "complement"
    oracle = _oracle_chain([r, a, p], semiring, mask, complement)

    plan = plan_galerkin(r, a, p, semiring=semiring, mask=mask,
                         complement_mask=complement,
                         sorted_output=sorted_output, cache=False)
    c = plan.execute(r, a, p)
    if sorted_output:
        assert c.sorted_cols
    assert np.allclose(np.asarray(c.to_dense()), oracle, atol=1e-3), \
        (semiring, masked, sorted_output)


@pytest.mark.parametrize("semiring", ("plus_times", "boolean"))
def test_power3_differential(semiring):
    a = rmat_csr(5, 3, "G500", seed=9)
    oracle = _oracle_chain([a, a, a], semiring)
    plan = plan_power(a, 3, semiring=semiring, sorted_output=True,
                      cache=False)
    c = plan.execute(a, a, a)
    assert np.allclose(np.asarray(c.to_dense()), oracle, atol=1e-3)
    # intermediates were kept unsorted whenever the stage emits select
    # order (the hash family); the *final* output is sorted on request
    assert c.sorted_cols


def test_sorted_final_bitmatches_composed_per_product_path():
    r, a, p = _rap(seed=4)
    chain = plan_galerkin(r, a, p, algorithm="hash_jnp", sorted_output=True,
                          cache=False)
    c = chain.execute(r, a, p)
    p1 = plan_spgemm(r, a, algorithm="hash_jnp", cache=False)
    c1 = p1.execute(r, a)
    p2 = plan_spgemm(c1, p, algorithm="hash_jnp", sorted_output=True,
                     cache=False)
    c_comp = p2.execute(c1, p)
    for field in ("indptr", "indices", "data"):
        assert np.array_equal(np.asarray(getattr(c, field)),
                              np.asarray(getattr(c_comp, field))), field
    assert int(c.nnz) == int(c_comp.nnz)


def test_chain_execute_rejects_wrong_structure():
    r, a, p = _rap(seed=5)
    plan = plan_galerkin(r, a, p, cache=False)
    with pytest.raises(AssertionError):
        plan.execute(r, a, a)          # wrong final operand shape
    with pytest.raises(AssertionError):
        plan.execute(r, a)             # wrong operand count


def test_chain_sorted_output_override():
    a = rmat_csr(5, 3, "G500", seed=6)
    plan = plan_power(a, 3, algorithm="hash_jnp", sorted_output=False,
                      cache=False)
    c_un = plan.execute(a, a, a)
    assert not c_un.sorted_cols
    c_so = plan.execute(a, a, a, sorted_output=True)
    assert c_so.sorted_cols
    assert np.allclose(np.asarray(c_un.to_dense()),
                       np.asarray(c_so.to_dense()))


# ---------------------------------------------------------------------------
# Plan cache behaviour
# ---------------------------------------------------------------------------

def test_repeat_galerkin_hits_chain_cache():
    r, a, p = _rap(seed=7)
    clear_plan_cache()
    c1 = galerkin(r, a, p, sorted_output=True)
    stats1 = plan_cache_stats()
    assert stats1["kinds"].get("chain") == 1
    c2 = galerkin(r, a, p, sorted_output=True)
    stats2 = plan_cache_stats()
    assert stats2["misses"] == stats1["misses"], \
        "repeat galerkin must replan nothing"
    assert stats2["hits"] > stats1["hits"]
    assert np.array_equal(np.asarray(c1.to_dense()),
                          np.asarray(c2.to_dense()))
    # a re-weighted A (same adjacency) also reuses the frozen chain
    a2 = CSR(a.indptr, a.indices, a.data * 3.0, a.nnz, a.shape,
             a.sorted_cols)
    before = plan_cache_stats()
    c3 = galerkin(r, a2, p, sorted_output=True)
    assert plan_cache_stats()["misses"] == before["misses"]
    assert np.allclose(np.asarray(c3.to_dense()),
                       3.0 * np.asarray(c1.to_dense()), atol=1e-3)


# ---------------------------------------------------------------------------
# Transpose + gram
# ---------------------------------------------------------------------------

def test_csr_transpose_and_perm():
    a = rmat_csr(5, 3, "G500", seed=8)
    ad = np.asarray(a.to_dense())
    t, perm = csr_transpose(a, return_perm=True)
    assert t.shape == (a.n_cols, a.n_rows) and t.sorted_cols
    assert np.allclose(np.asarray(t.to_dense()), ad.T)
    nnz = int(a.nnz)
    regather = np.asarray(a.data)[np.asarray(perm)][:nnz]
    assert np.array_equal(regather, np.asarray(t.data)[:nnz])
    # transpose of an *unsorted* CSR (hash-family output) is still exact
    u = spgemm(a, a, int((ad @ ad != 0).sum()), algorithm="hash_jnp")
    assert not u.sorted_cols
    tu = csr_transpose(u)
    assert np.allclose(np.asarray(tu.to_dense()),
                       np.asarray(u.to_dense()).T, atol=1e-3)


def test_gram_matches_scipy_and_regathers_values_only():
    a = rmat_csr(5, 3, "G500", seed=10)
    ad = np.asarray(a.to_dense())
    oracle = np.asarray((sp.csr_matrix(ad).T @ sp.csr_matrix(ad)).todense(),
                        np.float32)
    clear_plan_cache()
    g = gram(a, sorted_output=True)
    assert np.allclose(np.asarray(g.to_dense()), oracle, atol=1e-3)
    # re-weighted operand: same plan, values regathered through the frozen
    # transpose permutation
    a2 = CSR(a.indptr, a.indices, a.data * 2.0, a.nnz, a.shape,
             a.sorted_cols)
    before = plan_cache_stats()
    g2 = plan_gram(a2, sorted_output=True).execute(a2)
    assert plan_cache_stats()["misses"] == before["misses"]
    assert np.allclose(np.asarray(g2.to_dense()), 4.0 * oracle, atol=1e-2)


# ---------------------------------------------------------------------------
# Distributed chain
# ---------------------------------------------------------------------------

def test_distributed_chain_matches_single_node():
    n_dev = len(jax.devices())
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    a = rmat_csr(5, 3, "G500", seed=12)
    b = rmat_csr(5, 3, "ER", seed=13)
    a_sh = shard_csr_rows(a, n_dev, b=b)
    clear_plan_cache()
    dplan = plan_chain_1d(a_sh, [b, a], algorithm="hash",
                          sorted_output=True)
    c = unshard_rows(dplan.execute(mesh, a_sh, b, a))
    single = plan_chain([a, b, a], algorithm="hash_jnp",
                        sorted_output=True, cache=False)
    c_ref = single.execute(a, b, a)
    assert np.allclose(np.asarray(c.to_dense()),
                       np.asarray(c_ref.to_dense()), atol=1e-3)
    assert c.sorted_cols
    # repeat plan is one cache hit, zero new inspections
    before = plan_cache_stats()
    dplan2 = plan_chain_1d(a_sh, [b, a], algorithm="hash",
                           sorted_output=True)
    after = plan_cache_stats()
    assert dplan2 is dplan and after["misses"] == before["misses"]
    assert after["kinds"].get("chain_1d") == 1


# ---------------------------------------------------------------------------
# Mid-chain recipe hook + capacity bound math
# ---------------------------------------------------------------------------

def test_recommend_a_row_nnz_keys_a_side_stats_on_recorded_structure():
    a = rmat_csr(5, 3, "G500", seed=14)
    b = rmat_csr(5, 3, "ER", seed=15)
    _, stats_default = recommend(a, b)
    recorded = np.asarray(a.row_nnz()) * 4      # a denser recorded structure
    _, stats_hook = recommend(a, b, a_row_nnz=recorded)
    assert stats_hook.nnz_a == pytest.approx(4 * stats_default.nnz_a)
    assert stats_hook.density_ef == pytest.approx(4 * stats_default.density_ef)
    assert stats_hook.mean_row_nnz_a == \
        pytest.approx(4 * stats_default.mean_row_nnz_a)
    # flop-side stats still come from the real materialized structure
    assert stats_hook.flop == stats_default.flop
    # the hook reaches the plan cache key: same structures, different
    # recorded stats must not collide
    clear_plan_cache()
    p1 = plan_spgemm(a, b)
    p2 = plan_spgemm(a, b, a_row_nnz=jnp.asarray(recorded))
    assert p1.key != p2.key
    assert plan_cache_stats()["misses"] == 2


def test_chain_stage_recipes_see_intermediate_stats():
    """Stage >= 1 of an auto chain consumes the previous stage's recorded
    row_nnz_c -- the recorded choice must match a direct recommend on the
    materialized intermediate with those stats."""
    r, a, p = _rap(seed=16)
    chain = plan_galerkin(r, a, p, algorithm="auto", cache=False)
    inter = chain.stages[0].execute(r, a)
    algo, _ = recommend(inter, p, sorted_output=False, use_case="AxA",
                        row_nnz_c=chain.stages[1].row_nnz_c,
                        a_row_nnz=chain.stages[0].row_nnz_c)
    expect = algo
    if expect == "heap" and not (inter.sorted_cols and p.sorted_cols):
        expect = "hash"
    assert chain.stages[1].algorithm == expect


def test_chained_flop_bound_dominates_real_flops():
    a = rmat_csr(5, 3, "G500", seed=17)
    b = rmat_csr(5, 3, "ER", seed=18)
    plan = plan_spgemm(a, b, cache=False)
    inter = plan.execute(a, b)
    bound = np.asarray(chained_flop_bound(plan.row_nnz_c, a))
    real = np.asarray(flops_per_row(inter, a))
    assert (bound >= real).all()


def test_finalize_is_the_single_sort_site():
    a = rmat_csr(5, 3, "G500", seed=19)
    cd = np.asarray(a.to_dense()) @ np.asarray(a.to_dense())
    u = spgemm(a, a, int((cd != 0).sum()), algorithm="hash_jnp")
    assert not u.sorted_cols
    s = finalize(u, True)
    assert s.sorted_cols and finalize(s, True) is s
    assert finalize(u, False) is u
    assert np.allclose(np.asarray(s.to_dense()), cd, atol=1e-3)


# ---------------------------------------------------------------------------
# MCL convergence pin (examples/mcl.py)
# ---------------------------------------------------------------------------

def test_mcl_recovers_planted_clusters():
    from examples.mcl import clustered_graph, mcl
    n_clusters, size = 3, 12
    a = clustered_graph(n_clusters, size, seed=0)
    labels, n_iters = mcl(a, max_iters=40)
    assert n_iters < 40, "MCL must converge on the planted-partition graph"
    truth = np.repeat(np.arange(n_clusters), size)
    blocks = [set(labels[truth == k]) for k in range(n_clusters)]
    assert all(len(s) == 1 for s in blocks), blocks
    assert len({next(iter(s)) for s in blocks}) == n_clusters, blocks
