"""Checkpointing: round-trip, async, atomicity, GC, restart resume."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import ARCHS, reduced
from repro.parallel.sharding import single_device_ctx
from repro.train import optimizer as opt, step as step_lib, loop as loop_lib

CFG = reduced(ARCHS["qwen3-0.6b"], d_model=64, vocab=64)
PCTX = single_device_ctx(remat=False, attn_impl="full")
OCFG = opt.AdamWConfig(lr=1e-2)


def test_roundtrip(tmp_path):
    state = step_lib.init_state(jax.random.PRNGKey(0), CFG, OCFG)
    ck = Checkpointer(str(tmp_path))
    ck.save(5, state, blocking=True)
    assert ck.latest_step() == 5
    restored = ck.restore(5, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_async_and_gc(tmp_path):
    state = {"x": jnp.arange(10)}
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    ck.wait()
    assert ck.list_steps() == [3, 4]


def test_atomicity_no_tmp_left(tmp_path):
    state = {"x": jnp.arange(4)}
    ck = Checkpointer(str(tmp_path))
    ck.save(1, state, blocking=True)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_restart_bitwise_resume(tmp_path):
    lcfg = loop_lib.LoopConfig(total_steps=12, ckpt_every=6, log_every=6,
                               global_batch=4, seq_len=16,
                               ckpt_dir=str(tmp_path))
    s_full, _ = loop_lib.run(CFG, PCTX, OCFG, lcfg)
    # simulate crash after step 6: drop the final checkpoint, rerun
    shutil.rmtree(os.path.join(tmp_path, "step_00000012"))
    s_resumed, _ = loop_lib.run(CFG, PCTX, OCFG, lcfg)
    for a, b in zip(jax.tree.leaves(s_full.params),
                    jax.tree.leaves(s_resumed.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_failure_injection(tmp_path):
    lcfg = loop_lib.LoopConfig(total_steps=10, ckpt_every=4, log_every=5,
                               global_batch=4, seq_len=16,
                               ckpt_dir=str(tmp_path), fail_at_step=6)
    with pytest.raises(RuntimeError, match="injected failure"):
        loop_lib.run(CFG, PCTX, OCFG, lcfg)
    # a checkpoint at step 4 survives the crash
    ck = Checkpointer(str(tmp_path))
    assert ck.latest_step() == 4
