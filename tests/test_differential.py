"""Differential SpGEMM suite: every algorithm against a scipy oracle.

Each executable algorithm (esc / heap / hash / hash_jnp) is compared to an
independent scipy.sparse (plus_times) or numpy (other semirings) oracle
across semirings, masks (plain + complemented), sorted/unsorted output
requests, rectangular shapes, and empty-row/empty-matrix edge cases.

The deterministic grid below runs everywhere; the property-based layer at
the bottom additionally fuzzes structures when the optional ``hypothesis``
extra is installed (guarded like the other property suites -- absence
skips only that layer, never the grid).

Values are drawn from dyadic rationals ({0.5, 1.0, 1.5, 2.0}) so fp32
products and sums are exact and every comparison can be bitwise; they are
also strictly positive, which sidesteps the dense-oracle explicit-zero
caveat documented on ``spgemm_dense``.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

sp = pytest.importorskip("scipy.sparse")

import jax.numpy as jnp  # noqa: E402

from repro.core import CSR, spgemm, spgemm_heap  # noqa: E402

from _fuzz import csr_of as _csr, rand_dense as _rand_dense  # noqa: E402

try:
    from hypothesis import given, settings
    from _fuzz import product_case, traced_context_case
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ALGOS = ("esc", "heap", "hash", "hash_jnp")
SEMIRINGS = ("plus_times", "boolean", "min_plus", "plus_first")


# ---------------------------------------------------------------------------
# Oracles and builders
# ---------------------------------------------------------------------------

# Single shared implementation (tests/_oracles.py): plus_times/boolean go
# through scipy.sparse, the rest are numpy; also used by test_chain.py.
from _oracles import semiring_oracle as _oracle  # noqa: E402


def _mask_after(c: np.ndarray, mask_d: np.ndarray,
                complement: bool) -> np.ndarray:
    keep = (mask_d == 0) if complement else (mask_d != 0)
    return np.where(keep, c, 0.0)


def _run(a: CSR, b: CSR, algo: str, cap: int, **kw) -> CSR:
    return spgemm(a, b, cap, algorithm=algo, **kw)


# ---------------------------------------------------------------------------
# Deterministic grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("algo", ALGOS)
def test_semiring_matches_scipy_oracle(algo, semiring):
    """Rectangular (9, 7) x (7, 11) product, all semirings x algorithms."""
    ad = _rand_dense(9, 7, 0.35, seed=1)
    bd = _rand_dense(7, 11, 0.35, seed=2)
    a, b = _csr(ad), _csr(bd)
    cd = _oracle(ad, bd, semiring)
    c = _run(a, b, algo, cap=9 * 11, semiring=semiring)
    assert np.array_equal(np.asarray(c.to_dense()), cd), (algo, semiring)


@pytest.mark.parametrize("complement", (False, True))
@pytest.mark.parametrize("algo", ALGOS)
def test_masked_matches_oracle(algo, complement):
    ad = _rand_dense(8, 8, 0.4, seed=3)
    bd = _rand_dense(8, 8, 0.4, seed=4)
    md = _rand_dense(8, 8, 0.5, seed=5)
    a, b, mask = _csr(ad), _csr(bd), _csr(md)
    cd = _mask_after(_oracle(ad, bd, "plus_times"), md, complement)
    c = _run(a, b, algo, cap=64, mask=mask, complement_mask=complement)
    assert np.array_equal(np.asarray(c.to_dense()), cd), (algo, complement)


@pytest.mark.parametrize("algo", ALGOS)
def test_sorted_output_contract(algo):
    """sorted_output=True yields strictly increasing columns per row; the
    hash family's raw output keeps its unsorted (C8) flag."""
    ad = _rand_dense(10, 10, 0.4, seed=6)
    bd = _rand_dense(10, 10, 0.4, seed=7)
    a, b = _csr(ad), _csr(bd)
    cd = _oracle(ad, bd, "plus_times")
    c = _run(a, b, algo, cap=100, sorted_output=True)
    assert c.sorted_cols
    cols, ip = np.asarray(c.indices), np.asarray(c.indptr)
    for i in range(c.n_rows):
        assert np.all(np.diff(cols[ip[i]:ip[i + 1]]) > 0), (algo, i)
    assert np.array_equal(np.asarray(c.to_dense()), cd)
    raw = _run(a, b, algo, cap=100)
    assert raw.sorted_cols == (algo in ("esc", "heap"))
    assert np.array_equal(np.asarray(raw.to_dense()), cd)


@pytest.mark.parametrize("algo", ALGOS)
def test_empty_matrix_and_empty_rows(algo):
    # completely empty A
    empty = CSR.from_numpy_coo(np.zeros(0, np.int64), np.zeros(0, np.int64),
                               np.zeros(0, np.float32), (6, 5), cap=8)
    bd = _rand_dense(5, 7, 0.5, seed=8)
    b = _csr(bd)
    c = _run(empty, b, algo, cap=8)
    assert int(c.nnz) == 0
    assert np.array_equal(np.asarray(c.to_dense()), np.zeros((6, 7)))
    # empty x empty
    empty_b = CSR.from_numpy_coo(np.zeros(0, np.int64),
                                 np.zeros(0, np.int64),
                                 np.zeros(0, np.float32), (5, 7), cap=8)
    c2 = _run(empty, empty_b, algo, cap=8)
    assert int(c2.nnz) == 0
    # A with interior empty rows / B with empty columns
    ad = _rand_dense(8, 6, 0.5, seed=9)
    ad[[1, 4], :] = 0.0
    bd2 = _rand_dense(6, 8, 0.5, seed=10)
    bd2[:, [0, 5]] = 0.0
    a = _csr(ad)
    cd = _oracle(ad, bd2, "plus_times")
    c3 = _run(a, _csr(bd2), algo, cap=64)
    assert np.array_equal(np.asarray(c3.to_dense()), cd), algo
    ip = np.asarray(c3.indptr)
    assert ip[2] == ip[1] and ip[5] == ip[4]    # empty rows stay empty


def test_unsorted_inputs_route_and_heap_refuses():
    """esc/hash accept unsorted inputs; heap fails loudly (its contract)."""
    ad = _rand_dense(8, 8, 0.4, seed=11)
    bd = _rand_dense(8, 8, 0.4, seed=12)
    a = _csr(ad)
    # scramble within rows: reverse each row's entries, flag unsorted
    ip, ind, dat = (np.asarray(a.indptr), np.asarray(a.indices).copy(),
                    np.asarray(a.data).copy())
    for i in range(a.n_rows):
        ind[ip[i]:ip[i + 1]] = ind[ip[i]:ip[i + 1]][::-1]
        dat[ip[i]:ip[i + 1]] = dat[ip[i]:ip[i + 1]][::-1]
    au = CSR(jnp.asarray(ip), jnp.asarray(ind), jnp.asarray(dat),
             a.nnz, a.shape, sorted_cols=False)
    b = _csr(bd)
    cd = _oracle(ad, bd, "plus_times")
    for algo in ("esc", "hash", "hash_jnp"):
        c = _run(au, b, algo, cap=64)
        assert np.array_equal(np.asarray(c.to_dense()), cd), algo
    with pytest.raises(AssertionError, match="sorted inputs"):
        spgemm_heap(au, b, row_cap=8, k_width=au.cap)


# ---------------------------------------------------------------------------
# Property-based layer (optional hypothesis extra; strategies in _fuzz.py,
# shared with the batched-fleet fuzz in test_batch.py)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(product_case())
    @settings(max_examples=25, deadline=None)
    def test_property_all_algorithms_match_oracle(case):
        ad, bd, md, complement, semiring, algo = case
        a, b = _csr(ad), _csr(bd)
        mask = _csr(md) if md is not None else None
        cd = _oracle(ad, bd, semiring)
        if md is not None:
            cd = _mask_after(cd, md, complement)
        c = spgemm(a, b, ad.shape[0] * bd.shape[1], algorithm=algo,
                   semiring=semiring, mask=mask, complement_mask=complement)
        assert np.array_equal(np.asarray(c.to_dense()), cd), \
            (algo, semiring, complement)

    @given(traced_context_case())
    @settings(max_examples=10, deadline=None)
    def test_property_traced_contexts_match_oracle(case):
        """One structure-frozen hash plan, executed under vmap / inside a
        shard_map body / both nested, matches the scipy oracle bitwise per
        member -- and the counters prove the Pallas kernel (not the jnp
        twin) was the thing staged into the traced program."""
        from _fuzz import run_planned_hash_in_context
        ad, bd, member_vals, context, vector = case
        a, b = _csr(ad), _csr(bd)
        dense, counts = run_planned_hash_in_context(a, b, member_vals,
                                                    context, vector=vector)
        r, ccol = np.nonzero(ad)
        for e in range(member_vals.shape[0]):
            ad_e = ad.copy()
            ad_e[r, ccol] = member_vals[e]
            cd = _oracle(ad_e, bd, "plus_times")
            assert np.array_equal(dense[e], cd), (context, e)
        if context in ("vmap", "both"):
            assert counts["batched_numeric"] > 0, counts
        else:
            assert counts["numeric"] > 0, counts
