"""Multi-device behaviour via subprocess (XLA host-device-count must be set
before jax initializes, so these run as child processes)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, n_dev: int = 4, timeout=600):
    env = dict(os.environ)
    # replace (not append to) any inherited device-count flag: the CI
    # multi-device job exports one globally and XLA rejects duplicates
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        flags + [f"--xla_force_host_platform_device_count={n_dev}"])
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# Host-side sharding (no mesh needed -- runs in-process)
# ---------------------------------------------------------------------------

def test_shard_csr_rows_sparse_native_equal_flop_roundtrip():
    """shard_csr_rows must never densify, must cut equal-flop boundaries,
    and must round-trip exactly through unshard_rows."""
    import numpy as np
    from repro.core import CSR
    from repro.core.distributed import shard_csr_rows, unshard_rows
    from repro.core.schedule import flops_per_row
    from repro.data.rmat import rmat_csr

    a = rmat_csr(5, 4, "G500", seed=0)
    b = rmat_csr(5, 4, "ER", seed=1)
    calls = {"n": 0}
    orig = CSR.to_dense

    def spy(self):
        calls["n"] += 1
        return orig(self)

    CSR.to_dense = spy
    try:
        sh = shard_csr_rows(a, 4, b=b)
    finally:
        CSR.to_dense = orig
    assert calls["n"] == 0, "shard_csr_rows must stay sparse-native"

    rt = unshard_rows(sh)
    assert rt.shape == a.shape and rt.sorted_cols == a.sorted_cols
    assert int(rt.nnz) == int(a.nnz)
    assert np.array_equal(np.asarray(rt.to_dense()), np.asarray(a.to_dense()))

    # equal-flop invariant: every shard <= ceil(total/S) + max row flop
    flop = np.asarray(flops_per_row(a, b)).astype(np.int64)
    total, S = int(flop.sum()), 4
    starts = sh.row_starts
    assert starts[0] == 0 and starts[-1] == a.n_rows
    for s in range(S):
        part = int(flop[starts[s]:starts[s + 1]].sum())
        assert part <= -(-total // S) + int(flop.max()), (s, part)


def test_summa_panel_bounds_pins_panel_count():
    """k_panels is honored, never silently ignored (dead-arg regression)."""
    import pytest
    from repro.core.distributed import summa_panel_bounds

    bounds = summa_panel_bounds(64, 8, 16)
    assert len(bounds) == 16
    assert bounds[0] == (0, 4) and bounds[-1] == (60, 64)
    assert summa_panel_bounds(64, 8) == summa_panel_bounds(64, 8, 8)
    with pytest.raises(ValueError, match="multiple of the mesh axis"):
        summa_panel_bounds(64, 8, 12)
    with pytest.raises(ValueError, match="exceeds the contraction dim"):
        summa_panel_bounds(64, 8, 128)


def test_equal_weight_partition_degenerates_rebalance():
    """All-zero weights must split rows evenly, not pile every cut at n
    (the old zero-total prefix handed part 0 all rows and left every
    other part empty); with more parts than rows the empties spread."""
    import numpy as np
    from repro.core.schedule import equal_weight_partition

    starts = np.asarray(equal_weight_partition(np.zeros(8, np.int64), 4))
    assert starts[0] == 0 and starts[-1] == 8
    assert np.diff(starts).max() == 2, starts  # fails pre-fix: [8, 0, 0, 0]

    starts = np.asarray(equal_weight_partition(np.zeros(3, np.int64), 8))
    assert starts[0] == 0 and starts[-1] == 3
    assert np.all(np.diff(starts) >= 0)
    assert np.diff(starts).max() == 1, starts  # empties spread, not piled


def test_summa_panel_bounds_ragged_tail():
    """K need not divide evenly: a prime K schedules with a short final
    panel (the old code raised 'must divide' here)."""
    from repro.core.distributed import summa_panel_bounds

    bounds = summa_panel_bounds(13, 2)
    assert bounds == ((0, 7), (7, 13))
    # invariants every executor relies on: contiguous cover of [0, K),
    # first panel widest (buffers are sized off it), monotone bounds
    for k_dim, s, kp in ((13, 2, 2), (97, 4, 8), (10, 2, 8), (31, 1, 16)):
        b = summa_panel_bounds(k_dim, s, kp)
        assert len(b) == kp
        assert b[0][0] == 0 and b[-1][1] == k_dim
        widths = [hi - lo for lo, hi in b]
        assert all(w >= 0 for w in widths) and max(widths) == widths[0]
        for (_, hi), (lo2, _) in zip(b, b[1:]):
            assert hi == lo2


# ---------------------------------------------------------------------------
# Mesh equivalence (8-way host-device mesh, subprocess)
# ---------------------------------------------------------------------------

def test_distributed_1d_matches_single_node_planned():
    """1D products bit-match the single-node planned spgemm() per
    algorithm, repeat products hit the plan cache, and SpMM/BFS are
    rectangular-safe."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import CSR, plan_spgemm, plan_cache_stats
from repro.core.distributed import (shard_csr_rows, plan_spgemm_1d,
                                    spgemm_1d, spmm_1d, unshard_rows,
                                    multi_source_bfs)
from repro.data.rmat import rmat_csr
assert len(jax.devices()) == 8
mesh = Mesh(np.array(jax.devices()), ("data",))
a = rmat_csr(6, 4, "G500", seed=0)
b = rmat_csr(6, 4, "ER", seed=1)
a_sh = shard_csr_rows(a, 8, b=b)       # equal-flop boundaries
assert a_sh.row_starts[0] == 0 and a_sh.row_starts[-1] == 64

# bit-match per algorithm: each planned local product now runs the same
# kernel the single-node planned path runs -- including the Pallas hash
# kernel, which traces inside the shard_map body
for algo in ("esc", "heap", "hash"):
    ref = plan_spgemm(a, b, algorithm=algo).execute(a, b)
    dp = plan_spgemm_1d(a_sh, b, algorithm=algo)
    c = unshard_rows(dp.execute(mesh, a_sh, b))
    assert np.array_equal(np.asarray(c.to_dense()),
                          np.asarray(ref.to_dense())), algo
# the jnp twin stays the reference oracle: same accumulation order, but
# it rounds every product where the kernel fuses multiply-add (~1 ulp)
ref_twin = plan_spgemm(a, b, algorithm="hash_jnp").execute(a, b)
c_hash = unshard_rows(plan_spgemm_1d(a_sh, b, algorithm="hash")
                      .execute(mesh, a_sh, b))
assert np.allclose(np.asarray(c_hash.to_dense()),
                   np.asarray(ref_twin.to_dense()), atol=1e-5)

# masked boolean product bit-matches too
mask = rmat_csr(6, 3, "ER", seed=7)
refm = plan_spgemm(a, b, semiring="boolean", mask=mask,
                   algorithm="hash_jnp").execute(a, b)
dpm = plan_spgemm_1d(a_sh, b, semiring="boolean", mask=mask,
                     algorithm="hash")
cm = unshard_rows(dpm.execute(mesh, a_sh, b))
assert np.array_equal(np.asarray(cm.to_dense()),
                      np.asarray(refm.to_dense()))

# repeat products replan nothing (distributed plan-cache hit)
before = plan_cache_stats()
dp2 = plan_spgemm_1d(a_sh, b, algorithm="esc")
dp3 = plan_spgemm_1d(a_sh, b, algorithm="esc")
after = plan_cache_stats()
assert dp2 is dp3
assert after["misses"] == before["misses"], "repeat replanned something"
assert after["hits"] >= before["hits"] + 2

# planless entry dispatches through spgemm() with explicit algorithm
c_pl = unshard_rows(spgemm_1d(mesh, a_sh, b, cap_c=dp2.cap_c,
                              flop_cap=dp2.flop_cap, algorithm="esc"))
ref_esc = plan_spgemm(a, b, algorithm="esc").execute(a, b)
assert np.array_equal(np.asarray(c_pl.to_dense()),
                      np.asarray(ref_esc.to_dense()))

# rectangular SpMM regression: A (48, 32) with unequal nnz shards --
# the old code reshaped assuming square A and would mis-assemble here
rng = np.random.default_rng(0)
ar = CSR.from_numpy_coo(rng.integers(0, 48, 200),
                        rng.integers(0, 32, 200),
                        rng.normal(size=200).astype(np.float32), (48, 32))
ar_sh = shard_csr_rows(ar, 8)
x = rng.normal(size=(32, 5)).astype(np.float32)
y = spmm_1d(mesh, ar_sh, jnp.asarray(x))
assert y.shape == (48, 5)
assert np.allclose(np.asarray(y), np.asarray(ar.to_dense()) @ x, atol=1e-4)

# BFS on the (square) graph agrees with a host-side reference
sq = rmat_csr(6, 4, "G500", seed=2)
sq_sh = shard_csr_rows(sq, 8)
sources = [0, 3, 7]
dist = np.asarray(multi_source_bfs(mesh, sq_sh, jnp.array(sources), 64, 4))
adj = np.asarray(sq.to_dense()) != 0
ref_d = np.full((64, len(sources)), -1, np.int32)
for j, s in enumerate(sources):
    front = np.zeros(64, bool); front[s] = True; ref_d[s, j] = 0
    for hop in range(1, 5):
        front = (adj @ front) & (ref_d[:, j] < 0)   # nxt = A @ frontier
        ref_d[front, j] = hop
assert np.array_equal(dist, ref_d)
print("OK")
""", n_dev=8)


def test_distributed_summa_matches_single_node_and_honors_k_panels():
    _run("""
import numpy as np, jax
from jax.sharding import Mesh
from repro.core import CSR, plan_spgemm, plan_cache_stats
from repro.core.distributed import spgemm_summa, plan_spgemm_summa, \
    unshard_rows
def int_csr(m, n, nnz, seed):
    r = np.random.default_rng(seed)
    return CSR.from_numpy_coo(r.integers(0, m, nnz), r.integers(0, n, nnz),
                              r.integers(1, 5, nnz).astype(np.float32),
                              (m, n))
# integer values: fp32 panel-sum reassociation is exact, so the SUMMA
# merge must bit-match the single-node product
a = int_csr(64, 64, 300, 1)
b = int_csr(64, 48, 300, 2)
mesh = Mesh(np.array(jax.devices()), ("data",))
refd = np.asarray(plan_spgemm(a, b, algorithm="esc").execute(a, b)
                  .to_dense())
for kp in (8, 16):
    c = unshard_rows(spgemm_summa(mesh, a, b, k_panels=kp,
                                  algorithm="esc"))
    assert np.array_equal(np.asarray(c.to_dense()), refd), kp
# boolean semiring via the post-scatter threshold
refb = np.asarray(plan_spgemm(a, b, algorithm="esc", semiring="boolean")
                  .execute(a, b).to_dense())
cb = unshard_rows(spgemm_summa(mesh, a, b, semiring="boolean",
                               algorithm="esc"))
assert np.array_equal(np.asarray(cb.to_dense()), refb)
# min_plus has no dense add-identity: refuse instead of corrupting
try:
    spgemm_summa(mesh, a, b, semiring="min_plus")
except NotImplementedError:
    pass
else:
    raise AssertionError("min_plus SUMMA must raise")
# invalid panel counts fail loudly (dead-arg regression)
for bad in (3, 7, 128):
    try:
        spgemm_summa(mesh, a, b, k_panels=bad)
    except ValueError:
        pass
    else:
        raise AssertionError(f"k_panels={bad} must raise")
# repeat product hits the summa plan cache
before = plan_cache_stats()
c2 = unshard_rows(spgemm_summa(mesh, a, b, k_panels=8, algorithm="esc"))
after = plan_cache_stats()
assert after["misses"] == before["misses"]
assert np.array_equal(np.asarray(c2.to_dense()), refd)
# values stay out of the frozen panel structure: a reweighted operand
# reuses the cached plan and execute re-gathers the new values
import dataclasses as dc
a3 = dc.replace(a, data=a.data * 3.0)
c3 = unshard_rows(spgemm_summa(mesh, a3, b, k_panels=8, algorithm="esc"))
assert np.array_equal(np.asarray(c3.to_dense()), 3.0 * refd)
assert plan_cache_stats()["misses"] == after["misses"]
print("OK")
""", n_dev=8)


def test_unshard_rows_roundtrip_is_bitwise_with_cap():
    """shard -> unshard with an explicit ``cap=`` must reproduce the
    operand bitwise -- same arrays, same structure key -- so plan reuse
    after a round trip matches the single-node path (the old code shrank
    capacity to nnz, making every round trip a new structure)."""
    import numpy as np
    from repro.core.distributed import shard_csr_rows, unshard_rows
    from repro.core.plan import structure_key
    from _fuzz import csr_of, rand_dense

    a = csr_of(rand_dense(16, 12, 0.3, seed=3), cap=96)   # deliberate slack
    assert int(a.nnz) < a.cap == 96
    rt = unshard_rows(shard_csr_rows(a, 4), cap=a.cap)
    assert rt.cap == a.cap                      # fails pre-fix: cap == nnz
    for f in ("indptr", "indices", "data"):
        assert np.array_equal(np.asarray(getattr(rt, f)),
                              np.asarray(getattr(a, f))), f
    assert int(rt.nnz) == int(a.nnz) and rt.shape == a.shape
    assert structure_key(rt) == structure_key(a)
    # default preserves the sharded slack instead of shrinking to nnz
    sh = shard_csr_rows(a, 4)
    assert unshard_rows(sh).cap == 4 * sh.cap_per


def test_distributed_summa_ragged_prime_k():
    """SUMMA on a prime contraction dim (regression: the old panel
    schedule raised 'must divide' unless k_panels | K)."""
    _run("""
import numpy as np, jax
from jax.sharding import Mesh
from repro.core import CSR, plan_spgemm
from repro.core.distributed import spgemm_summa, unshard_rows
def int_csr(m, n, nnz, seed):
    r = np.random.default_rng(seed)
    return CSR.from_numpy_coo(r.integers(0, m, nnz), r.integers(0, n, nnz),
                              r.integers(1, 5, nnz).astype(np.float32),
                              (m, n))
a = int_csr(8, 13, 40, 1)     # K = 13 is prime
b = int_csr(13, 6, 30, 2)
mesh = Mesh(np.array(jax.devices()), ("data",))
refd = np.asarray(plan_spgemm(a, b, algorithm="esc").execute(a, b)
                  .to_dense())
for kp in (2, 4):             # ragged final panel: (12, 13) when kp=4
    c = unshard_rows(spgemm_summa(mesh, a, b, k_panels=kp,
                                  algorithm="esc"))
    assert np.array_equal(np.asarray(c.to_dense()), refd), kp
print("OK")
""", n_dev=2)


def test_distributed_1d_pb_sched_numeric_only():
    """The 1D plan's frozen PB geometry: shard_map executes run the
    scatter/merge Pallas pair with zero re-inspection, bit-match the
    mesh-free host twin, and general semirings fall back to esc."""
    _run("""
import numpy as np, jax
from jax.sharding import Mesh
from repro.core import CSR, plan_spgemm
from repro.core.distributed import shard_csr_rows, plan_spgemm_1d, \\
    unshard_rows
from repro.kernels.spgemm_pb import ops as pb_ops
def int_csr(m, n, nnz, seed):
    r = np.random.default_rng(seed)
    return CSR.from_numpy_coo(r.integers(0, m, nnz), r.integers(0, n, nnz),
                              r.integers(1, 5, nnz).astype(np.float32),
                              (m, n))
a = int_csr(32, 24, 120, 1)
b = int_csr(24, 28, 100, 2)
a_sh = shard_csr_rows(a, 4)
plan = plan_spgemm_1d(a_sh, b, algorithm="pb", sorted_output=True)
assert plan.pb_sched is not None and len(plan.pb_sched) == 6
mesh = Mesh(np.array(jax.devices()), ("data",))
pb_ops.reset_kernel_calls()
c = unshard_rows(plan.execute(mesh, a_sh, b))
cnt = pb_ops.kernel_call_counts()
assert cnt["inspect"] == 0 and cnt["scatter"] >= 1 and cnt["merge"] >= 1
refd = np.asarray(plan_spgemm(a, b, algorithm="esc", sorted_output=True)
                  .execute(a, b).to_dense())
assert np.array_equal(np.asarray(c.to_dense()), refd)
# mesh-free twin is bitwise the mesh result
host = plan.execute_shards_host(a_sh, b)
mesh_out = plan.execute(mesh, a_sh, b)
for f in ("indptr", "indices", "data", "nnz"):
    assert np.array_equal(np.asarray(getattr(host.parts, f)),
                          np.asarray(getattr(mesh_out.parts, f))), f
# a general semiring keeps pb_sched=None (esc substitution in-trace)
pg = plan_spgemm_1d(a_sh, b, algorithm="pb", semiring="min_plus")
assert pg.pb_sched is None
cg = unshard_rows(pg.execute(mesh, a_sh, b))
refm = np.asarray(plan_spgemm(a, b, algorithm="esc", semiring="min_plus")
                  .execute(a, b).to_dense())
assert np.array_equal(np.asarray(cg.to_dense()), refm)
print("OK")
""", n_dev=4)


def test_distributed_pb_summa_matches_classic_merge():
    """PB-SUMMA's all_to_all bucket exchange must reproduce the classic
    dense reduce-scatter merge bitwise (integer values), reuse the frozen
    structure on reweighted operands, and never re-inspect on repeat
    executes."""
    _run("""
import numpy as np, jax, dataclasses as dc
from jax.sharding import Mesh
from repro.core import CSR, plan_cache_stats
from repro.core.distributed import spgemm_summa, spgemm_pb_summa, \\
    plan_spgemm_pb_summa, unshard_rows
from repro.kernels.spgemm_pb import ops as pb_ops
def int_csr(m, n, nnz, seed):
    r = np.random.default_rng(seed)
    return CSR.from_numpy_coo(r.integers(0, m, nnz), r.integers(0, n, nnz),
                              r.integers(1, 5, nnz).astype(np.float32),
                              (m, n))
a = int_csr(32, 24, 150, 1)
b = int_csr(24, 28, 120, 2)
mesh = Mesh(np.array(jax.devices()), ("data",))
ref = unshard_rows(spgemm_summa(mesh, a, b, algorithm="esc"))
plan = plan_spgemm_pb_summa(a, b, 4)
pb_ops.reset_kernel_calls()
c = plan.execute(mesh, a, b)
assert pb_ops.kernel_call_counts()["inspect"] == 0
assert np.array_equal(np.asarray(unshard_rows(c).to_dense()),
                      np.asarray(ref.to_dense()))
# output is sorted CSR with the exact planned structure
assert bool(np.all(np.asarray(c.parts.nnz)
                   == np.asarray(plan.out_nnz)))
# repeat product hits the plan cache; reweighted values re-gather only
before = plan_cache_stats()["misses"]
c2 = spgemm_pb_summa(mesh, dc.replace(a, data=a.data * 2.0), b)
assert plan_cache_stats()["misses"] == before
assert np.array_equal(np.asarray(unshard_rows(c2).to_dense()),
                      2.0 * np.asarray(ref.to_dense()))
# multiple K-panels per chip stream through the same exchange
c3 = spgemm_pb_summa(mesh, a, b, k_panels=8)
assert np.array_equal(np.asarray(unshard_rows(c3).to_dense()),
                      np.asarray(ref.to_dense()))
print("OK")
""", n_dev=4)


def test_distributed_1d_empty_shards_execute():
    """The shard_map executor must handle empty shards: all-zero
    partition weights (empty operand) and more shards than rows."""
    _run("""
import numpy as np, jax
from jax.sharding import Mesh
from repro.core import CSR, plan_spgemm
from repro.core.distributed import (shard_csr_rows, plan_spgemm_1d,
                                    unshard_rows)
mesh = Mesh(np.array(jax.devices()), ("data",))

# all-zero weights: an empty operand has no flop anywhere -- the old
# partition handed shard 0 every row and trailing shards zero rows;
# either way the executor must survive and produce the empty product
empty = CSR.from_numpy_coo(np.zeros(0, np.int64), np.zeros(0, np.int64),
                           np.zeros(0, np.float32), (16, 8))
b = CSR.from_numpy_coo(np.array([0, 3, 5]), np.array([1, 2, 0]),
                       np.ones(3, np.float32), (8, 6))
e_sh = shard_csr_rows(empty, 8, b=b)
starts = np.asarray(e_sh.row_starts)
assert np.diff(starts).max() <= 2, starts   # rebalanced, not piled
ce = unshard_rows(plan_spgemm_1d(e_sh, b, algorithm="esc")
                  .execute(mesh, e_sh, b))
assert int(ce.nnz) == 0 and ce.shape == (16, 6)

# more shards than rows: some shards are necessarily empty
small = CSR.from_numpy_coo(np.array([0, 1, 2, 3]), np.array([0, 1, 2, 3]),
                           np.arange(1, 5, dtype=np.float32), (4, 8))
s_sh = shard_csr_rows(small, 8, b=b)
ref = np.asarray(plan_spgemm(small, b, algorithm="esc")
                 .execute(small, b).to_dense())
cs = unshard_rows(plan_spgemm_1d(s_sh, b, algorithm="esc")
                  .execute(mesh, s_sh, b))
assert np.array_equal(np.asarray(cs.to_dense()), ref)
print("OK")
""", n_dev=8)


def test_moe_ep_matches_dense():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import ARCHS, reduced
from repro.models import moe
from repro.parallel.sharding import ParallelCtx
cfg = reduced(ARCHS["qwen3-moe-30b-a3b"], d_model=64)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
pctx = ParallelCtx(mesh=mesh, batch_axes=("data",), fsdp_axes=("data",))
params = moe.init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
y_ref, _ = moe.apply_dense(params, x, cfg)
y_ep, _ = jax.jit(lambda p, x: moe.apply_ep(p, x, cfg, pctx))(params, x)
assert float(jnp.abs(y_ref - y_ep).max()) < 1e-4
print("OK")
""", n_dev=8)


def test_sharded_train_step_matches_single_device():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import ARCHS, reduced
from repro.parallel.sharding import ParallelCtx, single_device_ctx
from repro.train import optimizer as opt, step as step_lib
from repro.data.lm_synthetic import DataPipeline
cfg = reduced(ARCHS["qwen3-0.6b"], d_model=64, vocab=64)
ocfg = opt.AdamWConfig(lr=1e-2)
data = DataPipeline(cfg, 4, 32)
batch = data.batch(0)
key = jax.random.PRNGKey(0)
# single device
p0 = single_device_ctx(remat=False, attn_impl="full")
s0 = step_lib.init_state(key, cfg, ocfg)
s0b, m0 = jax.jit(step_lib.make_train_step(cfg, p0, ocfg))(s0, batch)
# sharded 2x2 mesh
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
p1 = ParallelCtx(mesh=mesh, batch_axes=("data",), fsdp_axes=("data",),
                 remat=False, attn_impl="full", moe_impl="dense")
s1 = step_lib.init_state(key, cfg, ocfg)
from repro.parallel.sharding import mesh_context
with mesh_context(mesh):
    s1b, m1 = jax.jit(step_lib.make_train_step(cfg, p1, ocfg))(s1, batch)
assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-4, (m0["loss"], m1["loss"])
d = max(float(jnp.abs(a - b).max()) for a, b in
        zip(jax.tree.leaves(s0b.params), jax.tree.leaves(s1b.params)))
assert d < 1e-3, d
print("OK")
""")


def test_elastic_checkpoint_reshard():
    """Save on a 4-device mesh, restore onto a 2-device mesh."""
    _run("""
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import Checkpointer
state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
mesh4 = Mesh(np.array(jax.devices()).reshape(4,), ("data",))
sh4 = {"w": NamedSharding(mesh4, P("data", None))}
state4 = jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh4)
d = tempfile.mkdtemp()
ck = Checkpointer(d)
ck.save(1, state4, blocking=True)
mesh2 = Mesh(np.array(jax.devices()[:2]).reshape(2,), ("data",))
sh2 = {"w": NamedSharding(mesh2, P(None, "data"))}
restored = ck.restore(1, state, sh2)
assert np.array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
assert restored["w"].sharding == sh2["w"]
print("OK")
""")
