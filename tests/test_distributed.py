"""Multi-device behaviour via subprocess (XLA host-device-count must be set
before jax initializes, so these run as child processes)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, n_dev: int = 4, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_dev}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_distributed_spgemm_spmm_bfs():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.distributed import (shard_csr_rows, spgemm_1d, spmm_1d,
                                    multi_source_bfs, spgemm_summa)
from repro.data.rmat import rmat_csr
a = rmat_csr(6, 4, "G500", seed=0)
b = rmat_csr(6, 4, "ER", seed=1)
ad, bd = np.asarray(a.to_dense()), np.asarray(b.to_dense())
cd = ad @ bd
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
ash = shard_csr_rows(a, 2)
c = spgemm_1d(mesh, ash, b, cap_c=512, flop_cap=8192, axis="data")
blocks = [np.asarray(jax.tree.map(lambda x: x[i], c).to_dense()) for i in range(2)]
assert np.allclose(np.concatenate(blocks, 0), cd, atol=1e-3)
x = np.random.default_rng(0).normal(size=(64, 8)).astype(np.float32)
y = spmm_1d(mesh, ash, jnp.asarray(x), axis="data")
assert np.allclose(np.asarray(y).reshape(64, 8), ad @ x, atol=1e-3)
cs = spgemm_summa(mesh, jnp.asarray(ad), jnp.asarray(bd))
assert np.allclose(np.asarray(cs), cd, atol=1e-3)
dist = multi_source_bfs(mesh, ash, jnp.array([0, 3, 7]), 64, 4, axis="data")
assert int((np.asarray(dist) >= 0).sum()) > 3
print("OK")
""")


def test_moe_ep_matches_dense():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import ARCHS, reduced
from repro.models import moe
from repro.parallel.sharding import ParallelCtx
cfg = reduced(ARCHS["qwen3-moe-30b-a3b"], d_model=64)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
pctx = ParallelCtx(mesh=mesh, batch_axes=("data",), fsdp_axes=("data",))
params = moe.init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
y_ref, _ = moe.apply_dense(params, x, cfg)
y_ep, _ = jax.jit(lambda p, x: moe.apply_ep(p, x, cfg, pctx))(params, x)
assert float(jnp.abs(y_ref - y_ep).max()) < 1e-4
print("OK")
""", n_dev=8)


def test_sharded_train_step_matches_single_device():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import ARCHS, reduced
from repro.parallel.sharding import ParallelCtx, single_device_ctx
from repro.train import optimizer as opt, step as step_lib
from repro.data.lm_synthetic import DataPipeline
cfg = reduced(ARCHS["qwen3-0.6b"], d_model=64, vocab=64)
ocfg = opt.AdamWConfig(lr=1e-2)
data = DataPipeline(cfg, 4, 32)
batch = data.batch(0)
key = jax.random.PRNGKey(0)
# single device
p0 = single_device_ctx(remat=False, attn_impl="full")
s0 = step_lib.init_state(key, cfg, ocfg)
s0b, m0 = jax.jit(step_lib.make_train_step(cfg, p0, ocfg))(s0, batch)
# sharded 2x2 mesh
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
p1 = ParallelCtx(mesh=mesh, batch_axes=("data",), fsdp_axes=("data",),
                 remat=False, attn_impl="full", moe_impl="dense")
s1 = step_lib.init_state(key, cfg, ocfg)
from repro.parallel.sharding import mesh_context
with mesh_context(mesh):
    s1b, m1 = jax.jit(step_lib.make_train_step(cfg, p1, ocfg))(s1, batch)
assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-4, (m0["loss"], m1["loss"])
d = max(float(jnp.abs(a - b).max()) for a, b in
        zip(jax.tree.leaves(s0b.params), jax.tree.leaves(s1b.params)))
assert d < 1e-3, d
print("OK")
""")


def test_elastic_checkpoint_reshard():
    """Save on a 4-device mesh, restore onto a 2-device mesh."""
    _run("""
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import Checkpointer
state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
mesh4 = Mesh(np.array(jax.devices()).reshape(4,), ("data",))
sh4 = {"w": NamedSharding(mesh4, P("data", None))}
state4 = jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh4)
d = tempfile.mkdtemp()
ck = Checkpointer(d)
ck.save(1, state4, blocking=True)
mesh2 = Mesh(np.array(jax.devices()[:2]).reshape(2,), ("data",))
sh2 = {"w": NamedSharding(mesh2, P(None, "data"))}
restored = ck.restore(1, state, sh2)
assert np.array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
assert restored["w"].sharding == sh2["w"]
print("OK")
""")
