"""Dry-run smoke: one real cell per step-kind compiles at 512 forced
devices in a subprocess (the full 40-cell x 2-mesh sweep is the
`results/dryrun_*.jsonl` artifact; this guards the machinery in CI)."""
import json
import os
import subprocess
import sys


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _dryrun(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)   # dryrun sets its own
    r = subprocess.run([sys.executable, "-m", "repro.launch.dryrun"] + args,
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout[-3000:]}\n" \
                              f"stderr:\n{r.stderr[-3000:]}"
    recs = [json.loads(l) for l in r.stdout.splitlines()
            if l.startswith("{")]
    assert recs and all("error" not in x for x in recs)
    return recs


def test_dryrun_decode_cell():
    recs = _dryrun(["--arch", "qwen3-0.6b", "--shape", "decode_32k"])
    r = recs[0]
    assert r["chips"] == 256
    assert r["hlo_flops"] > 0
    assert r["collectives"]["total_bytes"] > 0


def test_dryrun_multipod_train_cell():
    recs = _dryrun(["--arch", "qwen3-0.6b", "--shape", "train_4k",
                    "--multi-pod"])
    r = recs[0]
    assert r["chips"] == 512
    assert r["mesh"] == "2x16x16"
