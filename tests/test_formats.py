"""Format round-trips + invariants (unit + hypothesis property tests)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.formats import CSR, BCSR, ELL, csr_to_bcsr

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand_sparse(rng, m, n, density):
    x = rng.normal(size=(m, n)).astype(np.float32)
    mask = rng.random((m, n)) < density
    return np.where(mask, x, 0.0)


@given(m=st.integers(1, 24), n=st.integers(1, 24),
       density=st.floats(0.0, 0.6), seed=st.integers(0, 10))
def test_csr_dense_roundtrip(m, n, density, seed):
    rng = np.random.default_rng(seed)
    x = _rand_sparse(rng, m, n, density)
    c = CSR.from_dense(jnp.asarray(x))
    assert np.allclose(np.asarray(c.to_dense()), x)
    assert int(c.nnz) == int((x != 0).sum())
    # indptr consistency
    ip = np.asarray(c.indptr)
    assert ip[0] == 0 and ip[-1] == int(c.nnz)
    assert np.all(np.diff(ip) >= 0)


@given(seed=st.integers(0, 20))
def test_csr_sorted_within_rows(seed):
    rng = np.random.default_rng(seed)
    x = _rand_sparse(rng, 12, 17, 0.4)
    c = CSR.from_dense(jnp.asarray(x))
    cols = np.asarray(c.indices)
    ip = np.asarray(c.indptr)
    for i in range(12):
        row = cols[ip[i]:ip[i + 1]]
        assert np.all(np.diff(row) > 0), "row cols strictly increasing"


def test_csr_from_numpy_coo_duplicates():
    rows = np.array([0, 0, 1, 0])
    cols = np.array([1, 1, 2, 3])
    vals = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
    c = CSR.from_numpy_coo(rows, cols, vals, (2, 4))
    d = np.asarray(c.to_dense())
    assert d[0, 1] == 3.0 and d[1, 2] == 3.0 and d[0, 3] == 4.0
    assert int(c.nnz) == 3


def test_sort_rows_after_permutation():
    rng = np.random.default_rng(3)
    x = _rand_sparse(rng, 8, 8, 0.5)
    c = CSR.from_dense(jnp.asarray(x))
    # scramble within rows by reversing the live prefix per row
    perm = np.arange(c.cap)
    ip = np.asarray(c.indptr)
    for i in range(8):
        perm[ip[i]:ip[i + 1]] = perm[ip[i]:ip[i + 1]][::-1]
    scr = CSR(c.indptr, c.indices[perm], c.data[perm], c.nnz, c.shape,
              sorted_cols=False)
    srt = scr.sort_rows()
    assert np.allclose(np.asarray(srt.to_dense()), x)
    assert np.array_equal(np.asarray(srt.indices), np.asarray(c.indices))


@given(bm=st.sampled_from([2, 4, 8]), bn=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 10))
def test_bcsr_roundtrip(bm, bn, seed):
    rng = np.random.default_rng(seed)
    x = _rand_sparse(rng, 16, 24, 0.2)
    b = BCSR.from_dense(jnp.asarray(x), (bm, bn))
    assert np.allclose(np.asarray(b.to_dense()), x)


def test_ell_roundtrip():
    rng = np.random.default_rng(0)
    x = _rand_sparse(rng, 10, 12, 0.3)
    c = CSR.from_dense(jnp.asarray(x))
    width = int(np.max((x != 0).sum(axis=1)))
    e = ELL.from_csr(c, max(width, 1))
    assert np.allclose(np.asarray(e.to_dense()), x)


def test_csr_to_bcsr():
    rng = np.random.default_rng(1)
    x = _rand_sparse(rng, 16, 16, 0.2)
    c = CSR.from_dense(jnp.asarray(x))
    b = csr_to_bcsr(c, (4, 4))
    assert np.allclose(np.asarray(b.to_dense()), x)
