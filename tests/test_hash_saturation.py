"""Hash-table saturation regression (paper section 4, Fig. 7-8).

The hash accumulator's sensitive edge is the table boundary: the sizing
rule ``lowest_p2(min(N_col, max_row_flop) + 1)`` keeps the load factor
< 1 so linear probes terminate, but the *per-bin* sizes ride in as data
(scalar prefetch), so a schedule override can legally run a row at
**load factor 1.0** -- every slot occupied, the last insertion taking the
single remaining empty slot, every later probe terminating only because
its key is already resident.  The flush loop must then emit exactly
``table_size`` entries.  One row past the boundary, the natural sizing
must double the table.

Covered for the Pallas kernels (``spgemm_hash``, scalar and vectorized
probing -- at table size == CHUNK the vector path degenerates to a single
chunk, its own edge) and the jnp fallback (``spgemm_hash_jnp``), sorted
and unsorted output, plus the planner path that freezes per-bin sizes --
and, under ``jax.vmap`` over a two-member value fleet, the batched-grid
twins of both kernels at the same boundaries (the saturated table is
per-program scratch: members must not observe each other's slots).

Values are dyadic so every comparison is exact (bitwise on the dense
view).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp  # noqa: E402

from repro.core import CSR, plan_spgemm, spgemm_hash_jnp  # noqa: E402
from repro.kernels.spgemm_hash import ops as hash_ops  # noqa: E402
from repro.kernels.spgemm_hash.kernel import CHUNK  # noqa: E402
from _fuzz import VALS  # noqa: E402


def _pair_with_row_flop(d: int):
    """(A, B) whose C row 0 has exactly ``d`` distinct columns (flop d)
    and row 1 the same ``d`` columns with flop ``2d`` (duplicates that
    must accumulate across an already-saturated table)."""
    a = CSR.from_numpy_coo([0, 1, 1], [0, 0, 1], [1.0, 1.0, 0.5], (2, 2))
    rows = np.concatenate([np.zeros(d, np.int64), np.ones(d, np.int64)])
    cols = np.concatenate([np.arange(d), np.arange(d)])
    vals = VALS[np.arange(2 * d) % len(VALS)]
    b = CSR.from_numpy_coo(rows, cols, vals, (2, d))
    return a, b


def _oracle(a: CSR, b: CSR) -> np.ndarray:
    return np.asarray(a.to_dense(), np.float64) @ \
        np.asarray(b.to_dense(), np.float64)


def _check(c: CSR, cd: np.ndarray, sorted_output: bool):
    assert np.array_equal(np.asarray(c.to_dense(), np.float64), cd)
    if sorted_output:
        cols, ip = np.asarray(c.indices), np.asarray(c.indptr)
        for i in range(c.n_rows):
            assert np.all(np.diff(cols[ip[i]:ip[i + 1]]) > 0), i


@pytest.mark.parametrize("sorted_output", (False, True))
@pytest.mark.parametrize("vector", (False, True))
def test_pallas_hash_load_factor_one(vector, sorted_output):
    """Forced per-bin table == distinct column count: load factor 1.0.

    The schedule override pins ``bin_tsize`` to exactly ``d = CHUNK``
    (the smallest admissible table), so row 0 fills every slot and row 1
    re-probes a full table for each duplicate.  The flush must emit all
    ``d`` entries per row and the values must be exact.
    """
    d = CHUNK                                     # 8: p2, vector-minimal
    a, b = _pair_with_row_flop(d)
    cd = _oracle(a, b)
    offsets = jnp.asarray([0, 2], jnp.int32)
    bin_tsize = jnp.asarray([d], jnp.int32)
    c = hash_ops.spgemm_hash(a, b, cap_c=2 * d, vector=vector,
                             table_size=d, schedule=(offsets, bin_tsize))
    assert not c.sorted_cols
    ip = np.asarray(c.indptr)
    assert ip[1] - ip[0] == d and ip[2] - ip[1] == d   # table fully flushed
    if sorted_output:
        c = c.sort_rows()
    _check(c, cd, sorted_output)


@pytest.mark.parametrize("sorted_output", (False, True))
@pytest.mark.parametrize("vector", (False, True))
def test_pallas_hash_one_past_fill_doubles_table(vector, sorted_output):
    """One row past the exact-fill point: d = CHUNK + 1 distinct columns.

    The natural sizing must choose the next power of two (2 * CHUNK) --
    the +1 in ``lowest_p2(min(N_col, flop) + 1)`` is what forbids load
    factor 1.0 without an override -- and the results stay exact.
    """
    d = CHUNK + 1
    a, b = _pair_with_row_flop(d)
    cd = _oracle(a, b)
    offsets, bin_tsize, table_size = hash_ops.hash_schedule(a, b, n_bins=1)
    assert table_size == 2 * CHUNK                 # doubled, not saturated
    assert int(np.asarray(bin_tsize)[0]) == 2 * CHUNK
    c = hash_ops.spgemm_hash(a, b, cap_c=2 * d, vector=vector,
                             table_size=table_size,
                             schedule=(offsets, bin_tsize))
    if sorted_output:
        c = c.sort_rows()
    _check(c, cd, sorted_output)


def _vmap_saturation_fleet(a, b, d, vector, table_size, schedule):
    """Run a two-member value fleet on the saturating structure under
    ``jax.vmap`` and return per-member ``(indptr, dense)`` stacks plus the
    kernel-counter delta.  The schedule override closes over the vmapped
    call, so the ``custom_vmap`` rule must broadcast it onto the batched
    grid; the unplanned entry also exercises the batched *symbolic*
    kernel counting a saturated table."""
    import dataclasses

    import jax

    member_vals = jnp.stack([a.data, a.data * jnp.float32(2.0)])

    def one(v):
        c = hash_ops.spgemm_hash(dataclasses.replace(a, data=v), b,
                                 cap_c=2 * d, vector=vector,
                                 table_size=table_size, schedule=schedule)
        return c.indptr, c.to_dense()

    hash_ops.reset_kernel_calls()
    ips, denses = jax.vmap(one)(member_vals)
    return member_vals, np.asarray(ips), np.asarray(denses), \
        hash_ops.kernel_call_counts()


@pytest.mark.parametrize("vector", (False, True))
def test_batched_grid_load_factor_one_under_vmap(vector):
    """The load-factor-1.0 pin lifted onto the batched-grid kernel: every
    vmapped member runs row 0 at a completely full table and row 1
    re-probing it for each duplicate, and must flush exactly ``d`` slots
    with exact values -- per member."""
    d = CHUNK
    a, b = _pair_with_row_flop(d)
    offsets = jnp.asarray([0, 2], jnp.int32)
    bin_tsize = jnp.asarray([d], jnp.int32)
    member_vals, ips, denses, counts = _vmap_saturation_fleet(
        a, b, d, vector, table_size=d, schedule=(offsets, bin_tsize))
    assert counts["batched_symbolic"] > 0 and counts["batched_numeric"] > 0
    for e in range(2):
        assert ips[e, 1] - ips[e, 0] == d and ips[e, 2] - ips[e, 1] == d
        a_e = CSR(a.indptr, a.indices, member_vals[e], a.nnz, a.shape,
                  sorted_cols=a.sorted_cols)
        assert np.array_equal(denses[e].astype(np.float64),
                              _oracle(a_e, b)), e


@pytest.mark.parametrize("vector", (False, True))
def test_batched_grid_one_past_fill_doubles_table_under_vmap(vector):
    """One past exact fill under ``vmap``: the natural sizing's doubled
    table (2 * CHUNK) rides into the batched grid as data and every
    member stays exact."""
    d = CHUNK + 1
    a, b = _pair_with_row_flop(d)
    offsets, bin_tsize, table_size = hash_ops.hash_schedule(a, b, n_bins=1)
    assert table_size == 2 * CHUNK                 # doubled, not saturated
    member_vals, ips, denses, counts = _vmap_saturation_fleet(
        a, b, d, vector, table_size=table_size,
        schedule=(offsets, bin_tsize))
    assert counts["batched_symbolic"] > 0 and counts["batched_numeric"] > 0
    for e in range(2):
        assert ips[e, 1] - ips[e, 0] == d and ips[e, 2] - ips[e, 1] == d
        a_e = CSR(a.indptr, a.indices, member_vals[e], a.nnz, a.shape,
                  sorted_cols=a.sorted_cols)
        assert np.array_equal(denses[e].astype(np.float64),
                              _oracle(a_e, b)), e


@pytest.mark.parametrize("sorted_output", (False, True))
@pytest.mark.parametrize("d", (CHUNK, CHUNK + 1))
def test_hash_jnp_at_fill_boundary(d, sorted_output):
    """The jnp fallback on the same saturating structures, both sides of
    the boundary, sorted and unsorted -- contract-equivalent results."""
    a, b = _pair_with_row_flop(d)
    cd = _oracle(a, b)
    c = spgemm_hash_jnp(a, b, cap_c=2 * d)
    assert not c.sorted_cols
    if sorted_output:
        c = c.sort_rows()
    _check(c, cd, sorted_output)


def test_planned_hash_at_natural_max_load():
    """Through the planner: N_col < flop pins the table at
    ``lowest_p2(N_col + 1)``, the fullest load the natural sizing admits
    (``N_col / lowest_p2(N_col + 1)``; 1 - 1/16 here).  The frozen
    per-bin sizes must survive plan -> execute with exact results."""
    n = 15                                         # table = 16, load 15/16
    a = CSR.from_numpy_coo([0, 0], [0, 1], [1.0, 0.5], (1, 2))
    rows = np.concatenate([np.zeros(n, np.int64), np.ones(n, np.int64)])
    cols = np.concatenate([np.arange(n), np.arange(n)])
    vals = VALS[np.arange(2 * n) % len(VALS)]
    b = CSR.from_numpy_coo(rows, cols, vals, (2, n))
    cd = _oracle(a, b)
    plan = plan_spgemm(a, b, algorithm="hash", cache=False)
    assert plan.table_size == 16
    assert plan.nnz_c == n
    c = plan.execute(a, b)
    _check(c, cd, sorted_output=False)
    # row flop is 2n = 30 > n: the distinct count saturates at N_col
    ip = np.asarray(c.indptr)
    assert ip[1] - ip[0] == n
