"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU; the kernels target TPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import CSR, BCSR
from repro.data.rmat import rmat_csr
from repro.kernels.spgemm_hash.ops import spgemm_hash, spgemm_hash_symbolic
from repro.kernels.spgemm_hash.ref import numeric_ref, symbolic_ref
from repro.kernels.spgemm_bcsr.ops import spgemm_bcsr
from repro.kernels.spmm.ops import spmm_pallas
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.flash_attention.ops import flash_attention, chunked_attention
from repro.kernels.flash_attention.ref import attention_ref


# ---------------- hash SpGEMM ------------------------------------------------

@pytest.mark.parametrize("vector", [False, True])
@pytest.mark.parametrize("scale,ef,preset", [
    (4, 2, "ER"), (5, 3, "G500"), (5, 4, "ER"), (6, 2, "G500")])
def test_hash_spgemm_sweep(vector, scale, ef, preset):
    a = rmat_csr(scale, ef, preset, seed=scale + ef)
    b = rmat_csr(scale, ef, "ER", seed=scale + ef + 1)
    cd = np.asarray(numeric_ref(a, b))
    cap = int((cd != 0).sum()) + 16
    c = spgemm_hash(a, b, cap, vector=vector, n_bins=4)
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)
    rn = spgemm_hash_symbolic(a, b, vector=vector, n_bins=4)
    assert np.array_equal(np.asarray(rn), np.asarray(symbolic_ref(a, b)))


@pytest.mark.parametrize("table_size", [8, 16, 64])
def test_hash_spgemm_small_table_collisions(table_size):
    """Small power-of-two tables force heavy probing (collision factor c
    in Eq. 2) -- results must stay exact."""
    a = rmat_csr(4, 3, "G500", seed=9)
    b = rmat_csr(4, 3, "G500", seed=10)
    cd = np.asarray(numeric_ref(a, b))
    # table must still be >= max distinct cols per row + 1
    from repro.core.schedule import flops_per_row
    need = int(jnp.max(flops_per_row(a, b))) + 1
    if table_size < need:
        pytest.skip("table smaller than row bound")
    c = spgemm_hash(a, b, int((cd != 0).sum()) + 8, table_size=table_size,
                    n_bins=2)
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)


def test_hash_unsorted_flag_and_sort_epilogue():
    a = rmat_csr(5, 3, "G500", seed=1)
    b = rmat_csr(5, 3, "ER", seed=2)
    cd = np.asarray(numeric_ref(a, b))
    c = spgemm_hash(a, b, int((cd != 0).sum()) + 8, n_bins=4)
    assert not c.sorted_cols                    # C8: unsorted by default
    s = c.sort_rows()
    cols, ip = np.asarray(s.indices), np.asarray(s.indptr)
    for i in range(s.n_rows):
        assert np.all(np.diff(cols[ip[i]:ip[i + 1]]) > 0)


def test_hash_empty_matrix():
    z = CSR.from_dense(jnp.zeros((8, 8), jnp.float32), cap=4)
    c = spgemm_hash(z, z, cap_c=4, n_bins=2, table_size=8)
    assert int(c.nnz) == 0


# ---------------- BCSR SpGEMM ------------------------------------------------

@pytest.mark.parametrize("vector", [False, True])
@pytest.mark.parametrize("blocks", [((4, 4), (4, 4)), ((8, 16), (16, 8)),
                                    ((2, 8), (8, 4))])
def test_bcsr_spgemm_sweep(vector, blocks, rng):
    (bm, bk), (bk2, bn) = blocks
    m, k, n = bm * 6, bk * 5, bn * 7
    def blocky(mm, nn, tb, p):
        occ = rng.random((mm // tb[0], nn // tb[1])) < p
        x = rng.uniform(0.5, 1.5, (mm, nn)).astype(np.float32)
        return np.where(np.kron(occ, np.ones(tb)) > 0, x, 0.0)
    ad = blocky(m, k, (bm, bk), 0.4)
    bd = blocky(k, n, (bk, bn), 0.4)
    a = BCSR.from_dense(jnp.asarray(ad), (bm, bk))
    b = BCSR.from_dense(jnp.asarray(bd), (bk, bn))
    c = spgemm_bcsr(a, b, bcap_c=(m // bm) * (n // bn), vector=vector,
                    n_bins=3)
    assert np.allclose(np.asarray(c.to_dense()), ad @ bd, atol=1e-2)


# ---------------- SpMM -------------------------------------------------------

@pytest.mark.parametrize("k", [1, 8, 32])
@pytest.mark.parametrize("preset", ["ER", "G500"])
def test_spmm_sweep(k, preset, rng):
    a = rmat_csr(5, 3, preset, seed=k)
    x = jnp.asarray(rng.normal(size=(32, k)).astype(np.float32))
    y = spmm_pallas(a, x, n_bins=4)
    assert np.allclose(np.asarray(y), np.asarray(spmm_ref(a, x)), atol=1e-3)


# ---------------- flash attention --------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("h,hkv,d", [(4, 4, 32), (4, 2, 64), (8, 1, 32)])
def test_flash_attention_sweep(causal, h, hkv, d, rng):
    B, S = 2, 128
    q = jnp.asarray(rng.normal(size=(B, h, S, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, hkv, S, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, hkv, S, d)).astype(np.float32))
    ref = attention_ref(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, bq=64, bkv=64)
    assert float(jnp.abs(out - ref).max()) < 2e-5


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol, rng):
    B, H, S, D = 1, 2, 64, 32
    q = jnp.asarray(rng.normal(size=(B, H, S, D))).astype(dtype)
    k = jnp.asarray(rng.normal(size=(B, H, S, D))).astype(dtype)
    v = jnp.asarray(rng.normal(size=(B, H, S, D))).astype(dtype)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    out = flash_attention(q, k, v, causal=True, bq=32, bkv=32)
    assert float(jnp.abs(out.astype(jnp.float32) - ref).max()) < tol


@pytest.mark.parametrize("sq,skv", [(64, 64), (1, 128), (32, 128)])
def test_chunked_attention_decode_shapes(sq, skv, rng):
    B, H, HKV, D = 2, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, H, sq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, HKV, skv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, HKV, skv, D)).astype(np.float32))
    ref = attention_ref(q, k, v, causal=True)
    out = chunked_attention(q, k, v, causal=True, bkv=32)
    assert float(jnp.abs(out - ref).max()) < 2e-5
