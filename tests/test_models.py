"""Per-arch smoke tests (deliverable f): every assigned architecture, as a
reduced same-family config, runs one forward/train step on CPU with correct
output shapes and no NaNs; decode agrees with prefill."""
import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import pytest

from repro.configs import ARCHS, SHAPES, reduced, get
from repro.models import transformer as T
from repro.parallel.sharding import single_device_ctx

ALL_ARCHS = list(ARCHS)
PCTX = single_device_ctx(remat=False, attn_impl="full")


def _tokens(cfg, key, B=2, S=16):
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train(arch):
    cfg = reduced(ARCHS[arch])
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    toks = _tokens(cfg, key)
    loss, metrics = T.train_loss(params, {"tokens": toks, "labels": toks},
                                 cfg, PCTX)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    # one grad step is finite
    g = jax.grad(lambda p: T.train_loss(p, {"tokens": toks, "labels": toks},
                                        cfg, PCTX)[0])(params)
    gn = sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(g))
    assert bool(jnp.isfinite(gn)), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_prefill_shapes(arch):
    cfg = reduced(ARCHS[arch])
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    toks = _tokens(cfg, key, B=2, S=8)
    logits, caches = T.prefill(params, toks, cfg, PCTX)
    if cfg.n_codebooks:
        assert logits.shape == (2, 1, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "qwen1.5-32b",
                                  "mamba2-780m", "recurrentgemma-9b",
                                  "qwen3-moe-30b-a3b", "musicgen-medium"])
def test_decode_matches_prefill(arch):
    cfg = reduced(ARCHS[arch])
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, cfg)
    B, S = 2, 12
    toks = _tokens(cfg, key, B=B, S=S)
    _, caches = T.prefill(params, toks[:, :S - 1], cfg, PCTX)
    caches_full = T.init_caches(cfg, B, S, jnp.float32)

    def merge(cs, cb):
        if hasattr(cs, "k"):
            return type(cs)(cb.k.at[..., :S - 1, :].set(cs.k),
                            cb.v.at[..., :S - 1, :].set(cs.v))
        return cs

    merged = jtu.tree_map(merge, caches, caches_full,
                          is_leaf=lambda x: hasattr(x, "k") or
                          hasattr(x, "conv"))
    dec, _ = T.decode_step(params, toks[:, S - 1:S], merged, S - 1, cfg,
                           PCTX)
    ref, _ = T.prefill(params, toks, cfg, PCTX)
    assert float(jnp.abs(dec - ref).max()) < 2e-3, arch


def test_param_count_matches_init():
    for arch in ALL_ARCHS:
        cfg = reduced(ARCHS[arch])
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        n = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(n - analytic) / max(n, 1) < 0.02, \
            f"{arch}: init {n} vs analytic {analytic}"


def test_full_configs_match_assignment():
    """The exact values from the assignment table."""
    c = get("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (94, 4096, 64, 4)
    assert c.moe.n_experts == 128 and c.moe.top_k == 8
    c = get("granite-8b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (36, 4096, 14336, 49152)
    c = get("recurrentgemma-9b")
    assert c.plan == (("rglru", "gated_mlp"), ("rglru", "gated_mlp"),
                      ("attn_local", "gated_mlp"))
    assert c.attn_window == 2048 and c.n_layers == 38
    c = get("mamba2-780m")
    assert c.ssm.d_state == 128 and c.d_ff == 0
    c = get("musicgen-medium")
    assert c.n_codebooks == 4 and c.vocab_size == 2048
    c = get("chameleon-34b")
    assert c.vocab_size == 65536 and c.d_ff == 22016
    assert len(ARCHS) == 10 and len(SHAPES) == 4
