"""Differential suite for the propagation-blocking SpGEMM lane
(DESIGN.md section 18, after Gu et al.'s propagation blocking).

The oracle is scipy's CSR product: the planned PB path must reproduce
its structure bit for bit -- indptr, *sorted* column order (sorted
output is PB's contract; the structure was frozen at plan time), and
values bitwise on dyadic fixtures (all sums exact, so reduction order
cannot show through).  Both sides keep structurally-present entries, so
comparisons are exact.

Also pinned here: empty operands / empty products / rectangular shapes,
unsorted inputs (expansion never needs sorted columns; the output stays
sorted), every registered semiring through the jnp twin, masks in both
polarities (plan-time structural pruning), bitwise structure agreement
with the planned *hash* path under ``sorted_output=True``, the recipe's
compression-factor gate, the ``"pb"`` plan-cache kind with
counter-verified zero re-inspection on repeat executes, and the
batched-kernel dispatch under ``vmap`` over a member value fleet.  The
mesh lifts (1D ``pb_sched``, PB-SUMMA exchange) live in
``tests/test_distributed.py``; the hypothesis property layer at the
bottom consumes ``_fuzz.pb_case``.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import (PBPlan, clear_plan_cache, plan_cache_stats,  # noqa: E402
                        plan_pb, plan_spgemm, spgemm)
from repro.core.formats import CSR  # noqa: E402
from repro.core.recipe import (PB_MAX_COMPRESSION,  # noqa: E402
                               choose_algorithm, measure_stats)
from repro.kernels.spgemm_pb import ops as pb_ops  # noqa: E402
from _fuzz import (csr_of, member_value_fleet, rand_dense,  # noqa: E402
                   scramble_rows)

sp = pytest.importorskip("scipy.sparse")


def _sp(d: np.ndarray):
    return sp.csr_matrix(np.asarray(d, np.float32))


def _oracle(ad: np.ndarray, bd: np.ndarray):
    c = (_sp(ad) @ _sp(bd)).astype(np.float32)
    c.sort_indices()
    return c


def _assert_matches_scipy(c: CSR, oracle) -> None:
    """Bitwise structure + value equality against the scipy CSR product
    (PB emits sorted columns; dyadic fixtures make the values exact)."""
    nnz = int(c.nnz)
    assert nnz == oracle.nnz
    assert c.sorted_cols
    assert np.array_equal(np.asarray(c.indptr), oracle.indptr)
    assert np.array_equal(np.asarray(c.indices)[:nnz], oracle.indices)
    assert np.array_equal(np.asarray(c.data)[:nnz],
                          oracle.data.astype(np.float32))
    # padding beyond nnz is zeroed (the CSR dump contract)
    assert not np.any(np.asarray(c.indices)[nnz:])
    assert not np.any(np.asarray(c.data)[nnz:])


# ---------------------------------------------------------------------------
# scipy differential: shapes x densities x bucket counts
# ---------------------------------------------------------------------------

GRID = [
    # (m, k, n, da, db, n_buckets)
    (16, 16, 16, 0.2, 0.2, None),
    (16, 16, 16, 0.2, 0.2, 1),
    (16, 16, 16, 0.3, 0.3, 4),
    (24, 8, 40, 0.3, 0.15, 8),    # wide C: multi-bucket split
    (40, 24, 8, 0.15, 0.3, 2),    # tall A, narrow C
    (5, 7, 3, 0.6, 0.6, None),    # tiny odd shapes, dense-ish
    (16, 16, 16, 0.05, 0.05, 4),  # near-empty
]


@pytest.mark.parametrize("m,k,n,da,db,nb", GRID)
def test_pb_matches_scipy(m, k, n, da, db, nb):
    ad = rand_dense(m, k, da, seed=m * 31 + n)
    bd = rand_dense(k, n, db, seed=m * 37 + k)
    a, b = csr_of(ad), csr_of(bd)
    plan = plan_pb(a, b, n_buckets=nb, cache=False)
    _assert_matches_scipy(plan.execute(a, b), _oracle(ad, bd))


def test_empty_operands_and_empty_product():
    m, k, n = 8, 6, 10
    bd = rand_dense(k, n, 0.4, seed=3)
    za = csr_of(np.zeros((m, k), np.float32))
    b = csr_of(bd)
    for aa, bb, aden, bden in [
            (za, b, np.zeros((m, k), np.float32), bd),
            (csr_of(rand_dense(m, k, 0.4, seed=4)),
             csr_of(np.zeros((k, n), np.float32)),
             rand_dense(m, k, 0.4, seed=4), np.zeros((k, n), np.float32))]:
        plan = plan_pb(aa, bb, cache=False)
        assert plan.nnz_c == 0 and plan.total_flop == 0
        _assert_matches_scipy(plan.execute(aa, bb), _oracle(aden, bden))
    # structurally-disjoint K support: nonzero operands, empty product
    ad = np.zeros((4, 6), np.float32)
    bd2 = np.zeros((6, 4), np.float32)
    ad[:, :3] = rand_dense(4, 3, 0.9, seed=5)
    bd2[3:, :] = rand_dense(3, 4, 0.9, seed=6)
    a2, b2 = csr_of(ad), csr_of(bd2)
    plan = plan_pb(a2, b2, cache=False)
    assert plan.nnz_c == 0
    _assert_matches_scipy(plan.execute(a2, b2), _oracle(ad, bd2))


def test_unsorted_inputs_sorted_output():
    """Expansion is order-insensitive: scrambled operand rows produce the
    same frozen (sorted) output structure and the same values."""
    ad = rand_dense(12, 10, 0.35, seed=7)
    bd = rand_dense(10, 14, 0.3, seed=8)
    a, b = csr_of(ad), csr_of(bd)
    au, bu = scramble_rows(a), scramble_rows(b)
    oracle = _oracle(ad, bd)
    for aa, bb in [(au, b), (a, bu), (au, bu)]:
        plan = plan_pb(aa, bb, cache=False)
        _assert_matches_scipy(plan.execute(aa, bb), oracle)


# ---------------------------------------------------------------------------
# semirings and masks (jnp twin + plan-time structural pruning)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("semiring", ["boolean", "min_plus", "plus_first"])
def test_general_semirings_match_esc(semiring):
    ad = rand_dense(14, 12, 0.3, seed=9)
    bd = rand_dense(12, 11, 0.3, seed=10)
    a, b = csr_of(ad), csr_of(bd)
    plan = plan_pb(a, b, semiring=semiring, cache=False)
    c = plan.execute(a, b)
    ref = spgemm(a, b, cap_c=max(plan.nnz_c, 1), algorithm="esc",
                 semiring=semiring, sorted_output=True)
    nnz = int(ref.nnz)
    assert int(c.nnz) == nnz
    assert np.array_equal(np.asarray(c.indptr), np.asarray(ref.indptr))
    assert np.array_equal(np.asarray(c.indices)[:nnz],
                          np.asarray(ref.indices)[:nnz])
    assert np.array_equal(np.asarray(c.data)[:nnz],
                          np.asarray(ref.data)[:nnz])


@pytest.mark.parametrize("complement", [False, True])
def test_masked_products_match_esc(complement):
    ad = rand_dense(12, 10, 0.35, seed=11)
    bd = rand_dense(10, 12, 0.35, seed=12)
    md = (rand_dense(12, 12, 0.5, seed=13) > 0).astype(np.float32)
    a, b, mask = csr_of(ad), csr_of(bd), csr_of(md)
    plan = plan_pb(a, b, mask=mask, complement_mask=complement, cache=False)
    assert plan.has_mask
    c = plan.execute(a, b)
    ref = spgemm(a, b, cap_c=max(plan.nnz_c, 1), algorithm="esc",
                 mask=mask, complement_mask=complement, sorted_output=True)
    nnz = int(ref.nnz)
    assert int(c.nnz) == nnz
    assert np.array_equal(np.asarray(c.indptr), np.asarray(ref.indptr))
    assert np.array_equal(np.asarray(c.indices)[:nnz],
                          np.asarray(ref.indices)[:nnz])
    assert np.array_equal(np.asarray(c.data)[:nnz],
                          np.asarray(ref.data)[:nnz])


# ---------------------------------------------------------------------------
# bitwise agreement with the planned hash path (ISSUE acceptance)
# ---------------------------------------------------------------------------

def test_bitwise_structure_vs_planned_hash_sorted():
    """PB and the planned hash path under ``sorted_output=True`` freeze
    the *same* output structure (indptr + indices bitwise); dyadic values
    agree bitwise too, reduction order notwithstanding."""
    ad = rand_dense(16, 14, 0.3, seed=14)
    bd = rand_dense(14, 16, 0.3, seed=15)
    a, b = csr_of(ad), csr_of(bd)
    pbp = plan_pb(a, b, cache=False)
    hp = plan_spgemm(a, b, algorithm="hash", sorted_output=True,
                     cache=False)
    c_pb = pbp.execute(a, b)
    c_h = hp.execute(a, b)
    nnz = int(c_h.nnz)
    assert int(c_pb.nnz) == nnz == pbp.nnz_c
    assert np.array_equal(np.asarray(c_pb.indptr), np.asarray(c_h.indptr))
    assert np.array_equal(np.asarray(c_pb.indices)[:nnz],
                          np.asarray(c_h.indices)[:nnz])
    assert np.array_equal(np.asarray(c_pb.data)[:nnz],
                          np.asarray(c_h.data)[:nnz])


def test_dispatcher_pb_pads_to_caller_cap():
    ad = rand_dense(10, 10, 0.3, seed=16)
    bd = rand_dense(10, 10, 0.3, seed=17)
    a, b = csr_of(ad), csr_of(bd)
    nnz_c = _oracle(ad, bd).nnz
    cap = nnz_c + 13
    c = spgemm(a, b, cap_c=cap, algorithm="pb", sorted_output=True,
               cache=False)
    assert c.indices.shape[0] == cap
    _assert_matches_scipy(c, _oracle(ad, bd))


# ---------------------------------------------------------------------------
# recipe gate, plan cache, zero re-inspection
# ---------------------------------------------------------------------------

def test_recipe_routes_low_compression_to_pb():
    """A sorted AxA product whose expansion barely collapses (CF <= the
    gate) routes to pb; a high-CF product must not."""
    rng = np.random.default_rng(18)
    # one nonzero per row of A in distinct columns -> zero collisions
    ad = np.zeros((16, 16), np.float32)
    ad[np.arange(16), rng.permutation(16)] = 1.5
    a = csr_of(ad)
    stats = measure_stats(a, a)
    assert stats.compression_ratio <= PB_MAX_COMPRESSION
    assert choose_algorithm(a, a, sorted_output=True,
                            use_case="AxA") == "pb"
    dense = csr_of(rand_dense(16, 16, 0.6, seed=19))
    assert measure_stats(dense, dense).compression_ratio \
        > PB_MAX_COMPRESSION
    assert choose_algorithm(dense, dense, sorted_output=True,
                            use_case="AxA") != "pb"


def test_pb_cache_kind_and_zero_reinspection():
    clear_plan_cache()
    pb_ops.reset_kernel_calls()
    ad = rand_dense(12, 12, 0.3, seed=20)
    bd = rand_dense(12, 12, 0.3, seed=21)
    a, b = csr_of(ad), csr_of(bd)
    plan = plan_pb(a, b)
    assert isinstance(plan, PBPlan)
    assert pb_ops.kernel_call_counts()["inspect"] == 1
    assert plan_cache_stats()["kinds"].get("pb") == 1

    c1 = plan.execute(a, b)
    c2 = plan.execute(a, b)
    cnt = pb_ops.kernel_call_counts()
    assert cnt["inspect"] == 1            # executes never re-inspect
    assert cnt["scatter"] >= 2 and cnt["merge"] >= 2
    assert np.array_equal(np.asarray(c1.data), np.asarray(c2.data))

    replanned = plan_pb(a, b)             # cache hit: no new inspection
    assert replanned is plan
    assert pb_ops.kernel_call_counts()["inspect"] == 1


def test_nested_pb_plan_in_spgemm_plan():
    ad = rand_dense(10, 8, 0.3, seed=22)
    bd = rand_dense(8, 12, 0.3, seed=23)
    a, b = csr_of(ad), csr_of(bd)
    plan = plan_spgemm(a, b, algorithm="pb", sorted_output=True,
                       cache=False)
    assert isinstance(plan.pb_plan, PBPlan)
    c = plan.execute(a, b)
    oracle = _oracle(ad, bd)
    nnz = int(c.nnz)
    assert nnz == oracle.nnz
    assert np.array_equal(np.asarray(c.indptr), oracle.indptr)
    assert np.array_equal(np.asarray(c.indices)[:nnz], oracle.indices)
    assert np.array_equal(np.asarray(c.data)[:nnz],
                          oracle.data.astype(np.float32))


# ---------------------------------------------------------------------------
# layer-1 verifier: clean plans prove, perturbed plans are rejected
# ---------------------------------------------------------------------------

def test_verify_pb_clean_and_rejects_perturbations():
    import dataclasses

    from repro.verify import check_plan_vcs, verify_pb

    ad = rand_dense(12, 10, 0.3, seed=27)
    bd = rand_dense(10, 12, 0.3, seed=28)
    a, b = csr_of(ad), csr_of(bd)
    plan = plan_pb(a, b, cache=False)
    assert plan.total_flop > 0
    case = verify_pb(plan, a, b)
    assert case.budget["ok"], case.budget
    assert not case.violations and all(vc.ok for vc in case.vcs)

    # live segment slots pushed past cap_c: segment-bounds must fire
    bad = dataclasses.replace(plan, seg=plan.seg + plan.cap_c)
    failed = {vc.name for vc in check_plan_vcs(bad) if not vc.ok}
    assert "segment-bounds" in failed
    # bucket counts past the static capacity: bucket-capacity must fire
    bad = dataclasses.replace(
        plan, bucket_nnz=plan.bucket_nnz + plan.bucket_cap + 1)
    failed = {vc.name for vc in check_plan_vcs(bad) if not vc.ok}
    assert "bucket-capacity" in failed


# ---------------------------------------------------------------------------
# batched dispatch under vmap (member value fleet)
# ---------------------------------------------------------------------------

def test_vmap_value_fleet_dispatches_batched_kernels():
    ad = rand_dense(10, 10, 0.3, seed=24)
    bd = rand_dense(10, 10, 0.3, seed=25)
    a, b = csr_of(ad), csr_of(bd)
    plan = plan_pb(a, b, cache=False)
    fleet = member_value_fleet(ad, 3, seed=26)   # (3, nnz) scaled values

    def run(vals):
        a2 = CSR(a.indptr, a.indices, vals, a.nnz, a.shape, a.sorted_cols)
        return plan.execute(a2, b).data

    pb_ops.reset_kernel_calls()
    out = jax.vmap(run)(jnp.asarray(fleet))
    cnt = pb_ops.kernel_call_counts()
    assert cnt["batched_scatter"] >= 1 and cnt["batched_merge"] >= 1
    assert cnt["inspect"] == 0
    for e in range(3):
        ref = plan.execute(
            CSR(a.indptr, a.indices, jnp.asarray(fleet[e]), a.nnz,
                a.shape, a.sorted_cols), b).data
        assert np.array_equal(np.asarray(out[e]), np.asarray(ref))


# ---------------------------------------------------------------------------
# hypothesis property layer (optional extra)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from _fuzz import pb_case
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(pb_case())
    def test_fuzz_pb_vs_scipy(case):
        """Property layer: any low-compression product (forced empty
        rows/columns, mixed densities, every bucket count) planned and
        executed through the PB lane matches the scipy oracle exactly."""
        ad, bd, n_buckets = case
        a, b = csr_of(ad), csr_of(bd)
        plan = plan_pb(a, b, n_buckets=n_buckets, cache=False)
        _assert_matches_scipy(plan.execute(a, b), _oracle(ad, bd))
