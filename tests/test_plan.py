"""Planner subsystem + capacity bugfix regressions (DESIGN.md section 10).

Hypothesis-free on purpose (like test_semiring.py): this is the coverage
for the four capacity bugfixes and the plan-reuse contract, and it must
run even without the optional property-testing extra.

Contracts:
  * heap honors the caller's ``cap_c`` -- output shapes equal across
    algorithms (static-shape/jit-reuse contract);
  * heap row overflow *drops* the overflow and keeps the first ``row_cap``
    entries intact (vs the old silent overwrite of the last slot);
  * ``symbolic(flop_cap=exact)`` == ``symbolic()`` with the default
    worst-case buffer;
  * the int32 prefix-sum guard raises instead of mis-binning;
  * cached-plan execute == fresh ``spgemm`` across all semirings x masks,
    with zero schedule/symbolic recomputation and correct cache keying.
"""
import dataclasses
import importlib
import os
import sys

import numpy as np
import jax.numpy as jnp
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import (clear_plan_cache, plan_cache_stats, plan_spgemm,
                        spgemm, spgemm_heap, symbolic)
from repro.core import schedule as sched_pkg  # noqa: F401  # verify: allow(dead-import) -- deliberate import check
import repro.core.schedule as sched
from repro.core.plan import structure_key
from repro.data.rmat import rmat_csr

ALL_SEMIRINGS = ("plus_times", "boolean", "min_plus", "plus_first")


def _pair(seed=3, scale=5, ef=3):
    a = rmat_csr(scale, ef, "G500", seed=seed)
    b = rmat_csr(scale, ef, "ER", seed=seed + 100)
    cd = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
    return a, b, cd


def _dense_semiring(a, b, sr_name):
    ad, bd = np.asarray(a.to_dense()), np.asarray(b.to_dense())
    ap, bp = ad != 0, bd != 0
    if sr_name == "plus_times":
        return ad @ bd
    if sr_name == "boolean":
        return ((ap @ bp) > 0).astype(np.float32)
    if sr_name == "plus_first":
        return ad @ bp.astype(np.float32)
    if sr_name == "min_plus":
        s = np.where(ap[:, :, None] & bp[None, :, :],
                     ad[:, :, None] + bd[None, :, :], np.inf)
        out = s.min(axis=1)
        return np.where(np.isinf(out), 0.0, out).astype(np.float32)
    raise AssertionError(sr_name)


# ---------------------------------------------------------------------------
# Bugfix regressions
# ---------------------------------------------------------------------------

def test_heap_honors_cap_c_shapes_equal_across_algorithms():
    """spgemm(algorithm='heap') must return the same static output shapes
    as every other algorithm for the same cap_c (jit-reuse contract)."""
    a, b, cd = _pair()
    cap = int((cd != 0).sum()) + 8
    kw = dict(row_cap=int(max((cd != 0).sum(axis=1))) + 1,
              k_width=int(np.asarray(a.row_nnz()).max()) + 1)
    ch = spgemm(a, b, cap, algorithm="heap", **kw)
    for algo in ("esc", "hash"):
        c = spgemm(a, b, cap, algorithm=algo)
        assert ch.indices.shape == c.indices.shape == (cap,), algo
        assert ch.data.shape == c.data.shape == (cap,), algo
    assert np.allclose(np.asarray(ch.to_dense()), cd, atol=1e-3)
    # direct call without cap_c keeps the legacy m * row_cap panel size
    legacy = spgemm_heap(a, b, **kw)
    assert legacy.cap == a.n_rows * kw["row_cap"]


def test_heap_overflow_drops_instead_of_overwriting():
    """A row exceeding row_cap keeps its first row_cap (smallest-column)
    entries with correct values; overflow is dropped, never merged into
    the last slot."""
    a, b, cd = _pair(seed=7)
    full_cap = int((cd != 0).sum()) + 8
    k_width = int(np.asarray(a.row_nnz()).max()) + 1
    for row_cap in (1, 2, 3):
        c = spgemm_heap(a, b, row_cap=row_cap, k_width=k_width,
                        cap_c=full_cap)
        ip, cols, vals = (np.asarray(c.indptr), np.asarray(c.indices),
                          np.asarray(c.data))
        for i in range(a.n_rows):
            keep = np.nonzero(cd[i])[0][:row_cap]
            got_c = cols[ip[i]:ip[i + 1]]
            got_v = vals[ip[i]:ip[i + 1]]
            assert np.array_equal(got_c, keep), (row_cap, i)
            assert np.allclose(got_v, cd[i][keep], atol=1e-3), (row_cap, i)


def test_symbolic_flop_cap_equivalence():
    a, b, _ = _pair(seed=5)
    rn0, ip0, flop, total = symbolic(a, b)
    rn1, ip1, _, _ = symbolic(a, b, flop_cap=int(total))
    assert np.array_equal(np.asarray(rn0), np.asarray(rn1))
    assert np.array_equal(np.asarray(ip0), np.asarray(ip1))
    # masked variant too (the planner's path)
    mask = rmat_csr(5, 4, "ER", seed=9)
    rm0, im0, _, _ = symbolic(a, b, mask=mask)
    rm1, im1, _, _ = symbolic(a, b, mask=mask, flop_cap=int(total))
    assert np.array_equal(np.asarray(rm0), np.asarray(rm1))
    assert np.array_equal(np.asarray(im0), np.asarray(im1))


def test_rows_to_bins_overflow_guard():
    """int32 mode: the guard raises instead of mis-binning.  x64 mode
    (the CI leg with JAX_ENABLE_X64=1): accumulation is promoted to
    int64, the guard stays silent, and the huge input bins *exactly* --
    the promotion path that is otherwise only exercised implicitly."""
    import jax
    huge = jnp.full((8,), 2**30, jnp.int32)   # total 2^33 >> int32
    if jax.config.jax_enable_x64:
        sched.guard_i32_flop(huge, 8, "rows_to_bins")       # no raise
        off = np.asarray(sched.rows_to_bins(huge, 4))
        assert off[0] == 0 and off[-1] == 8
        # uniform rows: the equal-flop partition is exact under int64
        assert np.array_equal(off, [0, 2, 4, 6, 8])
        assert np.asarray(sched.bin_flop(huge, jnp.asarray(off))).sum() \
            == 8 * 2**30
    else:
        with pytest.raises(OverflowError, match="overflows the int32"):
            sched.rows_to_bins(huge, 8)
        with pytest.raises(OverflowError):
            sched.guard_i32_flop(huge, 1, "bin_flop")
    # sane totals stay silent and exact in both modes
    ok = jnp.full((8,), 1000, jnp.int32)
    off = np.asarray(sched.rows_to_bins(ok, 4))
    assert off[0] == 0 and off[-1] == 8


# ---------------------------------------------------------------------------
# Plan construction, caching, and reuse
# ---------------------------------------------------------------------------

def test_plan_records_exact_capacities_and_choice():
    a, b, cd = _pair()
    clear_plan_cache()
    plan = plan_spgemm(a, b)
    assert plan.nnz_c == int((cd != 0).sum()) == plan.cap_c
    assert plan.total_flop == plan.flop_cap
    assert plan.row_cap == int(max((cd != 0).sum(axis=1)))
    assert plan.algorithm in ("esc", "heap", "hash", "hash_vector", "dense")
    bt = np.asarray(plan.bin_tsize)
    assert bt.shape == (plan.n_bins,)
    assert np.all((bt & (bt - 1)) == 0) and bt.max() <= plan.table_size


@pytest.mark.parametrize("algo", ("esc", "heap", "hash", "hash_vector"))
def test_plan_execute_matches_fresh_spgemm(algo):
    a, b, cd = _pair(seed=11)
    clear_plan_cache()
    plan = plan_spgemm(a, b, algorithm=algo)
    c = plan.execute(a, b)
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3), algo
    assert int(c.nnz) == int((cd != 0).sum()), algo


@pytest.mark.parametrize("semiring", ALL_SEMIRINGS)
@pytest.mark.parametrize("masked", (False, True))
@pytest.mark.parametrize("complement", (False, True))
def test_plan_reuse_equals_fresh_across_semirings_and_masks(
        semiring, masked, complement):
    """Cached-plan execute == fresh spgemm over the test_semiring grid."""
    if complement and not masked:
        pytest.skip("complement needs a mask")
    a = rmat_csr(5, 3, "G500", seed=11)
    b = rmat_csr(5, 3, "ER", seed=111)
    mask = rmat_csr(5, 4, "ER", seed=7) if masked else None
    cd = _dense_semiring(a, b, semiring)
    if masked:
        md = np.asarray(mask.to_dense()) != 0
        cd = np.where(~md if complement else md, cd, 0.0)
    cap = int((cd != 0).sum()) + 8

    clear_plan_cache()
    plan = plan_spgemm(a, b, semiring=semiring, mask=mask,
                       complement_mask=complement)
    # second plan request: structure-identical -> cache hit, same object
    plan2 = plan_spgemm(a, b, semiring=semiring, mask=mask,
                        complement_mask=complement)
    assert plan2 is plan
    assert plan_cache_stats()["hits"] == 1

    c_plan = plan.execute(a, b)
    c_fresh = spgemm(a, b, cap, algorithm=plan.algorithm, semiring=semiring,
                     mask=mask, complement_mask=complement,
                     **({"row_cap": plan.row_cap, "k_width": plan.k_width}
                        if plan.algorithm == "heap" else {}))
    assert np.allclose(np.asarray(c_plan.to_dense()), cd, atol=1e-3)
    assert np.allclose(np.asarray(c_plan.to_dense()),
                       np.asarray(c_fresh.to_dense()), atol=1e-3)


def test_plan_execute_no_reinspection():
    """The executor must not touch schedule or the symbolic kernel."""
    a, b, cd = _pair(seed=2)
    clear_plan_cache()
    plan = plan_spgemm(a, b, algorithm="hash")
    counts = {}

    def counted(module_name, attr):
        mod = importlib.import_module(module_name)
        orig = getattr(mod, attr)

        def wrapper(*args, **kw):
            counts[attr] = counts.get(attr, 0) + 1
            return orig(*args, **kw)

        setattr(mod, attr, wrapper)
        return mod, attr, orig

    patched = [counted("repro.core.schedule", "make_schedule"),
               counted("repro.core.schedule", "rows_to_bins"),
               counted("repro.core.schedule", "flops_per_row"),
               counted("repro.kernels.spgemm_hash.kernel", "symbolic_call")]
    try:
        c = plan.execute(a, b)
    finally:
        for mod, attr, orig in patched:
            setattr(mod, attr, orig)
    assert counts == {}, f"execute re-inspected: {counts}"
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)


def test_plan_cache_keys_on_structure_not_values():
    a, b, cd = _pair(seed=4)
    clear_plan_cache()
    plan = plan_spgemm(a, b)
    # same structure, new values -> hit; result reflects the new values
    a2 = dataclasses.replace(a, data=a.data * 3.0)
    assert plan_spgemm(a2, b) is plan
    c2 = plan.execute(a2, b)
    assert np.allclose(np.asarray(c2.to_dense()), 3.0 * cd, atol=1e-3)
    assert structure_key(a2) == structure_key(a)
    # different structure -> miss
    a3 = rmat_csr(5, 3, "G500", seed=5)
    assert structure_key(a3) != structure_key(a)
    assert plan_spgemm(a3, b) is not plan
    # different request on the same structure -> its own plan
    assert plan_spgemm(a, b, semiring="boolean") is not plan


def test_plan_heap_matches_dispatcher_sortedness_contract():
    """Explicit heap on unsorted inputs fails loudly (like spgemm_heap);
    only the recipe's auto choice is demoted to the hash family."""
    a, b, _ = _pair(seed=3)
    au = a.with_unsorted_flag()
    clear_plan_cache()
    with pytest.raises(AssertionError, match="sorted inputs"):
        plan_spgemm(au, b, algorithm="heap")
    assert plan_spgemm(au, b).algorithm != "heap"


def test_plan_bucket_caps_power_of_two_and_correct():
    a, b, cd = _pair(seed=12)
    clear_plan_cache()
    p = plan_spgemm(a, b, algorithm="hash", bucket_caps=True)
    for cap in (p.cap_c, p.flop_cap, p.row_cap):
        assert cap & (cap - 1) == 0, cap            # powers of two
    assert p.cap_c >= p.nnz_c and p.flop_cap >= p.total_flop
    assert p.nnz_c == int((cd != 0).sum())          # counts stay exact
    c = p.execute(a, b)
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)
    # bucketed and exact requests are distinct cache entries
    assert plan_spgemm(a, b, algorithm="hash") is not p


def test_plan_cache_lru_bound():
    from repro.core import plan as plan_mod
    clear_plan_cache()
    old_cap = plan_mod.PLAN_CACHE_CAPACITY
    plan_mod.PLAN_CACHE_CAPACITY = 2
    try:
        a, b, _ = _pair(seed=20)
        p1 = plan_spgemm(a, b)
        p2 = plan_spgemm(a, b, semiring="boolean")
        assert plan_spgemm(a, b) is p1              # refreshes p1's recency
        p3 = plan_spgemm(a, b, semiring="plus_first")
        assert plan_cache_stats()["size"] == 2
        assert plan_spgemm(a, b) is p1              # survived (recently used)
        assert plan_spgemm(a, b, semiring="plus_first") is p3
        assert plan_spgemm(a, b, semiring="boolean") is not p2  # evicted
    finally:
        plan_mod.PLAN_CACHE_CAPACITY = old_cap


def test_plan_cache_stats_reports_zero_for_empty_kinds():
    """A cold cache reports every registered kind with a zero count --
    dashboards can index stats['kinds'][kind] unconditionally instead of
    KeyError-ing until the first plan of that kind lands."""
    from repro.core import PLAN_KINDS
    clear_plan_cache()
    kinds = plan_cache_stats()["kinds"]
    assert set(PLAN_KINDS) <= set(kinds)
    assert all(kinds[k] == 0 for k in PLAN_KINDS)
    a, b, _ = _pair(seed=30)
    plan_spgemm(a, b)
    kinds = plan_cache_stats()["kinds"]
    assert kinds["spgemm"] == 1
    assert all(kinds[k] == 0 for k in PLAN_KINDS if k != "spgemm")


def test_plan_execute_rejects_mismatched_structure():
    a, b, _ = _pair(seed=6)
    clear_plan_cache()
    plan = plan_spgemm(a, b)
    other = rmat_csr(4, 3, "ER", seed=1)          # 16x16: wrong shape
    with pytest.raises(AssertionError, match="plan is for"):
        plan.execute(other, other)
    # same shape/cap, different nnz -> caught by the cheap check
    smaller = rmat_csr(5, 2, "G500", seed=99)
    if smaller.cap == a.cap:
        pytest.skip("rng produced equal caps; cheap check not exercised")
    with pytest.raises(AssertionError):
        plan.execute(smaller, b)


def test_spgemm_plan_kwarg_and_sorted_epilogue():
    a, b, cd = _pair(seed=8)
    clear_plan_cache()
    plan = plan_spgemm(a, b, algorithm="hash", sorted_output=True)
    c = spgemm(a, b, plan=plan)
    assert c.sorted_cols
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)
    cols, ip = np.asarray(c.indices), np.asarray(c.indptr)
    for i in range(c.n_rows):
        assert np.all(np.diff(cols[ip[i]:ip[i + 1]]) > 0)


def test_planned_bfs_and_triangles_match_unplanned():
    """The example's plan-cached loops give unchanged results."""
    from examples.graph_analytics import (multi_source_bfs,
                                          multi_source_bfs_masked,
                                          triangle_count)
    from repro.data.rmat import symmetrize
    clear_plan_cache()
    a = symmetrize(rmat_csr(6, 6, "G500", seed=2))
    ad = np.asarray(a.to_dense()).astype(np.int64)
    brute = int(np.trace(np.linalg.matrix_power(ad, 3)) // 6)
    assert triangle_count(a) == brute
    sources = [0, 5, 21]
    d_dense = np.asarray(multi_source_bfs(a, sources, n_hops=4))
    d_mask = np.asarray(multi_source_bfs_masked(a, sources, n_hops=4))
    assert np.array_equal(d_dense, d_mask)
    before = plan_cache_stats()
    d_again = np.asarray(multi_source_bfs_masked(a, sources, n_hops=4))
    after = plan_cache_stats()
    assert np.array_equal(d_mask, d_again)
    assert after["misses"] == before["misses"], \
        "repeat BFS must plan nothing new"
    assert after["hits"] > before["hits"]


def test_plan_cache_restore_refreshes_recency():
    """Re-storing an existing key at capacity must refresh its recency
    (pop-before-insert): the old in-place overwrite kept the key's stale
    dict position, so a just-refreshed plan was evicted as "least
    recent" by the very next store."""
    from repro.core import plan as plan_mod
    from repro.core.plan import cache_store, cache_lookup
    clear_plan_cache()
    old_cap = plan_mod.PLAN_CACHE_CAPACITY
    plan_mod.PLAN_CACHE_CAPACITY = 2
    try:
        cache_store(("spgemm", "k1"), "v1")
        cache_store(("spgemm", "k2"), "v2")
        cache_store(("spgemm", "k1"), "v1-refreshed")  # re-store at capacity
        cache_store(("spgemm", "k3"), "v3")            # must evict k2, not k1
        assert cache_lookup(("spgemm", "k1")) == "v1-refreshed"
        assert cache_lookup(("spgemm", "k3")) == "v3"
        assert cache_lookup(("spgemm", "k2")) is None  # the true LRU victim
    finally:
        plan_mod.PLAN_CACHE_CAPACITY = old_cap
        clear_plan_cache()
