"""Recipe (paper section 4.2.4 + Table 4): cost model + selector."""

from repro.core.recipe import (SpGEMMStats, choose_algorithm_from_stats,
                               cost_hash, cost_heap, model_costs,
                               measure_stats, choose_algorithm)
from repro.data.rmat import rmat_csr


def _stats(**kw):
    base = dict(n_rows=1000, n_cols=1000, nnz_a=16_000, flop=256_000,
                nnz_c_est=128_000, max_row_flop=64, mean_row_nnz_a=16,
                row_skew=2.0, compression_ratio=2.0, density_ef=16.0)
    base.update(kw)
    return SpGEMMStats(**base)


def test_eq1_eq2_crossover():
    """Hash wins when flop(c)/nnz(c) (compression ratio) is large; heap is
    competitive when rows are tiny -- paper section 4.2.4."""
    dense_stats = _stats(compression_ratio=16.0, nnz_c_est=16_000)
    sparse_stats = _stats(density_ef=2.0, mean_row_nnz_a=2, flop=4_000,
                          nnz_c_est=3_900, compression_ratio=1.02)
    assert cost_hash(dense_stats, False) < cost_heap(dense_stats)
    # in the very sparse regime the ordering tightens (log factor ~1)
    mc = model_costs(sparse_stats, sorted_output=True)
    assert mc["heap"] <= mc["hash"] * 2.0


def test_table4_lxu():
    assert choose_algorithm_from_stats(_stats(compression_ratio=1.5), True,
                                       "LxU") == "heap"
    assert choose_algorithm_from_stats(_stats(compression_ratio=4.0), True,
                                       "LxU") == "hash"


def test_table4_axa_sparse_uniform():
    s = _stats(density_ef=4.0, row_skew=2.0)
    assert choose_algorithm_from_stats(s, True, "AxA") == "heap"
    assert choose_algorithm_from_stats(s, False, "AxA") == "hash_vector"


def test_table4_axa_dense_skewed():
    s = _stats(density_ef=16.0, row_skew=32.0)
    assert choose_algorithm_from_stats(s, True, "AxA") == "hash"
    assert choose_algorithm_from_stats(s, False, "AxA") == "hash"


def test_table4_tall_skinny():
    s = _stats(density_ef=16.0)
    assert choose_algorithm_from_stats(s, False, "tall_skinny") == "hash"
    assert choose_algorithm_from_stats(s, True, "tall_skinny") == "hash_vector"


def test_measure_stats_on_real_inputs():
    a = rmat_csr(5, 3, "G500", seed=0)
    b = rmat_csr(5, 3, "G500", seed=1)
    s = measure_stats(a, b)
    assert s.n_rows == 32 and s.flop > 0
    assert s.row_skew >= 1.0
    algo = choose_algorithm(a, b)
    assert algo in ("hash", "hash_vector", "heap", "esc")


def test_skewed_has_higher_skew_stat():
    er = rmat_csr(7, 8, "ER", seed=0)
    g5 = rmat_csr(7, 8, "G500", seed=0)
    s_er = measure_stats(er, er)
    s_g5 = measure_stats(g5, g5)
    assert s_g5.row_skew > s_er.row_skew, \
        "G500 (power law) must look more skewed than ER"


def test_measure_stats_collects_exact_eq_sums():
    """Eq.1/Eq.2 per-row log sums are collected and match a numpy
    recompute (paper section 4.2.4; PR-8 mispricing bugfix)."""
    import numpy as np
    from repro.core.schedule import flops_per_row
    from repro.core.spgemm import symbolic

    a = rmat_csr(5, 3, "G500", seed=2)
    b = rmat_csr(5, 3, "ER", seed=3)
    row_nnz_c, _, _, _ = symbolic(a, b)
    s = measure_stats(a, b, row_nnz_c=row_nnz_c)
    flop = np.asarray(flops_per_row(a, b), dtype=np.float64)
    nnz_a_rows = np.asarray(a.row_nnz(), dtype=np.float64)
    rc = np.asarray(row_nnz_c, dtype=np.float64)
    eq1 = float((flop * np.log2(np.maximum(nnz_a_rows, 2.0))).sum())
    eq2 = float((rc * np.log2(np.maximum(rc, 2.0))).sum())
    assert s.eq1_heap_log > 0.0 and s.eq2_hash_sort > 0.0
    assert abs(s.eq1_heap_log - eq1) <= 1e-3 * max(eq1, 1.0)
    assert abs(s.eq2_hash_sort - eq2) <= 1e-3 * max(eq2, 1.0)


def test_mean_based_ranking_inverts_on_skewed_input():
    """The regression the exact sums fix: one full row + a diagonal tail.

    The mean row nnz is ~2, so the old ``flop * log2(mean)`` heap cost
    collapses to ``flop * 1`` and heap *beats* unsorted hash
    (``1.5 * flop``).  The exact Eq.1 sum concentrates the flop in the
    full row where ``log2 nnz(a_0*) = log2 n``, pricing heap several
    times above hash -- the mean-based model inverts the true ranking
    exactly in the skewed regime the paper says matters (G500).
    """
    import dataclasses
    import numpy as np
    from repro.core.formats import CSR

    n = 64
    dense = np.zeros((n, n), np.float32)
    dense[0, :] = 1.0                    # one heavy row: nnz = n
    idx = np.arange(1, n)
    dense[idx, idx] = 1.0                # tail rows: nnz = 1
    a = CSR.from_dense(dense)
    s = measure_stats(a, a)
    assert s.mean_row_nnz_a < 2.5        # mean hides the heavy row

    legacy = dataclasses.replace(s, eq1_heap_log=0.0, eq2_hash_sort=0.0)
    # mean-substituted model: heap "wins" against unsorted hash...
    assert cost_heap(legacy) < cost_hash(legacy, False)
    # ...the exact per-row sums invert that -- hash wins, by a margin
    assert cost_heap(s) > cost_hash(s, False) * 2.0


def test_block_density_pads_non_tile_multiple_shapes():
    """1000x1000-style shapes (not a tile multiple) used to probe as 0.0
    and silently disable bcsr routing; padding to the tile grid keeps a
    dense-blocked matrix block-dense and Table-4+TPU recommends bcsr."""
    import numpy as np
    from repro.core.formats import CSR
    from repro.core.recipe import block_density_of

    n = 100                              # not a multiple of the 8x8 tile
    a = CSR.from_dense(np.ones((n, n), np.float32))
    dens = block_density_of(a)
    assert dens > 0.9, f"padded probe diluted to {dens}"
    assert choose_algorithm(a, a, probe_blocks=True) == "bcsr"
