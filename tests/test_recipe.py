"""Recipe (paper section 4.2.4 + Table 4): cost model + selector."""

from repro.core.recipe import (SpGEMMStats, choose_algorithm_from_stats,
                               cost_hash, cost_heap, model_costs,
                               measure_stats, choose_algorithm)
from repro.data.rmat import rmat_csr


def _stats(**kw):
    base = dict(n_rows=1000, n_cols=1000, nnz_a=16_000, flop=256_000,
                nnz_c_est=128_000, max_row_flop=64, mean_row_nnz_a=16,
                row_skew=2.0, compression_ratio=2.0, density_ef=16.0)
    base.update(kw)
    return SpGEMMStats(**base)


def test_eq1_eq2_crossover():
    """Hash wins when flop(c)/nnz(c) (compression ratio) is large; heap is
    competitive when rows are tiny -- paper section 4.2.4."""
    dense_stats = _stats(compression_ratio=16.0, nnz_c_est=16_000)
    sparse_stats = _stats(density_ef=2.0, mean_row_nnz_a=2, flop=4_000,
                          nnz_c_est=3_900, compression_ratio=1.02)
    assert cost_hash(dense_stats, False) < cost_heap(dense_stats)
    # in the very sparse regime the ordering tightens (log factor ~1)
    mc = model_costs(sparse_stats, sorted_output=True)
    assert mc["heap"] <= mc["hash"] * 2.0


def test_table4_lxu():
    assert choose_algorithm_from_stats(_stats(compression_ratio=1.5), True,
                                       "LxU") == "heap"
    assert choose_algorithm_from_stats(_stats(compression_ratio=4.0), True,
                                       "LxU") == "hash"


def test_table4_axa_sparse_uniform():
    s = _stats(density_ef=4.0, row_skew=2.0)
    assert choose_algorithm_from_stats(s, True, "AxA") == "heap"
    assert choose_algorithm_from_stats(s, False, "AxA") == "hash_vector"


def test_table4_axa_dense_skewed():
    s = _stats(density_ef=16.0, row_skew=32.0)
    assert choose_algorithm_from_stats(s, True, "AxA") == "hash"
    assert choose_algorithm_from_stats(s, False, "AxA") == "hash"


def test_table4_tall_skinny():
    s = _stats(density_ef=16.0)
    assert choose_algorithm_from_stats(s, False, "tall_skinny") == "hash"
    assert choose_algorithm_from_stats(s, True, "tall_skinny") == "hash_vector"


def test_measure_stats_on_real_inputs():
    a = rmat_csr(5, 3, "G500", seed=0)
    b = rmat_csr(5, 3, "G500", seed=1)
    s = measure_stats(a, b)
    assert s.n_rows == 32 and s.flop > 0
    assert s.row_skew >= 1.0
    algo = choose_algorithm(a, b)
    assert algo in ("hash", "hash_vector", "heap", "esc")


def test_skewed_has_higher_skew_stat():
    er = rmat_csr(7, 8, "ER", seed=0)
    g5 = rmat_csr(7, 8, "G500", seed=0)
    s_er = measure_stats(er, er)
    s_g5 = measure_stats(g5, g5)
    assert s_g5.row_skew > s_er.row_skew, \
        "G500 (power law) must look more skewed than ER"
