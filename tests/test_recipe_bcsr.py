"""TPU block-density extension of the recipe + BCSR dispatch path."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import CSR, spgemm
from repro.core.recipe import block_density_of, choose_algorithm

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def _block_clustered(rng, m, n, bm, bn, p_tile, fill):
    occ = rng.random((m // bm, n // bn)) < p_tile
    x = rng.uniform(0.5, 1.5, (m, n)).astype(np.float32)
    tile_mask = np.kron(occ, np.ones((bm, bn))) > 0
    elem_mask = rng.random((m, n)) < fill
    return np.where(tile_mask & elem_mask, x, 0.0)


def test_block_density_probe():
    rng = np.random.default_rng(0)
    dense_tiles = _block_clustered(rng, 64, 64, 8, 8, 0.3, 1.0)
    a = CSR.from_dense(jnp.asarray(dense_tiles))
    assert block_density_of(a) > 0.9
    scattered = np.zeros((64, 64), np.float32)
    idx = rng.choice(64 * 64, 100, replace=False)
    scattered.ravel()[idx] = 1.0
    b = CSR.from_dense(jnp.asarray(scattered))
    assert block_density_of(b) < 0.25


def test_recipe_prefers_bcsr_for_clustered():
    rng = np.random.default_rng(1)
    a = CSR.from_dense(jnp.asarray(_block_clustered(rng, 64, 64, 8, 8,
                                                    0.3, 1.0)))
    assert choose_algorithm(a, a, probe_blocks=True) == "bcsr"
    # scattered input keeps the scalar-regime choice
    scattered = np.zeros((64, 64), np.float32)
    idx = rng.choice(64 * 64, 200, replace=False)
    scattered.ravel()[idx] = 1.0
    b = CSR.from_dense(jnp.asarray(scattered))
    assert choose_algorithm(b, b, probe_blocks=True) != "bcsr"


@given(seed=st.integers(0, 8))
def test_bcsr_dispatch_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    ad = _block_clustered(rng, 32, 32, 8, 8, 0.4, 1.0)
    bd = _block_clustered(rng, 32, 32, 8, 8, 0.4, 1.0)
    a = CSR.from_dense(jnp.asarray(ad))
    b = CSR.from_dense(jnp.asarray(bd))
    cd = ad @ bd
    cap = max(int((cd != 0).sum()), 1) + 8
    c = spgemm(a, b, cap_c=cap, algorithm="bcsr", n_bins=2)
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-2)


@given(m=st.sampled_from([8, 16]), n=st.sampled_from([8, 16, 24]),
       k=st.sampled_from([8, 16]), density=st.floats(0.05, 0.6),
       seed=st.integers(0, 6))
def test_hash_equals_esc_on_arbitrary_patterns(m, n, k, density, seed):
    """Hash kernel == ESC on arbitrary (non-graph) rectangular patterns,
    including empty rows/columns."""
    rng = np.random.default_rng(seed)
    ad = np.where(rng.random((m, k)) < density,
                  rng.normal(size=(m, k)), 0).astype(np.float32)
    bd = np.where(rng.random((k, n)) < density,
                  rng.normal(size=(k, n)), 0).astype(np.float32)
    ad[m // 2] = 0          # force an empty row
    bd[:, n // 2] = 0       # force an empty column
    a = CSR.from_dense(jnp.asarray(ad))
    b = CSR.from_dense(jnp.asarray(bd))
    cd = ad @ bd
    cap = max(int((cd != 0).sum()), 1) + 8
    c_hash = spgemm(a, b, cap_c=cap, algorithm="hash", n_bins=2)
    c_esc = spgemm(a, b, cap_c=cap, algorithm="esc",
                   flop_cap=max(m * k * n, 1))
    assert np.allclose(np.asarray(c_hash.to_dense()), cd, atol=1e-4)
    assert np.allclose(np.asarray(c_esc.to_dense()), cd, atol=1e-4)
