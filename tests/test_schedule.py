"""Property tests for the load-balanced scheduler (paper C1, Fig. 6)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import schedule as sched
from repro.data.rmat import rmat_csr

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(seed=st.integers(0, 50), n_bins=st.sampled_from([1, 2, 4, 8, 16]))
def test_bins_invariants(seed, n_bins):
    rng = np.random.default_rng(seed)
    flop = jnp.asarray(rng.integers(0, 50, size=32).astype(np.int32))
    off = np.asarray(sched.rows_to_bins(flop, n_bins))
    assert off[0] == 0 and off[-1] == 32
    assert np.all(np.diff(off) >= 0)
    total = int(flop.sum())
    bf = np.asarray(sched.bin_flop(flop, jnp.asarray(off)))
    assert bf.sum() == total
    # balance bound: every bin <= ceil(total/n_bins) + max_row_flop
    bound = -(-total // n_bins) + int(flop.max()) if total else 0
    assert bf.max() <= max(bound, 0) + 1


@given(seed=st.integers(0, 20))
def test_flops_per_row_matches_bruteforce(seed):
    a = rmat_csr(5, 3, "G500", seed=seed)
    b = rmat_csr(5, 3, "ER", seed=seed + 1)
    flop = np.asarray(sched.flops_per_row(a, b))
    ad = (np.asarray(a.to_dense()) != 0)
    bd = (np.asarray(b.to_dense()) != 0)
    expect = (ad.astype(np.int64) @ bd.sum(axis=1)).astype(np.int64)
    assert np.array_equal(flop, expect)


def test_lowbnd():
    vec = jnp.asarray([1, 3, 3, 7, 10])
    assert int(sched.lowbnd(vec, 3)) == 1
    assert int(sched.lowbnd(vec, 4)) == 3
    assert int(sched.lowbnd(vec, 0)) == 0
    assert int(sched.lowbnd(vec, 11)) == 5


def test_lowest_p2():
    assert sched.lowest_p2(1) == 1
    assert sched.lowest_p2(2) == 2
    assert sched.lowest_p2(3) == 4
    assert sched.lowest_p2(1000) == 1024


try:
    from _fuzz import degenerate_partition_case
    HAVE_DEGEN = True
except ImportError:
    HAVE_DEGEN = False


@pytest.mark.skipif(not HAVE_DEGEN, reason="hypothesis unavailable")
@given(case=degenerate_partition_case() if HAVE_DEGEN else st.none())
def test_equal_weight_partition_degenerate_invariants(case):
    w, n_parts = case
    n, total = w.shape[0], int(w.sum())
    starts = np.asarray(sched.equal_weight_partition(w, n_parts))
    assert starts.shape == (n_parts + 1,)
    assert starts[0] == 0 and starts[-1] == n
    assert np.all(np.diff(starts) >= 0)
    # balance: every part's weight <= ceil(total/n_parts) + max weight
    bound = -(-total // n_parts) + (int(w.max()) if n else 0)
    for s in range(n_parts):
        assert int(w[starts[s]:starts[s + 1]].sum()) <= max(bound, 0)
    # zero totals must not collapse onto one part
    if total == 0 and n >= n_parts:
        assert np.diff(starts).max() <= -(-n // n_parts)


@given(seed=st.integers(0, 10))
def test_max_flop_per_bin_row_bounds_table(seed):
    a = rmat_csr(5, 4, "G500", seed=seed)
    b = rmat_csr(5, 4, "G500", seed=seed + 1)
    flop, offsets, tsize = sched.make_schedule(a, b, 4)
    flop, offsets, tsize = (np.asarray(flop), np.asarray(offsets),
                            np.asarray(tsize))
    for t in range(4):
        rows = range(offsets[t], offsets[t + 1])
        if len(list(rows)):
            m = max(flop[r] for r in rows)
            assert tsize[t] >= min(m, b.n_cols)
